"""Global pull-based admission tier vs static K-shard partitioning.

Scenarios the static partition can't balance (see core/admission.py):

* ``skewed`` — a contiguous hot block of VUs (near-zero think time, heavy
  functions) that ``ShardedSimulator``'s contiguous VU split concentrates on
  the first shard(s), run under memory pressure so the hot shard also
  thrashes cold starts.  Static partitioning (``backend="process"``, the
  scale-out default) vs the pull-based admission tier, same global VU
  programs, reporting cross-shard load CV, p99 and cold rate.
* ``burst`` — arrival waves of mixed hot/cold VUs (admission-time skew).
  Pull admission vs the arrival-capable naive baseline (``round_robin``
  binding on arrival), pull reacting to live per-shard pressure.

Acceptance (pinned by tests/test_admission.py): pull admission beats the
static partition on cross-shard load CV under the skewed scenario while the
static path stays byte-identical to the frozen seed engine.
"""

from __future__ import annotations

import time

FULL = dict(n_shards=4, n_workers=32, n_vus=96, duration_s=40.0, mem_pool_mb=1024.0)
QUICK = dict(n_shards=2, n_workers=8, n_vus=24, duration_s=10.0, mem_pool_mb=1024.0)


def _fmt(shard_counts, metrics, extra: str = "") -> str:
    from repro.core.admission import load_cv_across_shards

    cv = load_cv_across_shards(shard_counts)
    s = (
        f"shard_cv={cv:.3f};p99_ms={metrics.p99_ms:.0f};"
        f"mean_ms={metrics.mean_latency_ms:.0f};cold={metrics.cold_rate:.3f};"
        f"worker_cv={metrics.load_cv:.2f};requests={metrics.n_requests}"
    )
    return s + (";" + extra if extra else "")


def run(quick: bool = False):
    import numpy as np

    from repro.core import SimConfig, default_n_events
    from repro.core.admission import (
        AdmissionConfig,
        AdmissionSimulator,
        load_cv_across_shards,
        make_skewed_programs,
    )
    from repro.core.shard import ShardedSimulator

    from .common import save_json

    p = QUICK if quick else FULL
    K, W, VUS, DUR = p["n_shards"], p["n_workers"], p["n_vus"], p["duration_s"]
    cfg = SimConfig(mem_pool_mb=p["mem_pool_mb"])
    seed = 0
    rows = []
    payload = {"params": p}

    # ---------------------------------------------------- skewed hot block
    adm = AdmissionSimulator(K, W, scheduler="hiku", cfg=cfg, seed=seed)
    n_events = default_n_events(DUR)
    programs = make_skewed_programs(adm.funcs, VUS, n_events, seed, hot_frac=0.25)

    t0 = time.perf_counter()
    static = ShardedSimulator(K, W, scheduler="hiku", cfg=cfg, seed=seed,
                              backend="process").run(VUS, DUR, programs=programs)
    wall_static = time.perf_counter() - t0
    m_static = static.summarize(DUR)
    static_counts = [len(r.records) for r in static.shards]

    t0 = time.perf_counter()
    pull = adm.run(VUS, DUR, programs=programs)
    wall_pull = time.perf_counter() - t0
    m_pull = pull.summarize(DUR)
    pull_counts = pull.shard_requests.tolist()

    cv_static = load_cv_across_shards(static_counts)
    cv_pull = load_cv_across_shards(pull_counts)
    rows.append(
        (
            "admission/skewed/static_process",
            wall_static / max(m_static.n_requests, 1) * 1e6,
            _fmt(static_counts, m_static),
        )
    )
    rows.append(
        (
            "admission/skewed/pull",
            wall_pull / max(m_pull.n_requests, 1) * 1e6,
            _fmt(pull_counts, m_pull,
                 extra=f"cv_vs_static={cv_pull / max(cv_static, 1e-9):.3f}x;"
                       f"admitted={pull.admitted}"),
        )
    )
    payload["skewed"] = {
        "static": {"shard_requests": static_counts, "cv": cv_static,
                   "p99_ms": m_static.p99_ms, "cold_rate": m_static.cold_rate},
        "pull": {"shard_requests": pull_counts, "cv": cv_pull,
                 "p99_ms": m_pull.p99_ms, "cold_rate": m_pull.cold_rate,
                 "pulls": [s.pulls for s in pull.shards]},
    }

    # ------------------------------------------------------- arrival waves
    n_waves = 2 if quick else 4
    wave_gap = DUR / (n_waves + 1)
    arrivals = np.asarray([(vu % n_waves) * wave_gap for vu in range(VUS)])
    wave_progs = make_skewed_programs(adm.funcs, VUS, n_events, seed + 1, hot_frac=0.5)
    results = {}
    for policy in ("round_robin", "pull"):
        drv = AdmissionSimulator(
            K, W, scheduler="hiku", cfg=cfg, seed=seed,
            admission=AdmissionConfig(policy=policy),
        )
        t0 = time.perf_counter()
        r = drv.run(VUS, DUR, programs=wave_progs, arrivals=arrivals)
        wall = time.perf_counter() - t0
        m = r.summarize(DUR)
        results[policy] = r
        rows.append(
            (
                f"admission/burst/{policy}",
                wall / max(m.n_requests, 1) * 1e6,
                _fmt(r.shard_requests.tolist(), m,
                     extra=f"peak_queue={int(r.queue_depth.max(initial=0))};"
                           f"admitted={r.admitted}"),
            )
        )
    payload["burst"] = {
        pol: {"shard_requests": results[pol].shard_requests.tolist(),
              "cv": results[pol].shard_load_cv,
              "admitted": results[pol].admitted}
        for pol in results
    }
    save_json("admission", payload)
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
