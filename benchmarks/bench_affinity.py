"""Warm-locality affinity routing: cold-start rate vs the pull baseline.

The §11 acceptance benchmark (docs/ARCHITECTURE.md): the ``affinity``
admission policy routes each VU toward the shard whose per-function warm-set
digest (``Simulator.warm_digest``) says its program can start warm, scoring
shards by expected warm-hit probability against effective pressure.  The
claim to prove is the KV-router analog of Hiku's pull principle: on
locality-skewed traffic, digest-aware placement cuts the cold-start rate
below pressure-only placement *without* giving back tail latency.

Protocol — the 4-shard admission matrix on two locality-skewed scenarios:

* ``heavy_tail`` — 30% elephant VUs hammer the heavy warm-cost quartile:
  strong per-VU locality the digest can exploit;
* ``diurnal`` — sine-modulated arrivals, Azure-weighted uniform profiles:
  weak profile skew, so most of the win must come from first-call warmth.

Columns: ``pull`` (pressure only), ``cost`` (pressure x warm headroom),
``pull+steal`` (post-admission rebalancing), ``affinity`` (digest routing),
``affinity+steal`` (digest routing + warm-locality stealing).  The full
protocol aggregates over :data:`FULL_SEEDS`; ``--quick`` is one seed on the
2-shard matrix for CI smoke.

Acceptance rows (pinned by .github/workflows/ci.yml's grep and eyeballed in
benchmarks/results/): ``affinity/<scenario>/affinity_vs_pull`` must show
``cold_affinity < cold_pull`` with ``p99_affinity <= ~p99_pull`` on both
scenarios.
"""

from __future__ import annotations

import time
import warnings

FULL = dict(n_shards=4, n_workers=32, n_vus=96, duration_s=40.0, mem_pool_mb=1024.0)
QUICK = dict(n_shards=2, n_workers=8, n_vus=32, duration_s=14.0, mem_pool_mb=1024.0)

FULL_SEEDS = (0, 1, 2)
QUICK_SEEDS = (0,)

SCENARIOS = ("heavy_tail", "diurnal")
COLUMNS = ("pull", "cost", "pull+steal", "affinity", "affinity+steal")


def run_cell(policy: str, scenario_name: str, p: dict, seed: int = 0):
    """One (policy, scenario, seed) cell -> (run, metrics)."""
    from repro.core import SimConfig, make_functions
    from repro.core.admission import AdmissionConfig, AdmissionSimulator
    from repro.core.workloads import make_scenario

    # fixed function population, seeded traffic + engines: the seed axis
    # varies arrivals/programs/service draws, not the workload's shape
    funcs = make_functions(seed=0)
    scn = make_scenario(scenario_name, funcs, p["n_vus"], p["duration_s"], seed=seed)
    adm = AdmissionSimulator(
        p["n_shards"], p["n_workers"], scheduler="hiku",
        cfg=SimConfig(mem_pool_mb=p["mem_pool_mb"]), seed=seed,
        admission=AdmissionConfig(policy=policy, steal_watermark=1.25),
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        r = adm.run(scn.n_vus, p["duration_s"], **scn.run_kwargs())
    return r, r.summarize(p["duration_s"])


def _mean(xs):
    return sum(xs) / len(xs)


def run(quick: bool = False):
    from .common import save_json

    p = QUICK if quick else FULL
    seeds = QUICK_SEEDS if quick else FULL_SEEDS
    rows = []
    payload = {"params": dict(p), "seeds": list(seeds), "columns": list(COLUMNS)}
    for scn_name in SCENARIOS:
        agg = {}
        cell_json = {}
        for col in COLUMNS:
            t0 = time.perf_counter()
            ms = [run_cell(col, scn_name, p, seed=s)[1] for s in seeds]
            wall = time.perf_counter() - t0
            n_req = sum(m.n_requests for m in ms)
            cold = _mean([m.cold_rate for m in ms])
            p99 = _mean([m.p99_ms for m in ms])
            mean_ms = _mean([m.mean_latency_ms for m in ms])
            agg[col] = (cold, p99)
            cell_json[col.replace("+", "_")] = {
                "cold_rate": cold,
                "p99_ms": p99,
                "mean_ms": mean_ms,
                "cold_rate_per_seed": [m.cold_rate for m in ms],
                "p99_ms_per_seed": [m.p99_ms for m in ms],
                "n_requests": n_req,
            }
            rows.append(
                (
                    f"affinity/{scn_name}/{col}",
                    wall / max(n_req, 1) * 1e6,
                    f"cold_rate={cold:.4f};p99_ms={p99:.0f};"
                    f"mean_ms={mean_ms:.0f};seeds={len(seeds)};requests={n_req}",
                )
            )
        payload[scn_name] = cell_json
        # the §11 acceptance row: digest routing vs pressure-only placement
        cold_pull, p99_pull = agg["pull"]
        cold_aff, p99_aff = agg["affinity"]
        rows.append(
            (
                f"affinity/{scn_name}/affinity_vs_pull",
                0.0,
                f"cold_pull={cold_pull:.4f};cold_affinity={cold_aff:.4f};"
                f"cold_delta={cold_aff - cold_pull:+.4f};"
                f"p99_pull={p99_pull:.0f};p99_affinity={p99_aff:.0f};"
                f"p99_delta_ms={p99_aff - p99_pull:+.0f}",
            )
        )
    save_json("affinity", payload)
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
