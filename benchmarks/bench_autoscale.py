"""Autoscale matrix: sizing modes x bursty scenarios, costed in worker-seconds.

The elasticity counterpart of ``bench_chaos``: the same seeded bursty
scenarios (``flash_crowd``, ``diurnal``, ``on_off``) run under three pool
sizing modes —

* ``static`` — the full partition alive for the whole run (the baseline
  every prior benchmark used); cost = ``n_workers * duration_s``.
* ``reactive`` — :class:`~repro.core.autoscale.Autoscaler` feedback on the
  *current* window's load only (threshold autoscaling).
* ``predictive`` — the reactive floor plus the EWMA+trend / Welford
  forecast sized over an MPC-style horizon (Nguyen et al., PAPERS.md):
  capacity is provisioned for the worst forecast window, before the burst.

Per cell: provisioned cost (worker-seconds, the axis elasticity is bought
on), p99 / mean latency, cold rate, autoscaler actions, lost tasks.  Every
scenario also runs with an active ``spot_preemption`` chaos plan — fault
events and autoscaler actions interleave on the same engine hooks — so
the matrix shows sizing composing with failures, not dodging them.

Acceptance (the §14 / ROADMAP item-4 target, greppable rows):
``autoscale/<scenario>/predictive_vs_static`` must show predictive sizing
**cheaper** than the static pool (cost_frac < 1) at **equal p99**
(p99_frac <= 1.02) on ``flash_crowd`` and ``diurnal``, plain and under
the chaos plan (``autoscale/<scenario>+spot/...``).  Static runs byte-
match the no-bus engine (tests/test_stream.py, tests/test_equivalence.py).
"""

from __future__ import annotations

import time
import warnings

FULL = dict(n_shards=4, n_workers=32, n_vus=96, duration_s=40.0, mem_pool_mb=1024.0)
QUICK = dict(n_shards=2, n_workers=12, n_vus=32, duration_s=14.0, mem_pool_mb=1024.0)

SCENARIOS = ("flash_crowd", "diurnal", "on_off")
QUICK_SCENARIOS = ("flash_crowd",)
MODES = ("static", "reactive", "predictive")

#: scenarios the acceptance criterion binds (ROADMAP item 4); ``on_off``
#: rides along as an informational cell (accept=INFO) — its square-wave
#: troughs make retire/revive churn a judgement call, not a contract
REQUIRED = ("flash_crowd", "diurnal")

#: sizing knobs shared by both autoscaled modes (tuned on the FULL
#: protocol: enough headroom + downscale hysteresis that retiring warmth
#: doesn't churn cold starts through the diurnal trough/crest cycle)
KNOBS = dict(
    window_s=1.0, target_pressure=0.55, horizon_windows=4,
    down_after=2, notice_s=1.0,
)


def make_autoscaler(mode: str):
    """Fresh per-run Autoscaler (forecast state is per-run), or None."""
    from repro.core import AutoscaleConfig, Autoscaler

    if mode == "static":
        return None
    return Autoscaler(AutoscaleConfig(mode=mode, **KNOBS))


def spot_plan(p: dict, seed: int = 0):
    """The active chaos plan the matrix composes with: two preemption
    waves with notice windows and delayed replacements."""
    from repro.core import chaos

    dur = p["duration_s"]
    return chaos.spot_preemption(
        p["n_workers"], n_waves=2, wave_size=max(1, p["n_workers"] // 8),
        t0=0.25 * dur, t1=0.6 * dur, notice_s=2.0, replace_after_s=4.0,
        seed=seed,
    )


def run_cell(mode: str, scenario, p: dict, seed: int = 0):
    """One (sizing mode, scenario) cell -> (run, metrics, autoscaler)."""
    from repro.core import SimConfig
    from repro.core.admission import AdmissionConfig, AdmissionSimulator

    adm = AdmissionSimulator(
        p["n_shards"], p["n_workers"], scheduler="hiku",
        cfg=SimConfig(mem_pool_mb=p["mem_pool_mb"]), seed=seed,
        admission=AdmissionConfig(),
    )
    asc = make_autoscaler(mode)
    kw = scenario.run_kwargs()
    if asc is not None:
        kw["autoscaler"] = asc
    with warnings.catch_warnings():
        # shrunken pools legitimately leave some VUs unadmitted mid-trough
        warnings.simplefilter("ignore", RuntimeWarning)
        r = adm.run(scenario.n_vus, p["duration_s"], **kw)
    return r, r.summarize(p["duration_s"]), asc


def _fmt(r, m, asc) -> str:
    n_act = len(asc.actuator.actions) if asc is not None else 0
    return (
        f"cost_ws={r.worker_seconds:.0f};p99_ms={m.p99_ms:.0f};"
        f"mean_ms={m.mean_latency_ms:.0f};cold_rate={m.cold_rate:.4f};"
        f"actions={n_act};lost={r.lost_tasks};stranded={r.stranded};"
        f"requests={m.n_requests}"
    )


def run(quick: bool = False):
    import dataclasses

    from repro.core import make_functions
    from repro.core.workloads import make_scenario

    from .common import save_json

    p = QUICK if quick else FULL
    seed = 0
    funcs = make_functions(seed=seed)
    scn_names = QUICK_SCENARIOS if quick else SCENARIOS
    chaos_variants = (False, True)
    rows = []
    payload = {
        "params": dict(p), "modes": list(MODES), "knobs": dict(KNOBS),
        "scenarios": [
            s + ("+spot" if c else "") for c in chaos_variants for s in scn_names
        ],
    }
    for with_chaos in chaos_variants:
        for sname in scn_names:
            scn = make_scenario(sname, funcs, p["n_vus"], p["duration_s"], seed=seed)
            if with_chaos:
                scn = dataclasses.replace(scn, faults=spot_plan(p, seed=seed))
            tag = sname + ("+spot" if with_chaos else "")
            cell = {}
            for mode in MODES:
                t0 = time.perf_counter()
                r, m, asc = run_cell(mode, scn, p, seed=seed)
                wall = time.perf_counter() - t0
                cell[mode] = (r, m, asc)
                rows.append(
                    (
                        f"autoscale/{tag}/{mode}",
                        wall / max(m.n_requests, 1) * 1e6,
                        _fmt(r, m, asc),
                    )
                )
            payload[tag] = {
                mode: {
                    "cost_worker_seconds": r.worker_seconds,
                    "p99_ms": m.p99_ms,
                    "mean_ms": m.mean_latency_ms,
                    "cold_rate": m.cold_rate,
                    "actions": len(asc.actuator.actions) if asc else 0,
                    "lost_tasks": r.lost_tasks,
                    "n_requests": m.n_requests,
                }
                for mode, (r, m, asc) in cell.items()
            }
            # the acceptance row: predictive sizing vs the static pool —
            # cheaper capacity (cost_frac < 1) at equal p99 (<= 1.02)
            (r_st, m_st, _) = cell["static"]
            (r_pr, m_pr, _) = cell["predictive"]
            cost_frac = r_pr.worker_seconds / max(r_st.worker_seconds, 1e-9)
            p99_frac = m_pr.p99_ms / max(m_st.p99_ms, 1e-9)
            ok = cost_frac < 1.0 and p99_frac <= 1.02
            required = sname in REQUIRED
            accept = ("PASS" if ok else "FAIL") if required else "INFO"
            rows.append(
                (
                    f"autoscale/{tag}/predictive_vs_static",
                    0.0,
                    f"cost_frac={cost_frac:.3f};p99_frac={p99_frac:.3f};"
                    f"cost_static={r_st.worker_seconds:.0f};"
                    f"cost_predictive={r_pr.worker_seconds:.0f};"
                    f"p99_static={m_st.p99_ms:.0f};"
                    f"p99_predictive={m_pr.p99_ms:.0f};"
                    f"accept={accept}",
                )
            )
            payload[tag]["predictive_vs_static"] = {
                "cost_frac": cost_frac, "p99_frac": p99_frac, "accept": ok,
                "required": required,
            }
    save_json("autoscale", payload)
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
