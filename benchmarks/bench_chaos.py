"""Chaos matrix: every admission policy under every fault pattern.

The failure-mode counterpart of ``bench_policies``: the same seeded
``on_off`` bursty traffic runs under each declarative fault plan from
``core.chaos`` —

* ``shard_kill`` — a correlated wave kills every worker of shard 0 (the
  "rack loses power" pattern that strands queued work without salvage);
* ``spot`` — preemption waves with a notice window and autoscaler
  replacements (policies see the doomed workers coming);
* ``rolling`` — a deterministic rolling restart marching through the fleet;
* ``flappy`` — gray failure: workers cycling crash/repair forever.

Per cell: p99 / mean latency for surviving traffic, stranded tasks (queued
work left on dead shards — the §10 acceptance signal, 0 with salvage on),
lost tasks + lost rate (retry budget exhausted), resubmits, salvage count,
and recovery-latency percentiles (first failure to eventual completion).

Two baselines run beside the registered policies on every scenario, both
under the default ``pull`` policy:

* ``pull@nosalvage`` — modern retry/backoff but ``AdmissionConfig(salvage=
  False)``: dead-shard work strands;
* ``pull@legacy`` — the pre-chaos engine emulated exactly
  (``retry_budget=None, retry_backoff=1.0, salvage off``): infinite flat
  retries, no salvage — requests on dead shards spin forever as stranded
  outstanding work.

Acceptance (pinned by tests/test_chaos.py): under ``shard_kill``, salvage
strands zero queued tasks while both baselines strand > 0, and the salvage
run's lost rate is below the no-salvage baseline's effective loss at
comparable surviving-traffic p99.
"""

from __future__ import annotations

import time
import warnings

FULL = dict(n_shards=4, n_workers=32, n_vus=96, duration_s=40.0, mem_pool_mb=1024.0)
QUICK = dict(n_shards=2, n_workers=8, n_vus=32, duration_s=14.0, mem_pool_mb=1024.0)

FULL_FAULTS = ("shard_kill", "spot", "rolling", "flappy")
QUICK_FAULTS = ("shard_kill", "rolling")

#: baseline engine/tier configs beside the policy matrix (policy is pull)
BASELINES = ("pull@nosalvage", "pull@legacy")


def make_plan(name: str, p: dict, seed: int = 0):
    """Compile fault scenario ``name`` for protocol ``p`` (pure function)."""
    from repro.core import chaos

    n_shards, n_workers, dur = p["n_shards"], p["n_workers"], p["duration_s"]
    if name == "shard_kill":
        return chaos.shard_kill_wave(
            n_shards, n_workers, shards=[0], t_kill=0.35 * dur, jitter_s=0.2,
            seed=seed,
        )
    if name == "spot":
        return chaos.spot_preemption(
            n_workers, n_waves=2, wave_size=max(1, n_workers // 8),
            t0=0.25 * dur, t1=0.6 * dur, notice_s=2.0, replace_after_s=4.0,
            seed=seed,
        )
    if name == "rolling":
        return chaos.rolling_restart(
            n_workers, t0=0.3 * dur, downtime_s=2.0, stagger_s=1.0,
            batch=max(1, n_workers // 8),
        )
    if name == "flappy":
        return chaos.flappy_workers(
            range(0, n_workers, 4), dur, mtbf_s=8.0, mttr_s=2.0, t0=1.0,
            seed=seed,
        )
    raise ValueError(f"unknown fault scenario {name!r}")


def run_cell(policy: str, scenario, p: dict, seed: int = 0):
    """One (policy-or-baseline, fault scenario) cell -> (run, metrics).

    ``policy`` is a registered policy name, or one of :data:`BASELINES`
    (``pull`` admission with salvage off / the legacy engine emulated).
    """
    from repro.core import SimConfig
    from repro.core.admission import AdmissionConfig, AdmissionSimulator

    cfg_kw = dict(mem_pool_mb=p["mem_pool_mb"])
    adm_kw = dict(policy="pull" if policy in BASELINES else policy,
                  steal_watermark=1.25)
    if policy in BASELINES:
        adm_kw["salvage"] = False
    if policy == "pull@legacy":
        cfg_kw.update(retry_budget=None, retry_backoff=1.0)
    adm = AdmissionSimulator(
        p["n_shards"], p["n_workers"], scheduler="hiku",
        cfg=SimConfig(**cfg_kw), seed=seed,
        admission=AdmissionConfig(**adm_kw),
    )
    with warnings.catch_warnings():
        # killed capacity legitimately leaves VUs unadmitted mid-outage
        warnings.simplefilter("ignore", RuntimeWarning)
        r = adm.run(scenario.n_vus, p["duration_s"], **scenario.run_kwargs())
    return r, r.summarize(p["duration_s"])


def _fmt(r, m) -> str:
    return (
        f"p99_ms={m.p99_ms:.0f};mean_ms={m.mean_latency_ms:.0f};"
        f"stranded={r.stranded};lost={r.lost_tasks};"
        f"lost_rate={m.lost_task_rate:.4f};resubmits={r.resubmits};"
        f"salvages={r.n_salvages};rec_p99_ms={m.recovery_p99_ms:.0f};"
        f"requests={m.n_requests}"
    )


def run(quick: bool = False):
    import dataclasses

    from repro.core import make_functions
    from repro.core.policies import available_policies
    from repro.core.workloads import make_scenario

    from .common import save_json

    p = QUICK if quick else FULL
    seed = 0
    funcs = make_functions(seed=seed)
    columns = list(available_policies()) + list(BASELINES)
    fault_names = QUICK_FAULTS if quick else FULL_FAULTS
    base = make_scenario("on_off", funcs, p["n_vus"], p["duration_s"], seed=seed)
    rows = []
    payload = {"params": dict(p), "columns": columns, "faults": list(fault_names)}
    for fname in fault_names:
        plan = make_plan(fname, p, seed=seed)
        scn = dataclasses.replace(base, faults=plan)
        cell = {}
        for col in columns:
            t0 = time.perf_counter()
            r, m = run_cell(col, scn, p, seed=seed)
            wall = time.perf_counter() - t0
            cell[col] = (r, m)
            rows.append(
                (
                    f"chaos/{fname}/{col}",
                    wall / max(m.n_requests, 1) * 1e6,
                    _fmt(r, m),
                )
            )
        payload[fname] = {
            "plan": {"name": plan.name, "n_events": len(plan),
                     "horizon_s": plan.horizon},
            **{
                col.replace("+", "_").replace("@", "_"): {
                    "p99_ms": m.p99_ms,
                    "mean_ms": m.mean_latency_ms,
                    "stranded": r.stranded,
                    "lost_tasks": r.lost_tasks,
                    "lost_task_rate": m.lost_task_rate,
                    "resubmits": r.resubmits,
                    "salvages": r.n_salvages,
                    "recovery_p50_ms": m.recovery_p50_ms,
                    "recovery_p99_ms": m.recovery_p99_ms,
                    "n_requests": m.n_requests,
                }
                for col, (r, m) in cell.items()
            },
        }
        if fname == "shard_kill":
            # the §10 acceptance row: salvage vs the stranding baselines
            (r_pull, m_pull) = cell["pull"]
            (r_nosal, m_nosal) = cell["pull@nosalvage"]
            (r_leg, _) = cell["pull@legacy"]
            rows.append(
                (
                    "chaos/shard_kill/salvage_vs_baselines",
                    0.0,
                    f"stranded_salvage={r_pull.stranded};"
                    f"stranded_nosalvage={r_nosal.stranded};"
                    f"stranded_legacy={r_leg.stranded};"
                    f"lost_rate_salvage={m_pull.lost_task_rate:.4f};"
                    f"lost_rate_nosalvage={m_nosal.lost_task_rate:.4f};"
                    f"p99_salvage={m_pull.p99_ms:.0f};"
                    f"p99_nosalvage={m_nosal.p99_ms:.0f}",
                )
            )
    save_json("chaos", payload)
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
