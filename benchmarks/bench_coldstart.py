"""Figure 13: cold-start rate per scheduling algorithm."""

from __future__ import annotations

from .common import SCHEDULERS, matrix, save_json, stats


def run(quick: bool = False):
    m = matrix(quick)
    rows = []
    payload = {}
    for name in SCHEDULERS:
        s = stats(m, name)
        payload[name] = s["cold_rate"]
        rows.append((f"cold_rate/{name}", s["cold_rate"] * 1e6,
                     f"paper: hiku=30% others=43-59%; got={s['cold_rate']:.1%}"))
    save_json("fig13_coldstarts", payload)
    return rows
