"""Figure 17: requests/second at 20, 50, 100 virtual users."""

from __future__ import annotations

import numpy as np

from .common import SCHEDULERS, VU_LEVELS, matrix, save_json


def run(quick: bool = False):
    m = matrix(quick)
    rows = []
    payload = {}
    for name in SCHEDULERS:
        payload[name] = {}
        for vus in VU_LEVELS:
            rps = float(np.mean(m[name]["per_vu_rps"][vus]))
            payload[name][vus] = rps
            rows.append((f"concurrency_rps/{name}/{vus}vu", rps * 1e3, f"{rps:.1f} rps"))
    # the paper's headline: hiku's advantage grows with concurrency
    if not quick:
        h, c = payload["hiku"], payload["ch_bl"]
        adv_low = h[20] / max(c[20], 1e-9)
        adv_high = h[100] / max(c[100], 1e-9)
        rows.append(("concurrency_advantage_growth", (adv_high - adv_low) * 1e6,
                     f"paper: similar@20vu, hiku wins@100vu; got {adv_low:.3f}->{adv_high:.3f}"))
    save_json("fig17_concurrency", payload)
    return rows
