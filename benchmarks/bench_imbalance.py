"""Figures 14-15: load imbalance (CV of requests assigned/worker/second)."""

from __future__ import annotations

from .common import SCHEDULERS, matrix, save_json, stats


def run(quick: bool = False):
    m = matrix(quick)
    rows = []
    payload = {}
    for name in SCHEDULERS:
        s = stats(m, name)
        payload[name] = s["avg_cv"]
        rows.append((f"load_cv/{name}", s["avg_cv"] * 1e6,
                     f"paper: hiku=0.27 lc=0.26 chbl=0.31; got={s['avg_cv']:.3f}"))
    if payload.get("ch_bl"):
        imp = (payload["ch_bl"] - payload["hiku"]) / payload["ch_bl"] * 100
        rows.append(("load_cv_improvement_vs_chbl", imp * 1e3, f"paper=12.9% got={imp:.1f}%"))
    save_json("fig14_15_imbalance", payload)
    return rows
