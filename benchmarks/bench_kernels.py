"""Kernel micro-benchmarks.

On this CPU container the Pallas TPU kernels run in interpret mode (Python
loop — timings meaningless for TPU), so the timed paths here are:
* the XLA reference implementations (what the dry-run compiles), and
* the paper-relevant comparison: fused sched_step burst vs per-event scan —
  the scheduler hot path this framework contributes (both timed on XLA:CPU,
  an apples-to-apples comparison).
Pallas-kernel FLOP counts are derived analytically for the roofline notes.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jax_sched import ARRIVAL, init_state, sched_many
from repro.kernels import ref

from .common import save_json


def _time(fn, *args, n=5):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def run(quick: bool = False):
    rows = []
    payload = {}
    ks = jax.random.split(jax.random.key(0), 5)

    # flash attention ref (XLA path used by the dry-run)
    B, S, H, KH, hd = 1, 512 if quick else 1024, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KH, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KH, hd), jnp.float32)
    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True))
    us = _time(fa, q, k, v)
    flops = 4 * B * S * S * H * hd
    rows.append(("kernel/flash_attention_xla", us, f"{flops/us/1e6:.1f} GFLOP/s cpu"))
    payload["flash_attention_us"] = us

    # decode attention ref
    Sd = 4096 if quick else 16384
    kc = jax.random.normal(ks[1], (B, Sd, KH, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Sd, KH, hd), jnp.float32)
    qd = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    da = jax.jit(lambda q, kc, vc: ref.decode_attention_ref(q, kc, vc, jnp.int32(Sd - 1)))
    us = _time(da, qd, kc, vc)
    byts = 2 * Sd * KH * hd * 4
    rows.append(("kernel/decode_attention_xla", us, f"{byts/us/1e3:.1f} GB/s cache stream"))
    payload["decode_attention_us"] = us

    # SSD scan ref
    Ss, Hs, P, N = (512 if quick else 2048), 24, 64, 128
    x = jax.random.normal(ks[0], (1, Ss, Hs, P)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, Ss, Hs)))
    A = -jnp.exp(jax.random.normal(ks[2], (Hs,)) * 0.3)
    Bm = jax.random.normal(ks[3], (1, Ss, 1, N)) * 0.3
    Cm = jax.random.normal(ks[4], (1, Ss, 1, N)) * 0.3
    ssd = jax.jit(lambda *a: ref.ssd_scan_ref(*a, chunk=256)[0])
    us = _time(ssd, x, dt, A, Bm, Cm)
    rows.append(("kernel/ssd_scan_xla", us, f"S={Ss} H={Hs}"))
    payload["ssd_scan_us"] = us

    # fused scheduler burst vs per-event scan (the paper's hot path)
    R, F, W = 256, 40, 128
    funcs = jax.random.randint(ks[0], (R,), 0, F)
    idle = jax.random.randint(ks[1], (F, W), 0, 2)
    conns = jnp.zeros((W,), jnp.int32)
    fused = jax.jit(lambda f, i, c: ref.sched_step_ref(f, i, c)[0])
    us_fused = _time(fused, funcs, idle, conns)
    events = jnp.stack([jnp.full((R,), ARRIVAL), funcs, jnp.full((R,), -1)], 1).astype(jnp.int32)
    state = init_state(F, W)
    scan = jax.jit(lambda s, e: sched_many(s, e)[1][0])
    us_scan = _time(scan, state, events)
    rows.append(("kernel/sched_burst_fused", us_fused, f"{us_fused/R:.2f} us/req"))
    rows.append(("kernel/sched_burst_scan", us_scan,
                 f"fused speedup={us_scan/max(us_fused,1e-9):.2f}x"))
    payload["sched_fused_us"] = us_fused
    payload["sched_scan_us"] = us_scan
    save_json("kernels", payload)
    return rows
