"""Figures 10-12: response-latency CDF, means, and tail percentiles."""

from __future__ import annotations

import numpy as np

from .common import SCHEDULERS, matrix, save_json, stats


def run(quick: bool = False):
    m = matrix(quick)
    rows = []
    payload = {}
    for name in SCHEDULERS:
        s = stats(m, name)
        payload[name] = s
        rows.append((f"latency_mean/{name}", s["mean_ms"] * 1e3, f"p99={s['p99']:.0f}ms"))
    hiku = payload["hiku"]["mean_ms"]
    for name in SCHEDULERS[1:]:
        imp = (payload[name]["mean_ms"] - hiku) / payload[name]["mean_ms"] * 100
        rows.append((f"latency_improvement_vs/{name}", imp * 1e3,
                     f"paper=14.9-27.1% got={imp:.1f}%"))
    imp99 = [
        (payload[n]["p99"] - payload["hiku"]["p99"]) / payload[n]["p99"] * 100
        for n in SCHEDULERS[1:]
    ]
    rows.append(("latency_p99_improvement_max", max(imp99) * 1e3,
                 f"paper=up-to-36.4% got={max(imp99):.1f}%"))
    save_json("fig10_12_latency", payload)
    return rows
