"""Section V-B: scheduling-decision overhead per algorithm (µs/decision)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import make_scheduler
from repro.core.trace import make_functions

from .common import save_json


def run(quick: bool = False):
    funcs = [f.name for f in make_functions()]
    n = 2_000 if quick else 20_000
    rows = []
    payload = {}
    rng = np.random.default_rng(0)
    choices = rng.integers(0, len(funcs), n)
    for name in ("hiku", "ch_bl", "least_connections", "random", "ch", "rj_ch"):
        sched = make_scheduler(name, 5, seed=0)
        # warm some queues so hiku's pull path is exercised
        for f in funcs:
            sched.on_finish(0, f)
        t0 = time.perf_counter()
        for i in range(n):
            f = funcs[choices[i]]
            w = sched.schedule(f)
            if i % 3 == 0:
                sched.on_finish(w, f)
        dt = (time.perf_counter() - t0) / n
        payload[name] = dt * 1e3  # ms
        rows.append((f"sched_overhead/{name}", dt * 1e6,
                     f"paper: random=0.0023ms hiku=0.0149ms; got={dt*1e3:.4f}ms"))
    save_json("overhead", payload)
    return rows
