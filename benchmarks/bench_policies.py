"""Admission policies x bursty workload scenarios — the registry matrix.

Every registered admission policy (``core.policies``) runs every scenario in
the bursty workload suite (``core.workloads``) on identical seeded inputs:

* ``flash_crowd`` — near-simultaneous VU spike, half on tight first-response
  SLOs: the EDF (``deadline``) showcase.
* ``diurnal`` — sine-modulated arrival intensity over day/night cycles.
* ``on_off`` — Markov-modulated (ON/OFF) bursty arrivals (Figure 6 shape).
* ``heavy_tail`` — Pareto-think elephants hammering the heaviest functions
  among tight-SLO mice: where warm-capacity-aware ``cost`` admission
  separates from plain pull.

Per cell: p99 / mean latency, cold rate, cross-shard load CV, deadline-miss
rate (time-to-first-response vs the per-VU SLO; charged admission wait
included), admitted count, migrations.

Acceptance (pinned by tests/test_policies.py): on ``flash_crowd`` the
``deadline`` policy beats ``pull`` on deadline-miss rate with p99 within
10%, and the default ``pull`` policy remains byte-identical to the
pre-registry admission tier.
"""

from __future__ import annotations

import time
import warnings

FULL = dict(n_shards=4, n_workers=32, n_vus=96, duration_s=40.0, mem_pool_mb=1024.0)
QUICK = dict(n_shards=2, n_workers=8, n_vus=32, duration_s=14.0, mem_pool_mb=1024.0)

FULL_SCENARIOS = ("flash_crowd", "diurnal", "on_off", "heavy_tail")
QUICK_SCENARIOS = ("flash_crowd", "on_off")


def run_cell(policy: str, scenario, p: dict, seed: int = 0):
    """One (policy, scenario) cell; returns (AdmissionRun, RunMetrics)."""
    from repro.core import SimConfig
    from repro.core.admission import AdmissionConfig, AdmissionSimulator

    adm = AdmissionSimulator(
        p["n_shards"], p["n_workers"], scheduler="hiku",
        cfg=SimConfig(mem_pool_mb=p["mem_pool_mb"]), seed=seed,
        admission=AdmissionConfig(policy=policy, steal_watermark=1.25),
    )
    with warnings.catch_warnings():
        # backpressured bursts may leave VUs unadmitted; that's the scenario
        warnings.simplefilter("ignore", RuntimeWarning)
        r = adm.run(scenario.n_vus, p["duration_s"], **scenario.run_kwargs())
    return r, r.summarize(p["duration_s"])


def _fmt(r, m) -> str:
    return (
        f"p99_ms={m.p99_ms:.0f};mean_ms={m.mean_latency_ms:.0f};"
        f"miss={m.deadline_miss_rate:.3f};cold={m.cold_rate:.3f};"
        f"shard_cv={r.shard_load_cv:.3f};admitted={r.admitted};"
        f"migrations={r.n_migrations};requests={m.n_requests}"
    )


def run(quick: bool = False):
    from repro.core import make_functions
    from repro.core.policies import available_policies
    from repro.core.workloads import make_scenario

    from .common import save_json

    p = QUICK if quick else FULL
    seed = 0
    funcs = make_functions(seed=seed)
    policies = available_policies()
    scenarios = QUICK_SCENARIOS if quick else FULL_SCENARIOS
    rows = []
    payload = {"params": dict(p), "policies": policies, "scenarios": list(scenarios)}
    for scn_name in scenarios:
        scn = make_scenario(scn_name, funcs, p["n_vus"], p["duration_s"], seed=seed)
        cell = {}
        for policy in policies:
            t0 = time.perf_counter()
            r, m = run_cell(policy, scn, p, seed=seed)
            wall = time.perf_counter() - t0
            cell[policy] = (r, m)
            rows.append(
                (
                    f"policies/{scn_name}/{policy}",
                    wall / max(m.n_requests, 1) * 1e6,
                    _fmt(r, m),
                )
            )
        payload[scn_name] = {
            pol.replace("+", "_"): {
                "p99_ms": m.p99_ms,
                "mean_ms": m.mean_latency_ms,
                "deadline_miss_rate": m.deadline_miss_rate,
                "cold_rate": m.cold_rate,
                "shard_cv": r.shard_load_cv,
                "admitted": r.admitted,
                "migrations": r.n_migrations,
                "n_requests": m.n_requests,
            }
            for pol, (r, m) in cell.items()
        }
        if scn_name == "flash_crowd":
            # the registry acceptance row: EDF admission vs FIFO pull
            (_, m_pull), (_, m_dl) = cell["pull"], cell["deadline"]
            rows.append(
                (
                    "policies/flash_crowd/deadline_vs_pull",
                    0.0,
                    f"miss_pull={m_pull.deadline_miss_rate:.3f};"
                    f"miss_deadline={m_dl.deadline_miss_rate:.3f};"
                    f"p99_pull={m_pull.p99_ms:.0f};p99_deadline={m_dl.p99_ms:.0f};"
                    f"p99_delta={(m_dl.p99_ms - m_pull.p99_ms) / m_pull.p99_ms:+.1%}",
                )
            )
    save_json("policies", payload)
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
