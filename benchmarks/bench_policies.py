"""Admission policies x bursty workload scenarios — the registry matrix.

Every registered admission policy (``core.policies``) runs every scenario in
the bursty workload suite (``core.workloads``) on identical seeded inputs:

* ``flash_crowd`` — near-simultaneous VU spike, half on tight first-response
  SLOs: the EDF (``deadline``) showcase.
* ``diurnal`` — sine-modulated arrival intensity over day/night cycles.
* ``on_off`` — Markov-modulated (ON/OFF) bursty arrivals (Figure 6 shape).
* ``heavy_tail`` — Pareto-think elephants hammering the heaviest functions
  among tight-SLO mice: where warm-capacity-aware ``cost`` admission
  separates from plain pull.

Per cell: p99 / mean latency, cold rate, cross-shard load CV, deadline-miss
rate (time-to-first-response vs the per-VU SLO; charged admission wait
included), admitted count, migrations.

On top of the matrix sits the **leaderboard**: per scenario, every policy is
ranked on each of the scenario's metric axes (``Scenario.axes`` — p99, mean,
deadline-miss rate, cold rate; lower is better), and every (scenario, axis)
where a *learned* policy (``AdmissionPolicy.learned`` — ``sjf``/``bandit``/
``bandit+steal``) strictly beats **every** hand-tuned policy is reported in
the ``learned_vs_hand`` section and the
``policies/leaderboard/learned_vs_hand`` acceptance row.  The CI
``leaderboard`` job uploads the JSON payload as a build artifact.

Acceptance (pinned by tests/test_policies.py): on ``flash_crowd`` the
``deadline`` policy beats ``pull`` on deadline-miss rate with p99 within
10%; the default ``pull`` policy remains byte-identical to the
pre-registry admission tier; and at the full (checked-in) scale a learned
policy wins at least one (scenario, axis) outright — ``sjf``'s predicted-
duration queue order takes ``heavy_tail`` p99 against every hand-tuned
policy (tests/test_policies.py reads the checked-in
``benchmarks/results/policies.json``).
"""

from __future__ import annotations

import time
import warnings

FULL = dict(n_shards=4, n_workers=32, n_vus=96, duration_s=40.0, mem_pool_mb=1024.0)
QUICK = dict(n_shards=2, n_workers=8, n_vus=32, duration_s=14.0, mem_pool_mb=1024.0)

FULL_SCENARIOS = ("flash_crowd", "diurnal", "on_off", "heavy_tail")
QUICK_SCENARIOS = ("flash_crowd", "on_off")


def run_cell(policy: str, scenario, p: dict, seed: int = 0):
    """One (policy, scenario) cell; returns (AdmissionRun, RunMetrics)."""
    from repro.core import SimConfig
    from repro.core.admission import AdmissionConfig, AdmissionSimulator

    adm = AdmissionSimulator(
        p["n_shards"], p["n_workers"], scheduler="hiku",
        cfg=SimConfig(mem_pool_mb=p["mem_pool_mb"]), seed=seed,
        admission=AdmissionConfig(policy=policy, steal_watermark=1.25),
    )
    with warnings.catch_warnings():
        # backpressured bursts may leave VUs unadmitted; that's the scenario
        warnings.simplefilter("ignore", RuntimeWarning)
        r = adm.run(scenario.n_vus, p["duration_s"], **scenario.run_kwargs())
    return r, r.summarize(p["duration_s"])


def _fmt(r, m) -> str:
    return (
        f"p99_ms={m.p99_ms:.0f};mean_ms={m.mean_latency_ms:.0f};"
        f"miss={m.deadline_miss_rate:.3f};cold={m.cold_rate:.3f};"
        f"shard_cv={r.shard_load_cv:.3f};admitted={r.admitted};"
        f"migrations={r.n_migrations};requests={m.n_requests}"
    )


def leaderboard(payload: dict, scenarios, policies, axes_of) -> dict:
    """Rank every policy per (scenario, axis) and find outright learned wins.

    Consumes the matrix ``payload`` (per-scenario dicts of per-policy metric
    cells, ``+`` folded to ``_`` in policy keys), returns::

        {"rankings": {scn: {axis: [best..worst policy names]}},
         "learned_vs_hand": [{"scenario", "axis", "winner", "winner_value",
                              "best_hand", "best_hand_value"}, ...]}

    A learned win requires *strictly* beating every hand-tuned policy on the
    axis (ties don't count).  Lower is better on every axis.
    """
    from repro.core.policies import get_policy_class

    learned = {p for p in policies if get_policy_class(p).learned}
    rankings: dict = {}
    wins = []
    for scn_name in scenarios:
        cells = payload[scn_name]
        rankings[scn_name] = {}
        for axis in axes_of[scn_name]:
            vals = {p: cells[p.replace("+", "_")][axis] for p in policies}
            # stable ranking: value, then name, so ties read deterministically
            order = sorted(policies, key=lambda p: (vals[p], p))
            rankings[scn_name][axis] = order
            best = order[0]
            if best in learned:
                hand = [vals[p] for p in policies if p not in learned]
                if hand and vals[best] < min(hand):
                    best_hand = min(
                        (p for p in policies if p not in learned),
                        key=lambda p: (vals[p], p),
                    )
                    wins.append(
                        {
                            "scenario": scn_name,
                            "axis": axis,
                            "winner": best,
                            "winner_value": vals[best],
                            "best_hand": best_hand,
                            "best_hand_value": vals[best_hand],
                        }
                    )
    return {"rankings": rankings, "learned_vs_hand": wins}


def run(quick: bool = False):
    from repro.core import make_functions
    from repro.core.policies import available_policies
    from repro.core.workloads import make_scenario

    from .common import save_json

    p = QUICK if quick else FULL
    seed = 0
    funcs = make_functions(seed=seed)
    policies = available_policies()
    scenarios = QUICK_SCENARIOS if quick else FULL_SCENARIOS
    rows = []
    payload = {"params": dict(p), "policies": policies, "scenarios": list(scenarios)}
    axes_of = {}
    for scn_name in scenarios:
        scn = make_scenario(scn_name, funcs, p["n_vus"], p["duration_s"], seed=seed)
        axes_of[scn_name] = list(scn.axes)
        cell = {}
        for policy in policies:
            t0 = time.perf_counter()
            r, m = run_cell(policy, scn, p, seed=seed)
            wall = time.perf_counter() - t0
            cell[policy] = (r, m)
            rows.append(
                (
                    f"policies/{scn_name}/{policy}",
                    wall / max(m.n_requests, 1) * 1e6,
                    _fmt(r, m),
                )
            )
        payload[scn_name] = {
            pol.replace("+", "_"): {
                "p99_ms": m.p99_ms,
                "mean_ms": m.mean_latency_ms,
                "deadline_miss_rate": m.deadline_miss_rate,
                "cold_rate": m.cold_rate,
                "shard_cv": r.shard_load_cv,
                "admitted": r.admitted,
                "migrations": r.n_migrations,
                "n_requests": m.n_requests,
            }
            for pol, (r, m) in cell.items()
        }
        if scn_name == "flash_crowd":
            # the registry acceptance row: EDF admission vs FIFO pull
            (_, m_pull), (_, m_dl) = cell["pull"], cell["deadline"]
            rows.append(
                (
                    "policies/flash_crowd/deadline_vs_pull",
                    0.0,
                    f"miss_pull={m_pull.deadline_miss_rate:.3f};"
                    f"miss_deadline={m_dl.deadline_miss_rate:.3f};"
                    f"p99_pull={m_pull.p99_ms:.0f};p99_deadline={m_dl.p99_ms:.0f};"
                    f"p99_delta={(m_dl.p99_ms - m_pull.p99_ms) / m_pull.p99_ms:+.1%}",
                )
            )
    board = leaderboard(payload, scenarios, policies, axes_of)
    payload["leaderboard"] = board
    for scn_name in scenarios:
        ranks = board["rankings"][scn_name]
        rows.append(
            (
                f"policies/{scn_name}/leaderboard",
                0.0,
                ";".join(f"{axis}={ranks[axis][0]}" for axis in axes_of[scn_name]),
            )
        )
    wins = board["learned_vs_hand"]
    rows.append(
        (
            "policies/leaderboard/learned_vs_hand",
            0.0,
            f"wins={len(wins)};"
            + ";".join(
                f"{w['winner']}:{w['scenario']}:{w['axis']}="
                f"{w['winner_value']:.3f}<{w['best_hand_value']:.3f}"
                for w in wins
            ),
        )
    )
    save_json("policies", payload)
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
