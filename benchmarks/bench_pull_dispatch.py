"""Beyond-paper: JIQ microbatch dispatch vs static assignment (stragglers)."""

from __future__ import annotations

import numpy as np

from repro.training.pull_dispatch import simulate_dispatch

from .common import save_json


def run(quick: bool = False):
    rows = []
    payload = {}
    for frac, slow in [(0.0, 1.0), (0.06, 2.0), (0.12, 3.0), (0.25, 4.0)]:
        st, pu = simulate_dispatch(
            n_micro=64 if quick else 256, n_replicas=16,
            straggler_frac=frac, slowdown=slow, seed=3,
        )
        gain = (st.makespan - pu.makespan) / st.makespan * 100
        key = f"stragglers{int(frac*100)}pct_x{slow:g}"
        payload[key] = {"static_s": st.makespan, "pull_s": pu.makespan, "gain_pct": gain}
        rows.append((f"pull_dispatch/{key}", pu.makespan * 1e6,
                     f"static={st.makespan:.1f}s pull={pu.makespan:.1f}s gain={gain:.0f}%"))
    save_json("pull_dispatch", payload)
    return rows
