"""Sharded multi-cluster driver throughput at the production-scale anchors.

For each anchor (800 and 1600 total workers, 8 GB pools — the same configs
``bench_sim_speed`` tracks) runs the sharded driver at 1, 4, and 8 shards
and reports two rates:

* ``makespan_ev_s`` — total events / end-to-end wall time of the driver
  (process-pool backend for K>1; bounded by the local core count, so on a
  2-core CI box this tops out near 2x);
* ``aggregate_ev_s`` — the scale-out capacity metric: the sum of per-shard
  event rates, each shard timed on its own wall clock inside its worker
  process, exactly what K independent single-cluster deployments would
  report in aggregate.  ``speedup_vs_1shard`` is computed on this metric
  (the 1-shard case is the monolithic engine run through the same driver).

The **mega anchor** (``--mega``) is the 100k-worker / 1M-VU cluster at 8,
16, 32 and 64 shards.  Two acceptance rows ride along:

* ``flat_curve`` — aggregate events/sec must not drop more than 10% from
  8 to 64 shards (per-shard cost must not grow with cluster size);
* ``vs_legacy_8shards`` — the refactored control plane (bitmap
  least-connections tracker, vectorized VU-program generation, shared-
  memory shard transport) must deliver >=2x the aggregate events/sec of
  the legacy engine path (full-scan fallback, per-VU program loop,
  pickled results) on the identical workload.

``--quick`` runs the 2-shard smoke plus a reduced-scale replica of the
mega curve + acceptance rows (CI path; looser thresholds since sub-second
shards are noisy).
"""

from __future__ import annotations

import contextlib
import gc
import os

ANCHORS = {
    "800w_8000vu_8g": dict(n_workers=800, n_vus=8000, duration_s=4.0, mem_pool_mb=8192.0),
    "1600w_16000vu_8g": dict(n_workers=1600, n_vus=16000, duration_s=3.0, mem_pool_mb=8192.0),
}
SHARD_COUNTS = (1, 4, 8)
QUICK_SMOKE = dict(n_workers=200, n_vus=2000, duration_s=2.0, mem_pool_mb=2048.0)

MEGA_ANCHOR = dict(
    n_workers=100_000, n_vus=1_000_000, duration_s=1.0, mem_pool_mb=8192.0
)
MEGA_SHARD_COUNTS = (8, 16, 32, 64)
MEGA_MAX_DROP = 0.10  # acceptance: <=10% aggregate drop, 8 -> 64 shards
MEGA_MIN_LEGACY_RATIO = 2.0  # acceptance: >=2x over the legacy engine path

MEGA_QUICK = dict(n_workers=2_000, n_vus=20_000, duration_s=1.0, mem_pool_mb=4096.0)
MEGA_QUICK_SHARD_COUNTS = (2, 8)
MEGA_QUICK_MAX_DROP = 0.35  # sub-second shards: wide noise band
MEGA_QUICK_MIN_LEGACY_RATIO = 1.0  # sanity (gains shrink with shard size)


def _clear_engine_caches() -> None:
    from repro.core import simulator as _sim
    from repro.core import trace as _trace

    _sim._FLUCT_CACHE.clear()
    _trace._PROG_CACHE.clear()


@contextlib.contextmanager
def _legacy_engine():
    """Run the driver on the pre-refactor control plane: full-scan
    least-connections fallback, per-VU program generation, pickled shard
    results.  Class/module attributes patched here are inherited by the
    forked pool workers, so the whole process tree runs legacy."""
    from repro.core import shard, trace
    from repro.core.scheduler import Scheduler

    saved_lc = Scheduler._least_connections
    saved_fast = trace._PROG_FAST_OK
    saved_env = os.environ.get(shard.TRANSPORT_ENV)
    Scheduler._least_connections = Scheduler._least_connections_ref
    trace._PROG_FAST_OK = False
    os.environ[shard.TRANSPORT_ENV] = "pickle"
    try:
        yield
    finally:
        Scheduler._least_connections = saved_lc
        trace._PROG_FAST_OK = saved_fast
        if saved_env is None:
            os.environ.pop(shard.TRANSPORT_ENV, None)
        else:
            os.environ[shard.TRANSPORT_ENV] = saved_env


def _run(n_shards: int, cfg_kw: dict, backend: str):
    from repro.core import SimConfig
    from repro.core.shard import ShardedSimulator

    kw = dict(cfg_kw)
    n_vus = kw.pop("n_vus")
    duration_s = kw.pop("duration_s")
    n_workers = kw.pop("n_workers")
    _clear_engine_caches()
    gc.collect()
    driver = ShardedSimulator(
        n_shards, n_workers, scheduler="hiku", cfg=SimConfig(**kw), seed=0, backend=backend
    )
    return driver.run(n_vus=n_vus, duration_s=duration_s)


def _mega_rows(anchor_name: str, cfg_kw: dict, shard_counts, max_drop, min_ratio):
    """Events/sec-vs-cluster-size curve + the two acceptance rows."""
    rows, curve = [], {}
    for k in shard_counts:
        r = _run(k, cfg_kw, backend="process")
        curve[k] = r.aggregate_events_per_s
        rows.append(
            (
                f"shard_scale/{anchor_name}/{k}shards",
                r.wall_s / max(r.n_events, 1) * 1e6,
                f"events={r.n_events};makespan_s={r.wall_s:.2f};"
                f"makespan_ev_s={r.events_per_s:.0f};"
                f"aggregate_ev_s={curve[k]:.0f}",
            )
        )
    k_lo, k_hi = shard_counts[0], shard_counts[-1]
    drop = (curve[k_lo] - curve[k_hi]) / curve[k_lo]
    rows.append(
        (
            f"shard_scale/{anchor_name}/flat_curve",
            0.0,
            f"drop_{k_lo}to{k_hi}shards={drop * 100:.1f}%;"
            f"max_allowed={max_drop * 100:.0f}%;"
            f"accept={'PASS' if drop <= max_drop else 'FAIL'}",
        )
    )
    with _legacy_engine():
        rl = _run(k_lo, cfg_kw, backend="process")
    legacy_agg = rl.aggregate_events_per_s
    ratio = curve[k_lo] / legacy_agg if legacy_agg else float("inf")
    rows.append(
        (
            f"shard_scale/{anchor_name}/vs_legacy_{k_lo}shards",
            0.0,
            f"legacy_aggregate_ev_s={legacy_agg:.0f};ratio={ratio:.2f}x;"
            f"min_required={min_ratio:.1f}x;"
            f"accept={'PASS' if ratio >= min_ratio else 'FAIL'}",
        )
    )
    payload = {
        "anchor": anchor_name,
        "config": dict(cfg_kw),
        "aggregate_ev_s": {str(k): curve[k] for k in shard_counts},
        "drop_lo_to_hi": drop,
        "max_allowed_drop": max_drop,
        "legacy_aggregate_ev_s": legacy_agg,
        "ratio_vs_legacy": ratio,
        "min_required_ratio": min_ratio,
    }
    return rows, payload


def run(quick: bool = False, mega: bool = False):
    rows = []
    if quick:
        r = _run(2, QUICK_SMOKE, backend="auto")
        rows.append(
            (
                "shard_scale/quick_2shards_200w",
                r.wall_s / max(r.n_events, 1) * 1e6,
                f"events={r.n_events};records={len(r.records)};"
                f"makespan_s={r.wall_s:.2f};aggregate_ev_s={r.aggregate_events_per_s:.0f}",
            )
        )
        mega_rows, _ = _mega_rows(
            "mega_quick",
            MEGA_QUICK,
            MEGA_QUICK_SHARD_COUNTS,
            MEGA_QUICK_MAX_DROP,
            MEGA_QUICK_MIN_LEGACY_RATIO,
        )
        rows.extend(mega_rows)
        return rows
    if mega:
        from .common import save_json

        mega_rows, payload = _mega_rows(
            "mega_100kw_1mvu",
            MEGA_ANCHOR,
            MEGA_SHARD_COUNTS,
            MEGA_MAX_DROP,
            MEGA_MIN_LEGACY_RATIO,
        )
        rows.extend(mega_rows)
        save_json("shard_scale_mega", payload)
        return rows
    for aname, cfg_kw in ANCHORS.items():
        base_aggregate = None
        for k in SHARD_COUNTS:
            backend = "serial" if k == 1 else "process"
            r = _run(k, cfg_kw, backend)
            aggregate = r.aggregate_events_per_s
            makespan_rate = r.events_per_s
            if k == 1:
                base_aggregate = aggregate
            speedup = aggregate / base_aggregate if base_aggregate else float("nan")
            rows.append(
                (
                    f"shard_scale/{aname}/{k}shards",
                    r.wall_s / max(r.n_events, 1) * 1e6,
                    f"events={r.n_events};makespan_s={r.wall_s:.2f};"
                    f"makespan_ev_s={makespan_rate:.0f};aggregate_ev_s={aggregate:.0f};"
                    f"speedup_vs_1shard={speedup:.1f}x",
                )
            )
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke path")
    ap.add_argument(
        "--mega", action="store_true", help="full 100k-worker/1M-VU anchor (minutes)"
    )
    ap.add_argument(
        "--results-dir",
        default=None,
        help="where save_json writes (default: benchmarks/results/local, gitignored)",
    )
    a = ap.parse_args()
    if a.results_dir:
        from benchmarks import common

        common.set_results_dir(a.results_dir)
    for row in run(quick=a.quick, mega=a.mega):
        print(row)
