"""Sharded multi-cluster driver throughput at the production-scale anchors.

For each anchor (800 and 1600 total workers, 8 GB pools — the same configs
``bench_sim_speed`` tracks) runs the sharded driver at 1, 4, and 8 shards
and reports two rates:

* ``makespan_ev_s`` — total events / end-to-end wall time of the driver
  (process-pool backend for K>1; bounded by the local core count, so on a
  2-core CI box this tops out near 2x);
* ``aggregate_ev_s`` — the scale-out capacity metric: the sum of per-shard
  event rates, each shard timed on its own wall clock inside its worker
  process, exactly what K independent single-cluster deployments would
  report in aggregate.  ``speedup_vs_1shard`` is computed on this metric
  (the 1-shard case is the monolithic engine run through the same driver).

``--quick`` runs a single 2-shard smoke at reduced scale (CI path).
"""

from __future__ import annotations

import gc

ANCHORS = {
    "800w_8000vu_8g": dict(n_workers=800, n_vus=8000, duration_s=4.0, mem_pool_mb=8192.0),
    "1600w_16000vu_8g": dict(n_workers=1600, n_vus=16000, duration_s=3.0, mem_pool_mb=8192.0),
}
SHARD_COUNTS = (1, 4, 8)
QUICK_SMOKE = dict(n_workers=200, n_vus=2000, duration_s=2.0, mem_pool_mb=2048.0)


def _clear_engine_caches() -> None:
    from repro.core import simulator as _sim
    from repro.core import trace as _trace

    _sim._FLUCT_CACHE.clear()
    _trace._PROG_CACHE.clear()


def _run(n_shards: int, cfg_kw: dict, backend: str):
    from repro.core import SimConfig
    from repro.core.shard import ShardedSimulator

    kw = dict(cfg_kw)
    n_vus = kw.pop("n_vus")
    duration_s = kw.pop("duration_s")
    n_workers = kw.pop("n_workers")
    _clear_engine_caches()
    gc.collect()
    driver = ShardedSimulator(
        n_shards, n_workers, scheduler="hiku", cfg=SimConfig(**kw), seed=0, backend=backend
    )
    return driver.run(n_vus=n_vus, duration_s=duration_s)


def run(quick: bool = False):
    rows = []
    if quick:
        r = _run(2, QUICK_SMOKE, backend="auto")
        rows.append(
            (
                "shard_scale/quick_2shards_200w",
                r.wall_s / max(r.n_events, 1) * 1e6,
                f"events={r.n_events};records={len(r.records)};"
                f"makespan_s={r.wall_s:.2f};aggregate_ev_s={r.aggregate_events_per_s:.0f}",
            )
        )
        return rows
    for aname, cfg_kw in ANCHORS.items():
        base_aggregate = None
        for k in SHARD_COUNTS:
            backend = "serial" if k == 1 else "process"
            r = _run(k, cfg_kw, backend)
            aggregate = r.aggregate_events_per_s
            makespan_rate = r.events_per_s
            if k == 1:
                base_aggregate = aggregate
            speedup = aggregate / base_aggregate if base_aggregate else float("nan")
            rows.append(
                (
                    f"shard_scale/{aname}/{k}shards",
                    r.wall_s / max(r.n_events, 1) * 1e6,
                    f"events={r.n_events};makespan_s={r.wall_s:.2f};"
                    f"makespan_ev_s={makespan_rate:.0f};aggregate_ev_s={aggregate:.0f};"
                    f"speedup_vs_1shard={speedup:.1f}x",
                )
            )
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
