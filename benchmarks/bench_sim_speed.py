"""Simulator engine throughput vs the checked-in seed baseline (PR 1).

Measures end-to-end ``Simulator.run`` events/sec (records/sec and processed
heap-events/sec) for the paper-scale protocol and for production-scale
clusters, and compares against ``results/sim_speed_baseline.json`` — a
measurement of the pre-refactor (seed) engine checked in alongside the
refactor.  Because the refactored engine replays byte-identical
``RequestRecord`` streams (tests/test_equivalence.py), the records/sec ratio
*is* the event-throughput speedup.

Also reports the §V benchmark-matrix wall time (the workload every figure
module replays) and which dispatch path ``sched_many_fused`` takes on this
backend.

Caches (shared VU programs / fluctuation bands) are cleared before each
repeat so the numbers measure the engine, not warm caches; the baseline was
measured the same way.
"""

from __future__ import annotations

import gc
import json
import time
from pathlib import Path

BASELINE = Path(__file__).parent / "results" / "sim_speed_baseline.json"

# configs must mirror the baseline file entries
CONFIGS = {
    "paper_5w_50vu": dict(n_workers=5, n_vus=50, duration_s=60.0),
    "scale_100w_1000vu": dict(n_workers=100, n_vus=1000, duration_s=15.0),
    "scale_400w_4000vu": dict(n_workers=400, n_vus=4000, duration_s=10.0),
    "scale_800w_8000vu_8g": dict(
        n_workers=800, n_vus=8000, duration_s=10.0, mem_pool_mb=8192.0
    ),
    "scale_1600w_16000vu_8g": dict(
        n_workers=1600, n_vus=16000, duration_s=6.0, mem_pool_mb=8192.0
    ),
}
QUICK_CONFIGS = ("paper_5w_50vu", "scale_400w_4000vu")


def _clear_engine_caches() -> None:
    from repro.core import simulator as _sim
    from repro.core import trace as _trace

    _sim._FLUCT_CACHE.clear()
    _trace._PROG_CACHE.clear()


def _run_once(cfg_kw: dict):
    from repro.core import SimConfig, Simulator, make_scheduler

    kw = dict(cfg_kw)
    n_vus = kw.pop("n_vus")
    duration_s = kw.pop("duration_s")
    sched = make_scheduler("hiku", kw["n_workers"], seed=0)
    sim = Simulator(sched, cfg=SimConfig(**kw), seed=0)
    t0 = time.perf_counter()
    recs = sim.run(n_vus=n_vus, duration_s=duration_s)
    wall = time.perf_counter() - t0
    return len(recs), sim.n_events, wall


def run(quick: bool = False):
    rows = []
    baseline = json.loads(BASELINE.read_text())["configs"] if BASELINE.exists() else {}
    names = QUICK_CONFIGS if quick else list(CONFIGS)
    repeats = 1 if quick else 2
    for name in names:
        best = None
        for _ in range(repeats):
            _clear_engine_caches()
            gc.collect()
            n_rec, n_ev, wall = _run_once(CONFIGS[name])
            if best is None or wall < best[2]:
                best = (n_rec, n_ev, wall)
        n_rec, n_ev, wall = best
        rec_s = n_rec / wall
        ev_s = n_ev / wall
        base = baseline.get(name, {}).get("records_per_s")
        speedup = rec_s / base if base else float("nan")
        rows.append(
            (
                f"sim_speed/{name}",
                wall / n_ev * 1e6,  # us per processed event
                f"records_per_s={rec_s:.0f};events_per_s={ev_s:.0f};"
                f"seed_records_per_s={base};speedup={speedup:.1f}x",
            )
        )
    # §V experiment matrix wall time (what every figure module replays)
    from . import common

    _clear_engine_caches()
    gc.collect()
    t0 = time.perf_counter()
    m = common.run_matrix(quick=True)
    matrix_wall = time.perf_counter() - t0
    n_req = sum(m[s]["n_requests"] for s in m)
    rows.append(
        (
            "sim_speed/matrix_quick",
            matrix_wall / max(n_req, 1) * 1e6,
            f"wall_s={matrix_wall:.2f};requests={n_req}",
        )
    )
    # which dispatch path the fused mixed-event API takes here
    import jax

    backend = jax.default_backend()
    rows.append(
        (
            "sim_speed/fused_dispatch",
            0.0,
            f"backend={backend};path={'pallas_fused' if backend == 'tpu' else 'lax_scan_fallback'}",
        )
    )
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
