"""Cross-shard work stealing vs pull-only admission (post-admission imbalance).

Admission-time pull (``bench_admission``) balances what it can *see*: the
pressure each shard advertises when a VU arrives.  These scenarios are built
so that signal goes stale after binding — which is exactly the late-binding
gap work stealing (``core/stealing.py``, ``policy="pull+steal"``) closes:

* ``hot_block`` — a contiguous **delayed-onset hot block**: sleeper VUs
  whose first request is light and followed by a long nap, after which they
  hammer heavy functions with near-zero think time
  (``make_sleeper_programs``).  Napping VUs are invisible to
  ``Simulator.pressure``, so the admission heap keeps feeding the shards
  that hold them; when the block wakes, those shards thrash their memory
  pools and queue behind them while their neighbors idle below the
  watermark.  Pull-only admission cannot move the queue; stealing drains it
  across shards.
* ``wave`` — arrival waves of mixed sleeper/cold VUs: each wave re-binds on
  whatever pressure the previous wave left behind, compounding placement
  staleness.

Both scenarios report p99 / cross-shard load CV / migration counts for
``pull`` vs ``pull+steal`` on identical seeded workloads.  Acceptance
(pinned by tests/test_stealing.py): on ``hot_block``, ``pull+steal`` shows
lower p99 AND lower cross-shard load CV than pull-only, and every stolen
task completes exactly once (conservation).
"""

from __future__ import annotations

import time
import warnings

FULL = dict(
    n_shards=4, n_workers=16, n_vus=64, duration_s=30.0, mem_pool_mb=1024.0,
    wave_pool_mb=900.0, hot_frac=0.375, quiet_s=(6.0, 9.0), steal_watermark=1.25,
)
QUICK = dict(
    n_shards=2, n_workers=8, n_vus=32, duration_s=14.0, mem_pool_mb=1024.0,
    wave_pool_mb=900.0, hot_frac=0.375, quiet_s=(3.0, 5.0), steal_watermark=1.25,
)


def hot_block_workload(funcs, p: dict, seed: int):
    """The delayed-onset hot block: programs + arrivals (deterministic).

    Cold VUs arrive at t=0 and establish steady load; the sleeper block
    arrives over [1, 4) s so its (pressure-invisible) members concentrate on
    whichever shards look idlest — the post-admission imbalance seed."""
    import numpy as np

    from repro.core import default_n_events
    from repro.core.admission import make_sleeper_programs

    n_vus = p["n_vus"]
    programs = make_sleeper_programs(
        funcs, n_vus, default_n_events(p["duration_s"]), seed,
        hot_frac=p["hot_frac"], quiet_s=p["quiet_s"],
    )
    n_hot = int(round(p["hot_frac"] * n_vus))
    rng = np.random.default_rng((seed, 0xA11CE))
    arrivals = np.zeros(n_vus)
    arrivals[:n_hot] = rng.uniform(1.0, 4.0, n_hot)
    return programs, arrivals


def wave_workload(funcs, p: dict, seed: int, n_waves: int = 3):
    """Arrival waves of mixed sleeper/cold VUs (admission re-binds per wave)."""
    import numpy as np

    from repro.core import default_n_events
    from repro.core.admission import make_sleeper_programs

    n_vus = p["n_vus"]
    programs = make_sleeper_programs(
        funcs, n_vus, default_n_events(p["duration_s"]), seed + 1,
        hot_frac=0.5, quiet_s=p["quiet_s"],
    )
    wave_gap = p["duration_s"] / (n_waves + 1)
    arrivals = np.asarray([(vu % n_waves) * wave_gap for vu in range(n_vus)])
    return programs, arrivals


def _fmt(run, metrics) -> str:
    return (
        f"shard_cv={run.shard_load_cv:.3f};p99_ms={metrics.p99_ms:.0f};"
        f"mean_ms={metrics.mean_latency_ms:.0f};cold={metrics.cold_rate:.3f};"
        f"migrations={run.n_migrations};migrated_rate={metrics.migrated_rate:.4f};"
        f"requests={metrics.n_requests}"
    )


def run_scenario(scenario: str, p: dict, seed: int = 0):
    """Run one scenario under both policies; returns {policy: (run, metrics)}."""
    from repro.core import SimConfig
    from repro.core.admission import AdmissionConfig, AdmissionSimulator

    pool = p["wave_pool_mb"] if scenario == "wave" else p["mem_pool_mb"]
    cfg = SimConfig(mem_pool_mb=pool)
    out = {}
    for policy in ("pull", "pull+steal"):
        adm = AdmissionSimulator(
            p["n_shards"], p["n_workers"], scheduler="hiku", cfg=cfg, seed=seed,
            admission=AdmissionConfig(
                policy=policy, steal_watermark=p["steal_watermark"]
            ),
        )
        build = hot_block_workload if scenario == "hot_block" else wave_workload
        programs, arrivals = build(adm.funcs, p, seed)
        with warnings.catch_warnings():
            # backpressured waves may leave VUs unadmitted; that's the
            # scenario, not a bug — keep the bench output clean
            warnings.simplefilter("ignore", RuntimeWarning)
            r = adm.run(
                p["n_vus"], p["duration_s"], programs=programs, arrivals=arrivals
            )
        out[policy] = (r, r.summarize(p["duration_s"]))
    return out


def run(quick: bool = False):
    from .common import save_json

    p = QUICK if quick else FULL
    seed = 0
    rows = []
    payload = {"params": {**p, "quiet_s": list(p["quiet_s"])}}
    for scenario in ("hot_block", "wave"):
        t0 = time.perf_counter()
        res = run_scenario(scenario, p, seed=seed)
        wall = time.perf_counter() - t0
        (r_pull, m_pull), (r_steal, m_steal) = res["pull"], res["pull+steal"]
        for policy, (r, m) in res.items():
            rows.append(
                (
                    f"stealing/{scenario}/{policy}",
                    wall / 2 / max(m.n_requests, 1) * 1e6,
                    _fmt(r, m),
                )
            )
        rows.append(
            (
                f"stealing/{scenario}/delta",
                0.0,
                f"p99_pull={m_pull.p99_ms:.0f};p99_steal={m_steal.p99_ms:.0f};"
                f"cv_pull={r_pull.shard_load_cv:.3f};cv_steal={r_steal.shard_load_cv:.3f};"
                f"migrations={r_steal.n_migrations}",
            )
        )
        payload[scenario] = {
            pol.replace("+", "_"): {
                "shard_requests": r.shard_requests.tolist(),
                "cv": r.shard_load_cv,
                "p99_ms": m.p99_ms,
                "cold_rate": m.cold_rate,
                "migrations": r.n_migrations,
                "migrated_rate": m.migrated_rate,
                "stolen_in": [s.stolen_in for s in r.shards],
                "stolen_out": [s.stolen_out for s in r.shards],
            }
            for pol, (r, m) in res.items()
        }
    save_json("stealing", payload)
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
