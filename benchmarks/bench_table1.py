"""Table I: cold vs warm start latencies.

Two measurements:
(a) calibrated simulator inputs (the FunctionBench numbers the paper reports);
(b) REAL cold/warm execution on the serving engine — param materialization +
    XLA compile vs warm instance reuse on actual JAX models — demonstrating
    the same phenomenon on this framework's own substrate.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs import get_config
from repro.core.trace import TABLE_I
from repro.serving import Endpoint, ServingEngine

from .common import save_json


def run(quick: bool = False):
    rows = []
    ratios = []
    for app, (cold, warm) in sorted(TABLE_I.items()):
        rows.append((f"table1_sim/{app}", warm * 1e3, f"cold={cold}ms warm={warm}ms"))
        ratios.append(cold / warm)
    rows.append(("table1_sim/avg_cold_warm_ratio", float(np.mean(ratios)) * 1e6,
                 f"paper=1.79x got={np.mean(ratios):.2f}x"))

    # real measurement on the engine
    cfg = get_config("mamba2_130m").reduced()
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=32, vocab=64,
                              ssm=dataclasses.replace(cfg.ssm, d_state=8, headdim=8))
    eng = ServingEngine([Endpoint("bench", cfg, seed=0, max_cache_len=32)],
                        n_workers=1, scheduler="hiku")
    n = 2 if quick else 5
    cold_ms, warm_ms = [], []
    for i in range(n):
        eng.workers[0].idle.clear()  # force cold
        eng.workers[0].used_bytes = 0
        cold_ms.append(eng.submit("bench").latency_ms)
        warm_ms.append(eng.submit("bench").latency_ms)
    ratio = np.mean(cold_ms) / max(np.mean(warm_ms), 1e-9)
    rows.append(("table1_real/cold_ms", float(np.mean(cold_ms)) * 1e3,
                 f"real JAX instance cold start"))
    rows.append(("table1_real/warm_ms", float(np.mean(warm_ms)) * 1e3,
                 f"real warm reuse"))
    rows.append(("table1_real/ratio", ratio * 1e6, f"paper=1.79x(avg) got={ratio:.1f}x"))
    save_json("table1", {"sim": TABLE_I, "real_cold_ms": float(np.mean(cold_ms)),
                         "real_warm_ms": float(np.mean(warm_ms)), "real_ratio": float(ratio)})
    return rows
