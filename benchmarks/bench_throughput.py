"""Figure 16: cumulative processed requests (throughput)."""

from __future__ import annotations

from .common import SCHEDULERS, matrix, save_json


def run(quick: bool = False):
    m = matrix(quick)
    rows = []
    payload = {name: m[name]["n_requests"] for name in SCHEDULERS}
    for name in SCHEDULERS:
        rows.append((f"throughput_total/{name}", payload[name],
                     f"paper: hiku=16414 others=12361-15151"))
    hiku = payload["hiku"]
    gains = [(hiku - payload[n]) / payload[n] * 100 for n in SCHEDULERS[1:]]
    rows.append(("throughput_gain_range", max(gains) * 1e3,
                 f"paper=8.3-32.8% got={min(gains):.1f}-{max(gains):.1f}%"))
    save_json("fig16_throughput", payload)
    return rows
