"""Figures 4-6: workload characterization (skew, heterogeneity, burstiness)."""

from __future__ import annotations

import numpy as np

from repro.core.trace import (
    TABLE_I,
    azure_like_weights,
    bursty_interarrivals,
    fit_zipf_exponent,
)

from .common import save_json


def run(quick: bool = False):
    rows = []
    # Fig 4: skew — top-10% / top-1% invocation share of the fitted population
    w = np.sort(azure_like_weights(1000, seed=0, population=1000))[::-1]
    top10, top1 = float(w[:100].sum()), float(w[:10].sum())
    rows.append(("fig4_top10pct_share", top10 * 1e6, f"paper=92.3% got={top10:.1%}"))
    rows.append(("fig4_top1pct_share", top1 * 1e6, f"paper=51.3% got={top1:.1%}"))
    rows.append(("fig4_zipf_exponent", fit_zipf_exponent() * 1e6, "fitted"))

    # Fig 5: heterogeneity — spread of service times across functions
    warms = np.array([v[1] for v in TABLE_I.values()])
    cv = float(warms.std() / warms.mean())
    rows.append(("fig5_service_time_cv", cv * 1e6, f"across-function CV={cv:.2f}"))

    # Fig 6: burstiness — max/median per-minute rate swing
    ia = bursty_interarrivals(50_000 if not quick else 5_000, seed=1)
    t = np.cumsum(ia)
    per_min = np.histogram(t, bins=np.arange(0, t[-1], 60))[0]
    per_min = per_min[per_min > 0]
    swing = float(per_min.max() / np.median(per_min))
    rows.append(("fig6_burst_swing", swing * 1e6, f"paper=13.5x got={swing:.1f}x"))
    save_json("fig4_6_trace", {"top10": top10, "top1": top1, "service_cv": cv, "swing": swing})
    return rows
