"""Shared benchmark harness: the paper's §V experiment matrix, run once.

Protocol mirrors the paper: 5 workers, 40 functions (8 FunctionBench apps x 5
copies, Azure-skewed weights), closed-loop VUs at {20, 50, 100}, equal time
per VU level, N seeded runs per scheduler, identical seeded workloads across
schedulers.  Results are cached in-process so every figure module reads the
same matrix, and persisted to benchmarks/results/matrix.json.

Per-seed workloads (VU programs and service-time fluctuation bands) are
memoized inside core.trace / core.simulator, so the four schedulers replay
the same generated workload instead of regenerating it per cell; matrix wall
time is tracked by benchmarks/bench_sim_speed.py.

Results JSONs are written to ``RESULTS_DIR`` — by default
``benchmarks/results/local`` (gitignored), NOT the checked-in
``benchmarks/results/`` baselines, so casual ``python -m benchmarks.run``
invocations never churn files under version control.  Pass
``--results-dir benchmarks/results`` (or call :func:`set_results_dir`) to
deliberately refresh the checked-in results; see docs/BENCHMARKS.md for the
same-machine semantics of those baselines.
"""

from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, List, Sequence

import numpy as np

from repro.core import SimConfig, Simulator, make_scheduler, summarize
from repro.core.metrics import latency_cdf, load_cv_per_second

SCHEDULERS = ["hiku", "ch_bl", "least_connections", "random"]  # paper's four
EXTRA_SCHEDULERS = ["ch", "rj_ch"]
VU_LEVELS = [20, 50, 100]

#: checked-in baselines (read-only by convention; see module docstring)
CHECKED_IN_RESULTS = Path(__file__).parent / "results"
#: where save_json writes — defaults to a gitignored scratch dir
RESULTS_DIR = CHECKED_IN_RESULTS / "local"


def set_results_dir(path) -> Path:
    """Redirect ``save_json`` output (the ``--results-dir`` hook)."""
    global RESULTS_DIR
    RESULTS_DIR = Path(path)
    return RESULTS_DIR


def run_matrix(
    schedulers: Sequence[str] = SCHEDULERS,
    vu_levels: Sequence[int] = VU_LEVELS,
    seeds: Sequence[int] = (0, 1, 2),
    duration_s: float = 100.0,
    quick: bool = False,
) -> Dict:
    if quick:
        seeds = seeds[:1]
        duration_s = 30.0
    out: Dict[str, Dict] = {}
    for name in schedulers:
        # latency_ms/cold hold one numpy column chunk per cell (concatenated
        # lazily in stats()); no per-record Python objects are materialized
        per_sched = {"latency_ms": [], "cold": [], "cv_series": [], "per_vu_rps": {v: [] for v in vu_levels},
                     "n_requests": 0, "duration_total": 0.0}
        for seed in seeds:
            for vus in vu_levels:
                sched = make_scheduler(name, 5, seed=seed)
                sim = Simulator(sched, cfg=SimConfig(), seed=seed * 1000 + vus)
                sim.run(n_vus=vus, duration_s=duration_s)
                cols = sim.record_columns
                per_sched["latency_ms"].append(cols.latency_ms)
                per_sched["cold"].append(cols.cold.astype(np.float64))
                cv = load_cv_per_second(sim.assignment_columns, list(range(5)), duration_s)
                per_sched["cv_series"].append(cv)
                per_sched["per_vu_rps"][vus].append(len(cols) / duration_s)
                per_sched["n_requests"] += len(cols)
                per_sched["duration_total"] += duration_s
        out[name] = per_sched
    return out


_MATRIX_CACHE: Dict[str, Dict] = {}


def matrix(quick: bool = False) -> Dict:
    key = "quick" if quick else "full"
    if key not in _MATRIX_CACHE:
        _MATRIX_CACHE[key] = run_matrix(quick=quick)
    return _MATRIX_CACHE[key]


def save_json(name: str, payload) -> Path:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    p = RESULTS_DIR / f"{name}.json"

    def default(o):
        if isinstance(o, np.ndarray):
            return o.tolist()
        if isinstance(o, (np.floating, np.integer)):
            return float(o)
        raise TypeError(type(o))

    p.write_text(json.dumps(payload, indent=1, default=default))
    return p


def stats(m: Dict, name: str) -> Dict[str, float]:
    lat = np.concatenate(m[name]["latency_ms"])
    cold = np.concatenate(m[name]["cold"])
    cvs = np.concatenate([c for c in m[name]["cv_series"] if len(c)])
    return {
        "mean_ms": float(lat.mean()),
        "p50": float(np.percentile(lat, 50)),
        "p90": float(np.percentile(lat, 90)),
        "p95": float(np.percentile(lat, 95)),
        "p99": float(np.percentile(lat, 99)),
        "cold_rate": float(cold.mean()),
        "avg_cv": float(cvs.mean()),
        "total_requests": int(m[name]["n_requests"]),
    }
