"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (per repo convention).
``--quick`` shrinks the simulation matrix for CI.  Full results are also
persisted as JSON under ``--results-dir`` (default: benchmarks/results/local,
which is gitignored — the checked-in baselines under benchmarks/results/ are
only rewritten when you pass that directory explicitly; see
docs/BENCHMARKS.md).
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, help="comma list of bench names")
    ap.add_argument(
        "--results-dir",
        default=None,
        help="where bench modules persist their JSON results (default: "
        "benchmarks/results/local, so default runs never churn the "
        "checked-in baselines; pass benchmarks/results to refresh them)",
    )
    args = ap.parse_args(argv)

    from . import (
        bench_admission,
        bench_affinity,
        bench_autoscale,
        bench_chaos,
        bench_coldstart,
        bench_concurrency,
        bench_imbalance,
        bench_kernels,
        bench_latency,
        bench_overhead,
        bench_policies,
        bench_pull_dispatch,
        bench_shard_scale,
        bench_sim_speed,
        bench_stealing,
        bench_table1,
        bench_trace,
        bench_throughput,
        common,
    )

    if args.results_dir:
        common.set_results_dir(args.results_dir)

    modules = {
        "table1": bench_table1,
        "trace": bench_trace,
        "latency": bench_latency,
        "coldstart": bench_coldstart,
        "imbalance": bench_imbalance,
        "throughput": bench_throughput,
        "concurrency": bench_concurrency,
        "overhead": bench_overhead,
        "kernels": bench_kernels,
        "pull_dispatch": bench_pull_dispatch,
        "sim_speed": bench_sim_speed,
        "shard_scale": bench_shard_scale,
        "admission": bench_admission,
        "affinity": bench_affinity,
        "stealing": bench_stealing,
        "policies": bench_policies,
        "chaos": bench_chaos,
        "autoscale": bench_autoscale,
    }
    if args.only:
        keep = set(args.only.split(","))
        modules = {k: v for k, v in modules.items() if k in keep}

    print("name,us_per_call,derived")
    for name, mod in modules.items():
        t0 = time.time()
        try:
            rows = mod.run(quick=args.quick)
        except Exception as e:  # keep the harness running; surface the error
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}", flush=True)
            continue
        for rname, us, derived in rows:
            print(f"{rname},{us:.3f},{str(derived).replace(',', ';')}", flush=True)
        print(f"_bench_wall/{name},{(time.time()-t0)*1e6:.0f},seconds={time.time()-t0:.1f}",
              flush=True)


if __name__ == "__main__":
    main()
