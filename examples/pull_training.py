"""Beyond-paper demo: Join-Idle-Queue microbatch dispatch for straggler
mitigation in data-parallel training (DESIGN.md §2).

    PYTHONPATH=src python examples/pull_training.py
"""

from repro.training.pull_dispatch import simulate_dispatch


def main():
    print("microbatch dispatch under stragglers: static vs pull-based (JIQ)")
    print(f"{'scenario':<28}{'static':>9}{'pull':>9}{'gain':>7}")
    for frac, slow in [(0.0, 1.0), (0.06, 2.0), (0.12, 3.0), (0.25, 4.0)]:
        st, pu = simulate_dispatch(n_micro=256, n_replicas=16,
                                   straggler_frac=frac, slowdown=slow, seed=3)
        gain = (st.makespan - pu.makespan) / st.makespan * 100
        label = f"{frac:.0%} stragglers x{slow:g}"
        print(f"{label:<28}{st.makespan:>8.1f}s{pu.makespan:>8.1f}s{gain:>6.0f}%")
    print("\npull-based dispatch = the paper's idle-queue discipline applied to")
    print("DP replicas: idle replicas pull the next microbatch instead of")
    print("waiting on a static assignment — same self-balancing effect.")


if __name__ == "__main__":
    main()
