"""Quickstart: pull-based scheduling in 60 seconds.

Runs Hiku vs the paper's baselines on (a) the discrete-event cluster
simulator and (b) the real-model serving engine, and prints the §V metrics.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

from repro.configs import get_config
from repro.core import SimConfig, Simulator, make_scheduler, summarize
from repro.serving import Endpoint, ServingEngine


def simulated():
    print("== simulated cluster (5 workers, 40 functions, 50 VUs, 60s) ==")
    print(f"{'scheduler':<20}{'mean ms':>9}{'p99 ms':>9}{'cold':>7}{'CV':>7}{'rps':>7}")
    for name in ("hiku", "ch_bl", "least_connections", "random"):
        sched = make_scheduler(name, 5, seed=7)
        sim = Simulator(sched, cfg=SimConfig(), seed=7)
        recs = sim.run(n_vus=50, duration_s=60.0)
        m = summarize(recs, sim.assignments, list(range(5)), 60.0)
        print(f"{name:<20}{m.mean_latency_ms:>9.0f}{m.p99_ms:>9.0f}"
              f"{m.cold_rate:>7.1%}{m.load_cv:>7.2f}{m.throughput_rps:>7.1f}")


def real_models():
    print("\n== real JAX models on the serving engine (cold vs warm) ==")
    cfg = get_config("mamba2_130m").reduced()
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=32, vocab=64,
                              ssm=dataclasses.replace(cfg.ssm, d_state=8, headdim=8))
    eps = [Endpoint(f"fn{i}", cfg, seed=i, max_cache_len=32) for i in range(3)]
    eng = ServingEngine(eps, n_workers=2, scheduler="hiku")
    for i in range(6):
        r = eng.submit(f"fn{i % 3}")
        print(f"  req {i}: {r.func} -> worker {r.worker} "
              f"{'COLD' if r.cold else 'warm'} {r.latency_ms:8.1f} ms")
    s = eng.summary()
    print(f"  engine summary: {s['n']} reqs, cold_rate={s['cold_rate']:.0%}, "
          f"sched_overhead={s['sched_overhead_ms']:.4f} ms")


if __name__ == "__main__":
    simulated()
    real_models()
