"""END-TO-END SERVING DRIVER (the paper's kind): full §V experiment replay.

Replays the paper's evaluation protocol — 5 workers, 40 Azure-weighted
functions, closed-loop VUs at 20/50/100, seeded identical workloads per
scheduler — through the cluster simulator, scales the same protocol out
across K independent cluster shards via the sharded multi-cluster driver,
demonstrates the global pull-based admission tier balancing a skewed VU
population the static partition can't (with windowed metrics streaming off
the in-flight merge), compares admission policies from the pluggable
registry on a flash-crowd scenario (`pull` vs `deadline`, side by side),
then serves a *real* small model with batched requests through the engine
under the same scheduler, including a worker failure + elastic re-join
mid-run.

    PYTHONPATH=src python examples/serve_cluster.py [--quick] [--shards K]
"""

import argparse
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (
    ShardedSimulator,
    SimConfig,
    Simulator,
    default_n_events,
    make_functions,
    make_scheduler,
    summarize,
)
from repro.serving import Endpoint, ServingEngine


def replay_paper_protocol(quick: bool):
    duration = 30.0 if quick else 100.0
    vu_levels = [20, 50] if quick else [20, 50, 100]
    print(f"== §V replay: VUs={vu_levels}, {duration:.0f}s each, 5 workers ==")
    print(f"{'scheduler':<20}{'mean ms':>9}{'p99 ms':>9}{'cold':>7}{'CV':>7}{'total':>8}")
    results = {}
    for name in ("hiku", "ch_bl", "least_connections", "random"):
        lat, cold, cvs, total = [], [], [], 0
        for vus in vu_levels:
            sched = make_scheduler(name, 5, seed=11)
            sim = Simulator(sched, cfg=SimConfig(), seed=1000 + vus)
            recs = sim.run(n_vus=vus, duration_s=duration)
            m = summarize(recs, sim.assignments, list(range(5)), duration)
            lat.append(m.mean_latency_ms); cold.append(m.cold_rate)
            cvs.append(m.load_cv); total += m.n_requests
        results[name] = (np.mean(lat), np.mean(cold), np.mean(cvs), total)
        print(f"{name:<20}{np.mean(lat):>9.0f}{'':>9}{np.mean(cold):>7.1%}"
              f"{np.mean(cvs):>7.2f}{total:>8d}")
    h, c = results["hiku"], results["ch_bl"]
    print(f"\nhiku vs ch_bl: latency {100*(c[0]-h[0])/c[0]:+.1f}% "
          f"(paper: 14.9%), cold {h[1]:.0%} vs {c[1]:.0%} (paper: 30% vs 43%), "
          f"throughput {100*(h[3]-c[3])/c[3]:+.1f}% (paper: +8.3%)")


def sharded_scale_out(quick: bool, n_shards: int):
    n_workers, n_vus, dur = (100, 400, 10.0) if quick else (400, 2000, 20.0)
    print(f"\n== sharded multi-cluster driver: {n_shards} shards, "
          f"{n_workers} workers, {n_vus} VUs, {dur:.0f}s ==")
    driver = ShardedSimulator(n_shards, n_workers, scheduler="hiku",
                              cfg=SimConfig(mem_pool_mb=4096.0), seed=3, backend="auto")
    run = driver.run(n_vus=n_vus, duration_s=dur)
    for r in run.shards:
        print(f"  shard {r.spec.index}: seed={r.spec.seed} "
              f"{r.spec.cfg.n_workers}w/{r.spec.n_vus}vu -> {len(r.records)} reqs "
              f"@ {r.n_events / r.wall_s:,.0f} ev/s")
    m = run.summarize(dur)
    print(f"  merged: {m.n_requests} requests, mean {m.mean_latency_ms:.0f} ms, "
          f"p99 {m.p99_ms:.0f} ms, cold {m.cold_rate:.1%}, CV {m.load_cv:.2f}")
    print(f"  makespan {run.wall_s:.2f}s ({run.events_per_s:,.0f} ev/s end-to-end), "
          f"aggregate capacity {run.aggregate_events_per_s:,.0f} ev/s")


def admission_tier(quick: bool, n_shards: int):
    from repro.core import summarize_window
    from repro.core.admission import (
        AdmissionSimulator,
        load_cv_across_shards,
        make_skewed_programs,
    )

    n_workers, n_vus, dur = (16, 32, 10.0) if quick else (32, 96, 30.0)
    n_shards = min(n_shards, n_workers)
    print(f"\n== global pull-based admission tier: {n_shards} shards, "
          f"{n_workers} workers, {n_vus} VUs (25% hot block), {dur:.0f}s ==")
    cfg = SimConfig(mem_pool_mb=1024.0)
    adm = AdmissionSimulator(n_shards, n_workers, scheduler="hiku", cfg=cfg, seed=7)
    programs = make_skewed_programs(adm.funcs, n_vus, default_n_events(dur), 7)

    static = ShardedSimulator(n_shards, n_workers, scheduler="hiku", cfg=cfg,
                              seed=7, backend="auto").run(n_vus, dur, programs=programs)
    pull = adm.run(n_vus, dur, programs=programs)
    s_counts = [len(r.records) for r in static.shards]
    p_counts = pull.shard_requests.tolist()
    print(f"  static partition: per-shard requests {s_counts} "
          f"(cross-shard CV {load_cv_across_shards(s_counts):.2f}), "
          f"p99 {static.summarize(dur).p99_ms:.0f} ms")
    print(f"  pull admission:   per-shard requests {p_counts} "
          f"(cross-shard CV {pull.shard_load_cv:.2f}), "
          f"p99 {pull.summarize(dur).p99_ms:.0f} ms, "
          f"pulls {[s.pulls for s in pull.shards]}")

    # windowed metrics over the *in-flight* sharded run (streaming merge)
    window_s = 2.0 if quick else 5.0
    stream = ShardedSimulator(n_shards, n_workers, scheduler="hiku", cfg=cfg,
                              seed=7, backend="interleaved")
    print(f"  live {window_s:.0f}s windows (streaming merge, static partition):")
    for chunk in stream.run_stream(n_vus, dur, window_s=window_s, programs=programs):
        m = summarize_window(chunk.records, (chunk.assign_t, chunk.assign_w),
                             list(range(n_workers)), chunk.t_lo, chunk.t_hi)
        if m.n_requests:
            print(f"    ({chunk.t_lo:5.1f}, {chunk.t_hi:5.1f}]s: "
                  f"{m.n_requests:4d} reqs, p99 {m.p99_ms:6.0f} ms, "
                  f"cold {m.cold_rate:5.1%}, per-shard {chunk.shard_counts.tolist()}")


def work_stealing(quick: bool, n_shards: int):
    from repro.core.admission import (
        AdmissionConfig,
        AdmissionSimulator,
        make_sleeper_programs,
    )

    n_workers, n_vus, dur = (8, 32, 14.0) if quick else (16, 64, 30.0)
    n_shards = min(n_shards, n_workers)
    nap = (3.0, 5.0) if quick else (6.0, 9.0)
    print(f"\n== cross-shard work stealing: {n_shards} shards, {n_workers} "
          f"workers, {n_vus} VUs (37.5% delayed-onset hot block), {dur:.0f}s ==")
    cfg = SimConfig(mem_pool_mb=1024.0)
    programs = make_sleeper_programs(
        make_functions(seed=0), n_vus,
        default_n_events(dur), 0, hot_frac=0.375, quiet_s=nap)
    n_hot = int(round(0.375 * n_vus))
    arrivals = np.zeros(n_vus)
    arrivals[:n_hot] = np.random.default_rng((0, 0xA11CE)).uniform(1.0, 4.0, n_hot)
    for policy in ("pull", "pull+steal"):
        adm = AdmissionSimulator(
            n_shards, n_workers, scheduler="hiku", cfg=cfg, seed=0,
            admission=AdmissionConfig(policy=policy, steal_watermark=1.25))
        r = adm.run(n_vus, dur, programs=programs, arrivals=arrivals)
        m = r.summarize(dur)
        extra = ""
        if policy == "pull+steal":
            extra = (f", {r.n_migrations} migrations "
                     f"(in/out {[(s.stolen_in, s.stolen_out) for s in r.shards]})")
        print(f"  {policy:10s}: per-shard requests {r.shard_requests.tolist()} "
              f"(cross-shard CV {r.shard_load_cv:.2f}), p99 {m.p99_ms:.0f} ms"
              f"{extra}")


def policy_comparison(quick: bool, n_shards: int):
    """Same flash-crowd scenario under `pull` vs `deadline` admission,
    printed side by side (covered by the docs smoke marker in
    tests/test_docs.py)."""
    import warnings

    from repro.core import available_policies, make_scenario
    from repro.core.admission import AdmissionConfig, AdmissionSimulator

    n_workers, n_vus, dur = (8, 32, 14.0) if quick else (32, 96, 40.0)
    n_shards = min(n_shards, n_workers)
    print(f"\n== admission-policy registry: {available_policies()} ==")
    print(f"   flash crowd: {n_shards} shards, {n_workers} workers, {n_vus} VUs "
          f"(60% spike, half on 2s first-response SLOs), {dur:.0f}s")
    cfg = SimConfig(mem_pool_mb=1024.0)
    scn = make_scenario("flash_crowd", make_functions(seed=0), n_vus, dur, seed=0)
    print(f"   {'policy':<10}{'p99 ms':>8}{'miss':>7}{'cold':>7}{'CV':>7}"
          f"{'admitted':>10}{'requests':>10}")
    for policy in ("pull", "deadline"):
        adm = AdmissionSimulator(n_shards, n_workers, scheduler="hiku",
                                 cfg=cfg, seed=0,
                                 admission=AdmissionConfig(policy=policy))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            r = adm.run(scn.n_vus, dur, **scn.run_kwargs())
        m = r.summarize(dur)
        print(f"   {policy:<10}{m.p99_ms:>8.0f}{m.deadline_miss_rate:>7.2f}"
              f"{m.cold_rate:>7.1%}{r.shard_load_cv:>7.2f}{r.admitted:>10d}"
              f"{m.n_requests:>10d}")
    print("   (deadline = EDF-ordered global queue: tight-SLO VUs admitted "
          "ahead of the backlog; see docs/POLICIES.md)")


def serve_real_batched(quick: bool):
    print("\n== real-model serving with batched requests + failure/elastic ==")
    cfg = get_config("minicpm_2b").reduced()
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                              head_dim=16, d_ff=64, vocab=64)
    eps = [Endpoint(f"llm-{i}", cfg, seed=i, max_cache_len=48) for i in range(4)]
    eng = ServingEngine(eps, n_workers=3, scheduler="hiku")
    rng = np.random.default_rng(0)
    n = 8 if quick else 16
    for i in range(n):
        f = f"llm-{rng.integers(0, 4)}"
        tokens = jnp.ones((4, 8), jnp.int32)  # batch of 4 requests
        r = eng.submit(f, tokens=tokens, gen_len=3)
        tag = "COLD" if r.cold else "warm"
        print(f"  [{i:02d}] {f} -> w{r.worker} {tag:4s} {r.latency_ms:8.1f} ms")
        if i == n // 2:
            victim = r.worker
            print(f"  !! failing worker {victim} (instances lost, queues purged)")
            eng.fail_worker(victim)
            eng.add_worker(99)
            print("  ++ elastic join: worker 99 registered")
    s = eng.summary()
    print(f"  summary: {s['n']} batched requests, cold_rate={s['cold_rate']:.0%}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--shards", type=int, default=4,
                    help="shard count for the multi-cluster driver section")
    args = ap.parse_args()
    replay_paper_protocol(args.quick)
    sharded_scale_out(args.quick, args.shards)
    admission_tier(args.quick, args.shards)
    work_stealing(args.quick, args.shards)
    policy_comparison(args.quick, args.shards)
    serve_real_batched(args.quick)
