"""Training example: WSD schedule (MiniCPM) + checkpoint/restart + elastic resume.

Trains a reduced minicpm-family model on the synthetic Markov LM, async-
checkpointing every 50 steps, then simulates a failure by restoring from the
latest checkpoint onto a fresh mesh (elastic resume) and continuing — the
loss curve is seamless because the data pipeline is stateless-indexed.

    PYTHONPATH=src python examples/train_wsd.py [--steps 300]
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model, unzip
from repro.training import OptConfig, init_opt_state, make_train_step
from repro.training.checkpoint import wait_pending
from repro.training.data import DataConfig, MarkovLM
from repro.training.elastic import elastic_resume, save_for_elastic


def main(steps: int = 300):
    cfg = get_config("minicpm_2b").reduced()
    model = build_model(cfg, remat=False)
    params, _ = unzip(model.init(jax.random.key(0)))
    data = MarkovLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0))
    opt_cfg = OptConfig(lr=1e-2, warmup_steps=20, total_steps=steps, schedule="wsd")
    step_fn = jax.jit(make_train_step(model, opt_cfg=opt_cfg))
    opt = init_opt_state(params)
    ckpt_dir = tempfile.mkdtemp(prefix="hiku-wsd-")
    print(f"training {cfg.name}: {steps} steps, WSD schedule, ckpt={ckpt_dir}")
    print(f"entropy floor of the data: {data.entropy_floor_nats():.3f} nats")

    half = steps // 2
    for i in range(half):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, m = step_fn(params, opt, batch)
        if i % 50 == 0:
            save_for_elastic(ckpt_dir, i, params, opt)
            print(f"  step {i:4d} loss={float(m['loss']):.3f} lr={float(m['lr']):.2e} [ckpt]")
    save_for_elastic(ckpt_dir, half, params, opt)
    wait_pending(ckpt_dir)

    print(f"-- simulated failure at step {half}: restoring on a fresh mesh --")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params, opt, resumed = elastic_resume(ckpt_dir, model, mesh)
    print(f"   resumed from step {resumed}")
    for i in range(resumed, steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, m = step_fn(params, opt, batch)
        if i % 50 == 0 or i == steps - 1:
            print(f"  step {i:4d} loss={float(m['loss']):.3f} lr={float(m['lr']):.2e}")
    print(f"final loss {float(m['loss']):.3f} (floor {data.entropy_floor_nats():.3f})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    main(ap.parse_args().steps)
