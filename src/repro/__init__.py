"""repro — Hiku (pull-based serverless scheduling) as a JAX serving/training framework."""

__version__ = "1.0.0"
