from .base import (
    ARCH_ALIASES,
    ARCH_IDS,
    SHAPES,
    HybridConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    all_configs,
    cells,
    get_config,
    register_config,
)

__all__ = [
    "ARCH_ALIASES",
    "ARCH_IDS",
    "SHAPES",
    "HybridConfig",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "all_configs",
    "cells",
    "get_config",
    "register_config",
]
