"""Model/config system: every assigned architecture is a ``ModelConfig``.

``ModelConfig`` is a frozen dataclass covering dense / MoE / MLA / SSM /
hybrid / encoder-decoder families.  Each architecture file in this package
registers one full config (exact assigned hyperparameters) and every config
can produce a ``reduced()`` version for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------- subconfigs


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0            # shared (always-on) experts, DeepSeek-style
    expert_dff: int = 0          # per-expert FFN width
    router: str = "softmax"      # "softmax" (Mixtral) | "sigmoid" (DeepSeek-V3)
    n_dense_layers: int = 0      # leading dense layers (DeepSeek-V3: 3)
    dense_dff: int = 0           # FFN width of those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    ngroups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def nheads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: shared transformer blocks interleaved with SSM layers."""

    every: int = 6               # apply a shared block after every N ssm layers
    n_shared_blocks: int = 2     # alternating shared blocks
    concat_embedding: bool = True  # shared-block input = concat(h, embedding)


# ------------------------------------------------------------------- config


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # attention structure
    sliding_window: Optional[int] = None   # SWA width (None = full)
    global_every: Optional[int] = None     # gemma3: every Nth layer is global
    attn_logit_softcap: Optional[float] = None
    # block structure
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    norm_bias: bool = False
    act: str = "silu"            # silu (SwiGLU) | gelu (GeGLU / plain)
    gated_mlp: bool = True
    use_bias: bool = False
    parallel_block: bool = False  # Cohere: x + attn(n(x)) + mlp(n(x))
    qk_norm: bool = False
    # positions
    rope: bool = True
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None  # gemma3 global layers
    learned_pos: bool = False
    # embeddings / scaling
    tied_embeddings: bool = True
    scale_emb: float = 1.0        # MiniCPM: 12
    depth_scale: float = 1.0      # MiniCPM residual scale 1.4/sqrt(L)
    logit_soft_cap: Optional[float] = None
    # families
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_encoder_layers: int = 0
    # modality frontend stub: None | "audio" | "vision"
    frontend: Optional[str] = None
    n_frontend_tokens: int = 0    # vlm: patch tokens per example in train shape
    # training
    mtp_depth: int = 0            # DeepSeek-V3 multi-token prediction heads
    lr_schedule: str = "cosine"   # minicpm: "wsd"
    # notes recorded in DESIGN.md
    source: str = ""

    # ------------------------------------------------------------- derived
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (see DESIGN.md §4 skip list)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.sliding_window is not None:
            return True  # SWA / mostly-local attention
        return False

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, L = self.d_model, self.n_layers
        hd = self.head_dim_
        emb = self.vocab * d * (1 if self.tied_embeddings else 2)
        total = emb
        for layer in range(L):
            total += self._layer_params(layer)
        if self.enc_dec:
            for _ in range(self.n_encoder_layers):
                total += self._enc_layer_params()
        return total

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim_
        if self.mla is not None:
            m = self.mla
            qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
            p = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_hd
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            p += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            p += self.n_heads * m.v_head_dim * d
            return p
        return d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d

    def _mlp_params(self, d_ff: int) -> int:
        mult = 3 if self.gated_mlp else 2
        return mult * self.d_model * d_ff

    def _ssm_params(self) -> int:
        s = self.ssm
        d = self.d_model
        d_in = s.d_inner(d)
        nh = s.nheads(d)
        conv_dim = d_in + 2 * s.ngroups * s.d_state
        p = d * (2 * d_in + 2 * s.ngroups * s.d_state + nh)  # in_proj
        p += conv_dim * s.d_conv + d_in * d + 2 * nh  # conv, out_proj, A/D/dt_bias
        return p

    def _layer_params(self, layer: int) -> int:
        if self.family == "ssm":
            return self._ssm_params()
        if self.family == "hybrid":
            p = self._ssm_params()
            h = self.hybrid
            if (layer + 1) % h.every == 0:
                # shared blocks amortized: count once per distinct block
                pass
            return p
        p = self._attn_params()
        if self.moe is not None and layer >= self.moe.n_dense_layers:
            m = self.moe
            p += (m.n_experts + m.n_shared) * self._mlp_params(m.expert_dff) // 1
            p += self.d_model * m.n_experts  # router
        elif self.moe is not None:
            p += self._mlp_params(self.moe.dense_dff)
        else:
            p += self._mlp_params(self.d_ff)
        return p

    def _enc_layer_params(self) -> int:
        return self._attn_params() + self._mlp_params(self.d_ff)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        d = self.d_model
        emb = self.vocab * d * (1 if self.tied_embeddings else 2)
        total = emb
        for layer in range(self.n_layers):
            p = self._attn_params()
            if layer >= m.n_dense_layers:
                p += (m.top_k + m.n_shared) * self._mlp_params(m.expert_dff)
                p += d * m.n_experts
            else:
                p += self._mlp_params(m.dense_dff)
            total += p
        return total

    # ------------------------------------------------------------- reduced
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: Dict = {}
        kw["n_layers"] = min(self.n_layers, 4 if self.family not in ("hybrid",) else 6)
        kw["d_model"] = 64
        kw["n_heads"] = 4
        kw["n_kv_heads"] = min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4
        kw["head_dim"] = 16
        kw["d_ff"] = 128
        kw["vocab"] = 256
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=4,
                top_k=min(self.moe.top_k, 2),
                expert_dff=64,
                dense_dff=128 if self.moe.n_dense_layers else 0,
                n_dense_layers=min(self.moe.n_dense_layers, 1),
            )
        if self.mla is not None:
            kw["mla"] = MLAConfig(
                q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                qk_rope_head_dim=8, v_head_dim=16,
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, headdim=16, chunk=32)
        if self.hybrid is not None:
            kw["hybrid"] = dataclasses.replace(self.hybrid, every=3)
            kw["n_layers"] = 6
        if self.enc_dec:
            kw["n_encoder_layers"] = 2
            kw["n_layers"] = 2
        if self.sliding_window is not None:
            kw["sliding_window"] = 16
        if self.n_frontend_tokens:
            kw["n_frontend_tokens"] = 8
        return dataclasses.replace(self, name=self.name + "-reduced", **kw)


# ------------------------------------------------------------------- shapes

#: assigned input shapes: name -> (seq_len, global_batch, step_kind)
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

ARCH_IDS = [
    "gemma3_4b",
    "command_r_35b",
    "minicpm_2b",
    "command_r_plus_104b",
    "whisper_small",
    "mixtral_8x22b",
    "deepseek_v3_671b",
    "zamba2_2p7b",
    "llava_next_mistral_7b",
    "mamba2_130m",
]

# CLI ids (--arch) use dashes, matching the assignment sheet.
ARCH_ALIASES = {a.replace("_", "-").replace("-2p7b", "-2.7b"): a for a in ARCH_IDS}

_REGISTRY: Dict[str, ModelConfig] = {}


def register_config(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    key = ARCH_ALIASES.get(name, name).replace("-", "_")
    if key not in _REGISTRY:
        importlib.import_module(f"repro.configs.{key}")
    return _REGISTRY[key]


def all_configs() -> Dict[str, ModelConfig]:
    for a in ARCH_IDS:
        get_config(a)
    return dict(_REGISTRY)


def cells(include_skipped: bool = True):
    """Yield every (arch, shape, runnable, note) dry-run cell — 40 total."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape, (seq, batch, kind) in SHAPES.items():
            note = ""
            runnable = True
            if shape == "long_500k" and not cfg.sub_quadratic:
                runnable = False
                note = "skipped: pure full-attention arch (DESIGN.md §4)"
            if runnable or include_skipped:
                yield arch, shape, runnable, note
