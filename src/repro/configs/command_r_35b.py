"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.

GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01; unverified]
Derived: head_dim=128, Cohere parallel attn+FFN residual block, LayerNorm
(no bias), RoPE, tied embeddings (Cohere ties input/output embeddings).
"""

from .base import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="command_r_35b",
        family="dense",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22528,
        vocab=256000,
        head_dim=128,
        norm="layernorm",
        norm_bias=False,
        use_bias=False,
        parallel_block=True,
        act="silu",
        gated_mlp=True,
        rope=True,
        rope_theta=8_000_000.0,
        tied_embeddings=True,
        source="hf:CohereForAI/c4ai-command-r-v01; unverified",
    )
)
