"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.

GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01; unverified]
Derived: head_dim=128, Cohere parallel residual block family (see command_r_35b).
"""

from .base import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="command_r_plus_104b",
        family="dense",
        n_layers=64,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        d_ff=33792,
        vocab=256000,
        head_dim=128,
        norm="layernorm",
        norm_bias=False,
        use_bias=False,
        parallel_block=True,
        act="silu",
        gated_mlp=True,
        rope=True,
        rope_theta=75_000_000.0,
        tied_embeddings=True,
        source="hf:CohereForAI/c4ai-command-r-v01; unverified",
    )
)
