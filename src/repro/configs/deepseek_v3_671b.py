"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048 vocab=129280.

MLA, MoE 1 shared + 256 routed top-8, MTP. [arXiv:2412.19437; hf]
Derived (DeepSeek-V3 paper): MLA q_lora=1536, kv_lora=512, qk_nope=128,
qk_rope=64, v_head=128; first 3 layers dense with d_ff=18432; sigmoid router
with top-8 routing; 1 shared expert; MTP depth 1 (training feature).
The assigned d_ff=2048 is the per-expert (routed) FFN width.
"""

from .base import MLAConfig, ModelConfig, MoEConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="deepseek_v3_671b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,          # MLA is effectively MHA over a shared latent
        d_ff=2048,
        vocab=129280,
        head_dim=128,
        act="silu",
        gated_mlp=True,
        norm="rmsnorm",
        rope=True,
        rope_theta=10_000.0,
        tied_embeddings=False,
        mla=MLAConfig(
            q_lora_rank=1536,
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            n_experts=256,
            top_k=8,
            n_shared=1,
            expert_dff=2048,
            router="sigmoid",
            n_dense_layers=3,
            dense_dff=18432,
        ),
        mtp_depth=1,
        source="arXiv:2412.19437; hf",
    )
)
