"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local:global attention, 128k context. [hf:google/gemma-3-1b-pt; unverified]
Derived (DESIGN.md §4): head_dim=256 (Gemma3 card), sliding window 1024,
local rope theta 1e4 / global 1e6, GeGLU, RMSNorm, qk-norm, tied embeddings.
"""

from .base import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="gemma3_4b",
        family="dense",
        n_layers=34,
        d_model=2560,
        n_heads=8,
        n_kv_heads=4,
        d_ff=10240,
        vocab=262144,
        head_dim=256,
        sliding_window=1024,
        global_every=6,          # 5 local : 1 global
        act="gelu",
        gated_mlp=True,
        norm="rmsnorm",
        qk_norm=True,
        rope=True,
        rope_theta=10_000.0,
        rope_theta_global=1_000_000.0,
        tied_embeddings=True,
        source="hf:google/gemma-3-1b-pt; unverified",
    )
)
