"""llava-next-mistral-7b [vlm]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.

Anyres tiling. [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
Derived: Mistral-7B backbone (head_dim=128, SwiGLU, RMSNorm, RoPE, full
attention — LLaVA-1.6 disables SWA).  The anyres vision tower is a STUB:
``input_specs`` provides pre-projected patch embeddings (B, 2880, 4096) =
(4 tiles + 1 base) x 576 patches; see models/frontends.py.
"""

from .base import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="llava_next_mistral_7b",
        family="vlm",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=32000,
        head_dim=128,
        act="silu",
        gated_mlp=True,
        norm="rmsnorm",
        rope=True,
        rope_theta=1_000_000.0,
        tied_embeddings=False,
        frontend="vision",
        n_frontend_tokens=2880,   # anyres: (4 + 1) tiles x 576 patches
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
    )
)
