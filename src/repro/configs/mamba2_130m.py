"""mamba2-130m [ssm]: 24L d_model=768 (attn-free) vocab=50280, ssm_state=128.

SSD (state-space duality). [arXiv:2405.21060; unverified]
Derived: d_inner=1536 (expand 2), headdim=64 -> 24 ssm heads, d_state=128,
conv=4, chunk=256, ngroups=1, RMSNorm, no positional embedding, tied
embeddings.
"""

from .base import ModelConfig, SSMConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="mamba2_130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=1,               # unused (attention-free)
        n_kv_heads=1,
        d_ff=0,                  # no MLP: Mamba2 block only
        vocab=50280,
        head_dim=64,
        rope=False,
        norm="rmsnorm",
        tied_embeddings=True,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, headdim=64, ngroups=1, chunk=256),
        source="arXiv:2405.21060; unverified",
    )
)
