"""minicpm-2b [dense]: 40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753.

WSD schedule, llama-like. [arXiv:2404.06395; hf]
Derived: head_dim=64, SwiGLU, RMSNorm, RoPE; MiniCPM mup-style knobs:
scale_emb=12, depth-scaled residual 1.4/sqrt(40), tied embeddings.
The WSD (warmup-stable-decay) schedule is implemented in training/optimizer.py
and selected by ``lr_schedule="wsd"``.
"""

import math

from .base import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="minicpm_2b",
        family="dense",
        n_layers=40,
        d_model=2304,
        n_heads=36,
        n_kv_heads=36,
        d_ff=5760,
        vocab=122753,
        head_dim=64,
        act="silu",
        gated_mlp=True,
        norm="rmsnorm",
        rope=True,
        rope_theta=10_000.0,
        tied_embeddings=True,
        scale_emb=12.0,
        depth_scale=1.4 / math.sqrt(40),
        lr_schedule="wsd",
        source="arXiv:2404.06395; hf",
    )
)
