"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768.

MoE 8 experts top-2, SWA. [arXiv:2401.04088; hf]
Derived: head_dim=128, SWA window 4096 (per assignment note), softmax router,
SwiGLU experts, RMSNorm, RoPE, untied embeddings (Mistral family).
"""

from .base import ModelConfig, MoEConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="mixtral_8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab=32768,
        head_dim=128,
        sliding_window=4096,
        act="silu",
        gated_mlp=True,
        norm="rmsnorm",
        rope=True,
        rope_theta=1_000_000.0,
        tied_embeddings=False,
        moe=MoEConfig(
            n_experts=8,
            top_k=2,
            expert_dff=16384,
            router="softmax",
        ),
        source="arXiv:2401.04088; hf",
    )
)
