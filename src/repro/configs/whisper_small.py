"""whisper-small [audio]: 12L d_model=768 12H (MHA kv=12) d_ff=3072 vocab=51865.

Encoder-decoder, conv frontend (STUB). [arXiv:2212.04356; unverified]
Derived: 12 encoder + 12 decoder layers, learned positions, GELU MLP
(non-gated), LayerNorm with bias, cross-attention in the decoder.  The conv
frontend is a stub: ``input_specs`` provides post-conv frame embeddings
(B, T, 768); see models/frontends.py.
"""

from .base import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="whisper_small",
        family="audio",
        n_layers=12,              # decoder layers
        n_encoder_layers=12,
        enc_dec=True,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab=51865,
        head_dim=64,
        norm="layernorm",
        norm_bias=True,
        use_bias=True,
        act="gelu",
        gated_mlp=False,
        rope=False,
        learned_pos=True,
        tied_embeddings=True,
        frontend="audio",
        source="arXiv:2212.04356; unverified",
    )
)
