"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64.

Mamba2 + shared attention blocks. [arXiv:2411.15242; hf]
Derived: 54 Mamba2 layers (d_inner=5120, headdim=64 -> 80 ssm heads,
d_state=64, conv=4); 2 *shared* transformer blocks (32 heads, d_ff=10240)
applied after every 6th Mamba layer, alternating; shared-block input is
concat(hidden, embedding) -> down-projection (Zamba2 scheme; per-application
LoRA deltas omitted — simplification recorded in DESIGN.md §4).
"""

from .base import HybridConfig, ModelConfig, SSMConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="zamba2_2p7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        vocab=32000,
        head_dim=80,             # shared attention block: 2560/32
        act="silu",
        gated_mlp=True,
        norm="rmsnorm",
        rope=True,
        rope_theta=10_000.0,
        tied_embeddings=True,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, headdim=64, ngroups=1, chunk=256),
        hybrid=HybridConfig(every=6, n_shared_blocks=2, concat_embedding=True),
        source="arXiv:2411.15242; hf",
    )
)
