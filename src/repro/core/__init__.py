"""Core contribution of the paper: pull-based (Join-Idle-Queue) scheduling."""

from . import baselines as _baselines  # noqa: F401  (registers schedulers)
from . import hiku as _hiku  # noqa: F401
from .hiku import HikuScheduler
from .jax_sched import (
    ARRIVAL,
    EVICT,
    FINISH,
    JIQState,
    init_state,
    sched_many,
    sched_many_fused,
    sched_step,
)
from .metrics import RunMetrics, latency_cdf, load_cv_per_second, summarize
from .records import RecordAccumulator, RecordColumns, RequestRecord
from .scheduler import Scheduler, available_schedulers, make_scheduler
from .shard import MergedRun, ShardedSimulator, ShardResult, ShardSpec, shard_seed
from .simulator import SimConfig, Simulator
from .trace import FunctionSpec, make_functions, make_vu_programs

__all__ = [
    "ARRIVAL",
    "EVICT",
    "FINISH",
    "FunctionSpec",
    "HikuScheduler",
    "JIQState",
    "MergedRun",
    "RecordAccumulator",
    "RecordColumns",
    "RequestRecord",
    "RunMetrics",
    "Scheduler",
    "ShardResult",
    "ShardSpec",
    "ShardedSimulator",
    "SimConfig",
    "Simulator",
    "available_schedulers",
    "init_state",
    "latency_cdf",
    "load_cv_per_second",
    "make_functions",
    "make_scheduler",
    "make_vu_programs",
    "sched_many",
    "sched_many_fused",
    "sched_step",
    "shard_seed",
    "summarize",
]
