"""Core contribution of the paper: pull-based (Join-Idle-Queue) scheduling.

The exported surface, grouped by layer (docs/ARCHITECTURE.md is the
end-to-end tour; each symbol's docstring states which contracts bind it):

* schedulers — ``Scheduler`` (callback protocol), ``HikuScheduler``
  (Algorithm 1), ``make_scheduler``/``available_schedulers`` (registry);
* engine — ``Simulator`` + ``SimConfig`` (the bit-exact event loop),
  ``FunctionSpec``/``make_functions``/``make_vu_programs`` (seeded
  Azure-like workloads);
* records/metrics — ``RequestRecord``/``RecordColumns``/
  ``RecordAccumulator`` (columnar store), ``RunMetrics``/``summarize``/
  ``summarize_window``/``summarize_windows``/``latency_cdf``/
  ``load_cv_per_second`` (§V metrics, vectorized);
* scale-out — ``ShardedSimulator``/``ShardSpec``/``ShardResult``/
  ``MergedRun``/``StreamChunk``/``shard_seed`` (static K-shard partition +
  batch/streaming merge), ``AdmissionSimulator``/``AdmissionConfig``/
  ``AdmissionRun`` (global pull-based admission tier),
  ``AdmissionPolicy``/``ShardState``/``register_policy``/
  ``unregister_policy``/``available_policies``/``make_policy`` (pluggable
  admission-policy registry; see docs/POLICIES.md for the author
  contract), ``Scenario``/``make_scenario``/``available_scenarios``
  (bursty workload suite), ``StolenTask``/``Migration``/``steal_tick``
  (cross-shard work stealing over the admission co-run);
* learned state — ``DurationEstimator``/``BanditTuner``
  (``core.estimators``: snapshot-exact online estimation feeding the
  ``sjf``/``bandit`` policies), ``ShardScript``/``scripts_from_run``/
  ``replay_shards`` (``core.replay``: scripted per-shard re-execution of
  a recorded admission run, byte-identical on all three backends);
* event plane / autoscaling — ``EventPlane``/``MetricEvent``
  (``core.eventplane``: deterministic in-process pub/sub over windowed
  metric summaries), ``Autoscaler``/``AutoscaleConfig``/
  ``AutoscaleActuator`` (``core.autoscale``: reactive/predictive pool
  sizing on the bus, scale-down via notice windows, scale-to-zero
  janitor; docs/ARCHITECTURE.md §14 is the contract);
* chaos — ``FaultEvent``/``FaultPlan`` (declarative seeded fault
  schedules) with the ``shard_kill_wave``/``spot_preemption``/
  ``rolling_restart``/``flappy_workers`` generators, plus
  ``SalvagedVU``/``Salvage``/``drain_tick`` (dead-shard drain with
  exactly-once recovery; docs/ARCHITECTURE.md §10 is the contract);
* JAX form — ``JIQState``/``init_state``/``sched_step``/``sched_many``/
  ``sched_many_fused`` + the ``ARRIVAL``/``FINISH``/``EVICT`` event kinds
  (vectorized Algorithm 1, Pallas-fused on TPU).
"""

from . import baselines as _baselines  # noqa: F401  (registers schedulers)
from . import hiku as _hiku  # noqa: F401
from .admission import (
    AdmissionConfig,
    AdmissionRun,
    AdmissionShard,
    AdmissionSimulator,
)
from .autoscale import AutoscaleActuator, AutoscaleConfig, Autoscaler
from .chaos import (
    FaultEvent,
    FaultPlan,
    flappy_workers,
    rolling_restart,
    shard_kill_wave,
    spot_preemption,
)
from .estimators import BanditTuner, DurationEstimator
from .eventplane import EventPlane, MetricEvent
from .hiku import HikuScheduler
from .jax_sched import (
    ARRIVAL,
    EVICT,
    FINISH,
    JIQState,
    init_state,
    sched_many,
    sched_many_adaptive,
    sched_many_fused,
    sched_step,
)
from .metrics import (
    RunMetrics,
    latency_cdf,
    load_cv_per_second,
    summarize,
    summarize_window,
    summarize_windows,
)
from .policies import (
    AdmissionPolicy,
    ShardState,
    available_policies,
    make_policy,
    register_policy,
    unregister_policy,
)
from .records import RecordAccumulator, RecordColumns, RequestRecord
from .replay import ShardScript, replay_shards, scripts_from_run
from .scheduler import Scheduler, available_schedulers, make_scheduler
from .shard import (
    MergedRun,
    ShardedSimulator,
    ShardResult,
    ShardSpec,
    StreamChunk,
    shard_seed,
)
from .simulator import BurstDetector, SalvagedVU, SimConfig, Simulator, StolenTask
from .stealing import Migration, Salvage, drain_tick, steal_tick
from .trace import FunctionSpec, default_n_events, make_functions, make_vu_programs
from .workloads import Scenario, available_scenarios, make_scenario

__all__ = [
    "ARRIVAL",
    "AdmissionConfig",
    "AdmissionPolicy",
    "AdmissionRun",
    "AdmissionShard",
    "AdmissionSimulator",
    "AutoscaleActuator",
    "AutoscaleConfig",
    "Autoscaler",
    "BanditTuner",
    "BurstDetector",
    "DurationEstimator",
    "EVICT",
    "EventPlane",
    "FINISH",
    "FaultEvent",
    "FaultPlan",
    "FunctionSpec",
    "HikuScheduler",
    "JIQState",
    "MergedRun",
    "MetricEvent",
    "Migration",
    "RecordAccumulator",
    "RecordColumns",
    "RequestRecord",
    "RunMetrics",
    "Salvage",
    "SalvagedVU",
    "Scenario",
    "Scheduler",
    "ShardResult",
    "ShardScript",
    "ShardSpec",
    "ShardState",
    "ShardedSimulator",
    "SimConfig",
    "Simulator",
    "StolenTask",
    "StreamChunk",
    "available_policies",
    "available_scenarios",
    "available_schedulers",
    "init_state",
    "latency_cdf",
    "load_cv_per_second",
    "default_n_events",
    "drain_tick",
    "flappy_workers",
    "make_functions",
    "make_policy",
    "make_scenario",
    "make_scheduler",
    "make_vu_programs",
    "register_policy",
    "replay_shards",
    "rolling_restart",
    "sched_many",
    "sched_many_adaptive",
    "sched_many_fused",
    "sched_step",
    "scripts_from_run",
    "shard_kill_wave",
    "shard_seed",
    "spot_preemption",
    "steal_tick",
    "summarize",
    "summarize_window",
    "summarize_windows",
    "unregister_policy",
]
