"""Global pull-based admission tier: the paper's pull principle, one level up.

Hiku decouples worker selection from task assignment *inside* one cluster:
idle workers enqueue themselves in ``PQ_f`` and requests bind late to a
ready worker.  The sharded driver (``core.shard``) stops that idea at the
shard boundary — VUs are statically partitioned at plan time, so a bursty
shard queues while its neighbor idles, exactly the imbalance pull-based
scheduling eliminates within a cluster (the centralized-admission framing of
Hermes and NOAH).

This module closes the gap with a second, cluster-level instance of the pull
principle:

* all arrivals (closed-loop VUs, optionally with per-VU arrival times) enter
  ONE global admission queue instead of being split at plan time;
* each shard advertises its *local pressure* — queued arrivals per worker
  plus busy-worker fraction (``Simulator.pressure``) — and **pulls** the
  next arrival whenever its pressure sits below the admission watermark;
* the admission tier is itself a priority queue of shards keyed by pressure
  (``PQ_f`` at cluster granularity): the least-loaded shard pulls first,
  and every pull raises that shard's effective pressure by ``1/n_workers``
  until its event loop catches up, so one tick cannot flood a shard.

Execution co-runs the K shard simulators in simulated-time lockstep
(``Simulator.begin`` / ``step_until`` — the engine's backpressure hooks),
admitting between time slices via ``Simulator.admit_vu``.  The merged output
follows the shard merge contract: worker ids remapped by shard offsets,
VU local ids mapped through the admission-order table, streams stable-merged
by completion time with shard-index tie-break.

Admission binds a VU once.  ``policy="pull+steal"`` extends the pull loop
past that binding with cross-shard **work stealing** (``core.stealing``):
each tick, after admission pulls, queued tasks migrate from shards above
``steal_watermark`` to shards below the pull watermark — the same
pressure-keyed heap run in both directions.  Migrations carry the VU's
bit-exact service identity and are recorded in the ``migrated`` record
column and the run's ``migrations`` telemetry.

*Which* shard pulls, *when* it may, and *which* queued VU it receives are
policy decisions, dispatched through the pluggable registry in
``core.policies``: ``AdmissionConfig.policy`` names any registered
``AdmissionPolicy`` (``available_policies()`` lists them — ``pull``,
``round_robin``, ``pull+steal``, ``deadline``, ``cost``, ``predictive``,
``affinity``, ``affinity+steal`` plus the learned ``sjf``, ``bandit`` and
``bandit+steal`` ship built in), and the three original
behaviors run byte-identically through the same dispatch.  ``core.workloads`` generates the bursty
scenario suite (flash crowds, diurnal load, ON/OFF arrivals, heavy-tailed
service mixes) the policies are benchmarked on
(``benchmarks/bench_policies.py``).

The static partition (``ShardedSimulator``) remains the default and is
byte-identical to the frozen seed engine; the admission tier is a new
opt-in scenario with its own (still deterministic, still seeded) streams.
``benchmarks/bench_admission.py`` measures both on skewed/bursty arrival
populations the static partition cannot balance, and
``benchmarks/bench_stealing.py`` measures what stealing adds on
*post-admission* imbalance.
"""

from __future__ import annotations

import bisect
import dataclasses
import time
import warnings
from typing import List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .autoscale import AutoscaleActuator, Autoscaler
from .coord import ShardCoordinator
from .eventplane import CLUSTER_TOPIC, SHARD_TOPIC, EventPlane
from .metrics import RunMetrics, summarize
from .policies import PolicyContext, get_policy_class, make_policy, policy_knobs
from .records import RecordColumns
from .scheduler import make_scheduler
from .shard import merge_assignments, merge_window, shard_seed, split_even
from .simulator import SalvagedVU, SimConfig, Simulator
from .stealing import Migration, Salvage, drain_tick, steal_tick
from .trace import (
    FunctionSpec,
    VUProgram,
    default_n_events,
    make_functions,
    make_vu_programs,
)

__all__ = [
    "AdmissionConfig",
    "AdmissionRun",
    "AdmissionShard",
    "AdmissionSimulator",
    "load_cv_across_shards",
    "make_skewed_programs",
    "make_sleeper_programs",
]


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Admission-tier control knobs.

    Attributes:
        watermark: pressure threshold below which a shard pulls
            (``Simulator.pressure`` units: 0 = idle, 1 = all workers busy,
            >1 = queueing).  Each pull within a tick raises the shard's
            effective pressure by ``1/n_workers``, so a single tick admits
            at most ``watermark * n_workers`` VUs into an idle shard.
        tick_s: admission-loop period in *simulated* seconds; shards are
            stepped in lockstep between pulls, so this bounds how stale the
            pressure signal can be.
        batch_size: optional hard cap on VUs bound per shard per tick,
            honored by every policy (None: ``pull`` is watermark-limited
            only; ``round_robin`` drains the eligible queue each tick).
        policy: name of a registered admission policy
            (``core.policies.available_policies()``).  Built in: ``"pull"``
            (pressure-ordered watermark admission), ``"pull+steal"`` (pull
            plus per-tick cross-shard work stealing — see ``core.stealing``),
            ``"round_robin"`` (cyclic binding on arrival — the
            arrival-capable static baseline), ``"deadline"`` (EDF-ordered
            global queue), ``"cost"`` (warm-capacity-scaled pull threshold),
            ``"predictive"`` (EWMA arrival-forecast-modulated watermark)
            and ``"affinity"``/``"affinity+steal"`` (warm-locality routing
            against the per-function warm-set digest; the ``+steal``
            variant also steals warm-first), plus the learned tier —
            ``"sjf"`` (queue ordered by predicted total service time from
            an online per-function duration estimator) and
            ``"bandit"``/``"bandit+steal"`` (bandit-tuned watermark /
            watermark-pair; see ``core.estimators``).  Unknown names raise
            at config construction with the available list.
        steal_watermark: pressure above which a shard's queued tasks may be
            stolen (stealing policies only).  Must be >= ``watermark`` so a
            shard can never be victim and thief in the same tick; the band
            between the two watermarks is the hysteresis that keeps
            near-balanced shards from churning migrations.
        steal_batch: optional hard cap on migrations per tick
            (stealing policies only; None: the two heaps limit the tick).
        policy_args: optional policy-specific knobs, passed as keyword
            arguments to the policy constructor (e.g. ``{"cost_weight":
            0.8}`` for ``cost``, ``{"alpha": 0.5, "gain": 2.0}`` for
            ``predictive``).
        salvage: run the dead-shard drain (``core.stealing.drain_tick``)
            each tick: when a shard's last worker dies, its queued tasks
            and mid-think VUs are salvaged back through the admission tier
            onto live shards instead of stranding (§10 failure contract).
            On by default — ``False`` is the no-salvage baseline
            ``benchmarks/bench_chaos.py`` scores against.  With no fault
            plan the drain never fires either way.
    """

    watermark: float = 0.75
    tick_s: float = 0.25
    batch_size: Optional[int] = None
    policy: str = "pull"
    steal_watermark: float = 1.5
    steal_batch: Optional[int] = None
    policy_args: Optional[Mapping[str, object]] = None
    salvage: bool = True

    def __post_init__(self):
        cls = get_policy_class(self.policy)  # unknown name -> available list
        if self.tick_s <= 0:
            raise ValueError("tick_s must be > 0")
        if self.batch_size is not None and self.batch_size < 1:
            raise ValueError("batch_size must be >= 1 (or None for uncapped)")
        if cls.steals:
            if self.steal_watermark < self.watermark:
                raise ValueError(
                    "steal_watermark must be >= watermark (a shard must never "
                    "be steal victim and pull thief at once)"
                )
            if self.steal_batch is not None and self.steal_batch < 1:
                raise ValueError("steal_batch must be >= 1 (or None for uncapped)")
        # surface bad policy knobs at config time, not mid-run — naming the
        # offending key(s) and the accepted knobs for the resolved class
        args = dict(self.policy_args or {})
        try:
            cls(self, **args)
        except TypeError as err:
            knobs = policy_knobs(cls)
            bad = sorted(k for k in args if k not in knobs)
            if not bad:
                raise  # a TypeError of the policy's own making
            raise TypeError(
                f"policy {self.policy!r} ({cls.__name__}) got unknown "
                f"policy_args key(s) {', '.join(map(repr, bad))}; accepted "
                f"knobs: {knobs if knobs else '(none)'}"
            ) from err


@dataclasses.dataclass
class AdmissionShard:
    """One shard's output under global admission (analog of ``ShardResult``).

    ``records``/``assign_w`` carry *shard-local* ids; ``admitted`` is the
    local->global VU id table (position = local id, value = global id, in
    admission order)."""

    index: int
    seed: int
    n_workers: int
    worker_offset: int
    admitted: np.ndarray  # global VU ids, admission order
    admit_t: np.ndarray  # admission times (s), parallel to ``admitted``
    pulls: int  # admission-tier pulls this shard performed
    records: RecordColumns
    assign_t: np.ndarray
    assign_w: np.ndarray
    n_events: int
    stolen_out: int = 0  # queued tasks other shards stole from this one
    stolen_in: int = 0  # stolen tasks this shard received and re-injected
    # failure telemetry (0 on fault-free runs; see ARCHITECTURE.md §10)
    resubmits: int = 0  # failure-retry pushes this shard performed
    lost_tasks: int = 0  # tasks dropped after exhausting the retry budget
    salvaged_out: int = 0  # VUs drained off this shard while it was dead
    salvaged_in: int = 0  # salvaged VUs re-homed onto this shard
    outstanding: int = 0  # submitted-but-unresolved requests at run end
    alive: bool = True  # any live worker left at run end? (dead => stranded)
    #: integral of the live worker count over [0, duration_s) — the
    #: provisioned-capacity cost an elastic pool is scored on (§14);
    #: a static shard reads n_workers * duration_s
    worker_seconds: float = 0.0


@dataclasses.dataclass
class AdmissionRun:
    """Merged output of a global-admission run (analog of ``MergedRun``)."""

    shards: List[AdmissionShard]
    records: RecordColumns  # global ids, stable-merged by completion time
    assign_t: np.ndarray
    assign_w: np.ndarray
    workers: List[int]
    n_events: int
    wall_s: float
    admitted: int  # VUs admitted across all shards
    unadmitted: int  # VUs still waiting (or never eligible) at the deadline
    queue_t: np.ndarray  # admission-queue depth telemetry: sample times (s)
    queue_depth: np.ndarray  # eligible-but-unadmitted VUs at each sample
    migrations: List[Migration] = dataclasses.field(default_factory=list)
    #: per-global-VU relative latency deadline (ms; None when the workload
    #: carries no deadline metadata) — feeds RunMetrics.deadline_miss_rate
    deadline_ms: Optional[np.ndarray] = None
    #: per-global-VU arrival times (s) — the miss clock starts here, so
    #: admission-queue wait is charged against the deadline
    arrival_s: Optional[np.ndarray] = None
    #: dead-shard drain moves (``AdmissionConfig.salvage``; empty without
    #: faults) — one row per re-homed VU, ``in_flight`` rows carried a
    #: lost request with them
    salvages: List[Salvage] = dataclasses.field(default_factory=list)
    #: in-flight requests of salvaged VUs that never found a live home (the
    #: whole cluster stayed dark through the deadline) — counted as lost
    unsalvaged: int = 0
    #: failed-request recovery latencies (first failure -> completion, s),
    #: concatenated across shards — RunMetrics recovery percentiles
    recovery_s: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0)
    )
    #: the learned policy's per-window state snapshots, when the run was
    #: recorded (``policy_args={"record_state": True}`` on a
    #: ``LearnedPolicy``); pure JSON types, feedable back through
    #: ``policy_args={"replay_from": ...}`` for a byte-identical replay.
    #: ``None`` for unrecorded or non-learned runs.
    policy_state: Optional[List[Mapping]] = None

    @property
    def n_migrations(self) -> int:
        """Cross-shard task migrations performed (``pull+steal`` only)."""
        return len(self.migrations)

    @property
    def worker_seconds(self) -> float:
        """Provisioned-capacity cost: live-worker-count integral summed
        over shards (``benchmarks/bench_autoscale.py``'s cost axis)."""
        return float(sum(s.worker_seconds for s in self.shards))

    @property
    def n_salvages(self) -> int:
        """VUs re-homed off dead shards by the drain."""
        return len(self.salvages)

    @property
    def lost_tasks(self) -> int:
        """Requests dropped for good: retry budgets exhausted on any shard,
        plus in-flight requests of VUs that never found a live home."""
        return sum(s.lost_tasks for s in self.shards) + self.unsalvaged

    @property
    def resubmits(self) -> int:
        """Failure-retry pushes across all shards."""
        return sum(s.resubmits for s in self.shards)

    @property
    def stranded(self) -> int:
        """Submitted-but-unresolved requests stuck on *dead* shards at run
        end — work that can never complete (the §10 acceptance signal:
        with salvage on this is 0; live shards' end-of-run in-flight work
        is normal and not counted)."""
        return sum(s.outstanding for s in self.shards if not s.alive)

    @property
    def shard_requests(self) -> np.ndarray:
        """Completed requests per shard — the cross-shard balance signal."""
        return np.asarray([len(s.records) for s in self.shards], np.int64)

    @property
    def shard_load_cv(self) -> float:
        """CV of completed requests across shards (0 = perfectly balanced)."""
        return load_cv_across_shards(self.shard_requests)

    def summarize(self, duration_s: float) -> RunMetrics:
        return summarize(
            self.records, (self.assign_t, self.assign_w), self.workers, duration_s,
            deadline_ms=self.deadline_ms, arrival_s=self.arrival_s,
            resubmits=self.resubmits, lost_tasks=self.lost_tasks,
            recovery_s=self.recovery_s,
        )


def load_cv_across_shards(counts: Sequence[float]) -> float:
    """Coefficient of variation of per-shard load (std/mean; 0 = balanced)."""
    c = np.asarray(counts, np.float64)
    if c.size == 0 or c.mean() <= 0:
        return 0.0
    return float(c.std() / c.mean())


def make_skewed_programs(
    funcs: Sequence[FunctionSpec],
    n_vus: int,
    n_events: int,
    seed: int,
    hot_frac: float = 0.25,
    hot_think: Tuple[float, float] = (0.05, 0.15),
    cold_think: Tuple[float, float] = (1.0, 3.0),
) -> List[VUProgram]:
    """A VU population with a contiguous *hot block* the static partition
    cannot balance.

    The first ``hot_frac`` of VUs are hot: near-zero think time and calls
    drawn only from the heavier half of the function population (by warm
    latency).  The rest are cold: long think times, Azure-weighted function
    choice.  Because the block is contiguous, ``ShardedSimulator``'s
    contiguous VU split lands (nearly) all hot VUs on the first shard(s),
    while pressure-based admission spreads them by live load.  Deterministic
    per ``(seed, vu)`` like ``make_vu_programs``.
    """
    warm = np.asarray([f.warm_ms for f in funcs])
    heavy = np.flatnonzero(warm >= np.median(warm))
    weights = np.asarray([f.weight for f in funcs])
    weights = weights / weights.sum()
    n_hot = int(round(hot_frac * n_vus))
    programs = []
    for vu in range(n_vus):
        rng = np.random.default_rng((seed, vu))
        if vu < n_hot:
            idx = heavy[rng.integers(0, len(heavy), size=n_events)]
            sleep = rng.uniform(*hot_think, size=n_events)
        else:
            idx = rng.choice(len(funcs), size=n_events, p=weights)
            sleep = rng.uniform(*cold_think, size=n_events)
        programs.append(VUProgram(np.asarray(idx), sleep))
    return programs


def make_sleeper_programs(
    funcs: Sequence[FunctionSpec],
    n_vus: int,
    n_events: int,
    seed: int,
    hot_frac: float = 0.25,
    quiet_s: Tuple[float, float] = (4.0, 6.0),
    hot_think: Tuple[float, float] = (0.02, 0.1),
    cold_think: Tuple[float, float] = (1.0, 3.0),
) -> List[VUProgram]:
    """A *post-admission* imbalance workload: sleepers that turn hot.

    The first ``hot_frac`` of VUs are **sleepers**: their first request is
    light and followed by a long ``quiet_s`` think, after which they hammer
    heavy functions with near-zero think time.  At admission time a sleeper
    is indistinguishable from a cold VU — it contributes almost nothing to
    ``Simulator.pressure`` — so pressure-keyed admission necessarily places
    them by *current* load, and whichever shards took more sleepers blow up
    only after binding.  That is exactly the imbalance admission-time pull
    cannot fix and cross-shard work stealing (``policy="pull+steal"``) can.
    Deterministic per ``(seed, vu)`` like the other generators.
    """
    warm = np.asarray([f.warm_ms for f in funcs])
    heavy = np.flatnonzero(warm >= np.median(warm))
    light = np.flatnonzero(warm <= np.median(warm))
    weights = np.asarray([f.weight for f in funcs])
    weights = weights / weights.sum()
    n_hot = int(round(hot_frac * n_vus))
    programs = []
    for vu in range(n_vus):
        rng = np.random.default_rng((seed, vu))
        if vu < n_hot:
            idx = heavy[rng.integers(0, len(heavy), size=n_events)]
            sleep = rng.uniform(*hot_think, size=n_events)
            idx[0] = light[rng.integers(0, len(light))]  # light first touch
            sleep[0] = rng.uniform(*quiet_s)  # ... then the long nap
        else:
            idx = rng.choice(len(funcs), size=n_events, p=weights)
            sleep = rng.uniform(*cold_think, size=n_events)
        programs.append(VUProgram(np.asarray(idx), sleep))
    return programs


class AdmissionSimulator:
    """K shard simulators behind ONE pull-based global admission queue.

    Same worker partition and per-shard seeding contract as
    ``ShardedSimulator`` (largest-remainder split, golden-ratio
    ``shard_seed`` stride), but the VU population is *not* partitioned at
    plan time: shards pull arrivals from the shared admission queue when
    their local pressure drops below the watermark.  All shards serve one
    shared function population (``make_functions(seed)``) so any VU can bind
    to any shard.

    Args:
        n_shards: shard (independent cluster) count, >= 1.
        n_workers: total workers, split evenly across shards.
        scheduler: intra-shard scheduler name (``make_scheduler``).
        cfg: per-shard config template; ``n_workers`` is rewritten per shard.
        seed: global workload seed; shard ``k`` runs with
            ``shard_seed(seed, k)``.
        admission: :class:`AdmissionConfig` knobs.
    """

    def __init__(
        self,
        n_shards: int,
        n_workers: int,
        scheduler: str = "hiku",
        cfg: Optional[SimConfig] = None,
        seed: int = 0,
        admission: Optional[AdmissionConfig] = None,
    ):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if n_workers < n_shards:
            raise ValueError("need at least one worker per shard")
        self.n_shards = int(n_shards)
        self.n_workers = int(n_workers)
        self.scheduler = scheduler
        self.cfg = cfg or SimConfig()
        self.seed = int(seed)
        self.admission = admission or AdmissionConfig()
        # config values are validated by AdmissionConfig.__post_init__;
        # re-resolve the policy here so a name unregistered since the config
        # was built still fails fast, with the live available list
        self._policy_cls = get_policy_class(self.admission.policy)
        self.worker_split = split_even(self.n_workers, self.n_shards)
        self.worker_offsets = [0]
        for n in self.worker_split:
            self.worker_offsets.append(self.worker_offsets[-1] + n)
        # per-shard effective-pressure increment per admitted/stolen VU
        self.inv_workers = [1.0 / max(n, 1) for n in self.worker_split]
        self.funcs = make_functions(seed=self.seed)
        # fault schedule over GLOBAL worker ids (chaos.FaultPlan targets):
        # resolved to (shard, local) pairs when run() builds the shard sims
        self._failures: List[Tuple[float, int]] = []
        self._additions: List[Tuple[float, int]] = []
        self._notices: List[Tuple[float, int, float]] = []  # (t, gworker, until)

    # ------------------------------------------------------------- faults
    def _locate(self, worker: int, hook: str) -> Tuple[int, int]:
        """Global worker id -> (shard, local id) under the static partition.

        Ids outside ``[0, n_workers)`` are rejected — like the sharded
        driver, the admission tier's merge remaps by fixed shard offsets, so
        capacity beyond the partition would collide after the merge; revive
        failed ids instead of inventing new ones."""
        if not 0 <= worker < self.n_workers:
            raise ValueError(
                f"{hook}: global worker id {worker} out of range "
                f"[0, {self.n_workers}) — the admission tier's partition is "
                "static; inject_worker revives failed ids only"
            )
        k = bisect.bisect_right(self.worker_offsets, worker) - 1
        return k, worker - self.worker_offsets[k]

    def inject_failure(self, t: float, worker: int) -> None:
        """Schedule global worker ``worker`` to fail at time ``t`` (chaos
        hook; ``core.chaos.FaultPlan.apply`` drives this).  Validated
        against the partition here and against the run deadline by the
        owning shard's ``begin()``."""
        self._locate(worker, "inject_failure")
        self._failures.append((float(t), int(worker)))

    def inject_worker(self, t: float, worker: int) -> None:
        """Schedule global worker ``worker`` to (re)join at time ``t`` —
        the revival path that brings a dead shard back as an
        admission/steal candidate."""
        self._locate(worker, "inject_worker")
        self._additions.append((float(t), int(worker)))

    def inject_notice(self, t: float, worker: int, until: float) -> None:
        """Advisory preemption notice: ``worker`` will fail at ``until``.

        Never load-bearing — policies see it as ``ShardState
        .doomed_workers`` between ``t`` and ``until`` and may shed load
        early; the kill itself needs its own ``inject_failure`` (the
        ``spot_preemption`` plan emits both)."""
        self._locate(worker, "inject_notice")
        if until < t:
            raise ValueError(f"inject_notice: until={until} precedes t={t}")
        self._notices.append((float(t), int(worker), float(until)))

    # ----------------------------------------------------------------- run
    def run(
        self,
        n_vus: int = 20,
        duration_s: float = 100.0,
        programs: Optional[Sequence[VUProgram]] = None,
        arrivals: Optional[Sequence[float]] = None,
        deadlines: Optional[Sequence[float]] = None,
        faults: Optional["FaultPlan"] = None,  # noqa: F821 (core.chaos)
        bus: Optional[EventPlane] = None,
        autoscaler: Optional[Autoscaler] = None,
        metrics_window_s: Optional[float] = None,
    ) -> AdmissionRun:
        """Co-run the K shards under the global admission queue.

        Args:
            n_vus: global VU population size.
            duration_s: simulated experiment length, seconds.
            programs: explicit VU programs (len == ``n_vus``); default
                generates the seeded Azure-like workload over the shared
                function population.
            arrivals: per-VU admission-eligibility times, seconds (default:
                all eligible at t=0).  Admission happens only at tick
                boundaries ``i * tick_s`` strictly below ``duration_s``, and
                a VU is admissible at the first boundary at or after its
                arrival — so arrivals past the last such boundary (in
                particular any at or after ``duration_s``) are never
                admitted and count as unadmitted.  Shrink ``tick_s`` to
                shrink that end-of-run blind window.
            deadlines: per-VU *relative* latency deadlines, seconds
                (default: none; ``inf`` = that VU carries no SLO).
                Deadline-aware policies order the global queue by
                ``arrival + deadline`` (EDF), and
                ``AdmissionRun.summarize`` scores
                ``RunMetrics.deadline_miss_rate`` — the fraction of
                SLO-carrying VUs whose *first completion* landed after
                ``arrival + deadline`` (admission-queue wait is charged;
                a VU that never completes counts as missed; later
                requests are not scored).  Scenario generators in
                ``core.workloads`` produce these.
            faults: optional ``core.chaos.FaultPlan`` applied to this run —
                equivalent to calling :meth:`inject_failure` /
                :meth:`inject_worker` / :meth:`inject_notice` for each
                event before the run.  Scenario bundles carry one in
                ``Scenario.faults``.
            bus: optional :class:`~repro.core.eventplane.EventPlane` the
                loop publishes window summaries onto — one ``("shard", k)``
                event per shard (ascending ``k``, the merge tie-break)
                then one ``("cluster",)`` event per completed metric
                window, plus a final partial-window flush after the loop
                drains.  Subscribers must be registered before this call
                (the bus is sealed as the loops arm, §14).
            autoscaler: optional :class:`~repro.core.autoscale.Autoscaler`
                — subscribed to the bus (one is created when ``bus`` is
                None), bound to an actuator over this run's shards, and
                given the initial pool sizing before the loops arm.  Its
                decision window is ``autoscaler.cfg.window_s``.
            metrics_window_s: publish cadence when ``bus`` is given
                without an autoscaler (default 1.0).  Either way the
                window must be a positive multiple of ``tick_s``.

        Any VU still unadmitted at the deadline is reported on
        ``AdmissionRun.unadmitted`` and raises a ``RuntimeWarning`` — a
        silently shrunken population is a bug magnet in benchmarks.

        Deterministic for fixed inputs: the admission loop advances
        simulated time in ``tick_s`` slices, every registered policy's
        decisions are a pure function of the visible state (the
        ``core.policies`` determinism contract), and under stealing
        policies the steal schedule is equally a total order (see
        ``core.stealing``).
        """
        adm = self.admission
        policy = make_policy(adm.policy, adm, **dict(adm.policy_args or {}))
        if programs is None:
            programs = make_vu_programs(
                self.funcs, n_vus, default_n_events(duration_s), self.seed
            )
        programs = list(programs)
        if len(programs) != n_vus:
            raise ValueError(f"len(programs)={len(programs)} != n_vus={n_vus}")
        if arrivals is None:
            arr = np.zeros(n_vus)
        else:
            arr = np.asarray(arrivals, np.float64)
            if arr.shape != (n_vus,):
                raise ValueError(f"arrivals shape {arr.shape} != ({n_vus},)")
        if deadlines is None:
            dl = None
        else:
            dl = np.asarray(deadlines, np.float64)
            if dl.shape != (n_vus,):
                raise ValueError(f"deadlines shape {dl.shape} != ({n_vus},)")
        order = np.argsort(arr, kind="stable")  # admission-queue order
        if faults is not None:
            faults.apply(self)

        sims: List[Simulator] = []
        for k in range(self.n_shards):
            sk = shard_seed(self.seed, k)
            sched = make_scheduler(self.scheduler, self.worker_split[k], seed=sk)
            sim = Simulator(
                sched,
                funcs=self.funcs,
                cfg=dataclasses.replace(self.cfg, n_workers=self.worker_split[k]),
                seed=sk,
            )
            sims.append(sim)
        # route the fault schedule to the owning shards, then arm the loops
        # (begin() validates each shard's schedule against the deadline)
        for ft, gw in self._failures:
            k, local = self._locate(gw, "inject_failure")
            sims[k].inject_failure(ft, local)
        for ft, gw in self._additions:
            k, local = self._locate(gw, "inject_worker")
            sims[k].inject_worker(ft, local)
        notices = []  # (t_notice, shard, t_kill), doomed-worker signal
        for ft, gw, until in self._notices:
            k, local = self._locate(gw, "inject_notice")
            notices.append((ft, k, until))
            # forward to the owning engine too: inside the window the worker
            # drops out of warm_capacity()/warm_digest() (doomed capacity is
            # not headroom — the §11 bugfix), with zero event-loop effect
            sims[k].inject_notice(ft, local, until)

        # ---- live event plane + autoscaler (docs/ARCHITECTURE.md §14) ----
        # Publishing and sizing are opt-in: with neither a bus nor an
        # autoscaler this block is four no-op tests and the loop below is
        # byte-identical to the static form.
        actuator = None
        m_win = 0
        if autoscaler is not None:
            if bus is None:
                bus = EventPlane()
            win_s = autoscaler.cfg.window_s
        elif bus is not None:
            win_s = 1.0 if metrics_window_s is None else float(metrics_window_s)
        if bus is not None:
            m_win = round(win_s / adm.tick_s)
            if m_win < 1 or abs(m_win * adm.tick_s - win_s) > 1e-9:
                raise ValueError(
                    f"metric window {win_s}s must be a positive multiple of "
                    f"tick_s={adm.tick_s} — summaries publish on tick "
                    "boundaries only"
                )
        if autoscaler is not None:
            actuator = AutoscaleActuator(
                sims, self.worker_split, self.worker_offsets, notices,
                duration_s, autoscaler.cfg.notice_s,
            )
            autoscaler.attach(bus, actuator, self.worker_split)
            # initial pool: workers above each shard's initial target are
            # retired at t=0 through the same validated inject path the
            # chaos tier uses, so begin() checks the whole schedule at once
            for k, keep in enumerate(autoscaler.initial_split(self.worker_split)):
                for local in range(keep, self.worker_split[k]):
                    sims[k].inject_failure(0.0, local)
        if bus is not None:
            bus.seal()  # §14: subscribers register before the loops arm
        pub_seen = [0] * self.n_shards  # per-shard published-record cursors
        pub_widx = 0  # next metric-window index
        win_arrivals = 0  # VUs that became eligible this window

        for sim in sims:
            sim.begin(n_vus=0, duration_s=duration_s, programs=[])

        admitted: List[List[int]] = [[] for _ in range(self.n_shards)]
        admit_t: List[List[float]] = [[] for _ in range(self.n_shards)]
        pulls = [0] * self.n_shards
        migrations: List[Migration] = []
        ctx = PolicyContext(
            sims=sims,
            programs=programs,
            worker_split=self.worker_split,
            inv_workers=self.inv_workers,
            admitted=admitted,
            admit_t=admit_t,
            pulls=pulls,
            policy=policy,
            arrivals=arr,
            deadlines=dl,
        )
        # change-driven cluster view (docs/ARCHITECTURE.md §13): every shard
        # publishes a dirty flag on state change; refresh() below re-reads
        # only those shards, and the heap/steal/drain consumers run off the
        # cached deltas — byte-identical decisions at O(dirty) per tick
        coord = ShardCoordinator(sims)
        ctx.coord = coord
        qpos = 0
        queue_t: List[float] = []
        queue_depth: List[int] = []
        salvages: List[Salvage] = []
        salvage_buf: List[Tuple[int, SalvagedVU]] = []
        tick = 0
        t = 0.0
        t0 = time.perf_counter()
        while True:
            coord.refresh()  # drain the dirty set: the tick's cached view
            if m_win and tick and tick % m_win == 0:
                # a metric window just completed: every event with time <= t
                # has been processed, so the per-shard record accumulators
                # hold exactly the completions with t_done <= t.  Publish
                # (and let the autoscaler react) before this tick's
                # admissions — capacity decisions see last window's truth,
                # never a half-applied tick.
                self._publish_window(
                    bus, sims, coord, ctx, pub_seen, pub_widx,
                    t - win_s, t, win_arrivals,
                )
                pub_widx += 1
                win_arrivals = 0
            n_new = 0
            while qpos < n_vus and arr[order[qpos]] <= t:
                ctx.enqueue(int(order[qpos]))
                qpos += 1
                n_new += 1
            win_arrivals += n_new
            policy.observe(t, n_new, ctx)
            if notices:  # doomed-but-alive workers, per shard, right now
                doomed = [0] * self.n_shards
                for tn, k, until in notices:
                    if tn <= t < until:
                        doomed[k] += 1
                ctx.doomed = doomed
            if adm.salvage and t < duration_s and (coord.dead or salvage_buf):
                # dead-shard drain BEFORE fresh admissions: recovered work
                # re-enters the cluster ahead of new arrivals (§10 salvage
                # ordering), binding to the least-pressured live shards.
                # Skipped outright while no shard is dead and nothing is
                # buffered — the drain would scan and return empty anyway.
                moves, salvage_buf = drain_tick(
                    sims, self.inv_workers, t, pending=salvage_buf,
                    dead=coord.dead, pressures=coord.pressure,
                )
                for mv in moves:
                    gid = admitted[mv.src][mv.src_vu]
                    assert mv.dst_vu == len(admitted[mv.dst])
                    admitted[mv.dst].append(gid)
                    admit_t[mv.dst].append(mv.t)
                salvages.extend(moves)
            if t < duration_s and ctx.waiting_n:
                policy.admit_tick(t, ctx)
            if policy.steals and t < duration_s:
                # post-admission rebalance: the pull heap run in reverse too;
                # the watermark pair routes through the policy so learned
                # stealing policies (bandit+steal) can tune the band per
                # window (default: the static config pair, byte-identical)
                steal_wm, pull_wm = policy.steal_params()
                if steal_wm < pull_wm:  # the steal_tick invariant, kept
                    raise ValueError(  # loud even on skipped quiet ticks
                        f"steal_watermark {steal_wm} must be >= pull "
                        f"watermark {pull_wm} (a shard must never be victim "
                        "and thief at once)"
                    )
                # O(dirty) victim probe: with every cached pressure at or
                # below the steal watermark no shard qualifies as victim,
                # so the whole round is a guaranteed no-op — skip it.
                # (Admissions this tick never raise *live* pressure — they
                # only schedule submit events — so the cache is current.)
                if coord.pressure_max() > steal_wm:
                    moves = steal_tick(
                        sims,
                        steal_watermark=steal_wm,
                        pull_watermark=pull_wm,
                        inv_workers=self.inv_workers,
                        t=t,
                        max_moves=adm.steal_batch,
                        prefer_warm=policy.steal_affinity,
                        pressures=coord.pressure,
                    )
                else:
                    moves = []
                for mv in moves:
                    gid = admitted[mv.src][mv.src_vu]
                    assert mv.dst_vu == len(admitted[mv.dst])
                    admitted[mv.dst].append(gid)
                    admit_t[mv.dst].append(t)
                migrations.extend(moves)
            queue_t.append(t)
            queue_depth.append(ctx.waiting_n)
            if t >= duration_s and all(s.done for s in sims):
                break
            tick += 1
            t = tick * adm.tick_s  # drift-free, like _stream_windows
            for sim in sims:
                # frontier skip: a shard with nothing scheduled inside the
                # tick would pop no events (and never advance its clock), so
                # the call is a no-op — one O(1) peek instead
                if sim.next_event_time() <= t:
                    sim.step_until(t)
        if m_win and any(len(sim._rec) > s for sim, s in zip(sims, pub_seen)):
            # trailing completions past the last boundary: one final partial
            # window, so the published per-shard counts always sum to the
            # full record stream (pinned in tests/test_stream.py)
            self._publish_window(
                bus, sims, coord, ctx, pub_seen, pub_widx,
                pub_widx * win_s, t, win_arrivals,
            )
        wall_s = time.perf_counter() - t0
        run = self._merge(
            sims, admitted, admit_t, pulls, n_vus, wall_s, queue_t, queue_depth,
            migrations, dl, arr, salvages, salvage_buf,
        )
        for k, sim in enumerate(sims):
            run.shards[k].worker_seconds = sim.worker_seconds_until(duration_s)
        if getattr(policy, "record_state", False):
            run.policy_state = list(policy.snapshots)
        return run

    def _publish_window(
        self, bus, sims, coord, ctx, seen, widx, t_lo, t_hi, arrivals,
    ) -> None:
        """Publish one completed metric window: ``("shard", k)`` events in
        ascending shard order (the merge tie-break), then ``("cluster",)``
        — the §14 publish order.  ``seen`` holds per-shard record cursors
        (same exactly-once idiom as ``PolicyContext.new_completions``, on
        separate cursors so policies and subscribers never race)."""
        total = 0
        for k, sim in enumerate(sims):
            acc = sim._rec
            n = len(acc)
            i = seen[k]
            n_done = n - i
            sum_ms = 0.0
            n_cold = 0
            if n_done:
                ts, td, cold = acc.t_submit, acc.t_done, acc.cold
                for j in range(i, n):
                    sum_ms += (td[j] - ts[j]) * 1e3
                    n_cold += cold[j]
            seen[k] = n
            total += n_done
            bus.publish(
                (SHARD_TOPIC, k), widx, t_lo, t_hi,
                {
                    "n_done": n_done,
                    "sum_ms": sum_ms,
                    "n_cold": int(n_cold),
                    "load": sim._queued_n + sim._busy_n,
                    "alive": len(sim.workers),
                    "outstanding": sim.outstanding(),
                    "pressure": coord.pressure[k],
                },
            )
        bus.publish(
            (CLUSTER_TOPIC,), widx, t_lo, t_hi,
            {
                "n_done": total,
                "arrivals": arrivals,
                "queue_depth": ctx.waiting_n,
            },
        )

    def _merge(
        self, sims, admitted, admit_t, pulls, n_vus, wall_s, queue_t, queue_depth,
        migrations, deadlines=None, arrivals=None, salvages=None, salvage_buf=None,
    ) -> AdmissionRun:
        shards: List[AdmissionShard] = []
        parts: List[RecordColumns] = []
        ats, aws = [], []
        recovery: List[float] = []
        for k, sim in enumerate(sims):
            vu_map = np.asarray(admitted[k], np.int32)
            cols = sim.record_columns
            at, aw = sim.assignment_columns
            recovery.extend(sim.recovery_s)
            shards.append(
                AdmissionShard(
                    index=k,
                    seed=shard_seed(self.seed, k),
                    n_workers=self.worker_split[k],
                    worker_offset=self.worker_offsets[k],
                    admitted=vu_map,
                    admit_t=np.asarray(admit_t[k]),
                    pulls=pulls[k],
                    records=cols,
                    assign_t=at,
                    assign_w=aw,
                    n_events=sim.n_events,
                    stolen_out=sim.stolen_out,
                    stolen_in=sim.stolen_in,
                    resubmits=sim.resubmits,
                    lost_tasks=sim.lost_tasks,
                    salvaged_out=sim.salvaged_out,
                    salvaged_in=sim.salvaged_in,
                    outstanding=sim.outstanding(),
                    alive=bool(sim.workers),
                )
            )
            parts.append(cols.remap(worker_offset=self.worker_offsets[k]).remap_vus(vu_map))
            ats.append(at)
            aws.append(aw + self.worker_offsets[k])
        records = merge_window(parts)
        at, aw = merge_assignments(ats, aws)
        # a migrated VU appears in both the victim's and the receiver's
        # admission tables; the global population counts it once
        unique_admitted = len({g for a in admitted for g in a})
        unadmitted = n_vus - unique_admitted
        if unadmitted > 0:
            warnings.warn(
                f"{unadmitted} of {n_vus} VUs were never admitted (arrival in "
                "the end-of-run blind window, or watermark backpressure held "
                "them in the queue past the deadline); see "
                "AdmissionRun.unadmitted and the `arrivals` docs on "
                "AdmissionSimulator.run",
                RuntimeWarning,
                stacklevel=3,
            )
        return AdmissionRun(
            shards=shards,
            records=records,
            assign_t=at,
            assign_w=aw,
            workers=list(range(self.n_workers)),
            n_events=sum(s.n_events for s in sims),
            wall_s=wall_s,
            admitted=unique_admitted,
            unadmitted=unadmitted,
            queue_t=np.asarray(queue_t),
            queue_depth=np.asarray(queue_depth, np.int64),
            migrations=list(migrations),
            deadline_ms=None if deadlines is None else deadlines * 1e3,
            arrival_s=arrivals,
            salvages=list(salvages or ()),
            unsalvaged=sum(1 for _, sv in (salvage_buf or ()) if sv.in_flight),
            recovery_s=np.asarray(recovery, np.float64),
        )
