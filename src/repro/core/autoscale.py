"""Predictive pool autoscaling driven by the live metric event plane.

The admission loop (``AdmissionSimulator.run``) publishes one window
summary per shard per metric window onto an :class:`~repro.core.eventplane
.EventPlane`; the :class:`Autoscaler` here subscribes, forecasts demand,
and reconciles each shard's worker pool through an
:class:`AutoscaleActuator` — the mechanism half that issues the engine's
mid-run elasticity hooks (``schedule_worker_add`` / ``schedule_notice`` +
``schedule_worker_fail``).  Those are the *same* hooks the chaos tier
(``core.chaos``) compiles fault plans onto, so autoscaler actions and
injected faults interleave on one schedule, and every mutation marks the
owning shard dirty for the ShardCoordinator (§13).

Sizing brain (policy half, :class:`Autoscaler`):

* **reactive** — pure present-state feedback: each shard is sized to hold
  its *current* load (queued + busy tasks) at ``target_pressure``.
* **predictive** — the reactive floor plus an MPC-style horizon (Nguyen et
  al., PAPERS.md): cluster throughput is forecast by an EWMA with a linear
  trend term, per-request service time by a Welford estimator
  (:class:`~repro.core.estimators.DurationEstimator`), and the pool is
  sized for the *worst* forecast window within ``horizon_windows`` via
  Little's law — capacity arrives before the burst does, not after.

Scale-down always goes through a **notice window** first
(``schedule_notice`` then ``schedule_worker_fail`` at ``t + notice_s``):
while the notice is open the worker is excluded from
``warm_capacity``/``warm_digest`` (the PR-7 doomed-worker rule), so
admission and stealing stop routing work onto capacity about to retire.
A **scale-to-zero janitor** retires a shard's whole pool after
``idle_windows`` windows with no load, no outstanding work, and an empty
global queue (ColdBot-style); the admission tier's dead-shard salvage
drain re-homes any straggler VU, which is exactly the §10 machinery the
chaos tier already exercises.

Worker ids stay inside the static partition (``AdmissionSimulator``'s
merge remaps by fixed shard offsets): scale-up *revives* dead local ids,
never invents new ones.  Every decision is a pure function of the
published payload stream, so autoscaled runs are replayable bit-for-bit.
Contract: docs/ARCHITECTURE.md §14.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, NamedTuple, Optional, Sequence, Set

from .estimators import DurationEstimator
from .eventplane import CLUSTER_TOPIC, EventPlane, MetricEvent, SHARD_TOPIC

__all__ = ["AutoscaleConfig", "AutoscaleAction", "AutoscaleActuator", "Autoscaler"]


@dataclasses.dataclass(frozen=True)
class AutoscaleConfig:
    """Sizing knobs (validated; frozen so a config can key caches).

    ``window_s`` must be a positive multiple of the admission tier's
    ``tick_s`` — the run loop publishes (and the autoscaler decides) only
    on tick boundaries.
    """

    mode: str = "predictive"  # "reactive" | "predictive"
    window_s: float = 1.0  # metric/decision window, seconds
    target_pressure: float = 0.7  # size pools to hold load at this pressure
    min_workers: int = 1  # per-shard floor while the shard has work
    initial_frac: float = 0.5  # fraction of each shard's span alive at t=0
    notice_s: float = 1.0  # scale-down drain notice before the kill
    horizon_windows: int = 3  # MPC lookahead (predictive mode)
    alpha: float = 0.5  # EWMA smoothing for the throughput forecast
    max_step: int = 4  # max workers added per shard per window
    down_step: int = 1  # max workers retired per shard per window
    down_after: int = 2  # consecutive excess windows before any retirement
    scale_to_zero: bool = True  # allow the janitor to empty idle shards
    idle_windows: int = 3  # idle windows before the janitor zeroes a shard

    def __post_init__(self):
        if self.mode not in ("reactive", "predictive"):
            raise ValueError(
                f"mode must be 'reactive' or 'predictive', got {self.mode!r}"
            )
        if self.window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {self.window_s}")
        if not 0 < self.target_pressure <= 1:
            raise ValueError(
                f"target_pressure must be in (0, 1], got {self.target_pressure}"
            )
        if self.min_workers < 0:
            raise ValueError(f"min_workers must be >= 0, got {self.min_workers}")
        if not 0 < self.initial_frac <= 1:
            raise ValueError(
                f"initial_frac must be in (0, 1], got {self.initial_frac}"
            )
        if self.notice_s < 0:
            raise ValueError(f"notice_s must be >= 0, got {self.notice_s}")
        if self.horizon_windows < 1:
            raise ValueError(
                f"horizon_windows must be >= 1, got {self.horizon_windows}"
            )
        if not 0 < self.alpha <= 1:
            raise ValueError(f"alpha must be in (0, 1], got {self.alpha}")
        if self.max_step < 1:
            raise ValueError(f"max_step must be >= 1, got {self.max_step}")
        if self.down_step < 1:
            raise ValueError(f"down_step must be >= 1, got {self.down_step}")
        if self.down_after < 1:
            raise ValueError(f"down_after must be >= 1, got {self.down_after}")
        if self.idle_windows < 1:
            raise ValueError(f"idle_windows must be >= 1, got {self.idle_windows}")


class AutoscaleAction(NamedTuple):
    """One issued pool mutation (telemetry; ``worker`` is the GLOBAL id)."""

    t: float  # decision time (the window boundary)
    kind: str  # "add" | "notice" | "fail"
    shard: int
    worker: int
    fire_t: float  # when the engine event fires (== t for adds)


class AutoscaleActuator:
    """Mechanism half: reconcile per-shard pool sizes onto engine hooks.

    Owns the only mutable coupling to the run — it is constructed by
    ``AdmissionSimulator.run`` with the live shard sims, the run's notice
    list (the policy-visible doomed-worker signal), and the run deadline.
    ``scale_to`` converges the shard toward ``target`` workers: scale-up
    revives dead local ids lowest-id-first, scale-down dooms live ids
    highest-id-first through a notice window.  Actions whose engine event
    would land at or past the deadline are dropped (they could never fire,
    and begin()-style validation would raise) — the run always terminates.
    """

    def __init__(
        self,
        sims: Sequence,
        worker_split: Sequence[int],
        worker_offsets: Sequence[int],
        notices: List,
        duration_s: float,
        notice_s: float,
    ):
        self.sims = list(sims)
        self.worker_split = list(worker_split)
        self.worker_offsets = list(worker_offsets)
        self._notices = notices  # shared with the admission loop: (t, k, until)
        self.duration_s = float(duration_s)
        self.notice_s = float(notice_s)
        self.actions: List[AutoscaleAction] = []
        self._pending_add: List[Set[int]] = [set() for _ in sims]
        self._doomed: List[Dict[int, float]] = [{} for _ in sims]

    def alive(self, k: int) -> int:
        return len(self.sims[k].workers)

    def planned(self, k: int, t: float) -> int:
        """Pool size shard ``k`` is converging to: live workers plus
        scheduled-but-unfired adds minus scheduled-but-unfired kills.
        Purges bookkeeping for events that already fired (or workers the
        chaos tier killed out from under us) as a side effect."""
        sim = self.sims[k]
        workers = sim.workers
        self._pending_add[k] = {w for w in self._pending_add[k] if w not in workers}
        self._doomed[k] = {
            w: tk for w, tk in self._doomed[k].items() if w in workers
        }
        return len(workers) + len(self._pending_add[k]) - len(self._doomed[k])

    def scale_to(self, t: float, k: int, target: int) -> int:
        """Issue the adds/dooms moving shard ``k`` toward ``target`` live
        workers.  Returns the signed number of actions issued."""
        span = self.worker_split[k]
        target = max(0, min(int(target), span))
        sim = self.sims[k]
        planned = self.planned(k, t)
        off = self.worker_offsets[k]
        if planned < target:
            need = target - planned
            if t >= self.duration_s:
                return 0  # an add at/past the deadline could never fire
            dead = [
                w for w in range(span)
                if w not in sim.workers and w not in self._pending_add[k]
            ]
            for w in dead[:need]:
                sim.schedule_worker_add(t, w)
                self._pending_add[k].add(w)
                self.actions.append(AutoscaleAction(t, "add", k, off + w, t))
            return min(need, len(dead))
        if planned > target:
            t_kill = t + self.notice_s
            if t_kill >= self.duration_s:
                return 0  # never doom capacity the run can't outlive
            excess = planned - target
            victims = [
                w for w in sorted(sim.workers, reverse=True)
                if w not in self._doomed[k]
            ]
            n = 0
            for w in victims[:excess]:
                sim.schedule_notice(t, w, t_kill)
                self._notices.append((t, k, t_kill))
                sim.schedule_worker_fail(t_kill, w)
                self._doomed[k][w] = t_kill
                self.actions.append(AutoscaleAction(t, "notice", k, off + w, t))
                self.actions.append(AutoscaleAction(t, "fail", k, off + w, t_kill))
                n += 1
            return -n
        return 0


class Autoscaler:
    """Policy half: subscribe to the event plane, forecast, pick targets.

    Pure function of the published payload stream: per-shard reactive
    loads come from the ``("shard", k)`` events, the cluster forecast
    state (EWMA throughput + trend, Welford service time) updates on the
    ``("cluster",)`` event — which the §14 publish order delivers *last*
    within a window, so decisions always see the complete window.
    """

    def __init__(self, cfg: Optional[AutoscaleConfig] = None):
        self.cfg = cfg or AutoscaleConfig()
        self.actuator: Optional[AutoscaleActuator] = None
        self.worker_split: List[int] = []
        self._est = DurationEstimator(prior_ms=200.0)
        self._rate: Optional[float] = None  # EWMA completions/s, cluster
        self._trend = 0.0  # smoothed d(rate)/window
        self._win: Dict[int, Mapping] = {}
        self._idle: List[int] = []
        self._excess: List[int] = []  # consecutive over-provisioned windows
        self.targets_log: List[List[int]] = []  # per decision window

    # ------------------------------------------------------------- wiring
    def initial_split(self, worker_split: Sequence[int]) -> List[int]:
        """Initial per-shard pool sizes: ``ceil(initial_frac * span)``,
        floored at ``min_workers`` (capped by the span)."""
        cfg = self.cfg
        return [
            min(n, max(math.ceil(cfg.initial_frac * n), cfg.min_workers))
            for n in worker_split
        ]

    def attach(
        self, bus: EventPlane, actuator: AutoscaleActuator,
        worker_split: Sequence[int],
    ) -> None:
        """Bind to a run: subscribe on ``bus`` (must be unsealed) and take
        the actuator the decisions drive.  One Autoscaler drives one run."""
        if self.actuator is not None:
            raise RuntimeError(
                "Autoscaler is already attached to a run; build a fresh one "
                "(forecast state is per-run)"
            )
        self.actuator = actuator
        self.worker_split = list(worker_split)
        self._idle = [0] * len(worker_split)
        self._excess = [0] * len(worker_split)
        bus.subscribe((SHARD_TOPIC, "*"), self._on_shard)
        bus.subscribe((CLUSTER_TOPIC,), self._on_cluster)

    # ------------------------------------------------------- subscribers
    def _on_shard(self, ev: MetricEvent) -> None:
        self._win[ev.topic[1]] = ev.payload

    def _on_cluster(self, ev: MetricEvent) -> None:
        cfg = self.cfg
        p = ev.payload
        # ---- forecast state update (estimators.py Welford + EWMA) ----
        n_done = int(p.get("n_done", 0))
        lam = n_done / cfg.window_s  # observed completions/s this window
        if self._rate is None:
            self._rate, self._trend = lam, 0.0
        else:
            prev = self._rate
            self._rate = cfg.alpha * lam + (1 - cfg.alpha) * prev
            self._trend = (
                cfg.alpha * (self._rate - prev) + (1 - cfg.alpha) * self._trend
            )
        for k in range(len(self.worker_split)):
            w = self._win.get(k)
            if w and w.get("n_done", 0):
                self._est.update(0, w["sum_ms"] / w["n_done"])
        if self.actuator is None:
            return  # observe-only (e.g. subscribed to a run_stream bus)
        t = ev.t_hi
        queue_depth = int(p.get("queue_depth", 0))
        targets = self._decide(t, queue_depth)
        self.targets_log.append(targets)
        for k, target in enumerate(targets):
            self.actuator.scale_to(t, k, target)

    # --------------------------------------------------------- decisions
    def _decide(self, t: float, queue_depth: int) -> List[int]:
        cfg = self.cfg
        split = self.worker_split
        total_span = sum(split)
        # predictive demand: worst forecast window within the horizon,
        # Little's law (busy workers = throughput x service time), sized to
        # run at target_pressure
        pred_busy = 0.0
        if cfg.mode == "predictive" and self._rate is not None:
            service_s = self._est.predict_ms(0) / 1e3
            lam_worst = max(
                self._rate + h * self._trend for h in range(1, cfg.horizon_windows + 1)
            )
            pred_busy = max(lam_worst, 0.0) * service_s
        targets = []
        for k, span in enumerate(split):
            w = self._win.get(k)
            load = int(w["load"]) if w else 0
            outstanding = int(w.get("outstanding", 0)) if w else 0
            # a share of the global admission queue is demand headed here
            load += int(math.ceil(queue_depth * span / max(total_span, 1)))
            react = math.ceil(load / cfg.target_pressure) if load else 0
            pred = (
                math.ceil(pred_busy * span / total_span / cfg.target_pressure)
                if pred_busy > 0
                else 0
            )
            target = max(react, pred)
            janitor = False
            if load or outstanding or queue_depth or target:
                self._idle[k] = 0
                target = max(target, cfg.min_workers)
            else:
                self._idle[k] += 1
                if cfg.scale_to_zero and self._idle[k] >= cfg.idle_windows:
                    janitor = True  # the pool has been cold long enough
                else:
                    target = max(target, cfg.min_workers)
            # asymmetric convergence: scale up fast (a burst under-served is
            # queueing now), scale down slowly and only on *sustained*
            # excess (retiring warmth on one quiet window churns cold
            # starts — the diurnal trough/crest cycle punishes eagerness).
            # The janitor sweep bypasses the ramp: a provably idle pool
            # retires whole, not one worker per window.
            planned = self.actuator.planned(k, t)
            if janitor:
                target = 0
            elif target < planned:
                self._excess[k] += 1
                if self._excess[k] < cfg.down_after:
                    target = planned  # hold until the excess persists
                else:
                    target = planned - min(cfg.down_step, planned - target)
            else:
                self._excess[k] = 0
                target = min(planned + min(cfg.max_step, target - planned), span)
            targets.append(max(0, min(target, span)))
        return targets
