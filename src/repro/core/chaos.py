"""Declarative fault plans: correlated churn compiled onto the inject hooks.

The engine's fault surface is two hooks — ``inject_failure(t, worker)`` and
``inject_worker(t, worker)`` — one event at a time.  That is the right
*mechanism* (one worker dies, one worker joins), but the failure modes that
actually stress a scheduler are *patterns*: a whole shard's workers dying
together, a spot-preemption wave with a notice window, a rolling restart
marching through the fleet, flappy workers cycling between crash and repair.
ROADMAP item 4 calls for these as first-class scenario bundles; the NOAH
framing (PAPERS.md) is that lost work must be *re-queued, not dropped* —
which is exactly what the dead-shard drain + retry/backoff machinery this
module drives was built to guarantee.

A :class:`FaultPlan` is a named, immutable, time-sorted sequence of
:class:`FaultEvent`s over *global* worker ids.  Generators compile the
high-level patterns above into plans, bit-exactly seeded with the same
discipline as ``core.workloads``: every random draw comes from
``numpy.random.default_rng((seed, entity, TAG))`` — a pure function of the
arguments, so a plan is as replayable as the workload it runs against.

``FaultPlan.apply(target)`` walks the events onto any object exposing the
inject hooks — a single ``Simulator``, the sharded driver, or the admission
tier (``AdmissionSimulator`` additionally understands ``notice`` events:
policies see doomed-but-alive workers through ``ShardState.doomed_workers``
before the kill lands).  Validation stays where it lives: the engine's
``begin()`` rejects events past the run deadline or failures of workers
that never exist, so a plan that doesn't fit its run fails loudly.

What happens *after* the plan fires is the failure/recovery contract of
docs/ARCHITECTURE.md §10: capped-backoff retries with a per-task budget
(``SimConfig.retry_backoff`` / ``retry_max_delay_s`` / ``retry_budget``),
dead-shard salvage with exactly-once conservation
(``core.stealing.drain_tick``), and the failure telemetry columns on
``RunMetrics`` (``benchmarks/bench_chaos.py`` scores every registered
admission policy under these plans).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .shard import split_even

__all__ = [
    "FaultEvent",
    "FaultPlan",
    "flappy_workers",
    "rolling_restart",
    "shard_kill_wave",
    "spot_preemption",
]

# per-generator RNG stream tags (the workloads.py discipline: every stream
# is default_rng((seed, entity, TAG)) — disjoint across generators)
_KILL_TAG = 0xFA11
_SPOT_TAG = 0x5B07
_FLAP_TAG = 0xF1A9

#: event-kind ordering at equal time: a notice precedes the kill it warns
#: about, and an add at the same instant as a fail is processed after it
#: (revival semantics — the engine heap breaks ties by push order, and
#: ``apply`` pushes in plan order)
_KIND_ORDER = {"notice": 0, "fail": 1, "add": 2}


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault-plan event on a *global* worker id.

    ``kind`` is one of:

    * ``"fail"`` — the worker dies at ``t`` (``inject_failure``);
    * ``"add"`` — a worker with this id joins (or rejoins) at ``t``
      (``inject_worker``);
    * ``"notice"`` — a preemption warning: the worker is still alive but
      will be killed at ``until`` (spot semantics).  Targets without a
      ``inject_notice`` hook ignore notices — they are advisory signal for
      admission policies, never load-bearing for correctness.
    """

    t: float
    kind: str
    worker: int
    until: Optional[float] = None  # notice only: the scheduled kill time

    def __post_init__(self):
        if self.kind not in _KIND_ORDER:
            raise ValueError(
                f"unknown FaultEvent kind {self.kind!r}; expected one of "
                f"{sorted(_KIND_ORDER)}"
            )
        if self.t < 0:
            raise ValueError(f"FaultEvent.t must be >= 0, got {self.t}")
        if self.worker < 0:
            raise ValueError(f"FaultEvent.worker must be >= 0, got {self.worker}")
        if self.kind == "notice" and (self.until is None or self.until < self.t):
            raise ValueError(
                f"notice events need until >= t, got t={self.t} until={self.until}"
            )


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A named, immutable, time-sorted schedule of :class:`FaultEvent`s.

    Construction sorts events by ``(t, kind order, worker)`` — notice before
    fail before add at equal times — so two plans built from the same events
    in any order are equal and apply identically.  Plans compose with ``+``
    (events merged, re-sorted).
    """

    name: str
    events: Tuple[FaultEvent, ...]

    def __init__(self, name: str, events: Iterable[FaultEvent]):
        object.__setattr__(self, "name", str(name))
        ordered = tuple(
            sorted(events, key=lambda e: (e.t, _KIND_ORDER[e.kind], e.worker))
        )
        object.__setattr__(self, "events", ordered)

    def __len__(self) -> int:
        return len(self.events)

    def __add__(self, other: "FaultPlan") -> "FaultPlan":
        return FaultPlan(f"{self.name}+{other.name}", self.events + other.events)

    @property
    def horizon(self) -> float:
        """Latest event time (0.0 for an empty plan) — the plan must fit
        inside the run deadline or the engine's ``begin()`` rejects it."""
        out = 0.0
        for e in self.events:
            tt = e.t if e.until is None else e.until
            if tt > out:
                out = tt
        return out

    def apply(self, target) -> "FaultPlan":
        """Walk the plan onto ``target``'s inject hooks and return ``self``.

        ``target`` is anything with ``inject_failure``/``inject_worker``
        (``Simulator``, ``ShardedSimulator``, ``AdmissionSimulator``);
        ``notice`` events go to ``inject_notice(t, worker, until)`` when the
        target has it and are dropped otherwise (advisory only).
        """
        notice = getattr(target, "inject_notice", None)
        for e in self.events:
            if e.kind == "fail":
                target.inject_failure(e.t, e.worker)
            elif e.kind == "add":
                target.inject_worker(e.t, e.worker)
            elif notice is not None:
                notice(e.t, e.worker, e.until)
        return self


def _shard_workers(n_shards: int, n_workers: int, shard: int) -> range:
    """Global worker ids of shard ``shard`` under the even partition the
    sharded driver and admission tier both use (``split_even``)."""
    split = split_even(n_workers, n_shards)
    if not 0 <= shard < n_shards:
        raise ValueError(f"shard {shard} out of range [0, {n_shards})")
    lo = sum(split[:shard])
    return range(lo, lo + split[shard])


def shard_kill_wave(
    n_shards: int,
    n_workers: int,
    shards: Sequence[int],
    t_kill: float,
    stagger_s: float = 0.0,
    jitter_s: float = 0.0,
    seed: int = 0,
) -> FaultPlan:
    """Correlated shard failure: every worker of each listed shard dies.

    The canonical "rack loses power" pattern — the one that strands queued
    work without dead-shard salvage.  Shard ``shards[i]``'s workers all die
    at ``t_kill + i * stagger_s``, each perturbed by an independent
    ``uniform(0, jitter_s)`` drawn from ``default_rng((seed, shard, worker,
    _KILL_TAG))`` (0 jitter: a perfectly correlated instant).  Workers are
    mapped through the same even partition the admission tier uses, so
    "shard k" here is shard k of an ``AdmissionSimulator(n_shards,
    n_workers)``.
    """
    if t_kill < 0 or stagger_s < 0 or jitter_s < 0:
        raise ValueError("t_kill, stagger_s and jitter_s must be >= 0")
    events: List[FaultEvent] = []
    for i, k in enumerate(shards):
        base = t_kill + i * stagger_s
        for w in _shard_workers(n_shards, n_workers, k):
            t = base
            if jitter_s > 0:
                rng = np.random.default_rng((seed, k, w, _KILL_TAG))
                t = base + float(rng.uniform(0.0, jitter_s))
            events.append(FaultEvent(t=t, kind="fail", worker=w))
    return FaultPlan(f"shard_kill_wave[{','.join(map(str, shards))}]", events)


def spot_preemption(
    n_workers: int,
    n_waves: int,
    wave_size: int,
    t0: float,
    t1: float,
    notice_s: float = 2.0,
    replace_after_s: Optional[float] = None,
    seed: int = 0,
) -> FaultPlan:
    """Spot-instance preemption waves with a notice window.

    ``n_waves`` waves land at times drawn ``uniform(t0, t1)`` from
    ``default_rng((seed, wave, _SPOT_TAG))``; each wave preempts
    ``wave_size`` distinct workers sampled without replacement from the
    fleet.  Every victim gets a ``notice`` event ``notice_s`` before its
    kill (the cloud's two-minute warning, scaled) — admission policies see
    it as ``ShardState.doomed_workers`` — then the ``fail``.  With
    ``replace_after_s`` set, a replacement with the same id joins that many
    seconds after the kill (the autoscaler refilling capacity).
    """
    if not 0 <= t0 <= t1:
        raise ValueError(f"need 0 <= t0 <= t1, got t0={t0} t1={t1}")
    if wave_size < 1 or wave_size > n_workers:
        raise ValueError(f"wave_size must be in [1, {n_workers}], got {wave_size}")
    if notice_s < 0:
        raise ValueError("notice_s must be >= 0")
    events: List[FaultEvent] = []
    for wave in range(n_waves):
        rng = np.random.default_rng((seed, wave, _SPOT_TAG))
        t_hit = float(rng.uniform(t0, t1))
        victims = rng.choice(n_workers, size=wave_size, replace=False)
        t_notice = max(0.0, t_hit - notice_s)
        for w in sorted(int(v) for v in victims):
            events.append(FaultEvent(t=t_notice, kind="notice", worker=w, until=t_hit))
            events.append(FaultEvent(t=t_hit, kind="fail", worker=w))
            if replace_after_s is not None:
                events.append(
                    FaultEvent(t=t_hit + replace_after_s, kind="add", worker=w)
                )
    return FaultPlan(f"spot_preemption[{n_waves}x{wave_size}]", events)


def rolling_restart(
    n_workers: int,
    t0: float,
    downtime_s: float,
    stagger_s: float,
    batch: int = 1,
) -> FaultPlan:
    """Deterministic rolling restart: batches of workers cycle down and up.

    Worker ``w`` fails at ``t0 + (w // batch) * stagger_s`` and rejoins
    ``downtime_s`` later — the deploy pattern where capacity dips by
    ``batch`` workers at a time and every task on a restarting worker takes
    the retry path.  No randomness: a restart schedule is operator-chosen,
    not stochastic.
    """
    if downtime_s <= 0 or stagger_s < 0 or batch < 1 or t0 < 0:
        raise ValueError(
            "need downtime_s > 0, stagger_s >= 0, batch >= 1, t0 >= 0"
        )
    events: List[FaultEvent] = []
    for w in range(n_workers):
        t_down = t0 + (w // batch) * stagger_s
        events.append(FaultEvent(t=t_down, kind="fail", worker=w))
        events.append(FaultEvent(t=t_down + downtime_s, kind="add", worker=w))
    return FaultPlan(f"rolling_restart[b{batch}]", events)


def flappy_workers(
    workers: Sequence[int],
    duration_s: float,
    mtbf_s: float,
    mttr_s: float,
    t0: float = 0.0,
    seed: int = 0,
) -> FaultPlan:
    """Flappy workers: independent crash/repair renewal processes.

    Each listed worker alternates alive/dead phases with exponential
    durations — mean ``mtbf_s`` up, mean ``mttr_s`` down — drawn in
    sequence from its own stream ``default_rng((seed, worker, _FLAP_TAG))``,
    truncated at ``duration_s``.  The classic gray-failure workload: no
    shard ever dies outright, but retries and scheduler-view churn never
    stop either.
    """
    if mtbf_s <= 0 or mttr_s <= 0:
        raise ValueError("mtbf_s and mttr_s must be > 0")
    if t0 < 0 or duration_s <= t0:
        raise ValueError(f"need 0 <= t0 < duration_s, got t0={t0}")
    events: List[FaultEvent] = []
    for w in workers:
        rng = np.random.default_rng((seed, int(w), _FLAP_TAG))
        t = t0
        while True:
            t += float(rng.exponential(mtbf_s))
            if t >= duration_s:
                break
            events.append(FaultEvent(t=t, kind="fail", worker=int(w)))
            t += float(rng.exponential(mttr_s))
            if t >= duration_s:
                break
            events.append(FaultEvent(t=t, kind="add", worker=int(w)))
    return FaultPlan(f"flappy[{len(list(workers))}]", events)
