"""Dirty-shard coordination: change-driven cluster state for the tick path.

The admission tier's original tick loop pays O(K) per tick no matter what
the cluster is doing: it re-reads every shard's ``pressure()``, re-scans for
dead shards, and rebuilds the admission heap from scratch — even when not a
single event fired since the last tick.  At the 100k-worker/1M-VU anchor
that coordination cost dominates the run.

:class:`ShardCoordinator` inverts the flow.  Every shard engine publishes a
compact *dirty flag* into a shared sink the moment its admission-visible
state may have changed (``Simulator.attach_dirty`` / ``_mark_dirty`` — the
publication points are normative in docs/ARCHITECTURE.md §13), and the
coordinator re-reads **only the dirty shards** once per tick
(:meth:`refresh`).  Everything downstream consumes the cached deltas:

* the admission pressure heap is *persistent* across ticks with lazy-
  deletion repair keyed on a per-shard version counter — a refreshed shard
  pushes a superseding entry instead of forcing a rebuild;
* ``steal_tick`` / ``drain_tick`` take the cached pressure vector and dead
  set instead of re-polling engines;
* a lazy max-heap answers "could any shard be a steal victim?" in O(dirty)
  amortized, so the steal round is skipped entirely while the cluster is
  below the steal watermark.

Byte-identity argument (pinned by ``tests/test_coord.py`` against
``Simulator._pressure_ref`` and the frozen legacy engine): within a tick,
live pressure only changes at ``steal_queued`` and ``step_until`` — both
*after* every pressure read of the tick — so one cached read per dirty
shard per tick observes exactly the values the O(K) loop would.  The heap
pops identically because ``(pressure, shard_index)`` is a unique total
order: any heap holding the same valid-entry multiset yields the same pop
sequence, stale entries are discarded without effect, and the engine marks
conservatively (a spurious dirty flag costs one cached re-read, never a
decision).
"""

from __future__ import annotations

import heapq
from typing import List, Optional, Sequence, Set, Tuple

__all__ = ["ShardCoordinator"]


class ShardCoordinator:
    """Cached, change-driven view of a shard cluster for one admission run.

    Construction attaches every shard's dirty flag to a shared sink (all
    shards start dirty) and performs the first :meth:`refresh`.  The
    admission loop calls :meth:`refresh` once at the top of each tick;
    between refreshes, :attr:`pressure`, :attr:`dead` and the persistent
    admission heap are the tick's source of truth.

    Attributes:
        pressure: cached ``Simulator.pressure()`` per shard, valid as of the
            last refresh (``inf`` for a dead shard).
        dead: indices of shards with no live workers, as of the last
            refresh.  Iterate ``sorted(dead)`` to preserve shard-index
            order (the drain contract).
        refreshes: total dirty-shard re-reads performed — the coordination
            work actually done; an idle cluster accrues ~0 per tick while
            the O(K) loop would accrue K.
    """

    __slots__ = (
        "sims",
        "dirty",
        "pressure",
        "dead",
        "refreshes",
        "_heap",
        "_entry_ver",
        "_ver",
        "_pmax",
        "_pmax_ver",
        "_compact_at",
    )

    def __init__(self, sims: Sequence) -> None:
        K = len(sims)
        self.sims = list(sims)
        self.dirty: Set[int] = set()
        self.pressure: List[float] = [0.0] * K
        self.dead: Set[int] = set()
        self.refreshes = 0
        # persistent admission heap: (key, shard, ver) valid iff
        # ver == _entry_ver[shard]; _ver is the shard's monotone counter
        self._heap: List[Tuple[float, int, int]] = []
        self._entry_ver: List[int] = [-1] * K
        self._ver: List[int] = [0] * K
        # lazy max-heap over cached pressures: (-pressure, shard, ver)
        # valid iff ver == _pmax_ver[shard]; refreshed entries supersede
        self._pmax: List[Tuple[float, int, int]] = []
        self._pmax_ver: List[int] = [0] * K
        self._compact_at = max(64, 4 * K)
        for k, sim in enumerate(self.sims):
            sim.attach_dirty(self.dirty, k)  # marks every shard dirty now
        self.refresh()

    # ------------------------------------------------------------- refresh
    def refresh(self) -> int:
        """Re-read every dirty shard; returns the number refreshed.

        Per dirty shard: recompute the cached pressure (O(1) — the engine
        keeps incremental queued/busy counters), update the dead set, and
        push superseding entries onto both lazy heaps.  Clean shards are
        untouched, so an idle tick costs O(1).
        """
        d = self.dirty
        if not d:
            return 0
        n = len(d)
        heap, pmax = self._heap, self._pmax
        ver, entry_ver, pmax_ver = self._ver, self._entry_ver, self._pmax_ver
        for k in d:
            sim = self.sims[k]
            p = sim.pressure()
            self.pressure[k] = p
            if sim.workers:
                self.dead.discard(k)
            else:
                self.dead.add(k)
            v = ver[k] + 1
            ver[k] = v
            entry_ver[k] = v
            heapq.heappush(heap, (p, k, v))
            vm = pmax_ver[k] + 1
            pmax_ver[k] = vm
            heapq.heappush(pmax, (-p, k, vm))
        d.clear()
        self.refreshes += n
        if len(heap) > self._compact_at or len(pmax) > self._compact_at:
            self._compact()
        return n

    def _compact(self) -> None:
        """Drop stale entries and re-heapify.  The valid-entry multiset is
        unchanged, so pop order — and every admission decision — is too."""
        ev, mv = self._entry_ver, self._pmax_ver
        self._heap = [e for e in self._heap if ev[e[1]] == e[2]]
        heapq.heapify(self._heap)
        self._pmax = [e for e in self._pmax if mv[e[1]] == e[2]]
        heapq.heapify(self._pmax)

    # ------------------------------------------- persistent admission heap
    def peek(self) -> Optional[Tuple[float, int]]:
        """``(key, shard)`` of the minimum *valid* heap entry, or ``None``
        when every shard's entry has been popped this tick.  Discards stale
        entries from the top as a side effect (lazy repair)."""
        heap, ev = self._heap, self._entry_ver
        while heap:
            key, k, v = heap[0]
            if ev[k] == v:
                return key, k
            heapq.heappop(heap)
        return None

    def pop(self) -> Tuple[float, int]:
        """Pop the minimum *valid* entry (stale entries are discarded on the
        way, like :meth:`peek`); the shard is left with no valid entry until
        the next :meth:`push` or :meth:`refresh`.  Raises ``IndexError``
        when no valid entry remains."""
        heap, ev = self._heap, self._entry_ver
        while True:
            key, k, v = heapq.heappop(heap)
            if ev[k] == v:
                ev[k] = -1
                return key, k

    def push(self, key: float, k: int) -> None:
        """Give shard ``k`` a fresh valid entry at ``key`` (superseding any
        existing one via the version counter)."""
        v = self._ver[k] + 1
        self._ver[k] = v
        self._entry_ver[k] = v
        heapq.heappush(self._heap, (key, k, v))

    # ------------------------------------------------------ steal/drain view
    def pressure_max(self) -> float:
        """Maximum cached pressure across shards (lazy max-heap; O(dirty)
        amortized).  ``steal_tick`` is a guaranteed no-op when this is at
        or below the steal watermark — no shard qualifies as victim."""
        pmax, mv = self._pmax, self._pmax_ver
        while pmax:
            negp, k, v = pmax[0]
            if mv[k] == v:
                return -negp
            heapq.heappop(pmax)
        return float("-inf")
