"""Online learned state for admission policies: duration estimation + bandits.

ROADMAP item 5 asks for data-driven admission — per-function execution-time
estimates (Przybylski et al.) and adaptive thresholds (Nguyen et al.) —
*without losing byte-exact replay*.  This module is the state side of that
contract; ``core.policies`` hosts the policies that consume it (``sjf``,
``bandit``, ``bandit+steal``).

Two building blocks, both with an explicit serializable snapshot:

* :class:`DurationEstimator` — per-function online mean/variance of observed
  request durations, Welford's algorithm (numerically stable single-pass
  moments), plus a global fallback stream for never-seen functions and a
  static prior before any observation at all.
* :class:`BanditTuner` — a tiny multi-armed bandit (UCB1 or seeded
  epsilon-greedy) over a fixed arm set, fed one windowed reward at a time.
  Epsilon-greedy draws come from counter-based streams
  (``np.random.default_rng((seed, step))``), so the tuner carries **no RNG
  object in its state**: the next draw is a pure function of ``(seed,
  step)``, which is what keeps snapshots tiny and replay trivial.

Snapshot contract (normative; docs/POLICIES.md "Learned state"):
``snapshot()`` returns a dict of pure JSON types (str keys, int/float/list
values) that fully determines future behavior given the same constructor
arguments; ``restore(snapshot())`` is a no-op; and a snapshot survives
``json.loads(json.dumps(snap))`` **bit-exactly** — Python floats round-trip
through JSON's repr-based serialization unchanged, and the estimators store
nothing but Python ints and floats.  ``tests/test_estimators.py`` pins all
of this property-style; ``tests/test_replay.py`` pins the run-level
consequence (record-then-replay byte-identity).

Update-order contract: Welford's update is **not** commutative in floating
point, so only the counts (``n``) are exactly permutation-invariant; means
and variances are order-invariant up to numerical noise.  Policies therefore
fold observations in a single canonical order (the completion-stream order
of ``PolicyContext.new_completions``) — determinism comes from the canonical
order, not from commutativity.
"""

from __future__ import annotations

import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = ["BanditTuner", "DurationEstimator"]


def _validated_duration(duration_ms: float) -> float:
    """Reject junk at the update boundary: durations must be finite and > 0.

    A NaN would poison every downstream mean (and every heap the predictions
    key); a zero or negative duration is a caller bug (the completion feed
    measures ``t_done - t_submit`` of a completed request, which is strictly
    positive in the engine).  Raising here keeps estimator state valid by
    construction — the failed update leaves state untouched.
    """
    d = float(duration_ms)
    if not math.isfinite(d) or d <= 0.0:
        raise ValueError(
            f"duration_ms must be finite and > 0, got {duration_ms!r}"
        )
    return d


class _Welford:
    """One Welford moment stream: (n, mean, M2)."""

    __slots__ = ("n", "mean", "m2")

    def __init__(self, n: int = 0, mean: float = 0.0, m2: float = 0.0):
        self.n = int(n)
        self.mean = float(mean)
        self.m2 = float(m2)

    def push(self, x: float) -> None:
        self.n += 1
        delta = x - self.mean
        self.mean += delta / self.n
        self.m2 += delta * (x - self.mean)

    @property
    def variance(self) -> float:
        if self.n < 2:
            return 0.0
        # M2 is non-negative analytically; clamp the (rare) tiny negative
        # float residue so variance() is >= 0 by contract
        return max(self.m2, 0.0) / (self.n - 1)

    def state(self) -> List[float]:
        return [self.n, self.mean, self.m2]


class DurationEstimator:
    """Online per-function duration mean/variance (Welford), with fallback.

    ``update(func, duration_ms)`` folds one observed request duration into
    the function's moment stream *and* a global stream; ``predict_ms(func)``
    returns the function's mean when it has been observed, else the global
    mean, else ``prior_ms`` (cold start of the estimator itself).

    Updates must come only from the ``AdmissionPolicy.observe`` hook (the
    policy-author obligation in docs/POLICIES.md): that is the one place in
    the admission loop where the completion feed is drained exactly once in
    a canonical order, which is what makes estimator state — and therefore
    every decision keyed on it — bit-exactly replayable.
    """

    def __init__(self, prior_ms: float = 200.0):
        p = float(prior_ms)
        if not math.isfinite(p) or p <= 0.0:
            raise ValueError(f"prior_ms must be finite and > 0, got {prior_ms!r}")
        self.prior_ms = p
        self._funcs: Dict[int, _Welford] = {}
        self._global = _Welford()

    # ------------------------------------------------------------- updates
    def update(self, func: int, duration_ms: float) -> None:
        """Fold one observed duration; invalid inputs raise, state untouched."""
        f = int(func)
        if f < 0:
            raise ValueError(f"func index must be >= 0, got {func!r}")
        d = _validated_duration(duration_ms)
        w = self._funcs.get(f)
        if w is None:
            w = self._funcs[f] = _Welford()
        w.push(d)
        self._global.push(d)

    # --------------------------------------------------------------- reads
    @property
    def total_updates(self) -> int:
        """Observations folded so far (across all functions)."""
        return self._global.n

    def n(self, func: int) -> int:
        w = self._funcs.get(int(func))
        return 0 if w is None else w.n

    def mean_ms(self, func: int) -> float:
        """Observed mean duration of ``func`` (NaN when never observed)."""
        w = self._funcs.get(int(func))
        return float("nan") if w is None else w.mean

    def variance_ms2(self, func: int) -> float:
        """Sample variance of ``func``'s durations (0.0 when n < 2; >= 0)."""
        w = self._funcs.get(int(func))
        return 0.0 if w is None else w.variance

    def std_ms(self, func: int) -> float:
        return math.sqrt(self.variance_ms2(func))

    def predict_ms(self, func: int) -> float:
        """Predicted duration: per-func mean -> global mean -> prior."""
        w = self._funcs.get(int(func))
        if w is not None and w.n > 0:
            return w.mean
        if self._global.n > 0:
            return self._global.mean
        return self.prior_ms

    # ----------------------------------------------------------- snapshots
    def snapshot(self) -> dict:
        """Serializable full state: pure JSON types, JSON-round-trip exact."""
        return {
            "version": 1,
            "prior_ms": self.prior_ms,
            "global": self._global.state(),
            "funcs": {str(f): w.state() for f, w in sorted(self._funcs.items())},
        }

    def restore(self, snap: Mapping) -> None:
        """Replace state with ``snap`` (as produced by :meth:`snapshot`,
        possibly after a JSON round trip — string func keys are expected)."""
        if snap.get("version") != 1:
            raise ValueError(f"unsupported estimator snapshot: {snap.get('version')!r}")
        self.prior_ms = float(snap["prior_ms"])
        self._global = _Welford(*snap["global"])
        self._funcs = {int(f): _Welford(*s) for f, s in snap["funcs"].items()}

    @classmethod
    def from_snapshot(cls, snap: Mapping) -> "DurationEstimator":
        est = cls()
        est.restore(snap)
        return est


class BanditTuner:
    """Fixed-arm bandit over windowed rewards (UCB1 or seeded eps-greedy).

    ``arms`` is any fixed sequence of payloads (the values a policy reads
    through :attr:`current` — e.g. watermark multipliers); the tuner only
    tracks per-arm reward statistics and the current arm index.  Rewards are
    "higher is better".  ``feed(reward)`` credits the *current* arm, then
    selects the next arm:

    * untried arms first, in index order (every arm gets one pull);
    * ``mode="ucb"`` — UCB1: ``argmax mean + ucb_c * sqrt(ln(steps) / n)``,
      ties to the lowest index.  Fully deterministic.
    * ``mode="egreedy"`` — with probability ``epsilon`` explore a uniform
      arm, else exploit the best mean.  Both draws come from counter-based
      streams keyed on ``(seed, steps)``, so selection is a pure function
      of the snapshot state: no RNG object to serialize.
    """

    _MODES = ("ucb", "egreedy")
    _EXPLORE_TAG = 0xBA2D  # keeps the explore-index stream disjoint

    def __init__(
        self,
        arms: Sequence,
        mode: str = "ucb",
        epsilon: float = 0.1,
        ucb_c: float = 0.5,
        seed: int = 0,
    ):
        self.arms = tuple(arms)
        if not self.arms:
            raise ValueError("BanditTuner needs at least one arm")
        if mode not in self._MODES:
            raise ValueError(f"mode must be one of {self._MODES}, got {mode!r}")
        if not 0.0 <= float(epsilon) <= 1.0:
            raise ValueError(f"epsilon must be in [0, 1], got {epsilon!r}")
        if float(ucb_c) < 0.0:
            raise ValueError(f"ucb_c must be >= 0, got {ucb_c!r}")
        self.mode = mode
        self.epsilon = float(epsilon)
        self.ucb_c = float(ucb_c)
        self.seed = int(seed)
        self._n = [0] * len(self.arms)
        self._mean = [0.0] * len(self.arms)
        self._steps = 0  # rewards fed so far
        self._arm = 0  # current arm index

    # --------------------------------------------------------------- reads
    @property
    def arm_index(self) -> int:
        return self._arm

    @property
    def current(self):
        """The current arm's payload."""
        return self.arms[self._arm]

    def pulls(self, i: int) -> int:
        return self._n[i]

    def mean_reward(self, i: int) -> float:
        return self._mean[i]

    # ------------------------------------------------------------- updates
    def feed(self, reward: float) -> None:
        """Credit ``reward`` to the current arm, then pick the next arm."""
        r = float(reward)
        if not math.isfinite(r):
            raise ValueError(f"reward must be finite, got {reward!r}")
        i = self._arm
        self._n[i] += 1
        self._mean[i] += (r - self._mean[i]) / self._n[i]
        self._steps += 1
        self._arm = self._select()

    def _best(self) -> int:
        best, best_mean = 0, -math.inf
        for i, m in enumerate(self._mean):
            if m > best_mean:
                best, best_mean = i, m
        return best

    def _select(self) -> int:
        for i, n in enumerate(self._n):
            if n == 0:
                return i
        if self.mode == "ucb":
            log_t = math.log(self._steps)
            best, best_score = 0, -math.inf
            for i in range(len(self.arms)):
                score = self._mean[i] + self.ucb_c * math.sqrt(log_t / self._n[i])
                if score > best_score:
                    best, best_score = i, score
            return best
        # egreedy: counter-based streams -> pure function of (seed, steps)
        u = float(np.random.default_rng((self.seed, self._steps)).random())
        if u < self.epsilon:
            return int(
                np.random.default_rng(
                    (self.seed, self._steps, self._EXPLORE_TAG)
                ).integers(len(self.arms))
            )
        return self._best()

    # ----------------------------------------------------------- snapshots
    def snapshot(self) -> dict:
        """Serializable full state (arm stats + cursor; arms are config)."""
        return {
            "version": 1,
            "n_arms": len(self.arms),
            "arm": self._arm,
            "steps": self._steps,
            "n": list(self._n),
            "mean": list(self._mean),
        }

    def restore(self, snap: Mapping) -> None:
        if snap.get("version") != 1:
            raise ValueError(f"unsupported bandit snapshot: {snap.get('version')!r}")
        if int(snap["n_arms"]) != len(self.arms):
            raise ValueError(
                f"snapshot has {snap['n_arms']} arms, tuner has {len(self.arms)} "
                "— record and replay must share the arm set"
            )
        self._arm = int(snap["arm"])
        self._steps = int(snap["steps"])
        self._n = [int(x) for x in snap["n"]]
        self._mean = [float(x) for x in snap["mean"]]
