"""Deterministic in-process pub/sub bus for live windowed metrics.

The streaming drivers (``ShardedSimulator.run_stream`` and the admission
loop in ``AdmissionSimulator.run``) publish one summary event per shard per
completed metric window onto an :class:`EventPlane`; subscribers — the
autoscaler (``core.autoscale``), dashboards, tests — react synchronously
inside the publishing tick.  The design goal is **replayability**: a run
with subscribers attached must remain a pure function of (seed,
subscriptions), so the bus is deliberately synchronous, ordered, and
sealed:

* **Topics** are tuples: ``("shard", k)`` for shard ``k``'s window summary,
  ``("cluster",)`` for the merged cluster-level summary.  The window index
  and ``(t_lo, t_hi]`` bounds ride on the event itself.
* **Publish order within a window** follows the streaming merge tie-break
  (docs/ARCHITECTURE.md §6): shard topics in ascending shard index, then
  the cluster topic — the same total order the batch merge induces on
  records, so delivery order never depends on wall-clock scheduling.
* **Subscribers register before the run arms** (``seal()``, called by the
  drivers right before ``begin()``); late subscriptions raise instead of
  silently seeing a suffix of the stream.  Within one event, subscribers
  fire in registration order.
* **Payloads are immutable views** (``MappingProxyType``); a subscriber
  cannot mutate what a later subscriber sees.

Together these make the delivery log (``EventPlane.log``) a pure function
of (seed, subscriptions) — pinned by the property sweep in
tests/test_eventplane.py.  The contract is normative in
docs/ARCHITECTURE.md §14.
"""

from __future__ import annotations

import dataclasses
from types import MappingProxyType
from typing import Callable, List, Mapping, Tuple

__all__ = ["MetricEvent", "EventPlane", "SHARD_TOPIC", "CLUSTER_TOPIC"]

#: topic-kind heads (``("shard", k)`` / ``("cluster",)``)
SHARD_TOPIC = "shard"
CLUSTER_TOPIC = "cluster"

#: wildcard element for subscription patterns: matches any value at that slot
WILDCARD = "*"


@dataclasses.dataclass(frozen=True)
class MetricEvent:
    """One published window summary.

    ``seq`` is the global publish sequence number — the total order every
    subscriber observes.  ``payload`` is a read-only mapping of plain
    scalars (JSON types only, by convention), shared by every subscriber.
    """

    topic: Tuple
    window: int  # metric-window index, 0-based
    t_lo: float  # window bounds: records with t_lo < t_done <= t_hi
    t_hi: float
    payload: Mapping
    seq: int


def _matches(pattern: Tuple, topic: Tuple) -> bool:
    if len(pattern) != len(topic):
        return False
    return all(p == WILDCARD or p == t for p, t in zip(pattern, topic))


class EventPlane:
    """Synchronous, ordered, sealed pub/sub bus (see module docstring).

    ``log`` records every delivery as ``(seq, topic, window, sub_id)`` —
    cheap tuples, kept unconditionally so tests can pin that delivery
    order is a pure function of (seed, subscriptions).
    """

    def __init__(self):
        self._subs: List[Tuple[int, Tuple, Callable[[MetricEvent], None]]] = []
        self._sealed = False
        self._seq = 0
        self.published = 0  # events published
        self.delivered = 0  # (event, subscriber) deliveries
        self.log: List[Tuple[int, Tuple, int, int]] = []

    @property
    def sealed(self) -> bool:
        return self._sealed

    def subscribe(
        self, pattern: Tuple, fn: Callable[[MetricEvent], None]
    ) -> int:
        """Register ``fn`` for every topic matching ``pattern``.

        ``pattern`` is a topic tuple where any element may be the wildcard
        ``"*"`` — e.g. ``("shard", "*")`` matches every shard topic,
        ``("cluster",)`` exactly the cluster topic.  Must be called before
        the bus is sealed (the drivers seal right before ``begin()``);
        registration order is delivery order within an event.  Returns the
        subscription id used in the delivery ``log``.
        """
        if self._sealed:
            raise RuntimeError(
                "EventPlane is sealed: subscribers register before the run "
                "arms (begin()); a late subscriber would see only a suffix "
                "of the stream and break replayability"
            )
        if not isinstance(pattern, tuple) or not pattern:
            raise ValueError(f"pattern must be a non-empty tuple, got {pattern!r}")
        sub_id = len(self._subs)
        self._subs.append((sub_id, pattern, fn))
        return sub_id

    def seal(self) -> None:
        """Freeze the subscription set (idempotent).  Publishing also seals
        implicitly, so a forgotten ``seal()`` cannot reopen the window."""
        self._sealed = True

    def publish(
        self, topic: Tuple, window: int, t_lo: float, t_hi: float,
        payload: Mapping,
    ) -> MetricEvent:
        """Publish one window summary and deliver it synchronously.

        Callers are responsible for the §14 publish order (shard topics in
        ascending shard index, then the cluster topic, once per completed
        window); the bus preserves whatever order it is handed — it never
        reorders, buffers, or drops.
        """
        self._sealed = True
        ev = MetricEvent(
            topic=tuple(topic), window=int(window), t_lo=float(t_lo),
            t_hi=float(t_hi), payload=MappingProxyType(dict(payload)),
            seq=self._seq,
        )
        self._seq += 1
        self.published += 1
        for sub_id, pattern, fn in self._subs:
            if _matches(pattern, ev.topic):
                self.log.append((ev.seq, ev.topic, ev.window, sub_id))
                self.delivered += 1
                fn(ev)
        return ev
