"""Vectorized, bit-exact per-request service-time RNG.

The simulator seeds every request's service-time fluctuation by identity:
``np.random.default_rng((seed, vu, ev_idx)).lognormal(mean, sigma)`` — the
paper's fairness device (every scheduler replays identical stochastic
demand).  Constructing a fresh ``Generator`` per request costs ~10µs and was
the single largest item in the simulator profile.

This module computes the *same* doubles vectorized over the whole
``(vu, ev_idx)`` grid at ~0.1–0.3µs per draw by reimplementing, in numpy
array arithmetic, the exact pipeline a fresh ``default_rng(tuple)`` executes
for one lognormal draw:

  1. ``SeedSequence`` entropy pool mixing (uint32 hash mixing, pool size 4);
  2. ``PCG64`` seeding from ``generate_state(4, uint64)`` plus the first
     state advance (128-bit LCG emulated on uint64 hi/lo pairs) and the
     XSL-RR output function;
  3. the first iteration of the ziggurat ``standard_normal`` rejection
     sampler — the branch taken ~98.5% of the time;
  4. ``exp(mean + sigma * z)``.

For step 3 the ziggurat tables (``wi_double``/``ki_double``) are not exposed
by numpy, so ``learn_tables`` recovers them *observationally*: it draws
known-stream samples from real ``Generator`` objects and solves for the only
``wi[idx]`` double consistent with every observed ``(rabs, |z|)`` pair, and
records the largest first-draw-accepted ``rabs`` per idx as a conservative
acceptance bound.  Any draw the fast path cannot *prove* it reproduces
(rejection iterations, tail/wedge branches, unlearned idx, out-of-range
entropy) falls back to a per-element ``default_rng`` call — so the output is
bit-identical by construction, fast path or not.

A one-shot runtime self-test (:func:`selftest`) cross-checks a few hundred
tuples against ``default_rng`` on first use; on any mismatch (e.g. a numpy
upgrade changing the stream) the module degrades to the slow path globally.
"""

from __future__ import annotations

import json
import math
import warnings
from pathlib import Path
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "lognormal_matrix",
    "uniform_block",
    "learn_tables",
    "selftest",
    "selftest_uniform",
    "write_tables",
]

_TABLE_PATH = Path(__file__).with_name("zig_tables.json")

# ---------------------------------------------------------------- constants
# SeedSequence hash constants (numpy/random/bit_generator.pyx).
_XSHIFT = np.uint32(16)
_INIT_A = 0x43B0D7E5
_MULT_A = 0x931E8875
_INIT_B = 0x8B51F9DD
_MULT_B = 0x58F38DED
_MIX_L = np.uint32(0xCA01F9DD)
_MIX_R = np.uint32(0x4973F715)
_M32 = 0xFFFFFFFF

# PCG64 default multiplier (numpy/random/src/pcg64/pcg64.h), as hi/lo words.
_PCG_MULT_HI = np.uint64(2549297995355413924)
_PCG_MULT_LO = np.uint64(4865540595714422341)

_LO32 = np.uint64(0xFFFFFFFF)
_U64_1 = np.uint64(1)
_U64_32 = np.uint64(32)
_U64_63 = np.uint64(63)
_RABS_MASK = np.uint64(0x000FFFFFFFFFFFFF)


# ------------------------------------------------------------ 128-bit limbs
def _mul64_full(a: np.ndarray, b: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Full 64x64 -> 128 multiply on uint64 arrays, as (hi, lo)."""
    a0 = a & _LO32
    a1 = a >> _U64_32
    b0 = b & _LO32
    b1 = b >> _U64_32
    p00 = a0 * b0
    p01 = a0 * b1
    p10 = a1 * b0
    mid = (p00 >> _U64_32) + (p01 & _LO32) + (p10 & _LO32)
    lo = (p00 & _LO32) | ((mid & _LO32) << _U64_32)
    hi = a1 * b1 + (p01 >> _U64_32) + (p10 >> _U64_32) + (mid >> _U64_32)
    return hi, lo


def _pcg_step(sh, sl, inch, incl):
    """state = state * PCG_MULT + inc   (mod 2**128), vectorized."""
    hi, lo = _mul64_full(sl, _PCG_MULT_LO)
    hi = hi + sl * _PCG_MULT_HI + sh * _PCG_MULT_LO
    lo2 = lo + incl
    carry = (lo2 < lo).astype(np.uint64)
    return hi + inch + carry, lo2


def _pcg_output(sh, sl):
    """XSL-RR 128 -> 64 output function."""
    rot = sh >> np.uint64(58)
    xored = sh ^ sl
    return (xored >> rot) | (xored << ((np.uint64(64) - rot) & _U64_63))


# ------------------------------------------------------- SeedSequence stages
def _hashmix(v: np.ndarray, hc: int) -> Tuple[np.ndarray, int]:
    """One hashmix() call; ``hc`` is the evolving scalar hash constant."""
    v = v ^ np.uint32(hc)
    hc = (hc * _MULT_A) & _M32
    v = v * np.uint32(hc)
    v = v ^ (v >> _XSHIFT)
    return v, hc


def _mix(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    r = x * _MIX_L - y * _MIX_R
    return r ^ (r >> _XSHIFT)


def _seedseq_state4(words) -> Tuple[np.ndarray, ...]:
    """``SeedSequence(words).generate_state(4, uint64)`` for 3-word entropy.

    ``words`` are three broadcast-compatible uint32 arrays; returns four
    uint64 arrays (the PCG64 initstate/initseq words).
    """
    with np.errstate(over="ignore"):
        hc = _INIT_A
        pool = []
        for i in range(4):
            src = words[i] if i < len(words) else np.asarray(0, np.uint32)
            v, hc = _hashmix(src, hc)
            pool.append(v)
        for i_src in range(4):
            for i_dst in range(4):
                if i_src != i_dst:
                    h, hc = _hashmix(pool[i_src], hc)
                    pool[i_dst] = _mix(pool[i_dst], h)
        # entropy is never longer than the pool here (3 words < 4): done.
        hc = _INIT_B
        out32 = []
        for i in range(8):
            v = pool[i % 4] ^ np.uint32(hc)
            hc = (hc * _MULT_B) & _M32
            v = v * np.uint32(hc)
            out32.append(v ^ (v >> _XSHIFT))
        return tuple(
            out32[2 * i].astype(np.uint64) | (out32[2 * i + 1].astype(np.uint64) << _U64_32)
            for i in range(4)
        )


def _init_state(seed: int, vu: np.ndarray, ev: np.ndarray):
    """Freshly seeded PCG64 state for ``default_rng((seed, vu, ev))``.

    Returns ``(sh, sl, inch, incl)`` uint64 arrays: the 128-bit state a new
    generator holds *before* its first draw, plus the stream increment.
    """
    # 0-d array, not np.uint32 scalar: scalar uint ops emit overflow warnings
    w = (np.asarray(seed, np.uint32), vu.astype(np.uint32), ev.astype(np.uint32))
    v0, v1, v2, v3 = _seedseq_state4(w)
    # pcg64_set_seed: state=0; step; state+=initstate; step.
    inch = (v2 << _U64_1) | (v3 >> _U64_63)
    incl = (v3 << _U64_1) | _U64_1
    sl = incl + v1  # state=0 -> first step yields state=inc; then +initstate
    carry = (sl < incl).astype(np.uint64)
    sh = inch + v0 + carry
    sh, sl = _pcg_step(sh, sl, inch, incl)
    return sh, sl, inch, incl


def _first_uint64(seed: int, vu: np.ndarray, ev: np.ndarray):
    """The first uint64 a fresh ``default_rng((seed, vu, ev))`` would draw."""
    sh, sl, inch, incl = _init_state(seed, vu, ev)
    sh, sl = _pcg_step(sh, sl, inch, incl)  # advance consumed by the draw
    return _pcg_output(sh, sl)


# --------------------------------------------------- per-VU uniform streams
# next_double() for PCG64: (next_uint64 >> 11) * 2**-53.
_DOUBLE_SCALE = 1.0 / 9007199254740992.0
_U64_11 = np.uint64(11)

_SELFTEST_U_OK: Optional[bool] = None


def _init_state2(seed: int, vu: np.ndarray):
    """Freshly seeded PCG64 state for ``default_rng((seed, vu))``.

    The 2-word-entropy sibling of :func:`_init_state` (same SeedSequence
    pool mixing — entropy shorter than the pool takes the identical
    schedule), used for whole per-VU *streams* rather than one draw.
    """
    w = (np.asarray(seed, np.uint32), vu.astype(np.uint32))
    v0, v1, v2, v3 = _seedseq_state4(w)
    inch = (v2 << _U64_1) | (v3 >> _U64_63)
    incl = (v3 << _U64_1) | _U64_1
    sl = incl + v1
    carry = (sl < incl).astype(np.uint64)
    sh = inch + v0 + carry
    return _pcg_step(sh, sl, inch, incl) + (inch, incl)


def _uniform_block_impl(seed: int, n_vus: int, n_draws: int, vu_start: int = 0) -> np.ndarray:
    vu = np.arange(vu_start, vu_start + n_vus, dtype=np.uint32)
    sh, sl, inch, incl = _init_state2(seed, vu)
    out = np.empty((n_draws, n_vus))
    for _ in range(n_draws):
        sh, sl = _pcg_step(sh, sl, inch, incl)
        out[_] = (_pcg_output(sh, sl) >> _U64_11) * _DOUBLE_SCALE
    return np.ascontiguousarray(out.T)


def _slow_uniform_block(seed: int, n_vus: int, n_draws: int, vu_start: int = 0) -> np.ndarray:
    return np.array(
        [
            np.random.default_rng((seed, v)).random(n_draws)
            for v in range(vu_start, vu_start + n_vus)
        ]
    ).reshape(n_vus, n_draws)


def selftest_uniform(n: int = 64) -> bool:
    """Cross-check :func:`uniform_block` against per-VU ``default_rng`` once.

    Cached; on mismatch every subsequent ``uniform_block`` call takes the
    per-VU slow path (still bit-exact, just not fast)."""
    global _SELFTEST_U_OK
    if _SELFTEST_U_OK is None:
        try:
            got = _uniform_block_impl(192837, 8, n, vu_start=3)
            want = _slow_uniform_block(192837, 8, n, vu_start=3)
            _SELFTEST_U_OK = bool(np.array_equal(got, want))
        except Exception:
            _SELFTEST_U_OK = False
    return _SELFTEST_U_OK


def uniform_block(seed: int, n_vus: int, n_draws: int, vu_start: int = 0) -> np.ndarray:
    """(n_vus, n_draws) matrix whose row ``i`` is bit-identical to
    ``np.random.default_rng((seed, vu_start + i)).random(n_draws)``.

    These raw doubles are the substrate for any per-VU seeded draw sequence
    (``trace.make_vu_programs`` rebuilds its weighted choices and think
    times from them); vectorizing the PCG64 streams removes the per-VU
    ``Generator`` construction that dominates workload generation at
    mega-VU scale."""
    if n_vus <= 0 or n_draws <= 0:
        return np.zeros((max(n_vus, 0), max(n_draws, 0)))
    seed = int(seed)
    if not (0 <= seed < 2**32) or not selftest_uniform():
        if 0 <= seed < 2**32:
            _warn_fallback_once()
        return _slow_uniform_block(seed, n_vus, n_draws, vu_start=vu_start)
    return _uniform_block_impl(seed, n_vus, n_draws, vu_start=vu_start)


# ------------------------------------------------------------------- tables
_TABLES: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None
_SELFTEST_OK: Optional[bool] = None
_FALLBACK_WARNED = False


def _warn_fallback_once() -> None:
    """One warning per process when the self-test disables the fast path.

    The slow path is engaged on *every* subsequent call, so the guard keeps
    a degraded environment (e.g. a numpy upgrade that changed the PCG64 /
    ziggurat stream) from spamming a warning per matrix request while still
    surfacing the ~50x slowdown once.
    """
    global _FALLBACK_WARNED
    if not _FALLBACK_WARNED:
        _FALLBACK_WARNED = True
        warnings.warn(
            "fastrng fast path disabled (runtime self-test mismatch with this "
            "numpy's default_rng stream); falling back to per-tuple "
            "default_rng draws — still bit-exact, but ~50x slower",
            RuntimeWarning,
            stacklevel=3,
        )


def _load_tables() -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(wi, ki_safe, usable) arrays from the checked-in learned tables."""
    global _TABLES
    if _TABLES is None:
        wi = np.full(256, np.nan)
        ki = np.zeros(256, np.uint64)
        usable = np.zeros(256, bool)
        try:
            raw = json.loads(_TABLE_PATH.read_text())
            for k, hexval in raw["wi"].items():
                i = int(k)
                wi[i] = float.fromhex(hexval)
                ki[i] = int(raw["ki"][k])
                usable[i] = True
        except (OSError, KeyError, ValueError):
            pass  # no tables -> fast path never accepts, slow path still exact
        _TABLES = (wi, ki, usable)
    return _TABLES


def _slow_one(seed: int, vu: int, ev: int, mean: float, sigma: float) -> float:
    return float(np.random.default_rng((seed, vu, ev)).lognormal(mean=mean, sigma=sigma))


# Reusable generator for fast-path rejects: resetting PCG64 state to the
# (already vectorized-computed) freshly seeded state skips the ~7µs
# SeedSequence construction and replays the identical stream.
_FB_BG = np.random.PCG64()
_FB_GEN = np.random.Generator(_FB_BG)


def _slow_from_state(state: int, inc: int, mean: float, sigma: float) -> float:
    _FB_BG.state = {
        "bit_generator": "PCG64",
        "state": {"state": state, "inc": inc},
        "has_uint32": 0,
        "uinteger": 0,
    }
    return float(_FB_GEN.lognormal(mean=mean, sigma=sigma))


def selftest(n: int = 384) -> bool:
    """Cross-check the fast path against per-tuple ``default_rng`` once.

    Cached; on mismatch the module permanently falls back to the slow path
    (still bit-exact, just not fast).
    """
    global _SELFTEST_OK
    if _SELFTEST_OK is None:
        try:
            seed, vus, evs = 987654, 6, max(1, n // 6)
            got = _lognormal_matrix_impl(seed, vus, evs, -0.03125, 0.25, check=False)
            want = np.array(
                [[_slow_one(seed, v, e, -0.03125, 0.25) for e in range(evs)] for v in range(vus)]
            )
            _SELFTEST_OK = bool(np.array_equal(got, want))
        except Exception:
            _SELFTEST_OK = False
    return _SELFTEST_OK


def _lognormal_matrix_impl(
    seed: int,
    n_vus: int,
    n_events: int,
    mean: float,
    sigma: float,
    check: bool = True,
    ev_start: int = 0,
    vu_start: int = 0,
) -> np.ndarray:
    if check and not selftest():
        _warn_fallback_once()
        return np.array(
            [
                [_slow_one(seed, v, e, mean, sigma) for e in range(ev_start, ev_start + n_events)]
                for v in range(vu_start, vu_start + n_vus)
            ]
        )
    wi, ki_safe, usable = _load_tables()
    vu = np.repeat(np.arange(vu_start, vu_start + n_vus, dtype=np.uint32), n_events)
    ev = np.tile(np.arange(ev_start, ev_start + n_events, dtype=np.uint32), n_vus)
    sh0, sl0, inch, incl = _init_state(seed, vu, ev)
    sh, sl = _pcg_step(sh0, sl0, inch, incl)  # advance consumed by the draw
    r = _pcg_output(sh, sl)
    idx = (r & np.uint64(0xFF)).astype(np.intp)
    rr = r >> np.uint64(8)
    sign = (rr & _U64_1).astype(bool)
    rabs = (rr >> _U64_1) & _RABS_MASK
    # Fast-accept only when provably inside the learned acceptance region.
    ok = usable[idx] & (rabs <= ki_safe[idx])
    z = rabs.astype(np.float64) * wi[idx]
    z = np.where(sign, -z, z)
    # scalar libm exp, NOT np.exp: numpy's SIMD exp differs from the C
    # ``exp()`` inside random_lognormal by 1 ulp on some inputs
    arg = mean + sigma * z
    out = np.fromiter(map(math.exp, arg.tolist()), np.float64, count=arg.size)
    if not ok.all():
        for flat in np.flatnonzero(~ok):
            state = (int(sh0[flat]) << 64) | int(sl0[flat])
            inc = (int(inch[flat]) << 64) | int(incl[flat])
            out[flat] = _slow_from_state(state, inc, mean, sigma)
    return out.reshape(n_vus, n_events)


def lognormal_matrix(
    seed: int,
    n_vus: int,
    n_events: int,
    mean: float,
    sigma: float,
    ev_start: int = 0,
    vu_start: int = 0,
) -> np.ndarray:
    """(n_vus, n_events) matrix whose entry [i, j] is bit-identical to
    ``np.random.default_rng((seed, vu_start + i, ev_start + j)).lognormal(mean, sigma)``.

    ``ev_start`` extends a band rightward (more events per VU); ``vu_start``
    generates rows for a VU range, which is how dynamically admitted VUs get
    their fluctuation row without recomputing the whole band."""
    if n_vus <= 0 or n_events <= 0:
        return np.zeros((max(n_vus, 0), max(n_events, 0)))
    seed = int(seed)
    if not (0 <= seed < 2**32):  # multi-word entropy: different mix schedule
        return np.array(
            [
                [_slow_one(seed, v, e, mean, sigma) for e in range(ev_start, ev_start + n_events)]
                for v in range(vu_start, vu_start + n_vus)
            ]
        )
    return _lognormal_matrix_impl(
        seed, n_vus, n_events, mean, sigma, ev_start=ev_start, vu_start=vu_start
    )


# ----------------------------------------------------------- table learning
def learn_tables(n_draws: int = 200_000, min_samples: int = 3):
    """Recover ``wi``/acceptance-bound tables by observing real Generators.

    For entropy tuples ``(0, 0, e)`` we compute the first raw uint64 via the
    vectorized pipeline, draw ``standard_normal()`` from an identically
    seeded ``Generator``, and keep samples whose post-draw PCG64 state shows
    exactly one advance (first-draw ziggurat accept).  ``wi[idx]`` is then
    the unique double with ``rabs * wi == |z|`` across every sample of that
    idx; the acceptance bound is the largest accepted ``rabs`` observed.
    """
    ev = np.arange(n_draws, dtype=np.uint32)
    vu = np.zeros(n_draws, np.uint32)
    sh0, sl0, inch, incl = _init_state(0, vu, ev)
    sh, sl = _pcg_step(sh0, sl0, inch, incl)  # state after one consumed draw
    r = _pcg_output(sh, sl)
    idx_a = (r & np.uint64(0xFF)).astype(np.intp)
    rabs_a = ((r >> np.uint64(9)) & _RABS_MASK).astype(np.uint64)
    samples: dict = {}
    for e in range(n_draws):
        g = np.random.default_rng((0, 0, e))
        z = g.standard_normal()
        st = g.bit_generator.state["state"]["state"]
        if st == (int(sh[e]) << 64) | int(sl[e]):
            samples.setdefault(int(idx_a[e]), []).append((int(rabs_a[e]), abs(z)))
    wi_out, ki_out = {}, {}
    for idx, ss in samples.items():
        if len(ss) < min_samples:
            continue
        rab0, z0 = max(ss)
        if rab0 == 0:
            continue
        cands = {np.float64(z0) / np.float64(rab0)}
        for _ in range(3):
            cands.add(np.nextafter(max(cands), np.inf))
            cands.add(np.nextafter(min(cands), -np.inf))
        good = [c for c in cands if all(np.float64(ra) * c == zv for ra, zv in ss)]
        if len(good) != 1:
            continue
        wi_out[str(idx)] = float(good[0]).hex()
        ki_out[str(idx)] = max(ra for ra, _ in ss)
    return {"wi": wi_out, "ki": ki_out, "n_draws": n_draws, "numpy": np.__version__}


def write_tables(n_draws: int = 200_000, path: Optional[Path] = None) -> Path:
    path = path or _TABLE_PATH
    path.write_text(json.dumps(learn_tables(n_draws), indent=0))
    return path


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description="(re)generate the learned ziggurat tables")
    ap.add_argument("--n-draws", type=int, default=200_000)
    args = ap.parse_args()
    p = write_tables(args.n_draws)
    print(f"wrote {p}")
    _TABLES = None
    _SELFTEST_OK = None
    print("selftest:", selftest())
