"""Hiku: pull-based scheduling (Algorithm 1 of the paper).

Key idea: decouple worker selection from task assignment.  After a worker
finishes executing a function of type ``f`` it *proactively enqueues itself*
in the idle priority queue ``PQ_f`` (the pull mechanism).  An incoming request
for ``f`` dequeues the least-loaded enqueued worker — a guaranteed-warm
assignment.  If ``PQ_f`` is empty the fallback mechanism (least connections,
random tie-break) assigns the request.

``PQ_f`` is *sorted by the number of active connections* (Algorithm 1, note at
l.21).  A worker appears once per idle instance it has enqueued (it may appear
in several queues, and several times in one queue); ``on_evict`` removes one
occurrence (Algorithm 1 l.17-20).

Representation (PR 1 hot-path refactor; decisions are bit-identical to the
seed list-scan implementation, proven by tests/test_equivalence.py):

* ``idle_counts[f]`` is the queue *multiset* as ``{worker: count}`` — the
  seed engine's list with duplicates, collapsed.  Dequeue-min needs only
  multiset membership because the priority ``(conns[w], w)`` is a total
  order over distinct workers.
* ``_heaps[f]`` is a lazy-deletion binary heap of ``(conns-at-push, worker)``
  entries over that multiset, making dequeue O(log n) instead of an O(queue)
  scan per request.  Since connection counts drift after entries are pushed,
  every pop re-validates the entry against the live ``conns``: dead entries
  (evicted or failed workers) are dropped, stale priorities are refreshed in
  place.  On every conns *decrease* (``on_finish``/``on_cancel``) an accurate
  entry is pushed for each queue holding the worker, so a queue member can
  never be hidden behind a stale-high priority — which is exactly the
  invariant that makes the popped minimum equal the seed engine's fresh scan
  ``min((conns[w], w) for w in PQ_f)``.
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Set, Tuple

from .scheduler import Scheduler, register


@register("hiku")
class HikuScheduler(Scheduler):
    """Pull-based scheduler (the paper's contribution; see module docstring).

    Args:
        n_workers: initial worker count (ids 0..n-1; elastic add/remove via
            the worker callbacks).
        seed: tie-break RNG seed for the fallback path — part of the replay
            identity the equivalence suite pins.
        fallback: assignment when ``PQ_f`` is empty — ``"least_connections"``
            (Algorithm 1) or ``"random"``.

    Bound by the decision-equivalence contract: every ``select`` returns the
    worker the seed engine's list-scan implementation would have picked
    (tests/test_equivalence.py)."""

    def __init__(self, n_workers: int, seed: int = 0, fallback: str = "least_connections"):
        super().__init__(n_workers, seed)
        # PQ_f multiset + lazy-deletion heap (see module docstring).
        self.idle_counts: Dict[str, Dict[int, int]] = {}
        self._heaps: Dict[str, List[Tuple[int, int]]] = {}
        self._totals: Dict[str, int] = {}
        self._worker_funcs: Dict[int, Set[str]] = {}  # funcs holding the worker
        self.fallback = fallback
        # telemetry
        self.pull_hits = 0
        self.fallback_assigns = 0

    # ------------------------------------------------------------ schedule
    def select(self, func: str) -> int:
        if self._totals.get(func):
            # Pull mechanism: dequeue least-loaded enqueued worker.
            self.pull_hits += 1
            return self._dequeue_min(func)
        # Fallback mechanism (least connections, random tie-break).
        self.fallback_assigns += 1
        if self.fallback == "random":
            return self.rng.choice(self.workers)
        return self._least_connections()

    def _dequeue_min(self, func: str) -> int:
        # priority = (active connections, worker id): deterministic tie-break
        # by lowest id keeps this object semantically identical to the array
        # formulation in jax_sched.py (tie order is unspecified in the paper).
        heap = self._heaps[func]
        counts = self.idle_counts[func]
        conns = self.conns
        if len(heap) > 64 and len(heap) > 8 * len(counts):
            # too many stale/duplicate entries: rebuild from the live
            # multiset (exact priorities, one entry per enqueued instance
            # so multi-enqueued workers keep their multiplicity)
            heap = [(conns[w], w) for w, n in counts.items() for _ in range(n)]
            heapq.heapify(heap)
            self._heaps[func] = heap
        while True:
            c, w = heap[0]
            cw = conns.get(w)
            if cw is None or w not in counts:
                heapq.heappop(heap)  # worker left the queue/cluster: discard
            elif c != cw:
                heapq.heapreplace(heap, (cw, w))  # stale priority: refresh
            else:
                heapq.heappop(heap)
                n = counts[w] - 1
                if n:
                    counts[w] = n
                else:
                    del counts[w]
                    self._worker_funcs[w].discard(func)
                self._totals[func] -= 1
                return w

    # ------------------------------------------------------------ callbacks
    def on_finish(self, worker: int, func: str) -> None:
        # Scheduler._release inlined (hottest callback in the simulator)
        conns = self.conns
        old = conns.get(worker, 0)
        cw = old - 1 if old > 0 else 0
        conns[worker] = cw
        self.total_conns += cw - old
        if worker < len(self._conns_arr):
            self._conns_arr[worker] = cw
        self._lc_move(worker, cw)
        # decrease-key: re-post an accurate entry in every queue holding the
        # worker, so the lowered priority is visible to future dequeues
        # (func itself is covered by the unconditional enqueue push below)
        heaps = self._heaps
        push = heapq.heappush
        wf = self._worker_funcs.get(worker)
        entry = (cw, worker)
        if wf:
            for f in wf:
                if f != func:
                    push(heaps[f], entry)
            wf.add(func)
        else:
            self._worker_funcs[worker] = {func}
        # Pull: worker signals readiness for another request of this type.
        counts = self.idle_counts.get(func)
        if counts is None:
            counts = self.idle_counts[func] = {}
            heaps[func] = []
            self._totals[func] = 0
        counts[worker] = counts.get(worker, 0) + 1
        self._totals[func] += 1
        push(heaps[func], entry)

    def on_cancel(self, worker: int, func: str) -> None:
        super().on_cancel(worker, func)
        cw = self.conns.get(worker)
        if cw is not None:
            for f in self._worker_funcs.get(worker, ()):
                heapq.heappush(self._heaps[f], (cw, worker))

    def on_evict(self, worker: int, func: str) -> None:
        # Notification mechanism: drop one occurrence of worker from PQ_f.
        counts = self.idle_counts.get(func)
        if counts and worker in counts:
            n = counts[worker] - 1
            if n:
                counts[worker] = n
            else:
                del counts[worker]
                self._worker_funcs[worker].discard(func)
            self._totals[func] -= 1
            # the heap entry is lazily discarded at dequeue time

    def on_worker_removed(self, worker: int) -> None:
        super().on_worker_removed(worker)
        # Failure/scale-down: purge every queue entry of the worker.
        for f in self._worker_funcs.pop(worker, ()):
            counts = self.idle_counts.get(f)
            if counts is not None:
                self._totals[f] -= counts.pop(worker, 0)

    # ------------------------------------------------------------ telemetry
    def queue_depth(self, func: Optional[str] = None) -> int:
        if func is not None:
            return self._totals.get(func, 0)
        return sum(self._totals.values())
