"""Pull-based scheduling as a composable JAX module (vectorized Algorithm 1).

The control-plane scheduler in ``hiku.py`` is an event-driven Python object.
For high-throughput request streams (and to make the paper's algorithm a
first-class JAX citizen) this module expresses the *same* semantics as a pure
state-transition over arrays, scannable with ``jax.lax`` and shardable over
the worker axis:

* ``idle[f, w]``  — multiset size of worker ``w``'s entries in ``PQ_f``
  (one per enqueued idle instance).  Since ``PQ_f`` is priority-ordered by
  load, dequeuing the min-load member is ``argmin_w(conns | idle[f,w]>0)`` —
  the array form of a sorted queue; no order information is lost.
* ``conns[w]``    — active connections (the priority key of Algorithm 1).

Events are encoded as ``(kind, func, worker)`` int32 triples:
  kind 0 = ARRIVAL(func)        -> returns (worker, warm) assignment
  kind 1 = FINISH(func, worker) -> pull enqueue (Algorithm 1 l.13-16)
  kind 2 = EVICT(func, worker)  -> notification   (Algorithm 1 l.17-20)

Random tie-breaking uses the Gumbel-max trick over exact ties, matching the
"random selection from W_min" of the fallback mechanism.

``kernels/sched_step.py`` implements the ARRIVAL hot path as a fused Pallas
kernel; ``kernels/ref.py`` points back at this module as the oracle.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

ARRIVAL, FINISH, EVICT = 0, 1, 2
_INF = jnp.int32(2**30)


class JIQState(NamedTuple):
    """Scheduler state in array form: the whole of Algorithm 1's bookkeeping.

    ``idle[f, w]`` is the multiset count of worker ``w``'s entries in
    ``PQ_f`` (one per enqueued idle instance); ``conns[w]`` is the active
    connection count — the priority key.  Semantically equivalent to
    ``HikuScheduler``'s object state (see module docstring)."""

    idle: jax.Array   # (F, W) int32 — PQ_f membership multiset
    conns: jax.Array  # (W,)  int32 — active connections


def init_state(n_funcs: int, n_workers: int) -> JIQState:
    """Empty :class:`JIQState` for ``n_funcs`` functions x ``n_workers``
    workers (no idle instances enqueued, zero connections)."""
    return JIQState(
        idle=jnp.zeros((n_funcs, n_workers), jnp.int32),
        conns=jnp.zeros((n_workers,), jnp.int32),
    )


def _tie_break_argmin(scores: jax.Array, key: jax.Array | None) -> jax.Array:
    """argmin with uniform random choice among exact ties (Gumbel-max)."""
    if key is None:  # deterministic mode: first index wins
        return jnp.argmin(scores)
    m = scores.min()
    tied = scores == m
    g = jax.random.gumbel(key, scores.shape)
    return jnp.argmax(jnp.where(tied, g, -jnp.inf))


def sched_step(
    state: JIQState, event: jax.Array, key: jax.Array | None = None
) -> Tuple[JIQState, Tuple[jax.Array, jax.Array]]:
    """One event transition.  Returns (state', (worker, warm)).

    For FINISH/EVICT events the returned assignment is (-1, False).
    """
    kind, func, worker = event[0], event[1], event[2]
    idle_f = state.idle[func]

    # ---- ARRIVAL: pull mechanism, else least-connections fallback ----------
    has_idle = jnp.any(idle_f > 0)
    pull_scores = jnp.where(idle_f > 0, state.conns, _INF)
    if key is not None:
        k_pull, k_fb = jax.random.split(key)
    else:
        k_pull = k_fb = None
    w_pull = _tie_break_argmin(pull_scores, k_pull)
    w_fallback = _tie_break_argmin(state.conns, k_fb)
    w_assign = jnp.where(has_idle, w_pull, w_fallback).astype(jnp.int32)

    is_arrival = kind == ARRIVAL
    is_finish = kind == FINISH
    is_evict = kind == EVICT

    # idle-queue updates
    idle = state.idle
    #   ARRIVAL dequeues (only if pulled); FINISH enqueues; EVICT removes one.
    dec_arrival = (is_arrival & has_idle).astype(jnp.int32)
    idle = idle.at[func, w_assign].add(-dec_arrival)
    idle = idle.at[func, worker].add(is_finish.astype(jnp.int32))
    idle = idle.at[func, worker].add(-(is_evict & (idle[func, worker] > 0)).astype(jnp.int32))
    idle = jnp.maximum(idle, 0)

    # connection counts
    conns = state.conns
    conns = conns.at[w_assign].add(is_arrival.astype(jnp.int32))
    conns = conns.at[worker].add(-is_finish.astype(jnp.int32))
    conns = jnp.maximum(conns, 0)

    out_worker = jnp.where(is_arrival, w_assign, jnp.int32(-1))
    out_warm = is_arrival & has_idle
    return JIQState(idle, conns), (out_worker, out_warm)


def sched_many(
    state: JIQState, events: jax.Array, key: jax.Array | None = None
) -> Tuple[JIQState, Tuple[jax.Array, jax.Array]]:
    """Scan ``sched_step`` over an (N, 3) int32 event stream."""
    n = events.shape[0]
    keys = jax.random.split(key, n) if key is not None else None

    def body(carry, xs):
        if keys is None:
            ev = xs
            return sched_step(carry, ev, None)
        ev, k = xs
        return sched_step(carry, ev, k)

    xs = events if keys is None else (events, keys)
    return jax.lax.scan(body, state, xs)


def sched_many_fused(
    state: JIQState,
    events: jax.Array,
    key: jax.Array | None = None,
    chunk: int = 1024,
    interpret: bool | None = None,
) -> Tuple[JIQState, Tuple[jax.Array, jax.Array]]:
    """``sched_many`` with the whole stream fused into chunked Pallas dispatches.

    Each ``chunk`` of mixed (ARRIVAL|FINISH|EVICT) events costs *one* kernel
    dispatch (kernels/sched_step.sched_events) instead of one scan iteration
    per event; state is carried between chunks.  Bit-exact against
    ``sched_many(state, events, key=None)``.

    Fallback rules: with a PRNG ``key`` (randomized tie-breaks live in the
    scan path) or off-TPU the scan path is used, keeping this a drop-in call
    on any backend; ``interpret=True`` forces the fused kernel in interpreter
    mode (CPU tests).
    """
    if key is not None:
        return sched_many(state, events, key)
    if not interpret and jax.default_backend() != "tpu":
        # off-TPU the native kernel can't lower; only interpret=True forces it
        return sched_many(state, events, None)
    from ..kernels import ops  # deferred: kernels are optional off the hot path

    idle, conns = state.idle, state.conns
    n = events.shape[0]
    ws, warms = [], []
    for lo in range(0, n, chunk):
        ev = events[lo : lo + chunk]
        tail = chunk - ev.shape[0]
        if tail:
            # pad the ragged last chunk with kind=3 no-op events (func/worker
            # 0 keep the row loads in bounds; an unknown kind updates nothing)
            # so every dispatch shares one compiled (chunk,) shape
            pad = jnp.zeros((tail, 3), jnp.int32).at[:, 0].set(3)
            ev = jnp.concatenate([ev, pad])
        a, warm, idle, conns = ops.sched_events(
            ev[:, 0], ev[:, 1], ev[:, 2], idle, conns, interpret=interpret
        )
        if tail:
            a, warm = a[:-tail], warm[:-tail]
        ws.append(a)
        warms.append(warm)
    ws_all = jnp.concatenate(ws) if ws else jnp.zeros((0,), jnp.int32)
    warm_all = (
        jnp.concatenate(warms).astype(bool) if warms else jnp.zeros((0,), bool)
    )
    return JIQState(idle, conns), (ws_all, warm_all)


def sched_many_adaptive(
    state: JIQState,
    events: jax.Array,
    detector,
    densities=None,
    segment: int = 1024,
    key: jax.Array | None = None,
    interpret: bool | None = None,
) -> Tuple[JIQState, Tuple[jax.Array, jax.Array]]:
    """Burst-adaptive fused dispatch: ``sched_many`` with per-window chunk
    sizes chosen by a :class:`~repro.core.simulator.BurstDetector`.

    Walks the stream in ``segment``-event windows.  Before each window, one
    density sample is folded into ``detector`` (``densities[i]`` when given
    — e.g. ``Simulator.heap_density`` readings taken ahead of the clock —
    else the window's own event count, a pure stream-rate proxy) and the
    detector's answer picks the dispatch path: ``chunk == 1`` steps the
    window through the ``lax.scan`` path (sparse streams never pay
    kernel-launch padding for mostly-empty chunks), anything larger fuses
    the window through :func:`sched_many_fused` with that chunk.

    The detector is a pure observer — event order is untouched — so the
    result is **bitwise equal** to ``sched_many(state, events)`` for every
    detector state and density sequence (pinned in tests/test_scheduler.py).
    With a PRNG ``key`` (randomized tie-breaks live in the scan path) the
    whole stream takes the scan path unchanged.
    """
    if key is not None:
        return sched_many(state, events, key)
    if segment < 1:
        raise ValueError(f"segment must be >= 1, got {segment}")
    n = events.shape[0]
    n_windows = -(-n // segment)
    if densities is not None and len(densities) < n_windows:
        raise ValueError(
            f"densities has {len(densities)} samples for {n_windows} windows"
        )
    ws, warms = [], []
    for i in range(n_windows):
        ev = events[i * segment : (i + 1) * segment]
        sample = float(densities[i]) if densities is not None else float(ev.shape[0])
        chunk = detector.observe(sample)
        if chunk <= 1:
            state, (a, warm) = sched_many(state, ev, None)
        else:
            state, (a, warm) = sched_many_fused(
                state, ev, chunk=chunk, interpret=interpret
            )
        ws.append(a)
        warms.append(warm)
    ws_all = jnp.concatenate(ws) if ws else jnp.zeros((0,), jnp.int32)
    warm_all = (
        jnp.concatenate(warms).astype(bool) if warms else jnp.zeros((0,), bool)
    )
    return state, (ws_all, warm_all)


# ---------------------------------------------------------------- invariants
def check_invariants(state: JIQState) -> bool:
    """Structural invariants used by property tests."""
    ok = bool(jnp.all(state.idle >= 0)) and bool(jnp.all(state.conns >= 0))
    return ok
