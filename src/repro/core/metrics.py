"""Metrics of Section V: latency, cold-start rate, load imbalance (CV), throughput.

All metrics operate natively on the columnar record store (PR 2): a single
vectorized pass over ``RecordColumns`` / assignment arrays.  The legacy
row-API inputs (list of ``RequestRecord``, list of ``(t, worker)`` tuples)
are accepted through thin adapters that convert to columns first — the
numeric results are float-for-float identical either way, because the
vectorized expressions are the elementwise IEEE operations the old Python
loops performed (tests/test_records.py pins the parity at tolerance 0).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .records import RecordColumns, RequestRecord

RecordsLike = Union[RecordColumns, Sequence[RequestRecord]]
#: assignments as the legacy ``[(t, worker), ...]`` or ``(t[], worker[])`` arrays
AssignmentsLike = Union[Sequence[Tuple[float, int]], Tuple[np.ndarray, np.ndarray]]


@dataclasses.dataclass
class RunMetrics:
    """The §V scalar metrics of one run (or one stream window).

    Latencies in milliseconds; ``cold_rate`` is the cold-start fraction in
    [0, 1]; ``throughput_rps`` is requests per second over the summarized
    duration; ``load_cv`` is the mean per-second coefficient of variation
    of assignments across workers (Figure 14); ``migrated_rate`` is the
    fraction of requests completed on a shard other than their binding one
    (cross-shard work stealing; 0.0 whenever stealing is off);
    ``deadline_miss_rate`` is the fraction of deadline-carrying VUs whose
    *first completion* landed after ``arrival + deadline`` — time to first
    response, the flash-crowd SLO: it charges admission-queue wait as well
    as in-cluster latency, and a VU that never completed at all counts as
    missed (0.0 when the workload carries no deadline metadata — see
    ``summarize(deadline_ms=...)``).

    Failure telemetry (ARCHITECTURE.md §10; all 0.0 on fault-free runs):
    ``resubmit_rate`` is failure-retry pushes per completed request (can
    exceed 1 under heavy churn — one request may retry several times);
    ``lost_task_rate`` is the fraction of *resolved* requests that were
    dropped after exhausting the retry budget, ``lost / (completed +
    lost)``; ``recovery_p50_ms``/``recovery_p99_ms`` are percentiles of
    first-failure-to-completion latency over requests that survived at
    least one failure (0.0 when none did).

    Dataclass equality is exact float equality — the windowed-metrics
    parity tests rely on that."""

    n_requests: int
    mean_latency_ms: float
    p50_ms: float
    p90_ms: float
    p95_ms: float
    p99_ms: float
    cold_rate: float
    throughput_rps: float
    load_cv: float  # avg coefficient of variation of assignments/worker/second
    migrated_rate: float = 0.0
    deadline_miss_rate: float = 0.0
    resubmit_rate: float = 0.0
    lost_task_rate: float = 0.0
    recovery_p50_ms: float = 0.0
    recovery_p99_ms: float = 0.0

    def row(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _assignment_arrays(assignments: AssignmentsLike) -> Tuple[np.ndarray, np.ndarray]:
    """Adapter: legacy ``[(t, worker), ...]`` rows or a 2-tuple of ``(t,
    worker)`` columns (ndarrays or plain lists) -> float64/int64 arrays.

    A 2-tuple whose elements are arrays/lists is the columnar form; row
    streams are tuples-inside-a-sequence, so the shapes don't collide.
    """
    if (
        isinstance(assignments, tuple)
        and len(assignments) == 2
        and all(isinstance(c, (np.ndarray, list)) for c in assignments)
    ):
        t = np.asarray(assignments[0], np.float64)
        w = np.asarray(assignments[1], np.int64)
        if t.shape != w.shape:
            raise ValueError(f"assignment column lengths differ: {t.shape} vs {w.shape}")
        return t, w
    if not len(assignments):
        return np.zeros(0), np.zeros(0, np.int64)
    t, w = zip(*assignments)
    return np.asarray(t, np.float64), np.asarray(w, np.int64)


def latency_cdf(records: RecordsLike, n_points: int = 200) -> Tuple[np.ndarray, np.ndarray]:
    """Empirical latency CDF ``(latency_ms, fraction <= latency)`` over a
    record stream (Figures 10-12), downsampled to at most ``n_points``
    evenly spaced quantiles."""
    cols = RecordColumns.from_records(records)
    lat = np.sort(cols.latency_ms)
    y = np.arange(1, len(lat) + 1) / len(lat)
    if len(lat) > n_points:
        idx = np.linspace(0, len(lat) - 1, n_points).astype(int)
        return lat[idx], y[idx]
    return lat, y


def load_cv_per_second(
    assignments: AssignmentsLike, workers: Sequence[int], t_end: float
) -> np.ndarray:
    """Per-1s-bin CV across workers of assignment counts (Figure 14).

    The paper defines load imbalance as the coefficient of variation of the
    number of requests assigned per worker per second.  Vectorized: one
    ``bincount`` over ``bin * n_workers + worker_index`` — the integer count
    matrix is identical to the old per-assignment Python loop, so the CV
    series is bit-identical.
    """
    at, aw = _assignment_arrays(assignments)
    if at.size == 0 or not len(workers):
        return np.zeros(0)
    n_bins = int(np.ceil(t_end)) + 1
    n_w = len(workers)
    # dense worker-id -> column lookup (ids are small nonnegative ints)
    max_id = int(max(int(aw.max(initial=0)), max(workers)))
    lut = np.full(max_id + 1, -1, np.int64)
    for i, w in enumerate(workers):
        if 0 <= w <= max_id:
            lut[w] = i
    widx = lut[aw]
    known = widx >= 0
    bins = np.minimum(at.astype(np.int64), n_bins - 1)
    flat = bins[known] * n_w + widx[known]
    counts = np.bincount(flat, minlength=n_bins * n_w).reshape(n_bins, n_w)
    counts = counts.astype(np.float64)
    active = counts.sum(axis=1) > 0
    counts = counts[active]
    mean = counts.mean(axis=1)
    std = counts.std(axis=1)
    return np.where(mean > 0, std / np.maximum(mean, 1e-12), 0.0)


def summarize(
    records: RecordsLike,
    assignments: AssignmentsLike,
    workers: Sequence[int],
    duration_s: float,
    deadline_ms: Optional[np.ndarray] = None,
    arrival_s: Optional[np.ndarray] = None,
    resubmits: int = 0,
    lost_tasks: int = 0,
    recovery_s: Optional[Sequence[float]] = None,
) -> RunMetrics:
    """Aggregate §V metrics over a full record stream, in one vectorized pass.

    Args:
        records: completed-request stream (columnar or legacy row list).
        assignments: ``(t, worker)`` dispatch trace, columnar or row form;
            times in seconds.
        workers: global worker ids participating in the run (the CV
            denominator — include idle workers).
        duration_s: experiment length, seconds (throughput denominator).
        deadline_ms: optional per-VU relative latency deadline (ms), one
            entry per VU of the *population* (``inf`` = no deadline on
            that VU).  When given, ``deadline_miss_rate`` is the fraction
            of deadline-carrying VUs whose first completion exceeded
            ``arrival + deadline`` — time to first response, charging any
            admission-queue wait; a VU with no completions at all counts
            as missed.  Omitted: 0.0.
        arrival_s: per-VU arrival times (seconds), parallel to
            ``deadline_ms``; default: everyone at t=0 (the plain-engine
            convention where VU streams start with the run).
        resubmits: failure-retry pushes performed during the run
            (``Simulator.resubmits``, summed across shards) — feeds
            ``resubmit_rate``.
        lost_tasks: requests dropped after exhausting the retry budget
            (``Simulator.lost_tasks`` + never-re-homed salvage) — feeds
            ``lost_task_rate``.
        recovery_s: first-failure-to-completion latencies, seconds
            (``Simulator.recovery_s``) — feeds the recovery percentiles.

    Adapter-equivalence contract: row and columnar inputs produce
    float-for-float identical results (tests/test_records.py, tolerance 0).
    """
    cols = RecordColumns.from_records(records)
    n = len(cols)
    lat = cols.latency_ms if n else np.zeros(1)
    cold = cols.cold if n else np.zeros(1)
    migrated = cols.migrated if n else np.zeros(1)
    cv = load_cv_per_second(assignments, workers, duration_s)
    miss_rate = 0.0
    if deadline_ms is not None:
        dl = np.asarray(deadline_ms, np.float64)
        n_pop = dl.shape[0]
        arr_ms = (
            np.zeros(n_pop)
            if arrival_s is None
            else np.asarray(arrival_s, np.float64) * 1e3
        )
        first_done = np.full(n_pop, np.inf)
        if n:
            np.minimum.at(first_done, cols.vu, cols.t_done * 1e3)
        has_dl = np.isfinite(dl)
        if has_dl.any():
            miss = first_done[has_dl] - arr_ms[has_dl] > dl[has_dl]
            miss_rate = float(miss.mean())
    rec = (
        np.asarray(recovery_s, np.float64) * 1e3
        if recovery_s is not None and len(recovery_s)
        else np.zeros(0)
    )
    return RunMetrics(
        n_requests=n,
        mean_latency_ms=float(lat.mean()),
        p50_ms=float(np.percentile(lat, 50)),
        p90_ms=float(np.percentile(lat, 90)),
        p95_ms=float(np.percentile(lat, 95)),
        p99_ms=float(np.percentile(lat, 99)),
        cold_rate=float(cold.mean()),
        throughput_rps=n / max(duration_s, 1e-9),
        load_cv=float(cv.mean()) if cv.size else 0.0,
        migrated_rate=float(migrated.mean()),
        deadline_miss_rate=miss_rate,
        resubmit_rate=resubmits / max(n, 1),
        lost_task_rate=lost_tasks / (n + lost_tasks) if (n + lost_tasks) else 0.0,
        recovery_p50_ms=float(np.percentile(rec, 50)) if rec.size else 0.0,
        recovery_p99_ms=float(np.percentile(rec, 99)) if rec.size else 0.0,
    )


# ------------------------------------------------------------------ windowed
def summarize_window(
    records: RecordsLike,
    assignments: AssignmentsLike,
    workers: Sequence[int],
    t_lo: float,
    t_hi: float,
) -> RunMetrics:
    """Metrics for ONE completed stream window (``t_lo < t_done <= t_hi``).

    Takes exactly a :class:`~repro.core.shard.StreamChunk`'s payload — the
    window's records and its assignment slice — and evaluates the same
    vectorized expressions :func:`summarize` applies to a full run, with
    assignment times rebased to the window start so the per-second load-CV
    bins are window-relative.  Both the streaming consumer and the batch
    :func:`summarize_windows` go through this one function, which is what
    makes their floats identical (tests/test_stream.py pins the parity).
    """
    cols = RecordColumns.from_records(records)
    at, aw = _assignment_arrays(assignments)
    return summarize(cols, (at - t_lo, aw), workers, t_hi - t_lo)


def summarize_windows(
    records: RecordsLike,
    assignments: AssignmentsLike,
    workers: Sequence[int],
    window_s: float,
    duration_s: float,
    t_start: float = 0.0,
) -> List[Tuple[float, RunMetrics]]:
    """Windowed :func:`summarize` over a completion-ordered stream.

    Buckets records by ``t_done`` and assignments by assignment time into
    consecutive ``(t_lo, t_hi]`` windows of width ``window_s`` starting at
    ``t_start`` (the first window also includes events at exactly
    ``t_start``), continuing past ``duration_s`` until every record and
    assignment is covered (completions can trail the deadline by the
    scheduler overhead).  Returns ``[(t_hi, RunMetrics), ...]`` — the same
    windows, in the same order, with the same float values a streaming
    consumer gets from ``run_stream`` + :func:`summarize_window`.

    Requires the stream to be sorted by ``t_done`` (engine and merged-run
    order; see ``RecordColumns.window``).
    """
    if window_s <= 0:
        raise ValueError("window_s must be > 0")
    cols = RecordColumns.from_records(records)
    at, aw = _assignment_arrays(assignments)
    out: List[Tuple[float, RunMetrics]] = []
    i = 0
    n_rec = len(cols)
    n_asg = at.shape[0]
    ri = ai = 0
    while True:
        t_lo = t_start + i * window_s
        t_hi = t_start + (i + 1) * window_s
        wcols = cols.window(t_lo if i else -np.inf, t_hi)
        rj = ri + len(wcols)
        aj = int(np.searchsorted(at, t_hi, side="right"))
        out.append(
            (t_hi, summarize_window(wcols, (at[ai:aj], aw[ai:aj]), workers, t_lo, t_hi))
        )
        ri, ai = rj, aj
        i += 1
        if t_hi >= t_start + duration_s and ri >= n_rec and ai >= n_asg:
            return out
