"""Metrics of Section V: latency, cold-start rate, load imbalance (CV), throughput."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .simulator import RequestRecord


@dataclasses.dataclass
class RunMetrics:
    n_requests: int
    mean_latency_ms: float
    p50_ms: float
    p90_ms: float
    p95_ms: float
    p99_ms: float
    cold_rate: float
    throughput_rps: float
    load_cv: float  # avg coefficient of variation of assignments/worker/second

    def row(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def latency_cdf(records: Sequence[RequestRecord], n_points: int = 200) -> Tuple[np.ndarray, np.ndarray]:
    lat = np.sort([r.latency_ms for r in records])
    y = np.arange(1, len(lat) + 1) / len(lat)
    if len(lat) > n_points:
        idx = np.linspace(0, len(lat) - 1, n_points).astype(int)
        return lat[idx], y[idx]
    return lat, y


def load_cv_per_second(
    assignments: Sequence[Tuple[float, int]], workers: Sequence[int], t_end: float
) -> np.ndarray:
    """Per-1s-bin CV across workers of assignment counts (Figure 14).

    The paper defines load imbalance as the coefficient of variation of the
    number of requests assigned per worker per second.
    """
    if not assignments:
        return np.zeros(0)
    n_bins = int(np.ceil(t_end)) + 1
    wid_index = {w: i for i, w in enumerate(workers)}
    counts = np.zeros((n_bins, len(workers)))
    for t, w in assignments:
        if w in wid_index:
            counts[min(int(t), n_bins - 1), wid_index[w]] += 1
    active = counts.sum(axis=1) > 0
    counts = counts[active]
    mean = counts.mean(axis=1)
    std = counts.std(axis=1)
    return np.where(mean > 0, std / np.maximum(mean, 1e-12), 0.0)


def summarize(
    records: Sequence[RequestRecord],
    assignments: Sequence[Tuple[float, int]],
    workers: Sequence[int],
    duration_s: float,
) -> RunMetrics:
    lat = np.array([r.latency_ms for r in records]) if records else np.zeros(1)
    cold = np.array([r.cold for r in records]) if records else np.zeros(1)
    cv = load_cv_per_second(assignments, workers, duration_s)
    return RunMetrics(
        n_requests=len(records),
        mean_latency_ms=float(lat.mean()),
        p50_ms=float(np.percentile(lat, 50)),
        p90_ms=float(np.percentile(lat, 90)),
        p95_ms=float(np.percentile(lat, 95)),
        p99_ms=float(np.percentile(lat, 99)),
        cold_rate=float(cold.mean()),
        throughput_rps=len(records) / max(duration_s, 1e-9),
        load_cv=float(cv.mean()) if cv.size else 0.0,
    )
