"""Pluggable admission-policy registry: "add a policy" as a registry entry.

The global admission tier (``core.admission``) started life with one
hard-wired behavior — watermark pull — and two literals bolted beside it
(``round_robin``, ``pull+steal``).  This module turns the policy choice into
a first-class extension point:

* :class:`AdmissionPolicy` is the author-facing protocol: a policy sees the
  co-run only through :class:`ShardState` snapshots and the
  :class:`PolicyContext` mediator, and decides **which shard pulls next**
  (``rank_shards``), **whether a shard may pull right now** (``want_pull``),
  and — optionally — **which queued VU is admitted first**
  (``orders_queue`` + ``queue_key``).
* :func:`register_policy` / :func:`unregister_policy` /
  :func:`available_policies` / :func:`make_policy` are the registry.
  ``AdmissionConfig`` validates its ``policy`` field against it, so a typo
  fails at config construction with the available list in the message.

The three pre-registry behaviors are ported onto the protocol **byte
identically** (``pull``, ``round_robin``, ``pull+steal`` — the admission and
stealing suites pass unmodified), and three new policies ship against it:

* ``deadline`` — EDF: the global queue is ordered by absolute deadline
  (arrival + per-VU relative deadline from the workload metadata), so during
  a backlog the most urgent VUs are admitted first, while shard selection
  stays pressure-ordered.  Kaffes et al. (*Practical Scheduling for
  Real-World Serverless Computing*) motivate deadline-awareness under
  realistic arrival mixes; ``RunMetrics.deadline_miss_rate`` scores it.
* ``cost`` — cold-start-cost-aware pull: each shard's pressure is inflated
  by its *lack* of warm capacity (``Simulator.warm_capacity``), so shards
  whose sandbox pools are pinned by running work — the ones that would
  cold-start or queue a new VU — pull less, and warm shards soak up
  arrivals first.
* ``predictive`` — a cheap MPC-flavored baseline (Nguyen et al., *Taming
  Cold Starts with Model Predictive Control*): an EWMA forecast of the
  arrival rate modulates the pull watermark, so shards pre-drain the queue
  ahead of a building burst instead of reacting one tick late.
* ``affinity`` / ``affinity+steal`` — warm-locality routing: shards are
  scored by expected warm-hit probability × pressure against their
  per-function warm-set digest (``Simulator.warm_digest`` via
  ``ShardState.warm_digest``), the KV-router analog; the ``+steal`` variant
  also runs the steal round warm-locality-aware (thieves prefer tasks they
  can serve warm).

Three **learned** policies (ROADMAP item 5) carry online state fed
exclusively through :meth:`AdmissionPolicy.observe` (``core.estimators``
holds the state machinery; :class:`LearnedPolicy` the windowed
fold/record/replay discipline):

* ``sjf`` — shortest-predicted-job-first: the global queue is ordered by
  each VU's predicted total service time from an online per-function
  Welford duration estimator (Przybylski et al.'s execution-time-aware
  scheduling, learned on the fly).
* ``bandit`` / ``bandit+steal`` — a bandit meta-policy (UCB1 or seeded
  epsilon-greedy) tuning the pull watermark — and, in the ``+steal``
  variant, the (steal, pull) watermark pair — per scenario from a windowed
  reward blending window p99 and cold rate (Nguyen et al.'s adaptive
  thresholds, model-free).

Determinism contract (normative; docs/POLICIES.md is the author guide):
policy decisions must be a pure function of the visible state — the
:class:`ShardState` fields, the policy's own config, and what it has
observed through :meth:`AdmissionPolicy.observe`.  No wall clock, no global
RNG: two runs with identical inputs must admit identical sequences
(``tests/test_policies.py`` pins determinism for every registered policy).
"""

from __future__ import annotations

import dataclasses
import heapq
import inspect
import types
from collections import deque
from typing import (
    Dict,
    List,
    Mapping,
    NamedTuple,
    Optional,
    Sequence,
    Tuple,
    Type,
)

from .estimators import BanditTuner, DurationEstimator

__all__ = [
    "AdmissionPolicy",
    "AffinityPolicy",
    "AffinityStealPolicy",
    "BanditPolicy",
    "BanditStealPolicy",
    "Completion",
    "CostPolicy",
    "DeadlinePolicy",
    "LearnedPolicy",
    "PolicyContext",
    "PredictivePolicy",
    "PullPolicy",
    "PullStealPolicy",
    "RoundRobinPolicy",
    "ShardState",
    "SjfPolicy",
    "available_policies",
    "make_policy",
    "policy_knobs",
    "register_policy",
    "unregister_policy",
]


class Completion(NamedTuple):
    """One completed request, as seen by the policy-facing completion feed
    (:meth:`PolicyContext.new_completions`).

    ``duration_ms`` is the externally observable request latency
    (``(t_done - t_submit) * 1e3`` — the same arithmetic the metrics layer
    uses), ``gid`` the global VU id, ``shard`` the shard it completed on.
    """

    gid: int
    func: int
    duration_ms: float
    cold: bool
    shard: int


@dataclasses.dataclass(frozen=True)
class ShardState:
    """The per-shard snapshot a policy may read — nothing else.

    Policies never touch ``Simulator`` objects directly: the admission tier
    builds these snapshots each tick, which is what keeps a policy's
    decision surface explicit, serializable, and stable across engine
    refactors (the policy-author contract in docs/POLICIES.md).

    Attributes:
        index: shard index in ``[0, n_shards)``.
        pressure: the shard's *effective* pressure — ``Simulator.pressure()``
            at tick start plus ``inv_workers`` per VU already bound this tick
            (``inf`` for a dead shard: all workers failed).
        n_workers: the shard's planned worker count (the partition's split,
            not the live count).
        inv_workers: ``1 / n_workers`` — the effective-pressure increment
            one admitted VU costs.
        warm_capacity: fraction of the shard's sandbox-pool memory not
            pinned by running tasks, in ``[0, 1]`` (``Simulator
            .warm_capacity()``); 0.0 for a dead shard.  High values mean new
            work can start warm or cold-start without queueing.  Populated
            by the default ``admit_tick`` only when the policy sets
            ``uses_warm_capacity = True`` (it costs an O(workers) scan per
            shard per tick); otherwise ``nan`` — reading it without the
            flag makes every comparison False, so the mistake is loud
            (nothing admits) instead of silently wrong.
        tick_pulls: VUs this shard has already pulled in the current tick.
        t: simulated time of the tick, seconds.
        resubmits: failure-retry pushes this shard's engine has performed
            so far — the churn signal (0 on fault-free runs).  Cumulative,
            so policies that want a rate difference ticks themselves.
        lost_tasks: requests this shard has dropped after exhausting the
            retry budget (``SimConfig.retry_budget``), cumulative.
        doomed_workers: live workers under a preemption notice right now
            (``AdmissionSimulator.inject_notice`` /
            ``chaos.spot_preemption``): still serving, scheduled to die.
            Advisory — a policy may shed load from a doomed shard early,
            but correctness never depends on it.
        warm_digest: the shard's per-function warm-set digest — a read-only
            ``{func_index: warm_instance_count}`` mapping over live,
            un-doomed workers (``Simulator.warm_digest``; functions with no
            warm instance are absent).  Populated by the default
            ``admit_tick`` only when the policy sets ``uses_warm_digest =
            True``; otherwise ``None``, so an undeclared read fails loudly
            (``AttributeError``/``TypeError``) instead of silently scoring
            everything cold.  The digest contract is normative in
            docs/ARCHITECTURE.md §11.

    The three failure fields default to 0 and are documented normatively in
    docs/POLICIES.md §2 and docs/ARCHITECTURE.md §10.
    """

    index: int
    pressure: float
    n_workers: int
    inv_workers: float
    warm_capacity: float
    tick_pulls: int
    t: float
    resubmits: int = 0
    lost_tasks: int = 0
    doomed_workers: int = 0
    warm_digest: Optional[Mapping[int, int]] = None


class PolicyContext:
    """Mediator between the admission loop and a policy.

    Owns the global waiting queue (FIFO deque, or a priority heap when the
    policy sets ``orders_queue``) and performs the actual binding
    (:meth:`admit_next`) with the admission tier's bookkeeping — policies
    choose, the context executes.  Policies may call only the documented
    read methods and :meth:`admit_next`.
    """

    def __init__(
        self,
        sims: Sequence,
        programs: Sequence,
        worker_split: Sequence[int],
        inv_workers: Sequence[float],
        admitted: List[List[int]],
        admit_t: List[List[float]],
        pulls: List[int],
        policy: "AdmissionPolicy",
        arrivals=None,
        deadlines=None,
    ):
        self.sims = sims
        self.programs = programs
        self.worker_split = list(worker_split)
        self.inv_workers = list(inv_workers)
        self.admitted = admitted
        self.admit_t = admit_t
        self.pulls = pulls
        self.policy = policy
        self._arrivals = arrivals
        self._deadlines = deadlines
        self.total_workers = sum(self.worker_split)
        #: the run's ShardCoordinator (set by the admission loop).  When
        #: present, the default ``admit_tick`` consumes its persistent
        #: pressure heap instead of rebuilding one per tick — byte-identical
        #: decisions at O(dirty) coordination cost (docs/ARCHITECTURE.md
        #: §13).  ``None`` under direct PolicyContext construction (tests).
        self.coord = None
        # FIFO deque by default; a min-heap of (queue_key, arrival_seq, gid)
        # when the policy orders the queue (EDF et al.)
        self._ordered = bool(policy.orders_queue)
        self.waiting = [] if self._ordered else deque()
        self._seq = 0
        # per-shard doomed-worker counts (preemption notices); the admission
        # loop refreshes this each tick when a fault plan carries notices
        self.doomed: List[int] = [0] * len(sims)
        # per-VU function-frequency profiles, computed lazily (func_profile)
        self._profiles: Dict[int, Tuple[Tuple[int, float], ...]] = {}
        # completion-feed cursors: rows of each shard's record accumulator
        # already handed out through new_completions()
        self._rec_seen: List[int] = [0] * len(sims)

    # ------------------------------------------------------------- queue
    @property
    def n_shards(self) -> int:
        return len(self.sims)

    @property
    def waiting_n(self) -> int:
        """Eligible-but-unadmitted VUs currently in the global queue."""
        return len(self.waiting)

    def enqueue(self, gid: int) -> None:
        """Move an eligible arrival into the global queue (tier-internal)."""
        if self._ordered:
            heapq.heappush(
                self.waiting, (self.policy.queue_key(gid, self), self._seq, gid)
            )
            self._seq += 1
        else:
            self.waiting.append(gid)

    def peek_next(self) -> int:
        """Global VU id the next :meth:`admit_next` call would bind."""
        return self.waiting[0][2] if self._ordered else self.waiting[0]

    # ---------------------------------------------------- workload metadata
    def arrival_of(self, gid: int) -> float:
        """The VU's admission-eligibility time (seconds; 0.0 if untimed)."""
        return 0.0 if self._arrivals is None else float(self._arrivals[gid])

    def deadline_of(self, gid: int) -> float:
        """The VU's *relative* latency deadline (seconds; ``inf`` if none).

        Workloads without deadline metadata read ``inf`` for every VU, which
        makes deadline-ordered queues degrade to FIFO (arrival order breaks
        the tie) — a deadline policy on an unannotated workload behaves like
        plain pull.
        """
        if self._deadlines is None:
            return float("inf")
        return float(self._deadlines[gid])

    def func_profile(self, gid: int) -> Tuple[Tuple[int, float], ...]:
        """VU ``gid``'s function-call mix as ``((func_index, frequency),
        ...)`` sorted by function index, frequencies summing to 1.0.

        The locality key affinity scoring matches against a shard's
        ``warm_digest``.  Pure function of the workload (the VU's program),
        cached per VU, so repeated reads inside a tick are O(1); an empty
        program yields ``()``.
        """
        prof = self._profiles.get(gid)
        if prof is None:
            fi = self.programs[gid].func_idx
            n = len(fi)
            if n == 0:
                prof = ()
            else:
                counts: Dict[int, int] = {}
                for f in fi.tolist():
                    counts[f] = counts.get(f, 0) + 1
                prof = tuple(
                    (f, c / n) for f, c in sorted(counts.items())
                )
            self._profiles[gid] = prof
        return prof

    # ------------------------------------------------------ completion feed
    def new_completions(self) -> List[Completion]:
        """Requests completed since the last call, exactly once each, in
        canonical order (shard index, then per-shard completion order).

        This is the **only** sanctioned signal source for learned policy
        state (docs/POLICIES.md "Learned state"): the admission loop calls
        ``observe`` once per tick before admission, so a policy that drains
        this feed there sees every completion exactly once, in an order
        that is a pure function of the run — the property replay needs.
        Each record lives on exactly one shard's accumulator (a salvaged
        VU's later requests complete on its *new* shard, under a fresh
        local id already present in the admission table), so per-shard
        cursors cannot double-count across migrations or salvage.
        """
        out: List[Completion] = []
        for k, sim in enumerate(self.sims):
            acc = sim._rec  # the engine's columnar accumulator (mediator-only)
            n = len(acc)
            i = self._rec_seen[k]
            if n <= i:
                continue
            ts, td = acc.t_submit, acc.t_done
            fn, cold, vu = acc.func, acc.cold, acc.vu
            adm = self.admitted[k]
            for j in range(i, n):
                out.append(
                    Completion(
                        gid=adm[vu[j]],
                        func=fn[j],
                        duration_ms=(td[j] - ts[j]) * 1e3,
                        cold=cold[j],
                        shard=k,
                    )
                )
            self._rec_seen[k] = n
        return out

    # ------------------------------------------------------------- binding
    def admit_next(self, k: int, t: float) -> int:
        """Bind the queue head to shard ``k`` at time ``t``; returns the
        global VU id.  Performs the engine call (``admit_vu``) and all
        admission-table bookkeeping."""
        if self._ordered:
            gid = heapq.heappop(self.waiting)[2]
        else:
            gid = self.waiting.popleft()
        local = self.sims[k].admit_vu(self.programs[gid], t=t)
        assert local == len(self.admitted[k])
        self.admitted[k].append(gid)
        self.admit_t[k].append(t)
        self.pulls[k] += 1
        return gid

    # -------------------------------------------------------------- shards
    def shard_state(
        self, k: int, t: float, pressure: Optional[float] = None,
        warm: Optional[float] = None, tick_pulls: int = 0,
        digest: Optional[Mapping[int, int]] = None,
    ) -> ShardState:
        sim = self.sims[k]
        return ShardState(
            index=k,
            pressure=sim.pressure() if pressure is None else pressure,
            n_workers=self.worker_split[k],
            inv_workers=self.inv_workers[k],
            warm_capacity=(
                sim.warm_capacity() if warm is None else warm
            ),
            tick_pulls=tick_pulls,
            t=t,
            resubmits=getattr(sim, "resubmits", 0),
            lost_tasks=getattr(sim, "lost_tasks", 0),
            doomed_workers=self.doomed[k],
            # read-only view: the snapshot stays frozen end to end even
            # though the underlying digest is a plain dict
            warm_digest=(
                None if digest is None else types.MappingProxyType(digest)
            ),
        )


class AdmissionPolicy:
    """Base class / protocol for admission policies (the author contract).

    Subclass, set ``name``, override the hooks you need, and register:

    * :meth:`want_pull` — may this shard bind the next queued VU *right
      now*?  Called with the shard's live :class:`ShardState` before every
      single binding.  Default: effective pressure below the config
      watermark (the original pull behavior).
    * :meth:`rank_shards` — the tick's shard ordering, as ``(key, index)``
      min-heap entries; the lowest key pulls first, and every pull bumps
      the shard's key by ``inv_workers`` (the admission tier's
      effective-pressure accounting).  Default: pressure-ordered.
    * ``orders_queue`` + :meth:`queue_key` — opt into a priority-ordered
      global queue (lowest key admitted first; arrival order breaks ties).
      Default off: FIFO.
    * :meth:`observe` — per-tick telemetry feed (new-arrival count) for
      forecasting policies; called once per tick *before* admission.
    * ``steals`` — class flag: run ``core.stealing.steal_tick`` after
      admission each tick (the ``pull+steal`` composition).

    Policies are instantiated fresh per run (``make_policy``), so instance
    attributes are run-local state; determinism obligations are spelled out
    in docs/POLICIES.md.
    """

    #: registry key; subclasses must override.
    name: str = ""
    #: run cross-shard work stealing after each admission tick.
    steals: bool = False
    #: order the global queue by :meth:`queue_key` instead of FIFO.
    orders_queue: bool = False
    #: have ``admit_tick`` populate ``ShardState.warm_capacity`` (an extra
    #: O(workers) scan per shard per tick; without the flag the field is
    #: ``nan``).  Set it whenever a hook reads the warm-capacity signal.
    uses_warm_capacity: bool = False
    #: have ``admit_tick`` populate ``ShardState.warm_digest`` (one
    #: ``Simulator.warm_digest()`` snapshot per shard per tick; without the
    #: flag the field is ``None``).  Set it whenever a hook reads the
    #: per-function warm-set digest.
    uses_warm_digest: bool = False
    #: with ``steals``: run the per-tick steal round warm-locality-aware
    #: (``core.stealing.steal_tick(prefer_warm=True)`` — each thief prefers
    #: exporting tasks whose function is in its own warm digest).  Inert
    #: without ``steals``; off keeps steal schedules byte-identical to the
    #: pre-digest tier.
    steal_affinity: bool = False
    #: policy carries learned (observation-fed) state subject to the
    #: snapshot/replay contract in docs/POLICIES.md "Learned state".
    #: Set by :class:`LearnedPolicy`; informational for everything else.
    learned: bool = False

    def __init__(self, cfg, **kwargs):
        """``cfg`` is the run's ``AdmissionConfig``; extra ``kwargs`` come
        from ``AdmissionConfig.policy_args`` (policy-specific knobs)."""
        self.cfg = cfg
        if kwargs:
            bad = ", ".join(repr(k) for k in sorted(kwargs))
            accepted = policy_knobs(type(self))
            raise TypeError(
                f"{type(self).__name__} got unknown policy_args key(s) {bad}; "
                f"accepted knobs: {accepted if accepted else '(none)'}"
            )

    # ----------------------------------------------------------- the hooks
    def queue_key(self, gid: int, ctx: PolicyContext) -> float:
        """Priority of VU ``gid`` in the global queue (lower = sooner);
        only consulted when ``orders_queue`` is set."""
        return 0.0

    def want_pull(self, state: ShardState) -> bool:
        """May this shard bind the next queued VU right now?"""
        return state.pressure < self.cfg.watermark

    def rank_shards(self, states: Sequence[ShardState]) -> List[Tuple[float, int]]:
        """Min-heap entries ``(key, shard_index)``; lowest key pulls first."""
        return [(s.pressure, s.index) for s in states]

    def observe(self, t: float, n_new: int, ctx: PolicyContext) -> None:
        """Per-tick feed: ``n_new`` VUs became eligible at time ``t``.

        Called once per tick *before* admission; also the only hook from
        which learned state may be updated (drain
        :meth:`PolicyContext.new_completions` here — see
        :class:`LearnedPolicy` and docs/POLICIES.md "Learned state")."""

    def steal_params(self) -> Tuple[float, float]:
        """``(steal_watermark, pull_watermark)`` for this tick's steal round
        (consulted only when ``steals`` is set).  Default: the static
        config pair — byte-identical to the pre-hook tier.  Learned
        stealing policies (``bandit+steal``) override this to tune the
        hysteresis band per reward window; implementations must keep
        ``steal_watermark >= pull_watermark`` (the no victim-and-thief
        invariant ``AdmissionConfig`` enforces for the static pair)."""
        return (self.cfg.steal_watermark, self.cfg.watermark)

    # ------------------------------------------------------------ the tick
    def admit_tick(self, t: float, ctx: PolicyContext) -> None:
        """One admission round: bind queued VUs to shards until every shard
        declines (``want_pull``) or the queue / per-shard batch cap empties.

        The default is the admission tier's pressure-keyed heap — the
        cluster-level ``PQ_f`` — parameterized by :meth:`rank_shards` and
        :meth:`want_pull`, with the ``1/n_workers`` effective-pressure
        accounting per binding.  Policies that aren't heap-shaped
        (``round_robin``) override the whole tick.

        When the admission loop supplies a ``ShardCoordinator``
        (``ctx.coord``) and the policy keeps the default pressure ranking
        with no warm-signal snapshots, the tick runs against the
        coordinator's *persistent* lazy-deletion heap instead of rebuilding
        a K-entry heap from live engine reads — byte-identical decisions
        (the valid-entry multiset equals the rebuilt heap's; see
        docs/ARCHITECTURE.md §13) at O(dirty) coordination cost.
        """
        if (
            ctx.coord is not None
            and type(self).rank_shards is AdmissionPolicy.rank_shards
            and not self.uses_warm_capacity
            and not self.uses_warm_digest
        ):
            self._admit_tick_incremental(t, ctx)
            return
        cfg = self.cfg
        inv = ctx.inv_workers
        K = ctx.n_shards
        eff = [ctx.sims[k].pressure() for k in range(K)]
        if self.uses_warm_capacity:
            warm = [ctx.sims[k].warm_capacity() for k in range(K)]
        else:  # unrequested: nan, so an undeclared read fails loudly
            warm = [float("nan")] * K
        if self.uses_warm_digest:
            digests = [ctx.sims[k].warm_digest() for k in range(K)]
        else:  # unrequested: None, so an undeclared read fails loudly
            digests = [None] * K
        tick_pulls = [0] * K

        def state(k: int) -> ShardState:
            return ctx.shard_state(
                k, t, pressure=eff[k], warm=warm[k], tick_pulls=tick_pulls[k],
                digest=digests[k],
            )

        heap = self.rank_shards([state(k) for k in range(K)])
        for key, k in heap:
            if key != key:  # NaN: poisons every heap comparison silently
                raise ValueError(
                    f"{type(self).__name__}.rank_shards returned a NaN key "
                    f"for shard {k}. NaN compares False against everything, "
                    "so a NaN-keyed heap silently freezes admission. Most "
                    "likely the key reads ShardState.warm_capacity without "
                    "setting uses_warm_capacity = True (the field is nan "
                    "otherwise; see docs/POLICIES.md §2)."
                )
        heapq.heapify(heap)
        while ctx.waiting_n and heap:
            key, k = heap[0]
            if not self.want_pull(state(k)):
                heapq.heappop(heap)  # shard declines: done for this tick
                continue
            ctx.admit_next(k, t)
            eff[k] += inv[k]
            tick_pulls[k] += 1
            if cfg.batch_size is not None and tick_pulls[k] >= cfg.batch_size:
                heapq.heappop(heap)  # per-shard cap reached this tick
            else:
                heapq.heapreplace(heap, (key + inv[k], k))

    def _admit_tick_incremental(self, t: float, ctx: PolicyContext) -> None:
        """The default admission round against the coordinator's persistent
        heap (``ctx.coord``) — the O(dirty) twin of the rebuild loop above.

        Correspondence with the rebuild loop, entry by entry: at tick start
        every shard holds exactly one valid entry keyed at its cached
        pressure — the same multiset the rebuild heapifies, because a shard
        whose pressure changed was dirty and ``refresh()`` pushed a
        superseding entry.  An admission replaces the shard's entry at
        ``key + inv`` (the rebuild's ``heapreplace``); a decline or a
        batch-cap pop *parks* the shard — its entry is removed for the rest
        of the tick, exactly like the rebuild's ``heappop`` — and parked
        shards are re-posted at their cached base pressure when the tick
        ends, so next tick starts from the full multiset again.  (A parked
        shard that admitted this tick is dirty, so the re-post is
        superseded by the next ``refresh()`` before anyone reads it.)
        """
        cfg = self.cfg
        coord = ctx.coord
        inv = ctx.inv_workers
        tick_pulls: Dict[int, int] = {}
        nan = float("nan")  # warm signals unrequested on this path

        parked: List[int] = []
        try:
            while ctx.waiting_n:
                top = coord.peek()
                if top is None:
                    break  # every shard declined or capped this tick
                key, k = top
                state = ctx.shard_state(
                    k, t, pressure=key, warm=nan,
                    tick_pulls=tick_pulls.get(k, 0), digest=None,
                )
                if not self.want_pull(state):
                    coord.pop()  # shard declines: done for this tick
                    parked.append(k)
                    continue
                ctx.admit_next(k, t)
                pulls = tick_pulls.get(k, 0) + 1
                tick_pulls[k] = pulls
                coord.pop()
                if cfg.batch_size is not None and pulls >= cfg.batch_size:
                    parked.append(k)  # per-shard cap reached this tick
                else:
                    coord.push(key + inv[k], k)
        finally:
            for k in parked:
                coord.push(coord.pressure[k], k)


# --------------------------------------------------------------- registry
_REGISTRY: Dict[str, Type[AdmissionPolicy]] = {}


def register_policy(cls: Type[AdmissionPolicy]) -> Type[AdmissionPolicy]:
    """Register an :class:`AdmissionPolicy` subclass under ``cls.name``.

    Usable as a decorator.  Re-registering a taken name raises — call
    :func:`unregister_policy` first (tests do exactly this round-trip).
    """
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a non-empty `name`")
    if cls.name in _REGISTRY and _REGISTRY[cls.name] is not cls:
        raise ValueError(f"admission policy {cls.name!r} is already registered")
    _REGISTRY[cls.name] = cls
    return cls


def unregister_policy(name: str) -> Type[AdmissionPolicy]:
    """Remove (and return) a registered policy; unknown names raise."""
    try:
        return _REGISTRY.pop(name)
    except KeyError:
        raise ValueError(
            f"unknown admission policy {name!r}; registered: "
            f"{sorted(_REGISTRY)}"
        ) from None


def available_policies() -> List[str]:
    """Sorted names of every registered admission policy."""
    return sorted(_REGISTRY)


def get_policy_class(name: str) -> Type[AdmissionPolicy]:
    """Resolve a registered policy class by name (with suggestions)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown admission policy {name!r}; available: "
            f"{available_policies()}"
        ) from None


def make_policy(name: str, cfg, **kwargs) -> AdmissionPolicy:
    """Instantiate a fresh policy for one run (``kwargs`` are policy knobs,
    merged from ``AdmissionConfig.policy_args`` by the admission tier)."""
    return get_policy_class(name)(cfg, **kwargs)


def policy_knobs(cls: Type[AdmissionPolicy]) -> List[str]:
    """The ``policy_args`` keys ``cls`` accepts, sorted.

    Walks the MRO collecting every named ``__init__`` parameter (beyond
    ``self``/``cfg`` and the ``**kwargs`` pass-through), so knobs declared
    anywhere in an inheritance chain — e.g. ``BanditStealPolicy`` knobs
    split across :class:`BanditPolicy` and :class:`LearnedPolicy` — are all
    reported.  ``AdmissionConfig`` uses this to make unknown-knob errors
    name the alternatives."""
    knobs: List[str] = []
    for c in cls.__mro__:
        init = c.__dict__.get("__init__")
        if init is None:
            continue
        try:
            params = inspect.signature(init).parameters
        except (TypeError, ValueError):  # e.g. object.__init__ slot wrapper
            continue
        for name, prm in params.items():
            if name in ("self", "cfg"):
                continue
            if prm.kind in (prm.VAR_KEYWORD, prm.VAR_POSITIONAL):
                continue
            if name not in knobs:
                knobs.append(name)
    return sorted(knobs)


# ------------------------------------------------- the ported three
@register_policy
class PullPolicy(AdmissionPolicy):
    """Watermark pull — the original admission tier behavior, verbatim:
    pressure-ordered shard heap, pull while below ``cfg.watermark``."""

    name = "pull"


@register_policy
class PullStealPolicy(PullPolicy):
    """Pull admission plus per-tick cross-shard work stealing
    (``core.stealing.steal_tick`` runs after every admission round)."""

    name = "pull+steal"
    steals = True


@register_policy
class RoundRobinPolicy(AdmissionPolicy):
    """Bind each eligible arrival to the next shard in cyclic order
    immediately — the arrival-capable static baseline.  Ignores pressure
    entirely; ``batch_size`` still caps bindings per shard per tick."""

    name = "round_robin"

    def __init__(self, cfg, **kwargs):
        super().__init__(cfg, **kwargs)
        self._next = 0  # cyclic cursor, persistent across ticks

    def admit_tick(self, t: float, ctx: PolicyContext) -> None:
        cfg = self.cfg
        # consecutive cyclic slots, so a quota of batch_size * K gives every
        # shard at most batch_size this tick
        quota = (
            ctx.waiting_n if cfg.batch_size is None
            else cfg.batch_size * ctx.n_shards
        )
        while ctx.waiting_n and quota > 0:
            quota -= 1
            k = self._next % ctx.n_shards
            self._next += 1
            ctx.admit_next(k, t)


# ------------------------------------------------- the new three
@register_policy
class DeadlinePolicy(AdmissionPolicy):
    """Earliest-deadline-first admission.

    The global queue is ordered by *absolute* deadline — the VU's arrival
    time plus its relative deadline from the workload metadata
    (``AdmissionSimulator.run(deadlines=...)``; scenario generators in
    ``core.workloads`` produce them) — so during a backlog the most urgent
    VUs bind first, into the uncongested headroom, while slack-rich VUs
    absorb the congested drain.  Shard selection stays pressure-ordered.
    Without deadline metadata every key is ``inf`` and arrival order breaks
    the tie: plain pull.
    """

    name = "deadline"
    orders_queue = True

    def queue_key(self, gid: int, ctx: PolicyContext) -> float:
        return ctx.arrival_of(gid) + ctx.deadline_of(gid)


@register_policy
class CostPolicy(AdmissionPolicy):
    """Cold-start-cost-aware pull.

    Each shard's pull threshold is effectively scaled by its warm capacity:
    the ranking/gating key is ``pressure + cost_weight * (1 -
    warm_capacity)``, so a shard whose sandbox pool is pinned by running
    work — where a new VU would cold-start or queue for memory — looks more
    expensive and pulls less, while warm shards soak up arrivals first.

    ``policy_args``: ``cost_weight`` (pressure-units penalty at zero warm
    capacity; default 0.5).
    """

    name = "cost"
    uses_warm_capacity = True

    def __init__(self, cfg, cost_weight: float = 0.5, **kwargs):
        super().__init__(cfg, **kwargs)
        if cost_weight < 0:
            raise ValueError("cost_weight must be >= 0")
        self.cost_weight = float(cost_weight)

    def _cost(self, s: ShardState) -> float:
        return s.pressure + self.cost_weight * (1.0 - s.warm_capacity)

    def want_pull(self, state: ShardState) -> bool:
        return self._cost(state) < self.cfg.watermark

    def rank_shards(self, states: Sequence[ShardState]) -> List[Tuple[float, int]]:
        return [(self._cost(s), s.index) for s in states]


@register_policy
class AffinityPolicy(AdmissionPolicy):
    """Warm-locality affinity admission — the KV-router analog.

    Pressure-only ranking sends the next VU to the *emptiest* shard even
    when a slightly-busier neighbor already holds warm sandboxes for every
    function the VU calls — trading a queue-free cold start for the warm
    start Hiku's pull principle exists to harvest.  This policy scores each
    candidate shard by **expected warm-hit probability × pressure**, the way
    triton_distributed's KV router scores workers by cache-overlap cost:

    ``key(shard, vu) = pressure − affinity_weight · hit(vu, shard)``

    where ``hit`` blends two warmth signals against the shard's
    ``ShardState.warm_digest``: the fraction of the VU's whole function-call
    mix (``PolicyContext.func_profile``) with at least one warm instance,
    and — weighted by ``first_weight`` — whether the VU's *first* call can
    start warm right now (the one request whose cold/warm fate admission
    decides directly; later calls depend on keep-alive surviving the think
    times).  Lower key pulls first, so warmth is a *discount* on pressure: a
    shard ``affinity_weight`` pressure units busier still wins when it can
    serve the VU fully warm, while a stone-cold shard competes on pressure
    alone.  Because the key depends on *which* VU is at the queue head, the
    tick re-scores shards per binding (O(K) per VU) instead of using the
    per-tick heap; ``want_pull``'s watermark gate and the ``batch_size`` cap
    apply unchanged.

    After each binding the VU's first call optimistically claims one warm
    instance from the chosen shard's (tick-local) digest copy, so a burst
    admitted within one tick spreads over the warm capacity instead of
    dog-piling onto a single warm sandbox.

    ``policy_args``: ``affinity_weight`` (pressure-units discount at a 100%
    warm score; default 1.0) and ``first_weight`` (first-call share of the
    hit blend, in ``[0, 1]``; default 0.5).  The defaults are the
    ``bench_affinity`` acceptance operating point on the 4-shard
    ``heavy_tail``/``diurnal`` protocol.
    """

    name = "affinity"
    uses_warm_digest = True

    def __init__(self, cfg, affinity_weight: float = 1.0,
                 first_weight: float = 0.5, **kwargs):
        super().__init__(cfg, **kwargs)
        if affinity_weight < 0:
            raise ValueError("affinity_weight must be >= 0")
        if not 0.0 <= first_weight <= 1.0:
            raise ValueError("first_weight must be in [0, 1]")
        self.affinity_weight = float(affinity_weight)
        self.first_weight = float(first_weight)

    @staticmethod
    def warm_hit(profile: Sequence[Tuple[int, float]],
                 digest: Optional[Mapping[int, int]]) -> float:
        """Expected warm-hit probability of a VU profile against a shard
        digest: the summed call frequency of profile functions with >= 1
        warm instance, in ``[0, 1]``."""
        if not digest:
            return 0.0
        return sum(freq for f, freq in profile if digest.get(f, 0) > 0)

    def admit_tick(self, t: float, ctx: PolicyContext) -> None:
        cfg = self.cfg
        inv = ctx.inv_workers
        K = ctx.n_shards
        eff = [ctx.sims[k].pressure() for k in range(K)]
        # tick-local digest copies: optimistic claims below must not leak
        # into the engine's own counters
        digests = [dict(ctx.sims[k].warm_digest()) for k in range(K)]
        tick_pulls = [0] * K
        nan = float("nan")  # uses_warm_capacity is unset: field stays nan

        def state(k: int) -> ShardState:
            return ctx.shard_state(
                k, t, pressure=eff[k], warm=nan, tick_pulls=tick_pulls[k],
                digest=digests[k],
            )

        fw = self.first_weight
        while ctx.waiting_n:
            gid = ctx.peek_next()
            prof = ctx.func_profile(gid)
            fi = ctx.programs[gid].func_idx
            f0 = int(fi[0]) if len(fi) else -1
            best_key = best_k = None
            for k in range(K):
                if cfg.batch_size is not None and tick_pulls[k] >= cfg.batch_size:
                    continue
                s = state(k)
                if not self.want_pull(s):
                    continue
                d = digests[k]
                hit = (1.0 - fw) * self.warm_hit(prof, d)
                if fw and d.get(f0, 0) > 0:
                    hit += fw
                key = s.pressure - self.affinity_weight * hit
                if best_key is None or key < best_key:
                    best_key, best_k = key, k
            if best_k is None:
                break  # every shard declined or hit its per-tick cap
            ctx.admit_next(best_k, t)
            eff[best_k] += inv[best_k]
            tick_pulls[best_k] += 1
            if f0 >= 0:  # claim the first call's warm instance, if any
                d = digests[best_k]
                c = d.get(f0, 0)
                if c > 1:
                    d[f0] = c - 1
                elif c:
                    del d[f0]


@register_policy
class AffinityStealPolicy(AffinityPolicy):
    """Affinity admission plus warm-locality work stealing: the per-tick
    steal round runs with ``prefer_warm=True``, so each thief exports the
    newest victim task *whose function it can serve warm* (falling back to
    the plain newest) — the same digest consumed at both tiers."""

    name = "affinity+steal"
    steals = True
    steal_affinity = True


@register_policy
class PredictivePolicy(AdmissionPolicy):
    """EWMA arrival-rate forecast modulating the watermark (cheap MPC).

    Each tick the policy folds the newly eligible arrival count into an
    exponentially weighted moving average; the forecast load — EWMA
    arrivals per tick spread across the cluster's workers, in pressure
    units — is added to the pull watermark.  While a burst builds, shards
    pull *ahead* of it (pre-draining the queue the way a one-step MPC
    controller would); in calm traffic the EWMA decays and the policy
    relaxes back to plain pull.

    ``policy_args``: ``alpha`` (EWMA smoothing in (0, 1]; default 0.3) and
    ``gain`` (forecast-to-watermark coupling; default 1.0).
    """

    name = "predictive"

    def __init__(self, cfg, alpha: float = 0.3, gain: float = 1.0, **kwargs):
        super().__init__(cfg, **kwargs)
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        if gain < 0:
            raise ValueError("gain must be >= 0")
        self.alpha = float(alpha)
        self.gain = float(gain)
        self._rate = 0.0  # EWMA of new arrivals per tick
        self._watermark = cfg.watermark

    def observe(self, t: float, n_new: int, ctx: PolicyContext) -> None:
        self._rate += self.alpha * (n_new - self._rate)
        forecast_pressure = self._rate / max(ctx.total_workers, 1)
        self._watermark = self.cfg.watermark + self.gain * forecast_pressure

    def want_pull(self, state: ShardState) -> bool:
        return state.pressure < self._watermark


# ------------------------------------------------- the learned tier
class LearnedPolicy(AdmissionPolicy):
    """Shared machinery for policies with learned state (ROADMAP item 5).

    The windowed fold/record/replay discipline (normative in
    docs/POLICIES.md "Learned state"):

    * :meth:`observe` buffers the completion feed
      (:meth:`PolicyContext.new_completions`) every tick; learned state
      mutates **only at window boundaries** — every ``update_every``-th
      tick the buffered window is folded (:meth:`fold`), so between
      boundaries every decision reads a constant state.
    * with ``record_state=True`` the policy appends a full state snapshot
      (:meth:`state_snapshot` — pure JSON types) after each boundary;
      ``AdmissionSimulator.run`` surfaces the list as
      ``AdmissionRun.policy_state``.
    * with ``replay_from=<recorded snapshots>`` the policy **restores** the
      recorded snapshot at each boundary instead of folding.  Because a
      complete snapshot reproduces the recorded post-fold state exactly,
      a replayed run is byte-identical to its recording — which is
      precisely the test that snapshots capture *all* decision-relevant
      state (``tests/test_replay.py`` pins it).

    ``policy_args`` (shared by every learned policy): ``update_every``
    (ticks per window; default 8), ``record_state``, ``replay_from``.
    """

    learned = True

    def __init__(
        self,
        cfg,
        update_every: int = 8,
        record_state: bool = False,
        replay_from: Optional[Sequence[Mapping]] = None,
        **kwargs,
    ):
        super().__init__(cfg, **kwargs)
        if int(update_every) < 1:
            raise ValueError("update_every must be >= 1")
        self.update_every = int(update_every)
        self.record_state = bool(record_state)
        self._replay = None if replay_from is None else list(replay_from)
        #: post-boundary state snapshots (filled when ``record_state``)
        self.snapshots: List[dict] = []
        self._pending: List[Completion] = []
        self._ticks = 0
        self._windows = 0

    def observe(self, t: float, n_new: int, ctx: PolicyContext) -> None:
        self._pending.extend(ctx.new_completions())
        self._ticks += 1
        if self._ticks % self.update_every == 0:
            self._advance_window()

    def _advance_window(self) -> None:
        w = self._windows
        self._windows += 1
        if self._replay is not None:
            if w >= len(self._replay):
                raise IndexError(
                    f"replay_from carries {len(self._replay)} snapshots but "
                    f"the run reached window {w} — a replay must share the "
                    "recording's workload, duration and update_every"
                )
            self.restore_state(self._replay[w])
        else:
            self.fold(tuple(self._pending))
        self._pending.clear()
        if self.record_state:
            self.snapshots.append(self.state_snapshot())

    # ---------------------------------------------- subclass obligations
    def fold(self, completions: Tuple[Completion, ...]) -> None:
        """Fold one window of completions into the learned state."""
        raise NotImplementedError

    def state_snapshot(self) -> dict:
        """Full learned state as pure JSON types (the snapshot contract)."""
        raise NotImplementedError

    def restore_state(self, snap: Mapping) -> None:
        """Replace learned state with a recorded snapshot."""
        raise NotImplementedError


@register_policy
class SjfPolicy(LearnedPolicy):
    """Shortest-predicted-job-first admission (learned SJF).

    The global queue is ordered by each VU's **predicted total service
    time**: ``n_calls * sum(freq_f * predict_ms(f))`` over the VU's
    function-call mix (``PolicyContext.func_profile``), with predictions
    from an online per-function Welford duration estimator
    (``core.estimators.DurationEstimator``) fed by the completion stream.
    During a backlog the quick interactive VUs jump the long batch VUs —
    the mean-latency/SJF result Przybylski et al. obtain from per-function
    execution-time estimates — while shard selection stays
    pressure-ordered.  Before any observation the estimator predicts
    ``prior_ms`` for everything and the queue degrades to FIFO.

    Queue keys are computed at *enqueue* time (heap invariant), from the
    estimator state as of the last window boundary — constant between
    boundaries, so keys are replay-stable.

    ``policy_args``: ``prior_ms`` (pre-observation prediction, ms; default
    500 — the scale of a cold-started request, so early admissions aren't
    falsely scored short) plus the :class:`LearnedPolicy` knobs.
    """

    name = "sjf"
    orders_queue = True

    def __init__(self, cfg, prior_ms: float = 500.0, **kwargs):
        super().__init__(cfg, **kwargs)
        self.estimator = DurationEstimator(prior_ms=prior_ms)

    def fold(self, completions: Tuple[Completion, ...]) -> None:
        est = self.estimator
        for c in completions:
            est.update(c.func, c.duration_ms)

    def state_snapshot(self) -> dict:
        return {"estimator": self.estimator.snapshot()}

    def restore_state(self, snap: Mapping) -> None:
        self.estimator.restore(snap["estimator"])

    def queue_key(self, gid: int, ctx: PolicyContext) -> float:
        prof = ctx.func_profile(gid)
        n_calls = len(ctx.programs[gid].func_idx)
        predict = self.estimator.predict_ms
        return n_calls * sum(freq * predict(f) for f, freq in prof)


@register_policy
class BanditPolicy(LearnedPolicy):
    """Bandit-tuned pull watermark (model-free adaptive thresholds).

    Arms are watermark multipliers; each reward window (``update_every``
    ticks) scores the *current* arm by the requests that completed in the
    window — ``reward = -(p99_window_ms / 1e3 + cold_weight * cold_rate)``,
    the p99 + cold-rate blend — then a :class:`~repro.core.estimators
    .BanditTuner` (UCB1, or seeded epsilon-greedy with counter-based
    draws) picks the next arm.  ``want_pull`` gates on ``cfg.watermark *
    current_arm``: low arms throttle admission (fewer cold starts, longer
    queue wait), high arms drain the queue eagerly — the bandit learns the
    trade per scenario instead of hand-tuning it (Nguyen et al.'s adaptive
    sizing, without the model).  Windows with no completions feed no
    reward (an empty window says nothing about the arm).

    Arms may also be ``(watermark_mult, steal_mult)`` pairs — scalars are
    normalized to ``(mult, 1.0)``; the steal member only matters under
    :class:`BanditStealPolicy`.

    ``policy_args``: ``arms`` (default ``(0.6, 1.0, 1.6, 2.4)``), ``mode``
    (``"ucb"``/``"egreedy"``), ``epsilon``, ``ucb_c``, ``bandit_seed``,
    ``cold_weight`` plus the :class:`LearnedPolicy` knobs.
    """

    name = "bandit"

    DEFAULT_ARMS: Tuple = (0.6, 1.0, 1.6, 2.4)

    def __init__(
        self,
        cfg,
        arms: Optional[Sequence] = None,
        mode: str = "ucb",
        epsilon: float = 0.1,
        ucb_c: float = 0.5,
        bandit_seed: int = 0,
        cold_weight: float = 1.0,
        **kwargs,
    ):
        super().__init__(cfg, **kwargs)
        if cold_weight < 0:
            raise ValueError("cold_weight must be >= 0")
        self.cold_weight = float(cold_weight)
        pairs = []
        for a in arms if arms is not None else self.DEFAULT_ARMS:
            if isinstance(a, (tuple, list)):
                wm, sm = (float(a[0]), float(a[1]))
            else:
                wm, sm = float(a), 1.0
            if wm <= 0 or sm <= 0:
                raise ValueError(f"arm multipliers must be > 0, got {a!r}")
            if self.steals and cfg.steal_watermark * sm < cfg.watermark * wm:
                raise ValueError(
                    f"arm {a!r} puts the effective steal watermark "
                    f"({cfg.steal_watermark * sm:g}) below the effective pull "
                    f"watermark ({cfg.watermark * wm:g}) — a shard must never "
                    "be steal victim and pull thief at once"
                )
            pairs.append((wm, sm))
        self.tuner = BanditTuner(
            tuple(pairs), mode=mode, epsilon=epsilon, ucb_c=ucb_c,
            seed=bandit_seed,
        )

    def fold(self, completions: Tuple[Completion, ...]) -> None:
        if not completions:
            return  # empty window: no evidence, no reward
        durs = sorted(c.duration_ms for c in completions)
        p99 = durs[min(len(durs) - 1, int(0.99 * len(durs)))]
        cold_rate = sum(1 for c in completions if c.cold) / len(completions)
        self.tuner.feed(-(p99 / 1e3 + self.cold_weight * cold_rate))

    def state_snapshot(self) -> dict:
        return {"tuner": self.tuner.snapshot()}

    def restore_state(self, snap: Mapping) -> None:
        self.tuner.restore(snap["tuner"])

    def want_pull(self, state: ShardState) -> bool:
        return state.pressure < self.cfg.watermark * self.tuner.current[0]


@register_policy
class BanditStealPolicy(BanditPolicy):
    """Bandit tuning the **(pull, steal) watermark pair** jointly.

    Same reward loop as ``bandit``, with stealing on and two-dimensional
    arms: each ``(watermark_mult, steal_mult)`` arm sets both the pull gate
    (``cfg.watermark * watermark_mult``) and the steal round's hysteresis
    band via :meth:`steal_params` (``cfg.steal_watermark * steal_mult``
    over the scaled pull watermark).  Construction rejects any arm whose
    effective steal watermark falls below its effective pull watermark, so
    the no victim-and-thief invariant holds on every arm.
    """

    name = "bandit+steal"
    steals = True

    DEFAULT_ARMS: Tuple = (
        (0.6, 1.0), (1.0, 1.0), (1.6, 1.2), (1.0, 0.7), (1.6, 1.6),
    )

    def steal_params(self) -> Tuple[float, float]:
        wm, sm = self.tuner.current
        return (self.cfg.steal_watermark * sm, self.cfg.watermark * wm)
