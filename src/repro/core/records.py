"""Columnar request-record store (the scale-out substrate of PR 2).

The simulator historically accumulated one ``RequestRecord`` NamedTuple per
completed request and one ``(t, worker)`` tuple per assignment — fine for the
paper's 5-worker protocol, hostile to production-scale runs: per-record
Python objects dominate memory at millions of requests, every metric pays a
Python-loop extraction, and shipping shard results between processes pickles
object graphs instead of buffers.

This module stores the same stream as seven parallel columns::

    t_submit  float64   submission time (s)
    t_done    float64   completion time incl. scheduler overhead (s)
    func      int32     function index
    worker    int32     worker id (shard-local until merged)
    cold      bool      cold-start flag
    vu        int32     virtual-user id (shard-local until merged)
    migrated  bool      completed on a shard other than the binding one
                        (cross-shard work stealing; always False without it)

Contracts:

* **Byte fidelity** — conversion ``records <-> columns`` is lossless:
  float64 columns hold the exact same doubles the NamedTuples carried, so
  the frozen-seed-engine equivalence suite keeps byte-for-byte guarantees
  through the columnar path (tests/test_records*.py pin the round-trip).
* **Order preservation** — columns keep the engine's completion order;
  ``concat``/``take`` are the only reordering primitives and both are
  explicit.
* **Zero-copy views** — ``as_structured`` reinterprets nothing; it copies
  once into a packed structured array for storage/IPC, and ``columns`` of a
  ``RecordColumns`` are the live numpy arrays (no per-access copies).

``RecordAccumulator`` is the growable form the simulator appends into
(plain Python lists per column — the cheapest exact append available to the
interpreter), snapshotting to ``RecordColumns`` on demand.
"""

from __future__ import annotations

from typing import Iterator, List, NamedTuple, Sequence, Union

import numpy as np


class RequestRecord(NamedTuple):
    """One completed request (the legacy row API, kept as the adapter).

    ``migrated`` defaults to False so 6-field legacy rows (the frozen seed
    engine's NamedTuples, pre-stealing pickles) adapt losslessly."""

    t_submit: float
    t_complete: float
    func: int
    worker: int
    cold: bool
    vu: int
    migrated: bool = False

    @property
    def latency_ms(self) -> float:
        return (self.t_complete - self.t_submit) * 1e3


#: packed on-disk / IPC layout of one record row
REC_DTYPE = np.dtype(
    [
        ("t_submit", "<f8"),
        ("t_done", "<f8"),
        ("func", "<i4"),
        ("worker", "<i4"),
        ("cold", "?"),
        ("vu", "<i4"),
        ("migrated", "?"),
    ]
)

_FIELDS = ("t_submit", "t_done", "func", "worker", "cold", "vu", "migrated")
_COL_DTYPES = (np.float64, np.float64, np.int32, np.int32, np.bool_, np.int32, np.bool_)


class RecordColumns:
    """Seven parallel numpy columns over a request-record stream.

    Column units: times in seconds (float64 — the exact doubles the engine
    produced; byte-fidelity contract), memory-free ids as int32, ``cold``/
    ``migrated`` as bool.  Completion order is preserved; only ``concat``/
    ``take`` (and the searchsorted-based ``window`` view) reorder,
    explicitly.  Worker and VU ids are shard-local until remapped
    (``remap``/``remap_vus``).  ``migrated`` defaults to all-False so
    6-column call sites (pre-work-stealing streams) stay valid."""

    __slots__ = _FIELDS

    def __init__(self, t_submit, t_done, func, worker, cold, vu, migrated=None):
        self.t_submit = np.asarray(t_submit, np.float64)
        self.t_done = np.asarray(t_done, np.float64)
        self.func = np.asarray(func, np.int32)
        self.worker = np.asarray(worker, np.int32)
        self.cold = np.asarray(cold, np.bool_)
        self.vu = np.asarray(vu, np.int32)
        n = self.t_submit.shape[0]
        self.migrated = (
            np.zeros(n, np.bool_) if migrated is None else np.asarray(migrated, np.bool_)
        )
        for name in _FIELDS[1:]:
            if getattr(self, name).shape != (n,):
                raise ValueError(f"column {name!r} length != {n}")

    # ------------------------------------------------------------ conversion
    @classmethod
    def from_records(
        cls, records: Union["RecordColumns", Sequence[RequestRecord]]
    ) -> "RecordColumns":
        """Adapter: list-of-``RequestRecord`` (or any row 6-tuples) -> columns."""
        if isinstance(records, RecordColumns):
            return records
        if not len(records):
            return cls.empty()
        return cls(*zip(*records))

    def to_records(self) -> List[RequestRecord]:
        """Columns -> list of ``RequestRecord`` with native Python scalars.

        ``ndarray.tolist`` yields the exact doubles/ints/bools stored, so the
        round-trip is bit-lossless.
        """
        return [
            RequestRecord(*row)
            for row in zip(
                self.t_submit.tolist(),
                self.t_done.tolist(),
                self.func.tolist(),
                self.worker.tolist(),
                self.cold.tolist(),
                self.vu.tolist(),
                self.migrated.tolist(),
            )
        ]

    @classmethod
    def empty(cls) -> "RecordColumns":
        return cls((), (), (), (), (), ())

    def as_structured(self) -> np.ndarray:
        """Packed structured array (``REC_DTYPE``) — one buffer for IPC/disk."""
        out = np.empty(len(self), REC_DTYPE)
        for name in _FIELDS:
            out[name] = getattr(self, name)
        return out

    @classmethod
    def from_structured(cls, arr: np.ndarray) -> "RecordColumns":
        """Unpack a structured array, matching fields by name.

        Only ``migrated`` may be absent (pre-work-stealing captures default
        it to False); any other missing field is data corruption and raises.
        """
        names = arr.dtype.names or ()
        missing = [n for n in _FIELDS[:6] if n not in names]
        if missing:
            raise ValueError(f"structured record array lacks fields {missing}")
        return cls(
            *(
                arr[name] if name in names else np.zeros(len(arr), dt)
                for name, dt in zip(_FIELDS, _COL_DTYPES)
            )
        )

    # -------------------------------------------------------------- protocol
    def __len__(self) -> int:
        return self.t_submit.shape[0]

    def __iter__(self) -> Iterator[RequestRecord]:
        return iter(self.to_records())

    def __getitem__(self, i):
        if isinstance(i, (int, np.integer)):
            return RequestRecord(
                float(self.t_submit[i]),
                float(self.t_done[i]),
                int(self.func[i]),
                int(self.worker[i]),
                bool(self.cold[i]),
                int(self.vu[i]),
                bool(self.migrated[i]),
            )
        return RecordColumns(*(getattr(self, name)[i] for name in _FIELDS))

    def equals(self, other: "RecordColumns") -> bool:
        return len(self) == len(other) and all(
            np.array_equal(getattr(self, name), getattr(other, name)) for name in _FIELDS
        )

    # --------------------------------------------------------------- derived
    @property
    def latency_ms(self) -> np.ndarray:
        """Vectorized ``RequestRecord.latency_ms``: identical doubles."""
        return (self.t_done - self.t_submit) * 1e3

    # ---------------------------------------------------------------- reshaping
    @staticmethod
    def concat(parts: Sequence["RecordColumns"]) -> "RecordColumns":
        parts = [p for p in parts if len(p)]
        if not parts:
            return RecordColumns.empty()
        return RecordColumns(
            *(np.concatenate([getattr(p, name) for p in parts]) for name in _FIELDS)
        )

    def take(self, idx: np.ndarray) -> "RecordColumns":
        return RecordColumns(*(getattr(self, name)[idx] for name in _FIELDS))

    def remap(self, worker_offset: int = 0, vu_offset: int = 0) -> "RecordColumns":
        """Shift shard-local worker/VU ids into a global id range (merge step)."""
        if not worker_offset and not vu_offset:
            return self
        return RecordColumns(
            self.t_submit,
            self.t_done,
            self.func,
            self.worker + np.int32(worker_offset),
            self.cold,
            self.vu + np.int32(vu_offset),
            self.migrated,
        )

    def remap_vus(self, vu_map: np.ndarray) -> "RecordColumns":
        """Translate local VU ids through an explicit id table (``vu_map[local]
        -> global``) — the merge step for dynamically admitted VUs, where
        local ids are admission-order positions rather than a contiguous
        offset range."""
        vu_map = np.asarray(vu_map, np.int32)
        return RecordColumns(
            self.t_submit, self.t_done, self.func, self.worker, self.cold,
            vu_map[self.vu], self.migrated,
        )

    def window(self, t_lo: float, t_hi: float) -> "RecordColumns":
        """Records completing in the half-open-from-above window
        ``t_lo < t_done <= t_hi``.

        Requires the stream to be sorted by ``t_done`` (engine completion
        order and merged-run order both are); the slice is then two binary
        searches, so windowed metrics over a merged run pay O(log n) per
        window instead of a mask per call.  Pass ``t_lo=-inf`` for the first
        window of a stream (includes records completing exactly at the
        stream start)."""
        lo = int(np.searchsorted(self.t_done, t_lo, side="right"))
        hi = int(np.searchsorted(self.t_done, t_hi, side="right"))
        return self[lo:hi]


class RecordAccumulator:
    """Growable columnar accumulator the simulator hot loop appends into.

    Per-column Python lists: a list append is the cheapest exact way to grow
    from the interpreter, and the values stored are the *same* Python floats
    /bools the legacy NamedTuple stream carried, so ``to_records`` is exact
    by construction (no float round-trip at all on the list path).
    """

    __slots__ = ("t_submit", "t_done", "func", "worker", "cold", "vu", "migrated")

    def __init__(self):
        self.t_submit: List[float] = []
        self.t_done: List[float] = []
        self.func: List[int] = []
        self.worker: List[int] = []
        self.cold: List[bool] = []
        self.vu: List[int] = []
        self.migrated: List[bool] = []

    def append(self, t_submit, t_done, func, worker, cold, vu, migrated=False) -> None:
        self.t_submit.append(t_submit)
        self.t_done.append(t_done)
        self.func.append(func)
        self.worker.append(worker)
        self.cold.append(cold)
        self.vu.append(vu)
        self.migrated.append(migrated)

    def extend(self, cols: RecordColumns) -> None:
        """Append a columnar chunk (the streaming-merge consumer path).

        ``ndarray.tolist`` yields the exact stored doubles/ints/bools, so
        accumulating stream chunks and snapshotting with :meth:`columns`
        reproduces the batch-merged stream byte-for-byte."""
        self.t_submit.extend(cols.t_submit.tolist())
        self.t_done.extend(cols.t_done.tolist())
        self.func.extend(cols.func.tolist())
        self.worker.extend(cols.worker.tolist())
        self.cold.extend(cols.cold.tolist())
        self.vu.extend(cols.vu.tolist())
        self.migrated.extend(cols.migrated.tolist())

    def __len__(self) -> int:
        return len(self.t_submit)

    def columns(self) -> RecordColumns:
        return RecordColumns(
            self.t_submit, self.t_done, self.func, self.worker, self.cold, self.vu,
            self.migrated,
        )

    def to_records(self) -> List[RequestRecord]:
        return [
            RequestRecord(*row)
            for row in zip(
                self.t_submit, self.t_done, self.func, self.worker, self.cold, self.vu,
                self.migrated,
            )
        ]

    def clear(self) -> None:
        for name in self.__slots__:
            getattr(self, name).clear()


# -------------------------------------------------- shared-memory transport
# One POSIX shared-memory segment per shard result: the packed REC_DTYPE
# record rows, then the float64 assignment times, then the int64 assignment
# worker ids (each section 8-byte aligned).  A shard child writes its columns
# straight into the segment and ships only the (name, row counts) metadata
# through the process pool; the parent reattaches and copies the columns out
# in one memcpy per section instead of pickling object graphs.
#
# Lifetime contract (docs/ARCHITECTURE.md §13; pinned by
# tests/test_records_shm.py and the leak check in tests/test_shard.py):
# segments are *explicitly* managed — both sides immediately detach the
# segment from Python's ``resource_tracker`` (whose exit-time cleanup is
# process-scoped and double-unlinks under fork pools) and the pool driver
# unlinks every segment it named in a ``finally``, so a writer crash before
# the merge leaves nothing behind in ``/dev/shm``.

def shm_layout(n_rec: int, n_asg: int) -> "tuple[int, int, int]":
    """``(assign_t offset, assign_w offset, total bytes)`` of a segment
    holding ``n_rec`` records and ``n_asg`` assignments.  Offsets are
    8-byte aligned so the float64/int64 views are aligned regardless of the
    packed record section's odd itemsize."""
    at_off = -(-(n_rec * REC_DTYPE.itemsize) // 8) * 8
    aw_off = at_off + 8 * n_asg
    return at_off, aw_off, aw_off + 8 * n_asg


def _untrack_shm(shm) -> None:
    """Detach a segment from ``resource_tracker`` exit-time cleanup: this
    module owns segment lifetime explicitly (create/attach both register on
    Python <= 3.12, so without this every attaching process unlinks the
    segment again at exit)."""
    from multiprocessing import resource_tracker

    try:
        resource_tracker.unregister(shm._name, "shared_memory")
    except Exception:
        pass  # tracker already gone (interpreter teardown) — nothing to undo


def write_columns_shm(
    name: str, records: RecordColumns, assign_t, assign_w
) -> "Union[str, None]":
    """Create segment ``name`` and fill it with one shard's columns.

    Writes each column directly into an aligned view over the segment (one
    memcpy per column, no intermediate structured array) and detaches the
    mapping before returning.  Returns ``name``, or ``None`` without
    creating anything when there are no rows at all (POSIX shm rejects
    zero-byte segments, and there is nothing to ship)."""
    from multiprocessing import shared_memory

    assign_t = np.asarray(assign_t, np.float64)
    assign_w = np.asarray(assign_w, np.int64)
    n_rec, n_asg = len(records), len(assign_t)
    if len(assign_w) != n_asg:
        raise ValueError("assign_t/assign_w length mismatch")
    at_off, aw_off, total = shm_layout(n_rec, n_asg)
    if total == 0:
        return None
    shm = shared_memory.SharedMemory(name=name, create=True, size=total)
    try:
        _untrack_shm(shm)
        if n_rec:
            rows = np.ndarray(n_rec, dtype=REC_DTYPE, buffer=shm.buf)
            for field in _FIELDS:
                rows[field] = getattr(records, field)
            del rows  # release the buffer export before close()
        if n_asg:
            np.ndarray(n_asg, np.float64, buffer=shm.buf, offset=at_off)[:] = assign_t
            np.ndarray(n_asg, np.int64, buffer=shm.buf, offset=aw_off)[:] = assign_w
    finally:
        shm.close()
    return name


def read_columns_shm(
    name: str, n_rec: int, n_asg: int
) -> "tuple[RecordColumns, np.ndarray, np.ndarray]":
    """Attach segment ``name``, copy its columns out, and detach.

    The returned arrays own their memory (one memcpy per section), so the
    caller may unlink the segment immediately.  Row counts travel out of
    band (the shipment metadata) — the segment itself is headerless."""
    from multiprocessing import shared_memory

    at_off, aw_off, _total = shm_layout(n_rec, n_asg)
    shm = shared_memory.SharedMemory(name=name)
    try:
        _untrack_shm(shm)
        if n_rec:
            rows = np.empty(n_rec, REC_DTYPE)
            rows[:] = np.ndarray(n_rec, dtype=REC_DTYPE, buffer=shm.buf)
            cols = RecordColumns.from_structured(rows)
        else:
            cols = RecordColumns.empty()
        if n_asg:
            at = np.array(np.ndarray(n_asg, np.float64, buffer=shm.buf, offset=at_off))
            aw = np.array(np.ndarray(n_asg, np.int64, buffer=shm.buf, offset=aw_off))
        else:
            at, aw = np.zeros(0, np.float64), np.zeros(0, np.int64)
        return cols, at, aw
    finally:
        shm.close()


def unlink_columns_shm(name: "Union[str, None]") -> None:
    """Remove segment ``name`` if it exists (idempotent crash-safe cleanup:
    attach, detach from the tracker, unlink; a missing segment — never
    created, or already unlinked — is not an error)."""
    from multiprocessing import shared_memory

    if name is None:
        return
    try:
        shm = shared_memory.SharedMemory(name=name)
    except FileNotFoundError:
        return
    try:
        # no _untrack_shm on success: unlink() itself unregisters the name,
        # which balances the register the attach above performed
        shm.unlink()
    except FileNotFoundError:
        # raced with another cleanup (writer-crash salvage vs the parent's
        # finally-unlink): the segment is already gone, but the failed
        # unlink never unregistered the attach — balance it explicitly or
        # resource_tracker re-unlinks the *name* at exit, clobbering any
        # later segment that reused it
        _untrack_shm(shm)
    finally:
        shm.close()
