"""Record-then-replay for admission runs: scripted per-shard re-execution.

Learned policies put run history into the decision path, so "the run is
deterministic" needs teeth beyond re-running the whole admission loop: this
module re-executes a *recorded* admission run shard by shard, from nothing
but each shard's admission table, and demands byte-identical record streams.

Why that is a meaningful check: under a steal-free, salvage-free admission
run, a shard's entire evolution is determined by its seed, its config, and
the ``(time, program)`` sequence of VUs admitted into it — the policy (with
all its learned state) influenced *which* VUs bound *when*, and nothing
else.  :func:`scripts_from_run` extracts exactly that interface
(:class:`ShardScript`, picklable), and :func:`replay_shards` re-runs the
scripts on any of the three shard execution styles:

* ``serial`` — one shard after another in this process;
* ``interleaved`` — all shards round-robined tick by tick in this process
  (the lockstep shape of the admission co-run itself);
* ``process`` — one OS process per shard (fork-based pool, same idiom as
  ``core.shard``).

All three must reproduce each recorded shard's ``RequestRecord`` stream and
assignment trace **byte-for-byte** (``tests/test_replay.py`` pins it, for
learned policies recorded via ``policy_args={"record_state": True}`` whose
estimator snapshots replay through ``replay_from`` — the two halves of the
record-then-replay contract in docs/POLICIES.md "Learned state").

Runs with cross-shard identity moves (steals, dead-shard salvage) are *not*
scriptable per shard — a migrated VU's service identity spans two engines —
so :func:`scripts_from_run` refuses them loudly.  Engine-local faults
(worker kills/revivals/notices) replay fine: the schedule rides on the
script.
"""

from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import warnings
from concurrent.futures import ProcessPoolExecutor
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .records import RecordColumns
from .scheduler import make_scheduler
from .shard import shard_seed
from .simulator import SimConfig, Simulator
from .trace import VUProgram, make_functions

__all__ = [
    "REPLAY_BACKENDS",
    "ShardScript",
    "ScriptResult",
    "replay_shards",
    "run_script",
    "scripts_from_run",
]

REPLAY_BACKENDS = ("serial", "interleaved", "process")


@dataclasses.dataclass
class ShardScript:
    """Everything one shard needs to re-run a recorded admission run
    (picklable, so the ``process`` backend can ship it to a child).

    ``admits`` is the shard's recorded admission schedule — ``(t, program)``
    in admission order, times on the admission tick grid.  ``funcs_seed``
    regenerates the *shared* function population (``make_functions``): under
    global admission every shard serves the same functions, unlike the
    static partition's per-shard populations.  Fault events carry
    shard-local worker ids.
    """

    index: int
    seed: int  # shard_seed(run_seed, index) — the engine identity
    scheduler: str
    cfg: SimConfig  # n_workers already rewritten to the shard's split
    funcs_seed: int
    duration_s: float
    tick_s: float
    admits: Tuple[Tuple[float, VUProgram], ...]
    failures: Tuple[Tuple[float, int], ...] = ()
    additions: Tuple[Tuple[float, int], ...] = ()
    notices: Tuple[Tuple[float, int, float], ...] = ()


@dataclasses.dataclass
class ScriptResult:
    """One replayed shard's output, in shard-local ids — directly
    comparable against the recorded ``AdmissionShard``."""

    index: int
    records: RecordColumns
    assign_t: np.ndarray
    assign_w: np.ndarray
    n_events: int

    def matches(self, shard) -> bool:
        """Byte-identical to a recorded ``AdmissionShard``?"""
        return bool(
            self.records.equals(shard.records)
            and np.array_equal(self.assign_t, shard.assign_t)
            and np.array_equal(self.assign_w, shard.assign_w)
        )


def scripts_from_run(adm, run, programs, duration_s: float) -> List[ShardScript]:
    """Extract per-shard replay scripts from a recorded admission run.

    Args:
        adm: the ``AdmissionSimulator`` that produced ``run`` (source of
            seeds, partition, scheduler, config and any injected fault
            schedule).
        run: the ``AdmissionRun`` to replay.  Must be steal- and
            salvage-free — cross-shard identity moves cannot be replayed
            shard-locally, and the refusal is loud.
        programs: the global VU programs the run was driven with.
        duration_s: the recorded run's deadline (not stored on the run).
    """
    if run.n_migrations or run.n_salvages:
        raise ValueError(
            f"run has {run.n_migrations} migrations and {run.n_salvages} "
            "salvages — a VU whose service identity moved between shards "
            "cannot be replayed shard-locally; record with a steal-free "
            "policy and no dead-shard drain to script the run"
        )
    per_failures: List[List[Tuple[float, int]]] = [[] for _ in range(adm.n_shards)]
    per_additions: List[List[Tuple[float, int]]] = [[] for _ in range(adm.n_shards)]
    per_notices: List[List[Tuple[float, int, float]]] = [
        [] for _ in range(adm.n_shards)
    ]
    for ft, gw in adm._failures:
        k, local = adm._locate(gw, "scripts_from_run")
        per_failures[k].append((ft, local))
    for ft, gw in adm._additions:
        k, local = adm._locate(gw, "scripts_from_run")
        per_additions[k].append((ft, local))
    for ft, gw, until in adm._notices:
        k, local = adm._locate(gw, "scripts_from_run")
        per_notices[k].append((ft, local, until))
    scripts = []
    for k, shard in enumerate(run.shards):
        admits = tuple(
            (float(t), programs[int(gid)])
            for t, gid in zip(shard.admit_t, shard.admitted)
        )
        scripts.append(
            ShardScript(
                index=k,
                seed=shard_seed(adm.seed, k),
                scheduler=adm.scheduler,
                cfg=dataclasses.replace(adm.cfg, n_workers=adm.worker_split[k]),
                funcs_seed=adm.seed,
                duration_s=float(duration_s),
                tick_s=float(adm.admission.tick_s),
                admits=admits,
                failures=tuple(per_failures[k]),
                additions=tuple(per_additions[k]),
                notices=tuple(per_notices[k]),
            )
        )
    return scripts


def _script_steps(script: ShardScript) -> Iterator[Optional[ScriptResult]]:
    """Generator form of one shard's replay: yields ``None`` once per
    admission tick (the interleave points), then the :class:`ScriptResult`.

    Stepping on the recorded tick grid reproduces the admission co-run's
    engine calls exactly: admissions land at their recorded boundary times
    (bit-equal floats — both sides compute ``tick * tick_s``), and event
    processing order inside the engine depends only on the event heap, not
    on the step granularity.
    """
    funcs = make_functions(seed=script.funcs_seed)
    sched = make_scheduler(
        script.scheduler, script.cfg.n_workers, seed=script.seed
    )
    sim = Simulator(sched, funcs=funcs, cfg=script.cfg, seed=script.seed)
    for ft, w in script.failures:
        sim.inject_failure(ft, w)
    for ft, w in script.additions:
        sim.inject_worker(ft, w)
    for ft, w, until in script.notices:
        sim.inject_notice(ft, w, until)
    sim.begin(n_vus=0, duration_s=script.duration_s, programs=[])
    admits = script.admits
    i = 0
    tick = 0
    t = 0.0
    while True:
        while i < len(admits) and admits[i][0] <= t:
            sim.admit_vu(admits[i][1], t=t)
            i += 1
        if t >= script.duration_s and sim.done and i == len(admits):
            break
        tick += 1
        t = tick * script.tick_s  # drift-free, like the admission loop
        sim.step_until(t)
        yield None
    at, aw = sim.assignment_columns
    yield ScriptResult(
        index=script.index,
        records=sim.record_columns,
        assign_t=at,
        assign_w=aw,
        n_events=sim.n_events,
    )


def run_script(script: ShardScript) -> ScriptResult:
    """Replay one shard's script to completion (the ``serial``/``process``
    unit of work; module-level, hence picklable)."""
    result = None
    for result in _script_steps(script):
        pass
    return result


def _run_interleaved(scripts: Sequence[ShardScript]) -> List[ScriptResult]:
    """Round-robin all shard replays tick by tick in this process — the
    lockstep shape of the admission co-run itself."""
    gens = [_script_steps(s) for s in scripts]
    results: List[Optional[ScriptResult]] = [None] * len(scripts)
    live = list(range(len(scripts)))
    while live:
        still = []
        for i in live:
            step = next(gens[i])
            if step is None:
                still.append(i)
            else:
                results[i] = step
        live = still
    return results  # type: ignore[return-value]


def _run_process_pool(scripts: Sequence[ShardScript]) -> List[ScriptResult]:
    # same fork-first idiom as core.shard: replay children are pure
    # numpy/heapq and never enter XLA, so jax's blanket fork warning does
    # not apply; REPRO_SHARD_START_METHOD overrides where fork is not viable
    start = os.environ.get("REPRO_SHARD_START_METHOD") or (
        "fork" if "fork" in mp.get_all_start_methods() else "spawn"
    )
    ctx = mp.get_context(start)
    max_workers = min(len(scripts), os.cpu_count() or 1)
    with warnings.catch_warnings():
        if start == "fork":
            warnings.filterwarnings(
                "ignore", message=r"os\.fork\(\) was called", category=RuntimeWarning
            )
        with ProcessPoolExecutor(max_workers=max_workers, mp_context=ctx) as pool:
            return list(pool.map(run_script, scripts))


def replay_shards(
    scripts: Sequence[ShardScript], backend: str = "serial"
) -> List[ScriptResult]:
    """Replay shard scripts on one of the three backends (shard order
    preserved; identical results on all three by the determinism contract)."""
    if backend == "serial":
        return [run_script(s) for s in scripts]
    if backend == "interleaved":
        return _run_interleaved(scripts)
    if backend == "process":
        return _run_process_pool(scripts)
    raise ValueError(
        f"unknown replay backend {backend!r}; available: {REPLAY_BACKENDS}"
    )
