"""Scheduler interface for the serverless control plane.

The scheduler maps an incoming request for a function type to a worker id
(Section III-A of the paper: ``S(r_i) = (w_j, t_exec)``; the execution time is
decided by the worker/simulator, the scheduler only picks ``w_j``).

Schedulers keep their *own view* of cluster state, fed exclusively through the
callbacks below — exactly like the OpenLambda scheduler proxy the paper extends:

* ``on_assign(w, f)``   — request dispatched to ``w`` (active connection opens).
* ``on_finish(w, f)``   — worker reports completion (connection closes).  For
  Hiku this is the *pull* signal: the worker enqueues itself in ``PQ_f``.
* ``on_evict(w, f)``    — worker evicted an idle instance of ``f`` (keep-alive
  timeout or memory pressure) and *notifies* the scheduler (Section IV-A,
  notification mechanism).
* ``on_worker_added/on_worker_removed`` — elastic scaling / failure events.
"""

from __future__ import annotations

import abc
import random
from typing import Callable, Dict, List, Optional

import numpy as np


# Least-connections tie bitmaps: 64 ids per word, 16 words (1024 ids) per
# popcount block — block counts let a tie select skip most of the bitmap.
_LC_BLOCK_WORDS = 16


class Scheduler(abc.ABC):
    """Base class; concrete schedulers implement ``select``."""

    name: str = "base"

    def __init__(self, n_workers: int, seed: int = 0):
        self.n_workers = n_workers
        self.workers: List[int] = list(range(n_workers))
        self.rng = random.Random(seed)
        # Scheduler-view active connections per worker (LC fallback et al.).
        # Managed via the callbacks; total_conns mirrors the sum over live
        # workers so bounded-load baselines avoid an O(workers) sum per
        # request.
        self.conns: Dict[int, int] = {w: 0 for w in self.workers}
        self.total_conns = 0
        # Dense mirror of ``conns`` for the least-connections scan: C-speed
        # argmin over 100s of workers instead of a Python listcomp.  Only
        # valid while worker ids are ascending (so id order == workers-list
        # order and the tie set comes out in the seed engine's order);
        # otherwise _least_connections falls back to the exact scan.
        self._conns_arr = np.zeros(max(n_workers, 1), np.int64)
        self._live_ids: Optional[np.ndarray] = None  # rebuilt lazily
        self._ids_ascending = True
        # Incremental least-connections tracker: per-conns-value tie counts
        # plus a two-level id bitmap over the *live* workers — a list of
        # 64-bit words and per-16-word block popcounts.  Every conns change
        # touches one word and one block counter (O(1), no wide-int
        # copies), and a tie select walks blocks -> words -> bytes, so the
        # fallback needs no O(workers) pass per call at 10k+ worker shards
        # (byte-identical to the full scan — see _least_connections /
        # _least_connections_ref).
        self._lc_val: Dict[int, int] = {w: 0 for w in self.workers}
        self._lc_cnt: Dict[int, int] = {0: n_workers} if n_workers else {}
        self._lc_nwords = (max(n_workers, 1) + 63) >> 6
        words, blocks = self._lc_new_rows()
        full, rem = divmod(n_workers, 64)
        for wi in range(full):
            words[wi] = 0xFFFFFFFFFFFFFFFF
            blocks[wi >> 4] += 64
        if rem:
            words[full] = (1 << rem) - 1
            blocks[full >> 4] += rem
        self._lc_bm: Dict[int, List[int]] = {0: words} if n_workers else {}
        self._lc_blk: Dict[int, List[int]] = {0: blocks} if n_workers else {}
        self._lc_min = 0

    # ------------------------------------------------------------------ API
    @abc.abstractmethod
    def select(self, func: str) -> int:
        """Pick a worker for a request of function type ``func``."""

    def schedule(self, func: str) -> int:
        w = self.select(func)
        self.on_assign(w, func)
        return w

    # ------------------------------------------------ conns-bucket tracker
    def _lc_new_rows(self):
        """Fresh (words, block-popcounts) rows at current capacity."""
        nw = self._lc_nwords
        return [0] * nw, [0] * ((nw + _LC_BLOCK_WORDS - 1) // _LC_BLOCK_WORDS)

    def _lc_grow(self, nwords: int) -> None:
        """Extend every value's rows to hold ids up to ``nwords * 64``."""
        nwords = max(nwords, 2 * self._lc_nwords)
        self._lc_nwords = nwords
        nblocks = (nwords + _LC_BLOCK_WORDS - 1) // _LC_BLOCK_WORDS
        for v, row in self._lc_bm.items():
            row.extend([0] * (nwords - len(row)))
            blk = self._lc_blk[v]
            blk.extend([0] * (nblocks - len(blk)))

    def _lc_move(self, worker: int, new: int) -> None:
        """Move a *live* worker between conns buckets (no-op for phantom
        ids — conns entries whose worker left the cluster stay out of the
        tie sets, exactly like the scan over ``self.workers``)."""
        val = self._lc_val
        old = val.get(worker)
        if old is None or old == new:
            return
        val[worker] = new
        cnt, bm, blk = self._lc_cnt, self._lc_bm, self._lc_blk
        wi = worker >> 6
        bit = 1 << (worker & 63)
        bm[old][wi] &= ~bit
        blk[old][wi >> 4] -= 1
        c = cnt[old] - 1
        if c:
            cnt[old] = c
        else:
            del cnt[old]
        if new in cnt:
            cnt[new] += 1
        else:
            cnt[new] = 1
            if new not in bm:
                bm[new], blk[new] = self._lc_new_rows()
        bm[new][wi] |= bit
        blk[new][wi >> 4] += 1
        if new < self._lc_min:
            self._lc_min = new
        elif old == self._lc_min and old not in cnt:
            m = old
            while m not in cnt:  # conns move by +-1: terminates by ``new``
                m += 1
            self._lc_min = m

    def _lc_add(self, worker: int) -> None:
        """Track a newly live worker (conns 0)."""
        wi = worker >> 6
        if wi >= self._lc_nwords:
            self._lc_grow(wi + 1)
        self._lc_val[worker] = 0
        self._lc_cnt[0] = self._lc_cnt.get(0, 0) + 1
        if 0 not in self._lc_bm:
            self._lc_bm[0], self._lc_blk[0] = self._lc_new_rows()
        self._lc_bm[0][wi] |= 1 << (worker & 63)
        self._lc_blk[0][wi >> 4] += 1
        self._lc_min = 0

    def _lc_drop(self, worker: int) -> None:
        """Stop tracking a removed worker."""
        old = self._lc_val.pop(worker, None)
        if old is None:
            return
        cnt = self._lc_cnt
        self._lc_bm[old][worker >> 6] &= ~(1 << (worker & 63))
        self._lc_blk[old][worker >> 10] -= 1
        c = cnt[old] - 1
        if c:
            cnt[old] = c
        else:
            del cnt[old]
            if old == self._lc_min:
                self._lc_min = min(cnt) if cnt else 0

    # ------------------------------------------------------------ callbacks
    def on_assign(self, worker: int, func: str) -> None:
        new = self.conns.get(worker, 0) + 1
        self.conns[worker] = new
        self.total_conns += 1
        if worker < len(self._conns_arr):
            self._conns_arr[worker] = new
        self._lc_move(worker, new)

    def _release(self, worker: int) -> int:
        """Clamped connection decrement + total/dense-mirror bookkeeping.

        Shared by on_finish/on_cancel (HikuScheduler.on_finish inlines the
        same sequence for hot-path speed — keep them in sync).
        """
        old = self.conns.get(worker, 0)
        new = old - 1 if old > 0 else 0
        self.conns[worker] = new
        self.total_conns += new - old
        if worker < len(self._conns_arr):
            self._conns_arr[worker] = new
        self._lc_move(worker, new)
        return new

    def on_finish(self, worker: int, func: str) -> None:
        self._release(worker)

    def on_cancel(self, worker: int, func: str) -> None:
        """Undo an assignment that never executed (failure race).

        Unlike ``on_finish`` this must NOT signal idle capacity (no pull
        enqueue in Hiku) — it only releases the connection count.
        """
        self._release(worker)

    def on_evict(self, worker: int, func: str) -> None:  # noqa: B027
        """Sandbox-destruction notification; default: ignored."""

    def on_worker_added(self, worker: int) -> None:
        if worker not in self.conns:
            if self.workers and worker < self.workers[-1]:
                self._ids_ascending = False  # id order != list order
            self.workers.append(worker)
            self.conns[worker] = 0
            self.n_workers = len(self.workers)
            if worker >= len(self._conns_arr):
                grown = np.zeros(max(worker + 1, 2 * len(self._conns_arr)), np.int64)
                grown[: len(self._conns_arr)] = self._conns_arr
                self._conns_arr = grown
            self._conns_arr[worker] = 0
            self._live_ids = None
            self._lc_add(worker)

    def on_worker_removed(self, worker: int) -> None:
        if worker in self.conns:
            self.workers.remove(worker)
            self.total_conns -= self.conns.pop(worker)
            self.n_workers = len(self.workers)
            self._live_ids = None
            self._lc_drop(worker)

    # ------------------------------------------------------------- helpers
    def _least_connections(self) -> int:
        """Least-connections with random tie-breaking (Algorithm 1 l.8-10).

        Fed by the incremental conns tracker: the minimum, its tie count
        and its tie *bitmap* are already maintained, so a call is one RNG
        draw plus a k-th-set-bit select over the two-level bitmap (block
        popcounts -> words -> bytes) — no O(workers) pass at any tie size
        (at mega shards the tie set is routinely half the cluster).

        Byte-identity with :meth:`_least_connections_ref`: the reference
        draws ``rng.choice(tied)`` over the ascending tie array, which
        consumes exactly one ``_randbelow(len(tied))`` — the same single
        draw as ``rng.randrange(t)`` — and returns the ``k``-th entry,
        i.e. the ``k``-th smallest tied id, i.e. the ``k``-th set bit of
        the tie bitmap.  Pinned live by tests/test_scheduler.py.  The
        reference remains the exact path for non-ascending worker ids
        (out-of-order elastic joins), where tie order follows the workers
        *list*, not sorted ids.
        """
        if not self._ids_ascending:
            return self._least_connections_ref()
        m = self._lc_min
        t = self._lc_cnt.get(m)
        if not t:
            return self._least_connections_ref()
        k = self.rng.randrange(t)
        blocks = self._lc_blk[m]
        bi = 0
        c = blocks[0]
        while k >= c:
            k -= c
            bi += 1
            c = blocks[bi]
        words = self._lc_bm[m]
        wi = bi << 4
        c = words[wi].bit_count()
        while k >= c:
            k -= c
            wi += 1
            c = words[wi].bit_count()
        w = words[wi]
        base = wi << 6
        c = (w & 0xFF).bit_count()
        while k >= c:
            k -= c
            base += 8
            w >>= 8
            c = (w & 0xFF).bit_count()
        b = w & 0xFF
        for _ in range(k):
            b &= b - 1
        return base + (b & -b).bit_length() - 1

    def _least_connections_ref(self) -> int:
        """The full-scan form (the seed engine's): retained as the byte-
        identity oracle for the tracker-fed fast path, as the exact path
        for non-ascending worker ids, and as the forced-legacy mode of
        ``benchmarks/bench_shard_scale.py``."""
        if not self._ids_ascending:
            conns = self.conns
            cs = [conns[w] for w in self.workers]
            lmin = min(cs)
            tied = [w for w, c in zip(self.workers, cs) if c == lmin]
            return self.rng.choice(tied)
        ids = self._live_ids
        if ids is None:
            ids = self._live_ids = np.array(self.workers, np.int64)
        sub = self._conns_arr[ids]
        tied = ids[sub == sub.min()]
        return int(self.rng.choice(tied))


# Registry -----------------------------------------------------------------
_REGISTRY: Dict[str, Callable[..., Scheduler]] = {}


def register(name: str):
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def make_scheduler(name: str, n_workers: int, seed: int = 0, **kw) -> Scheduler:
    """Instantiate a registered scheduler by name (``"hiku"``, ``"ch_bl"``,
    ``"least_connections"``, ``"random"``, ...).

    ``seed`` feeds the scheduler's private tie-break RNG only — workload
    randomness lives in the simulator — and is part of the replay identity
    the equivalence suite pins.  Extra kwargs go to the concrete class
    (e.g. ``fallback=`` for hiku, ``threshold=`` for CH-BL)."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown scheduler {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](n_workers, seed=seed, **kw)


def available_schedulers() -> List[str]:
    """Sorted names accepted by :func:`make_scheduler`."""
    return sorted(_REGISTRY)
