"""Scheduler interface for the serverless control plane.

The scheduler maps an incoming request for a function type to a worker id
(Section III-A of the paper: ``S(r_i) = (w_j, t_exec)``; the execution time is
decided by the worker/simulator, the scheduler only picks ``w_j``).

Schedulers keep their *own view* of cluster state, fed exclusively through the
callbacks below — exactly like the OpenLambda scheduler proxy the paper extends:

* ``on_assign(w, f)``   — request dispatched to ``w`` (active connection opens).
* ``on_finish(w, f)``   — worker reports completion (connection closes).  For
  Hiku this is the *pull* signal: the worker enqueues itself in ``PQ_f``.
* ``on_evict(w, f)``    — worker evicted an idle instance of ``f`` (keep-alive
  timeout or memory pressure) and *notifies* the scheduler (Section IV-A,
  notification mechanism).
* ``on_worker_added/on_worker_removed`` — elastic scaling / failure events.
"""

from __future__ import annotations

import abc
import random
from typing import Callable, Dict, List, Optional

import numpy as np


class Scheduler(abc.ABC):
    """Base class; concrete schedulers implement ``select``."""

    name: str = "base"

    def __init__(self, n_workers: int, seed: int = 0):
        self.n_workers = n_workers
        self.workers: List[int] = list(range(n_workers))
        self.rng = random.Random(seed)
        # Scheduler-view active connections per worker (LC fallback et al.).
        # Managed via the callbacks; total_conns mirrors the sum over live
        # workers so bounded-load baselines avoid an O(workers) sum per
        # request.
        self.conns: Dict[int, int] = {w: 0 for w in self.workers}
        self.total_conns = 0
        # Dense mirror of ``conns`` for the least-connections scan: C-speed
        # argmin over 100s of workers instead of a Python listcomp.  Only
        # valid while worker ids are ascending (so id order == workers-list
        # order and the tie set comes out in the seed engine's order);
        # otherwise _least_connections falls back to the exact scan.
        self._conns_arr = np.zeros(max(n_workers, 1), np.int64)
        self._live_ids: Optional[np.ndarray] = None  # rebuilt lazily
        self._ids_ascending = True

    # ------------------------------------------------------------------ API
    @abc.abstractmethod
    def select(self, func: str) -> int:
        """Pick a worker for a request of function type ``func``."""

    def schedule(self, func: str) -> int:
        w = self.select(func)
        self.on_assign(w, func)
        return w

    # ------------------------------------------------------------ callbacks
    def on_assign(self, worker: int, func: str) -> None:
        new = self.conns.get(worker, 0) + 1
        self.conns[worker] = new
        self.total_conns += 1
        if worker < len(self._conns_arr):
            self._conns_arr[worker] = new

    def _release(self, worker: int) -> int:
        """Clamped connection decrement + total/dense-mirror bookkeeping.

        Shared by on_finish/on_cancel (HikuScheduler.on_finish inlines the
        same sequence for hot-path speed — keep them in sync).
        """
        old = self.conns.get(worker, 0)
        new = old - 1 if old > 0 else 0
        self.conns[worker] = new
        self.total_conns += new - old
        if worker < len(self._conns_arr):
            self._conns_arr[worker] = new
        return new

    def on_finish(self, worker: int, func: str) -> None:
        self._release(worker)

    def on_cancel(self, worker: int, func: str) -> None:
        """Undo an assignment that never executed (failure race).

        Unlike ``on_finish`` this must NOT signal idle capacity (no pull
        enqueue in Hiku) — it only releases the connection count.
        """
        self._release(worker)

    def on_evict(self, worker: int, func: str) -> None:  # noqa: B027
        """Sandbox-destruction notification; default: ignored."""

    def on_worker_added(self, worker: int) -> None:
        if worker not in self.conns:
            if self.workers and worker < self.workers[-1]:
                self._ids_ascending = False  # id order != list order
            self.workers.append(worker)
            self.conns[worker] = 0
            self.n_workers = len(self.workers)
            if worker >= len(self._conns_arr):
                grown = np.zeros(max(worker + 1, 2 * len(self._conns_arr)), np.int64)
                grown[: len(self._conns_arr)] = self._conns_arr
                self._conns_arr = grown
            self._conns_arr[worker] = 0
            self._live_ids = None

    def on_worker_removed(self, worker: int) -> None:
        if worker in self.conns:
            self.workers.remove(worker)
            self.total_conns -= self.conns.pop(worker)
            self.n_workers = len(self.workers)
            self._live_ids = None

    # ------------------------------------------------------------- helpers
    def _least_connections(self) -> int:
        """Least-connections with random tie-breaking (Algorithm 1 l.8-10).

        Vectorized over the dense conns mirror; the tie set, its order (the
        ascending workers list) and the single ``rng.choice`` consumption are
        identical to a full Python scan, which remains as the fallback for
        non-ascending worker ids.
        """
        if not self._ids_ascending:
            conns = self.conns
            cs = [conns[w] for w in self.workers]
            lmin = min(cs)
            tied = [w for w, c in zip(self.workers, cs) if c == lmin]
            return self.rng.choice(tied)
        ids = self._live_ids
        if ids is None:
            ids = self._live_ids = np.array(self.workers, np.int64)
        sub = self._conns_arr[ids]
        tied = ids[sub == sub.min()]
        return int(self.rng.choice(tied))


# Registry -----------------------------------------------------------------
_REGISTRY: Dict[str, Callable[..., Scheduler]] = {}


def register(name: str):
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def make_scheduler(name: str, n_workers: int, seed: int = 0, **kw) -> Scheduler:
    """Instantiate a registered scheduler by name (``"hiku"``, ``"ch_bl"``,
    ``"least_connections"``, ``"random"``, ...).

    ``seed`` feeds the scheduler's private tie-break RNG only — workload
    randomness lives in the simulator — and is part of the replay identity
    the equivalence suite pins.  Extra kwargs go to the concrete class
    (e.g. ``fallback=`` for hiku, ``threshold=`` for CH-BL)."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown scheduler {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](n_workers, seed=seed, **kw)


def available_schedulers() -> List[str]:
    """Sorted names accepted by :func:`make_scheduler`."""
    return sorted(_REGISTRY)
