"""Sharded multi-cluster simulation driver (the ROADMAP's scale-out step).

Partitions a large cluster and its virtual-user population into ``K``
independent shards — each a self-contained ``Simulator`` with its own seed
stream, worker pool, function population, and scheduler instance (serverless
scheduling as job scheduling across independent pools, per NOAH; core-granular
multi-cluster scheduling at datacenter scale, per Kaffes et al.) — runs them
on one of three backends, and merges the per-shard record streams into one
columnar store (``core.records``).

Contracts (pinned by tests/test_shard.py, tests/test_invariants.py, and the
frozen-seed-engine checks in tests/test_equivalence.py):

* **Per-shard exactness** — a shard's ``RequestRecord`` stream is
  byte-identical to a monolithic run of that shard's slice through the plain
  engine (and therefore to the frozen seed engine), on every backend.
* **Seeding contract** — shard ``k`` of a driver seeded with ``seed`` runs
  with ``shard_seed(seed, k) = (seed + 0x9E3779B1 * k) mod 2**32``: a
  golden-ratio uint32 stride keeps shard streams disjoint while staying in
  the single-word-entropy range the vectorized service RNG covers.
* **Partition contract** — workers and VUs split largest-remainder evenly
  (sizes differ by at most one); shard ``k`` owns the contiguous global id
  ranges starting at its prefix-sum offsets.
* **Merge semantics** — shard-local worker/VU ids are remapped by the shard
  offsets into disjoint global ranges, then streams are stable-merged by
  completion time (ties broken by shard index), matching the completion
  order a monolithic engine emits.  Aggregate metrics come out of one
  vectorized pass over the merged columns.
* **Stream semantics** — ``run_stream`` emits the same merge incrementally
  as completed ``StreamChunk`` windows (heap-merge frontier: a record is
  emitted once no shard can still produce an earlier completion);
  concatenated chunks are byte-identical to the batch merge on every
  backend and for any window width (tests/test_stream.py).

Backends:

* ``process`` — fork-based process pool, one shard per core; shard columns
  travel back through parent-named ``multiprocessing.shared_memory``
  segments (one memcpy per section, a few hundred bytes of pickled
  metadata per shard) with deterministic close/unlink teardown in the
  driver — set ``REPRO_SHARD_TRANSPORT=pickle`` to fall back to shipping
  the column buffers over the pool's pickle channel.
* ``interleaved`` — cooperative round-robin of ``Simulator.run_iter``
  generators in a single process (deterministic, no IPC; the fallback where
  fork is unavailable).
* ``serial`` — one shard after another (the K=1 degenerate case).

``aggregate_events_per_s`` is the scale-out capacity metric: the sum of
per-shard event rates, each shard measured on its own wall clock — what K
independent clusters report in aggregate.  The makespan-based rate
(``n_events / wall_s``) is additionally bounded by the local core count.
"""

from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import time
import warnings
from collections import deque
from concurrent.futures import ProcessPoolExecutor
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from bisect import bisect_right

from .metrics import RunMetrics, summarize
from .records import (
    RecordColumns,
    read_columns_shm,
    unlink_columns_shm,
    write_columns_shm,
)
from .scheduler import make_scheduler
from .simulator import SimConfig, Simulator
from .trace import VUProgram

__all__ = [
    "SEED_STRIDE",
    "MergedRun",
    "ShardResult",
    "ShardSpec",
    "ShardedSimulator",
    "StreamChunk",
    "build_simulator",
    "merge_shard_results",
    "run_shard",
    "shard_seed",
    "split_even",
]

SEED_STRIDE = 0x9E3779B1  # golden-ratio uint32 stride (per-shard seed contract)


def shard_seed(seed: int, index: int) -> int:
    """Per-shard base seed (documented contract; see module docstring)."""
    return (int(seed) + SEED_STRIDE * int(index)) % (2**32)


def split_even(total: int, parts: int) -> List[int]:
    """Largest-remainder partition: sizes differ by at most 1, sum == total."""
    base, rem = divmod(int(total), int(parts))
    return [base + (1 if i < rem else 0) for i in range(parts)]


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Everything needed to replay one shard deterministically (picklable).

    ``programs`` is None for the default self-generated workload (the shard
    derives its VU programs from its own seed); when set, it carries this
    shard's contiguous slice of an explicit global VU population — the
    trace-driven path benchmarks use to build cross-shard skew the static
    partition cannot balance."""

    index: int
    n_shards: int
    scheduler: str
    seed: int
    n_vus: int
    duration_s: float
    cfg: SimConfig  # n_workers already set to this shard's share
    worker_offset: int  # global id base for this shard's workers
    vu_offset: int  # global id base for this shard's VUs
    failures: Tuple[Tuple[float, int], ...] = ()  # (t, local worker id)
    additions: Tuple[Tuple[float, int], ...] = ()  # (t, local worker id)
    programs: Optional[Tuple[VUProgram, ...]] = None  # explicit VU slice
    #: shared-memory segment this shard ships its columns through (set by the
    #: process-pool driver only; None everywhere else, keeping spec equality
    #: and pickles from older captures intact)
    shm_name: Optional[str] = None


@dataclasses.dataclass
class ShardResult:
    """One shard's output: columnar stream with *shard-local* ids (the exact
    byte-identical replay of that slice) plus its throughput accounting.

    ``resubmits``/``lost_tasks`` surface the engine's failure-retry
    counters (docs/ARCHITECTURE.md §10): retry pushes after a worker died
    mid-request, and requests dropped once ``SimConfig.retry_budget`` ran
    out.  Both stay 0 on a failure-free replay."""

    spec: ShardSpec
    records: RecordColumns
    assign_t: np.ndarray
    assign_w: np.ndarray
    n_events: int
    wall_s: float
    resubmits: int = 0
    lost_tasks: int = 0


def build_simulator(spec: ShardSpec) -> Simulator:
    """Construct the shard's scheduler + simulator exactly as specced."""
    sched = make_scheduler(spec.scheduler, spec.cfg.n_workers, seed=spec.seed)
    sim = Simulator(sched, cfg=spec.cfg, seed=spec.seed)
    for t, w in spec.failures:
        sim.inject_failure(t, w)
    for t, w in spec.additions:
        sim.inject_worker(t, w)
    return sim


def _result_from(spec: ShardSpec, sim: Simulator, wall_s: float) -> ShardResult:
    at, aw = sim.assignment_columns
    return ShardResult(
        spec=spec,
        records=sim.record_columns,
        assign_t=at,
        assign_w=aw,
        n_events=sim.n_events,
        wall_s=wall_s,
        resubmits=sim.resubmits,
        lost_tasks=sim.lost_tasks,
    )


def run_shard(spec: ShardSpec) -> ShardResult:
    """Run one shard to completion (the in-process / pickle-transport entry).

    Drains ``run_iter`` directly so no per-record Python objects are ever
    materialized — results stay columnar end to end.
    """
    sim = build_simulator(spec)
    programs = list(spec.programs) if spec.programs is not None else None
    t0 = time.perf_counter()
    for _ in sim.run_iter(n_vus=spec.n_vus, duration_s=spec.duration_s, programs=programs):
        pass
    return _result_from(spec, sim, time.perf_counter() - t0)


#: set to ``pickle`` to ship shard results through the pool's pickle channel
#: instead of shared-memory segments (debugging / exotic platforms)
TRANSPORT_ENV = "REPRO_SHARD_TRANSPORT"

#: every segment the pool driver names starts with this (leak checks key on it)
SHM_PREFIX = "repro-shm-"


@dataclasses.dataclass
class _ShardShipment:
    """What a shard child sends back over the pool's pickle channel when the
    columns travel through shared memory: segment metadata plus the scalar
    counters — a few hundred bytes regardless of run size."""

    index: int
    shm_name: Optional[str]  # None when the shard produced zero rows
    n_rec: int
    n_asg: int
    n_events: int
    wall_s: float
    resubmits: int
    lost_tasks: int


def _run_shard_shipped(spec: ShardSpec) -> _ShardShipment:
    """Pool entry for the shared-memory transport: run the shard, write its
    columns into the parent-named segment, return only the metadata.

    The timed window covers the event loop exactly as ``run_shard``'s does;
    the segment write happens after the clock stops, so per-shard
    ``wall_s`` (and ``aggregate_events_per_s``) measure the same thing on
    both transports."""
    sim = build_simulator(spec)
    programs = list(spec.programs) if spec.programs is not None else None
    t0 = time.perf_counter()
    for _ in sim.run_iter(n_vus=spec.n_vus, duration_s=spec.duration_s, programs=programs):
        pass
    wall = time.perf_counter() - t0
    cols = sim.record_columns
    at, aw = sim.assignment_columns
    name = write_columns_shm(spec.shm_name, cols, at, aw)
    return _ShardShipment(
        index=spec.index,
        shm_name=name,
        n_rec=len(cols),
        n_asg=len(at),
        n_events=sim.n_events,
        wall_s=wall,
        resubmits=sim.resubmits,
        lost_tasks=sim.lost_tasks,
    )


@dataclasses.dataclass
class MergedRun:
    """K shard results merged into one global columnar stream."""

    shards: List[ShardResult]
    records: RecordColumns  # global ids, stable-merged by completion time
    assign_t: np.ndarray  # global assignment trace, stable-merged by time
    assign_w: np.ndarray
    workers: List[int]  # global ids of the statically partitioned workers
    n_events: int
    wall_s: float  # end-to-end makespan including backend overhead

    @property
    def events_per_s(self) -> float:
        """Makespan throughput: bounded by local cores running the backends."""
        return self.n_events / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def aggregate_events_per_s(self) -> float:
        """Scale-out capacity: sum of per-shard rates on their own clocks."""
        return float(sum(r.n_events / r.wall_s for r in self.shards if r.wall_s > 0))

    def summarize(self, duration_s: float) -> RunMetrics:
        return summarize(
            self.records,
            (self.assign_t, self.assign_w),
            self.workers,
            duration_s,
            resubmits=sum(r.resubmits for r in self.shards),
            lost_tasks=sum(r.lost_tasks for r in self.shards),
        )


def merge_assignments(
    ats: Sequence[np.ndarray], aws: Sequence[np.ndarray]
) -> Tuple[np.ndarray, np.ndarray]:
    """Stable-merge per-shard assignment traces by time (shard-order concat +
    stable sort — the merge contract's tie-break, shared by the batch merge,
    the streaming merge, and the admission tier).  ``aws`` entries must
    already carry global worker ids."""
    if not ats:
        return np.zeros(0), np.zeros(0, np.int64)
    at = np.concatenate([np.asarray(a, np.float64) for a in ats])
    aw = np.concatenate([np.asarray(w, np.int64) for w in aws])
    order = np.argsort(at, kind="stable")
    return at[order], aw[order]


def merge_shard_results(results: Sequence[ShardResult], wall_s: float) -> MergedRun:
    """Remap shard-local ids to global ranges and stable-merge by time."""
    results = sorted(results, key=lambda r: r.spec.index)
    parts = [
        r.records.remap(worker_offset=r.spec.worker_offset, vu_offset=r.spec.vu_offset)
        for r in results
    ]
    records = merge_window(parts)
    at, aw = merge_assignments(
        [r.assign_t for r in results],
        [r.assign_w + r.spec.worker_offset for r in results],
    )
    workers = [
        r.spec.worker_offset + i for r in results for i in range(r.spec.cfg.n_workers)
    ]
    return MergedRun(
        shards=list(results),
        records=records,
        assign_t=at,
        assign_w=aw,
        workers=workers,
        n_events=sum(r.n_events for r in results),
        wall_s=wall_s,
    )


# ------------------------------------------------------------ streaming merge
@dataclasses.dataclass
class StreamChunk:
    """One completed window of a streaming K-shard merge.

    ``records`` holds the window's globally-id-remapped records in exactly
    the batch-merge order (stable by completion time, ties broken by shard
    index); concatenating every chunk of a stream reproduces
    ``MergedRun.records`` byte-for-byte.  Windows are
    ``t_lo < t_done <= t_hi`` (the first window also includes the stream
    start), with record times bucketed by ``t_done`` and assignments by
    assignment time.
    """

    index: int  # window number, 0-based
    t_lo: float
    t_hi: float
    records: RecordColumns  # global ids, merged by (t_done, shard)
    assign_t: np.ndarray
    assign_w: np.ndarray
    shard_counts: np.ndarray  # records per shard in this window (live load view)


class _StreamCursor:
    """Incremental reader over one shard's (possibly still growing) stream.

    Works over python lists (a live simulator's accumulator, via bisect) and
    numpy arrays (a completed shard's columns, same bisection protocol)
    alike; both are ascending in ``t_done`` / assignment time because the
    engine appends in event order."""

    __slots__ = ("td", "cols", "at", "aw", "ri", "ai")

    def __init__(self, td, cols, at, aw):
        self.td = td  # t_done sequence, ascending
        self.cols = cols  # 7-tuple of parallel column sequences
        self.at = at  # assignment times, ascending
        self.aw = aw
        self.ri = 0
        self.ai = 0

    def take_records(self, t_hi: float) -> RecordColumns:
        j = bisect_right(self.td, t_hi, self.ri)
        out = RecordColumns(*(c[self.ri : j] for c in self.cols))
        self.ri = j
        return out

    def take_assignments(self, t_hi: float) -> Tuple[np.ndarray, np.ndarray]:
        j = bisect_right(self.at, t_hi, self.ai)
        at = np.asarray(self.at[self.ai : j], np.float64)
        aw = np.asarray(self.aw[self.ai : j], np.int64)
        self.ai = j
        return at, aw

    @property
    def drained(self) -> bool:
        return self.ri >= len(self.td) and self.ai >= len(self.at)


def _cursor_for_result(res: ShardResult) -> _StreamCursor:
    c = res.records
    return _StreamCursor(
        c.t_done, (c.t_submit, c.t_done, c.func, c.worker, c.cold, c.vu, c.migrated),
        res.assign_t, res.assign_w,
    )


def _cursor_for_sim(sim: Simulator) -> _StreamCursor:
    acc = sim._rec
    return _StreamCursor(
        acc.t_done,
        (acc.t_submit, acc.t_done, acc.func, acc.worker, acc.cold, acc.vu, acc.migrated),
        sim._asg_t, sim._asg_w,
    )


def merge_window(parts: Sequence[RecordColumns]) -> RecordColumns:
    """Stable-merge already-remapped per-shard window segments by completion
    time — the same ``concat`` + stable argsort the batch merge applies, so
    a window of the stream equals the corresponding slice of the batch-merged
    stream."""
    cat = RecordColumns.concat(parts)
    if len(cat):
        cat = cat.take(np.argsort(cat.t_done, kind="stable"))
    return cat


def _stream_windows(
    specs: Sequence[ShardSpec],
    cursors: Sequence[_StreamCursor],
    duration_s: float,
    window_s: float,
    advance=None,
) -> "Iterator[StreamChunk]":
    """Yield merged windows until the run is over and every cursor drains.

    ``advance(t_hi)`` (live mode) steps each shard's event loop to the
    window boundary before the take, so a record can only be read once no
    shard can still produce an earlier completion — the heap-merge safety
    frontier."""
    if window_s <= 0:
        raise ValueError("window_s must be > 0")
    i = 0
    while True:
        t_lo = i * window_s
        t_hi = (i + 1) * window_s
        if advance is not None:
            advance(t_hi)
        parts, counts, ats, aws = [], [], [], []
        for spec, cur in zip(specs, cursors):
            p = cur.take_records(t_hi).remap(
                worker_offset=spec.worker_offset, vu_offset=spec.vu_offset
            )
            parts.append(p)
            counts.append(len(p))
            at, aw = cur.take_assignments(t_hi)
            ats.append(at)
            aws.append(aw + spec.worker_offset)
        records = merge_window(parts)
        at, aw = merge_assignments(ats, aws)
        yield StreamChunk(
            index=i,
            t_lo=t_lo,
            t_hi=t_hi,
            records=records,
            assign_t=at,
            assign_w=aw,
            shard_counts=np.asarray(counts, np.int64),
        )
        i += 1
        if t_hi >= duration_s and all(c.drained for c in cursors):
            return


def _publish_chunks(chunks, bus, n_shards: int):
    """Publish each chunk's window summary before yielding it (the
    ``run_stream(bus=...)`` path).  Payloads derive from the chunk alone —
    the same values on every backend — and follow the §14 publish order:
    shard topics in ascending shard index, then the cluster topic."""
    from .eventplane import CLUSTER_TOPIC, SHARD_TOPIC

    for ch in chunks:
        for k in range(n_shards):
            bus.publish(
                (SHARD_TOPIC, k), ch.index, ch.t_lo, ch.t_hi,
                {"n_done": int(ch.shard_counts[k])},
            )
        bus.publish(
            (CLUSTER_TOPIC,), ch.index, ch.t_lo, ch.t_hi,
            {"n_done": len(ch.records), "n_assign": int(len(ch.assign_t))},
        )
        yield ch


def _run_process_pool(
    specs: Sequence[ShardSpec], max_workers: Optional[int] = None
) -> List[ShardResult]:
    # fork is the only start method that doesn't re-pay the jax import in
    # every child; shard children are pure numpy/heapq and never enter XLA,
    # so jax's blanket fork-deadlock warning doesn't apply — suppress just
    # that warning at the fork site.  REPRO_SHARD_START_METHOD overrides
    # (spawn/forkserver) for environments where fork is not viable.
    start = os.environ.get("REPRO_SHARD_START_METHOD") or (
        "fork" if "fork" in mp.get_all_start_methods() else "spawn"
    )
    ctx = mp.get_context(start)
    max_workers = max_workers or min(len(specs), os.cpu_count() or 1)
    use_shm = os.environ.get(TRANSPORT_ENV, "shm").strip().lower() != "pickle"
    with warnings.catch_warnings():
        if start == "fork":
            warnings.filterwarnings(
                "ignore", message=r"os\.fork\(\) was called", category=RuntimeWarning
            )
        with ProcessPoolExecutor(max_workers=max_workers, mp_context=ctx) as pool:
            if not use_shm:
                return list(pool.map(run_shard, specs))
            # parent names every segment up front: whatever happens in the
            # children (including a crash mid-write), the finally below can
            # find and unlink each one — deterministic teardown, no orphans
            token = f"{SHM_PREFIX}{os.getpid()}-{os.urandom(4).hex()}"
            named = [
                dataclasses.replace(s, shm_name=f"{token}-{s.index}") for s in specs
            ]
            try:
                shipments = list(pool.map(_run_shard_shipped, named))
                results = []
                for spec, ship in zip(specs, shipments):
                    if ship.shm_name is None:  # zero-row shard: no segment
                        cols = RecordColumns.empty()
                        at = np.zeros(0, np.float64)
                        aw = np.zeros(0, np.int64)
                    else:
                        cols, at, aw = read_columns_shm(
                            ship.shm_name, ship.n_rec, ship.n_asg
                        )
                    results.append(
                        ShardResult(
                            spec=spec,  # the caller's spec: shm_name stays None
                            records=cols,
                            assign_t=at,
                            assign_w=aw,
                            n_events=ship.n_events,
                            wall_s=ship.wall_s,
                            resubmits=ship.resubmits,
                            lost_tasks=ship.lost_tasks,
                        )
                    )
                return results
            finally:
                for s in named:
                    unlink_columns_shm(s.shm_name)


def _run_interleaved(
    specs: Sequence[ShardSpec], yield_every: int = 2048
) -> List[ShardResult]:
    """Round-robin the shard event loops cooperatively in this process."""
    sims = [build_simulator(spec) for spec in specs]
    walls = [0.0] * len(specs)
    ready = deque(
        (i, sim.run_iter(n_vus=spec.n_vus, duration_s=spec.duration_s,
                         programs=list(spec.programs) if spec.programs is not None else None,
                         yield_every=yield_every))
        for i, (spec, sim) in enumerate(zip(specs, sims))
    )
    while ready:
        i, gen = ready.popleft()
        t0 = time.perf_counter()
        try:
            next(gen)
        except StopIteration:
            gen = None
        walls[i] += time.perf_counter() - t0
        if gen is not None:
            ready.append((i, gen))
    return [
        _result_from(spec, sim, walls[i])
        for i, (spec, sim) in enumerate(zip(specs, sims))
    ]


class ShardedSimulator:
    """K independent ``Simulator`` shards behind one ``run()`` call.

    Args:
        n_shards: shard (independent cluster) count, >= 1.
        n_workers: total workers, split largest-remainder evenly; shard
            ``k`` owns the contiguous global id range starting at its
            prefix-sum offset (partition contract).
        scheduler: per-shard scheduler name (each shard gets its own
            instance via ``make_scheduler``).
        cfg: per-shard :class:`SimConfig` template; ``n_workers`` is
            rewritten per shard, every other knob is shared.
        seed: driver seed; shard ``k`` runs with ``shard_seed(seed, k)``
            (golden-ratio stride, see module docstring — the seeding
            contract).
        backend: ``"process"`` / ``"interleaved"`` / ``"serial"`` /
            ``"auto"``; all backends produce identical per-shard streams.

    Elasticity and fault injection stay per-shard (each shard is an
    independent cluster): ``inject_failure`` and ``inject_worker`` both take
    a *global* worker id and map it onto the owning shard via the static
    partition.  Because global ids live inside a
    shard's static span by construction, elastic joins are re-joins of
    failed workers — ids beyond the partition would remap into the *next*
    shard's global range after the merge, so they are rejected.
    """

    def __init__(
        self,
        n_shards: int,
        n_workers: int,
        scheduler: str = "hiku",
        cfg: Optional[SimConfig] = None,
        seed: int = 0,
        backend: str = "auto",
    ):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if n_workers < n_shards:
            raise ValueError("need at least one worker per shard")
        if backend not in ("auto", "serial", "interleaved", "process"):
            raise ValueError(f"unknown backend {backend!r}")
        self.n_shards = int(n_shards)
        self.n_workers = int(n_workers)
        self.scheduler = scheduler
        self.cfg = cfg or SimConfig()
        self.seed = int(seed)
        self.backend = backend
        self._failures: List[Tuple[int, float, int]] = []  # (shard, t, local id)
        self._additions: List[Tuple[int, float, int]] = []
        self.worker_split = split_even(self.n_workers, self.n_shards)
        self.worker_offsets = [0]
        for n in self.worker_split:
            self.worker_offsets.append(self.worker_offsets[-1] + n)

    # ------------------------------------------------------------ topology
    def shard_of_worker(self, worker: int) -> Tuple[int, int]:
        """Global worker id -> (shard index, shard-local worker id)."""
        for k in range(self.n_shards):
            lo, hi = self.worker_offsets[k], self.worker_offsets[k + 1]
            if lo <= worker < hi:
                return k, worker - lo
        raise ValueError(f"worker {worker} outside the static partition")

    def inject_failure(self, t: float, worker: int) -> None:
        """Schedule a worker failure at time ``t`` by *global* worker id."""
        k, local = self.shard_of_worker(worker)
        self._failures.append((k, t, local))

    def inject_worker(self, t: float, worker: int) -> None:
        """Schedule an (elastic re-)join at time ``t`` by *global* worker id.

        Unified with :meth:`inject_failure`: the global id resolves to
        ``(owning shard, local id)`` through the static partition, so
        ``inject_failure(t1, w)`` + ``inject_worker(t2, w)`` round-trips the
        same physical worker.  Ids outside the partition are rejected
        because the merge remap only covers the static spans.  (The
        pre-unification ``inject_worker(t, local_id, shard=k)`` form,
        deprecated since PR 4, has been removed.)
        """
        k, local = self.shard_of_worker(worker)
        self._additions.append((k, t, local))

    # ---------------------------------------------------------------- plan
    def plan(
        self,
        n_vus: int,
        duration_s: float,
        programs: Optional[Sequence[VUProgram]] = None,
    ) -> List[ShardSpec]:
        """The deterministic per-shard specs a run() with these args uses.

        With ``programs`` (an explicit global VU population, len ==
        ``n_vus``) each shard receives its *contiguous* slice — global VU
        ``vu_offset + i`` is shard-local VU ``i`` — which is exactly the
        static partitioning the pull-based admission tier
        (``core.admission``) is benchmarked against."""
        if programs is not None and len(programs) != n_vus:
            raise ValueError(f"len(programs)={len(programs)} != n_vus={n_vus}")
        vu_split = split_even(n_vus, self.n_shards)
        vu_off = 0
        specs = []
        for k in range(self.n_shards):
            specs.append(
                ShardSpec(
                    index=k,
                    n_shards=self.n_shards,
                    scheduler=self.scheduler,
                    seed=shard_seed(self.seed, k),
                    n_vus=vu_split[k],
                    duration_s=float(duration_s),
                    cfg=dataclasses.replace(self.cfg, n_workers=self.worker_split[k]),
                    worker_offset=self.worker_offsets[k],
                    vu_offset=vu_off,
                    failures=tuple((t, w) for s, t, w in self._failures if s == k),
                    additions=tuple((t, w) for s, t, w in self._additions if s == k),
                    programs=(
                        tuple(programs[vu_off : vu_off + vu_split[k]])
                        if programs is not None
                        else None
                    ),
                )
            )
            vu_off += vu_split[k]
        return specs

    def _resolve_backend(self) -> str:
        if self.backend != "auto":
            return self.backend
        if self.n_shards == 1:
            return "serial"
        if "fork" in mp.get_all_start_methods() and (os.cpu_count() or 1) > 1:
            return "process"
        return "interleaved"

    # ----------------------------------------------------------------- run
    def run(
        self,
        n_vus: int = 20,
        duration_s: float = 100.0,
        programs: Optional[Sequence[VUProgram]] = None,
    ) -> MergedRun:
        """Run all K shards to completion and batch-merge their streams.

        Args:
            n_vus: global closed-loop VU count, split largest-remainder
                evenly across shards.
            duration_s: simulated experiment length per shard, seconds.
            programs: optional explicit global VU population (see
                :meth:`plan`); default: each shard self-generates from its
                own seed.

        Bound by the merge contract: the returned stream is stable-merged
        by completion time (ties broken by shard index) over byte-exact
        per-shard replays.
        """
        specs = self.plan(n_vus, duration_s, programs)
        backend = self._resolve_backend()
        t0 = time.perf_counter()
        if backend == "process":
            results = _run_process_pool(specs)
        elif backend == "interleaved":
            results = _run_interleaved(specs)
        else:
            results = [run_shard(s) for s in specs]
        return merge_shard_results(results, time.perf_counter() - t0)

    # -------------------------------------------------------------- stream
    def run_stream(
        self,
        n_vus: int = 20,
        duration_s: float = 100.0,
        window_s: float = 1.0,
        programs: Optional[Sequence[VUProgram]] = None,
        bus=None,
    ) -> Iterator[StreamChunk]:
        """Streaming form of :meth:`run`: heap-merge the shard streams into
        completed ``window_s``-wide :class:`StreamChunk` windows.

        Concatenating every chunk's records reproduces the batch
        ``run().records`` byte-for-byte on every backend (pinned by
        tests/test_stream.py).  On the ``interleaved`` backend the shard
        event loops are co-run in simulated-time lockstep and each window is
        emitted as soon as it completes, so windowed metrics
        (``metrics.summarize_window``) observe an *in-flight* sharded run;
        ``serial``/``process`` complete the shards first and then stream the
        identical merge (useful for post-hoc windowing, without the
        in-flight property).

        ``bus`` optionally attaches an :class:`~repro.core.eventplane
        .EventPlane`: before each chunk is yielded, one ``("shard", k)``
        summary per shard (ascending ``k`` — the merge tie-break) and one
        ``("cluster",)`` summary are published for that window.  Payloads
        are pure functions of the chunk, so the published stream is
        byte-identical across backends (tests/test_stream.py) and the bus
        is sealed here, before the loops arm (§14).
        """
        specs = self.plan(n_vus, duration_s, programs)
        backend = self._resolve_backend()
        if bus is not None:
            bus.seal()
        if backend == "interleaved":
            sims = [build_simulator(spec) for spec in specs]
            for spec, sim in zip(specs, sims):
                sim.begin(
                    n_vus=spec.n_vus,
                    duration_s=spec.duration_s,
                    programs=list(spec.programs) if spec.programs is not None else None,
                )
            cursors = [_cursor_for_sim(sim) for sim in sims]

            def advance(t_hi: float) -> None:
                for sim in sims:
                    sim.step_until(t_hi)

            chunks = _stream_windows(specs, cursors, duration_s, window_s, advance)
        else:
            if backend == "process":
                results = _run_process_pool(specs)
            else:
                results = [run_shard(s) for s in specs]
            results = sorted(results, key=lambda r: r.spec.index)
            cursors = [_cursor_for_result(r) for r in results]
            chunks = _stream_windows(specs, cursors, duration_s, window_s)
        if bus is None:
            yield from chunks
        else:
            yield from _publish_chunks(chunks, bus, len(specs))
