"""Discrete-event simulator of a FaaS cluster (reproduces the paper's §V).

Models the OpenLambda deployment of the paper: ``n_workers`` workers, each a
processor-sharing server with ``cores`` vCPUs and a finite sandbox memory
pool, a keep-alive evictor (Figure 2 lifecycle), and closed-loop virtual
users (k6) replaying seeded programs.  Any ``core.Scheduler`` plugs in; the
simulator feeds it the assign/finish/evict callbacks the real control plane
would.

Fidelity notes (recorded per DESIGN.md §2):
* scheduler<->worker notification latency is 0 (LAN RTT in the paper, ~µs);
* each sandbox executes one request at a time (OpenLambda semantics);
* cold start = instance initialization work added to the task (Table I
  cold-warm delta), executed under processor sharing like the paper's VMs;
* per-request service fluctuation is seeded by request identity so every
  scheduler replays identical stochastic demand (paper's fairness device).

Hot-path engineering (PR 1): the event engine is bit-for-bit equivalent to
the seed implementation (tests/test_equivalence.py proves identical
``RequestRecord`` streams against the frozen copy in tests/legacy) but about
an order of magnitude faster at scale:

* service fluctuations are pre-generated in vectorized bands via
  ``trace.service_fluctuations`` (same ``(seed, vu, ev_idx)`` identity, same
  doubles) instead of constructing a ``default_rng`` per request;
* per-function idle lists are kept in ascending ``last_used`` order, so LRU
  eviction inspects one head per function and keep-alive sweeps stop at the
  first unexpired instance instead of rescanning every idle sandbox;
* each worker caches its running-set minimum remaining time, so scheduling
  the next completion no longer rescans all running tasks (processor sharing
  subtracts the same amount from every task, which preserves the minimum);
* the event loop dispatches on integer event kinds with pre-resolved
  function metadata (name/memory/latency arrays) instead of per-event
  getattr + dataclass attribute chases.

Columnar accumulation (PR 2): records and assignments accumulate into
per-column buffers (``core.records``) instead of per-record Python objects.
``Simulator.records`` / ``Simulator.assignments`` remain list views
(materialized lazily, cached) so the legacy API — and the byte-for-byte
equivalence suite against tests/legacy — is unchanged, while
``record_columns`` / ``assignment_columns`` expose the stream as numpy
arrays for vectorized metrics, cheap IPC, and the sharded driver
(``core.shard``).  ``run_iter`` is the generator form of ``run`` used by
the sharded driver's interleaved backend.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .records import RecordAccumulator, RecordColumns, RequestRecord
from .scheduler import Scheduler
from .trace import (
    FunctionSpec,
    VUProgram,
    default_n_events,
    make_functions,
    make_vu_programs,
    service_fluctuations,
)


@dataclasses.dataclass
class SimConfig:
    """Cluster + experiment knobs for one :class:`Simulator` (the paper's §V
    OpenLambda deployment, parameterized).

    Changing any field changes the event stream, so configs are part of the
    replay identity: the byte-for-byte equivalence suite
    (tests/test_equivalence.py) always runs seed and refactored engines with
    the *same* ``SimConfig``.

    Attributes:
        n_workers: worker (OpenLambda node) count.  The sharded driver
            rewrites this per shard via ``dataclasses.replace``.
        cores_per_worker: vCPUs per worker; tasks share them processor-
            sharing style (rate = cores/n_running when oversubscribed).
        mem_pool_mb: sandbox memory pool per worker, MB.  Calibrated with
            ``keep_alive_s`` so the §V protocol lands at the paper's
            operating point (hiku cold rate ~20-30%, baselines 33-60%).
        keep_alive_s: idle-instance keep-alive before the sweeper evicts,
            seconds (Figure 2 lifecycle).
        sweep_every_s: keep-alive sweep period, seconds.
        exec_sigma: lognormal sigma of per-request service fluctuation
            (Figure 5); part of the fluctuation-band cache key.
        overhead_ms: scheduler decision overhead added to every request's
            completion time, milliseconds (the §V overhead experiment).
        retry_delay_s: control-plane resubmit delay after a request is lost
            to a worker failure, seconds — the *base* of the backoff
            schedule: attempt ``i`` (1-based) retries after
            ``min(retry_delay_s * retry_backoff**(i-1), retry_max_delay_s)``.
            Attempt 1 is always exactly ``retry_delay_s``, which is what
            keeps single-retry runs byte-identical to the flat-delay seed
            engine.
        retry_backoff: multiplicative backoff factor per retry attempt
            (>= 1; 1.0 reproduces the seed engine's flat delay exactly).
        retry_max_delay_s: cap on the backoff delay, seconds.
        retry_budget: per-task retry attempts before the request is
            *dropped* and counted in ``Simulator.lost_tasks`` /
            ``RunMetrics.lost_task_rate`` (its closed-loop VU halts).
            ``None`` retries forever — the seed engine's behavior, where a
            task on a fully-dead cluster loops until the deadline.
    """

    n_workers: int = 5
    cores_per_worker: float = 4.0
    # pool/keep-alive calibrated so the §V protocol lands at the paper's
    # operating point (hiku lowest cold rate ~20-30%, baselines 33-60%;
    # see EXPERIMENTS.md §Reproduction for the calibration sweep)
    mem_pool_mb: float = 2048.0
    keep_alive_s: float = 45.0
    sweep_every_s: float = 1.0
    exec_sigma: float = 0.25
    overhead_ms: float = 0.0  # scheduler decision overhead added to latency
    retry_delay_s: float = 0.05  # base resubmit delay after worker failure
    retry_backoff: float = 2.0  # exponential backoff factor per attempt
    retry_max_delay_s: float = 1.0  # backoff cap
    retry_budget: Optional[int] = 8  # attempts before the task counts lost

    def __post_init__(self):
        if self.retry_delay_s <= 0:
            raise ValueError(f"retry_delay_s must be > 0, got {self.retry_delay_s}")
        if self.retry_backoff < 1.0:
            raise ValueError(f"retry_backoff must be >= 1, got {self.retry_backoff}")
        if self.retry_max_delay_s < self.retry_delay_s:
            raise ValueError(
                f"retry_max_delay_s {self.retry_max_delay_s} must be >= "
                f"retry_delay_s {self.retry_delay_s}"
            )
        if self.retry_budget is not None and self.retry_budget < 1:
            raise ValueError(
                f"retry_budget must be >= 1 (or None for unlimited), "
                f"got {self.retry_budget}"
            )


# RequestRecord lives in core.records now; re-exported here for the legacy
# import path (``from repro.core.simulator import RequestRecord``).
__all__ = [
    "BurstDetector",
    "RequestRecord",
    "SalvagedVU",
    "SimConfig",
    "Simulator",
    "StolenTask",
]


class BurstDetector:
    """EWMA + threshold burst detector over near-horizon event density.

    The adaptive half of the fused-dispatch path (``jax_sched
    .sched_many_adaptive``): callers feed it the event density ahead of the
    clock — :meth:`Simulator.heap_density`, or events/s over an incoming
    event window — and it answers with a dispatch chunk size.  A smoothed
    density above a threshold selects that threshold's chunk (largest
    first); below every threshold it falls back to ``base_chunk`` (1 =
    single-event stepping), so sparse streams never pay kernel-launch
    padding for mostly-empty chunks and bursts batch wide.

    The EWMA (``ewma += alpha * (density - ewma)``; first observation
    primes it) makes the choice hysteretic: one quiet window inside a burst
    does not collapse the chunk size, and one spike does not inflate it.
    Pure observer — it never touches event order, so dispatch results are
    bitwise independent of the chunk choice (pinned in
    tests/test_scheduler.py).

    Args:
        alpha: EWMA smoothing factor in (0, 1].
        thresholds: ``((density, chunk), ...)`` sorted descending by
            density; the first row whose density the EWMA meets wins.
        base_chunk: chunk when the EWMA is below every threshold.
    """

    __slots__ = ("alpha", "thresholds", "base_chunk", "ewma", "_primed")

    def __init__(
        self,
        alpha: float = 0.25,
        thresholds: Tuple[Tuple[float, int], ...] = ((4096.0, 4096), (1024.0, 1024), (256.0, 256)),
        base_chunk: int = 1,
    ):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if base_chunk < 1:
            raise ValueError(f"base_chunk must be >= 1, got {base_chunk}")
        rows = tuple((float(d), int(c)) for d, c in thresholds)
        if any(c < 1 for _, c in rows):
            raise ValueError(f"chunk sizes must be >= 1, got {rows}")
        if list(rows) != sorted(rows, reverse=True):
            raise ValueError(f"thresholds must be sorted descending, got {rows}")
        self.alpha = alpha
        self.thresholds = rows
        self.base_chunk = int(base_chunk)
        self.ewma = 0.0
        self._primed = False

    def observe(self, density: float) -> int:
        """Fold one density sample in; return the chunk size to use now."""
        density = float(density)
        if not self._primed:
            self.ewma = density
            self._primed = True
        else:
            self.ewma += self.alpha * (density - self.ewma)
        return self.chunk

    @property
    def chunk(self) -> int:
        """Current chunk choice for the smoothed density (no fold)."""
        for thresh, chunk in self.thresholds:
            if self.ewma >= thresh:
                return chunk
        return self.base_chunk


# integer event kinds; the *push order* (and with it the tie-breaking
# sequence number) is part of the replay contract with the seed engine
_SUBMIT, _COMPLETE, _RESUBMIT, _SWEEP, _FAIL, _ADD = 0, 1, 2, 3, 4, 5


class _Instance:
    __slots__ = ("func", "mem_mb", "last_used")

    def __init__(self, func: int, mem_mb: float, t: float):
        self.func = func
        self.mem_mb = mem_mb
        self.last_used = t


class _Task:
    __slots__ = (
        "func", "vu", "ev_idx", "t_submit", "work_s", "remaining_s", "cold",
        "worker", "migrated", "attempts", "fail_t",
    )

    def __init__(self, func: int, vu: int, ev_idx: int, t_submit: float):
        self.func = func
        self.vu = vu
        self.ev_idx = ev_idx
        self.t_submit = t_submit
        self.work_s = 0.0
        self.remaining_s = 0.0
        self.cold = False
        self.worker = -1
        self.migrated = False  # re-injected by cross-shard work stealing
        self.attempts = 0  # failure retries so far (backoff exponent)
        self.fail_t = -1.0  # first time a failure hit this task (<0: never)


class _Worker:
    """Processor-sharing server with a sandbox memory pool."""

    __slots__ = (
        "wid", "cores", "pool_mb", "running", "idle", "busy_mem_mb", "idle_mem_mb",
        "pending", "last_t", "version", "alive", "_min_rem", "_min_ok", "_sched_t",
    )

    def __init__(self, wid: int, cfg: SimConfig):
        self.wid = wid
        self.cores = cfg.cores_per_worker
        self.pool_mb = cfg.mem_pool_mb
        self.running: List[_Task] = []
        # func -> idle instances in ascending last_used order (append-newest /
        # evict-oldest-first keeps the invariant; see evict_lru)
        self.idle: Dict[int, List[_Instance]] = {}
        self.busy_mem_mb = 0.0
        self.idle_mem_mb = 0.0
        self.pending: List[_Task] = []  # waiting for memory
        self.last_t = 0.0
        self.version = 0  # invalidates stale completion events
        self.alive = True
        # cached min(task.remaining_s) over running; valid while _min_ok.
        # Advancing subtracts the identical dt*rate from every task, which
        # preserves both the argmin and (bitwise) the minimum value.
        self._min_rem = 0.0
        self._min_ok = True
        self._sched_t: Optional[float] = None  # time of the live completion event

    # ---------------------------------------------------------------- PS
    def advance(self, t: float) -> None:
        dt = t - self.last_t
        running = self.running
        if dt > 0 and running:
            n = len(running)
            cores = self.cores
            d = dt if cores >= n else dt * (cores / n)
            for task in running:
                task.remaining_s -= d
            if self._min_ok:
                self._min_rem -= d
        self.last_t = t

    def start(self, task: _Task) -> None:
        self.running.append(task)
        if self._min_ok:
            if len(self.running) == 1 or task.remaining_s < self._min_rem:
                self._min_rem = task.remaining_s

    def next_completion(self, t: float) -> Optional[float]:
        running = self.running
        if not running:
            return None
        if not self._min_ok:
            m = running[0].remaining_s
            for task in running:
                rs = task.remaining_s
                if rs < m:
                    m = rs
            self._min_rem = m
            self._min_ok = True
        m = self._min_rem
        if m <= 0.0:
            m = 0.0
        n = len(running)
        cores = self.cores
        return t + (m if cores >= n else m / (cores / n))

    # ------------------------------------------------------------- memory
    def mem_usage(self) -> float:
        return self.busy_mem_mb + self.idle_mem_mb

    def pop_idle(self, func: int) -> _Instance:
        lst = self.idle[func]
        inst = lst.pop()
        if not lst:
            del self.idle[func]
        self.idle_mem_mb -= inst.mem_mb
        return inst

    def evict_lru(self) -> Optional[_Instance]:
        """Evict the least-recently-used idle instance (force eviction).

        Each per-func list is ascending in ``last_used``, so the global LRU
        is the strictly smallest head across funcs — first such func in dict
        order, exactly the instance the seed engine's full scan selected.
        """
        best_func = -1
        best_last = None
        for func, lst in self.idle.items():
            h = lst[0].last_used
            if best_last is None or h < best_last:
                best_last = h
                best_func = func
        if best_last is None:
            return None
        lst = self.idle[best_func]
        inst = lst.pop(0)
        if not lst:
            del self.idle[best_func]
        self.idle_mem_mb -= inst.mem_mb
        return inst


# Shared fluctuation bands: (seed, n_vus, sigma) -> {"cols": int, "rows":
# list-of-lists, "pending": set-of-row-indices}.  Rows are grown in place, so
# the 4-scheduler benchmark matrix pays for each (seed, vu, ev) draw once, not
# once per scheduler.  "pending" rows were appended empty by ``admit_vu`` and
# are filled lazily in batch (``_flush_fluct``) — deterministic regardless of
# which sharing simulator flushes, because every row's fill is a pure function
# of its (seed, vu) identity and the shared cache key fixes the seed.
_FLUCT_CACHE: Dict[Tuple[int, int, float], Dict] = {}


@dataclasses.dataclass(frozen=True)
class StolenTask:
    """One queued task exported by :meth:`Simulator.steal_queued` — the unit
    of cross-shard work stealing (see ``core.stealing``).

    Everything a destination shard needs to replay the request — and the
    migrated VU's whole future — bit-exactly travels with the task:

    * ``func``/``ev_idx``/``t_submit`` — the request itself; ``t_submit`` is
      the *original* submission time, so its latency keeps the queueing delay
      accrued on the victim shard plus the migration wait.
    * ``origin_seed``/``origin_vu`` — the service-fluctuation identity of the
      VU's *first* binding.  All of the VU's draws — this request and every
      later one — stay ``default_rng((origin_seed, origin_vu, ev))`` no
      matter how many times it migrates (the paper's fairness device is
      invariant under migration).
    * ``fluct_row`` — the draws materialized so far (destination fills any
      gap from the identity, bit-exact either way).
    * ``program``/``prog_funcs``/``prog_sleeps``/``next_pos`` — the closed
      loop: the VU resumes its program on the destination at ``next_pos``.
    * ``src_vu`` — the victim-shard-local VU id at steal time (coordinator
      bookkeeping: maps to the global id through the admission table).
    * ``attempts``/``fail_t`` — the task's failure-retry history (backoff
      exponent and first-failure time), carried so a salvaged task's
      recovery latency is charged on the shard that finally completes it.
    """

    func: int
    ev_idx: int
    t_submit: float
    origin_seed: int
    origin_vu: int
    fluct_row: List[float]
    program: VUProgram
    prog_funcs: List[int]
    prog_sleeps: List[float]
    next_pos: int
    src_vu: int
    attempts: int = 0
    fail_t: float = -1.0


@dataclasses.dataclass(frozen=True)
class SalvagedVU:
    """One live VU exported off a *dead* shard by
    :meth:`Simulator.salvage_queued` — the unit of dead-shard drain
    (``core.stealing.drain_tick``).

    ``stolen`` reuses the :class:`StolenTask` migration identity (program,
    resume position, bit-exact service-fluctuation identity), so re-homing a
    salvaged VU replays the same draws as a work-stealing migration would.
    ``in_flight`` distinguishes the two VU states a dead shard can hold:

    * ``True`` — the VU's single outstanding request was waiting for retry
      (a ``_RESUBMIT`` event); the receiver re-dispatches it immediately and
      the completion is flagged ``migrated``.
    * ``False`` — the VU was mid-think (a scheduled ``_SUBMIT``); the
      receiver resumes its program at ``resume_t`` (clamped to its clock),
      and ``stolen.func``/``ev_idx`` echo the *next* program position.

    ``resume_t`` is the dead shard's scheduled event time for the VU (the
    retry time or the end-of-think submit time).
    """

    stolen: StolenTask
    in_flight: bool
    resume_t: float


class Simulator:
    """Event-driven FaaS cluster; ``run()`` returns request records + stats.

    Entry points (all drive the ONE event loop, so the byte-for-byte replay
    contract against the frozen seed engine covers each of them):

    * :meth:`run` — batch: drain to the deadline, return the record list.
    * :meth:`run_iter` — cooperative: yields every ``yield_every`` events
      (the sharded driver's ``interleaved`` backend).
    * :meth:`begin` + :meth:`step_until` — externally clocked: the caller
      advances simulated time in slices and may inject arrivals between
      slices via :meth:`admit_vu` (streaming merge / admission tier).

    Args:
        scheduler: any ``core.Scheduler``; fed the assign/finish/evict
            callbacks the real control plane would issue.
        funcs: function population (default: the seeded 40-function
            Azure-like population from ``trace.make_functions``).
        cfg: cluster knobs (:class:`SimConfig`).
        seed: workload seed.  Seeds VU programs *and* the per-request
            service-fluctuation identity ``(seed, vu, ev)``; under the
            sharded driver this is ``shard_seed(seed, k)``.
    """

    def __init__(
        self,
        scheduler: Scheduler,
        funcs: Optional[Sequence[FunctionSpec]] = None,
        cfg: Optional[SimConfig] = None,
        seed: int = 0,
    ):
        self.cfg = cfg or SimConfig()
        self.sched = scheduler
        self.funcs = list(funcs) if funcs is not None else make_functions(seed=seed)
        self.seed = seed
        self.workers = {w: _Worker(w, self.cfg) for w in range(self.cfg.n_workers)}
        # incremental pressure counters: total pending tasks and workers with
        # at least one running task, maintained at every mutation site so
        # pressure() is O(1) instead of an O(workers) scan per call.  The
        # cluster tier polls pressure per shard per tick — at 100k workers
        # the scan was the coordination cost, not the event loop.
        self._queued_n = 0
        self._busy_n = 0
        # dirty-shard publication (core.coord): when a coordinator attaches a
        # sink, any state change that can move pressure / warm state / the
        # dead-or-alive status adds this shard's index to it.  None (the
        # default) costs one truth test on the marking paths and nothing in
        # the event loop itself — static runs are untouched.
        self._dirty_sink: Optional[set] = None
        self._dirty_idx = -1
        self._heap: List[Tuple[float, int, int, tuple]] = []
        self._seq = itertools.count()
        self.t = 0.0
        self._deadline = 0.0  # set by begin()/run_iter()
        # columnar accumulation; .records/.assignments are lazy list views
        self._rec = RecordAccumulator()
        self._rec_append = self._rec.append
        self._rec_list: Optional[List[RequestRecord]] = None
        self._asg_t: List[float] = []
        self._asg_w: List[int] = []
        self._asg_list: Optional[List[Tuple[float, int]]] = None
        self._failures: List[Tuple[float, int]] = []
        self._additions: List[Tuple[float, int]] = []
        self.n_events = 0  # heap events processed (bench_sim_speed)
        # cross-shard work stealing (core.stealing) telemetry + state:
        # _fluct_identity is None until the first foreign (stolen-in) VU
        # arrives; then it maps row index -> (seed, vu) fluctuation identity.
        self.stolen_out = 0
        self.stolen_in = 0
        self._fluct_identity: Optional[List[Tuple[int, int]]] = None
        # failure telemetry (core.chaos / RunMetrics failure columns):
        self.resubmits = 0  # retry pushes after a failure hit a task
        self.lost_tasks = 0  # tasks dropped after exhausting retry_budget
        self.salvaged_out = 0  # VUs exported off this (dead) shard
        self.salvaged_in = 0  # salvaged VUs re-homed onto this shard
        self.recovery_s: List[float] = []  # first-failure -> completion, s
        # advisory preemption notices: (t, worker, until) — see inject_notice
        self._notices: List[Tuple[float, int, float]] = []
        # elastic-pool cost accounting (core.autoscale): piecewise integral
        # of the live worker count over simulated time, accrued at the only
        # two places the count changes (_ev_fail / _ev_add_worker).  Pure
        # bookkeeping — no event is reordered, so byte-identity holds.
        self._ws_acc = 0.0
        self._ws_t = 0.0
        # per-function warm-set digest: func -> idle (warm) instance count
        # across live workers, maintained incrementally at every idle-set
        # mutation (complete / warm reuse / LRU evict / keep-alive sweep /
        # worker death).  Pure bookkeeping on existing transitions — no RNG,
        # no event reordering — so the byte-for-byte replay contract with
        # tests/legacy is untouched.  Read via warm_digest().
        self._warm: Dict[int, int] = {}
        # pre-resolved per-function metadata (hot-loop lookups)
        self._fnames = [f.name for f in self.funcs]
        self._fmem = [f.mem_mb for f in self.funcs]
        self._fcold = [f.cold_ms for f in self.funcs]
        self._fwarm = [f.warm_ms for f in self.funcs]

    # ------------------------------------------------------------ views
    @property
    def records(self) -> List[RequestRecord]:
        """Legacy list-of-``RequestRecord`` view (materialized, cached)."""
        if self._rec_list is None or len(self._rec_list) != len(self._rec):
            self._rec_list = self._rec.to_records()
        return self._rec_list

    @property
    def record_columns(self) -> RecordColumns:
        """The record stream as numpy columns (no per-record objects)."""
        return self._rec.columns()

    @property
    def assignments(self) -> List[Tuple[float, int]]:
        """Legacy ``[(t, worker), ...]`` view (materialized, cached)."""
        if self._asg_list is None or len(self._asg_list) != len(self._asg_t):
            self._asg_list = list(zip(self._asg_t, self._asg_w))
        return self._asg_list

    @property
    def assignment_columns(self) -> Tuple[np.ndarray, np.ndarray]:
        """Assignment trace as ``(t float64[], worker int64[])`` columns."""
        return (
            np.asarray(self._asg_t, np.float64),
            np.asarray(self._asg_w, np.int64),
        )

    # ------------------------------------------------------------- events
    def _push(self, t: float, kind: int, payload: tuple = ()) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    def inject_failure(self, t: float, worker: int) -> None:
        """Schedule worker ``worker`` to fail at time ``t``.

        ``worker`` must be a nonnegative id that exists by time ``t`` —
        either in the initial ``[0, n_workers)`` range or scheduled via
        :meth:`inject_worker`; :meth:`begin` validates the full schedule
        (unknown ids and times past the run deadline raise ``ValueError``
        instead of silently never firing)."""
        if worker < 0:
            raise ValueError(f"inject_failure: worker id must be >= 0, got {worker}")
        if t < 0:
            raise ValueError(f"inject_failure: t must be >= 0, got {t}")
        self._failures.append((t, worker))

    def inject_worker(self, t: float, worker: int) -> None:
        """Schedule a worker with id ``worker`` to join at time ``t``.

        New ids beyond the initial range are the elastic scale-up path;
        re-adding a previously failed id revives it.  :meth:`begin`
        validates ``t`` against the run deadline (see
        :meth:`inject_failure`)."""
        if worker < 0:
            raise ValueError(f"inject_worker: worker id must be >= 0, got {worker}")
        if t < 0:
            raise ValueError(f"inject_worker: t must be >= 0, got {t}")
        self._additions.append((t, worker))

    def inject_notice(self, t: float, worker: int, until: float) -> None:
        """Advisory preemption notice: ``worker`` is scheduled to die at
        ``until`` (spot-preemption semantics; the kill needs its own
        :meth:`inject_failure`).

        While the notice window ``[t, until)`` is open, the worker is
        excluded from the :meth:`warm_capacity` headroom sum and its idle
        instances from the :meth:`warm_digest` counts — capacity about to be
        preempted is not headroom new work should be routed onto.  Purely
        advisory: the event loop, records, and replay identity are
        untouched (a static run with notices stays byte-identical to one
        without).  :meth:`begin` validates ids like :meth:`inject_failure`.
        """
        if worker < 0:
            raise ValueError(f"inject_notice: worker id must be >= 0, got {worker}")
        if t < 0:
            raise ValueError(f"inject_notice: t must be >= 0, got {t}")
        if until < t:
            raise ValueError(f"inject_notice: until={until} precedes t={t}")
        self._notices.append((t, worker, until))

    # ------------------------------------------- mid-run elasticity hooks
    # The inject_* schedule above is pre-run: begin() validates it in one
    # pass and seeds the heap.  The schedule_* forms below are the *live*
    # counterparts for an already-armed loop — the autoscaler actuator
    # (core.autoscale) calls them between step_until() slices.  Each one
    # validates eagerly (begin() has already run) and marks the shard dirty
    # immediately, so the ShardCoordinator contract (§13) covers every
    # autoscaler mutation the same tick it is scheduled.

    def _check_schedule(self, hook: str, t: float, worker: int) -> None:
        if worker < 0:
            raise ValueError(f"{hook}: worker id must be >= 0, got {worker}")
        if t < self.t:
            raise ValueError(
                f"{hook}: t={t} precedes the shard clock {self.t} — events "
                "cannot be scheduled into the past"
            )
        if t > self._deadline:
            raise ValueError(
                f"{hook}: t={t} is past the run deadline {self._deadline} "
                "and would never fire"
            )

    def schedule_worker_add(self, t: float, worker: int) -> None:
        """Mid-run :meth:`inject_worker`: worker ``worker`` (re)joins at
        ``t``.  Requires a prior :meth:`begin`; ``t`` must lie between the
        shard clock and the run deadline."""
        self._check_schedule("schedule_worker_add", t, worker)
        self._push(t, _ADD, (worker,))
        self._mark_dirty()

    def schedule_worker_fail(self, t: float, worker: int) -> None:
        """Mid-run :meth:`inject_failure`: worker ``worker`` dies at ``t``
        (same validation window as :meth:`schedule_worker_add`)."""
        self._check_schedule("schedule_worker_fail", t, worker)
        self._push(t, _FAIL, (worker,))
        self._mark_dirty()

    def schedule_notice(self, t: float, worker: int, until: float) -> None:
        """Mid-run :meth:`inject_notice`: open an advisory preemption
        window ``[t, until)`` on ``worker`` right now.  ``_doomed_now``
        reads the notice list live, so the warm-capacity/digest exclusion
        applies from the moment the window opens."""
        self._check_schedule("schedule_notice", t, worker)
        if until < t:
            raise ValueError(f"schedule_notice: until={until} precedes t={t}")
        self._notices.append((t, worker, until))
        self._mark_dirty()

    # ------------------------------------------------ worker-seconds cost
    def _ws_accrue(self) -> None:
        self._ws_acc += len(self.workers) * (self.t - self._ws_t)
        self._ws_t = self.t

    def worker_seconds_until(self, t: float) -> float:
        """Integral of the live worker count from the run start to ``t`` —
        the provisioned-capacity cost (worker-seconds) an elastic pool is
        scored on (``benchmarks/bench_autoscale.py``).  Non-mutating; a
        static run reads ``n_workers * duration``."""
        return self._ws_acc + len(self.workers) * max(t - self._ws_t, 0.0)

    # ------------------------------------------------------- fluctuations
    def _fluct_entry(self, n_vus: int) -> Dict:
        key = (self.seed, n_vus, self.cfg.exec_sigma)
        entry = _FLUCT_CACHE.get(key)
        if entry is None:
            if len(_FLUCT_CACHE) >= 8:
                _FLUCT_CACHE.clear()
            entry = _FLUCT_CACHE[key] = {
                "cols": 0,
                "rows": [[] for _ in range(n_vus)],
                "pending": set(),
            }
        return entry

    def _fluct_row_identity(self, v: int) -> Tuple[int, int]:
        """Row index -> the (seed, vu) its draws are seeded by.

        Native rows are ``(self.seed, v)``; rows received through work
        stealing keep their origin identity (``_fluct_identity``)."""
        ident = self._fluct_identity
        return ident[v] if ident is not None else (self.seed, v)

    @staticmethod
    def _identity_runs(idxs) -> Iterator[Tuple[int, int, List[int]]]:
        """Group ``(row_index, seed, vu)`` triples into maximal runs of the
        same seed and consecutive vus, so each run fills with ONE vectorized
        ``service_fluctuations`` call (bit-identical to per-row calls by the
        fastrng identity contract)."""
        run: List[int] = []
        run_seed = run_vu0 = 0
        for i, s, v in idxs:
            if run and s == run_seed and v == run_vu0 + len(run):
                run.append(i)
                continue
            if run:
                yield run_seed, run_vu0, run
            run, run_seed, run_vu0 = [i], s, v
        if run:
            yield run_seed, run_vu0, run

    def _flush_fluct(self) -> None:
        """Fill rows ``admit_vu`` appended lazily, batched per identity run.

        Deferring the fill to first use turns the admission tier's one
        kernel invocation *per admitted VU* into one per admission burst
        (same doubles: entry ``[i, j]`` is a pure function of the (seed, vu,
        ev) identity, so batched and per-VU grows are bit-identical)."""
        entry = self._fluct
        pending = entry["pending"]
        if not pending:
            return
        cols = entry["cols"]
        if cols:
            rows = entry["rows"]
            sigma = self.cfg.exec_sigma
            triples = sorted((i, *self._fluct_row_identity(i)) for i in pending)
            for seed, vu0, run in self._identity_runs(triples):
                band = service_fluctuations(seed, len(run), cols, sigma, vu_start=vu0)
                for i, extra in zip(run, band.tolist()):
                    rows[i].extend(extra)
        pending.clear()

    def _extend_fluct(self, upto: int) -> None:
        """Grow the fluctuation band to cover event index ``upto``."""
        self._flush_fluct()
        entry = self._fluct
        cols = entry["cols"]
        new_cols = max(upto + 1, cols * 2, 32)
        sigma = self.cfg.exec_sigma
        rows = entry["rows"]
        if self._fluct_identity is None:
            band = service_fluctuations(self.seed, len(rows), new_cols - cols, sigma, ev_start=cols)
            for row, extra in zip(rows, band.tolist()):
                row.extend(extra)
        else:
            triples = ((i, *self._fluct_identity[i]) for i in range(len(rows)))
            for seed, vu0, run in self._identity_runs(triples):
                band = service_fluctuations(
                    seed, len(run), new_cols - cols, sigma, ev_start=cols, vu_start=vu0
                )
                for i, extra in zip(run, band.tolist()):
                    rows[i].extend(extra)
        entry["cols"] = new_cols

    def _detach_fluct(self) -> None:
        """Give this simulator a private fluctuation table (copy-on-steal).

        Cache entries are shared by (seed, n_vus, sigma); foreign rows from
        stolen-in VUs are *not* a pure function of that key, so the first
        ``receive_task`` detaches from the shared cache before appending.
        With stealing off this never runs and the shared-band fast path is
        untouched."""
        if self._fluct_identity is not None:
            return
        entry = self._fluct
        self._fluct = {
            "cols": entry["cols"],
            "rows": [list(r) for r in entry["rows"]],
            "pending": set(entry["pending"]),
        }
        self._fluct_identity = [(self.seed, v) for v in range(len(entry["rows"]))]

    # --------------------------------------------------------------- run
    def run(
        self,
        n_vus: int = 20,
        duration_s: float = 100.0,
        programs: Optional[List[VUProgram]] = None,
        t_start: float = 0.0,
    ) -> List[RequestRecord]:
        """Run the full experiment and return the legacy record list.

        Args:
            n_vus: closed-loop virtual users (all start at ``t_start``).
            duration_s: simulated experiment length, seconds; events past
                ``t_start + duration_s`` are not processed.
            programs: explicit per-VU programs (len == ``n_vus``); default
                generates the seeded Azure-like workload.
            t_start: simulated start time, seconds.

        Bound by the byte-for-byte replay contract: the returned
        ``RequestRecord`` stream is identical to the frozen seed engine's
        for the same (scheduler, cfg, seed, workload).
        """
        for _ in self.run_iter(n_vus, duration_s, programs, t_start):
            pass
        return self.records

    def begin(
        self,
        n_vus: int = 20,
        duration_s: float = 100.0,
        programs: Optional[List[VUProgram]] = None,
        t_start: float = 0.0,
    ) -> None:
        """Arm the event loop without running it (the backpressure hook).

        Seeds the heap with the initial VU submits, the keep-alive sweep and
        any injected failure/addition events, exactly as :meth:`run_iter`
        does before its first pop.  Afterwards the caller drives the clock
        explicitly with :meth:`step_until` and may feed arrivals in with
        :meth:`admit_vu` — this is how the global admission tier
        (``core.admission``) co-runs K shard simulators in simulated-time
        lockstep.  ``begin(n_vus=0, programs=[])`` arms an *empty* cluster
        that only serves admitted VUs.
        """
        cfg = self.cfg
        if programs is None:
            programs = make_vu_programs(
                self.funcs, n_vus, default_n_events(duration_s), self.seed
            )
        self._programs = list(programs)
        self._prog_funcs = [p.func_idx.tolist() for p in programs]
        self._prog_sleeps = [p.sleep_s.tolist() for p in programs]
        self._vu_pos = [0] * n_vus
        self._deadline = t_start + duration_s
        self._ws_acc = 0.0
        self._ws_t = t_start
        self._fluct_identity = None  # fresh run: all rows native until a steal
        self._fluct = self._fluct_entry(n_vus)
        self._overhead_s = cfg.overhead_ms / 1e3

        # injection-schedule validation: an event past the deadline or a
        # failure of a worker that never exists would silently no-op — loud
        # ValueError instead (the chaos tier builds on these hooks)
        known = set(range(cfg.n_workers)) | {w for _, w in self._additions}
        for t, w in self._failures:
            if w not in known:
                raise ValueError(
                    f"inject_failure({t}, {w}): worker {w} is neither in the "
                    f"initial range [0, {cfg.n_workers}) nor scheduled by "
                    "inject_worker"
                )
            if t > self._deadline:
                raise ValueError(
                    f"inject_failure({t}, {w}): t is past the run deadline "
                    f"{self._deadline} and would never fire"
                )
        for t, w in self._additions:
            if t > self._deadline:
                raise ValueError(
                    f"inject_worker({t}, {w}): t is past the run deadline "
                    f"{self._deadline} and would never fire"
                )
        for t, w, until in self._notices:
            if w not in known:
                raise ValueError(
                    f"inject_notice({t}, {w}, {until}): worker {w} is neither "
                    f"in the initial range [0, {cfg.n_workers}) nor scheduled "
                    "by inject_worker"
                )

        for vu in range(n_vus):
            self._push(t_start, _SUBMIT, (vu,))
        self._push(t_start + cfg.sweep_every_s, _SWEEP)
        for t, w in self._failures:
            self._push(t, _FAIL, (w,))
        for t, w in self._additions:
            self._push(t, _ADD, (w,))

    def _step_event(self, kind: int, payload: tuple) -> None:
        # The one kind->handler dispatch, shared by run_iter and step_until
        # so the two clock forms cannot drift apart.
        if kind == _SUBMIT:
            self._ev_submit(payload[0])
        elif kind == _COMPLETE:
            self._ev_complete(payload[0], payload[1])
        elif kind == _RESUBMIT:
            self._dispatch(payload[0])
        elif kind == _SWEEP:
            self._ev_sweep()
        elif kind == _FAIL:
            self._ev_fail(payload[0])
        else:
            self._ev_add_worker(payload[0])

    def run_iter(
        self,
        n_vus: int = 20,
        duration_s: float = 100.0,
        programs: Optional[List[VUProgram]] = None,
        t_start: float = 0.0,
        yield_every: int = 4096,
    ) -> Iterator[int]:
        """Generator form of :meth:`run`: identical event semantics, but
        yields the running processed-event count every ``yield_every``
        events so multiple simulators can be interleaved cooperatively in
        one process (the sharded driver's ``interleaved`` backend).

        ``run`` is exactly ``drain(run_iter(...))`` — there is ONE event
        loop, so the byte-for-byte replay contract with tests/legacy covers
        both entry points.  (:meth:`begin` + :meth:`step_until` expose the
        same loop under external clock control; the pop/dispatch sequence,
        and therefore the record stream, is identical on every path.)
        """
        self.begin(n_vus, duration_s, programs, t_start)
        heap = self._heap
        pop = heapq.heappop
        step = self._step_event
        deadline = self._deadline
        n = 0
        try:
            while heap:
                t, _, kind, payload = pop(heap)
                if t > deadline:
                    break
                self.t = t
                n += 1
                step(kind, payload)
                if not n % yield_every:
                    yield n
        finally:
            # also runs on GeneratorExit, so a consumer that stops driving
            # the generator early still gets the processed events accounted
            self.n_events += n

    # ----------------------------------------------- stepped clock / admission
    def step_until(self, t_limit: float) -> int:
        """Process every pending event with time <= ``t_limit`` (seconds).

        The stepped form of the :meth:`run_iter` loop: same pop order, same
        handler dispatch, so driving a simulator with a monotone sequence of
        ``step_until`` calls up to the deadline reproduces the exact record
        stream ``run`` emits (events past the deadline are never processed
        on either path).  Requires a prior :meth:`begin`.  Returns the
        number of events processed this call.
        """
        heap = self._heap
        pop = heapq.heappop
        step = self._step_event
        deadline = self._deadline
        bound = t_limit if t_limit < deadline else deadline
        n = 0
        while heap and heap[0][0] <= bound:
            t, _, kind, payload = pop(heap)
            self.t = t
            n += 1
            step(kind, payload)
        self.n_events += n
        if n:
            self._mark_dirty()
        return n

    @property
    def done(self) -> bool:
        """True once no pending event falls inside the deadline."""
        return not self._heap or self._heap[0][0] > self._deadline

    def next_event_time(self) -> float:
        """Time of the earliest pending event (``inf`` on an empty heap).

        The event frontier the cluster tier uses to skip ``step_until`` on
        shards with nothing scheduled inside the tick — an O(1) peek, so an
        idle shard costs one comparison per tick instead of a call."""
        return self._heap[0][0] if self._heap else float("inf")

    def heap_density(self, horizon_s: float = 0.25) -> float:
        """Pending events per second inside the heap's near horizon.

        Counts events within ``horizon_s`` of the earliest pending event —
        the burst signal a :class:`BurstDetector` folds to pick dispatch
        chunk sizes.  One O(heap) pass; meant to be sampled per dispatch
        batch or per tick, never per event."""
        if horizon_s <= 0:
            raise ValueError(f"horizon_s must be > 0, got {horizon_s}")
        heap = self._heap
        if not heap:
            return 0.0
        hi = heap[0][0] + horizon_s
        n = 0
        for ev in heap:
            if ev[0] <= hi:
                n += 1
        return n / horizon_s

    def attach_dirty(self, sink: set, idx: int) -> None:
        """Publish this shard's state changes into ``sink`` as index ``idx``.

        The dirty-shard contract (docs/ARCHITECTURE.md §13): after any event
        processing or external mutation (admit / receive / steal / salvage)
        the shard adds ``idx`` to ``sink``; the coordinator drains the set
        each tick and re-reads only those shards.  Marking may over-approximate
        (a sweep that evicted nothing still marks) — that costs a cached
        re-read, never a stale decision.  Attaching marks immediately so the
        first refresh sees every shard."""
        self._dirty_sink = sink
        self._dirty_idx = idx
        sink.add(idx)

    def _mark_dirty(self) -> None:
        s = self._dirty_sink
        if s is not None:
            s.add(self._dirty_idx)

    def pressure(self) -> float:
        """Local load pressure: queued arrivals per worker + busy fraction.

        ``queued`` counts tasks parked on worker pending queues (admitted
        but waiting for sandbox memory); ``busy`` counts workers with at
        least one running task.  Both are normalized by the live worker
        count, so an idle cluster reads 0.0, a fully busy queue-free
        cluster reads 1.0, and queueing pushes the value above 1.  This is
        the watermark signal the global admission tier polls between
        :meth:`step_until` calls.

        O(1): both counts are maintained incrementally at every mutation
        site (:meth:`_pressure_ref` is the retired scan, kept as the
        invariant oracle for tests).  Same integers, same division — the
        value is bit-identical to the scan's.
        """
        alive = len(self.workers)
        if not alive:
            return float("inf")
        return (self._queued_n + self._busy_n) / alive

    def _pressure_ref(self) -> float:
        """The original O(workers) pressure scan — the oracle the counter
        invariant is pinned against (tests/test_coord.py)."""
        alive = busy = queued = 0
        for w in self.workers.values():
            alive += 1
            if w.running:
                busy += 1
            queued += len(w.pending)
        if not alive:
            return float("inf")
        return (queued + busy) / alive

    def _doomed_now(self) -> set:
        """Live worker ids currently inside a preemption-notice window
        (``tn <= now < until``; see :meth:`inject_notice`).  Empty set —
        and zero overhead beyond one truth test — when no notices exist."""
        if not self._notices:
            return set()
        now = self.t
        return {
            w for tn, w, until in self._notices
            if tn <= now < until and w in self.workers
        }

    def warm_capacity(self) -> float:
        """Fraction of sandbox-pool memory not pinned by running tasks.

        ``(free + idle) / pool`` summed over live workers, in ``[0, 1]``:
        idle memory is warm instances a new request can reuse, free memory
        can host a fresh sandbox without eviction — together they are the
        headroom to place new work without queueing behind the memory pool.
        0.0 for a dead cluster (no live workers).  This is the cold-start
        cost signal admission policies read (``core.policies.CostPolicy``)
        alongside :meth:`pressure`.

        Workers inside an open preemption-notice window
        (:meth:`inject_notice`) are excluded from the sum entirely: their
        pools are capacity about to be preempted, not headroom — counting
        them would route new work onto sandboxes scheduled to die.  A
        cluster whose every live worker is doomed reads 0.0.  The field's
        validity window for policies is documented in docs/POLICIES.md §2.
        """
        doomed = self._doomed_now()
        total = busy = 0.0
        for w in self.workers.values():
            if w.wid in doomed:
                continue
            total += w.pool_mb
            busy += w.busy_mem_mb
        if total <= 0.0:
            return 0.0
        return (total - busy) / total

    def warm_digest(self) -> Dict[int, int]:
        """Per-function warm-set digest: ``{func_index: warm_count}`` over
        live, un-doomed workers — the shard's locality signal.

        ``warm_count`` is the number of idle (keep-alive) instances of the
        function a new request could reuse right now.  The counts are
        maintained incrementally at every idle-set transition (completion
        adds, warm reuse / LRU eviction / keep-alive sweep / worker death
        remove), so the read is O(distinct warm functions) — a dict copy —
        not an O(workers × instances) scan.  Functions with zero warm
        instances are absent, which keeps the digest compact.

        Idle instances on workers inside an open preemption-notice window
        are subtracted (same rule as :meth:`warm_capacity`): warmth about
        to be preempted must not attract new placements.  The affinity
        admission policy (``core.policies.AffinityPolicy``) and the
        work-stealing tier (``core.stealing.steal_tick``) consume this via
        ``ShardState.warm_digest``; the contract is normative in
        docs/ARCHITECTURE.md §11.
        """
        digest = dict(self._warm)
        doomed = self._doomed_now()
        if doomed:
            for wid in doomed:
                for func, lst in self.workers[wid].idle.items():
                    c = digest.get(func, 0) - len(lst)
                    if c > 0:
                        digest[func] = c
                    else:
                        digest.pop(func, None)
        return digest

    def _warm_dec(self, func: int, n: int = 1) -> None:
        """Drop ``n`` warm instances of ``func`` from the digest counts."""
        w = self._warm
        c = w.get(func, 0) - n
        if c > 0:
            w[func] = c
        else:
            w.pop(func, None)

    def admit_vu(self, program: VUProgram, t: Optional[float] = None) -> int:
        """Admit one closed-loop VU mid-run (the admission tier's pull).

        Appends the program to the live population and schedules its first
        submit at time ``t`` (default: the current clock).  Returns the new
        VU's *local* id — callers that merge streams across simulators keep
        their own local->global id map.  The VU's service-fluctuation row is
        pre-filled to the band's current width so the (seed, vu, ev)
        identity seeding holds for admitted VUs exactly as for planned
        ones.  Requires a prior :meth:`begin`; ``t`` must not precede the
        current clock.

        The row itself is appended *empty* and marked pending: the fill to
        the band's current width happens lazily (``_flush_fluct``) at the
        VU's first dispatch, so a burst of admissions costs one vectorized
        kernel call instead of one per VU — with bit-identical draws.
        """
        t = self.t if t is None else float(t)
        if t < self.t:
            raise ValueError(f"cannot admit in the past: t={t} < now={self.t}")
        vu = len(self._prog_funcs)
        self._programs.append(program)
        self._prog_funcs.append(program.func_idx.tolist())
        self._prog_sleeps.append(program.sleep_s.tolist())
        self._vu_pos.append(0)
        entry = self._fluct
        rows = entry["rows"]
        cols = entry["cols"]
        while len(rows) <= vu:  # deterministic grow (entries may be shared)
            v = len(rows)
            rows.append([])
            if cols:
                entry["pending"].add(v)
            if self._fluct_identity is not None:
                self._fluct_identity.append((self.seed, v))
        self._push(t, _SUBMIT, (vu,))
        self._mark_dirty()
        return vu

    # ------------------------------------------------- cross-shard stealing
    def steal_queued(self, n: int, prefer=None) -> List[StolenTask]:
        """Export up to ``n`` tasks parked on worker pending queues (the
        work-stealing victim hook; see :class:`StolenTask` for what travels).

        Only *pending* tasks — admitted but still waiting for sandbox memory
        — are stealable: they hold no memory, have done no work, and are
        their closed-loop VU's single in-flight request, so exporting one
        migrates the VU's entire future with it (the VU is retired locally;
        no local events for it remain).  Victim order is deterministic:
        longest pending queue first (ties by registration order), newest
        task first.

        ``prefer`` (optional): a set of function indices the thief can serve
        warm (its ``warm_digest`` keys).  Victim-worker selection is
        unchanged, but within the chosen queue the newest task whose
        function is in ``prefer`` is exported instead of the plain newest —
        warm-locality stealing.  The fallback when nothing matches, and the
        ``prefer=None`` default, are byte-identical to the unparameterized
        form, so existing steal schedules are untouched.
        Each export releases the local scheduler's connection via
        ``on_cancel`` — the assignment never executed here.
        """
        out: List[StolenTask] = []
        while len(out) < n:
            victim = None
            best = 0
            for w in self.workers.values():
                if len(w.pending) > best:
                    best = len(w.pending)
                    victim = w
            if victim is None:
                break
            task = victim.pending.pop()
            if prefer and task.func not in prefer:
                # scan newest -> oldest for the first warm-servable task;
                # the already-popped newest is the fallback
                pend = victim.pending
                for i in range(len(pend) - 1, -1, -1):
                    if pend[i].func in prefer:
                        pend.append(task)  # put the fallback back (newest)
                        task = pend.pop(i)
                        break
            self._queued_n -= 1  # net one task left pending (swap is neutral)
            self.sched.on_cancel(task.worker, self._fnames[task.func])
            vu = task.vu
            self._flush_fluct()
            oseed, ovu = self._fluct_row_identity(vu)
            out.append(
                StolenTask(
                    func=task.func,
                    ev_idx=task.ev_idx,
                    t_submit=task.t_submit,
                    origin_seed=oseed,
                    origin_vu=ovu,
                    fluct_row=list(self._fluct["rows"][vu]),
                    program=self._programs[vu],
                    prog_funcs=self._prog_funcs[vu],
                    prog_sleeps=self._prog_sleeps[vu],
                    next_pos=self._vu_pos[vu],
                    src_vu=vu,
                    attempts=task.attempts,
                    fail_t=task.fail_t,
                )
            )
            self._vu_pos[vu] = len(self._prog_funcs[vu])  # retire the VU here
            self.stolen_out += 1
        if out:
            self._mark_dirty()
        return out

    def _export_vu(self, vu: int, func: int, ev_idx: int, t_submit: float,
                   attempts: int = 0, fail_t: float = -1.0) -> StolenTask:
        """Package VU ``vu``'s whole future as a :class:`StolenTask` and
        retire it locally (shared by :meth:`steal_queued` — inlined there for
        the hot path — and :meth:`salvage_queued`).  Caller supplies the
        in-flight request identity, or the *next* program position for a
        mid-think VU."""
        self._flush_fluct()
        oseed, ovu = self._fluct_row_identity(vu)
        stolen = StolenTask(
            func=func,
            ev_idx=ev_idx,
            t_submit=t_submit,
            origin_seed=oseed,
            origin_vu=ovu,
            fluct_row=list(self._fluct["rows"][vu]),
            program=self._programs[vu],
            prog_funcs=self._prog_funcs[vu],
            prog_sleeps=self._prog_sleeps[vu],
            next_pos=self._vu_pos[vu],
            src_vu=vu,
            attempts=attempts,
            fail_t=fail_t,
        )
        self._vu_pos[vu] = len(self._prog_funcs[vu])  # retire the VU here
        return stolen

    def salvage_queued(self) -> List[SalvagedVU]:
        """Export every still-live VU of a DEAD shard (no live workers) —
        the dead-shard drain hook (``core.stealing.drain_tick``).

        When the last worker dies, every VU is in one of two states, both
        parked on the event heap: its single outstanding request waits for a
        backoff retry (``_RESUBMIT``), or it is mid-think with a scheduled
        next submit (``_SUBMIT``).  Both are pure control-plane state — no
        sandbox memory, no partial work — so each VU's whole future can
        migrate exactly like a stolen pending task (same
        :class:`StolenTask` identity, bit-exact service draws).  The
        exported events are removed from the heap (exactly-once: the task
        re-runs on the receiver or nowhere), VUs are retired locally, and
        sweep/stale events stay behind.  Raises on a shard that still has
        live workers — salvage is the *dead*-shard path; live imbalance is
        work stealing's job.
        """
        if self.workers:
            raise ValueError(
                "salvage_queued requires a dead shard (live workers: "
                f"{sorted(self.workers)}); use steal_queued for live rebalance"
            )
        out: List[SalvagedVU] = []
        keep: List[Tuple[float, int, int, tuple]] = []
        for entry in self._heap:
            t, _, kind, payload = entry
            if kind == _RESUBMIT:
                task: _Task = payload[0]
                stolen = self._export_vu(
                    task.vu, task.func, task.ev_idx, task.t_submit,
                    attempts=task.attempts, fail_t=task.fail_t,
                )
                out.append(SalvagedVU(stolen=stolen, in_flight=True, resume_t=t))
            elif kind == _SUBMIT:
                vu = payload[0]
                pos = self._vu_pos[vu]
                funcs = self._prog_funcs[vu]
                if pos >= len(funcs):
                    continue  # exhausted program: drop the stale submit
                stolen = self._export_vu(vu, funcs[pos], pos, t)
                out.append(SalvagedVU(stolen=stolen, in_flight=False, resume_t=t))
            else:
                keep.append(entry)
        if len(keep) != len(self._heap):
            self._heap = keep
            heapq.heapify(self._heap)
        self.salvaged_out += len(out)
        if out:
            self._mark_dirty()
        return out

    def receive_task(self, stolen: StolenTask, t: Optional[float] = None) -> int:
        """Re-inject a stolen task (the work-stealing destination hook).

        Registers the migrated VU as a fresh local id — program resumed at
        ``next_pos``, fluctuation row bound to the *origin* identity
        ``(origin_seed, origin_vu)`` so every service draw replays bit-exactly
        (see :class:`StolenTask`) — and dispatches the stolen request at time
        ``t`` (default: now) with its original submission time, so recorded
        latency keeps the victim-side queueing delay.  Completion marks the
        record's ``migrated`` column.  Returns the new local VU id; callers
        that merge streams extend their local->global id table with it.
        """
        t = self.t if t is None else float(t)
        if t < self.t:
            raise ValueError(f"cannot receive in the past: t={t} < now={self.t}")
        vu = self._register_foreign(stolen)
        task = _Task(stolen.func, vu, stolen.ev_idx, stolen.t_submit)
        task.migrated = True
        task.attempts = stolen.attempts
        task.fail_t = stolen.fail_t
        self._push(t, _RESUBMIT, (task,))
        self.stolen_in += 1
        self._mark_dirty()
        return vu

    def _register_foreign(self, stolen: StolenTask) -> int:
        """Register a migrated VU as a fresh local id: program resumed at
        ``next_pos``, fluctuation row bound to the origin identity
        ``(origin_seed, origin_vu)`` so every service draw replays
        bit-exactly.  Shared by :meth:`receive_task` (work stealing) and
        :meth:`receive_salvaged` (dead-shard drain)."""
        vu = len(self._prog_funcs)
        self._programs.append(stolen.program)
        self._prog_funcs.append(stolen.prog_funcs)
        self._prog_sleeps.append(stolen.prog_sleeps)
        self._vu_pos.append(stolen.next_pos)
        self._detach_fluct()
        self._flush_fluct()  # fill native placeholders before the foreign row
        entry = self._fluct
        cols = entry["cols"]
        row = list(stolen.fluct_row[:cols])
        if len(row) < cols:  # origin band was narrower: fill from identity
            band = service_fluctuations(
                stolen.origin_seed, 1, cols - len(row), self.cfg.exec_sigma,
                ev_start=len(row), vu_start=stolen.origin_vu,
            )
            row.extend(band[0].tolist())
        # the foreign row must land at exactly index ``vu``: a band inherited
        # from the shared cache may be wider than this run's population (rows
        # left by earlier same-seed runs), in which case the now-private slot
        # is repointed rather than appended past the VU's index
        rows = entry["rows"]
        if len(rows) == vu:
            rows.append(row)
            self._fluct_identity.append((stolen.origin_seed, stolen.origin_vu))
        else:
            rows[vu] = row
            self._fluct_identity[vu] = (stolen.origin_seed, stolen.origin_vu)
            entry["pending"].discard(vu)
        return vu

    def receive_salvaged(self, sal: SalvagedVU, t: Optional[float] = None) -> int:
        """Re-home a VU salvaged off a dead shard (the drain's destination
        hook; mirror of :meth:`receive_task`).

        An in-flight VU's lost request re-dispatches immediately at ``t`` —
        salvage *is* its recovery, so it does not also serve out the dead
        shard's remaining backoff delay — keeping its original submit time
        (recorded latency charges the whole outage) and its retry history;
        its completion is flagged ``migrated``.  A mid-think VU resumes its
        program at ``max(resume_t, t)``: thinking continued while the shard
        was dark, only dispatch needs a live home.  Returns the new local VU
        id for the admission table.
        """
        t = self.t if t is None else float(t)
        if t < self.t:
            raise ValueError(f"cannot receive in the past: t={t} < now={self.t}")
        stolen = sal.stolen
        vu = self._register_foreign(stolen)
        if sal.in_flight:
            task = _Task(stolen.func, vu, stolen.ev_idx, stolen.t_submit)
            task.migrated = True
            task.attempts = stolen.attempts
            task.fail_t = stolen.fail_t
            self._push(t, _RESUBMIT, (task,))
        else:
            self._push(max(sal.resume_t, t), _SUBMIT, (vu,))
        self.salvaged_in += 1
        self._mark_dirty()
        return vu

    def outstanding(self) -> int:
        """Submitted-but-unresolved requests right now: running + pending on
        live workers, plus retry re-submissions waiting on the heap.  On a
        dead shard after :meth:`salvage_queued` this is 0 — the acceptance
        signal that the drain strands nothing (mid-think VUs have no
        *submitted* request, so they don't count here)."""
        n = 0
        for entry in self._heap:
            if entry[2] == _RESUBMIT:
                n += 1
        for w in self.workers.values():
            n += len(w.running) + len(w.pending)
        return n

    # ------------------------------------------------------------ handlers
    def _ev_submit(self, vu: int) -> None:
        pos = self._vu_pos[vu]
        funcs = self._prog_funcs[vu]
        if pos >= len(funcs) or self.t > self._deadline:
            return
        self._vu_pos[vu] = pos + 1
        self._dispatch(_Task(funcs[pos], vu, pos, self.t))

    def _retry_delay(self, attempts: int) -> float:
        """Backoff schedule: attempt ``i`` (1-based) waits
        ``min(retry_delay_s * retry_backoff**(i-1), retry_max_delay_s)``.
        Attempt 1 is exactly ``retry_delay_s`` — the seed engine's flat
        delay — which is what keeps single-retry runs byte-identical."""
        cfg = self.cfg
        if attempts <= 1:
            return cfg.retry_delay_s
        d = cfg.retry_delay_s * cfg.retry_backoff ** (attempts - 1)
        return d if d < cfg.retry_max_delay_s else cfg.retry_max_delay_s

    def _retry_or_lose(self, task: _Task) -> None:
        """A failure hit ``task``: resubmit with backoff, or — once the
        per-task ``retry_budget`` is exhausted — drop it as lost.  A lost
        task's closed-loop VU halts (it never completes, so it never thinks
        and never submits again); ``lost_tasks`` counts it and
        ``RunMetrics.lost_task_rate`` reports it."""
        task.attempts += 1
        if task.fail_t < 0.0:
            task.fail_t = self.t
        budget = self.cfg.retry_budget
        if budget is not None and task.attempts > budget:
            self.lost_tasks += 1
            return
        self.resubmits += 1
        self._push(self.t + self._retry_delay(task.attempts), _RESUBMIT, (task,))

    def _dispatch(self, task: _Task) -> None:
        fname = self._fnames[task.func]
        if not self.workers:
            # fully-dead cluster: nobody to schedule onto.  Backoff-retry
            # (the admission tier's drain salvages the task off this shard;
            # standalone, the retry_budget bounds the loop).
            self._retry_or_lose(task)
            return
        w = self.sched.schedule(fname)
        worker = self.workers.get(w)
        if worker is None or not worker.alive:
            # scheduler view raced with a failure; retry shortly
            self.sched.on_cancel(w, fname)
            self._retry_or_lose(task)
            return
        task.worker = w
        self._asg_t.append(self.t)
        self._asg_w.append(w)
        self._start_or_queue(worker, task)

    def _start_or_queue(self, worker: _Worker, task: _Task) -> None:
        worker.advance(self.t)
        func = task.func
        if func in worker.idle:
            inst = worker.pop_idle(func)
            self._warm_dec(func)  # warm reuse: the instance is busy again
            worker.busy_mem_mb += inst.mem_mb
            task.cold = False
            base_ms = self._fwarm[func]
        else:
            # cold path: make room for a new sandbox
            mem = self._fmem[func]
            while worker.busy_mem_mb + worker.idle_mem_mb + mem > worker.pool_mb:
                evicted = worker.evict_lru()
                if evicted is None:
                    break
                self._warm_dec(evicted.func)
                self.sched.on_evict(worker.wid, self._fnames[evicted.func])
            if worker.busy_mem_mb + worker.idle_mem_mb + mem > worker.pool_mb:
                worker.pending.append(task)  # waits for memory
                self._queued_n += 1
                return
            worker.busy_mem_mb += mem
            task.cold = True
            base_ms = self._fcold[func]
        entry = self._fluct
        row = entry["rows"][task.vu]
        if task.ev_idx >= entry["cols"]:
            self._extend_fluct(task.ev_idx)
            row = entry["rows"][task.vu]
        elif entry["pending"]:
            self._flush_fluct()  # lazily admitted rows fill in place
        task.work_s = task.remaining_s = base_ms * row[task.ev_idx] / 1e3
        if not worker.running:
            self._busy_n += 1  # idle -> busy transition
        worker.start(task)
        self._reschedule(worker)

    def _reschedule(self, worker: _Worker) -> None:
        nxt = worker.next_completion(self.t)
        if nxt is not None:
            if nxt == worker._sched_t:
                return  # the pending completion event is already correct
            worker.version += 1
            worker._sched_t = nxt
            heapq.heappush(
                self._heap, (nxt, next(self._seq), _COMPLETE, (worker.wid, worker.version))
            )
        elif worker._sched_t is not None:
            worker.version += 1  # invalidate the now-wrong pending event
            worker._sched_t = None

    def _ev_complete(self, wid: int, version: int) -> None:
        worker = self.workers.get(wid)
        if worker is None or version != worker.version or not worker.alive:
            return
        worker._sched_t = None  # this event is the live one; it just fired
        worker.advance(self.t)
        done = []
        keep = []
        for task in worker.running:
            (done if task.remaining_s <= 1e-12 else keep).append(task)
        if done:
            worker.running = keep
            if not keep:
                self._busy_n -= 1  # busy -> idle transition
            worker._min_ok = False
            for task in done:
                self._complete(worker, task)
        # pending tasks may now fit (an instance went idle and can be evicted)
        self._drain_pending(worker)
        self._reschedule(worker)

    def _complete(self, worker: _Worker, task: _Task) -> None:
        func = task.func
        mem = self._fmem[func]
        worker.busy_mem_mb -= mem
        t = self.t
        lst = worker.idle.get(func)
        if lst is None:
            worker.idle[func] = [_Instance(func, mem, t)]
        else:
            lst.append(_Instance(func, mem, t))  # t monotone: stays ascending
        worker.idle_mem_mb += mem
        self._warm[func] = self._warm.get(func, 0) + 1  # one more warm inst
        self.sched.on_finish(worker.wid, self._fnames[func])
        t_done = t + self._overhead_s
        if task.fail_t >= 0.0:
            # the request survived >=1 failure: recovery latency is first
            # failure -> completion (RunMetrics recovery percentiles)
            self.recovery_s.append(t_done - task.fail_t)
        self._rec_append(
            task.t_submit, t_done, func, worker.wid, task.cold, task.vu, task.migrated
        )
        # closed loop: VU thinks, then submits its next request
        sleeps = self._prog_sleeps[task.vu]
        ei = task.ev_idx
        sleep = sleeps[ei] if ei < len(sleeps) else sleeps[-1]
        heapq.heappush(self._heap, (t_done + sleep, next(self._seq), _SUBMIT, (task.vu,)))

    def _drain_pending(self, worker: _Worker) -> None:
        if not worker.pending:
            return
        waiting, worker.pending = worker.pending, []  # _start_or_queue may re-append
        self._queued_n -= len(waiting)
        for task in waiting:
            if (
                task.func in worker.idle
                or worker.mem_usage() + self._fmem[task.func] <= worker.pool_mb
                or worker.idle_mem_mb > 0
            ):
                self._start_or_queue(worker, task)
            else:
                worker.pending.append(task)
                self._queued_n += 1

    def _ev_sweep(self) -> None:
        cfg = self.cfg
        ka = cfg.keep_alive_s
        for worker in self.workers.values():
            if not worker.alive:
                continue
            worker.advance(self.t)
            if worker.idle:
                t = self.t
                for func in list(worker.idle):
                    lst = worker.idle[func]
                    # ascending last_used: expired instances form a prefix
                    cut = 0
                    end = len(lst)
                    while cut < end and t - lst[cut].last_used > ka:
                        inst = lst[cut]
                        worker.idle_mem_mb -= inst.mem_mb
                        self.sched.on_evict(worker.wid, self._fnames[func])
                        cut += 1
                    if cut:
                        self._warm_dec(func, cut)
                        if cut == end:
                            del worker.idle[func]
                        else:
                            worker.idle[func] = lst[cut:]
            self._drain_pending(worker)
        self._push(self.t + cfg.sweep_every_s, _SWEEP)

    # ------------------------------------------------- elasticity / faults
    def _ev_fail(self, wid: int) -> None:
        worker = self.workers.get(wid)
        if worker is None or not worker.alive:
            return
        self._ws_accrue()  # close the cost interval at the old pool size
        worker.advance(self.t)
        worker.alive = False
        self._queued_n -= len(worker.pending)
        if worker.running:
            self._busy_n -= 1
        self.sched.on_worker_removed(wid)
        # running + pending tasks are lost; control plane retries them with
        # capped exponential backoff until the per-task budget runs out
        for task in worker.running + worker.pending:
            fresh = _Task(task.func, task.vu, task.ev_idx, task.t_submit)
            fresh.migrated = task.migrated  # a retried stolen task stays stolen
            fresh.attempts = task.attempts
            fresh.fail_t = task.fail_t
            self._retry_or_lose(fresh)
        for func, lst in worker.idle.items():
            self._warm_dec(func, len(lst))  # the warm set dies with the worker
        worker.running, worker.pending, worker.idle = [], [], {}
        worker.busy_mem_mb = worker.idle_mem_mb = 0.0
        del self.workers[wid]

    def _ev_add_worker(self, wid: int) -> None:
        if wid in self.workers:
            return
        self._ws_accrue()  # close the cost interval at the old pool size
        w = _Worker(wid, self.cfg)
        w.last_t = self.t
        self.workers[wid] = w
        self.sched.on_worker_added(wid)
