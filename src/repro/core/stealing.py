"""Cross-shard work stealing: pull-based balancing *after* admission binds.

The global admission tier (``core.admission``) applies Hiku's pull principle
at arrival time: the least-pressured shard pulls the next VU.  That decision
is made once — if a shard turns hot later (its VUs ramp up, its memory pool
thrashes cold starts), the queue that builds behind it can never drain on an
idle neighbor.  This is exactly the late-binding gap the serverless
scheduling literature pins on static placement (Kaffes et al.'s core-granular
migration, NOAH's job-migration view): tail latency is dominated by work
stuck behind the wrong queue.

``steal_tick`` closes the gap with the admission tier's own mechanism run in
**both directions**: each tick, one pressure-keyed heap of *victims* (shards
above ``steal_watermark``) and one of *thieves* (shards below the pull
watermark).  While both heaps are non-empty, the most-pressured victim
exports one queued task (``Simulator.steal_queued``) and the least-pressured
thief re-injects it (``Simulator.receive_task``); each move adjusts both
shards' effective pressure by ``1/n_workers`` — the same accounting the
admission tier applies per pull — so a single tick cannot flood a thief or
drain a victim past the watermarks.

Contracts (stated normatively in docs/ARCHITECTURE.md §8):

* only *pending* tasks migrate (admitted, waiting for sandbox memory: no
  work done, no memory held) and the closed-loop VU migrates with its task;
* the migrated VU's service-fluctuation identity ``(origin_seed, origin_vu)``
  travels with it, so every replayed draw is bit-exact under migration;
* a dead shard (all workers failed, pressure ``inf``) can never be a thief,
  and has nothing stealable as a victim;
* with stealing off nothing here runs: the static partition and the pull
  tier stay byte/stream-identical to their pre-stealing behavior.

Determinism: heap order is a total order ``(pressure, shard index)``, victim
selection inside a shard is deterministic (``steal_queued``), so a steal
schedule is a pure function of the co-run state.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Sequence, Tuple

from .simulator import SalvagedVU, Simulator, StolenTask

__all__ = ["Migration", "Salvage", "drain_tick", "steal_tick"]


@dataclasses.dataclass(frozen=True)
class Migration:
    """One completed task migration (telemetry row on ``AdmissionRun``).

    ``src_vu``/``dst_vu`` are shard-local VU ids (the victim's id at steal
    time and the fresh id the destination registered); the admission tier
    resolves ``src_vu`` through its admission table to a global VU id.
    """

    t: float
    src: int
    dst: int
    src_vu: int
    dst_vu: int
    func: int
    ev_idx: int


def steal_tick(
    sims: Sequence[Simulator],
    steal_watermark: float,
    pull_watermark: float,
    inv_workers: Sequence[float],
    t: Optional[float] = None,
    max_moves: Optional[int] = None,
    prefer_warm: bool = False,
    pressures: Optional[Sequence[float]] = None,
) -> List[Migration]:
    """One stealing round over co-run shards; returns the moves it made.

    Args:
        sims: the K shard simulators (co-run via ``begin``/``step_until``).
        steal_watermark: pressure above which a shard is a victim; must sit
            at or above ``pull_watermark`` or a shard could be both sides of
            the same move.
        pull_watermark: pressure below which a shard may receive (the
            admission tier's pull watermark — stealing is admission's
            mirror image).  The admission loop sources this pair from
            ``AdmissionPolicy.steal_params()`` each tick, so learned
            policies (``bandit+steal``) may retune the band per reward
            window — the invariant above must hold for every value the
            policy can return.
        inv_workers: per-shard ``1 / n_workers`` pressure increments.
        t: simulated re-injection time (default: each receiver's clock).
        max_moves: optional hard cap on migrations this tick.
        prefer_warm: warm-locality stealing (``AdmissionPolicy
            .steal_affinity``): each move passes the thief's warm-digest
            function set (``Simulator.warm_digest`` keys, computed once per
            thief per tick) to ``steal_queued(prefer=...)``, so within the
            victim's chosen queue the newest *warm-servable* task is
            exported instead of the plain newest.  Victim/thief heap order
            is untouched, and ``False`` (the default) is byte-identical to
            the pre-digest tier — the ARCHITECTURE §11 off-path guarantee.
        pressures: the tick's per-shard pressure vector, when the caller
            already holds one — the admission loop passes its
            ``ShardCoordinator``'s cached vector (docs/ARCHITECTURE.md
            §13), which equals the live reads at this point in the tick
            (live pressure cannot change between the tick-top refresh and
            the steal round).  Default ``None``: read live.

    The two heaps are rebuilt from the tick's pressure vector each round;
    within the tick, moves adjust effective pressures exactly like admission
    pulls do, so staleness is bounded by the tick period either way.
    """
    if steal_watermark < pull_watermark:
        raise ValueError(
            f"steal_watermark {steal_watermark} must be >= pull watermark "
            f"{pull_watermark} (a shard must never be victim and thief at once)"
        )
    if pressures is None:
        pressures = [sim.pressure() for sim in sims]
    # max-heap of victims, min-heap of thieves — the same pressure-keyed
    # heap the admission tier runs, here in both directions at once.
    victims = [(-p, k) for k, p in enumerate(pressures) if p > steal_watermark]
    thieves = [(p, k) for k, p in enumerate(pressures) if p < pull_watermark]
    heapq.heapify(victims)
    heapq.heapify(thieves)
    moves: List[Migration] = []
    # per-thief warm-function sets, computed lazily once per tick: a steal
    # moves only *pending* tasks, which never touch any shard's idle set,
    # so the digests cannot change mid-tick
    warm_sets: dict = {}
    while victims and thieves and (max_moves is None or len(moves) < max_moves):
        neg_pv, v = victims[0]
        pt, th = thieves[0]
        if -neg_pv <= steal_watermark or pt >= pull_watermark:
            break  # both frontiers inside the watermark band: balanced enough
        if prefer_warm:
            prefer = warm_sets.get(th)
            if prefer is None:
                prefer = warm_sets[th] = frozenset(sims[th].warm_digest())
            got = sims[v].steal_queued(1, prefer=prefer)
        else:
            got = sims[v].steal_queued(1)
        if not got:
            heapq.heappop(victims)  # pressured but nothing queued is stealable
            continue
        stolen: StolenTask = got[0]
        # never before the receiver's clock: unevenly stepped sims would
        # otherwise reject the receive AFTER the victim was already mutated,
        # losing the task (exactly-once would break)
        when = sims[th].t if t is None else max(t, sims[th].t)
        dst_vu = sims[th].receive_task(stolen, t=when)
        moves.append(
            Migration(
                t=when,
                src=v,
                dst=th,
                src_vu=stolen.src_vu,
                dst_vu=dst_vu,
                func=stolen.func,
                ev_idx=stolen.ev_idx,
            )
        )
        heapq.heapreplace(victims, (neg_pv + inv_workers[v], v))
        heapq.heapreplace(thieves, (pt + inv_workers[th], th))
    return moves


@dataclasses.dataclass(frozen=True)
class Salvage:
    """One VU re-homed off a dead shard (telemetry row on ``AdmissionRun``).

    Shape-compatible with :class:`Migration` (same local-id semantics, same
    admission-table resolution of ``src_vu``), plus ``in_flight``: ``True``
    when the salvaged VU carried a lost request that re-dispatches on the
    destination (its completion is flagged ``migrated``), ``False`` for a
    mid-think VU that merely resumes its program there.  ``func``/``ev_idx``
    identify the in-flight request, or the VU's next program position.
    """

    t: float
    src: int
    dst: int
    src_vu: int
    dst_vu: int
    func: int
    ev_idx: int
    in_flight: bool


def drain_tick(
    sims: Sequence[Simulator],
    inv_workers: Sequence[float],
    t: float,
    pending: Optional[List[Tuple[int, SalvagedVU]]] = None,
    dead: Optional[Sequence[int]] = None,
    pressures: Optional[Sequence[float]] = None,
) -> Tuple[List[Salvage], List[Tuple[int, SalvagedVU]]]:
    """One dead-shard drain round: salvage every fully-dead shard's live VUs
    onto live shards.  Returns ``(moves, leftovers)``.

    The recovery half of the §10 failure contract (docs/ARCHITECTURE.md):
    when a shard's last worker dies, its queued work must re-enter the
    global pool instead of stranding.  Each dead shard (no live workers —
    pressure ``inf``) is drained via ``Simulator.salvage_queued``; exports
    are placed on live shards through the same pressure-keyed min-heap and
    ``1/n_workers`` effective-pressure accounting as admission pulls and
    steals.  Unlike stealing there is no watermark gate: salvaged work is
    *survival* traffic and must land somewhere even if every live shard is
    above the pull watermark.

    ``pending`` carries exports buffered from earlier ticks when the whole
    cluster was dark; they are placed first (oldest outage first).  When no
    live shard exists this tick, all exports come back as ``leftovers`` for
    the caller to retry after a revival (``inject_worker``) — exactly-once
    either way: a salvaged VU is re-homed once or still owned by the buffer.

    Determinism: dead shards drain in index order, ``salvage_queued``'s
    export order is the victim heap order, and placement is the
    ``(pressure, index)`` total order — a pure function of the co-run state.

    ``dead`` and ``pressures`` let a caller holding a ``ShardCoordinator``
    view (docs/ARCHITECTURE.md §13) skip the O(K) dead-scan and the live
    pressure reads: ``dead`` is the coordinator's dead-shard set (iterated
    sorted, preserving the index-order drain contract), ``pressures`` its
    cached vector — both equal to the live reads at this point in the tick.
    """
    exports: List[Tuple[int, SalvagedVU]] = list(pending or ())
    if dead is None:
        dead_idx = [k for k, sim in enumerate(sims) if not sim.workers]
    else:
        dead_idx = sorted(dead)
    for k in dead_idx:
        for sv in sims[k].salvage_queued():
            exports.append((k, sv))
    if not exports:
        return [], []
    thieves = [
        ((sim.pressure() if pressures is None else pressures[k]), k)
        for k, sim in enumerate(sims)
        if sim.workers
    ]
    if not thieves:
        return [], exports  # cluster fully dark: buffer until a revival
    heapq.heapify(thieves)
    moves: List[Salvage] = []
    for src, sv in exports:
        p, th = thieves[0]
        # never before the receiver's clock (the steal_tick rule: the victim
        # is already mutated, so a rejected receive would lose the task)
        when = max(t, sims[th].t)
        dst_vu = sims[th].receive_salvaged(sv, t=when)
        moves.append(
            Salvage(
                t=when,
                src=src,
                dst=th,
                src_vu=sv.stolen.src_vu,
                dst_vu=dst_vu,
                func=sv.stolen.func,
                ev_idx=sv.stolen.ev_idx,
                in_flight=sv.in_flight,
            )
        )
        heapq.heapreplace(thieves, (p + inv_workers[th], th))
    return moves, []
