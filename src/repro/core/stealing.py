"""Cross-shard work stealing: pull-based balancing *after* admission binds.

The global admission tier (``core.admission``) applies Hiku's pull principle
at arrival time: the least-pressured shard pulls the next VU.  That decision
is made once — if a shard turns hot later (its VUs ramp up, its memory pool
thrashes cold starts), the queue that builds behind it can never drain on an
idle neighbor.  This is exactly the late-binding gap the serverless
scheduling literature pins on static placement (Kaffes et al.'s core-granular
migration, NOAH's job-migration view): tail latency is dominated by work
stuck behind the wrong queue.

``steal_tick`` closes the gap with the admission tier's own mechanism run in
**both directions**: each tick, one pressure-keyed heap of *victims* (shards
above ``steal_watermark``) and one of *thieves* (shards below the pull
watermark).  While both heaps are non-empty, the most-pressured victim
exports one queued task (``Simulator.steal_queued``) and the least-pressured
thief re-injects it (``Simulator.receive_task``); each move adjusts both
shards' effective pressure by ``1/n_workers`` — the same accounting the
admission tier applies per pull — so a single tick cannot flood a thief or
drain a victim past the watermarks.

Contracts (stated normatively in docs/ARCHITECTURE.md §8):

* only *pending* tasks migrate (admitted, waiting for sandbox memory: no
  work done, no memory held) and the closed-loop VU migrates with its task;
* the migrated VU's service-fluctuation identity ``(origin_seed, origin_vu)``
  travels with it, so every replayed draw is bit-exact under migration;
* a dead shard (all workers failed, pressure ``inf``) can never be a thief,
  and has nothing stealable as a victim;
* with stealing off nothing here runs: the static partition and the pull
  tier stay byte/stream-identical to their pre-stealing behavior.

Determinism: heap order is a total order ``(pressure, shard index)``, victim
selection inside a shard is deterministic (``steal_queued``), so a steal
schedule is a pure function of the co-run state.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Sequence

from .simulator import Simulator, StolenTask

__all__ = ["Migration", "steal_tick"]


@dataclasses.dataclass(frozen=True)
class Migration:
    """One completed task migration (telemetry row on ``AdmissionRun``).

    ``src_vu``/``dst_vu`` are shard-local VU ids (the victim's id at steal
    time and the fresh id the destination registered); the admission tier
    resolves ``src_vu`` through its admission table to a global VU id.
    """

    t: float
    src: int
    dst: int
    src_vu: int
    dst_vu: int
    func: int
    ev_idx: int


def steal_tick(
    sims: Sequence[Simulator],
    steal_watermark: float,
    pull_watermark: float,
    inv_workers: Sequence[float],
    t: Optional[float] = None,
    max_moves: Optional[int] = None,
) -> List[Migration]:
    """One stealing round over co-run shards; returns the moves it made.

    Args:
        sims: the K shard simulators (co-run via ``begin``/``step_until``).
        steal_watermark: pressure above which a shard is a victim; must sit
            at or above ``pull_watermark`` or a shard could be both sides of
            the same move.
        pull_watermark: pressure below which a shard may receive (the
            admission tier's pull watermark — stealing is admission's
            mirror image).
        inv_workers: per-shard ``1 / n_workers`` pressure increments.
        t: simulated re-injection time (default: each receiver's clock).
        max_moves: optional hard cap on migrations this tick.

    The two heaps are rebuilt from live ``Simulator.pressure()`` each tick;
    within the tick, moves adjust effective pressures exactly like admission
    pulls do, so staleness is bounded by the tick period either way.
    """
    if steal_watermark < pull_watermark:
        raise ValueError(
            f"steal_watermark {steal_watermark} must be >= pull watermark "
            f"{pull_watermark} (a shard must never be victim and thief at once)"
        )
    pressures = [sim.pressure() for sim in sims]
    # max-heap of victims, min-heap of thieves — the same pressure-keyed
    # heap the admission tier runs, here in both directions at once.
    victims = [(-p, k) for k, p in enumerate(pressures) if p > steal_watermark]
    thieves = [(p, k) for k, p in enumerate(pressures) if p < pull_watermark]
    heapq.heapify(victims)
    heapq.heapify(thieves)
    moves: List[Migration] = []
    while victims and thieves and (max_moves is None or len(moves) < max_moves):
        neg_pv, v = victims[0]
        pt, th = thieves[0]
        if -neg_pv <= steal_watermark or pt >= pull_watermark:
            break  # both frontiers inside the watermark band: balanced enough
        got = sims[v].steal_queued(1)
        if not got:
            heapq.heappop(victims)  # pressured but nothing queued is stealable
            continue
        stolen: StolenTask = got[0]
        # never before the receiver's clock: unevenly stepped sims would
        # otherwise reject the receive AFTER the victim was already mutated,
        # losing the task (exactly-once would break)
        when = sims[th].t if t is None else max(t, sims[th].t)
        dst_vu = sims[th].receive_task(stolen, t=when)
        moves.append(
            Migration(
                t=when,
                src=v,
                dst=th,
                src_vu=stolen.src_vu,
                dst_vu=dst_vu,
                func=stolen.func,
                ev_idx=stolen.ev_idx,
            )
        )
        heapq.heapreplace(victims, (neg_pv + inv_workers[v], v))
        heapq.heapreplace(thieves, (pt + inv_workers[th], th))
    return moves
