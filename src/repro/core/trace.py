"""Azure-like serverless workload generation (Section III-B, Figures 4-6).

The paper drives its evaluation with invocation probabilities sampled from the
Azure Functions dataset mapped onto 40 functions (8 FunctionBench apps x 5
copies), closed-loop k6 virtual users with U(0.1, 1)s think time, and
service-time heterogeneity.  This module generates statistically matching
workloads from a seed:

* **Skewed popularity** — Zipf exponent fitted so that for a large function
  population the top 10% of functions receive ~92.3% and the top 1% ~51.3% of
  invocations (the dataset stats quoted in Section III-B).  The 40 experiment
  functions take their weights from random ranks of that population, exactly
  like the paper's random subsampling of the dataset.
* **Heterogeneous performance** — per-app warm/cold base latencies from
  Table I with per-invocation lognormal fluctuation (Figure 5).
* **Bursty invocations** — closed-loop VUs produce arrival bursts naturally;
  an open-loop Markov-modulated generator is provided for the Figure-6
  characterization benchmark.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Dict, List, Sequence, Tuple

import numpy as np

from . import fastrng

# Table I of the paper: FunctionBench on OpenLambda / m5.xlarge, ms.
TABLE_I: Dict[str, Tuple[float, float]] = {
    # app: (cold_ms, warm_ms)
    "chameleon": (536.0, 392.0),
    "dd": (706.0, 549.0),
    "float_operation": (263.0, 94.0),
    "gzip_compression": (510.0, 303.0),
    "json_dumps_loads": (269.0, 105.0),
    "linpack": (282.0, 58.0),
    "matmul": (284.0, 125.0),
    "pyaes": (329.0, 149.0),
}

# Plausible resident-set footprints for the FunctionBench sandboxes (MB).
# These act as the worker memory-pool pressure knob; see simulator defaults.
APP_MEM_MB: Dict[str, float] = {
    "chameleon": 340.0,
    "dd": 420.0,
    "float_operation": 160.0,
    "gzip_compression": 380.0,
    "json_dumps_loads": 210.0,
    "linpack": 260.0,
    "matmul": 310.0,
    "pyaes": 200.0,
}


@dataclasses.dataclass(frozen=True)
class FunctionSpec:
    """One deployable function: Table-I base latencies (ms), sandbox
    footprint (MB) and its Azure-skewed invocation probability."""

    name: str
    app: str
    cold_ms: float
    warm_ms: float
    mem_mb: float
    weight: float  # invocation probability


def fit_zipf_exponent(n: int = 1000, top10_share: float = 0.923) -> float:
    """Bisection fit of a single Zipf exponent to the top-10% share."""
    ranks = np.arange(1, n + 1, dtype=np.float64)

    def share(s: float) -> float:
        w = ranks ** (-s)
        w /= w.sum()
        return float(w[: n // 10].sum())

    lo, hi = 0.4, 3.0
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if share(mid) < top10_share:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def _population_weights(n: int, top1: float = 0.513, top10: float = 0.923) -> np.ndarray:
    """Hierarchically calibrated popularity: matches BOTH Azure skew stats
    exactly by construction (top 1% -> 51.3%, top 10% -> 92.3% of calls),
    with Zipf-shaped mass inside each tier (Section III-B, Figure 4)."""
    w = np.empty(n)
    k1, k10 = max(1, n // 100), max(2, n // 10)
    tiers = [(0, k1, top1), (k1, k10, top10 - top1), (k10, n, 1.0 - top10)]
    for lo, hi, mass in tiers:
        # uniform within tier keeps the rank ordering monotone across tier
        # boundaries, so the top-k statistics hold exactly after sorting
        w[lo:hi] = mass / (hi - lo)
    return w


_POP_CACHE: Dict[int, np.ndarray] = {}


def azure_like_weights(n_funcs: int, seed: int, population: int = 1000) -> np.ndarray:
    """Sample ``n_funcs`` normalized weights from the calibrated population.

    Mirrors the paper's procedure: "randomly selected 40 functions from this
    dataset, calculated and normalized invocation probabilities".
    """
    if population not in _POP_CACHE:
        _POP_CACHE[population] = _population_weights(population)
    pop = _POP_CACHE[population]
    if n_funcs == population:
        return pop.copy()
    rng = np.random.default_rng(seed)
    idx = rng.choice(population, size=n_funcs, replace=False)
    w = pop[idx]
    return w / w.sum()


def make_functions(n_copies: int = 5, seed: int = 0) -> List[FunctionSpec]:
    """8 FunctionBench apps x ``n_copies`` uniquely named functions."""
    apps = sorted(TABLE_I)
    names = [f"{app}-{c}" for app in apps for c in range(n_copies)]
    weights = azure_like_weights(len(names), seed)
    funcs = []
    for i, name in enumerate(names):
        app = name.rsplit("-", 1)[0]
        cold, warm = TABLE_I[app]
        funcs.append(
            FunctionSpec(
                name=name,
                app=app,
                cold_ms=cold,
                warm_ms=warm,
                mem_mb=APP_MEM_MB[app],
                weight=float(weights[i]),
            )
        )
    return funcs


@dataclasses.dataclass
class VUProgram:
    """Pre-generated closed-loop program for one virtual user.

    Choices and think times are drawn ahead of time from the seed so that
    *every scheduler replays the identical request sequence* — the paper's
    fairness device ("we seeded the random number generator ... so that the
    order of function invocations as well as sleep durations ... were
    identical for each scheduling algorithm").
    """

    func_idx: np.ndarray  # (n_events,)
    sleep_s: np.ndarray  # (n_events,)


_PROG_CACHE: Dict[tuple, List["VUProgram"]] = {}
_PROG_FAST_OK = True  # cleared on any spot-check mismatch: per-VU path forever


def _vu_programs_ref(
    n_funcs: int,
    weights: np.ndarray,
    n_vus: int,
    n_events: int,
    seed: int,
    think_lo: float,
    think_hi: float,
    vu_start: int = 0,
) -> List["VUProgram"]:
    """The seed engine's per-VU draw loop, verbatim: one fresh
    ``default_rng((seed, vu))`` per VU.  Reference for the vectorized fast
    path (spot checks, pin tests, and the fallback when the fast path cannot
    prove itself)."""
    programs = []
    for vu in range(vu_start, vu_start + n_vus):
        rng = np.random.default_rng((seed, vu))
        idx = rng.choice(n_funcs, size=n_events, p=weights)
        sleep = rng.uniform(think_lo, think_hi, size=n_events)
        programs.append(VUProgram(idx, sleep))
    return programs


def _vu_programs_vec(
    n_funcs: int,
    weights: np.ndarray,
    n_vus: int,
    n_events: int,
    seed: int,
    think_lo: float,
    think_hi: float,
) -> List["VUProgram"]:
    """Vectorized, bit-exact rebuild of the per-VU draw loop.

    ``Generator.choice(n, size, p)`` is cdf-inversion over ``size`` raw
    uniform doubles and ``Generator.uniform`` is ``lo + (hi-lo) * u`` over
    the next ``size`` — both exactly reproducible from the first
    ``2*n_events`` doubles of each VU's stream, which ``fastrng
    .uniform_block`` computes for all VUs at once.  Each fresh workload key
    spot-checks one row against the real per-VU Generator and degrades to
    the reference loop process-wide on any mismatch (e.g. a numpy upgrade
    changing ``choice``'s consumption pattern)."""
    global _PROG_FAST_OK
    u = fastrng.uniform_block(seed, n_vus, 2 * n_events)
    cdf = weights.cumsum()
    cdf /= cdf[-1]
    idx = cdf.searchsorted(u[:, :n_events], side="right").astype(np.intp, copy=False)
    sleep = think_lo + (think_hi - think_lo) * u[:, n_events:]
    check = _vu_programs_ref(n_funcs, weights, 1, n_events, seed, think_lo, think_hi)[0]
    if not (np.array_equal(idx[0], check.func_idx) and np.array_equal(sleep[0], check.sleep_s)):
        _PROG_FAST_OK = False
        warnings.warn(
            "vectorized VU-program fast path disagrees with default_rng on "
            "this numpy; falling back to the per-VU loop (bit-exact, slower)",
            RuntimeWarning,
            stacklevel=3,
        )
        return _vu_programs_ref(n_funcs, weights, n_vus, n_events, seed, think_lo, think_hi)
    return [VUProgram(idx[v], sleep[v]) for v in range(n_vus)]


def default_n_events(duration_s: float) -> int:
    """Engine-default events per VU program for a ``duration_s``-second run.

    A generous upper bound (4 requests/s plus slack) so closed-loop VUs
    never exhaust their program before the deadline.  Every driver that
    builds a default workload (``Simulator.begin``, ``AdmissionSimulator``,
    benchmarks, examples) uses this one formula, which is pinned by the
    frozen seed engine's replay contract — changing it changes every
    default-workload stream."""
    return int(duration_s * 4) + 16


def make_vu_programs(
    funcs: Sequence[FunctionSpec],
    n_vus: int,
    n_events: int,
    seed: int,
    think_lo: float = 0.1,
    think_hi: float = 1.0,
) -> List[VUProgram]:
    """Seeded closed-loop programs for ``n_vus`` virtual users.

    VU ``vu`` draws ``n_events`` weighted function choices and
    ``U(think_lo, think_hi)`` think times (seconds) from
    ``default_rng((seed, vu))`` — deterministic per (weights, shape, seed),
    so every scheduler replays the identical request sequence (the paper's
    fairness device).  Returned lists are memoized and shared read-only."""
    # Programs are a pure function of (weights, shape, seed): memoize so the
    # benchmark matrix generates each seeded workload once, not once per
    # scheduler.  Returned lists are shared read-only.
    key = (tuple(f.weight for f in funcs), n_vus, n_events, seed, think_lo, think_hi)
    cached = _PROG_CACHE.get(key)
    if cached is not None:
        return cached
    weights = np.array([f.weight for f in funcs])
    weights = weights / weights.sum()
    seed_i = int(seed)
    if _PROG_FAST_OK and n_vus >= 4 and n_events > 0 and 0 <= seed_i < 2**32:
        programs = _vu_programs_vec(
            len(funcs), weights, n_vus, n_events, seed_i, think_lo, think_hi
        )
    else:
        programs = _vu_programs_ref(
            len(funcs), weights, n_vus, n_events, seed, think_lo, think_hi
        )
    if len(_PROG_CACHE) >= 16:
        _PROG_CACHE.clear()
    _PROG_CACHE[key] = programs
    return programs


def service_time_ms(spec: FunctionSpec, cold: bool, rng: np.random.Generator, sigma: float = 0.25) -> float:
    """Lognormal fluctuation around Table-I base latency (Figure 5)."""
    base = spec.cold_ms if cold else spec.warm_ms
    return float(base * rng.lognormal(mean=-0.5 * sigma**2, sigma=sigma))


def service_fluctuations(
    seed: int, n_vus: int, n_events: int, sigma: float, ev_start: int = 0, vu_start: int = 0
) -> np.ndarray:
    """Pre-generated per-request service-time fluctuation band.

    Entry ``[i, j]`` is bit-identical to what the seed simulator drew
    per-request: ``default_rng((seed, vu_start + i, ev_start + j))
    .lognormal(-σ²/2, σ)`` — the request-identity seeding that lets every
    scheduler replay the same stochastic demand.  Computed vectorized (see
    ``fastrng``) so programs can carry their fluctuations instead of paying
    a Generator construction per request in the simulator hot loop;
    ``vu_start`` lets dynamically admitted VUs fill in their single row.
    """
    from .fastrng import lognormal_matrix

    return lognormal_matrix(
        seed, n_vus, n_events, -0.5 * sigma**2, sigma, ev_start=ev_start, vu_start=vu_start
    )


# ------------------------------------------------------------------ Figure 6
def bursty_interarrivals(
    n: int,
    seed: int,
    base_rate: float = 50.0,
    burst_rate: float = 900.0,
    mean_burst_s: float = 40.0,
    mean_calm_s: float = 300.0,
) -> np.ndarray:
    """Time-modulated Poisson interarrivals (sec): minute-scale bursts so the
    per-minute arrival rate swings by ~13.5x (Figure 6).  Used by the
    open-loop trace characterization benchmark and burst-resilience tests."""
    rng = np.random.default_rng(seed)
    out = np.empty(n)
    bursting = False
    t = 0.0
    t_switch = rng.exponential(mean_calm_s)
    for i in range(n):
        if t >= t_switch:
            bursting = not bursting
            t_switch = t + rng.exponential(mean_burst_s if bursting else mean_calm_s)
        rate = burst_rate if bursting else base_rate
        out[i] = rng.exponential(1.0 / rate)
        t += out[i]
    return out
