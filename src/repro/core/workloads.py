"""Bursty workload scenario suite: the traffic shapes policies compete on.

The paper motivates pull-based scheduling by its behavior under "commonly
occurring bursty workloads", but the repo's built-in populations only
exercised synthetic hot-block skew (``admission.make_skewed_programs`` /
``make_sleeper_programs``).  This module generates the realistic arrival
mixes the policy literature compares on (Kaffes et al., Nguyen et al. —
see PAPERS.md), as self-contained :class:`Scenario` bundles the admission
tier consumes directly:

* ``flash_crowd`` — a background population, then a spike of VUs arriving
  nearly at once, half on tight latency SLOs: the EDF showcase.
* ``diurnal`` — arrival times drawn from a sine-modulated intensity
  (day/night load), via deterministic inverse-transform sampling.
* ``on_off`` — Markov-modulated (ON/OFF bursty) arrivals layered on
  ``trace.bursty_interarrivals``, the Figure-6 generator.
* ``heavy_tail`` — a heavy-tailed service mix: a minority of VUs hammer the
  heaviest functions with Pareto-tailed think times.

Determinism contract (same device as ``trace.py``): every scenario is a
pure function of its arguments — no scenario reads global RNG state — so
it replays bit-exactly for every policy, and the engine's ``(seed, vu,
ev)`` fluctuation identity (``core.fastrng``) applies unchanged on top.
Program and deadline draws additionally use per-VU identity streams
(``np.random.default_rng((seed, vu[, tag]))``: VU ``i``'s draws are
independent of how many other VUs exist); the one exception is ``on_off``
*arrivals*, which come from a single seeded MMPP chain
(``trace.bursty_interarrivals``) — sequential by construction, so
``arrivals[i]`` depends on the draws before it (still bit-exact replay,
just not per-VU regenerable).

``make_scenario(name, ...)`` resolves from the ``SCENARIOS`` registry;
``benchmarks/bench_policies.py`` runs the policies x scenarios matrix.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .trace import FunctionSpec, VUProgram, bursty_interarrivals, default_n_events

__all__ = [
    "SCENARIOS",
    "Scenario",
    "available_scenarios",
    "diurnal",
    "flash_crowd",
    "heavy_tail",
    "make_scenario",
    "on_off",
]

# rng tags: keep per-VU draw streams for programs/arrivals/deadlines disjoint
_ARRIVAL_TAG = 0x0A11
_CLASS_TAG = 0xC1A5


@dataclasses.dataclass
class Scenario:
    """One replayable traffic shape: programs + arrivals + deadline metadata.

    ``arrivals`` are admission-eligibility times (seconds); ``deadlines``
    are per-VU *relative* latency SLOs (seconds; ``None`` when the scenario
    carries no deadline semantics).  Feed it to the admission tier with
    ``adm.run(scn.n_vus, duration_s, **scn.run_kwargs())``.

    ``faults`` optionally attaches a :class:`~repro.core.chaos.FaultPlan`
    (injected failure/recovery schedule) so a chaos scenario travels as one
    replayable bundle; ``run_kwargs`` forwards it only when set, keeping
    plain scenarios byte-identical to their pre-chaos form.

    ``axes`` names the metric columns this scenario is scored on in the
    policy leaderboard (``benchmarks/bench_policies`` cell keys: p99_ms,
    mean_ms, deadline_miss_rate, cold_rate); lower is better on every axis.
    """

    name: str
    programs: List[VUProgram]
    arrivals: np.ndarray
    deadlines: Optional[np.ndarray] = None
    faults: Optional[object] = None  # chaos.FaultPlan; object to avoid a cycle
    axes: Tuple[str, ...] = (
        "p99_ms", "mean_ms", "deadline_miss_rate", "cold_rate"
    )

    @property
    def n_vus(self) -> int:
        return len(self.programs)

    def run_kwargs(self) -> dict:
        """Keyword arguments for ``AdmissionSimulator.run``."""
        kw = dict(
            programs=self.programs, arrivals=self.arrivals, deadlines=self.deadlines
        )
        if self.faults is not None:
            kw["faults"] = self.faults
        return kw


def _weights(funcs: Sequence[FunctionSpec]) -> np.ndarray:
    w = np.asarray([f.weight for f in funcs])
    return w / w.sum()


def _heavy_funcs(funcs: Sequence[FunctionSpec], quantile: float = 0.75) -> np.ndarray:
    warm = np.asarray([f.warm_ms for f in funcs])
    return np.flatnonzero(warm >= np.quantile(warm, quantile))


def _light_funcs(funcs: Sequence[FunctionSpec], quantile: float = 0.5) -> np.ndarray:
    warm = np.asarray([f.warm_ms for f in funcs])
    return np.flatnonzero(warm <= np.quantile(warm, quantile))


def flash_crowd(
    funcs: Sequence[FunctionSpec],
    n_vus: int,
    duration_s: float,
    seed: int,
    spike_frac: float = 0.6,
    spike_at_frac: float = 0.25,
    tight_deadline_s: float = 2.0,
    loose_deadline_s: float = float("inf"),
) -> Scenario:
    """A flash crowd: background load, then a near-simultaneous VU spike.

    The first ``spike_frac`` of VUs arrive together inside a one-second
    window at ``spike_at_frac * duration_s``; alternating spike VUs are
    *interactive* (light functions, short think, ``tight_deadline_s``
    first-response SLO) and *batch* (heavy functions, ``loose_deadline_s``
    — default none: batch work has no latency SLO and is excluded from the
    miss-rate denominator).  The rest are background: Azure-weighted calls,
    moderate think, no SLO, arriving over the pre-spike window.  Because
    the spike dwarfs the watermark capacity, the admission queue backs up —
    which queued VU binds first is exactly what deadline-aware admission
    decides better than FIFO pull (the interactive VUs' first response
    otherwise waits behind batch admissions).
    """
    weights = _weights(funcs)
    heavy = _heavy_funcs(funcs)
    light = _light_funcs(funcs)
    n_events = default_n_events(duration_s)
    n_spike = int(round(spike_frac * n_vus))
    spike_t = spike_at_frac * duration_s
    programs: List[VUProgram] = []
    arrivals = np.empty(n_vus)
    deadlines = np.full(n_vus, loose_deadline_s)
    for vu in range(n_vus):
        rng = np.random.default_rng((seed, vu))
        arr_rng = np.random.default_rng((seed, vu, _ARRIVAL_TAG))
        if vu < n_spike:
            arrivals[vu] = spike_t + arr_rng.uniform(0.0, 1.0)
            if vu % 2 == 0:  # interactive half: tight SLO, light calls
                idx = light[rng.integers(0, len(light), size=n_events)]
                sleep = rng.uniform(0.1, 0.4, size=n_events)
                deadlines[vu] = tight_deadline_s
            else:  # batch half: heavy calls, slack SLO
                idx = heavy[rng.integers(0, len(heavy), size=n_events)]
                sleep = rng.uniform(0.2, 0.8, size=n_events)
        else:
            arrivals[vu] = arr_rng.uniform(0.0, max(spike_t - 1.0, 0.5))
            idx = rng.choice(len(funcs), size=n_events, p=weights)
            sleep = rng.uniform(0.5, 2.0, size=n_events)
        programs.append(VUProgram(np.asarray(idx), sleep))
    return Scenario("flash_crowd", programs, arrivals, deadlines)


def diurnal(
    funcs: Sequence[FunctionSpec],
    n_vus: int,
    duration_s: float,
    seed: int,
    cycles: float = 2.0,
    amplitude: float = 0.85,
    deadline_s: float = 4.0,
) -> Scenario:
    """Diurnal sine load: arrivals from a sinusoid-modulated intensity.

    Intensity ``λ(t) ∝ 1 + amplitude * sin(...)`` over ``cycles`` full
    periods in the arrival horizon (the first 75% of the run, so the tail
    can drain), starting at the trough.  Each VU's arrival is the inverse
    CDF of the cumulative intensity at its own uniform quantile — a pure
    function of ``(seed, vu)``, so the waveform replays bit-exactly.
    """
    horizon = 0.75 * duration_s
    grid = np.linspace(0.0, horizon, 4096)
    phase = 2.0 * np.pi * cycles * grid / horizon
    lam = 1.0 + amplitude * np.sin(phase - 0.5 * np.pi)  # start at the trough
    cum = np.cumsum(lam)
    cum = (cum - cum[0]) / (cum[-1] - cum[0])
    weights = _weights(funcs)
    n_events = default_n_events(duration_s)
    programs: List[VUProgram] = []
    arrivals = np.empty(n_vus)
    for vu in range(n_vus):
        rng = np.random.default_rng((seed, vu))
        u = np.random.default_rng((seed, vu, _ARRIVAL_TAG)).uniform()
        arrivals[vu] = float(np.interp(u, cum, grid))
        idx = rng.choice(len(funcs), size=n_events, p=weights)
        sleep = rng.uniform(0.2, 1.0, size=n_events)
        programs.append(VUProgram(idx, sleep))
    return Scenario("diurnal", programs, arrivals, np.full(n_vus, deadline_s))


def on_off(
    funcs: Sequence[FunctionSpec],
    n_vus: int,
    duration_s: float,
    seed: int,
    burst_factor: float = 12.0,
    deadline_s: float = 3.0,
) -> Scenario:
    """ON/OFF bursty (Markov-modulated Poisson) arrivals.

    Interarrival times come from ``trace.bursty_interarrivals`` — the
    Figure-6 two-state MMPP — with rates scaled to the run: calm traffic
    trickles, ON periods arrive ``burst_factor`` times faster.  Arrivals
    are clipped to the first 80% of the run so the tail drains (and no VU
    lands in the end-of-run admission blind window).  Note the arrival
    chain is one sequential ``default_rng(seed)`` stream (a Markov chain
    cannot be drawn per-VU); programs keep per-``(seed, vu)`` identity.
    """
    horizon = 0.8 * duration_s
    base_rate = max(n_vus / horizon, 1e-6)
    inter = bursty_interarrivals(
        n_vus,
        seed,
        base_rate=base_rate,
        burst_rate=burst_factor * base_rate,
        mean_burst_s=horizon / 8.0,
        mean_calm_s=horizon / 3.0,
    )
    arrivals = np.minimum(np.cumsum(inter), horizon)
    weights = _weights(funcs)
    n_events = default_n_events(duration_s)
    programs: List[VUProgram] = []
    for vu in range(n_vus):
        rng = np.random.default_rng((seed, vu))
        idx = rng.choice(len(funcs), size=n_events, p=weights)
        sleep = rng.uniform(0.1, 0.8, size=n_events)
        programs.append(VUProgram(idx, sleep))
    return Scenario("on_off", programs, arrivals, np.full(n_vus, deadline_s))


def heavy_tail(
    funcs: Sequence[FunctionSpec],
    n_vus: int,
    duration_s: float,
    seed: int,
    heavy_frac: float = 0.3,
    pareto_shape: float = 1.5,
    tight_deadline_s: float = 2.0,
    loose_deadline_s: float = 30.0,
) -> Scenario:
    """Heavy-tailed service mix: a hammering minority among light traffic.

    ``heavy_frac`` of VUs call only the heaviest function quartile with
    Pareto(``pareto_shape``)-tailed think times — long lulls punctuated by
    hammering runs — on slack SLOs; the light majority runs
    Azure-weighted calls on tight SLOs.  Arrivals trickle in over the
    first 30% of the run.  The elephant/mice mix is where cost-aware
    admission (warm-capacity scaling) separates from plain pull.
    """
    weights = _weights(funcs)
    heavy = _heavy_funcs(funcs)
    n_events = default_n_events(duration_s)
    n_heavy = int(round(heavy_frac * n_vus))
    programs: List[VUProgram] = []
    arrivals = np.empty(n_vus)
    deadlines = np.empty(n_vus)
    for vu in range(n_vus):
        rng = np.random.default_rng((seed, vu))
        arrivals[vu] = np.random.default_rng((seed, vu, _ARRIVAL_TAG)).uniform(
            0.0, 0.3 * duration_s
        )
        if vu < n_heavy:  # elephants: heavy calls, Pareto-tailed think
            idx = heavy[rng.integers(0, len(heavy), size=n_events)]
            sleep = np.minimum(0.05 * rng.pareto(pareto_shape, size=n_events), 10.0)
            deadlines[vu] = loose_deadline_s
        else:  # mice: light Azure mix, tight SLO
            idx = rng.choice(len(funcs), size=n_events, p=weights)
            sleep = rng.uniform(0.2, 1.0, size=n_events)
            deadlines[vu] = tight_deadline_s
        programs.append(VUProgram(np.asarray(idx), sleep))
    return Scenario("heavy_tail", programs, arrivals, deadlines)


#: scenario registry: name -> builder(funcs, n_vus, duration_s, seed, **kw)
SCENARIOS: Dict[str, Callable[..., Scenario]] = {
    "flash_crowd": flash_crowd,
    "diurnal": diurnal,
    "on_off": on_off,
    "heavy_tail": heavy_tail,
}


def available_scenarios() -> List[str]:
    """Sorted names of every registered workload scenario."""
    return sorted(SCENARIOS)


def make_scenario(
    name: str,
    funcs: Sequence[FunctionSpec],
    n_vus: int,
    duration_s: float,
    seed: int = 0,
    **kwargs,
) -> Scenario:
    """Build a registered scenario by name (unknown names list the registry)."""
    try:
        build = SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; available: {available_scenarios()}"
        ) from None
    return build(funcs, n_vus, duration_s, seed, **kwargs)
