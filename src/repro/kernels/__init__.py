"""Pallas TPU kernels (validated in interpret mode on CPU; see ops.py)."""

from . import ops, ref

__all__ = ["ops", "ref"]
