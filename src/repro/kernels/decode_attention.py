"""Flash-decode: single-token attention against a long KV cache (Pallas TPU).

The serve_step hot spot.  grid = (B, KH, n_kv_blocks) with the kv-block dim
innermost; each (batch, kv-head) pair streams cache blocks through VMEM while
the G = H/KH grouped query heads ride along as the MXU's M dimension
(a (G, block_k) logit tile per step — GQA head packing).  Running
(m, l, acc) statistics live in f32 VMEM scratch; the output is finalized on
the last block.

block_k defaults to 512: a (512, head_dim=128) f32 cache tile is 256 KiB —
two of them (K and V) plus stats stay comfortably inside VMEM and keep the
HBM stream long enough to saturate bandwidth (decode is memory-bound; see
EXPERIMENTS.md §Roofline).

The same (m, l, acc) merge combines *cross-device* partials under the
context-parallel decode sharding (cache seq sharded over "model") — this
kernel is the single-device block of that schedule.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -2.0e38


def _dec_kernel(valid_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                *, scale, window, block_k, n_k):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid = valid_ref[0]
    k_start = ki * block_k
    k_pos = k_start + jax.lax.iota(jnp.int32, block_k)
    ok = k_pos <= valid
    if window is not None:
        ok &= (valid - k_pos) < window

    @pl.when(jnp.any(ok))
    def _tile():
        q = q_ref[0, 0, :, :].astype(jnp.float32)        # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)        # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, bk)
        s = jnp.where(ok[None, :], s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0, :, :] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,        # (B, H, hd)
    k_cache: jax.Array,  # (B, S, KH, hd)
    v_cache: jax.Array,
    valid_len: jax.Array,  # scalar int32
    window: Optional[int] = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, S, KH, hd = k_cache.shape
    H = q.shape[1]
    G = H // KH
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    n_k = S // block_k
    qg = q.reshape(B, KH, G, hd)
    valid = jnp.asarray(valid_len, jnp.int32).reshape(1)

    kernel = functools.partial(_dec_kernel, scale=1.0 / (hd ** 0.5), window=window,
                               block_k=block_k, n_k=n_k)
    out = pl.pallas_call(
        kernel,
        grid=(B, KH, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # valid_len scalar
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        interpret=interpret,
    )(valid, qg, k_cache, v_cache)
    return out.reshape(B, H, hd)
