"""Flash attention (prefill/train) as a Pallas TPU kernel.

Canonical streaming-softmax formulation tiled for the MXU/VMEM:
grid = (B, H, n_q_blocks, n_kv_blocks) with the kv-block dim innermost —
TPU grids iterate the last dim sequentially per core, so the f32 accumulator
and the running (m, l) statistics live in VMEM scratch and persist across kv
blocks; the output block is finalized and written once on the last kv step.

Block shapes default to (block_q=128, block_k=128): MXU-aligned (128x128)
and a VMEM working set of q/k/v/acc blocks
(~4 x 128 x head_dim x 4B ~ 256 KiB at head_dim=128) far under the ~16 MiB
VMEM budget, leaving room for double buffering.

GQA: query head h reads kv head h // (H // KH).  Causal and sliding-window
masks are applied per (q-block, kv-block) tile with early full-tile skips.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -2.0e38


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, scale, causal, window, block_q, block_k, n_k):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # tile-level skip: with causal masking, tiles entirely above the diagonal
    # (or entirely outside the window) contribute nothing.
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    ok = jnp.ones((block_q, block_k), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window

    @pl.when(jnp.any(ok))
    def _tile():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)
        s = jnp.where(ok, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, :, 0, :] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, KH, hd)
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, hd = q.shape
    KH = k.shape[2]
    G = H // KH
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    n_q, n_k = S // block_q, S // block_k
    grid = (B, H, n_q, n_k)
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            # f32 accumulator + running (m, l) persist across the kv grid dim
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
