"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to auto: Pallas kernels execute natively on TPU and in
interpret mode elsewhere (this container is CPU-only, so tests/examples run
the kernel bodies in interpret mode; the dry-run uses the XLA reference path
— see DESIGN.md §6).  Wrappers handle padding/layout so call sites stay
shape-clean.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import decode_attention as _dec
from . import flash_attention as _fa
from . import sched_step as _ss
from . import ssd_scan as _ssd
from . import ref


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128, interpret: Optional[bool] = None):
    interpret = _auto_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, valid_len, window: Optional[int] = None,
                     block_k: int = 512, interpret: Optional[bool] = None):
    interpret = _auto_interpret() if interpret is None else interpret
    return _dec.decode_attention(q, k_cache, v_cache, valid_len, window=window,
                                 block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "block_h", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, chunk: int = 128, block_h: int = 8,
             interpret: Optional[bool] = None):
    interpret = _auto_interpret() if interpret is None else interpret
    if Bm.shape[2] != 1:  # kernel covers ngroups=1; general case -> oracle
        return ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk)
    S = x.shape[1]
    pad = (-S) % chunk
    if pad:
        x, dt, Bm, Cm = (jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
                         for t in (x, dt, Bm, Cm))
    y, st = _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, block_h=block_h, interpret=interpret)
    return (y[:, :S] if pad else y), st


@functools.partial(jax.jit, static_argnames=("interpret",))
def sched_step(funcs, idle, conns, interpret: Optional[bool] = None):
    """Burst scheduling: pad workers to the 128-lane axis, run, unpad."""
    interpret = _auto_interpret() if interpret is None else interpret
    F, W = idle.shape
    padW = (-W) % 128 if not interpret else 0
    if padW:
        idle = jnp.pad(idle, ((0, 0), (0, padW)))
        conns = jnp.pad(conns, (0, padW), constant_values=2**30)  # never selected
    a, warm, idle2, conns2 = _ss.sched_step(funcs, idle, conns, interpret=interpret)
    if padW:
        idle2, conns2 = idle2[:, :W], conns2[:W]
    return a, warm, idle2, conns2


@functools.partial(jax.jit, static_argnames=("interpret",))
def sched_events(kinds, funcs, workers, idle, conns, interpret: Optional[bool] = None):
    """Fused mixed (ARRIVAL|FINISH|EVICT) burst: pad lanes, run, unpad."""
    interpret = _auto_interpret() if interpret is None else interpret
    F, W = idle.shape
    padW = (-W) % 128 if not interpret else 0
    if padW:
        idle = jnp.pad(idle, ((0, 0), (0, padW)))
        conns = jnp.pad(conns, (0, padW), constant_values=2**30)  # never selected
    a, warm, idle2, conns2 = _ss.sched_events(
        kinds, funcs, workers, idle, conns, interpret=interpret
    )
    if padW:
        idle2, conns2 = idle2[:, :W], conns2[:W]
    return a, warm, idle2, conns2
