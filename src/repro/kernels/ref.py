"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests).

Each function mirrors the exact contract of its kernel in ops.py:
  flash_attention_ref  <-> kernels/flash_attention.py
  decode_attention_ref <-> kernels/decode_attention.py
  ssd_scan_ref         <-> kernels/ssd_scan.py  (the chunked SSD of
                           models/mamba.py, re-exported for the sweep tests)
  sched_step_ref       <-> kernels/sched_step.py (vectorized Algorithm 1
                           ARRIVAL path over a request burst)
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..models.mamba import ssd_chunked as _ssd_chunked

_NEG_INF = -2.0e38


def flash_attention_ref(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, KH, hd)
    v: jax.Array,  # (B, S, KH, hd)
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    B, S, H, hd = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, S, KH, G, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k, preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= pos[None, :] <= pos[:, None]
    if window is not None:
        ok &= (pos[:, None] - pos[None, :]) < window
    logits = jnp.where(ok[None, None, None], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, S, H, hd)


def decode_attention_ref(
    q: jax.Array,        # (B, H, hd) — one new token per sequence
    k_cache: jax.Array,  # (B, S, KH, hd)
    v_cache: jax.Array,  # (B, S, KH, hd)
    valid_len: jax.Array,  # scalar int32: entries [0, valid_len] are live
    window: int | None = None,
) -> jax.Array:
    B, S, KH, hd = k_cache.shape
    H = q.shape[1]
    G = H // KH
    qg = q.reshape(B, KH, G, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    logits = jnp.einsum(
        "bkgh,bskh->bkgs", qg, k_cache.astype(q.dtype), preferred_element_type=jnp.float32
    ) * scale
    pos = jnp.arange(S)
    ok = pos <= valid_len
    if window is not None:
        ok &= (valid_len - pos) < window
    logits = jnp.where(ok[None, None, None, :], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v_cache.astype(q.dtype))
    return out.reshape(B, H, hd)


def ssd_scan_ref(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD oracle — delegates to the model's pure-jnp implementation."""
    return _ssd_chunked(x, dt, A, Bm, Cm, chunk, init_state)


def sched_step_ref(
    funcs: jax.Array,  # (R,) int32 — function id per request, in order
    idle: jax.Array,   # (F, W) int32 — PQ_f multiset (idle instances)
    conns: jax.Array,  # (W,) int32 — active connections
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Vectorized Algorithm-1 ARRIVAL burst (deterministic first-index ties).

    Returns (assignments (R,), warm (R,), idle', conns').
    """
    INF = jnp.int32(2**30)

    def step(carry, f):
        idle, conns = carry
        row = idle[f]
        has_idle = jnp.any(row > 0)
        pull_scores = jnp.where(row > 0, conns, INF)
        w = jnp.where(has_idle, jnp.argmin(pull_scores), jnp.argmin(conns)).astype(jnp.int32)
        idle = idle.at[f, w].add(-has_idle.astype(jnp.int32))
        conns = conns.at[w].add(1)
        return (idle, conns), (w, has_idle)

    (idle, conns), (ws, warm) = jax.lax.scan(step, (idle, conns), funcs)
    return ws, warm, idle, conns


def sched_events_ref(
    kinds: jax.Array,    # (R,) int32 — 0 ARRIVAL / 1 FINISH / 2 EVICT
    funcs: jax.Array,    # (R,) int32
    workers: jax.Array,  # (R,) int32 (-1 for ARRIVAL)
    idle: jax.Array,     # (F, W) int32
    conns: jax.Array,    # (W,) int32
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Mixed-event oracle for kernels/sched_step.sched_events: the scan of
    ``core.jax_sched.sched_step`` with deterministic ties (key=None)."""
    from ..core.jax_sched import JIQState, sched_many

    events = jnp.stack([kinds, funcs, workers], axis=1).astype(jnp.int32)
    state, (ws, warm) = sched_many(JIQState(idle, conns), events, key=None)
    return ws, warm.astype(jnp.int32), state.idle, state.conns
