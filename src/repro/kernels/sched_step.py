"""Fused pull-based scheduling step (Algorithm 1 ARRIVAL burst) in Pallas.

The paper's own hot path: for each request in a burst, (1) masked-argmin over
workers with an idle instance of the requested function (the PQ_f dequeue),
(2) least-connections argmin fallback, (3) connection/idle-table updates that
the *next* request in the burst observes.  The sequential dependence makes
this a scan — fused here into one kernel invocation so the whole burst costs
one dispatch (vs. one XLA scan iteration each; see benchmarks/bench_kernels).

Layout: workers live on the 128-lane axis (W padded to a lane multiple by
ops.py, padding masked with +INF connections); the idle table rows for the
burst's functions are resident in VMEM; the request loop is a fori_loop with
dynamic row loads — the TPU analogue of the paper's Go scheduler loop.

Tie-breaking is deterministic (lowest index), matching ``ref.sched_step_ref``;
the randomized tie-break of Algorithm 1 lives in the control plane
(core/jax_sched.py) where a PRNG key is available.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INF = 2**30  # python int: jnp scalars would be captured as kernel constants


def _sched_kernel(funcs_ref, idle_ref, conns_ref, assign_ref, warm_ref, idle_out, conns_out):
    idle_out[...] = idle_ref[...]
    conns_out[...] = conns_ref[...]
    R = funcs_ref.shape[0]
    W = conns_ref.shape[0]

    def body(i, _):
        f = funcs_ref[i]
        row = pl.load(idle_out, (pl.dslice(f, 1), slice(None)))[0]  # (W,)
        conns = conns_out[...]
        has_idle = jnp.any(row > 0)
        pull_scores = jnp.where(row > 0, conns, _INF)
        w_pull = jnp.argmin(pull_scores).astype(jnp.int32)
        w_fb = jnp.argmin(conns).astype(jnp.int32)
        w = jnp.where(has_idle, w_pull, w_fb)
        # dequeue from PQ_f (if pulled) + open connection
        dec = has_idle.astype(jnp.int32)
        old_row = pl.load(idle_out, (pl.dslice(f, 1), pl.dslice(w, 1)))
        pl.store(idle_out, (pl.dslice(f, 1), pl.dslice(w, 1)), old_row - dec)
        old_c = pl.load(conns_out, (pl.dslice(w, 1),))
        pl.store(conns_out, (pl.dslice(w, 1),), old_c + 1)
        pl.store(assign_ref, (pl.dslice(i, 1),), w[None])
        pl.store(warm_ref, (pl.dslice(i, 1),), has_idle[None].astype(jnp.int32))
        return 0

    jax.lax.fori_loop(0, R, body, 0)


def sched_step(
    funcs: jax.Array,  # (R,) int32
    idle: jax.Array,   # (F, W) int32
    conns: jax.Array,  # (W,) int32
    interpret: bool = False,
):
    """Returns (assign (R,), warm (R,) int32, idle', conns')."""
    R = funcs.shape[0]
    F, W = idle.shape
    return pl.pallas_call(
        _sched_kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((F, W), lambda: (0, 0)),
            pl.BlockSpec((W,), lambda: (0,)),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((F, W), lambda: (0, 0)),
            pl.BlockSpec((W,), lambda: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R,), jnp.int32),
            jax.ShapeDtypeStruct((R,), jnp.int32),
            jax.ShapeDtypeStruct((F, W), jnp.int32),
            jax.ShapeDtypeStruct((W,), jnp.int32),
        ],
        interpret=interpret,
    )(funcs, idle, conns)
