"""Fused pull-based scheduling in Pallas: ARRIVAL bursts + mixed events.

The paper's own hot path: for each request in a burst, (1) masked-argmin over
workers with an idle instance of the requested function (the PQ_f dequeue),
(2) least-connections argmin fallback, (3) connection/idle-table updates that
the *next* request in the burst observes.  The sequential dependence makes
this a scan — fused here into one kernel invocation so the whole burst costs
one dispatch (vs. one XLA scan iteration each; see benchmarks/bench_kernels).

Two kernels:

* ``sched_step``   — the original ARRIVAL-only burst.
* ``sched_events`` — full mixed ``(ARRIVAL|FINISH|EVICT)`` event streams, the
  fused form of ``core.jax_sched.sched_step`` scanned over a burst: FINISH
  performs the pull enqueue (idle[f, w] += 1, connection closes), EVICT the
  notification removal.  Bit-exact against ``sched_many(..., key=None)``
  (deterministic lowest-index ties); exposed as
  ``core.jax_sched.sched_many_fused`` with chunking + off-TPU fallback.

Layout: workers live on the 128-lane axis (W padded to a lane multiple by
ops.py, padding masked with +INF connections); the idle table rows for the
burst's functions are resident in VMEM; the request loop is a fori_loop with
dynamic row loads — the TPU analogue of the paper's Go scheduler loop.

Tie-breaking is deterministic (lowest index), matching ``ref.sched_step_ref``;
the randomized tie-break of Algorithm 1 lives in the control plane
(core/jax_sched.py) where a PRNG key is available.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INF = 2**30  # python int: jnp scalars would be captured as kernel constants


def _sched_kernel(funcs_ref, idle_ref, conns_ref, assign_ref, warm_ref, idle_out, conns_out):
    idle_out[...] = idle_ref[...]
    conns_out[...] = conns_ref[...]
    R = funcs_ref.shape[0]
    W = conns_ref.shape[0]

    def body(i, _):
        f = funcs_ref[i]
        row = pl.load(idle_out, (pl.dslice(f, 1), slice(None)))[0]  # (W,)
        conns = conns_out[...]
        has_idle = jnp.any(row > 0)
        pull_scores = jnp.where(row > 0, conns, _INF)
        w_pull = jnp.argmin(pull_scores).astype(jnp.int32)
        w_fb = jnp.argmin(conns).astype(jnp.int32)
        w = jnp.where(has_idle, w_pull, w_fb)
        # dequeue from PQ_f (if pulled) + open connection
        dec = has_idle.astype(jnp.int32)
        old_row = pl.load(idle_out, (pl.dslice(f, 1), pl.dslice(w, 1)))
        pl.store(idle_out, (pl.dslice(f, 1), pl.dslice(w, 1)), old_row - dec)
        old_c = pl.load(conns_out, (pl.dslice(w, 1),))
        pl.store(conns_out, (pl.dslice(w, 1),), old_c + 1)
        pl.store(assign_ref, (pl.dslice(i, 1),), w[None])
        pl.store(warm_ref, (pl.dslice(i, 1),), has_idle[None].astype(jnp.int32))
        return 0

    jax.lax.fori_loop(0, R, body, 0)


def sched_step(
    funcs: jax.Array,  # (R,) int32
    idle: jax.Array,   # (F, W) int32
    conns: jax.Array,  # (W,) int32
    interpret: bool = False,
):
    """Returns (assign (R,), warm (R,) int32, idle', conns')."""
    R = funcs.shape[0]
    F, W = idle.shape
    return pl.pallas_call(
        _sched_kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((F, W), lambda: (0, 0)),
            pl.BlockSpec((W,), lambda: (0,)),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((F, W), lambda: (0, 0)),
            pl.BlockSpec((W,), lambda: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R,), jnp.int32),
            jax.ShapeDtypeStruct((R,), jnp.int32),
            jax.ShapeDtypeStruct((F, W), jnp.int32),
            jax.ShapeDtypeStruct((W,), jnp.int32),
        ],
        interpret=interpret,
    )(funcs, idle, conns)


# --------------------------------------------------------------- mixed events
def _sched_events_kernel(
    kinds_ref, funcs_ref, workers_ref, idle_ref, conns_ref,
    assign_ref, warm_ref, idle_out, conns_out,
):
    idle_out[...] = idle_ref[...]
    conns_out[...] = conns_ref[...]
    R = kinds_ref.shape[0]

    def body(i, _):
        k = kinds_ref[i]
        f = funcs_ref[i]
        w_ev = workers_ref[i]
        w_ev = jnp.where(w_ev < 0, 0, w_ev)  # ARRIVAL carries -1: unused below
        is_arr = (k == 0).astype(jnp.int32)
        is_fin = (k == 1).astype(jnp.int32)
        is_evt = (k == 2).astype(jnp.int32)

        row = pl.load(idle_out, (pl.dslice(f, 1), slice(None)))[0]  # (W,)
        conns = conns_out[...]
        has_idle = jnp.any(row > 0)
        pull_scores = jnp.where(row > 0, conns, _INF)
        w_pull = jnp.argmin(pull_scores).astype(jnp.int32)
        w_fb = jnp.argmin(conns).astype(jnp.int32)
        w_assign = jnp.where(has_idle, w_pull, w_fb)

        # ARRIVAL: dequeue from PQ_f (if pulled) + open connection
        dec = is_arr * has_idle.astype(jnp.int32)
        cell = pl.load(idle_out, (pl.dslice(f, 1), pl.dslice(w_assign, 1)))
        pl.store(idle_out, (pl.dslice(f, 1), pl.dslice(w_assign, 1)), cell - dec)
        c_cell = pl.load(conns_out, (pl.dslice(w_assign, 1),))
        pl.store(conns_out, (pl.dslice(w_assign, 1),), c_cell + is_arr)

        # FINISH: pull enqueue + close connection; EVICT: notification removal
        cell = pl.load(idle_out, (pl.dslice(f, 1), pl.dslice(w_ev, 1)))
        cell = cell + is_fin
        cell = cell - is_evt * (cell > 0).astype(jnp.int32)
        pl.store(idle_out, (pl.dslice(f, 1), pl.dslice(w_ev, 1)), cell)
        c_cell = pl.load(conns_out, (pl.dslice(w_ev, 1),))
        c_cell = c_cell - is_fin
        c_cell = jnp.maximum(c_cell, 0)
        pl.store(conns_out, (pl.dslice(w_ev, 1),), c_cell)

        pl.store(assign_ref, (pl.dslice(i, 1),),
                 jnp.where(is_arr == 1, w_assign, jnp.int32(-1))[None])
        pl.store(warm_ref, (pl.dslice(i, 1),),
                 (is_arr * has_idle.astype(jnp.int32))[None])
        return 0

    jax.lax.fori_loop(0, R, body, 0)


def sched_events(
    kinds: jax.Array,   # (R,) int32 — 0 ARRIVAL / 1 FINISH / 2 EVICT
    funcs: jax.Array,   # (R,) int32
    workers: jax.Array,  # (R,) int32 (-1 for ARRIVAL)
    idle: jax.Array,    # (F, W) int32
    conns: jax.Array,   # (W,) int32
    interpret: bool = False,
):
    """Fused mixed-event burst.  Returns (assign, warm, idle', conns').

    One dispatch per burst; semantics identical to scanning
    ``core.jax_sched.sched_step`` with ``key=None`` (assign/warm are -1/0 for
    non-ARRIVAL events).
    """
    R = kinds.shape[0]
    F, W = idle.shape
    return pl.pallas_call(
        _sched_events_kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((F, W), lambda: (0, 0)),
            pl.BlockSpec((W,), lambda: (0,)),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((F, W), lambda: (0, 0)),
            pl.BlockSpec((W,), lambda: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R,), jnp.int32),
            jax.ShapeDtypeStruct((R,), jnp.int32),
            jax.ShapeDtypeStruct((F, W), jnp.int32),
            jax.ShapeDtypeStruct((W,), jnp.int32),
        ],
        interpret=interpret,
    )(kinds, funcs, workers, idle, conns)
