"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

grid = (B, n_head_blocks, n_chunks) with chunks innermost: the (bh, P, N)
f32 carry state lives in VMEM scratch and is threaded through the sequential
chunk iterations (reset at chunk 0) — the inter-chunk linear recurrence of
the SSD algorithm.  Within a chunk the quadratic (attention-like) form runs
on MXU-shaped tiles.

Head-blocking keeps the VMEM working set bounded: at (block_h=8, Q=128,
P=64, N=128) the resident tiles are
  x (Q,bh,P) 256KiB + L (Q,Q,bh) 512KiB + state (bh,P,N) 256KiB + B/C (Q,N)
well under budget.  ngroups=1 (both assigned SSM archs) — B/C tiles are
shared across the head block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state, *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0].astype(jnp.float32)       # (Q, bh, P)
    dt = dt_ref[0].astype(jnp.float32)     # (Q, bh)
    A = a_ref[...].astype(jnp.float32)     # (bh,)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)  # (Q, N)
    Q = x.shape[0]

    dA = dt * A[None, :]                   # (Q, bh)
    cs = jnp.cumsum(dA, axis=0)            # (Q, bh)
    # L[q, k, h] = exp(cs_q - cs_k) for q >= k
    diff = cs[:, None, :] - cs[None, :, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where((ki <= qi)[:, :, None], jnp.exp(diff), 0.0)  # (Q, Q, bh)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (Q, Q)
    y_intra = jnp.einsum("qk,qkh,kh,khp->qhp", scores, L, dt, x)

    h_in = state[...]                       # (bh, P, N)
    y_inter = jnp.einsum("qn,hpn,qh->qhp", Cm, h_in, jnp.exp(cs))

    decay_end = jnp.exp(cs[-1][None, :] - cs) * dt  # (Q, bh)
    st_chunk = jnp.einsum("qh,qn,qhp->hpn", decay_end, Bm, x)
    state[...] = h_in * jnp.exp(cs[-1])[:, None, None] + st_chunk

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)
    st_ref[0] = state[...].astype(st_ref.dtype)


def ssd_scan(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)  post-softplus
    A: jax.Array,   # (H,) negative
    Bm: jax.Array,  # (B, S, 1, N)  (ngroups=1)
    Cm: jax.Array,  # (B, S, 1, N)
    chunk: int = 128,
    block_h: int = 8,
    interpret: bool = False,
):
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert Bm.shape[2] == 1, "kernel supports ngroups=1 (both assigned SSM archs)"
    assert S % chunk == 0, (S, chunk)
    if H % block_h != 0:
        block_h = H
    nc = S // chunk
    nhb = H // block_h

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, st = pl.pallas_call(
        kernel,
        grid=(B, nhb, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_h, P), lambda b, hb, ci: (b, ci, hb, 0)),
            pl.BlockSpec((1, chunk, block_h), lambda b, hb, ci: (b, ci, hb)),
            pl.BlockSpec((block_h,), lambda b, hb, ci: (hb,)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, hb, ci: (b, ci, 0, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, hb, ci: (b, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_h, P), lambda b, hb, ci: (b, ci, hb, 0)),
            pl.BlockSpec((1, block_h, P, N), lambda b, hb, ci: (b, hb, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_h, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, st
