import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  REPRO_DRYRUN_DEVICES overrides for small CI meshes.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.environ['REPRO_DRYRUN_DEVICES']}"
    )

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape x mesh) cell:
  lower the step function with abstract inputs -> compile -> record
  memory_analysis / cost_analysis / collective schedule, and write one JSON
  per cell under --out (benchmarks/results/dryrun by default).  Incremental:
  existing JSONs are skipped unless --force.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                   # all cells, both meshes
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --shape decode_32k --mesh single
  REPRO_DRYRUN_DEVICES=16 ... --mesh-shape 4x4                   # reduced CI mesh
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs.base import ARCH_ALIASES, ARCH_IDS, SHAPES, cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import step_fn_and_specs
from repro.sharding.rules import make_plan
from repro.utils.hlo import collective_stats, op_census, total_collective_bytes

# TPU v5e hardware constants (per chip) — roofline denominators.
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
ICI_BW = 50e9  # per link


def _mesh_from_arg(mesh_arg: str, mesh_shape: str | None):
    if mesh_shape:
        dims = tuple(int(x) for x in mesh_shape.split("x"))
        axes = ("pod", "data", "model")[-len(dims):] if len(dims) == 3 else ("data", "model")
        return jax.make_mesh(dims, axes), mesh_arg
    return make_production_mesh(multi_pod=(mesh_arg == "multi")), mesh_arg


def sharded_arg_bytes(args, shardings, mesh) -> int:
    """Exact per-device resident bytes of the step inputs."""
    total = 0

    def one(sds, sh):
        nonlocal total
        n = int(np.prod(sds.shape)) * sds.dtype.itemsize
        if hasattr(sh, "spec"):
            denom = 1
            for entry in sh.spec:
                if entry is None:
                    continue
                for ax in (entry if isinstance(entry, tuple) else (entry,)):
                    denom *= mesh.shape[ax]
            n //= max(denom, 1)
        total += n

    jax.tree.map(one, args, shardings,
                 is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return total


def run_cell(arch: str, shape: str, mesh, mesh_name: str, plan=None, remat=True,
             level: str = "baseline") -> dict:
    cfg = get_config(arch)
    t0 = time.time()
    fn, args, in_sh, out_sh, plan = step_fn_and_specs(
        cfg, shape, mesh, plan=plan, remat=remat, level=level)
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_d = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_d[k] = int(v)
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax returns one dict per device
        cost = cost[0] if cost else {}
    cost_d = {k: float(v) for k, v in cost.items() if isinstance(v, (int, float)) and not k.startswith("utilization")}

    hlo = compiled.as_text()
    coll = collective_stats(hlo, default_trip=cfg.n_layers)
    traffic_b, result_b = total_collective_bytes(coll)
    census = op_census(hlo)

    n_chips = int(np.prod(list(mesh.shape.values())))
    seq, batch, kind = SHAPES[shape]
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    arg_bytes = sharded_arg_bytes(args, in_sh, mesh)
    out_bytes = 0
    if out_sh is not None:
        try:
            out_sds = jax.eval_shape(fn, *args)
            out_bytes = sharded_arg_bytes(out_sds, out_sh, mesh)
        except Exception:
            pass
    # terms are seconds-per-step on the per-device partitioned module.
    # memory: XLA:CPU 'bytes accessed' is pre-fusion and bf16-upcast-inflated;
    # the analytic term (inputs read once + outputs written once) is the
    # TPU-realistic floor and is what §Roofline tabulates. Both recorded.
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = (arg_bytes + out_bytes) / HBM_BW
    memory_s_xla = bytes_dev / HBM_BW
    collective_s = traffic_b / ICI_BW

    n_tok = batch * (1 if kind == "decode" else seq)
    n_active = cfg.n_active_params()
    model_flops = (6 if kind == "train" else 2) * n_active * n_tok
    hlo_total = flops_dev * n_chips

    out = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "mesh_shape": {k: int(v) for k, v in mesh.shape.items()},
        "plan": plan.name,
        "kind": kind,
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem_d,
        "arg_bytes_per_device": arg_bytes,
        "out_bytes_per_device": out_bytes,
        "cost_analysis": cost_d,
        "collectives": coll,
        "collective_traffic_bytes_per_device": traffic_b,
        "collective_result_bytes_per_device": result_b,
        "op_census": census,
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "memory_s_xla": memory_s_xla,
            "collective_s": collective_s,
            "dominant": max(
                ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
                key=lambda kv: kv[1],
            )[0],
            "model_flops": model_flops,
            "hlo_flops_total": hlo_total,
            "useful_flops_ratio": model_flops / hlo_total if hlo_total else 0.0,
        },
    }
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", choices=["all"] + list(SHAPES))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--mesh-shape", default=None, help="override e.g. 4x4 (CI)")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--plan", default=None, help="fsdp|tp|tp+seqshard override")
    ap.add_argument("--level", default="baseline", choices=["baseline", "opt"],
                    help="opt = hillclimb levers (shard_map EP MoE, ws decode)")
    ap.add_argument("--tag", default="", help="suffix for result filenames")
    args = ap.parse_args(argv)

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    archs = ARCH_IDS if args.arch == "all" else [ARCH_ALIASES.get(args.arch, args.arch).replace("-", "_")]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    plan = None
    if args.plan:
        plan = make_plan(args.plan, fsdp="fsdp" in args.plan, seq_shard="seqshard" in args.plan)

    n_ok = n_skip = n_fail = 0
    for mesh_name in meshes:
        mesh, _ = _mesh_from_arg(mesh_name, args.mesh_shape)
        for arch in archs:
            cfg = get_config(arch)
            for shape in shapes:
                cell_id = f"{arch}__{shape}__{mesh_name}" + (f"__{args.tag}" if args.tag else "")
                path = outdir / f"{cell_id}.json"
                if path.exists() and not args.force:
                    print(f"[skip-cached] {cell_id}")
                    n_ok += 1
                    continue
                if shape == "long_500k" and not cfg.sub_quadratic:
                    path.write_text(json.dumps({
                        "arch": arch, "shape": shape, "mesh": mesh_name,
                        "skipped": True,
                        "note": "pure full-attention arch; see DESIGN.md §4",
                    }, indent=1))
                    print(f"[skip-noted ] {cell_id}")
                    n_skip += 1
                    continue
                try:
                    res = run_cell(arch, shape, mesh, mesh_name, plan=plan,
                                   remat=not args.no_remat, level=args.level)
                    path.write_text(json.dumps(res, indent=1))
                    r = res["roofline"]
                    print(
                        f"[ok] {cell_id}: compile={res['compile_s']}s "
                        f"flops/dev={res['cost_analysis'].get('flops', 0):.3e} "
                        f"terms(c/m/coll)={r['compute_s']:.2e}/{r['memory_s']:.2e}/"
                        f"{r['collective_s']:.2e}s dominant={r['dominant']}",
                        flush=True,
                    )
                    n_ok += 1
                except Exception:
                    n_fail += 1
                    err = traceback.format_exc()
                    (outdir / f"{cell_id}.FAILED.txt").write_text(err)
                    print(f"[FAIL] {cell_id}:\n{err}", flush=True)
    print(f"dryrun done: ok={n_ok} skipped={n_skip} failed={n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
