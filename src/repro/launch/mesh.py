"""Production mesh definitions (see MULTI-POD DRY-RUN spec).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The single-pod mesh is
16x16 = 256 chips ("data", "model"); the multi-pod mesh is 2x16x16 = 512
chips ("pod", "data", "model") — the "pod" axis composes with "data" for
batch/FSDP sharding and carries the cross-pod (DCN) collectives.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke runs of the sharded code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))
