"""Roofline aggregation: dry-run JSONs -> per-cell table + hillclimb picks.

    PYTHONPATH=src python -m repro.launch.roofline [--dir benchmarks/results/dryrun]
                                                   [--mesh single] [--md]

Terms (seconds/step, per-device partitioned module — v5e constants):
  compute    = HLO flops / 197e12
  memory     = (input bytes read + output bytes written)/dev / 819e9
               (analytic floor; XLA:CPU 'bytes accessed' kept as x-check)
  collective = modeled ring traffic / 50e9
Roofline fraction = compute / max(terms): 1.0 = compute-bound (ideal).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List


def load_cells(dirpath: str, mesh: str = "single") -> List[Dict]:
    out = []
    for p in sorted(Path(dirpath).glob(f"*__{mesh}.json")):
        d = json.loads(p.read_text())
        out.append(d)
    return out


def summarize_cell(d: Dict) -> Dict:
    if d.get("skipped"):
        return {"arch": d["arch"], "shape": d["shape"], "skipped": True, "note": d["note"]}
    r = d["roofline"]
    terms = {"compute": r["compute_s"], "memory": r["memory_s"], "collective": r["collective_s"]}
    tmax = max(terms.values())
    frac = terms["compute"] / tmax if tmax > 0 else 1.0
    return {
        "arch": d["arch"],
        "shape": d["shape"],
        "plan": d.get("plan", "?"),
        "compute_s": terms["compute"],
        "memory_s": terms["memory"],
        "collective_s": terms["collective"],
        "dominant": r["dominant"],
        "roofline_fraction": frac,
        "useful_flops_ratio": r.get("useful_flops_ratio", 0.0),
        "hbm_gb_per_dev": (d.get("arg_bytes_per_device", 0) + 0.0) / 1e9,
        "compile_s": d.get("compile_s", 0),
    }


LEVERS = {
    ("collective", "moe"): "explicit shard_map all-to-all dispatch instead of XLA scatter-gather",
    ("collective", "any"): "reduce-scatter+all-gather instead of all-reduce; overlap with compute",
    ("memory", "decode"): "shard KV heads / ring-buffer SWA cache / int8 KV",
    ("memory", "train"): "saveable-dots remat policy; fused optimizer update",
    ("compute", "any"): "already compute-bound: larger per-chip batch or faster kernels",
}


def lever_for(row: Dict, kind_hint: str) -> str:
    dom = row["dominant"]
    if dom == "collective" and "moe" in kind_hint:
        return LEVERS[("collective", "moe")]
    if dom == "collective":
        return LEVERS[("collective", "any")]
    if dom == "memory" and "decode" in kind_hint:
        return LEVERS[("memory", "decode")]
    if dom == "memory":
        return LEVERS[("memory", "train")]
    return LEVERS[("compute", "any")]


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | plan | compute s | memory s | collective s | dominant "
           "| roofline frac | useful flops | HBM GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | SKIP | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['plan']} | {r['compute_s']:.2e} "
            f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} | {r['dominant']} "
            f"| {r['roofline_fraction']:.3f} | {r['useful_flops_ratio']:.2f} "
            f"| {r['hbm_gb_per_dev']:.1f} |"
        )
    return hdr + "\n".join(lines)


def pick_hillclimb(rows: List[Dict]) -> Dict[str, Dict]:
    """Three DISTINCT cells: worst fraction, most collective-bound, and the
    serving-decode cell most representative of the paper's technique."""
    live = [r for r in rows if not r.get("skipped")]
    key = lambda r: (r["arch"], r["shape"])
    coll = max(live, key=lambda r: r["collective_s"])
    worst = min((r for r in live if key(r) != key(coll)),
                key=lambda r: r["roofline_fraction"])
    taken = {key(coll), key(worst)}
    serving = [r for r in live if r["shape"] in ("decode_32k", "long_500k")
               and key(r) not in taken]
    rep = max(serving, key=lambda r: max(r["memory_s"], r["collective_s"]))
    return {"worst_fraction": worst, "most_collective_bound": coll, "paper_representative": rep}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args(argv)
    rows = [summarize_cell(d) for d in load_cells(args.dir, args.mesh)]
    if args.md:
        print(markdown_table(rows))
    else:
        for r in rows:
            print(json.dumps(r))
    picks = pick_hillclimb(rows)
    print("\n## hillclimb picks")
    for k, v in picks.items():
        print(f"- {k}: {v['arch']} x {v['shape']} (dominant={v['dominant']}, "
              f"frac={v['roofline_fraction']:.4f})")


if __name__ == "__main__":
    main()
