"""Serving launcher CLI — the paper's system end to end.

    PYTHONPATH=src python -m repro.launch.serve --scheduler hiku \
        --workers 3 --endpoints 4 --requests 24 [--fail-at 12]

Deploys N endpoints (reduced-config JAX models) over simulated worker hosts,
drives a seeded Azure-skewed request stream through the chosen scheduler, and
prints per-request outcomes + summary.  ``--fail-at`` kills the busiest
worker mid-run and elastically joins a replacement (fault-tolerance demo).
"""

from __future__ import annotations

import argparse
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.scheduler import available_schedulers
from repro.core.trace import azure_like_weights
from repro.serving import Endpoint, ServingEngine


def _endpoint(name, seed):
    cfg = get_config("minicpm_2b").reduced()
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                              head_dim=16, d_ff=64, vocab=64)
    return Endpoint(name, cfg, seed=seed, max_cache_len=48)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="hiku", choices=available_schedulers())
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--endpoints", type=int, default=4)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    eps = [_endpoint(f"fn-{i}", i) for i in range(args.endpoints)]
    eng = ServingEngine(eps, n_workers=args.workers, scheduler=args.scheduler,
                        seed=args.seed)
    rng = np.random.default_rng(args.seed)
    weights = azure_like_weights(args.endpoints, args.seed)
    print(f"scheduler={args.scheduler} workers={args.workers} "
          f"endpoints={args.endpoints} (Azure-skewed popularity)")
    for i in range(args.requests):
        f = f"fn-{rng.choice(args.endpoints, p=weights)}"
        tokens = jnp.ones((args.batch, 8), jnp.int32)
        r = eng.submit(f, tokens=tokens, gen_len=2)
        print(f"  [{i:03d}] {r.func:6s} -> w{r.worker} "
              f"{'COLD' if r.cold else 'warm'} {r.latency_ms:9.1f} ms "
              f"(sched {r.sched_overhead_ms*1e3:.1f} us)")
        if args.fail_at is not None and i == args.fail_at:
            victim = r.worker
            eng.fail_worker(victim)
            new_id = max(eng.workers) + 1
            eng.add_worker(new_id)
            print(f"  !! worker {victim} failed; worker {new_id} joined")
    s = eng.summary()
    print(f"summary: n={s['n']} mean={s['mean_latency_ms']:.1f}ms "
          f"cold_rate={s['cold_rate']:.0%} sched_overhead={s['sched_overhead_ms']:.4f}ms")


if __name__ == "__main__":
    main()
