"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

``input_specs(cfg, shape_name)`` returns the exact abstract inputs each step
function consumes — weak-type-correct, shardable, zero device allocation.
``step_fn_and_specs`` assembles the full (fn, args, in_shardings) triple for
train / prefill / decode cells, including abstract params, optimizer state
and KV caches.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from ..configs.base import SHAPES, ModelConfig
from ..models import build_model, unzip
from ..models.frontends import AUDIO_MEMORY_T
from ..sharding.rules import ShardingPlan, auto_plan, logical_to_mesh, param_shardings
from ..training.optimizer import OptConfig, OptState
from ..training.train_step import make_serve_steps, make_train_step

import os as _os

PARAM_DTYPE = jnp.bfloat16
#: KV-cache dtype; REPRO_CACHE_DTYPE=float8_e4m3fn halves the decode memory
#: term (§Perf iteration: fp8 KV, the vLLM-style serving trade-off).
CACHE_DTYPE = getattr(jnp, _os.environ.get("REPRO_CACHE_DTYPE", "bfloat16"))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def whisper_decoder_len(seq: int) -> int:
    return max(seq // 8, 8)


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract model inputs for one assigned shape (tokens/frames/patches)."""
    seq, batch, kind = SHAPES[shape_name]
    if kind == "decode":
        return {"tokens": _sds((batch, 1), jnp.int32)}
    if cfg.enc_dec:
        return {
            "frames": _sds((batch, seq, cfg.d_model), PARAM_DTYPE),
            "tokens": _sds((batch, whisper_decoder_len(seq)), jnp.int32),
        }
    specs = {"tokens": _sds((batch, seq), jnp.int32)}
    if cfg.family == "vlm":
        specs["patches"] = _sds((batch, cfg.n_frontend_tokens, cfg.d_model), PARAM_DTYPE)
    return specs


def batch_axes(cfg: ModelConfig, shape_name: str) -> Dict[str, tuple]:
    seq, batch, kind = SHAPES[shape_name]
    if kind == "decode":
        return {"tokens": ("batch", None)}
    ax = {"tokens": ("batch", "seq")}
    if cfg.enc_dec:
        ax["frames"] = ("batch", "seq", "embed")
    if cfg.family == "vlm":
        ax["patches"] = ("batch", None, "embed")
    return ax


def abstract_params(model, max_seq: int = 4096):
    p = jax.eval_shape(lambda k: model.init(k, max_seq=max_seq), jax.random.key(0))
    return unzip(p)


def step_fn_and_specs(
    cfg: ModelConfig,
    shape_name: str,
    mesh,
    plan: Optional[ShardingPlan] = None,
    remat: bool = True,
    level: str = "baseline",
):
    """Returns (fn, arg_specs, in_shardings, out_shardings|None, plan)."""
    seq, batch, kind = SHAPES[shape_name]
    n_model = mesh.shape.get("model", 1)
    plan = plan or auto_plan(cfg, kind, n_model=n_model, batch=batch, level=level)
    model = build_model(cfg, param_dtype=PARAM_DTYPE, remat=remat)

    max_seq = seq if (cfg.enc_dec or kind != "train") else seq
    params_sds, axes = abstract_params(model, max_seq=max_seq)
    p_shard = param_shardings(mesh, plan, axes, params_sds)
    repl = NamedSharding(mesh, PartitionSpec())

    batch_sds = input_specs(cfg, shape_name)
    b_ax = batch_axes(cfg, shape_name)
    b_shard = {
        k: logical_to_mesh(mesh, plan.activation_rules, b_ax[k], v.shape)
        for k, v in batch_sds.items()
    }

    if kind == "train":
        opt_sds = OptState(
            m=jax.tree.map(lambda s: _sds(s.shape, jnp.float32), params_sds),
            v=jax.tree.map(lambda s: _sds(s.shape, jnp.float32), params_sds),
            step=_sds((), jnp.int32),
        )
        opt_shard = OptState(m=p_shard, v=p_shard, step=repl)
        fn = make_train_step(model, mesh, plan, OptConfig(schedule=cfg.lr_schedule))
        args = (params_sds, opt_sds, batch_sds)
        in_sh = (p_shard, opt_shard, b_shard)
        metrics_sds = jax.eval_shape(fn, *args)[2]
        out_sh = (p_shard, opt_shard, jax.tree.map(lambda _: repl, metrics_sds))
        return fn, args, in_sh, out_sh, plan

    def _cache_shardings(cache_tree):
        c_ax = model.cache_axes()
        return jax.tree.map(
            lambda names, s: logical_to_mesh(mesh, plan.activation_rules, names, s.shape),
            c_ax,
            cache_tree,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(i, (str, type(None))) for i in x),
        )

    if kind == "prefill":
        prefill_step, _ = make_serve_steps(model, mesh, plan)
        args = (params_sds, batch_sds)
        in_sh = (p_shard, b_shard)
        cache_out, logits_out = jax.eval_shape(prefill_step, *args)
        logits_sh = logical_to_mesh(
            mesh, plan.activation_rules, ("batch", "vocab"), logits_out.shape
        )
        out_sh = (_cache_shardings(cache_out), logits_sh)
        return prefill_step, args, in_sh, out_sh, plan

    # decode
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(batch, seq, dtype=CACHE_DTYPE, memory_t=AUDIO_MEMORY_T)
    )
    cache_shard = _cache_shardings(cache_sds)
    _, decode_step = make_serve_steps(model, mesh, plan)
    args = (params_sds, batch_sds["tokens"], cache_sds, _sds((), jnp.int32))
    in_sh = (p_shard, b_shard["tokens"], cache_shard, repl)
    logits_out, cache_out = jax.eval_shape(decode_step, *args)
    logits_sh = logical_to_mesh(mesh, plan.activation_rules, ("batch", "vocab"), logits_out.shape)
    out_sh = (logits_sh, _cache_shardings(cache_out))
    return decode_step, args, in_sh, out_sh, plan
