"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --steps 200 \
        [--reduced] [--ckpt-dir /tmp/ckpt] [--resume] [--batch 8] [--seq 128]

On this CPU container ``--reduced`` (default) trains the reduced config of
the chosen architecture on the synthetic Markov LM; on a real TPU cluster the
same entry point runs the full config against the production mesh (the step
function and sharding plans are identical — see launch/dryrun.py for the
compile-level proof across all 40 cells).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model, unzip
from repro.training import OptConfig, init_opt_state, make_train_step
from repro.training.checkpoint import latest_step, wait_pending
from repro.training.data import DataConfig, MarkovLM
from repro.training.elastic import elastic_resume, save_for_elastic


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, remat=not args.reduced)
    data = MarkovLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch, seed=0))
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 10),
                        total_steps=args.steps, schedule=cfg.lr_schedule)
    step_fn = jax.jit(make_train_step(model, opt_cfg=opt_cfg))

    start = 0
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        params, opt, start = elastic_resume(args.ckpt_dir, model, mesh)
        print(f"resumed from step {start}")
    else:
        params, _ = unzip(model.init(jax.random.key(0), max_seq=args.seq))
        opt = init_opt_state(params)

    print(f"training {cfg.name} ({cfg.n_params()/1e6:.1f}M params) "
          f"for {args.steps} steps, schedule={opt_cfg.schedule}")
    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, m = step_fn(params, opt, batch)
        if args.ckpt_dir and i and i % args.ckpt_every == 0:
            save_for_elastic(args.ckpt_dir, i, params, opt)
        if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
            print(f"  step {i:5d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} gnorm={float(m['grad_norm']):.2f}")
    if args.ckpt_dir:
        save_for_elastic(args.ckpt_dir, args.steps, params, opt, async_=False)
        wait_pending(args.ckpt_dir)
    dt = time.time() - t0
    print(f"done: {args.steps - start} steps in {dt:.1f}s "
          f"({(args.steps - start) / max(dt, 1e-9):.2f} steps/s)")


if __name__ == "__main__":
    main()
