from .layers import Param, is_param, stack_params, unzip
from .model import Model, build_model

__all__ = ["Model", "Param", "build_model", "is_param", "stack_params", "unzip"]
