"""Attention variants: full/GQA, sliding-window, MLA (DeepSeek), cross-attn.

Forward paths:
* ``attn_forward``  — train/prefill over (B, S, d); returns output + KV for the
  cache.  Sliding-window / global masks are driven by *traced per-layer
  scalars* so heterogeneous stacks (gemma3 5:1 local:global) stay scannable.
* ``attn_decode``   — one-token step against a fixed-size KV cache
  (flash-decode semantics; the Pallas kernel in kernels/decode_attention.py
  implements the same contraction).
* ``mla_*``         — MLA with the *absorbed* decode path: the cache holds the
  compressed latent (kv_lora + rope dims) and queries are absorbed through
  W_UK / W_UV, so decode never materializes per-head K/V (DeepSeek-V2/V3).

The XLA (einsum) implementation is the reference and the dry-run path; Pallas
kernels are drop-in replacements on TPU via ``impl="pallas"`` (kernels/ops.py).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Param, apply_rope, param, rmsnorm

_NEG_INF = -2.0e38
GLOBAL_WINDOW = jnp.int32(2**30)  # "window" value meaning full attention


# ------------------------------------------------------------------- params
def init_attention(key, cfg, dtype=jnp.float32) -> Dict[str, Param]:
    d, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 8)
    p = {
        "wq": param(ks[0], (d, H, hd), ("embed", "heads", "head_dim"), dtype),
        "wk": param(ks[1], (d, KH, hd), ("embed", "kv_heads", "head_dim"), dtype),
        "wv": param(ks[2], (d, KH, hd), ("embed", "kv_heads", "head_dim"), dtype),
        "wo": param(ks[3], (H, hd, d), ("heads", "head_dim", "embed"), dtype),
    }
    if cfg.use_bias:
        p["bq"] = param(ks[4], (H, hd), ("heads", "head_dim"), dtype, init="zeros")
        p["bk"] = param(ks[5], (KH, hd), ("kv_heads", "head_dim"), dtype, init="zeros")
        p["bv"] = param(ks[6], (KH, hd), ("kv_heads", "head_dim"), dtype, init="zeros")
        p["bo"] = param(ks[7], (d,), ("embed",), dtype, init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = param(ks[4], (hd,), ("head_dim",), init="zeros")
        p["k_norm"] = param(ks[5], (hd,), ("head_dim",), init="zeros")
    return p


def init_mla(key, cfg, dtype=jnp.float32) -> Dict[str, Param]:
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wq_a": param(ks[0], (d, m.q_lora_rank), ("embed", "q_lora"), dtype),
        "q_norm": param(ks[1], (m.q_lora_rank,), ("q_lora",), init="zeros"),
        "wq_b": param(ks[2], (m.q_lora_rank, H, qk_hd), ("q_lora", "heads", "head_dim"), dtype),
        "wkv_a": param(ks[3], (d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", "kv_lora"), dtype),
        "kv_norm": param(ks[4], (m.kv_lora_rank,), ("kv_lora",), init="zeros"),
        "wkv_b": param(
            ks[5],
            (m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim),
            ("kv_lora", "heads", "head_dim"),
            dtype,
        ),
        "wo": param(ks[6], (H, m.v_head_dim, d), ("heads", "head_dim", "embed"), dtype),
    }


# -------------------------------------------------------------------- core
def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, window, causal: bool) -> jax.Array:
    """(Sq, Sk) additive mask. window is a traced int scalar (GLOBAL_WINDOW=full)."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= dk <= dq
    ok &= (dq - dk) < window  # sliding window (no-op when window is huge)
    return jnp.where(ok, 0.0, _NEG_INF)


def sdpa(
    q: jax.Array,  # (B, Sq, KH, G, hd)
    k: jax.Array,  # (B, Sk, KH, hd)
    v: jax.Array,  # (B, Sk, KH, hd)
    bias: Optional[jax.Array],  # broadcastable to (B, KH, G, Sq, Sk)
    softcap: Optional[float] = None,
) -> jax.Array:
    """Grouped-query attention without materializing repeated K/V."""
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32)
    logits = logits * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    if bias is not None:
        logits = logits + bias
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


# --------------------------------------------------------------- GQA paths
def attn_forward(
    p: Dict[str, jax.Array],
    x: jax.Array,  # (B, S, d)
    cfg,
    positions: jax.Array,  # (B, S)
    window=None,  # traced scalar or None -> full
    theta=None,
    causal: bool = True,
    kv_memory: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attn K/V source
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    B, S, _ = x.shape
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    G = H // KH
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    if kv_memory is None:
        k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
        v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        k_pos = positions[0]
    else:
        mem, mem_pos = kv_memory
        k = jnp.einsum("btd,dhe->bthe", mem, p["wk"])
        v = jnp.einsum("btd,dhe->bthe", mem, p["wv"])
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        k_pos = mem_pos[0]
        causal = False
    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if cfg.rope and kv_memory is None:
        th = theta if theta is not None else cfg.rope_theta
        q = apply_rope(q, positions, th)
        k = apply_rope(k, positions, th)
    w = window if window is not None else GLOBAL_WINDOW
    bias = _mask_bias(positions[0], k_pos, w, causal)[None, None, None]
    qg = q.reshape(B, S, KH, G, hd)
    out = sdpa(qg, k, v, bias, cfg.attn_logit_softcap).reshape(B, S, H, hd)
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    if "bo" in p:
        y = y + p["bo"]
    return y, (k, v)


def attn_decode(
    p: Dict[str, jax.Array],
    x: jax.Array,  # (B, 1, d)
    cache: Tuple[jax.Array, jax.Array],  # k/v: (B, S_cache, KH, hd)
    cfg,
    cache_index: jax.Array,  # scalar int32 OR (B,) per-slot positions
    window=None,
    theta=None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One-token decode; writes the new KV at ``cache_index`` (ring-free).

    ``cache_index`` may be a scalar (whole batch at one position — the
    dry-run/serving fast path, lowered as dynamic_update_slice) or a (B,)
    vector (continuous batching: each slot at its own age, lowered as a
    per-row scatter; see serving/batching.py).
    """
    B = x.shape[0]
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    G = H // KH
    k_cache, v_cache = cache
    S = k_cache.shape[1]
    per_slot = jnp.ndim(cache_index) == 1
    idx_vec = jnp.broadcast_to(jnp.asarray(cache_index, jnp.int32).reshape(-1), (B,))
    pos = idx_vec[:, None]
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k_new = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v_new = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    if "bq" in p:
        q, k_new, v_new = q + p["bq"], k_new + p["bk"], v_new + p["bv"]
    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"])
        k_new = rmsnorm(k_new, p["k_norm"])
    if cfg.rope:
        th = theta if theta is not None else cfg.rope_theta
        q = apply_rope(q, pos, th)
        k_new = apply_rope(k_new, pos, th)
    if per_slot:
        rows = jnp.arange(B)
        wr = jnp.minimum(idx_vec, S - 1)
        k_cache = k_cache.at[rows, wr].set(k_new[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[rows, wr].set(v_new[:, 0].astype(v_cache.dtype))
    else:
        idx = jnp.minimum(jnp.asarray(cache_index, jnp.int32), S - 1)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype), (0, idx, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype), (0, idx, 0, 0))
    w = window if window is not None else GLOBAL_WINDOW
    k_pos = jnp.arange(S, dtype=jnp.int32)
    valid = (k_pos[None, :] <= idx_vec[:, None]) & ((idx_vec[:, None] - k_pos[None, :]) < w)
    bias = jnp.where(valid, 0.0, _NEG_INF)[:, None, None, None, :]
    qg = q.reshape(B, 1, KH, G, hd)
    out = sdpa(qg, k_cache.astype(q.dtype), v_cache.astype(q.dtype), bias, cfg.attn_logit_softcap)
    y = jnp.einsum("bshe,hed->bsd", out.reshape(B, 1, H, hd), p["wo"])
    if "bo" in p:
        y = y + p["bo"]
    return y, (k_cache, v_cache)


# --------------------------------------------------------------- MLA paths
def _mla_qkv(p, x, cfg, positions):
    m = cfg.mla
    H = cfg.n_heads
    q = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    q = rmsnorm(q, p["q_norm"])
    q = jnp.einsum("bsr,rhe->bshe", q, p["wq_b"])  # (B,S,H,nope+rope)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, p["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(
    p, x: jax.Array, cfg, positions: jax.Array
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Train/prefill MLA with expanded per-head K/V (standard formulation)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, positions)
    kv = jnp.einsum("bsr,rhe->bshe", c_kv, p["wkv_b"])
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, m.qk_rope_head_dim))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    bias = _mask_bias(positions[0], positions[0], GLOBAL_WINDOW, True)[None, None, None]
    out = sdpa(q.reshape(B, S, H, 1, -1), k, v, bias)
    y = jnp.einsum("bshe,hed->bsd", out.reshape(B, S, H, m.v_head_dim), p["wo"])
    # cache = compressed latent + shared rope key (absorbed decode reads these)
    return y, (c_kv, k_rope)


def mla_decode(
    p, x: jax.Array, cache: Tuple[jax.Array, jax.Array], cfg, cache_index: jax.Array
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Absorbed MLA decode: latent cache only, no per-head K/V materialized."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    c_cache, r_cache = cache  # (B,S,kv_lora), (B,S,rope)
    S = c_cache.shape[1]
    per_slot = jnp.ndim(cache_index) == 1
    idx_vec = jnp.broadcast_to(jnp.asarray(cache_index, jnp.int32).reshape(-1), (B,))
    pos = idx_vec[:, None]
    q_nope, q_rope, c_new, r_new = _mla_qkv(p, x, cfg, pos)
    if per_slot:
        rows = jnp.arange(B)
        wr = jnp.minimum(idx_vec, S - 1)
        c_cache = c_cache.at[rows, wr].set(c_new[:, 0].astype(c_cache.dtype))
        r_cache = r_cache.at[rows, wr].set(r_new[:, 0].astype(r_cache.dtype))
    else:
        idx = jnp.minimum(jnp.asarray(cache_index, jnp.int32), S - 1)
        c_cache = jax.lax.dynamic_update_slice(c_cache, c_new.astype(c_cache.dtype), (0, idx, 0))
        r_cache = jax.lax.dynamic_update_slice(r_cache, r_new.astype(r_cache.dtype), (0, idx, 0))
    # absorb q through W_UK:  (B,1,H,nope) x (r,H,nope) -> (B,H,r)
    w_uk = p["wkv_b"][..., : m.qk_nope_head_dim]
    w_uv = p["wkv_b"][..., m.qk_nope_head_dim :]
    q_lat = jnp.einsum("bshe,rhe->bhr", q_nope, w_uk)
    scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_nope_head_dim + m.qk_rope_head_dim))
    c = c_cache.astype(x.dtype)
    r = r_cache.astype(x.dtype)
    scores = jnp.einsum("bhr,bsr->bhs", q_lat, c, preferred_element_type=jnp.float32)
    scores += jnp.einsum("bshe,bte->bht", q_rope, r, preferred_element_type=jnp.float32)
    k_pos = jnp.arange(S, dtype=jnp.int32)
    valid = k_pos[None, :] <= idx_vec[:, None]  # (B, S)
    scores = jnp.where(valid[:, None, :], scores * scale, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhs,bsr->bhr", probs, c)
    out = jnp.einsum("bhr,rhe->bhe", o_lat, w_uv)  # (B,H,v_head)
    y = jnp.einsum("bhe,hed->bd", out, p["wo"])[:, None, :]
    return y, (c_cache, r_cache)
