"""Modality frontend STUBS (per assignment: backbone only, frontend stubbed).

``[audio]`` / ``[vlm]`` architectures receive *precomputed* frame/patch
embeddings through ``input_specs()``; these helpers document the shapes and
provide synthetic embeddings for smoke tests and examples.

* whisper-small — the conv1d x2 + GELU frontend that maps 80-mel spectrogram
  frames to d_model embeddings is stubbed: inputs are post-conv frames
  (B, T, 768).  Real Whisper: T=1500 for 30 s audio.
* llava-next — the CLIP-ViT anyres tower + 2-layer MLP projector is stubbed:
  inputs are pre-projected patch embeddings (B, 2880, 4096); anyres tiling of
  a 672x672 image = (4 tiles + 1 base) x 576 patches.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

AUDIO_MEMORY_T = 1500  # whisper 30s encoder length used by serving


def synth_audio_frames(key, batch: int, t: int, d_model: int, dtype=jnp.float32):
    return jax.random.normal(key, (batch, t, d_model), dtype) * 0.02


def synth_patches(key, batch: int, n_patches: int, d_model: int, dtype=jnp.float32):
    return jax.random.normal(key, (batch, n_patches, d_model), dtype) * 0.02
