"""Parameter system + common layers (functional JAX, no framework deps).

Parameters are nested dicts with ``Param`` leaves carrying *logical axis
names* alongside the array.  ``unzip`` splits a Param tree into a value tree
(used by forward passes) and an axes tree (consumed by sharding/rules.py to
build NamedShardings) — keeping the definition and its sharding metadata in
one place, MaxText-style.

Logical axes used across the zoo:
  "embed"   — d_model dims            "mlp"     — FFN hidden dims
  "heads"   — query-head dims         "kv_heads"— kv-head dims
  "head_dim"— per-head dims           "vocab"   — vocabulary dims
  "experts" — MoE expert dims         "layers"  — scanned-layer stacking dim
  "ssm_inner"/"ssm_heads"/"ssm_state" — Mamba dims
  "q_lora"/"kv_lora" — MLA latent dims
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    value: Any
    axes: Tuple[Optional[str], ...]

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)


def param(
    key: jax.Array,
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    dtype=jnp.float32,
    scale: Optional[float] = None,
    init: str = "normal",
) -> Param:
    assert len(shape) == len(axes), (shape, axes)
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    else:
        if scale is None:
            fan_in = shape[0] if len(shape) > 1 else max(shape[-1], 1)
            scale = 1.0 / np.sqrt(fan_in)
        v = (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    return Param(v, tuple(axes))


def is_param(x) -> bool:
    return isinstance(x, Param)


def unzip(tree):
    """Param tree -> (values tree, axes tree)."""
    values = jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def stack_params(trees):
    """Stack per-layer Param trees along a leading "layers" axis (for scan)."""

    def _stack(*ps):
        return Param(jnp.stack([p.value for p in ps]), ("layers",) + ps[0].axes)

    return jax.tree_util.tree_map(_stack, *trees, is_leaf=is_param)


# ------------------------------------------------------------------- layers
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: Optional[jax.Array], eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def init_norm(key, cfg, axes=("embed",), dim=None) -> Dict[str, Param]:
    dim = dim if dim is not None else cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": param(key, (dim,), axes, init="zeros")}  # (1+scale) form
    out = {"scale": param(key, (dim,), axes, init="ones")}
    if cfg.norm_bias:
        out["bias"] = param(key, (dim,), axes, init="zeros")
    return out


def apply_norm(p: Dict[str, jax.Array], x: jax.Array, cfg) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p.get("bias"))


def act_fn(name: str) -> Callable[[jax.Array], jax.Array]:
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}[name]


# --------------------------------------------------------------------- MLP
def init_mlp(key, cfg, d_ff: Optional[int] = None, dtype=jnp.float32) -> Dict[str, Param]:
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {}
    if cfg.gated_mlp:
        p["wi_gate"] = param(ks[0], (d, d_ff), ("embed", "mlp"), dtype)
        p["wi_up"] = param(ks[1], (d, d_ff), ("embed", "mlp"), dtype)
    else:
        p["wi_up"] = param(ks[1], (d, d_ff), ("embed", "mlp"), dtype)
    p["wo"] = param(ks[2], (d_ff, d), ("mlp", "embed"), dtype)
    if cfg.use_bias:
        p["bi"] = param(ks[3], (d_ff,), ("mlp",), dtype, init="zeros")
        p["bo"] = param(ks[3], (d,), ("embed",), dtype, init="zeros")
    return p


def apply_mlp(p, x: jax.Array, cfg) -> jax.Array:
    act = act_fn(cfg.act)
    up = x @ p["wi_up"]
    if "bi" in p:
        up = up + p["bi"]
    h = act(x @ p["wi_gate"]) * up if "wi_gate" in p else act(up)
    y = h @ p["wo"]
    if "bo" in p:
        y = y + p["bo"]
    return y


# -------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta) -> jax.Array:
    """x: (..., S, H, head_dim); positions: (..., S) int32; theta scalar."""
    freqs = rope_freqs(x.shape[-1], theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- embeddings
def init_embedding(key, cfg, dtype=jnp.float32) -> Dict[str, Param]:
    ks = jax.random.split(key, 2)
    p = {"tokens": param(ks[0], (cfg.vocab, cfg.d_model), ("vocab", "embed"), dtype, scale=0.02)}
    if not cfg.tied_embeddings:
        p["unembed"] = param(ks[1], (cfg.d_model, cfg.vocab), ("embed", "vocab"), dtype)
    return p


def embed_tokens(p, tokens: jax.Array, cfg, dtype) -> jax.Array:
    x = p["tokens"][tokens].astype(dtype)
    return x * jnp.asarray(cfg.scale_emb, dtype) if cfg.scale_emb != 1.0 else x


def unembed(p, x: jax.Array, cfg) -> jax.Array:
    if cfg.tied_embeddings:
        logits = x @ p["tokens"].astype(x.dtype).T
        if cfg.scale_emb != 1.0:  # MiniCPM: logits scaled by 1/(d/db); fold into emb scale
            logits = logits / jnp.asarray(cfg.scale_emb, x.dtype)
    else:
        logits = x @ p["unembed"].astype(x.dtype)
    if cfg.logit_soft_cap:
        c = cfg.logit_soft_cap
        logits = c * jnp.tanh(logits / c)
    return logits
