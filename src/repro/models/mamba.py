"""Mamba2 (SSD — state-space duality) block: chunked train/prefill + O(1) decode.

Follows the SSD algorithm of Dao & Gu (arXiv:2405.21060): the sequence is
split into chunks; within a chunk the quadratic (attention-like) form is
used, across chunks a linear recurrence carries the (H, P, N) state.  This
pure-jnp version is both the reference for the Pallas ``ssd_scan`` kernel and
the XLA path used by the dry-run.

Decode keeps (conv_state, ssm_state) per layer and costs O(1) per token —
the reason mamba2/zamba2 run the ``long_500k`` shape.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..sharding import shard
from .layers import Param, param, rmsnorm


class MambaState(NamedTuple):
    conv: jax.Array  # (B, d_conv-1, conv_dim)
    ssm: jax.Array   # (B, H, P, N)


def _dims(cfg):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    H = s.nheads(cfg.d_model)
    return s, d_in, H, s.headdim, s.d_state, s.ngroups


def init_mamba(key, cfg, dtype=jnp.float32) -> Dict:
    s, d_in, H, P, N, G = _dims(cfg)
    d = cfg.d_model
    conv_dim = d_in + 2 * G * N
    ks = jax.random.split(key, 6)
    in_dim = 2 * d_in + 2 * G * N + H  # [z, x, B, C, dt]
    return {
        "in_proj": param(ks[0], (d, in_dim), ("embed", "ssm_inner"), dtype),
        "conv_w": param(ks[1], (s.d_conv, conv_dim), (None, "ssm_inner"), dtype, scale=0.5),
        "conv_b": param(ks[2], (conv_dim,), ("ssm_inner",), dtype, init="zeros"),
        "A_log": param(ks[3], (H,), ("ssm_heads",), jnp.float32, init="zeros"),
        "D": param(ks[3], (H,), ("ssm_heads",), jnp.float32, init="ones"),
        "dt_bias": param(ks[4], (H,), ("ssm_heads",), jnp.float32, init="zeros"),
        "norm": param(ks[4], (d_in,), ("ssm_inner",), init="zeros"),
        "out_proj": param(ks[5], (d_in, d), ("ssm_inner", "embed"), dtype),
    }


def _split_proj(cfg, zxbcdt):
    s, d_in, H, P, N, G = _dims(cfg)
    z, xBC, dt = jnp.split(zxbcdt, [d_in, d_in + d_in + 2 * G * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array, prev: jax.Array = None):
    """Depthwise causal conv over (B, S, C); ``prev``: (B, K-1, C) history."""
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[-1]), xBC.dtype)
    xpad = jnp.concatenate([prev, xBC], axis=1)
    out = sum(xpad[:, i : i + xBC.shape[1]] * w[i] for i in range(K))
    new_prev = xpad[:, xpad.shape[1] - (K - 1) :]
    return jax.nn.silu(out + b), new_prev


def _segsum(dA: jax.Array) -> jax.Array:
    """L[..., i, j] = sum_{k=j+1..i} dA_k for i >= j else -inf. dA: (..., Q)."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)  (post-softplus, >0)
    A: jax.Array,   # (H,) negative
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    chunk: int,
    init_state: jax.Array = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """SSD chunked scan. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B_, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    nc = S // chunk
    f32 = jnp.float32

    xc = x.reshape(B_, nc, chunk, H, P).astype(f32)
    dtc = dt.reshape(B_, nc, chunk, H).astype(f32)
    Bc = Bm.reshape(B_, nc, chunk, G, N).astype(f32)
    Cc = Cm.reshape(B_, nc, chunk, G, N).astype(f32)
    BH = jnp.repeat(Bc, rep, axis=3)  # (B,nc,Q,H,N)
    CH = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A.astype(f32)  # (B,nc,Q,H)
    dA_t = jnp.moveaxis(dA, -1, -2)  # (B,nc,H,Q)
    L = jnp.exp(_segsum(dA_t))  # (B,nc,H,Q,Q)

    # intra-chunk (quadratic) term   (c = chunk idx, s = state dim)
    scores = jnp.einsum("bcqhs,bckhs->bchqk", CH, BH) * L
    y_intra = jnp.einsum("bchqk,bckh,bckhp->bcqhp", scores, dtc, xc)

    # chunk states: decay from position j to chunk end
    cs = jnp.cumsum(dA_t, axis=-1)
    decay_to_end = jnp.exp(cs[..., -1:] - cs)  # (B,nc,H,Q)
    states = jnp.einsum("bchq,bcqh,bcqhs,bcqhp->bchps", decay_to_end, dtc, BH, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cs[..., -1])  # (B,nc,H)
    h0 = jnp.zeros((B_, H, P, N), f32) if init_state is None else init_state.astype(f32)

    def step(h, inp):
        st, dec = inp
        h_new = h * dec[..., None, None] + st
        return h_new, h

    (h_final, h_prev) = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # (B,nc,H,P,N) state entering each chunk

    # inter-chunk contribution: C_i . (decay_from_start * h_prev)
    decay_from_start = jnp.exp(cs)  # (B,nc,H,Q)
    y_inter = jnp.einsum("bcqhs,bchps,bchq->bcqhp", CH, h_prev, decay_from_start)

    y = (y_intra + y_inter).reshape(B_, S, H, P)
    return y, h_final


def mamba_forward(
    p, x: jax.Array, cfg, init_state: MambaState = None
) -> Tuple[jax.Array, MambaState]:
    """Full-sequence Mamba2 block. x: (B, S, d)."""
    s, d_in, H, P, N, G = _dims(cfg)
    B_, S, _ = x.shape
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    prev = init_state.conv if init_state is not None else None
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], prev)
    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)
    xs = xs.reshape(B_, S, H, P)
    Bm = Bm.reshape(B_, S, G, N)
    Cm = Cm.reshape(B_, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xs = shard(xs, ("batch", "seq", "ssm_heads", None))
    pad = (-S) % s.chunk
    if pad:
        xs, dt, Bm, Cm = (jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2)) for t in (xs, dt, Bm, Cm))
    ssm0 = init_state.ssm if init_state is not None else None
    y, h = ssd_chunked(xs, dt, A, Bm, Cm, s.chunk, ssm0)
    if pad:
        y = y[:, :S]
    y = y + xs[:, :S] * p["D"][None, None, :, None]  # skip connection (D term)
    y = y.reshape(B_, S, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"], MambaState(conv_state, h)


def mamba_decode(p, x: jax.Array, cfg, state: MambaState) -> Tuple[jax.Array, MambaState]:
    """One-token step. x: (B, 1, d); O(1) state update."""
    s, d_in, H, P, N, G = _dims(cfg)
    B_ = x.shape[0]
    zxbcdt = x[:, 0] @ p["in_proj"]  # (B, in_dim)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    # conv update
    conv = jnp.concatenate([state.conv, xBC[:, None, :]], axis=1)  # (B,K,C)
    w = p["conv_w"]
    out = jnp.einsum("bkc,kc->bc", conv, w) + p["conv_b"]
    xBC = jax.nn.silu(out)
    new_conv = conv[:, 1:]
    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)
    xs = xs.reshape(B_, H, P)
    Bm = Bm.reshape(B_, G, N)
    Cm = Cm.reshape(B_, G, N)
    rep = H // G
    BH = jnp.repeat(Bm, rep, axis=1)  # (B,H,N)
    CH = jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)  # (B,H)
    h = state.ssm * dA[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, BH.astype(jnp.float32), xs.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhpn->bhp", CH.astype(jnp.float32), h) + xs * p["D"][None, :, None]
    y = y.reshape(B_, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    return (y @ p["out_proj"])[:, None, :], MambaState(new_conv, h)


def init_mamba_state(cfg, batch: int, dtype=jnp.float32) -> MambaState:
    s, d_in, H, P, N, G = _dims(cfg)
    conv_dim = d_in + 2 * G * N
    return MambaState(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        ssm=jnp.zeros((batch, H, P, N), jnp.float32),
    )
