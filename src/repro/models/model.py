"""Model assembly: build init/forward/loss/prefill/decode for any ModelConfig.

One entry point serves all 10 assigned architectures:

    model = build_model(get_config("gemma3-4b"))
    params = model.init(jax.random.key(0))
    loss, metrics = model.loss(params, {"tokens": ...})
    cache = model.init_cache(batch, seq)
    logits, cache = model.decode_step(params, tok, cache, cache_index)

Families: decoder-only LM (dense/moe/vlm), SSM (mamba2), hybrid (zamba2),
encoder-decoder audio (whisper).  See DESIGN.md §4 for derivations.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import shard
from .attention import GLOBAL_WINDOW, attn_decode, attn_forward, init_attention
from .layers import (
    Param,
    apply_mlp,
    apply_norm,
    embed_tokens,
    init_embedding,
    init_mlp,
    init_norm,
    param,
    unembed,
    unzip,
)
from .mamba import MambaState, init_mamba, init_mamba_state, mamba_decode, mamba_forward
from .transformer import block_forward, init_block, init_stack, layer_meta, run_stack


def build_model(cfg, param_dtype=jnp.float32, remat: bool = True) -> "Model":
    return Model(cfg, param_dtype, remat)


class Model:
    def __init__(self, cfg, param_dtype=jnp.float32, remat: bool = True):
        self.cfg = cfg
        self.dtype = param_dtype
        self.remat = remat

    # ================================================================ init
    def init(self, key: jax.Array, max_seq: int = 4096):
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 12)
        p: Dict[str, Any] = {"embed": init_embedding(ks[0], cfg, dt)}
        p["final_norm"] = init_norm(ks[1], cfg)

        if cfg.family == "ssm":
            p["layers"] = self._init_ssm_stack(ks[2])
        elif cfg.family == "hybrid":
            p.update(self._init_hybrid(ks[2]))
        elif cfg.enc_dec:
            p.update(self._init_encdec(ks[2], max_seq))
        elif cfg.moe is not None and cfg.moe.n_dense_layers > 0:
            p["dense_stack"] = init_stack(ks[2], cfg, cfg.moe.n_dense_layers, False, dtype=dt)
            p["moe_stack"] = init_stack(
                ks[3], cfg, cfg.n_layers - cfg.moe.n_dense_layers, True, dtype=dt
            )
        elif cfg.moe is not None:
            p["stack"] = init_stack(ks[2], cfg, cfg.n_layers, True, dtype=dt)
        else:
            p["stack"] = init_stack(ks[2], cfg, cfg.n_layers, False, dtype=dt)

        if cfg.mtp_depth:
            p["mtp"] = {
                "proj": param(ks[4], (2 * cfg.d_model, cfg.d_model), ("embed", "embed"), dt),
                "block": init_block(ks[5], cfg, moe_layer=False, dtype=dt),
                "norm_h": init_norm(ks[6], cfg),
                "norm_e": init_norm(ks[7], cfg),
            }
        return p

    def _init_ssm_stack(self, key):
        from .layers import stack_params

        cfg, dt = self.cfg, self.dtype
        keys = jax.random.split(key, cfg.n_layers)
        layers = []
        for k in keys:
            k1, k2 = jax.random.split(k)
            layers.append({"ln": init_norm(k1, cfg), "mamba": init_mamba(k2, cfg, dt)})
        return stack_params(layers)

    def _init_hybrid(self, key):
        """Zamba2: scan over (n_layers // every) superblocks of ``every``
        Mamba layers + one shared-transformer application (parity-alternating
        shared weights, dynamically indexed inside the scan body)."""
        from .layers import stack_params

        cfg, dt = self.cfg, self.dtype
        h = cfg.hybrid
        assert cfg.n_layers % h.every == 0, (cfg.n_layers, h.every)
        n_groups = cfg.n_layers // h.every
        ks = jax.random.split(key, cfg.n_layers + 2 * h.n_shared_blocks)
        groups = []
        for g in range(n_groups):
            layers = []
            for e in range(h.every):
                k1, k2 = jax.random.split(ks[g * h.every + e])
                layers.append({"ln": init_norm(k1, cfg), "mamba": init_mamba(k2, cfg, dt)})
            groups.append(stack_params(layers))
        shared = []
        for b in range(h.n_shared_blocks):
            kb = ks[cfg.n_layers + 2 * b]
            kp = ks[cfg.n_layers + 2 * b + 1]
            in_dim = 2 * cfg.d_model if h.concat_embedding else cfg.d_model
            shared.append(
                {
                    "proj": param(kp, (in_dim, cfg.d_model), ("embed", "embed"), dt),
                    "block": init_block(kb, cfg, moe_layer=False, dtype=dt),
                }
            )
        return {"mamba_groups": stack_params(groups), "shared_blocks": stack_params(shared)}

    def _init_encdec(self, key, max_seq: int):
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 6)
        return {
            "enc_pos": param(ks[0], (max_seq, cfg.d_model), (None, "embed"), dt, scale=0.02),
            "dec_pos": param(ks[1], (max_seq, cfg.d_model), (None, "embed"), dt, scale=0.02),
            "encoder": init_stack(ks[2], cfg, cfg.n_encoder_layers, dtype=dt),
            "enc_norm": init_norm(ks[3], cfg),
            "stack": init_stack(ks[4], cfg, cfg.n_layers, cross=True, dtype=dt),
        }

    # ============================================================= forward
    def forward(self, params, batch: Dict[str, jax.Array], mode: str = "train"):
        """Full-sequence forward. Returns (logits, aux, caches_or_None)."""
        cfg = self.cfg
        if cfg.enc_dec:
            return self._forward_encdec(params, batch, mode)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed_tokens(params["embed"], tokens, cfg, self.dtype)
        if cfg.family == "vlm" and "patches" in batch:
            n_img = batch["patches"].shape[1]
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x[:, n_img:]], axis=1)
        x = shard(x, ("batch", "seq", "embed"))
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x_emb = x

        caches = None
        if cfg.family == "ssm":
            x, aux, caches = self._run_ssm(params, x, mode)
        elif cfg.family == "hybrid":
            x, aux, caches = self._run_hybrid(params, x, x_emb, positions, mode)
        else:
            x, aux, caches = self._run_lm_stacks(params, x, positions, mode)
        x = apply_norm(params["final_norm"], x, cfg)
        x = shard(x, ("batch", "seq", "embed"))
        logits = unembed(params["embed"], x, cfg)
        logits = shard(logits, ("batch", "seq", "vocab"))
        if cfg.mtp_depth and mode == "train":
            aux = (aux, self._mtp_hidden(params, x_emb, x, tokens))
        return logits, aux, caches

    def _run_lm_stacks(self, params, x, positions, mode, cache_index=None, caches=None):
        cfg = self.cfg
        aux_total = jnp.float32(0.0)
        out_caches = {}
        if "dense_stack" in params:
            nd = cfg.moe.n_dense_layers
            wd, td = layer_meta(cfg, nd)
            x, c1, a1 = run_stack(
                params["dense_stack"], x, cfg, positions, wd, td, mode,
                caches["dense"] if caches else None, cache_index, remat=self.remat,
            )
            wm, tm = layer_meta(cfg, cfg.n_layers - nd)
            x, c2, a2 = run_stack(
                params["moe_stack"], x, cfg, positions, wm, tm, mode,
                caches["moe"] if caches else None, cache_index, remat=self.remat,
            )
            aux_total = a1 + a2
            out_caches = {"dense": c1, "moe": c2}
        else:
            w, t = layer_meta(cfg)
            x, c, aux_total = run_stack(
                params["stack"], x, cfg, positions, w, t, mode,
                caches["stack"] if caches else None, cache_index, remat=self.remat,
            )
            out_caches = {"stack": c}
        return x, aux_total, (out_caches if mode in ("prefill", "decode") else None)

    def _run_ssm(self, params, x, mode, states=None):
        cfg = self.cfg

        def body(carry, xs):
            h = carry
            if mode == "decode":
                p_l, st_l = xs
                hn = apply_norm(p_l["ln"], h, cfg)
                y, new_st = mamba_decode(p_l["mamba"], hn, cfg, st_l)
            else:
                p_l = xs
                hn = apply_norm(p_l["ln"], h, cfg)
                y, new_st = mamba_forward(p_l["mamba"], hn, cfg)
            out = new_st if mode in ("decode", "prefill") else jnp.zeros((), jnp.float32)
            return h + y, out

        if self.remat and mode == "train":
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        xs = (params["layers"], states["layers"]) if mode == "decode" else params["layers"]
        x, out_states = jax.lax.scan(body, x, xs)
        caches = {"layers": out_states} if mode in ("prefill", "decode") else None
        return x, jnp.float32(0.0), caches

    def _run_hybrid(self, params, x, x_emb, positions, mode, cache_index=None, caches=None):
        cfg = self.cfg
        h = cfg.hybrid
        n_groups = cfg.n_layers // h.every
        shared = params["shared_blocks"]
        parities = jnp.arange(n_groups, dtype=jnp.int32) % h.n_shared_blocks

        def body(carry, xs):
            hcur = carry
            if mode == "decode":
                pg, parity, (st_g, kv_g) = xs
            else:
                pg, parity = xs
                st_g = kv_g = None
            new_states = []
            for e in range(h.every):
                p_l = jax.tree.map(lambda a: a[e], pg)
                hn = apply_norm(p_l["ln"], hcur, cfg)
                if mode == "decode":
                    st = jax.tree.map(lambda a: a[e], st_g)
                    y, st2 = mamba_decode(p_l["mamba"], hn, cfg, st)
                else:
                    y, st2 = mamba_forward(p_l["mamba"], hn, cfg)
                hcur = hcur + y
                if mode in ("prefill", "decode"):
                    new_states.append(st2)
            # shared transformer block (parity-alternating weights)
            sb = jax.tree.map(lambda a: a[parity], shared)
            inp = jnp.concatenate([hcur, x_emb], axis=-1) if h.concat_embedding else hcur
            hb = inp @ sb["proj"]
            yb, kv_out, _ = block_forward(
                sb["block"], hb, cfg, positions, mode=mode, cache=kv_g,
                cache_index=cache_index,
            )
            hcur = hcur + (yb - hb)  # block returns hb+delta; add only the delta
            if mode == "train":
                return hcur, jnp.zeros((), jnp.float32)
            st_stack = jax.tree.map(lambda *a: jnp.stack(a), *new_states)
            return hcur, (st_stack, kv_out)

        if self.remat and mode == "train":
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

        xs = (params["mamba_groups"], parities)
        if mode == "decode":
            xs = xs + ((caches["mamba"], caches["shared_kv"]),)
        x, ys = jax.lax.scan(body, x, xs)
        caches_out = None
        if mode in ("prefill", "decode"):
            caches_out = {"mamba": ys[0], "shared_kv": ys[1]}
        return x, jnp.float32(0.0), caches_out

    def _forward_encdec(self, params, batch, mode):
        cfg = self.cfg
        frames = batch["frames"]  # (B, T, d) post-conv stub embeddings
        tokens = batch["tokens"]  # (B, S_dec)
        B, T, _ = frames.shape
        S = tokens.shape[1]
        memory = frames.astype(self.dtype) + params["enc_pos"][:T].astype(self.dtype)
        memory = shard(memory, ("batch", "seq", "embed"))
        enc_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        w, t = layer_meta(cfg, cfg.n_encoder_layers)
        memory, _, _ = run_stack(
            params["encoder"], memory, cfg, enc_pos, w, t, "train",
            remat=self.remat, causal=False,  # encoder is bidirectional
        )
        memory = apply_norm(params["enc_norm"], memory, cfg)

        x = embed_tokens(params["embed"], tokens, cfg, self.dtype)
        x = x + params["dec_pos"][:S].astype(x.dtype)
        dec_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        wd, td = layer_meta(cfg)
        x, c, aux = run_stack(
            params["stack"], x, cfg, dec_pos, wd, td, mode,
            kv_memory=(memory, enc_pos), remat=self.remat,
        )
        x = apply_norm(params["final_norm"], x, cfg)
        logits = unembed(params["embed"], x, cfg)
        caches = None
        if mode == "prefill":
            caches = {"stack": c, "memory": memory, "enc_pos": enc_pos}
        return logits, aux, caches

    # ================================================================ loss
    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        logits, aux, _ = self.forward(params, batch, mode="train")
        tokens = batch["tokens"]
        labels = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
        mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
        if cfg.family == "vlm" and "patches" in batch:
            n_img = batch["patches"].shape[1]
            mask = mask.at[:, : n_img - 1].set(0.0)  # no loss on image positions
        ce = _xent(logits, labels, mask)
        metrics = {"ce": ce}
        total = ce
        if cfg.moe is not None:
            moe_aux = aux[0] if isinstance(aux, tuple) else aux
            total = total + cfg.moe.router_aux_weight * moe_aux / max(cfg.n_layers, 1)
            metrics["moe_aux"] = moe_aux
        if cfg.mtp_depth and isinstance(aux, tuple):
            mtp_ce = self._mtp_loss(params, aux[1], tokens)
            total = total + 0.1 * mtp_ce
            metrics["mtp_ce"] = mtp_ce
        metrics["loss"] = total
        return total, metrics

    # ---------------------------------------------------------------- MTP
    def _mtp_hidden(self, params, x_emb, h_final, tokens):
        """DeepSeek-V3 MTP depth-1: combine h_t with emb(t+1) to predict t+2."""
        cfg = self.cfg
        m = params["mtp"]
        e_next = jnp.concatenate([x_emb[:, 1:], x_emb[:, -1:]], axis=1)
        hcat = jnp.concatenate(
            [apply_norm(m["norm_h"], h_final, cfg), apply_norm(m["norm_e"], e_next, cfg)], -1
        )
        h = hcat @ m["proj"]
        B, S, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        h, _, _ = block_forward(m["block"], h, cfg, positions, mode="train")
        return h

    def _mtp_loss(self, params, h_mtp, tokens):
        cfg = self.cfg
        logits = unembed(params["embed"], h_mtp, cfg)
        labels = jnp.concatenate([tokens[:, 2:], tokens[:, -1:], tokens[:, -1:]], axis=1)
        mask = jnp.ones_like(labels, jnp.float32).at[:, -2:].set(0.0)
        return _xent(logits, labels, mask)

    # ============================================================ serving
    def prefill(self, params, batch):
        """Forward + cache build. Returns (cache, last-position logits)."""
        logits, _, caches = self.forward(params, batch, mode="prefill")
        return caches, logits[:, -1]

    def decode_step(self, params, tokens, cache, cache_index):
        """tokens: (B, 1) int32 (LM) — one token for the whole batch."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg, self.dtype)
        B = tokens.shape[0]
        # cache_index: scalar (all slots at one age) or (B,) per-slot ages
        idx_vec = jnp.broadcast_to(jnp.asarray(cache_index, jnp.int32).reshape(-1), (B,))
        positions = idx_vec[:, None]
        if cfg.enc_dec:
            pidx = jnp.minimum(idx_vec, params["dec_pos"].shape[0] - 1)
            x = x + jnp.take(params["dec_pos"], pidx, axis=0)[:, None, :].astype(x.dtype)
            wd, td = layer_meta(cfg)
            x, c, _ = run_stack(
                params["stack"], x, cfg, positions, wd, td, "decode",
                caches=cache["stack"], cache_index=cache_index,
                kv_memory=(cache["memory"], cache["enc_pos"]), remat=False,
            )
            cache = {**cache, "stack": c}
        elif cfg.family == "ssm":
            x, _, c = self._run_ssm(params, x, "decode", states=cache)
            cache = c
        elif cfg.family == "hybrid":
            x_emb = x
            x, _, cache = self._run_hybrid(params, x, x_emb, positions, "decode", cache_index, cache)
        else:
            x, _, cache = self._run_lm_stacks(params, x, positions, "decode", cache_index, cache)
        x = apply_norm(params["final_norm"], x, cfg)
        logits = unembed(params["embed"], x, cfg)
        return logits[:, 0], cache

    # ------------------------------------------------------------- caches
    def init_cache(self, batch: int, seq: int, dtype=jnp.bfloat16, memory_t: int = 1500):
        cfg = self.cfg
        if cfg.family == "ssm":
            st = init_mamba_state(cfg, batch, dtype)
            return {"layers": jax.tree.map(lambda a: jnp.stack([a] * cfg.n_layers), st)}
        if cfg.family == "hybrid":
            st = init_mamba_state(cfg, batch, dtype)
            n_groups = cfg.n_layers // cfg.hybrid.every
            every = cfg.hybrid.every
            KH, hd = cfg.n_kv_heads, cfg.head_dim_
            return {
                # (groups, every, B, ...) matching the superblock scan
                "mamba": jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (n_groups, every) + a.shape), st
                ),
                "shared_kv": (
                    jnp.zeros((n_groups, batch, seq, KH, hd), dtype),
                    jnp.zeros((n_groups, batch, seq, KH, hd), dtype),
                ),
            }
        if cfg.enc_dec:
            L, KH, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim_
            return {
                "stack": (
                    jnp.zeros((L, batch, seq, KH, hd), dtype),
                    jnp.zeros((L, batch, seq, KH, hd), dtype),
                ),
                "memory": jnp.zeros((batch, memory_t, cfg.d_model), dtype),
                "enc_pos": jnp.zeros((batch, memory_t), jnp.int32),
            }
        if cfg.mla is not None:
            m = cfg.mla

            def mla_cache(L):
                return (
                    jnp.zeros((L, batch, seq, m.kv_lora_rank), dtype),
                    jnp.zeros((L, batch, seq, m.qk_rope_head_dim), dtype),
                )

            if cfg.moe is not None and cfg.moe.n_dense_layers:
                nd = cfg.moe.n_dense_layers
                return {"dense": mla_cache(nd), "moe": mla_cache(cfg.n_layers - nd)}
            return {"stack": mla_cache(cfg.n_layers)}
        L, KH, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim_
        kv = (
            jnp.zeros((L, batch, seq, KH, hd), dtype),
            jnp.zeros((L, batch, seq, KH, hd), dtype),
        )
        return {"stack": kv}


    def cache_axes(self):
        """Logical-axis names per cache leaf (same structure as init_cache)."""
        cfg = self.cfg
        kv_ax = ("layers", "cache_batch", "seq_kv", "kv_heads", "head_dim")
        if cfg.family == "ssm":
            return {
                "layers": MambaState(
                    conv=("layers", "cache_batch", None, "ssm_inner"),
                    ssm=("layers", "cache_batch", "ssm_heads", None, None),
                )
            }
        if cfg.family == "hybrid":
            return {
                "mamba": MambaState(
                    conv=("layers", None, "cache_batch", None, "ssm_inner"),
                    ssm=("layers", None, "cache_batch", "ssm_heads", None, None),
                ),
                "shared_kv": (kv_ax, kv_ax),
            }
        if cfg.enc_dec:
            return {
                "stack": (kv_ax, kv_ax),
                "memory": ("cache_batch", "seq", "embed"),
                "enc_pos": ("cache_batch", "seq"),
            }
        if cfg.mla is not None:
            c_ax = ("layers", "cache_batch", "seq_kv", "kv_lora")
            r_ax = ("layers", "cache_batch", "seq_kv", None)
            if cfg.moe is not None and cfg.moe.n_dense_layers:
                return {"dense": (c_ax, r_ax), "moe": (c_ax, r_ax)}
            return {"stack": (c_ax, r_ax)}
        return {"stack": (kv_ax, kv_ax)}


def _xent(logits: jax.Array, labels: jax.Array, mask: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (lse - gold) * mask
    return ce.sum() / jnp.maximum(mask.sum(), 1.0)
