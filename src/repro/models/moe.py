"""Mixture-of-Experts with sorted capacity dispatch (Mixtral / DeepSeek-V3).

Dispatch is the sort-based formulation (no (T, E, C) one-hot einsum, which is
infeasible at DeepSeek scale): flatten token->expert assignments, stable-sort
by expert id, compute each token's slot within its expert group, drop beyond
capacity, scatter into an (E, C, d) buffer, run the expert FFNs as one
batched matmul, and scatter-add back weighted by the router gates.

Sharding: the buffer is annotated ("experts", "expert_cap", "embed") so the
expert dim maps to the model axis (expert parallelism) and capacity to the
data axis — the scatter/gather becomes the dispatch all-to-all under SPMD.
DeepSeek-V3 sigmoid routing + shared expert and the Switch-style auxiliary
load-balancing loss are included.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import shard
from ..sharding.ctx import get_ctx
from .layers import Param, act_fn, init_mlp, param

try:  # jax>=0.8 exposes shard_map at top level
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def init_moe(key, cfg, dtype=jnp.float32) -> Dict:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p = {
        "router": param(ks[0], (d, m.n_experts), ("embed", "experts"), jnp.float32),
        "wi_gate": param(ks[1], (m.n_experts, d, m.expert_dff), ("experts", "embed", "mlp"), dtype),
        "wi_up": param(ks[2], (m.n_experts, d, m.expert_dff), ("experts", "embed", "mlp"), dtype),
        "wo": param(ks[3], (m.n_experts, m.expert_dff, d), ("experts", "mlp", "embed"), dtype),
    }
    if m.router == "sigmoid":
        p["router_bias"] = param(ks[4], (m.n_experts,), ("experts",), jnp.float32, init="zeros")
    if m.n_shared:
        import dataclasses as _dc

        shared_cfg = _dc.replace(cfg, gated_mlp=True, use_bias=False)
        p["shared"] = init_mlp(ks[5], shared_cfg, d_ff=m.expert_dff * m.n_shared, dtype=dtype)
    return p


def route(p, x_flat: jax.Array, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (gates (T,k), expert_idx (T,k), aux_loss scalar)."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), p["router"])
    if m.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + p["router_bias"]  # aux-loss-free balancing bias (DSv3)
        gates, idx = jax.lax.top_k(sel, m.top_k)
        gates = jnp.take_along_axis(scores, idx, axis=-1)  # weights use raw scores
        gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
        probs = scores / (scores.sum(-1, keepdims=True) + 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, m.top_k)
        gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
    # Switch-style load-balance aux: E * sum_e f_e * P_e
    T = x_flat.shape[0]
    f = jnp.zeros((m.n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * m.top_k)
    P = probs.mean(0)
    aux = m.n_experts * jnp.sum(f * P)
    return gates.astype(x_flat.dtype), idx, aux


def _dispatch_ffn(x_flat, gates, idx, wg, wu, wo, e0, E_loc, C, act, dtype):
    """Sort-based capacity dispatch restricted to experts [e0, e0+E_loc).

    Returns the combined (T, d) contribution of those experts (zeros for
    tokens routed elsewhere).  Pure function of local data — the shard_map
    bodies below call it with per-shard expert slices.
    """
    T, d = x_flat.shape
    k = idx.shape[-1]
    eid_rel = idx.reshape(-1) - e0
    in_range = (eid_rel >= 0) & (eid_rel < E_loc)
    sort_key = jnp.where(in_range, eid_rel, E_loc)  # out-of-range sorts last
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    order = jnp.argsort(sort_key, stable=True)
    key_s, tok_s = sort_key[order], tok[order]
    counts = jnp.bincount(key_s, length=E_loc + 1)[:E_loc]
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    safe_key = jnp.minimum(key_s, E_loc - 1)
    slot = jnp.arange(T * k, dtype=jnp.int32) - starts[safe_key].astype(jnp.int32)
    keep = (key_s < E_loc) & (slot < C)
    dest = safe_key * C + jnp.clip(slot, 0, C - 1)

    buf = jnp.zeros((E_loc * C, d), dtype)
    buf = buf.at[dest].add(jnp.where(keep[:, None], x_flat[tok_s], 0))
    h = buf.reshape(E_loc, C, d)
    up = jnp.einsum("ecd,edf->ecf", h, wu)
    gate = jnp.einsum("ecd,edf->ecf", h, wg)
    out = jnp.einsum("ecf,efd->ecd", act(gate) * up, wo).reshape(E_loc * C, d)

    gates_s = gates.reshape(-1)[order]
    contrib = out[dest] * jnp.where(keep, gates_s, 0.0)[:, None]
    return jnp.zeros((T, d), dtype).at[tok_s].add(contrib)


def _capacity(cf: float, T: int, k: int, E: int) -> int:
    C = int(cf * T * k / E)
    return max(8, -(-C // 8) * 8)


def apply_moe(p, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).  Dispatches to the shard_map EP path
    when a sharding context with a plan is active (see apply_moe_sharded)."""
    ctx = get_ctx()
    if ctx is not None and ctx[2].get("moe_mode") in ("capacity", "resident"):
        return apply_moe_sharded(p, x, cfg, ctx)

    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    x_flat = x.reshape(T, d)
    gates, idx, aux = route(p, x_flat, cfg)
    C = _capacity(m.capacity_factor, T, m.top_k, m.n_experts)
    y = _dispatch_ffn(
        x_flat, gates, idx, p["wi_gate"], p["wi_up"], p["wo"],
        0, m.n_experts, C, act_fn(cfg.act), x.dtype,
    )
    if "shared" in p:
        from .layers import apply_mlp

        y = y + apply_mlp(p["shared"], x_flat, cfg)
    return y.reshape(B, S, d), aux


def apply_moe_sharded(p, x: jax.Array, cfg, ctx) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map (the §Perf hillclimb path).

    mode="capacity" (train/prefill): tokens stay batch-sharded over the data
      axes (they are replicated over "model" already), each model shard
      locally dispatches to its E/n_model experts and computes; the combine
      is ONE psum of (T_local, d) activations over "model" per layer —
      replacing the XLA scatter/all-reduce of the full (E*C, d) buffer
      (158 TB -> ~GBs for deepseek train; EXPERIMENTS.md §Perf).
    mode="resident" (decode): experts are fully resident, sharded over
      (model x data) with no per-step weight gathers; the (tiny) token batch
      is all-gathered over data instead — weights don't move, tokens do.
    """
    from jax.sharding import PartitionSpec as P

    mesh, rules, extras = ctx
    mode = extras["moe_mode"]
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    act = act_fn(cfg.act)
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_data = math.prod(mesh.shape[a] for a in dp)
    n_model = mesh.shape.get("model", 1)
    E = m.n_experts

    x_flat = x.reshape(T, d)
    gates, idx, aux = route(p, x_flat, cfg)
    wg, wu, wo = p["wi_gate"], p["wi_up"], p["wo"]

    # resident EP: experts owned 1-per-cell over ("model",)+dp_own; any data
    # axes NOT in dp_own (e.g. "pod" on the multi-pod mesh) replicate the
    # expert weights and stay pure data-parallel — pods never exchange MoE
    # traffic at decode.
    dp_own: tuple = ()
    for k_ax in range(len(dp), -1, -1):
        cand = dp[len(dp) - k_ax:]
        nd = math.prod(mesh.shape[a] for a in cand) if cand else 1
        if E % (n_model * nd) == 0:
            dp_own = cand
            break
    n_own = math.prod(mesh.shape[a] for a in dp_own) if dp_own else 1

    if mode == "resident" and E % (n_model * n_own) == 0 and n_model * n_own > 1:
        E_loc = E // (n_model * n_own)
        C = _capacity(m.capacity_factor, T // max(n_data // n_own, 1), m.top_k, E)
        w_spec = P(("model",) + dp_own, None, None)

        def body(xf, g, i, wg_, wu_, wo_):
            if dp_own:
                d_idx = jax.lax.axis_index(dp_own[0])
                for ax in dp_own[1:]:
                    d_idx = d_idx * mesh.shape[ax] + jax.lax.axis_index(ax)
            else:
                d_idx = 0
            e0 = (jax.lax.axis_index("model") * n_own + d_idx) * E_loc
            # weights stay put; the (small) token batch moves within dp_own
            xg = jax.lax.all_gather(xf, dp_own, axis=0, tiled=True) if dp_own else xf
            gg = jax.lax.all_gather(g, dp_own, axis=0, tiled=True) if dp_own else g
            ig = jax.lax.all_gather(i, dp_own, axis=0, tiled=True) if dp_own else i
            contrib = _dispatch_ffn(xg, gg, ig, wg_, wu_, wo_, e0, E_loc, C, act, xf.dtype)
            # combine: joint psum then keep own token slice.  (§Perf
            # iteration 3 tried psum(model) + psum_scatter(data) — measured
            # 8% WORSE under the ring-traffic model; refuted and reverted.)
            out = jax.lax.psum(contrib, ("model",) + dp_own)
            T_loc = xf.shape[0]
            return jax.lax.dynamic_slice_in_dim(out, d_idx * T_loc, T_loc, 0)

    elif E % n_model == 0:  # capacity mode: experts split over "model"
        E_loc = E // n_model
        C = _capacity(m.capacity_factor, T // max(n_data, 1), m.top_k, E)
        w_spec = (P("model", None, None),) * 3

        def body(xf, g, i, wg_, wu_, wo_):
            e0 = jax.lax.axis_index("model") * E_loc
            contrib = _dispatch_ffn(xf, g, i, wg_, wu_, wo_, e0, E_loc, C, act, xf.dtype)
            # NOTE §Perf iteration 2 (refuted): an explicit bf16 cast here is
            # a no-op — with bf16 params the combine is already bf16 on the
            # wire; the f32 volumes in the dry-run HLO are an XLA:CPU
            # upcast artifact, not real TPU traffic.
            return jax.lax.psum(contrib, "model")

    else:  # few-expert archs (mixtral E=8 < 16): TP-within-expert — every
        # rank holds ALL experts on a 1/n_model slice of the FFN dim; the
        # down-proj partials combine in the same single psum per layer.
        C = _capacity(m.capacity_factor, T // max(n_data, 1), m.top_k, E)
        w_spec = (
            P(None, None, "model"),  # wi_gate (E, d, f/16)
            P(None, None, "model"),  # wi_up
            P(None, "model", None),  # wo (E, f/16, d)
        )

        def body(xf, g, i, wg_, wu_, wo_):
            contrib = _dispatch_ffn(xf, g, i, wg_, wu_, wo_, 0, E, C, act, xf.dtype)
            return jax.lax.psum(contrib, "model")

    t_spec = P(dp if dp else None, None)
    if not isinstance(w_spec, tuple):
        w_spec = (w_spec,) * 3
    y = _shard_map(
        body,
        mesh=mesh,
        in_specs=(t_spec, t_spec, t_spec) + w_spec,
        out_specs=t_spec,
        check_vma=False,
    )(x_flat, gates, idx, wg, wu, wo)

    if "shared" in p:
        from .layers import apply_mlp

        y = y + apply_mlp(p["shared"], x_flat, cfg)
    return y.reshape(B, S, d), aux
