"""Transformer blocks + layer stacks for every assigned family.

Homogeneous stacks (dense / MoE / SSM) are scanned with ``jax.lax.scan`` over
stacked parameters — one compiled layer body regardless of depth, which keeps
HLO small for the 512-device dry-run and enables per-layer remat.
Heterogeneity is expressed through *traced per-layer metadata* (gemma3's 5:1
local:global pattern rides through scan as per-layer window/theta arrays).
Structurally different stacks (DeepSeek's 3 dense + 58 MoE layers; whisper's
encoder/decoder; zamba2's shared attention blocks) are composed from several
scans / an unrolled loop with genuinely shared weights.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import shard
from .attention import (
    GLOBAL_WINDOW,
    attn_decode,
    attn_forward,
    init_attention,
    init_mla,
    mla_decode,
    mla_forward,
)
from .layers import (
    apply_mlp,
    apply_norm,
    init_mlp,
    init_norm,
    param,
    stack_params,
)
from .mamba import init_mamba, init_mamba_state, mamba_decode, mamba_forward
from .moe import apply_moe, init_moe


# ----------------------------------------------------------- layer metadata
def layer_meta(cfg, n_layers: Optional[int] = None):
    """(window[i], theta[i]) arrays driving SWA / gemma3 local:global."""
    L = n_layers or cfg.n_layers
    windows, thetas = [], []
    for i in range(L):
        is_global = cfg.global_every is not None and (i + 1) % cfg.global_every == 0
        if cfg.sliding_window is not None and not is_global:
            windows.append(cfg.sliding_window)
            thetas.append(cfg.rope_theta)
        else:
            windows.append(int(GLOBAL_WINDOW))
            thetas.append(cfg.rope_theta_global or cfg.rope_theta)
    return jnp.array(windows, jnp.int32), jnp.array(thetas, jnp.float32)


# ------------------------------------------------------------------- block
def init_block(key, cfg, moe_layer: bool = False, cross: bool = False, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": init_norm(ks[0], cfg)}
    p["attn"] = init_mla(ks[1], cfg, dtype) if cfg.mla else init_attention(ks[1], cfg, dtype)
    if cross:
        p["ln_cross"] = init_norm(ks[2], cfg)
        p["cross"] = init_attention(ks[3], cfg, dtype)
    if not cfg.parallel_block:
        p["ln2"] = init_norm(ks[4], cfg)
    if moe_layer:
        p["moe"] = init_moe(ks[5], cfg, dtype)
    else:
        d_ff = cfg.moe.dense_dff if (cfg.moe and cfg.moe.n_dense_layers) else cfg.d_ff
        p["mlp"] = init_mlp(ks[5], cfg, d_ff=d_ff, dtype=dtype)
    return p


def block_forward(
    p: Dict,
    x: jax.Array,
    cfg,
    positions: jax.Array,
    window=None,
    theta=None,
    mode: str = "train",
    cache=None,
    cache_index=None,
    kv_memory=None,
    causal: bool = True,
) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (x', cache_entry, aux_loss). cache_entry is the new KV for
    prefill/decode modes, None-shaped zeros otherwise."""
    ds = jnp.asarray(cfg.depth_scale, x.dtype)
    h = apply_norm(p["ln1"], x, cfg)
    h = shard(h, ("batch", "seq", "embed"))
    if cfg.mla:
        if mode == "decode":
            a, new_cache = mla_decode(p["attn"], h, cache, cfg, cache_index)
        else:
            a, new_cache = mla_forward(p["attn"], h, cfg, positions)
    else:
        if mode == "decode":
            a, new_cache = attn_decode(p["attn"], h, cache, cfg, cache_index, window, theta)
        else:
            a, new_cache = attn_forward(p["attn"], h, cfg, positions, window, theta, causal=causal)
    aux = jnp.float32(0.0)
    if cfg.parallel_block:
        # Cohere: y = x + attn(n(x)) + mlp(n(x)) (single pre-norm)
        m = apply_mlp(p["mlp"], h, cfg)
        y = x + (a + m) * ds
        return y, new_cache, aux
    x = x + a * ds
    if "cross" in p:
        hc = apply_norm(p["ln_cross"], x, cfg)
        c, _ = attn_forward(p["cross"], hc, cfg, positions, kv_memory=kv_memory)
        x = x + c * ds
    h2 = apply_norm(p["ln2"], x, cfg)
    h2 = shard(h2, ("batch", "seq", "embed"))
    if "moe" in p:
        m, aux = apply_moe(p["moe"], h2, cfg)
    else:
        m = apply_mlp(p["mlp"], h2, cfg)
    return x + m * ds, new_cache, aux


# ----------------------------------------------------------- scanned stack
def init_stack(key, cfg, n_layers: int, moe_layer: bool = False, cross: bool = False, dtype=jnp.float32):
    keys = jax.random.split(key, n_layers)
    return stack_params([init_block(k, cfg, moe_layer, cross, dtype) for k in keys])


def run_stack(
    stack: Dict,
    x: jax.Array,
    cfg,
    positions: jax.Array,
    windows: jax.Array,
    thetas: jax.Array,
    mode: str = "train",
    caches=None,
    cache_index=None,
    kv_memory=None,
    remat: bool = True,
    causal: bool = True,
):
    """Scan a homogeneous stack. caches: pytree stacked on leading layer dim."""

    def body(carry, xs):
        h, aux = carry
        if mode == "decode":
            p_l, w_l, t_l, c_l = xs
        else:
            p_l, w_l, t_l = xs
            c_l = None
        y, new_c, a = block_forward(
            p_l, h, cfg, positions, w_l, t_l, mode=mode, cache=c_l,
            cache_index=cache_index, kv_memory=kv_memory, causal=causal,
        )
        if mode == "train":
            return (y, aux + a), jnp.zeros((), jnp.float32)
        return (y, aux + a), new_c  # prefill: created KV; decode: updated KV

    if remat and mode == "train":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    xs = (stack, windows, thetas)
    if mode == "decode":
        xs = xs + (caches,)
    (x, aux), out_caches = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
    return x, (out_caches if mode in ("decode", "prefill") else None), aux
