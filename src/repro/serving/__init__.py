from .engine import RequestResult, ServingEngine
from .worker import Endpoint, ExecutionRecord, Instance, WorkerHost

__all__ = [
    "Endpoint",
    "ExecutionRecord",
    "Instance",
    "RequestResult",
    "ServingEngine",
    "WorkerHost",
]
