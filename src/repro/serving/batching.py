"""Continuous batching: iteration-level scheduling of concurrent requests.

Requests join/leave the running batch between decode steps (vLLM-style)
instead of static request batches: a request that finishes frees its cache
slot for the next queued request at the next iteration.  Combined with Hiku
this is the worker-side execution model — the scheduler places requests on
workers, the batcher packs them into the worker's decode loop.

Every iteration issues ONE batched ``decode_step`` over all slots with a
per-slot ``cache_index`` vector (the model's decode path scatters each row
at its own age and masks per-row validity).  Prompt prefill rides the same
loop: a slot in prefill phase consumes its next prompt token instead of its
last generated one — fixed shapes, jit-friendly, no recompilation as the
mix of prefill/decode requests changes.  Free slots decode a dummy token
that lands at position 0 and is overwritten on reuse (masked by slot length
— the standard static-shape trade-off on TPU).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from .kv_cache import CacheManager


@dataclasses.dataclass
class GenRequest:
    request_id: str
    prompt: List[int]
    max_new_tokens: int = 8
    generated: List[int] = dataclasses.field(default_factory=list)
    _consumed: int = 0  # prompt tokens fed so far

    @property
    def in_prefill(self) -> bool:
        return self._consumed < len(self.prompt)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class ContinuousBatcher:
    def __init__(self, model, params, n_slots: int = 4, max_len: int = 64,
                 dtype=jnp.float32):
        self.model = model
        self.params = params
        self.mgr = CacheManager(model, n_slots, max_len, dtype=dtype)
        self.queue: Deque[GenRequest] = deque()
        self.running: Dict[str, GenRequest] = {}
        self.completed: Dict[str, GenRequest] = {}
        self._decode = jax.jit(model.decode_step)
        self.steps = 0

    def submit(self, req: GenRequest) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue and self.mgr.allocate(self.queue[0].request_id):
            req = self.queue.popleft()
            self.running[req.request_id] = req

    def step(self) -> int:
        """One continuous-batching iteration; returns #running requests."""
        self._admit()
        if not self.running:
            return 0
        B = self.mgr.n_slots
        toks = np.zeros((B, 1), np.int32)
        lengths = np.zeros(B, np.int32)
        for rid, req in self.running.items():
            slot = self.mgr.slots[rid]
            if req.in_prefill:
                toks[slot.idx, 0] = req.prompt[req._consumed]
            else:
                toks[slot.idx, 0] = (req.generated[-1] if req.generated
                                     else (req.prompt[-1] if req.prompt else 1))
            lengths[slot.idx] = slot.length
        logits, self.mgr.cache = self._decode(
            self.params, jnp.asarray(toks), self.mgr.cache, jnp.asarray(lengths)
        )
        best = np.asarray(jnp.argmax(logits, axis=-1))
        for rid, req in list(self.running.items()):
            slot = self.mgr.slots[rid]
            slot.length = min(slot.length + 1, self.mgr.max_len - 1)
            if req.in_prefill:
                req._consumed += 1
                if not req.in_prefill:
                    # the logits after the final prompt token ARE the first
                    # generation — capture them, don't re-feed the prompt end
                    req.generated.append(int(best[slot.idx]))
            else:
                req.generated.append(int(best[slot.idx]))
            if req.done:
                del self.running[rid]
                self.mgr.release(rid)
                self.completed[rid] = req
        self.steps += 1
        return len(self.running)

    def run_to_completion(self, max_steps: int = 1000) -> Dict[str, List[int]]:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
        return {rid: req.generated for rid, req in self.completed.items()}
