"""Serverless serving engine: scheduler + worker hosts + endpoints.

The complete control plane of Figure 1 over *real JAX models*: requests for a
function type arrive, the pluggable scheduler (core/) picks a worker, the
worker executes (cold start = param init + XLA compile, warm = instance
reuse), completion triggers the pull-enqueue, evictions trigger the
notification mechanism.  ``bench_table1`` and the serving examples run on
this engine; cluster-scale timing studies use core/simulator.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..core.scheduler import Scheduler, make_scheduler
from .worker import Endpoint, ExecutionRecord, WorkerHost


@dataclasses.dataclass
class RequestResult:
    func: str
    worker: int
    cold: bool
    latency_ms: float
    sched_overhead_ms: float


class ServingEngine:
    def __init__(
        self,
        endpoints: Sequence[Endpoint],
        n_workers: int = 2,
        scheduler: str | Scheduler = "hiku",
        mem_pool_bytes: int = 2 * 2**30,
        keep_alive_s: float = 60.0,
        seed: int = 0,
    ):
        self.endpoints: Dict[str, Endpoint] = {e.name: e for e in endpoints}
        self.workers = {
            w: WorkerHost(w, mem_pool_bytes, keep_alive_s) for w in range(n_workers)
        }
        self.sched = (
            scheduler
            if isinstance(scheduler, Scheduler)
            else make_scheduler(scheduler, n_workers, seed=seed)
        )
        for w in self.workers.values():
            w.on_evict = self.sched.on_evict
        self.records: List[RequestResult] = []

    def submit(self, func: str, tokens: Optional[jnp.ndarray] = None, gen_len: int = 2) -> RequestResult:
        ep = self.endpoints[func]
        if tokens is None:
            tokens = jnp.ones((1, 8), jnp.int32)
        t0 = time.perf_counter()
        w = self.sched.schedule(func)
        t_sched = (time.perf_counter() - t0) * 1e3
        rec: ExecutionRecord = self.workers[w].execute(ep, tokens, gen_len)
        self.sched.on_finish(w, func)
        out = RequestResult(
            func=func, worker=w, cold=rec.cold,
            latency_ms=rec.total_ms, sched_overhead_ms=t_sched,
        )
        self.records.append(out)
        return out

    def sweep(self) -> None:
        for w in self.workers.values():
            w.sweep()

    # ------------------------------------------------------------- faults
    def fail_worker(self, wid: int) -> None:
        """Simulate node failure: drop all instances, deregister from scheduler."""
        w = self.workers.pop(wid, None)
        if w is not None:
            self.sched.on_worker_removed(wid)

    def add_worker(self, wid: int, mem_pool_bytes: int = 2 * 2**30, keep_alive_s: float = 60.0) -> None:
        host = WorkerHost(wid, mem_pool_bytes, keep_alive_s)
        host.on_evict = self.sched.on_evict
        self.workers[wid] = host
        self.sched.on_worker_added(wid)

    # ------------------------------------------------------------ metrics
    def summary(self) -> Dict[str, float]:
        lat = np.array([r.latency_ms for r in self.records]) if self.records else np.zeros(1)
        cold = np.array([r.cold for r in self.records]) if self.records else np.zeros(1)
        ov = np.array([r.sched_overhead_ms for r in self.records]) if self.records else np.zeros(1)
        return {
            "n": len(self.records),
            "mean_latency_ms": float(lat.mean()),
            "cold_rate": float(cold.mean()),
            "sched_overhead_ms": float(ov.mean()),
        }
