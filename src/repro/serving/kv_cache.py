"""KV-cache slot management for batched serving.

A ``CacheManager`` owns one model-level cache pytree of shape
(B_slots, ...) and hands out *slots* to requests: allocation finds a free
slot, release returns it.  Per-slot valid lengths drive the decode masks, so
requests of different ages can share one batched ``decode_step`` call — the
substrate for continuous batching (batching.py).

Layout note: caches produced by ``Model.init_cache`` carry the batch dim at
position 1 (after "layers"/"groups") for stacked entries and position 0 for
whisper memory — ``_batch_axis`` resolves this per leaf by matching the slot
count, which keeps the manager model-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Slot:
    idx: int
    request_id: str
    length: int = 0  # tokens currently in the cache


class CacheManager:
    def __init__(self, model, n_slots: int, max_len: int, dtype=jnp.bfloat16):
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = model.init_cache(n_slots, max_len, dtype=dtype)
        self._free: List[int] = list(range(n_slots))
        self.slots: Dict[str, Slot] = {}

    # ------------------------------------------------------------- slots
    def allocate(self, request_id: str) -> Optional[Slot]:
        if not self._free:
            return None
        slot = Slot(self._free.pop(0), request_id)
        self.slots[request_id] = slot
        return slot

    def release(self, request_id: str) -> None:
        slot = self.slots.pop(request_id, None)
        if slot is not None:
            self._free.append(slot.idx)

    @property
    def active(self) -> List[Slot]:
        return sorted(self.slots.values(), key=lambda s: s.idx)

    def utilization(self) -> float:
        return 1.0 - len(self._free) / self.n_slots

    # ------------------------------------------------------------ lengths
    def lengths(self) -> np.ndarray:
        out = np.zeros(self.n_slots, np.int32)
        for s in self.slots.values():
            out[s.idx] = s.length
        return out

    def bytes(self) -> int:
        return sum(int(np.prod(l.shape)) * l.dtype.itemsize
                   for l in jax.tree.leaves(self.cache))
