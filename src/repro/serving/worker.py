"""Worker host: the function-instance lifecycle (Figure 2) on real JAX models.

A worker owns an HBM memory pool and a sandbox table of *instances* — a
materialized param set + jitted prefill/decode executables for one endpoint
("function type").  Cold start = param materialization + XLA compile (+ cache
allocation); warm start = reuse of a resident idle instance.  The evictor
implements keep-alive timeouts and LRU force-eviction under memory pressure,
emitting the scheduler notifications of Section IV-A.

This is the *real-compute* control plane (Table-I-style measurements run on
it).  Timing studies at cluster scale use core/simulator.py — recorded in
DESIGN.md §2.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import build_model, unzip


@dataclasses.dataclass
class Endpoint:
    """A deployable function type: model config + weight seed."""

    name: str
    cfg: object  # ModelConfig
    seed: int = 0
    max_cache_len: int = 128

    def est_bytes(self) -> int:
        p = self.cfg.n_params() * 4  # f32 on CPU host
        return int(p * 1.2) + 64 * self.max_cache_len * 1024


class Instance:
    """One warm sandbox: params + compiled serve executables."""

    __slots__ = ("endpoint", "model", "params", "decode_fn", "prefill_fn", "last_used", "busy")

    def __init__(self, endpoint: Endpoint):
        self.endpoint = endpoint
        model = build_model(endpoint.cfg, param_dtype=jnp.float32, remat=False)
        self.model = model
        params, _ = unzip(model.init(jax.random.key(endpoint.seed), max_seq=endpoint.max_cache_len))
        self.params = jax.tree.map(lambda a: jax.block_until_ready(a), params)
        self.prefill_fn = jax.jit(model.prefill)
        self.decode_fn = jax.jit(model.decode_step)
        self.last_used = time.monotonic()
        self.busy = False

    def generate(self, tokens: jnp.ndarray, gen_len: int = 4) -> jnp.ndarray:
        """Prefill + a few decode steps (the 'function execution')."""
        model, ep = self.model, self.endpoint
        B, S = tokens.shape
        cache = model.init_cache(B, ep.max_cache_len, dtype=jnp.float32, memory_t=8)
        if ep.cfg.enc_dec:
            frames = jnp.zeros((B, S, ep.cfg.d_model), jnp.float32)
            batch = {"frames": frames, "tokens": tokens}
        else:
            batch = {"tokens": tokens}
        _, last_logits = self.prefill_fn(self.params, batch)
        out = [jnp.argmax(last_logits, -1)]
        idx = jnp.int32(min(S, ep.max_cache_len - gen_len - 1))
        for i in range(gen_len - 1):
            logits, cache = self.decode_fn(self.params, out[-1][:, None], cache, idx + i)
            out.append(jnp.argmax(logits, -1))
        return jax.block_until_ready(jnp.stack(out, 1))


@dataclasses.dataclass
class ExecutionRecord:
    func: str
    worker: int
    cold: bool
    init_ms: float
    exec_ms: float

    @property
    def total_ms(self) -> float:
        return self.init_ms + self.exec_ms


class WorkerHost:
    def __init__(self, wid: int, mem_pool_bytes: int = 2 * 2**30, keep_alive_s: float = 60.0):
        self.wid = wid
        self.pool = mem_pool_bytes
        self.keep_alive_s = keep_alive_s
        self.idle: Dict[str, List[Instance]] = {}
        self.used_bytes = 0
        self.on_evict: Optional[Callable[[int, str], None]] = None

    # ------------------------------------------------------------- memory
    def _evict_lru(self) -> bool:
        lru_key, lru_i, lru_t = None, -1, float("inf")
        for name, lst in self.idle.items():
            for i, inst in enumerate(lst):
                if inst.last_used < lru_t:
                    lru_key, lru_i, lru_t = name, i, inst.last_used
        if lru_key is None:
            return False
        inst = self.idle[lru_key].pop(lru_i)
        if not self.idle[lru_key]:
            del self.idle[lru_key]
        self.used_bytes -= inst.endpoint.est_bytes()
        if self.on_evict:
            self.on_evict(self.wid, lru_key)
        return True

    def sweep(self) -> None:
        now = time.monotonic()
        for name in list(self.idle):
            keep = []
            for inst in self.idle[name]:
                if now - inst.last_used > self.keep_alive_s:
                    self.used_bytes -= inst.endpoint.est_bytes()
                    if self.on_evict:
                        self.on_evict(self.wid, name)
                else:
                    keep.append(inst)
            if keep:
                self.idle[name] = keep
            else:
                del self.idle[name]

    # ------------------------------------------------------------ execute
    def execute(self, ep: Endpoint, tokens: jnp.ndarray, gen_len: int = 4) -> ExecutionRecord:
        cold = not self.idle.get(ep.name)
        t0 = time.perf_counter()
        if cold:
            need = ep.est_bytes()
            while self.used_bytes + need > self.pool and self._evict_lru():
                pass
            inst = Instance(ep)  # materialize + compile == cold start
            self.used_bytes += need
        else:
            inst = self.idle[ep.name].pop()
        t1 = time.perf_counter()
        inst.generate(tokens, gen_len)
        t2 = time.perf_counter()
        inst.last_used = time.monotonic()
        self.idle.setdefault(ep.name, []).append(inst)
        return ExecutionRecord(
            func=ep.name, worker=self.wid, cold=cold,
            init_ms=(t1 - t0) * 1e3 if cold else 0.0,
            exec_ms=(t2 - t1) * 1e3,
        )
