from .ctx import activation_rules, shard, use_rules
from .rules import ShardingPlan, logical_to_mesh, param_shardings

__all__ = [
    "ShardingPlan",
    "activation_rules",
    "logical_to_mesh",
    "param_shardings",
    "shard",
    "use_rules",
]
