"""Activation-sharding context.

Model code annotates activations with *logical* axis names:

    x = shard(x, ("batch", "seq", "embed"))

Outside any context this is the identity (CPU smoke tests).  Inside
``use_rules(mesh, rules)`` it becomes ``jax.lax.with_sharding_constraint``
with the logical names resolved to mesh axes — the single hook through which
the launcher switches sharding plans without touching model code.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec

_STATE = threading.local()


def _resolve(names: Sequence[Optional[str]], rules: Dict[str, tuple], mesh, shape) -> PartitionSpec:
    used = set()
    spec = []
    for dim, name in enumerate(names):
        axes = rules.get(name, ()) if name else ()
        picked = []
        size = 1
        for ax in axes:
            if ax in used or ax not in mesh.shape:
                continue
            size *= mesh.shape[ax]
            picked.append(ax)
        # divisibility guard: drop the whole assignment if the dim can't split
        if picked and (shape[dim] % size == 0) and shape[dim] > 0:
            used.update(picked)
            spec.append(tuple(picked) if len(picked) > 1 else picked[0])
        else:
            spec.append(None)
    return PartitionSpec(*spec)


def shard(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return x
    mesh, rules, _ = ctx
    if x.ndim != len(names):
        return x
    spec = _resolve(names, rules, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def get_ctx():
    """(mesh, rules, extras) of the active sharding context, or None."""
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def use_rules(mesh, rules: Dict[str, tuple], **extras):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = (mesh, rules, extras)
    try:
        yield
    finally:
        _STATE.ctx = prev


def activation_rules(plan) -> Dict[str, tuple]:
    """Logical-activation-axis -> mesh-axes mapping for a ShardingPlan."""
    r = dict(plan.activation_rules)
    return r
