"""Sharding plans: logical axis names -> mesh axes, with divisibility guards.

A ``ShardingPlan`` is two rule tables (params, activations).  Resolution is
shape-aware: a rule only applies if the dim divides by the mesh-axes product
and no mesh axis is used twice in one spec — this single guard is what lets
every (arch x shape) cell compile on the same mesh (GQA archs with
kv_heads=4 or 8 simply drop the model axis on that dim and pick it up on the
context-parallel seq dim instead).

Baseline plans (hillclimbed variants are recorded in EXPERIMENTS.md §Perf):
* TP        — params tensor-parallel over "model"; activations batch-sharded
              over ("pod", "data").
* TP+FSDP   — additionally shard the d_model ("embed") dim of weights over
              ("pod", "data") (ZeRO-3 style; XLA all-gathers per layer).
              Auto-enabled when the TP-sharded replica would not fit HBM.
* EP        — MoE experts over "model" (DeepSeek: 16 experts/device),
              dispatch capacity over "data".
* Context-parallel decode — KV caches shard their *sequence* dim over
              "model"; softmax reductions become the flash-decode combine.
"""

from __future__ import annotations

import dataclasses
import os as _os
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec

from .ctx import _resolve

HBM_BYTES = 16 * 2**30  # TPU v5e


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    name: str
    param_rules: Dict[str, Tuple[str, ...]]
    activation_rules: Dict[str, Tuple[str, ...]]
    # MoE distribution: None -> in-graph scatter dispatch (paper-faithful XLA
    # baseline); "capacity" -> shard_map EP w/ psum combine (train/prefill);
    # "resident" -> fully-resident 2D EP, tokens move not weights (decode).
    moe_mode: Optional[str] = None


def _base_param_rules(fsdp: bool) -> Dict[str, Tuple[str, ...]]:
    fs = ("pod", "data") if fsdp else ()
    return {
        "embed": fs,
        "mlp": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": (),
        "vocab": ("model",),
        "experts": ("model",),
        "q_lora": (),
        "kv_lora": fs,
        "ssm_inner": ("model",),
        "ssm_heads": ("model",),
        "ssm_state": (),
        "layers": (),
    }


def _base_activation_rules() -> Dict[str, Tuple[str, ...]]:
    return {
        "batch": ("pod", "data"),
        "cache_batch": ("pod", "data"),  # KV-cache batch dim (always sharded)
        "seq": (),
        "embed": (),
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "experts": ("model",),
        "expert_cap": ("pod", "data"),
        "seq_kv": ("model",),  # context-parallel KV cache
        "ssm_heads": ("model",),
    }


def make_plan(
    name: str = "tp",
    fsdp: bool = False,
    seq_shard: bool = False,
    moe_mode: Optional[str] = None,
    weight_stationary: bool = False,
    sp_embed: bool = False,
    overrides: Optional[Dict[str, Dict[str, Tuple[str, ...]]]] = None,
) -> ShardingPlan:
    pr = _base_param_rules(fsdp)
    ar = _base_activation_rules()
    if seq_shard:  # sequence parallelism for B=1 long-context
        ar["seq"] = ("pod", "data")
    if sp_embed:
        # SP-style boundaries: block inputs/outputs sharded on d_model over
        # "model" — XLA converts the TP all-reduces into reduce-scatter +
        # all-gather pairs (half the boundary wire volume).
        ar["embed"] = ("model",)
    if moe_mode == "resident":
        # 2D EP residency: experts over (model x data), replicated over pods
        # (pods stay independent DP replicas for decode; moe.py matches this
        # ownership in its shard_map body)
        pr["experts"] = ("model", "data")
        sp_embed = False
    if weight_stationary:
        # decode on FSDP-sized models: weights stay 2D-sharded; the tiny
        # activations move instead.  Dropping the batch constraint alone is
        # not enough (SPMD still gathers weights) — the d_model dim of the
        # boundary activations is explicitly sharded over the FSDP axes so
        # every dot contracts over a sharded dim: partial sums + a tiny
        # output all-reduce replace the per-layer weight all-gather.
        ar["batch"] = ()
        ar["embed"] = ("pod", "data")
    if overrides:
        pr.update(overrides.get("params", {}))
        ar.update(overrides.get("activations", {}))
    return ShardingPlan(name, pr, ar, moe_mode=moe_mode)


def auto_plan(
    cfg, step_kind: str, n_model: int = 16, batch: int = 0,
    level: str = "baseline",
) -> ShardingPlan:
    """Pick the plan for (arch, step) from HBM arithmetic.

    level="baseline" is the paper-faithful pjit/XLA path (recorded first in
    §Perf); level="opt" enables the beyond-baseline hillclimb levers
    (shard_map EP MoE, resident experts, weight-stationary decode).
    """
    p_bytes = cfg.n_params() * 2  # bf16
    state_mult = 3.0 if step_kind == "train" else 1.0  # + m,v (see optimizer)
    tp_resident = p_bytes * state_mult / max(n_model, 1)
    fsdp = tp_resident > 0.5 * HBM_BYTES
    seq_shard = step_kind == "decode" and batch == 1
    moe_mode = None
    ws = False
    if level == "opt":
        if cfg.moe is not None:
            if step_kind == "decode":
                # resident EP needs >=1 expert per mesh cell; for few-expert
                # archs (mixtral E=8) the in-graph dispatch is already cheap
                # at decode token counts and weight movement would dominate
                # (measured: 0.27 -> 0.64 s — see §Perf generalization table)
                moe_mode = "resident" if cfg.moe.n_experts >= n_model * n_model else None
            else:
                moe_mode = "capacity"
        if fsdp and step_kind == "decode":
            ws = True  # weight-stationary decode (also for MoE: MLA/dense parts)
    nm = f"{'fsdp+' if fsdp else ''}tp" + ("+seqshard" if seq_shard else "")
    if moe_mode:
        nm += f"+ep-{moe_mode}"
    if ws:
        nm += "+ws"
    sp = level == "opt" and step_kind == "train" and _os.environ.get("REPRO_SP_EMBED") == "1"
    if sp:
        nm += "+sp"
    return make_plan(nm, fsdp=fsdp, seq_shard=seq_shard, moe_mode=moe_mode,
                     weight_stationary=ws, sp_embed=sp)


# ---------------------------------------------------------------- resolvers
def logical_to_mesh(mesh, plan_rules: Dict, names: Sequence[Optional[str]], shape) -> NamedSharding:
    return NamedSharding(mesh, _resolve(names, plan_rules, mesh, shape))


def param_shardings(mesh, plan: ShardingPlan, axes_tree, shape_tree):
    """Tree of NamedShardings for a param tree (axes names + shapes)."""

    def one(names, arr):
        shape = arr.shape if hasattr(arr, "shape") else tuple(arr)
        return logical_to_mesh(mesh, plan.param_rules, names, shape)

    return jax.tree.map(one, axes_tree, shape_tree, is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(i, (str, type(None))) for i in x))
