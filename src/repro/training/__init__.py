from .optimizer import OptConfig, OptState, adamw_update, init_opt_state, schedule_lr
from .train_step import make_eval_step, make_serve_steps, make_train_step

__all__ = [
    "OptConfig",
    "OptState",
    "adamw_update",
    "init_opt_state",
    "make_eval_step",
    "make_serve_steps",
    "make_train_step",
    "schedule_lr",
]
