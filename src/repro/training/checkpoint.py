"""Checkpoint/restart: atomic, integrity-checked, async, reshard-on-restore.

Layout per step:  <dir>/step_0000042/
    manifest.json   — step, tree structure, per-leaf sha256, wall time
    arrays.npz      — flattened leaves keyed by tree path

Fault-tolerance properties:
* atomic publish: written to ``.tmp-…`` then os.rename (a crashed writer never
  corrupts the latest checkpoint);
* integrity: sha256 per leaf, verified on restore (detects torn/bit-rotten
  files before they poison a 1000-node run);
* async: ``save_async`` snapshots to host memory synchronously (cheap) and
  writes in a background thread — train steps are not blocked by the
  filesystem;
* elastic restore: ``restore`` takes target NamedShardings and device_puts
  each leaf, so a checkpoint written on one mesh resumes on another
  (training/elastic.py wires this to plan changes).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize ml_dtypes extension types; store them as raw views.
_EXT_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_storable(a: np.ndarray) -> np.ndarray:
    for name, (ext, raw) in _EXT_DTYPES.items():
        if a.dtype == ext:
            return a.view(raw)
    return a


def _from_storable(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXT_DTYPES:
        return a.view(_EXT_DTYPES[dtype_name][0])
    return a


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _sha(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


def save(ckpt_dir: str | os.PathLike, step: int, tree, keep: int = 3) -> Path:
    """Synchronous atomic checkpoint write; returns the published path."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp-step_{step:08d}-{os.getpid()}"
    tmp.mkdir(parents=True, exist_ok=True)
    np.savez(tmp / "arrays.npz", **{k: _to_storable(v) for k, v in flat.items()})
    manifest = {
        "step": step,
        "time": time.time(),
        "leaves": {k: {"sha": _sha(_to_storable(v)), "shape": list(v.shape),
                       "dtype": str(v.dtype)} for k, v in flat.items()},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


_PENDING: Dict[str, threading.Thread] = {}


def save_async(ckpt_dir: str | os.PathLike, step: int, tree, keep: int = 3) -> threading.Thread:
    """Snapshot to host now, write in background; returns the writer thread."""
    host_tree = jax.tree.map(lambda a: np.asarray(a), tree)  # sync snapshot
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree, keep), daemon=True)
    t.start()
    _PENDING[str(ckpt_dir)] = t
    return t


def wait_pending(ckpt_dir: str | os.PathLike) -> None:
    t = _PENDING.pop(str(ckpt_dir), None)
    if t is not None:
        t.join()


def latest_step(ckpt_dir: str | os.PathLike) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")]
    return max(steps) if steps else None


def restore(ckpt_dir: str | os.PathLike, like, step: Optional[int] = None,
            shardings=None, verify: bool = True):
    """Restore into the structure of ``like``; device_put per-leaf shardings."""
    d = Path(ckpt_dir)
    step = latest_step(d) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {d}")
    path = d / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    arrays = np.load(path / "arrays.npz")
    if verify:
        for k, meta in manifest["leaves"].items():
            got = _sha(arrays[k])
            if got != meta["sha"]:
                raise IOError(f"checkpoint corruption at leaf {k}: {got} != {meta['sha']}")

    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    sh_flat = None
    if shardings is not None:
        sh_flat = treedef.flatten_up_to(shardings)
    out = []
    for i, (pth, leaf) in enumerate(leaves_paths):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
        a = _from_storable(arrays[key], manifest["leaves"][key]["dtype"])
        if sh_flat is not None:
            out.append(jax.device_put(a, sh_flat[i]))
        else:
            out.append(jax.numpy.asarray(a))
    return treedef.unflatten(out), step


def _gc(ckpt_dir: Path, keep: int) -> None:
    steps = sorted(ckpt_dir.glob("step_*"), key=lambda p: p.name)
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)
