"""Int8 block-quantized gradient compression for cross-pod all-reduce.

Distributed-optimization trick for the multi-pod mesh: intra-pod gradient
reduction stays bf16/f32 over ICI, but the cross-pod hop rides DCN (an order
of magnitude less bandwidth) — quantizing that hop to int8 with per-block
f32 scales cuts cross-pod traffic ~4x at <1e-2 relative error (test-bounded).
Optional error feedback accumulates the quantization residual into the next
step (standard EF-SGD trick; keeps convergence unbiased in expectation).

``compressed_psum`` is written for use inside shard_map over the "pod" axis;
on a 1-axis mesh it degrades to an exact psum (tested).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize(x: jax.Array, block: int = BLOCK) -> Tuple[jax.Array, jax.Array]:
    """x -> (int8 values, f32 per-block scales). Shape-preserving."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize(q: jax.Array, scale: jax.Array, shape, block: int = BLOCK) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_roundtrip_error(x: jax.Array) -> float:
    q, s = quantize(x)
    y = dequantize(q, s, x.shape)
    denom = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    return float(jnp.max(jnp.abs(y - x.astype(jnp.float32))) / denom)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-compressed all-reduce over ``axis_name`` (inside shard_map)."""
    q, s = quantize(x)
    # dequantize-then-psum keeps the reduction exact in f32 while the *wire*
    # format (what all-gather/reduce-scatter moves under XLA) is int8+scales.
    deq = dequantize(q, s, x.shape)
    return jax.lax.psum(deq, axis_name)


def compressed_grad_tree(grads, residual: Optional[Any] = None):
    """Quantize a gradient pytree with optional error feedback.

    Returns (dequantized_grads, new_residual).
    """
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = quantize(corrected)
        deq = dequantize(q, s, g.shape)
        return deq.astype(g.dtype), corrected - deq

    pairs = jax.tree.map(one, grads, residual)
    deq = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return deq, res
