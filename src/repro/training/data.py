"""Deterministic, shardable synthetic-LM data pipeline.

Stateless indexing: ``batch_at(step)`` is a pure function of (seed, step,
host slice), so training is resumable from any checkpoint step and *elastic*
— on a data-parallel resize each host recomputes its slice of the same global
batch (training/elastic.py), with no data loss or duplication.

Token stream is a seeded first-order Markov chain over the vocabulary (plus a
skip-gram tie), giving ~2.5 bits/token of learnable structure so example
training runs show real loss decrease (quickstart / train_wsd examples).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 256
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    branching: int = 4  # successors per token (lower = easier to learn)


class MarkovLM:
    """Seeded synthetic language with learnable bigram structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, B = cfg.vocab, cfg.branching
        self.successors = rng.integers(0, V, size=(V, B))
        self.probs = rng.dirichlet(np.ones(B) * 0.5, size=V)

    def _sample_rows(self, rng: np.random.Generator, n: int) -> np.ndarray:
        V, B = self.cfg.vocab, self.cfg.branching
        S = self.cfg.seq_len
        out = np.empty((n, S), np.int32)
        tok = rng.integers(0, V, size=n)
        for t in range(S):
            out[:, t] = tok
            u = rng.random((n, 1))
            cum = np.cumsum(self.probs[tok], axis=1)
            choice = (u > cum).sum(axis=1).clip(0, B - 1)
            tok = self.successors[tok, choice]
        return out

    def batch_at(self, step: int, host_id: int = 0, n_hosts: int = 1) -> Dict[str, np.ndarray]:
        """Deterministic host slice of the global batch for ``step``."""
        gb = self.cfg.global_batch
        assert gb % n_hosts == 0, (gb, n_hosts)
        per = gb // n_hosts
        rng = np.random.default_rng((self.cfg.seed, step, host_id))
        return {"tokens": self._sample_rows(rng, per)}

    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        return {"tokens": np.concatenate(
            [self.batch_at(step, h, 1)["tokens"] for h in range(1)], axis=0
        )}

    def entropy_floor_nats(self) -> float:
        """Per-token conditional entropy of the chain (loss floor)."""
        p = self.probs
        h_rows = -(p * np.log(np.maximum(p, 1e-12))).sum(axis=1)
        return float(h_rows.mean())


def device_put_batch(batch: Dict[str, np.ndarray], mesh=None, rules=None):
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    from jax.sharding import NamedSharding

    from ..sharding.ctx import _resolve

    out = {}
    for k, v in batch.items():
        names = ("batch", "seq") if v.ndim == 2 else ("batch", "seq", "embed")
        spec = _resolve(names, rules or {}, mesh, v.shape)
        out[k] = jax.device_put(jnp.asarray(v), NamedSharding(mesh, spec))
    return out
