"""Elastic scaling: resume a run on a different mesh / data-parallel size.

Invariants preserved across a resize:
* optimizer state and params reshard to the new plan's NamedShardings
  (checkpoint.restore does the device_put);
* the data pipeline is stateless-indexed (training/data.py), so each host
  recomputes its slice of the SAME global batch sequence — global batch and
  sample order are invariant under resizes;
* the step counter lives in the checkpoint, so schedules (WSD/cosine) are
  unaffected.

``plan_for_mesh`` re-derives shardings for the new mesh; on real clusters the
launcher calls this after jax.distributed re-initialization with the
surviving hosts (scale-down after failure, scale-up after repair).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

from ..sharding.rules import ShardingPlan, auto_plan, param_shardings
from . import checkpoint as ckpt
from .optimizer import OptState


def plan_for_mesh(cfg, mesh, step_kind: str = "train") -> ShardingPlan:
    return auto_plan(cfg, step_kind, n_model=mesh.shape.get("model", 1))


def shardings_for(model, mesh, plan: ShardingPlan, max_seq: int = 4096):
    from ..launch.specs import abstract_params  # local import: avoids cycle

    params_sds, axes = abstract_params(model, max_seq=max_seq)
    p_sh = param_shardings(mesh, plan, axes, params_sds)
    from jax.sharding import NamedSharding, PartitionSpec

    repl = NamedSharding(mesh, PartitionSpec())
    opt_sh = OptState(m=p_sh, v=p_sh, step=repl)
    return params_sds, p_sh, opt_sh


def elastic_resume(
    ckpt_dir,
    model,
    mesh,
    plan: Optional[ShardingPlan] = None,
    step: Optional[int] = None,
) -> Tuple[Any, OptState, int]:
    """Restore (params, opt_state, step) resharded onto ``mesh``."""
    plan = plan or plan_for_mesh(model.cfg, mesh)
    params_sds, p_sh, opt_sh = shardings_for(model, mesh, plan)
    like = {
        "params": params_sds,
        "opt": OptState(
            m=jax.tree.map(lambda s: s, params_sds),
            v=jax.tree.map(lambda s: s, params_sds),
            step=jax.ShapeDtypeStruct((), jax.numpy.int32),
        ),
    }
    sh = {"params": p_sh, "opt": opt_sh}
    restored, step = ckpt.restore(ckpt_dir, like, step=step, shardings=sh)
    return restored["params"], restored["opt"], step


def save_for_elastic(ckpt_dir, step: int, params, opt_state: OptState, async_: bool = True):
    tree = {"params": params, "opt": opt_state}
    if async_:
        return ckpt.save_async(ckpt_dir, step, tree)
    return ckpt.save(ckpt_dir, step, tree)
