"""AdamW + LR schedules (cosine, MiniCPM's WSD) — pure-pytree, shardable.

Moments inherit the parameter sharding (the dry-run passes the same
NamedShardings for ``m``/``v`` as for params), so optimizer state is fully
distributed under FSDP plans.  An optional int8 block-quantized moment store
(``quantize_moments=True``) implements the distributed-optimization trick of
8-bit optimizer state for HBM-constrained training (used by the deepseek
hillclimb; see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: str = "cosine"  # cosine | wsd | const
    warmup_steps: int = 100
    total_steps: int = 10_000
    stable_frac: float = 0.8  # WSD: fraction of post-warmup steps at peak lr
    min_lr_frac: float = 0.1
    moment_dtype: Any = jnp.float32


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def schedule_lr(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Cosine or Warmup-Stable-Decay (MiniCPM) schedule."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "wsd":
        # stable at peak for stable_frac, then exponential-style decay to min
        in_decay = t > cfg.stable_frac
        d = jnp.clip((t - cfg.stable_frac) / max(1e-9, 1 - cfg.stable_frac), 0.0, 1.0)
        decay = jnp.where(in_decay, cfg.min_lr_frac ** d, 1.0)
    else:
        decay = jnp.ones_like(t)
    return cfg.lr * warm * decay


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(m=zeros, v=jax.tree.map(jnp.copy, zeros), step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        jax.tree.reduce(
            lambda a, b: a + b,
            jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree),
            jnp.float32(0.0),
        )
    )


def adamw_update(
    grads, state: OptState, params, cfg: OptConfig
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / c1
        vh = v2 / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(new_m, new_v, step), metrics
