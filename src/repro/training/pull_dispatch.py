"""Pull-based microbatch dispatch — the paper's JIQ idea applied to training.

Beyond-paper transfer (DESIGN.md §2): in large data-parallel runs, per-step
straggling (bad host, thermal throttle, preemption neighbor) makes static
"every replica gets M/R microbatches" dispatch run at the pace of the slowest
replica.  Treating gradient microbatches as FaaS requests and DP replicas as
workers, the Join-Idle-Queue discipline applies verbatim: a replica that
finishes its microbatch *pulls* the next one from the step's queue.

``simulate_dispatch`` quantifies the makespan win (bench_pull_dispatch);
``pull_schedule`` returns the per-replica assignment realized by the pull
discipline so a gradient-accumulation loop can weight contributions
correctly (sum of per-microbatch grads is order-invariant).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class DispatchResult:
    makespan: float
    per_replica_counts: np.ndarray
    assignment: List[int]  # microbatch -> replica


def static_dispatch(step_cost: np.ndarray) -> DispatchResult:
    """Pre-assigned equal split: replica r runs microbatches r*M/R..(r+1)*M/R."""
    M, R = step_cost.shape
    per = M // R
    times = np.zeros(R)
    assignment = []
    for r in range(R):
        for m in range(r * per, (r + 1) * per):
            times[r] += step_cost[m, r]
            assignment.append(r)
    return DispatchResult(float(times.max()), np.full(R, per), assignment)


def pull_dispatch(step_cost: np.ndarray) -> DispatchResult:
    """JIQ: idle replicas pull the next microbatch from the queue."""
    M, R = step_cost.shape
    heap = [(0.0, r) for r in range(R)]  # (available_at, replica)
    heapq.heapify(heap)
    counts = np.zeros(R, int)
    assignment = []
    finish = 0.0
    for m in range(M):
        t, r = heapq.heappop(heap)
        t2 = t + step_cost[m, r]
        counts[r] += 1
        assignment.append(r)
        finish = max(finish, t2)
        heapq.heappush(heap, (t2, r))
    return DispatchResult(float(finish), counts, assignment)


def straggler_cost_matrix(
    n_micro: int,
    n_replicas: int,
    base_s: float = 1.0,
    straggler_frac: float = 0.1,
    slowdown: float = 3.0,
    jitter: float = 0.05,
    seed: int = 0,
) -> np.ndarray:
    """(M, R) per-microbatch step costs with a slow tail of replicas."""
    rng = np.random.default_rng(seed)
    speed = np.ones(n_replicas)
    n_slow = max(1, int(straggler_frac * n_replicas)) if straggler_frac > 0 else 0
    if n_slow:
        speed[rng.choice(n_replicas, n_slow, replace=False)] = slowdown
    noise = rng.lognormal(0, jitter, size=(n_micro, n_replicas))
    return base_s * speed[None, :] * noise


def simulate_dispatch(
    n_micro: int = 128, n_replicas: int = 16, **kw
) -> Tuple[DispatchResult, DispatchResult]:
    cost = straggler_cost_matrix(n_micro, n_replicas, **kw)
    return static_dispatch(cost), pull_dispatch(cost)
