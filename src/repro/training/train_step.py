"""Train step factory: loss -> grads -> AdamW, with activation-sharding rules.

``make_train_step(model, mesh, plan, opt_cfg)`` returns a pure function
``step(params, opt_state, batch) -> (params, opt_state, metrics)`` whose
trace runs under the plan's activation rules (so every ``shard()`` annotation
in the model resolves against the production mesh).  Without mesh/plan the
same factory yields an unsharded step for CPU tests.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.ctx import use_rules
from .optimizer import OptConfig, OptState, adamw_update, init_opt_state


def make_train_step(model, mesh=None, plan=None, opt_cfg: Optional[OptConfig] = None):
    opt_cfg = opt_cfg or OptConfig(schedule=model.cfg.lr_schedule)

    def body(params, opt_state: OptState, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        new_params, new_state, opt_metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = {**metrics, **opt_metrics}
        return new_params, new_state, metrics

    if mesh is None or plan is None:
        return body

    def step(params, opt_state, batch):
        with use_rules(mesh, plan.activation_rules, moe_mode=plan.moe_mode):
            return body(params, opt_state, batch)

    return step


def make_eval_step(model, mesh=None, plan=None):
    def body(params, batch):
        loss, metrics = model.loss(params, batch)
        return metrics

    if mesh is None or plan is None:
        return body

    def step(params, batch):
        with use_rules(mesh, plan.activation_rules, moe_mode=plan.moe_mode):
            return body(params, batch)

    return step


def make_serve_steps(model, mesh=None, plan=None):
    """(prefill_step, decode_step) under the plan's activation rules."""

    def prefill_body(params, batch):
        return model.prefill(params, batch)

    def decode_body(params, tokens, cache, cache_index):
        return model.decode_step(params, tokens, cache, cache_index)

    if mesh is None or plan is None:
        return prefill_body, decode_body

    def prefill_step(params, batch):
        with use_rules(mesh, plan.activation_rules, moe_mode=plan.moe_mode):
            return prefill_body(params, batch)

    def decode_step(params, tokens, cache, cache_index):
        with use_rules(mesh, plan.activation_rules, moe_mode=plan.moe_mode):
            return decode_body(params, tokens, cache, cache_index)

    return prefill_step, decode_step
