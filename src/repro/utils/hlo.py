"""Post-SPMD HLO analysis: collective traffic, trip counts, op census.

``collective_stats(compiled.as_text())`` feeds the roofline collective term
(EXPERIMENTS.md §Roofline).  Three subtleties handled here:

1. jax scans lower to ``while`` loops whose bodies appear ONCE in the text but
   execute trip-count times — we segment the module into computations, map
   ``while(condition=%c, body=%b)`` attributes, read the trip count from the
   loop-bound constant in the condition computation, and multiply collective
   volume inside bodies accordingly (nested whiles compose).
2. Operand shapes are not printed in this HLO dialect, so traffic is modeled
   from result shapes with per-kind ring multipliers over the replica-group
   size g: all-gather (g-1)/g x result, all-reduce 2(g-1)/g x result,
   reduce-scatter (g-1) x result (result is the scattered shard),
   all-to-all (g-1)/g, collective-permute 1x.
3. ``-start``/``-done`` async pairs are counted once.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_COLL_RE = re.compile(
    r"=\s*.*?\s(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start)?\("
)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_WHILE_RE = re.compile(r"while\(.*?condition=(%[\w.\-]+), body=(%[\w.\-]+)", re.DOTALL)
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_bytes_of(dt: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _result_bytes(line: str, kind: str) -> int:
    lhs = line.split("=", 1)[1]
    before = lhs[: lhs.index(kind)]
    return sum(_shape_bytes_of(dt, dims) for dt, dims in _SHAPE_RE.findall(before))


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _traffic(kind: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return float(result_bytes) * (g - 1)
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)  # collective-permute


def _segment(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its lines (header included)."""
    comps: Dict[str, List[str]] = {}
    name, buf = None, []
    for line in hlo.splitlines():
        if not line.startswith(" ") and "{" in line and ("(" in line):
            hdr = line.strip()
            if hdr.startswith("ENTRY"):
                cname = "ENTRY"
            elif hdr.startswith("%"):
                cname = hdr.split(" ", 1)[0].rstrip("(")
                cname = hdr[: hdr.index(" (")] if " (" in hdr else cname
            else:
                continue
            name, buf = cname, [line]
            comps[name] = buf
        elif name is not None:
            buf.append(line)
            if line.rstrip() == "}":
                name = None
    return comps


def _trip_count(cond_lines: List[str]) -> Optional[int]:
    consts = [int(m.group(1)) for line in cond_lines for m in _CONST_RE.finditer(line)]
    return max(consts) if consts else None


def collective_stats(hlo_text: str, default_trip: int = 1) -> Dict[str, Dict[str, float]]:
    """Per-kind {count, result_bytes, traffic_bytes}, trip-count multiplied."""
    comps = _segment(hlo_text)

    # map body computation -> trip count
    body_trips: Dict[str, int] = {}
    for cname, lines in comps.items():
        text = "\n".join(lines)
        for m in _WHILE_RE.finditer(text):
            cond, body = m.group(1), m.group(2)
            trips = _trip_count(comps.get(cond, [])) or default_trip
            body_trips[body] = trips

    # iterate to fix nested whiles (multiply by parent trip counts)
    def multiplier(cname: str, depth: int = 0) -> int:
        if depth > 4:
            return 1
        mult = body_trips.get(cname, 1) if cname in body_trips else 1
        # find parents: computations containing a while whose body is cname
        for parent, lines in comps.items():
            text = "\n".join(lines)
            if f"body={cname}" in text and parent != cname:
                return mult * multiplier(parent, depth + 1)
        return mult

    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "result_bytes": 0.0, "traffic_bytes": 0.0}
    )
    for cname, lines in comps.items():
        mult = multiplier(cname)
        for line in lines:
            m = _COLL_RE.search(line)
            if not m or "-done(" in line:
                continue
            kind = m.group(1)
            rb = _result_bytes(line, kind)
            g = _group_size(line)
            s = stats[kind]
            s["count"] += mult
            s["result_bytes"] += rb * mult
            s["traffic_bytes"] += _traffic(kind, rb, g) * mult
    return dict(stats)


def total_collective_bytes(stats: Dict[str, Dict[str, float]]) -> Tuple[float, float]:
    traffic = sum(s["traffic_bytes"] for s in stats.values())
    result = sum(s["result_bytes"] for s in stats.values())
    return traffic, result


def op_census(hlo_text: str, ops=("fusion", "dot", "convolution", "custom-call")) -> Dict[str, int]:
    census: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        for op in ops:
            if f" {op}(" in line:
                census[op] += 1
    return dict(census)
