"""Frozen copy of the seed (pre-refactor) scheduling engine.

These modules are byte-for-byte the seed implementations of the simulator,
scheduler base, Hiku, baselines, and trace generation (imports rewired to be
package-local).  They exist solely as the equivalence oracle for the
refactored hot path: tests/test_equivalence.py proves the optimized engine
produces byte-identical ``RequestRecord`` streams against this reference for
all four paper schedulers.  Do not optimize or "fix" these files.
"""

from . import baselines as _baselines  # noqa: F401  (registers schedulers)
from . import hiku as _hiku  # noqa: F401
from .scheduler import make_scheduler
from .simulator import SimConfig, Simulator

__all__ = ["SimConfig", "Simulator", "make_scheduler"]
