"""Baseline schedulers evaluated in the paper (Section V) plus RJ-CH.

* ``random``             — uniform random worker.
* ``least_connections``  — min active connections, random tie-break.
* ``ch``                 — consistent hashing on a ring with virtual nodes
                           (Section II-C, Figure 3).
* ``ch_bl``              — consistent hashing with bounded loads
                           [Mirrokni et al.], load threshold c = 1.25 as
                           recommended and used by the paper.
* ``rj_ch``              — random-jump consistent hashing [Chen et al.]:
                           jump to a random non-overloaded worker instead of
                           walking the ring (avoids cascaded overflows).

The ring uses a salted stable hash (blake2b) so experiments are reproducible
across processes (Python's builtin ``hash`` is randomized per process).
"""

from __future__ import annotations

import bisect
import hashlib
from typing import List, Tuple

from .scheduler import Scheduler, register


def _stable_hash(key: str) -> int:
    return int.from_bytes(hashlib.blake2b(key.encode(), digest_size=8).digest(), "big")


@register("random")
class RandomScheduler(Scheduler):
    def select(self, func: str) -> int:
        return self.rng.choice(self.workers)


@register("least_connections")
class LeastConnectionsScheduler(Scheduler):
    def select(self, func: str) -> int:
        return self._least_connections()


class _HashRing:
    """Consistent-hash ring with virtual nodes."""

    def __init__(self, workers: List[int], vnodes: int = 100):
        self.vnodes = vnodes
        self._ring: List[Tuple[int, int]] = []  # (point, worker)
        for w in workers:
            self.add(w)

    def add(self, worker: int) -> None:
        for v in range(self.vnodes):
            point = _stable_hash(f"worker-{worker}-vnode-{v}")
            bisect.insort(self._ring, (point, worker))

    def remove(self, worker: int) -> None:
        self._ring = [(p, w) for (p, w) in self._ring if w != worker]

    def walk(self, key: str):
        """Yield workers clockwise from the key's position (with wrap)."""
        point = _stable_hash(key)
        i = bisect.bisect_right(self._ring, (point, -1))
        n = len(self._ring)
        for k in range(n):
            yield self._ring[(i + k) % n][1]

    def lookup(self, key: str) -> int:
        return next(self.walk(key))


@register("ch")
class ConsistentHashingScheduler(Scheduler):
    """Plain consistent hashing: next clockwise worker on the ring."""

    def __init__(self, n_workers: int, seed: int = 0, vnodes: int = 100):
        super().__init__(n_workers, seed)
        self.ring = _HashRing(self.workers, vnodes)

    def select(self, func: str) -> int:
        return self.ring.lookup(func)

    def on_worker_added(self, worker: int) -> None:
        super().on_worker_added(worker)
        self.ring.add(worker)

    def on_worker_removed(self, worker: int) -> None:
        super().on_worker_removed(worker)
        self.ring.remove(worker)


class _BoundedLoadMixin:
    """Shared overload predicate for CH-BL / RJ-CH.

    A worker is *overloaded* when accepting one more request would push its
    active-connection count above ``ceil(c * mean_load)`` with c = 1.25
    (the bounded-loads capacity rule of Mirrokni et al. applied to the
    active-request load measure used by the OpenLambda scheduler).
    """

    threshold: float

    def _capacity(self) -> float:
        total = sum(self.conns[w] for w in self.workers) + 1  # incl. new req
        import math

        return math.ceil(self.threshold * total / max(1, len(self.workers)))

    def _overloaded(self, worker: int, cap: float) -> bool:
        return self.conns[worker] + 1 > cap


@register("ch_bl")
class CHBLScheduler(ConsistentHashingScheduler, _BoundedLoadMixin):
    """Consistent hashing with bounded loads (threshold 1.25)."""

    def __init__(self, n_workers: int, seed: int = 0, vnodes: int = 100, threshold: float = 1.25):
        super().__init__(n_workers, seed, vnodes)
        self.threshold = threshold

    def select(self, func: str) -> int:
        cap = self._capacity()
        first = None
        for w in self.ring.walk(func):
            if first is None:
                first = w
            if not self._overloaded(w, cap):
                return w
        return first  # everyone overloaded: fall back to hash target

    # NOTE: cascaded overflows (Section II-C) are inherent: the clockwise
    # successor of a hot worker absorbs its spill and overloads next.


@register("rj_ch")
class RJCHScheduler(ConsistentHashingScheduler, _BoundedLoadMixin):
    """Random-jump consistent hashing: random non-overloaded worker on spill."""

    def __init__(self, n_workers: int, seed: int = 0, vnodes: int = 100, threshold: float = 1.25):
        super().__init__(n_workers, seed, vnodes)
        self.threshold = threshold

    def select(self, func: str) -> int:
        cap = self._capacity()
        target = self.ring.lookup(func)
        if not self._overloaded(target, cap):
            return target
        ok = [w for w in self.workers if not self._overloaded(w, cap) and w != target]
        return self.rng.choice(ok) if ok else target
