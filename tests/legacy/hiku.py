"""Hiku: pull-based scheduling (Algorithm 1 of the paper).

Key idea: decouple worker selection from task assignment.  After a worker
finishes executing a function of type ``f`` it *proactively enqueues itself*
in the idle priority queue ``PQ_f`` (the pull mechanism).  An incoming request
for ``f`` dequeues the least-loaded enqueued worker — a guaranteed-warm
assignment.  If ``PQ_f`` is empty the fallback mechanism (least connections,
random tie-break) assigns the request.

``PQ_f`` is *sorted by the number of active connections* (Algorithm 1, note at
l.21).  Because connection counts change continuously, we store queue
membership as a multiset and resolve the minimum at dequeue time — equivalent
to keeping the queue re-sorted, and identical to what the paper's Go
implementation achieves with its sorted container.  A worker appears once per
idle instance it has enqueued (it may appear in several queues, and several
times in one queue).  ``on_evict`` removes *the first occurrence* of the
worker (Algorithm 1 l.17-20).
"""

from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Optional

from .scheduler import Scheduler, register


@register("hiku")
class HikuScheduler(Scheduler):
    """Pull-based scheduler (the paper's contribution)."""

    def __init__(self, n_workers: int, seed: int = 0, fallback: str = "least_connections"):
        super().__init__(n_workers, seed)
        # PQ_f as multiset: func -> list of worker ids (one entry per enqueued
        # idle instance).  Min-load resolution happens at dequeue.
        self.idle_queues: Dict[str, List[int]] = defaultdict(list)
        self.fallback = fallback
        # telemetry
        self.pull_hits = 0
        self.fallback_assigns = 0

    # ------------------------------------------------------------ schedule
    def select(self, func: str) -> int:
        pq = self.idle_queues.get(func)
        if pq:
            # Pull mechanism: dequeue least-loaded enqueued worker.
            w = self._dequeue_min(pq)
            self.pull_hits += 1
            return w
        # Fallback mechanism (least connections, random tie-break).
        self.fallback_assigns += 1
        if self.fallback == "random":
            return self.rng.choice(self.workers)
        return self._least_connections()

    def _dequeue_min(self, pq: List[int]) -> int:
        # priority = (active connections, worker id): deterministic tie-break
        # by lowest id keeps this object semantically identical to the array
        # formulation in jax_sched.py (tie order is unspecified in the paper).
        lmin = min((self.conns.get(w, 0), w) for w in pq)
        pq.remove(lmin[1])
        return lmin[1]

    # ------------------------------------------------------------ callbacks
    def on_finish(self, worker: int, func: str) -> None:
        super().on_finish(worker, func)
        # Pull: worker signals readiness for another request of this type.
        if worker in self.conns:  # ignore signals from removed workers
            self.idle_queues[func].append(worker)

    def on_evict(self, worker: int, func: str) -> None:
        # Notification mechanism: drop first occurrence of worker from PQ_f.
        pq = self.idle_queues.get(func)
        if pq:
            try:
                pq.remove(worker)
            except ValueError:
                pass

    def on_worker_removed(self, worker: int) -> None:
        super().on_worker_removed(worker)
        # Failure/scale-down: purge every queue entry of the worker.
        for pq in self.idle_queues.values():
            while worker in pq:
                pq.remove(worker)

    # ------------------------------------------------------------ telemetry
    def queue_depth(self, func: Optional[str] = None) -> int:
        if func is not None:
            return len(self.idle_queues.get(func, ()))
        return sum(len(q) for q in self.idle_queues.values())
