"""Scheduler interface for the serverless control plane.

The scheduler maps an incoming request for a function type to a worker id
(Section III-A of the paper: ``S(r_i) = (w_j, t_exec)``; the execution time is
decided by the worker/simulator, the scheduler only picks ``w_j``).

Schedulers keep their *own view* of cluster state, fed exclusively through the
callbacks below — exactly like the OpenLambda scheduler proxy the paper extends:

* ``on_assign(w, f)``   — request dispatched to ``w`` (active connection opens).
* ``on_finish(w, f)``   — worker reports completion (connection closes).  For
  Hiku this is the *pull* signal: the worker enqueues itself in ``PQ_f``.
* ``on_evict(w, f)``    — worker evicted an idle instance of ``f`` (keep-alive
  timeout or memory pressure) and *notifies* the scheduler (Section IV-A,
  notification mechanism).
* ``on_worker_added/on_worker_removed`` — elastic scaling / failure events.
"""

from __future__ import annotations

import abc
import random
from typing import Callable, Dict, List, Optional


class Scheduler(abc.ABC):
    """Base class; concrete schedulers implement ``select``."""

    name: str = "base"

    def __init__(self, n_workers: int, seed: int = 0):
        self.n_workers = n_workers
        self.workers: List[int] = list(range(n_workers))
        self.rng = random.Random(seed)
        # Scheduler-view active connections per worker (LC fallback et al.).
        self.conns: Dict[int, int] = {w: 0 for w in self.workers}

    # ------------------------------------------------------------------ API
    @abc.abstractmethod
    def select(self, func: str) -> int:
        """Pick a worker for a request of function type ``func``."""

    def schedule(self, func: str) -> int:
        w = self.select(func)
        self.on_assign(w, func)
        return w

    # ------------------------------------------------------------ callbacks
    def on_assign(self, worker: int, func: str) -> None:
        self.conns[worker] = self.conns.get(worker, 0) + 1

    def on_finish(self, worker: int, func: str) -> None:
        self.conns[worker] = max(0, self.conns.get(worker, 0) - 1)

    def on_cancel(self, worker: int, func: str) -> None:
        """Undo an assignment that never executed (failure race).

        Unlike ``on_finish`` this must NOT signal idle capacity (no pull
        enqueue in Hiku) — it only releases the connection count.
        """
        self.conns[worker] = max(0, self.conns.get(worker, 0) - 1)

    def on_evict(self, worker: int, func: str) -> None:  # noqa: B027
        """Sandbox-destruction notification; default: ignored."""

    def on_worker_added(self, worker: int) -> None:
        if worker not in self.conns:
            self.workers.append(worker)
            self.conns[worker] = 0
            self.n_workers = len(self.workers)

    def on_worker_removed(self, worker: int) -> None:
        if worker in self.conns:
            self.workers.remove(worker)
            del self.conns[worker]
            self.n_workers = len(self.workers)

    # ------------------------------------------------------------- helpers
    def _least_connections(self) -> int:
        """Least-connections with random tie-breaking (Algorithm 1 l.8-10)."""
        lmin = min(self.conns[w] for w in self.workers)
        tied = [w for w in self.workers if self.conns[w] == lmin]
        return self.rng.choice(tied)


# Registry -----------------------------------------------------------------
_REGISTRY: Dict[str, Callable[..., Scheduler]] = {}


def register(name: str):
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def make_scheduler(name: str, n_workers: int, seed: int = 0, **kw) -> Scheduler:
    if name not in _REGISTRY:
        raise KeyError(f"unknown scheduler {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](n_workers, seed=seed, **kw)


def available_schedulers() -> List[str]:
    return sorted(_REGISTRY)
