"""Discrete-event simulator of a FaaS cluster (reproduces the paper's §V).

Models the OpenLambda deployment of the paper: ``n_workers`` workers, each a
processor-sharing server with ``cores`` vCPUs and a finite sandbox memory
pool, a keep-alive evictor (Figure 2 lifecycle), and closed-loop virtual
users (k6) replaying seeded programs.  Any ``core.Scheduler`` plugs in; the
simulator feeds it the assign/finish/evict callbacks the real control plane
would.

Fidelity notes (recorded per DESIGN.md §2):
* scheduler<->worker notification latency is 0 (LAN RTT in the paper, ~µs);
* each sandbox executes one request at a time (OpenLambda semantics);
* cold start = instance initialization work added to the task (Table I
  cold-warm delta), executed under processor sharing like the paper's VMs;
* per-request service fluctuation is seeded by request identity so every
  scheduler replays identical stochastic demand (paper's fairness device).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .scheduler import Scheduler
from .trace import FunctionSpec, VUProgram, make_functions, make_vu_programs


@dataclasses.dataclass
class SimConfig:
    n_workers: int = 5
    cores_per_worker: float = 4.0
    # pool/keep-alive calibrated so the §V protocol lands at the paper's
    # operating point (hiku lowest cold rate ~20-30%, baselines 33-60%;
    # see EXPERIMENTS.md §Reproduction for the calibration sweep)
    mem_pool_mb: float = 2048.0
    keep_alive_s: float = 45.0
    sweep_every_s: float = 1.0
    exec_sigma: float = 0.25
    overhead_ms: float = 0.0  # scheduler decision overhead added to latency
    retry_delay_s: float = 0.05  # resubmit delay after worker failure


@dataclasses.dataclass
class RequestRecord:
    t_submit: float
    t_complete: float
    func: int
    worker: int
    cold: bool
    vu: int

    @property
    def latency_ms(self) -> float:
        return (self.t_complete - self.t_submit) * 1e3


class _Instance:
    __slots__ = ("func", "mem_mb", "last_used")

    def __init__(self, func: int, mem_mb: float, t: float):
        self.func = func
        self.mem_mb = mem_mb
        self.last_used = t


class _Task:
    __slots__ = ("func", "vu", "ev_idx", "t_submit", "work_s", "remaining_s", "cold", "worker")

    def __init__(self, func: int, vu: int, ev_idx: int, t_submit: float):
        self.func = func
        self.vu = vu
        self.ev_idx = ev_idx
        self.t_submit = t_submit
        self.work_s = 0.0
        self.remaining_s = 0.0
        self.cold = False
        self.worker = -1


class _Worker:
    """Processor-sharing server with a sandbox memory pool."""

    def __init__(self, wid: int, cfg: SimConfig):
        self.wid = wid
        self.cores = cfg.cores_per_worker
        self.pool_mb = cfg.mem_pool_mb
        self.running: List[_Task] = []
        self.idle: Dict[int, List[_Instance]] = {}  # func -> idle instances
        self.busy_mem_mb = 0.0
        self.idle_mem_mb = 0.0
        self.pending: List[_Task] = []  # waiting for memory
        self.last_t = 0.0
        self.version = 0  # invalidates stale completion events
        self.alive = True

    # ---------------------------------------------------------------- PS
    def rate(self) -> float:
        n = len(self.running)
        return 1.0 if n == 0 else min(1.0, self.cores / n)

    def advance(self, t: float) -> None:
        dt = t - self.last_t
        if dt > 0 and self.running:
            r = self.rate()
            for task in self.running:
                task.remaining_s -= dt * r
        self.last_t = t

    def next_completion(self, t: float) -> Optional[float]:
        if not self.running:
            return None
        r = self.rate()
        min_rem = min(task.remaining_s for task in self.running)
        return t + max(0.0, min_rem) / r

    # ------------------------------------------------------------- memory
    def mem_usage(self) -> float:
        return self.busy_mem_mb + self.idle_mem_mb

    def has_idle(self, func: int) -> bool:
        return bool(self.idle.get(func))

    def pop_idle(self, func: int) -> _Instance:
        inst = self.idle[func].pop()
        if not self.idle[func]:
            del self.idle[func]
        self.idle_mem_mb -= inst.mem_mb
        return inst

    def push_idle(self, inst: _Instance, t: float) -> None:
        inst.last_used = t
        self.idle.setdefault(inst.func, []).append(inst)
        self.idle_mem_mb += inst.mem_mb

    def evict_lru(self) -> Optional[_Instance]:
        """Evict the least-recently-used idle instance (force eviction)."""
        best: Optional[Tuple[int, int]] = None
        for func, lst in self.idle.items():
            for i, inst in enumerate(lst):
                if best is None or inst.last_used < self.idle[best[0]][best[1]].last_used:
                    best = (func, i)
        if best is None:
            return None
        func, i = best
        inst = self.idle[func].pop(i)
        if not self.idle[func]:
            del self.idle[func]
        self.idle_mem_mb -= inst.mem_mb
        return inst


class Simulator:
    """Event-driven FaaS cluster; ``run()`` returns request records + stats."""

    def __init__(
        self,
        scheduler: Scheduler,
        funcs: Optional[Sequence[FunctionSpec]] = None,
        cfg: Optional[SimConfig] = None,
        seed: int = 0,
    ):
        self.cfg = cfg or SimConfig()
        self.sched = scheduler
        self.funcs = list(funcs) if funcs is not None else make_functions(seed=seed)
        self.seed = seed
        self.workers = {w: _Worker(w, self.cfg) for w in range(self.cfg.n_workers)}
        self._heap: List[Tuple[float, int, str, tuple]] = []
        self._seq = itertools.count()
        self.t = 0.0
        self.records: List[RequestRecord] = []
        self.assignments: List[Tuple[float, int]] = []  # (t, worker)
        self._failures: List[Tuple[float, int]] = []
        self._additions: List[Tuple[float, int]] = []

    # ------------------------------------------------------------- events
    def _push(self, t: float, kind: str, payload: tuple = ()) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    def inject_failure(self, t: float, worker: int) -> None:
        self._failures.append((t, worker))

    def inject_worker(self, t: float, worker: int) -> None:
        self._additions.append((t, worker))

    # --------------------------------------------------------------- run
    def run(
        self,
        n_vus: int = 20,
        duration_s: float = 100.0,
        programs: Optional[List[VUProgram]] = None,
        t_start: float = 0.0,
    ) -> List[RequestRecord]:
        cfg = self.cfg
        if programs is None:
            # generous upper bound on events per VU
            n_events = int(duration_s * 4) + 16
            programs = make_vu_programs(self.funcs, n_vus, n_events, self.seed)
        self._programs = programs
        self._vu_pos = [0] * n_vus
        self._deadline = t_start + duration_s

        for vu in range(n_vus):
            self._push(t_start, "submit", (vu,))
        self._push(t_start + cfg.sweep_every_s, "sweep")
        for t, w in self._failures:
            self._push(t, "fail", (w,))
        for t, w in self._additions:
            self._push(t, "add_worker", (w,))

        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            if t > self._deadline:
                break
            self.t = t
            getattr(self, f"_ev_{kind}")(*payload)
        return self.records

    # ------------------------------------------------------------ handlers
    def _ev_submit(self, vu: int) -> None:
        prog = self._programs[vu]
        pos = self._vu_pos[vu]
        if pos >= len(prog.func_idx) or self.t > self._deadline:
            return
        self._vu_pos[vu] = pos + 1
        func = int(prog.func_idx[pos])
        task = _Task(func, vu, pos, self.t)
        self._dispatch(task)

    def _dispatch(self, task: _Task) -> None:
        fname = self.funcs[task.func].name
        w = self.sched.schedule(fname)
        if w not in self.workers or not self.workers[w].alive:
            # scheduler view raced with a failure; retry shortly
            self.sched.on_cancel(w, fname)
            self._push(self.t + self.cfg.retry_delay_s, "resubmit", (task,))
            return
        task.worker = w
        self.assignments.append((self.t, w))
        self._start_or_queue(self.workers[w], task)

    def _ev_resubmit(self, task: _Task) -> None:
        self._dispatch(task)

    def _start_or_queue(self, worker: _Worker, task: _Task) -> None:
        worker.advance(self.t)
        spec = self.funcs[task.func]
        if worker.has_idle(task.func):
            inst = worker.pop_idle(task.func)
            worker.busy_mem_mb += inst.mem_mb
            task.cold = False
        else:
            # cold path: make room for a new sandbox
            while worker.mem_usage() + spec.mem_mb > worker.pool_mb:
                evicted = worker.evict_lru()
                if evicted is None:
                    break
                self.sched.on_evict(worker.wid, self.funcs[evicted.func].name)
            if worker.mem_usage() + spec.mem_mb > worker.pool_mb:
                worker.pending.append(task)  # waits for memory
                return
            worker.busy_mem_mb += spec.mem_mb
            task.cold = True
        task.work_s = self._service_s(task)
        task.remaining_s = task.work_s
        worker.running.append(task)
        self._reschedule(worker)

    def _service_s(self, task: _Task) -> float:
        spec = self.funcs[task.func]
        rng = np.random.default_rng((self.seed, task.vu, task.ev_idx))
        sigma = self.cfg.exec_sigma
        fluct = rng.lognormal(mean=-0.5 * sigma**2, sigma=sigma)
        base_ms = spec.cold_ms if task.cold else spec.warm_ms
        return base_ms * fluct / 1e3

    def _reschedule(self, worker: _Worker) -> None:
        worker.version += 1
        nxt = worker.next_completion(self.t)
        if nxt is not None:
            self._push(nxt, "complete", (worker.wid, worker.version))

    def _ev_complete(self, wid: int, version: int) -> None:
        worker = self.workers.get(wid)
        if worker is None or version != worker.version or not worker.alive:
            return
        worker.advance(self.t)
        done = [task for task in worker.running if task.remaining_s <= 1e-12]
        worker.running = [task for task in worker.running if task.remaining_s > 1e-12]
        for task in done:
            self._complete(worker, task)
        # pending tasks may now fit (an instance went idle and can be evicted)
        self._drain_pending(worker)
        self._reschedule(worker)

    def _complete(self, worker: _Worker, task: _Task) -> None:
        spec = self.funcs[task.func]
        worker.busy_mem_mb -= spec.mem_mb
        worker.push_idle(_Instance(task.func, spec.mem_mb, self.t), self.t)
        self.sched.on_finish(worker.wid, spec.name)
        t_done = self.t + self.cfg.overhead_ms / 1e3
        self.records.append(
            RequestRecord(task.t_submit, t_done, task.func, worker.wid, task.cold, task.vu)
        )
        # closed loop: VU thinks, then submits its next request
        prog = self._programs[task.vu]
        sleep = float(prog.sleep_s[min(task.ev_idx, len(prog.sleep_s) - 1)])
        self._push(t_done + sleep, "submit", (task.vu,))

    def _drain_pending(self, worker: _Worker) -> None:
        if not worker.pending:
            return
        waiting, worker.pending = worker.pending, []  # _start_or_queue may re-append
        for task in waiting:
            spec = self.funcs[task.func]
            if (
                worker.has_idle(task.func)
                or worker.mem_usage() + spec.mem_mb <= worker.pool_mb
                or worker.idle_mem_mb > 0
            ):
                self._start_or_queue(worker, task)
            else:
                worker.pending.append(task)

    def _ev_sweep(self) -> None:
        cfg = self.cfg
        for worker in self.workers.values():
            if not worker.alive:
                continue
            worker.advance(self.t)
            for func in list(worker.idle):
                keep = []
                for inst in worker.idle[func]:
                    if self.t - inst.last_used > cfg.keep_alive_s:
                        worker.idle_mem_mb -= inst.mem_mb
                        self.sched.on_evict(worker.wid, self.funcs[func].name)
                    else:
                        keep.append(inst)
                if keep:
                    worker.idle[func] = keep
                else:
                    del worker.idle[func]
            self._drain_pending(worker)
        self._push(self.t + cfg.sweep_every_s, "sweep")

    # ------------------------------------------------- elasticity / faults
    def _ev_fail(self, wid: int) -> None:
        worker = self.workers.get(wid)
        if worker is None or not worker.alive:
            return
        worker.advance(self.t)
        worker.alive = False
        self.sched.on_worker_removed(wid)
        # running + pending tasks are lost; control plane retries them
        for task in worker.running + worker.pending:
            fresh = _Task(task.func, task.vu, task.ev_idx, task.t_submit)
            self._push(self.t + self.cfg.retry_delay_s, "resubmit", (fresh,))
        worker.running, worker.pending, worker.idle = [], [], {}
        worker.busy_mem_mb = worker.idle_mem_mb = 0.0
        del self.workers[wid]

    def _ev_add_worker(self, wid: int) -> None:
        if wid in self.workers:
            return
        w = _Worker(wid, self.cfg)
        w.last_t = self.t
        self.workers[wid] = w
        self.sched.on_worker_added(wid)
