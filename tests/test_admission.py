"""Global pull-based admission tier: balance acceptance vs the static
partition, determinism, merge/id-remap correctness, arrival handling,
watermark backpressure, and the engine-level admit_vu contract."""

import numpy as np
import pytest

from repro.core import SimConfig, Simulator, default_n_events, make_scheduler
from repro.core.admission import (
    AdmissionConfig,
    AdmissionSimulator,
    load_cv_across_shards,
    make_skewed_programs,
)
from repro.core.shard import ShardedSimulator

pytestmark = pytest.mark.shard

K, W, VUS, DUR = 4, 16, 48, 15.0
SEED = 0


@pytest.fixture(scope="module")
def skewed():
    adm = AdmissionSimulator(K, W, scheduler="hiku", seed=SEED)
    programs = make_skewed_programs(adm.funcs, VUS, default_n_events(DUR), SEED)
    return adm, programs


def test_pull_beats_static_partition_on_shard_load_cv(skewed):
    """Acceptance: under a skewed arrival population the admission tier's
    cross-shard load CV is well below the static partition's."""
    adm, programs = skewed
    static = ShardedSimulator(K, W, scheduler="hiku", seed=SEED, backend="serial").run(
        VUS, DUR, programs=programs
    )
    pull = adm.run(VUS, DUR, programs=programs)
    cv_static = load_cv_across_shards([len(r.records) for r in static.shards])
    cv_pull = pull.shard_load_cv
    assert pull.admitted == VUS
    assert cv_pull < 0.5 * cv_static, (cv_pull, cv_static)


def test_admission_run_is_deterministic(skewed):
    adm, programs = skewed
    r1 = adm.run(VUS, DUR, programs=programs)
    r2 = AdmissionSimulator(K, W, scheduler="hiku", seed=SEED).run(
        VUS, DUR, programs=programs
    )
    assert r1.records.equals(r2.records)
    assert np.array_equal(r1.assign_t, r2.assign_t)
    assert np.array_equal(r1.assign_w, r2.assign_w)
    assert [s.admitted.tolist() for s in r1.shards] == [
        s.admitted.tolist() for s in r2.shards
    ]


def test_merge_ids_and_ordering(skewed):
    adm, programs = skewed
    run = adm.run(VUS, DUR, programs=programs)
    g = run.records
    assert len(g) == sum(len(s.records) for s in run.shards)
    # global ids in range; VU ids translated through the admission tables
    assert run.workers == list(range(W))
    assert g.worker.min() >= 0 and g.worker.max() < W
    assert set(g.vu.tolist()) <= set(range(VUS))
    # every admitted VU id is unique across shards (late binding, no dup)
    all_admitted = np.concatenate([s.admitted for s in run.shards])
    assert len(all_admitted) == len(set(all_admitted.tolist())) == VUS
    # merged stream is completion-ordered, assignments time-ordered
    assert (np.diff(g.t_done) >= 0).all()
    assert (np.diff(run.assign_t) >= 0).all()
    # per-shard records use local ids that map back into the global tables
    for s in run.shards:
        if len(s.records):
            assert s.records.vu.max() < len(s.admitted)
            assert s.records.worker.max() < s.n_workers


def test_summarize_matches_direct_metrics(skewed):
    from repro.core import summarize

    adm, programs = skewed
    run = adm.run(VUS, DUR, programs=programs)
    m = run.summarize(DUR)
    assert m == summarize(run.records, (run.assign_t, run.assign_w), run.workers, DUR)
    assert m.n_requests == len(run.records)


def test_watermark_throttles_admission():
    """A tiny watermark keeps most of the queue waiting (backpressure);
    the default admits everyone eventually."""
    adm_tight = AdmissionSimulator(
        2, 4, scheduler="hiku", seed=1,
        admission=AdmissionConfig(watermark=0.26, batch_size=1),
    )
    programs = make_skewed_programs(adm_tight.funcs, 24, 64, 1, hot_frac=1.0)
    with pytest.warns(RuntimeWarning, match="never admitted"):
        r = adm_tight.run(24, 10.0, programs=programs)
    assert r.admitted < 24  # queue never fully drained
    assert r.unadmitted == 24 - r.admitted
    assert int(r.queue_depth.max(initial=0)) > 0


def test_arrival_times_gate_eligibility():
    adm = AdmissionSimulator(2, 8, scheduler="hiku", seed=2)
    programs = make_skewed_programs(adm.funcs, 12, 64, 2)
    arrivals = [0.0] * 6 + [5.0] * 3 + [100.0] * 3  # last 3 after the deadline
    with pytest.warns(RuntimeWarning, match="never admitted"):
        r = adm.run(12, 10.0, programs=programs, arrivals=arrivals)
    assert r.admitted == 9 and r.unadmitted == 3
    admit_times = {
        int(g): float(t)
        for s in r.shards
        for g, t in zip(s.admitted.tolist(), s.admit_t.tolist())
    }
    assert all(admit_times[g] >= 5.0 for g in range(6, 9))
    assert all(admit_times[g] < 5.0 for g in range(6))


def test_arrivals_in_final_partial_tick_window_stay_unadmitted():
    """Pin the tick-quantized deadline semantics: admission only happens at
    tick boundaries strictly below duration_s, so an arrival between the
    last boundary and the deadline is never admitted (documented in
    AdmissionSimulator.run)."""
    adm = AdmissionSimulator(2, 8, scheduler="hiku", seed=2)  # tick_s=0.25
    programs = make_skewed_programs(adm.funcs, 4, 64, 2)
    with pytest.warns(RuntimeWarning, match="never admitted"):
        r = adm.run(4, 10.0, programs=programs, arrivals=[0.0, 0.0, 9.8, 9.9])
    assert r.admitted == 2 and r.unadmitted == 2
    admitted_gids = sorted(g for s in r.shards for g in s.admitted.tolist())
    assert admitted_gids == [0, 1]


def test_round_robin_policy_binds_on_arrival():
    adm = AdmissionSimulator(
        3, 9, scheduler="hiku", seed=3, admission=AdmissionConfig(policy="round_robin")
    )
    programs = make_skewed_programs(adm.funcs, 12, 64, 3)
    r = adm.run(12, 8.0, programs=programs)
    assert r.admitted == 12
    assert int(r.queue_depth.max(initial=0)) == 0  # never queues
    # cyclic binding: shard k gets gids congruent to k mod 3 (all arrive at 0)
    for k, s in enumerate(r.shards):
        assert s.admitted.tolist() == [g for g in range(12) if g % 3 == k]


def test_round_robin_honors_batch_size():
    """batch_size caps round_robin bindings per shard per tick too, so a
    capped burst baseline is actually capped."""
    adm = AdmissionSimulator(
        2, 4, scheduler="hiku", seed=4,
        admission=AdmissionConfig(policy="round_robin", batch_size=1, tick_s=0.5),
    )
    programs = make_skewed_programs(adm.funcs, 8, 32, 4)
    r = adm.run(8, 10.0, programs=programs)
    assert r.admitted == 8
    # tick 0 binds at most batch_size per shard (2 total), leaving 6 queued
    assert int(r.queue_depth[0]) == 6
    # the queue drains by at most 2 per tick thereafter
    assert (np.diff(r.queue_depth[r.queue_depth > 0]) >= -2).all()
    for s in r.shards:
        assert (np.diff(np.unique(s.admit_t)) >= adm.admission.tick_s - 1e-12).all()


def test_constructor_and_run_validation():
    with pytest.raises(ValueError):
        AdmissionSimulator(0, 4)
    with pytest.raises(ValueError):
        AdmissionSimulator(5, 4)
    with pytest.raises(ValueError):
        AdmissionSimulator(2, 4, admission=AdmissionConfig(policy="gossip"))
    with pytest.raises(ValueError):
        AdmissionSimulator(2, 4, admission=AdmissionConfig(tick_s=0.0))
    with pytest.raises(ValueError):
        AdmissionSimulator(2, 4, admission=AdmissionConfig(batch_size=0))
    adm = AdmissionSimulator(2, 4, seed=0)
    progs = make_skewed_programs(adm.funcs, 4, 16, 0)
    with pytest.raises(ValueError):
        adm.run(8, 5.0, programs=progs)  # len(programs) != n_vus
    with pytest.raises(ValueError):
        adm.run(4, 5.0, programs=progs, arrivals=[0.0])  # bad arrivals shape


def test_admitted_vu_fluctuations_keep_identity_seeding():
    """An admitted VU's service draws use the (seed, local_vu, ev) identity —
    the paper's fairness device extends to dynamically admitted VUs."""
    from repro.core import make_functions, make_vu_programs

    funcs = make_functions(seed=0)
    programs = make_vu_programs(funcs, 3, 40, 77)
    sigma = SimConfig().exec_sigma

    sim = Simulator(make_scheduler("hiku", 2, seed=77), cfg=SimConfig(), seed=77)
    sim.begin(n_vus=2, duration_s=12.0, programs=programs[:2])
    sim.step_until(3.0)
    local = sim.admit_vu(programs[2], t=3.0)
    assert local == 2
    while not sim.done:
        sim.step_until(sim.t + 4.0)
    row = sim._fluct["rows"][local]
    assert len(row) > 0
    for ev in (0, 1, len(row) - 1):
        want = np.random.default_rng((77, local, ev)).lognormal(
            mean=-0.5 * sigma**2, sigma=sigma
        )
        assert row[ev] == want
    # the admitted VU actually produced records
    assert (sim.record_columns.vu == local).any()


def test_admit_vu_rejects_past_times():
    sim = Simulator(make_scheduler("hiku", 2, seed=0), cfg=SimConfig(), seed=0)
    sim.begin(n_vus=0, duration_s=5.0, programs=[])
    sim.step_until(2.0)
    from repro.core import make_functions, make_vu_programs

    prog = make_vu_programs(make_functions(seed=0), 1, 8, 0)[0]
    with pytest.raises(ValueError):
        sim.admit_vu(prog, t=1.0)


def test_pressure_signal_bounds():
    sim = Simulator(make_scheduler("hiku", 4, seed=0), cfg=SimConfig(), seed=0)
    sim.begin(n_vus=0, duration_s=5.0, programs=[])
    assert sim.pressure() == 0.0  # idle
    sim2 = Simulator(make_scheduler("hiku", 2, seed=0), cfg=SimConfig(n_workers=2), seed=0)
    sim2.run(n_vus=30, duration_s=3.0)
    # after the run everything drained again
    assert sim2.pressure() >= 0.0
