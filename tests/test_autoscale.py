"""Autoscaler (docs/ARCHITECTURE.md §14): sizing brain + engine actuation.

Unit pins for the engine's mid-run elasticity hooks (validation + the §13
dirty mark on every mutation), the worker-seconds cost integral, the
actuator's revive/doom bookkeeping, and the sizing decisions (asymmetric
hysteresis, predictive lookahead, scale-to-zero janitor) driven through a
stub actuator.  Integration pins: autoscaled runs are deterministic, the
coordinator A/B holds (tests/test_coord.py), and conservation/exactly-once
survives the autoscaler composing with live chaos plans on the same hooks.
"""

import dataclasses
import warnings

import numpy as np
import pytest

from repro.core import (
    AutoscaleConfig,
    Autoscaler,
    EventPlane,
    SimConfig,
    Simulator,
    make_functions,
    make_scheduler,
    shard_kill_wave,
    spot_preemption,
)
from repro.core.admission import AdmissionConfig, AdmissionSimulator
from repro.core.autoscale import AutoscaleActuator
from repro.core.eventplane import CLUSTER_TOPIC, SHARD_TOPIC
from repro.core.workloads import make_scenario

pytestmark = pytest.mark.shard

FUNCS = make_functions(seed=0)


def _sim(n_workers=4, dur=10.0, seed=0):
    sim = Simulator(
        make_scheduler("hiku", n_workers, seed=seed), funcs=FUNCS,
        cfg=SimConfig(n_workers=n_workers), seed=seed,
    )
    sim.begin(n_vus=0, duration_s=dur, programs=[])
    return sim


# ------------------------------------------------------------ config guard
def test_config_validation():
    AutoscaleConfig()  # defaults are valid
    for bad in (
        dict(mode="magic"),
        dict(window_s=0.0),
        dict(target_pressure=0.0),
        dict(target_pressure=1.5),
        dict(min_workers=-1),
        dict(initial_frac=0.0),
        dict(notice_s=-0.1),
        dict(horizon_windows=0),
        dict(alpha=0.0),
        dict(max_step=0),
        dict(down_step=0),
        dict(down_after=0),
        dict(idle_windows=0),
    ):
        with pytest.raises(ValueError):
            AutoscaleConfig(**bad)


def test_initial_split_floor_and_cap():
    asc = Autoscaler(AutoscaleConfig(initial_frac=0.5, min_workers=2))
    assert asc.initial_split([8, 5, 1]) == [4, 3, 1]  # ceil, floored, capped
    asc = Autoscaler(AutoscaleConfig(initial_frac=0.1, min_workers=2))
    assert asc.initial_split([8, 5, 1]) == [2, 2, 1]


# -------------------------------------- engine hooks: validation + dirty mark
def test_schedule_hooks_validate_and_mark_dirty():
    """The §13 invariant the coordinator A/B rests on: every elasticity
    mutation marks the owning shard dirty *at schedule time* (the heap
    gained an event the frontier skip must see)."""
    sim = _sim()
    sink = set()
    sim.attach_dirty(sink, 3)
    sink.clear()
    sim.schedule_worker_add(1.0, 0)
    assert sink == {3}
    sink.clear()
    sim.schedule_worker_fail(2.0, 1)
    assert sink == {3}
    sink.clear()
    sim.schedule_notice(2.0, 2, until=3.0)
    assert sink == {3}
    # validation: past times, beyond-deadline times, bad ids, until < t
    with pytest.raises(ValueError):
        sim.schedule_worker_add(11.0, 0)  # past the deadline
    with pytest.raises(ValueError):
        sim.schedule_worker_fail(1.0, -1)
    sim.step_until(5.0)
    with pytest.raises(ValueError):
        sim.schedule_worker_add(4.0, 0)  # behind the clock
    with pytest.raises(ValueError):
        sim.schedule_notice(6.0, 0, until=5.5)


# ------------------------------------------------- worker-seconds integral
def test_worker_seconds_piecewise_integral():
    """cost = integral of live workers: 4 x 2s, 3 x 4s, 4 x 4s."""
    sim = Simulator(
        make_scheduler("hiku", 4, seed=0), funcs=FUNCS,
        cfg=SimConfig(n_workers=4), seed=0,
    )
    sim.inject_failure(2.0, 3)
    sim.inject_worker(6.0, 3)
    sim.begin(n_vus=0, duration_s=10.0, programs=[])
    sim.step_until(10.0)
    assert sim.worker_seconds_until(10.0) == 4 * 2 + 3 * 4 + 4 * 4
    # the read is non-mutating and monotone in t
    assert sim.worker_seconds_until(10.0) == 36.0
    assert sim.worker_seconds_until(8.0) == 28.0


def test_worker_seconds_static_run_is_pool_times_duration():
    adm = AdmissionSimulator(
        2, 8, scheduler="hiku", cfg=SimConfig(mem_pool_mb=1024.0), seed=0,
        admission=AdmissionConfig(),
    )
    run = adm.run(12, 6.0)
    assert run.worker_seconds == 8 * 6.0
    assert [s.worker_seconds for s in run.shards] == [4 * 6.0, 4 * 6.0]


# ------------------------------------------------------------ the actuator
def test_actuator_dooms_high_ids_revives_low_ids():
    sim = _sim(n_workers=4, dur=10.0)
    notices = []
    act = AutoscaleActuator([sim], [4], [0], notices, 10.0, notice_s=1.0)
    assert act.alive(0) == 4 and act.planned(0, 0.0) == 4
    assert act.scale_to(0.0, 0, 2) == -2  # dooms workers 3 then 2
    assert notices == [(0.0, 0, 1.0), (0.0, 0, 1.0)]
    assert [(a.kind, a.worker) for a in act.actions] == [
        ("notice", 3), ("fail", 3), ("notice", 2), ("fail", 2),
    ]
    assert act.planned(0, 0.0) == 2  # doomed capacity no longer counts
    assert act.scale_to(0.0, 0, 2) == 0  # converged: idempotent
    sim.step_until(1.5)  # the kills fire at t=1.0
    assert act.alive(0) == 2 and act.planned(0, 1.5) == 2
    assert act.scale_to(1.5, 0, 3) == 1  # revives the lowest dead id: 2
    adds = [a for a in act.actions if a.kind == "add"]
    assert [(a.worker, a.fire_t) for a in adds] == [(2, 1.5)]
    assert act.planned(0, 1.5) == 3  # pending add counts before it fires
    sim.step_until(1.6)
    assert act.alive(0) == 3


def test_actuator_drops_actions_past_the_deadline():
    """The termination guarantee: no engine event is ever scheduled at or
    past the deadline (it could never fire; the run must end)."""
    sim = _sim(n_workers=4, dur=10.0)
    act = AutoscaleActuator([sim], [4], [0], [], 10.0, notice_s=1.0)
    act.scale_to(0.0, 0, 2)
    sim.step_until(9.5)
    assert act.scale_to(9.5, 0, 1) == 0  # kill would land at 10.5 >= 10
    assert act.scale_to(10.0, 0, 4) == 0  # add at the deadline itself
    assert not [a for a in act.actions if a.fire_t >= 10.0]


def test_actuator_clamps_target_to_span():
    sim = _sim(n_workers=4, dur=10.0)
    act = AutoscaleActuator([sim], [4], [0], [], 10.0, notice_s=1.0)
    assert act.scale_to(0.0, 0, 99) == 0  # span-clamped: already at 4
    act.scale_to(0.0, 0, -5)  # clamped to 0: dooms everyone
    assert act.planned(0, 0.0) == 0


# ------------------------------------------- sizing decisions (stub-driven)
class _StubActuator:
    """Recording actuator: tracks the planned size per shard, no engine."""

    def __init__(self, split):
        self._planned = list(split)
        self.calls = []

    def planned(self, k, t):
        return self._planned[k]

    def scale_to(self, t, k, target):
        self.calls.append((t, k, target))
        self._planned[k] = target
        return 0


def _drive(asc, split, windows):
    """Publish synthetic metric windows; each entry is (loads, n_done,
    sum_ms, queue_depth)."""
    bus = EventPlane()
    stub = _StubActuator(split)
    asc.attach(bus, stub, split)
    for i, (loads, n_done, sum_ms, queue_depth) in enumerate(windows):
        t_hi = float(i + 1)
        for k, load in enumerate(loads):
            bus.publish(
                (SHARD_TOPIC, k), i, t_hi - 1.0, t_hi,
                {
                    "n_done": n_done, "sum_ms": sum_ms, "n_cold": 0,
                    "load": load, "alive": stub.planned(k, t_hi),
                    "outstanding": load, "pressure": 0.0,
                },
            )
        bus.publish(
            (CLUSTER_TOPIC,), i, t_hi - 1.0, t_hi,
            {"n_done": n_done * len(loads), "arrivals": 0,
             "queue_depth": queue_depth},
        )
    return stub


def test_reactive_downscale_is_hysteretic_upscale_is_not():
    """Excess capacity is retired only after ``down_after`` consecutive
    over-provisioned windows, then ``down_step`` per window; demand spikes
    recover up to ``max_step`` immediately."""
    asc = Autoscaler(AutoscaleConfig(
        mode="reactive", target_pressure=0.5, down_after=2, down_step=1,
        max_step=4,
    ))
    low = ([2], 4, 400.0, 0)  # react target: ceil(2/0.5) = 4 < planned 8
    high = ([4], 4, 400.0, 0)  # react target: 8
    stub = _drive(asc, [8], [low, low, low, high])
    assert [t for _, _, t in stub.calls] == [8, 7, 6, 8]
    assert asc.targets_log == [[8], [7], [6], [8]]


def test_janitor_zeroes_an_idle_shard_bypassing_the_ramp():
    """After ``idle_windows`` windows with no load, no outstanding work and
    an empty queue, the whole pool retires at once (scale-to-zero)."""
    asc = Autoscaler(AutoscaleConfig(
        mode="reactive", scale_to_zero=True, idle_windows=3, down_after=2,
        down_step=1, min_workers=1,
    ))
    idle = ([0], 0, 0.0, 0)
    stub = _drive(asc, [8], [idle, idle, idle])
    assert [t for _, _, t in stub.calls] == [8, 7, 0]


def test_janitor_disabled_keeps_the_min_workers_floor():
    asc = Autoscaler(AutoscaleConfig(
        mode="reactive", scale_to_zero=False, idle_windows=3, down_after=1,
        down_step=4, min_workers=1,
    ))
    idle = ([0], 0, 0.0, 0)
    stub = _drive(asc, [8], [idle] * 6)
    assert stub.calls[-1][2] == 1  # ramps down to the floor, never 0


def test_predictive_provisions_ahead_of_a_rising_rate():
    """With identical (low) current load, the predictive mode sizes for the
    forecast worst window — strictly above the reactive answer once the
    completion rate trends up."""
    windows = [
        ([1], n_done, n_done * 500.0, 0) for n_done in (0, 10, 20, 30)
    ]
    stub_r = _drive(
        Autoscaler(AutoscaleConfig(mode="reactive", max_step=8)), [8], windows
    )
    stub_p = _drive(
        Autoscaler(AutoscaleConfig(mode="predictive", max_step=8)), [8], windows
    )
    assert stub_p.calls[-1][2] > stub_r.calls[-1][2]


def test_queue_depth_counts_as_shard_demand():
    """A backed-up global admission queue raises every shard's target even
    when the shards themselves look idle."""
    asc = Autoscaler(AutoscaleConfig(mode="reactive", target_pressure=0.5))
    stub = _drive(asc, [4, 4], [([0, 0], 0, 0.0, 6)])
    # each shard owns half the queue: ceil(3/0.5) = 6, span-clamped to 4
    assert [t for _, _, t in stub.calls] == [4, 4]


def test_attach_twice_raises():
    asc = Autoscaler()
    asc.attach(EventPlane(), _StubActuator([4]), [4])
    with pytest.raises(RuntimeError, match="attached"):
        asc.attach(EventPlane(), _StubActuator([4]), [4])


# ------------------------------------------------------------- integration
def _autoscaled(scenario="flash_crowd", mode="predictive", faults=None,
                seed=0, K=3, W=12, vus=24, dur=10.0):
    scn = make_scenario(scenario, FUNCS, vus, dur, seed=seed)
    if faults is not None:
        scn = dataclasses.replace(scn, faults=faults)
    adm = AdmissionSimulator(
        K, W, scheduler="hiku", cfg=SimConfig(mem_pool_mb=1024.0), seed=seed,
        admission=AdmissionConfig(),
    )
    asc = Autoscaler(AutoscaleConfig(mode=mode, target_pressure=0.6))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        run = adm.run(vus, dur, autoscaler=asc, **scn.run_kwargs())
    return run, asc


def test_autoscaled_run_is_deterministic():
    """Decisions are a pure function of the published stream: identical
    runs, identical action schedules, identical targets."""
    a, asc_a = _autoscaled()
    b, asc_b = _autoscaled()
    assert a.records.equals(b.records)
    np.testing.assert_array_equal(a.assign_t, b.assign_t)
    assert asc_a.actuator.actions == asc_b.actuator.actions
    assert asc_a.targets_log == asc_b.targets_log
    assert a.worker_seconds == b.worker_seconds


def test_autoscaled_run_cheaper_than_static_nothing_lost():
    """The headline economics at smoke scale: elasticity buys worker-seconds
    back without losing or stranding a single task."""
    run, asc = _autoscaled()
    assert len(asc.actuator.actions) > 0
    assert run.worker_seconds < 12 * 10.0  # strictly under the static pool
    assert run.lost_tasks == 0 and run.stranded == 0
    assert len(run.records) > 0


def _no_duplicate_completions(run):
    order = np.lexsort((run.records.t_submit, run.records.vu))
    vu, ts = run.records.vu[order], run.records.t_submit[order]
    assert not ((np.diff(vu) == 0) & (np.diff(ts) == 0)).any()


def test_conservation_under_shard_kill_wave_with_autoscaler():
    """Chaos composition (§10 x §14): a correlated shard kill with the
    autoscaler live on the same hooks — salvage bookkeeping balances,
    nothing strands, nothing completes twice, and the run is replayable."""
    faults = shard_kill_wave(3, 12, shards=[1], t_kill=3.0)
    a, asc_a = _autoscaled(scenario="on_off", faults=faults)
    assert len(asc_a.actuator.actions) > 0  # both planes actually acted
    assert a.stranded == 0 and a.unsalvaged == 0
    assert sum(s.salvaged_out for s in a.shards) == a.n_salvages
    assert sum(s.salvaged_in for s in a.shards) == a.n_salvages
    _no_duplicate_completions(a)
    b, asc_b = _autoscaled(scenario="on_off", faults=faults)
    assert a.records.equals(b.records)
    assert asc_a.actuator.actions == asc_b.actuator.actions


def test_conservation_under_spot_preemption_with_autoscaler():
    """Spot preemptions (notice -> kill -> delayed replace) interleave with
    autoscaler adds/dooms on one event schedule; conservation holds."""
    faults = spot_preemption(
        12, n_waves=2, wave_size=2, t0=2.0, t1=6.0, notice_s=1.0,
        replace_after_s=2.0, seed=0,
    )
    a, asc_a = _autoscaled(faults=faults)
    assert len(asc_a.actuator.actions) > 0
    assert a.stranded == 0 and a.unsalvaged == 0
    _no_duplicate_completions(a)
    b, asc_b = _autoscaled(faults=faults)
    assert a.records.equals(b.records)
    assert asc_a.actuator.actions == asc_b.actuator.actions
    assert a.worker_seconds == b.worker_seconds


def test_autoscaler_creates_bus_when_none_given():
    run, asc = _autoscaled(dur=6.0, vus=12)
    assert asc.actuator is not None
    assert len(asc.targets_log) > 0  # decisions fired on the implicit bus
    assert run.n_events > 0
