"""Hypothesis property sweep for the event-plane/autoscale PR (§14).

Three randomized invariants, separate from the deterministic suites so
environments without hypothesis still run those:

* burst-adaptive fused dispatch (``sched_many_adaptive``) is **bitwise**
  equal to the event-by-event scan on arbitrary mixed event streams, under
  arbitrary detector tunings and density sample streams;
* the :class:`BurstDetector` chunk choice is monotone in the observed
  density stream (pointwise-dominating densities never pick a smaller
  chunk) whenever the threshold table maps higher densities to larger
  chunks;
* the :class:`EventPlane` delivery log is a pure function of
  (seed, subscriptions): replaying the same seeded publish sequence into
  the same subscription set reproduces the log exactly, payloads included.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip only the property tests
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import (  # noqa: E402
    ARRIVAL,
    BurstDetector,
    EventPlane,
    init_state,
    sched_many,
    sched_many_adaptive,
)
from repro.core.eventplane import CLUSTER_TOPIC, SHARD_TOPIC  # noqa: E402

pytestmark = pytest.mark.shard

N_FUNCS, N_WORKERS = 6, 9


def _mixed_events(rng, n, n_funcs=N_FUNCS, n_workers=N_WORKERS):
    """Random arrival/finish/evict stream (same shape as tests/
    test_scheduler.py): worker ids only matter for non-arrival kinds."""
    events = []
    for _ in range(n):
        k = int(rng.integers(0, 3))
        events.append(
            (k, int(rng.integers(0, n_funcs)),
             -1 if k == ARRIVAL else int(rng.integers(0, n_workers)))
        )
    return jnp.array(events, jnp.int32)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.integers(1, 120),
    threshold=st.floats(1.0, 1000.0),
    chunk=st.integers(2, 64),
    alpha=st.floats(0.05, 1.0),
    segment=st.integers(1, 90),
)
def test_adaptive_dispatch_bitwise_equals_scan(
    seed, n, threshold, chunk, alpha, segment
):
    """Whatever chunk sizes the detector picks window by window — including
    mid-stream switches and ragged tails — the fused dispatch result is
    bitwise the scan's: the detector is a pure observer."""
    rng = np.random.default_rng(seed)
    ev = _mixed_events(rng, n)
    n_windows = -(-n // segment)  # ceil: one density sample per window
    densities = rng.uniform(0.0, 2.0 * threshold, n_windows).tolist()
    det = BurstDetector(
        alpha=alpha, thresholds=((threshold, chunk),), base_chunk=1
    )
    s1, (ws1, warm1) = sched_many(init_state(N_FUNCS, N_WORKERS), ev)
    s2, (ws2, warm2) = sched_many_adaptive(
        init_state(N_FUNCS, N_WORKERS), ev, det, densities=densities,
        segment=segment, interpret=True,
    )
    assert jnp.all(ws1 == ws2) and jnp.all(warm1 == warm2)
    assert jnp.all(s1.idle == s2.idle) and jnp.all(s1.conns == s2.conns)


@settings(max_examples=50, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_samples=st.integers(1, 12),
    alpha=st.floats(0.05, 1.0),
    n_rows=st.integers(1, 4),
)
def test_burst_detector_chunk_monotone_in_density(seed, n_samples, alpha, n_rows):
    """Feed two streams where one pointwise dominates the other: the EWMA
    (linear, positive weights) dominates too, so with a threshold table
    whose chunks grow with density the chosen chunk never shrinks."""
    rng = np.random.default_rng(seed)
    # density-descending AND chunk-descending rows: monotone table
    dens = np.sort(rng.uniform(1.0, 1000.0, n_rows))[::-1]
    chunks = np.sort(rng.integers(2, 4096, n_rows))[::-1]
    table = tuple((float(d), int(c)) for d, c in zip(dens, chunks))
    lo = rng.uniform(0.0, 1500.0, n_samples)
    hi = lo + rng.uniform(0.0, 500.0, n_samples)  # pointwise >= lo
    det_lo = BurstDetector(alpha=alpha, thresholds=table, base_chunk=1)
    det_hi = BurstDetector(alpha=alpha, thresholds=table, base_chunk=1)
    for a, b in zip(lo, hi):
        c_lo, c_hi = det_lo.observe(float(a)), det_hi.observe(float(b))
        assert det_hi.ewma >= det_lo.ewma
        assert c_hi >= c_lo


@settings(max_examples=50, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_shards=st.integers(1, 5),
    n_events=st.integers(1, 80),
    patterns=st.lists(
        st.sampled_from(
            [
                (SHARD_TOPIC, "*"),
                (SHARD_TOPIC, 0),
                (SHARD_TOPIC, 2),
                (CLUSTER_TOPIC,),
                (CLUSTER_TOPIC, "*"),  # wrong arity: matches nothing
            ]
        ),
        min_size=0,
        max_size=6,
    ),
)
def test_delivery_log_pure_function_of_seed_and_subscriptions(
    seed, n_shards, n_events, patterns
):
    """Two buses with the same subscription list, fed the same seeded
    publish sequence, produce identical delivery logs and identical
    per-subscriber event streams — delivery order is never a function of
    anything but (seed, subscriptions)."""

    def build():
        bus = EventPlane()
        seen = [[] for _ in patterns]
        for sink, pattern in zip(seen, patterns):
            bus.subscribe(
                pattern,
                lambda ev, sink=sink: sink.append(
                    (ev.seq, ev.topic, ev.window, dict(ev.payload))
                ),
            )
        rng = np.random.default_rng(seed)
        for i in range(n_events):
            k = int(rng.integers(0, n_shards + 1))
            topic = (SHARD_TOPIC, k) if k < n_shards else (CLUSTER_TOPIC,)
            bus.publish(topic, i, float(i), float(i + 1),
                        {"n_done": int(rng.integers(0, 100))})
        return bus, seen

    bus_a, seen_a = build()
    bus_b, seen_b = build()
    assert bus_a.log == bus_b.log
    assert seen_a == seen_b
    assert (bus_a.published, bus_a.delivered) == (bus_b.published, bus_b.delivered)
    # the log is exactly the per-subscriber streams, interleaved in seq
    # order with registration order breaking ties
    rebuilt = [
        (seq, topic, window, sub_id)
        for sub_id, stream in enumerate(seen_a)
        for (seq, topic, window, _payload) in stream
    ]
    rebuilt.sort(key=lambda r: (r[0], r[3]))
    assert rebuilt == bus_a.log
