"""Continuous batching + cache-slot management."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import build_model, unzip
from repro.serving.batching import ContinuousBatcher, GenRequest
from repro.serving.kv_cache import CacheManager


def _tiny_model():
    cfg = get_config("llava_next_mistral_7b").reduced()
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                              head_dim=16, d_ff=64, vocab=64)
    model = build_model(cfg, remat=False)
    params, _ = unzip(model.init(jax.random.key(0)))
    return model, params


def test_cache_manager_slots():
    model, _ = _tiny_model()
    mgr = CacheManager(model, n_slots=3, max_len=16, dtype=jnp.float32)
    a = mgr.allocate("a")
    b = mgr.allocate("b")
    c = mgr.allocate("c")
    assert {a.idx, b.idx, c.idx} == {0, 1, 2}
    assert mgr.allocate("d") is None  # full
    assert mgr.utilization() == 1.0
    mgr.release("b")
    d = mgr.allocate("d")
    assert d.idx == 1  # reused slot
    assert mgr.bytes() > 0


def test_continuous_batching_completes_and_interleaves():
    model, params = _tiny_model()
    b = ContinuousBatcher(model, params, n_slots=2, max_len=32)
    # 4 requests but only 2 slots: finishing requests free slots mid-run
    for i in range(4):
        b.submit(GenRequest(f"r{i}", prompt=[1 + i, 2 + i], max_new_tokens=3 + i))
    out = b.run_to_completion()
    assert set(out) == {"r0", "r1", "r2", "r3"}
    for i in range(4):
        assert len(out[f"r{i}"]) == 3 + i
        assert all(0 <= t < model.cfg.vocab for t in out[f"r{i}"])
    assert b.mgr.utilization() == 0.0  # all slots returned


def test_batched_isolation():
    """Tokens decoded in one slot must not corrupt another slot's stream."""
    model, params = _tiny_model()
    # run request alone
    b1 = ContinuousBatcher(model, params, n_slots=2, max_len=32)
    b1.submit(GenRequest("solo", prompt=[5, 6, 7], max_new_tokens=4))
    solo = b1.run_to_completion()["solo"]
    # run the same request alongside a noisy neighbor
    b2 = ContinuousBatcher(model, params, n_slots=2, max_len=32)
    b2.submit(GenRequest("solo", prompt=[5, 6, 7], max_new_tokens=4))
    b2.submit(GenRequest("noise", prompt=[9, 10, 11, 12], max_new_tokens=6))
    both = b2.run_to_completion()
    assert both["solo"] == solo
