"""Benchmark harness smoke test: ``benchmarks/run.py --quick`` must run every
module without ERROR rows (so bench modules can't silently bit-rot)."""

import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_benchmarks_run_quick_smoke():
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--quick"],
        cwd=ROOT,
        capture_output=True,
        text=True,
        timeout=900,
        env={**__import__("os").environ, "PYTHONPATH": str(ROOT / "src")},
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    lines = [l for l in out.stdout.splitlines() if l.strip()]
    assert lines and lines[0] == "name,us_per_call,derived"
    errors = [l for l in lines if "/ERROR," in l]
    assert not errors, f"benchmark modules failed: {errors}"
    # every registered module must have reported a wall-time row
    walls = {l.split(",")[0].split("/")[1] for l in lines if l.startswith("_bench_wall/")}
    expected = {"table1", "trace", "latency", "coldstart", "imbalance", "throughput",
                "concurrency", "overhead", "kernels", "pull_dispatch", "sim_speed",
                "shard_scale", "admission", "stealing", "affinity", "autoscale"}
    assert expected <= walls, f"missing modules: {expected - walls}"
    # the quick path must include the 2-shard smoke
    assert any(l.startswith("shard_scale/quick_2shards") for l in lines), lines[-20:]


@pytest.mark.slow
def test_sim_speed_bench_reports_10x_at_scale():
    """Acceptance: >=10x events/sec over the checked-in seed baseline at the
    production-scale anchor configs.

    The checked-in baseline is an absolute same-machine measurement, so on
    much slower hardware this assertion is about the *reported* ratio; the
    hardware-independent regression pin is the live legacy-vs-new test below.
    """
    sys.path.insert(0, str(ROOT))
    try:
        from benchmarks import bench_sim_speed
    finally:
        sys.path.pop(0)
    rows = bench_sim_speed.run(quick=False)
    speedups = {}
    for name, _, derived in rows:
        if "speedup=" in str(derived):
            speedups[name] = float(str(derived).split("speedup=")[1].rstrip("x"))
    scale_anchors = [v for k, v in speedups.items() if k.endswith("_8g")]
    assert scale_anchors, f"no scale anchors in {speedups}"
    assert max(scale_anchors) >= 10.0, f"speedups below acceptance: {speedups}"


@pytest.mark.slow
@pytest.mark.shard
def test_shard_scale_bench_aggregate_speedup_acceptance():
    """Acceptance: >=3x aggregate events/sec at 8 shards vs 1 shard at the
    1600-worker anchor.  The aggregate metric sums per-shard rates measured
    on each shard's own wall clock (what K independent clusters report), so
    it is meaningful even on a 2-core CI box where the makespan speedup is
    bounded by local parallelism."""
    sys.path.insert(0, str(ROOT))
    try:
        from benchmarks import bench_shard_scale
    finally:
        sys.path.pop(0)
    rows = bench_shard_scale.run(quick=False)
    speedups = {}
    for name, _, derived in rows:
        if "speedup_vs_1shard=" in str(derived):
            speedups[name] = float(str(derived).split("speedup_vs_1shard=")[1].rstrip("x"))
    anchor = {k: v for k, v in speedups.items()
              if "1600w" in k and k.endswith("/8shards")}
    assert anchor, f"no 8-shard 1600w row in {speedups}"
    assert max(anchor.values()) >= 3.0, f"aggregate speedups below acceptance: {speedups}"


@pytest.mark.slow
def test_engine_speedup_live_vs_frozen_seed():
    """Hardware-independent acceptance backstop: time the frozen seed engine
    (tests/legacy) and the refactored engine live, same process, same config
    (a reduced-duration variant of the 800w/8G scale anchor)."""
    import gc
    import time

    from legacy import SimConfig as LegacyCfg
    from legacy import Simulator as LegacySim
    from legacy import make_scheduler as legacy_make
    from repro.core import SimConfig, Simulator, make_scheduler

    nw, vus, dur, mem = 800, 8000, 4.0, 8192.0

    def timed(mk, Sim, Cfg):
        gc.collect()
        sched = mk("hiku", nw, seed=0)
        sim = Sim(sched, cfg=Cfg(n_workers=nw, mem_pool_mb=mem), seed=0)
        t0 = time.perf_counter()
        recs = sim.run(n_vus=vus, duration_s=dur)
        return len(recs), time.perf_counter() - t0

    n_new, wall_new = timed(make_scheduler, Simulator, SimConfig)
    n_old, wall_old = timed(legacy_make, LegacySim, LegacyCfg)
    assert n_new == n_old  # same workload replayed
    ratio = wall_old / wall_new
    # full-duration anchors measure ~12-18x; 6x here leaves noise headroom
    # while still catching any order-of-magnitude regression
    assert ratio >= 6.0, f"live speedup collapsed: {ratio:.1f}x ({wall_old:.1f}s vs {wall_new:.1f}s)"
