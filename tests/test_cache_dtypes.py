"""fp8 KV cache (the §Perf serving trade-off) stays numerically sane."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model, unzip


@pytest.mark.parametrize("cache_dtype,tol", [(jnp.bfloat16, 0.15), (jnp.float8_e4m3fn, 0.60)])
def test_decode_with_quantized_cache(cache_dtype, tol):
    """Decode logits with a low-precision cache track the f32-cache logits.

    The bound is on the relative L2 error of the final logits — loose enough
    for quantization noise, tight enough to catch layout/scale bugs.
    """
    cfg = get_config("llava_next_mistral_7b").reduced()
    model = build_model(cfg, remat=False)
    params, _ = unzip(model.init(jax.random.key(0)))
    B, S = 2, 16

    def run(dtype):
        cache = model.init_cache(B, S, dtype=dtype)
        # pre-fill the cache through real decode steps so values are lifelike
        logits = None
        for i in range(6):
            tok = jnp.full((B, 1), 3 + i, jnp.int32)
            logits, cache = model.decode_step(params, tok, cache, jnp.int32(i))
        return np.asarray(logits, np.float32)

    ref = run(jnp.float32)
    got = run(cache_dtype)
    rel = np.linalg.norm(got - ref) / (np.linalg.norm(ref) + 1e-9)
    assert np.isfinite(got).all()
    assert rel < tol, f"{cache_dtype}: rel={rel:.3f}"


def test_fp8_cache_halves_bytes():
    cfg = get_config("command_r_plus_104b")
    model = build_model(cfg)
    c8 = jax.eval_shape(lambda: model.init_cache(8, 128, dtype=jnp.float8_e4m3fn))
    c16 = jax.eval_shape(lambda: model.init_cache(8, 128, dtype=jnp.bfloat16))
    b8 = sum(np.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(c8))
    b16 = sum(np.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(c16))
    assert b8 * 2 == b16
