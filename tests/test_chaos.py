"""Chaos tier: fault-plan compilation determinism, retry/backoff config and
inject-hook validation, dead-shard salvage (exactly-once, bit-exact identity,
stranding acceptance vs the no-salvage/legacy baselines), the dead-shard
revival regression, and per-policy conservation under an active fault plan."""

import dataclasses

import numpy as np
import pytest

from repro.core import SimConfig, Simulator, make_scheduler
from repro.core.admission import AdmissionConfig, AdmissionSimulator
from repro.core.chaos import (
    FaultEvent,
    FaultPlan,
    flappy_workers,
    rolling_restart,
    shard_kill_wave,
    spot_preemption,
)
from repro.core.policies import available_policies
from repro.core.trace import make_functions, make_vu_programs, service_fluctuations
from repro.core.workloads import make_scenario

pytestmark = pytest.mark.shard


# ------------------------------------------------------------ plan layer
def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown FaultEvent kind"):
        FaultEvent(t=1.0, kind="explode", worker=0)
    with pytest.raises(ValueError, match="t must be >= 0"):
        FaultEvent(t=-1.0, kind="fail", worker=0)
    with pytest.raises(ValueError, match="worker must be >= 0"):
        FaultEvent(t=1.0, kind="fail", worker=-2)
    with pytest.raises(ValueError, match="until >= t"):
        FaultEvent(t=5.0, kind="notice", worker=0)  # no until
    with pytest.raises(ValueError, match="until >= t"):
        FaultEvent(t=5.0, kind="notice", worker=0, until=4.0)


def test_fault_plan_sorts_composes_and_reports_horizon():
    a = FaultEvent(t=2.0, kind="add", worker=1)
    b = FaultEvent(t=2.0, kind="fail", worker=1)
    c = FaultEvent(t=1.0, kind="notice", worker=0, until=9.0)
    p1 = FaultPlan("x", [a, b, c])
    p2 = FaultPlan("x", [c, a, b])
    assert p1 == p2  # construction order is irrelevant
    # at equal t: notice < fail < add (revival after the kill it undoes)
    assert [e.kind for e in p1.events] == ["notice", "fail", "add"]
    assert len(p1) == 3
    assert p1.horizon == 9.0  # a notice's until counts toward the horizon
    both = p1 + FaultPlan("y", [FaultEvent(t=20.0, kind="fail", worker=3)])
    assert both.name == "x+y" and len(both) == 4 and both.horizon == 20.0


def test_generators_are_pure_functions_of_their_arguments():
    kw = dict(n_shards=4, n_workers=32, shards=[0, 2], t_kill=5.0,
              stagger_s=1.0, jitter_s=0.5, seed=3)
    assert shard_kill_wave(**kw) == shard_kill_wave(**kw)
    assert shard_kill_wave(**kw) != shard_kill_wave(**{**kw, "seed": 4})
    sp = dict(n_workers=16, n_waves=2, wave_size=3, t0=2.0, t1=8.0, seed=1)
    assert spot_preemption(**sp) == spot_preemption(**sp)
    fl = dict(workers=[0, 5], duration_s=30.0, mtbf_s=5.0, mttr_s=1.0, seed=2)
    assert flappy_workers(**fl) == flappy_workers(**fl)


def test_shard_kill_wave_covers_exactly_the_listed_shards():
    plan = shard_kill_wave(4, 32, shards=[1], t_kill=3.0)
    # even split of 32 over 4: shard 1 owns global workers 8..15
    assert sorted(e.worker for e in plan.events) == list(range(8, 16))
    assert all(e.kind == "fail" and e.t == 3.0 for e in plan.events)
    with pytest.raises(ValueError, match="out of range"):
        shard_kill_wave(4, 32, shards=[4], t_kill=3.0)


def test_spot_preemption_emits_notice_kill_replace_triplets():
    plan = spot_preemption(8, n_waves=1, wave_size=2, t0=4.0, t1=4.0,
                           notice_s=2.0, replace_after_s=3.0, seed=0)
    kinds = sorted((e.kind, e.t) for e in plan.events)
    assert [k for k, _ in kinds] == ["add", "add", "fail", "fail",
                                     "notice", "notice"]
    for e in plan.events:
        if e.kind == "notice":
            assert e.t == 2.0 and e.until == 4.0
        elif e.kind == "fail":
            assert e.t == 4.0
        else:
            assert e.t == 7.0


def test_rolling_restart_is_deterministic_batched():
    plan = rolling_restart(8, t0=1.0, downtime_s=2.0, stagger_s=3.0, batch=2)
    fails = {e.worker: e.t for e in plan.events if e.kind == "fail"}
    adds = {e.worker: e.t for e in plan.events if e.kind == "add"}
    assert fails[0] == fails[1] == 1.0 and fails[6] == fails[7] == 10.0
    assert all(adds[w] == fails[w] + 2.0 for w in range(8))


def test_flappy_workers_alternate_and_truncate():
    plan = flappy_workers([3], duration_s=60.0, mtbf_s=4.0, mttr_s=1.0, seed=0)
    assert len(plan) > 2
    kinds = [e.kind for e in plan.events]
    assert kinds == ["fail", "add"] * (len(kinds) // 2) + (
        ["fail"] if len(kinds) % 2 else []
    )
    assert plan.horizon < 60.0


# --------------------------------------------- config + inject validation
def test_retry_config_validation():
    with pytest.raises(ValueError, match="retry_delay_s"):
        SimConfig(retry_delay_s=0.0)
    with pytest.raises(ValueError, match="retry_backoff"):
        SimConfig(retry_backoff=0.5)
    with pytest.raises(ValueError, match="retry_max_delay_s"):
        SimConfig(retry_max_delay_s=0.01)  # below retry_delay_s
    with pytest.raises(ValueError, match="retry_budget"):
        SimConfig(retry_budget=0)


def _sim(n_workers=4, seed=0):
    return Simulator(
        make_scheduler("hiku", n_workers, seed=seed),
        cfg=SimConfig(n_workers=n_workers), seed=seed,
    )


def test_inject_hooks_reject_bad_ids_and_times():
    sim = _sim()
    with pytest.raises(ValueError, match="worker id must be >= 0"):
        sim.inject_failure(1.0, -1)
    with pytest.raises(ValueError, match="t must be >= 0"):
        sim.inject_failure(-0.5, 0)
    with pytest.raises(ValueError, match="worker id must be >= 0"):
        sim.inject_worker(1.0, -3)
    # failing a worker that never exists surfaces at begin(), loudly
    sim.inject_failure(1.0, 7)
    with pytest.raises(ValueError, match="neither in the initial range"):
        sim.begin(n_vus=0, duration_s=10.0, programs=[])
    # schedules beyond the run deadline are rejected, not silently dropped
    sim2 = _sim()
    sim2.inject_failure(50.0, 1)
    with pytest.raises(ValueError, match="deadline"):
        sim2.begin(n_vus=0, duration_s=10.0, programs=[])
    sim3 = _sim()
    sim3.inject_worker(50.0, 9)
    with pytest.raises(ValueError, match="deadline"):
        sim3.begin(n_vus=0, duration_s=10.0, programs=[])


def test_admission_tier_rejects_out_of_partition_ids():
    adm = AdmissionSimulator(2, 8, scheduler="hiku", seed=0)
    with pytest.raises(ValueError, match="out of range"):
        adm.inject_failure(1.0, 8)
    with pytest.raises(ValueError, match="out of range"):
        adm.inject_worker(1.0, -1)
    with pytest.raises(ValueError, match="out of range"):
        adm.inject_notice(1.0, 99, until=2.0)
    with pytest.raises(ValueError, match="precedes"):
        adm.inject_notice(3.0, 0, until=2.0)


def test_backoff_formula_capped_and_legacy_compatible():
    cfg = SimConfig(retry_delay_s=0.05, retry_backoff=2.0, retry_max_delay_s=0.3)
    sim = Simulator(make_scheduler("hiku", 2, seed=0), cfg=cfg, seed=0)
    # attempt 1 is exactly the flat legacy delay (byte-identity anchor)
    assert sim._retry_delay(1) == cfg.retry_delay_s
    assert sim._retry_delay(2) == 0.1
    assert sim._retry_delay(3) == 0.2
    assert sim._retry_delay(4) == 0.3  # capped
    assert sim._retry_delay(9) == 0.3


# ------------------------------------------------------- engine salvage
def _dead_pressured_sim(seed=5, n_vus=8):
    """A 2-worker sim whose workers both die mid-run, leaving queued work."""
    funcs = make_functions(seed=0)
    progs = make_vu_programs(funcs, n_vus, 64, seed)
    sim = Simulator(
        make_scheduler("hiku", 2, seed=seed), funcs=funcs,
        cfg=SimConfig(n_workers=2, mem_pool_mb=400.0), seed=seed,
    )
    sim.inject_failure(2.0, 0)
    sim.inject_failure(2.5, 1)
    sim.begin(n_vus=n_vus, duration_s=30.0, programs=progs)
    sim.step_until(4.0)
    assert not sim.workers and sim.pressure() == float("inf")
    return sim, funcs


def test_salvage_drains_dead_shard_to_zero_outstanding():
    sim, _ = _dead_pressured_sim()
    out = sim.salvage_queued()
    assert len(out) > 0 and any(sv.in_flight for sv in out)
    assert sim.salvaged_out == len(out)
    assert sim.outstanding() == 0  # nothing stranded after the drain
    assert sim.salvage_queued() == []  # exactly-once: a second drain is empty


def test_salvage_requires_a_dead_shard():
    funcs = make_functions(seed=0)
    sim = Simulator(make_scheduler("hiku", 2, seed=1), funcs=funcs,
                    cfg=SimConfig(n_workers=2), seed=1)
    sim.begin(n_vus=2, duration_s=10.0,
              programs=make_vu_programs(funcs, 2, 16, 1))
    with pytest.raises(ValueError, match="dead shard"):
        sim.salvage_queued()


def test_salvaged_identity_bit_exact_on_destination():
    """The §10 invariant: a salvaged VU's service draws replay the ORIGIN
    (seed, vu) identity bit-exactly on its new home — same contract as
    stealing, across the salvage path."""
    sim, funcs = _dead_pressured_sim()
    dst = Simulator(make_scheduler("hiku", 2, seed=99), funcs=funcs,
                    cfg=SimConfig(n_workers=2), seed=99)
    dst.begin(n_vus=0, duration_s=40.0, programs=[])
    dst.step_until(4.0)
    salvaged = sim.salvage_queued()
    locals_ = [dst.receive_salvaged(sv, t=4.0) for sv in salvaged]
    assert dst.salvaged_in == len(salvaged)
    while not dst.done:
        dst.step_until(dst.t + 5.0)
    sigma = SimConfig().exec_sigma
    for sv, local in zip(salvaged, locals_):
        row = dst._fluct["rows"][local]
        assert len(row) > 0
        want = service_fluctuations(
            sv.stolen.origin_seed, 1, len(row), sigma,
            vu_start=sv.stolen.origin_vu,
        )[0]
        assert np.array_equal(np.asarray(row), want)
    # every in-flight salvage completed exactly once, flagged migrated,
    # with recovery latency charged from its first failure
    n_inflight = sum(1 for sv in salvaged if sv.in_flight)
    assert int(dst.record_columns.migrated.sum()) == n_inflight
    assert len(dst.recovery_s) >= n_inflight
    assert all(r > 0 for r in dst.recovery_s)


def test_retry_budget_exhaustion_counts_lost_tasks():
    funcs = make_functions(seed=0)
    progs = make_vu_programs(funcs, 4, 32, 3)
    cfg = SimConfig(n_workers=2, retry_budget=2, retry_delay_s=0.05)
    sim = Simulator(make_scheduler("hiku", 2, seed=3), funcs=funcs, cfg=cfg, seed=3)
    sim.inject_failure(1.0, 0)
    sim.inject_failure(1.0, 1)
    sim.run(n_vus=4, duration_s=12.0, programs=progs)
    assert sim.lost_tasks > 0  # budget ran out with no capacity left
    assert sim.resubmits > 0
    assert sim.outstanding() == 0  # lost, not stranded: the queue drained


# ------------------------------------- admission tier: salvage acceptance
QUICK = dict(n_shards=2, n_workers=8, n_vus=32, duration_s=14.0,
             mem_pool_mb=1024.0)


def _chaos_cell(column, fault="shard_kill", seed=0):
    from benchmarks.bench_chaos import QUICK as P
    from benchmarks.bench_chaos import make_plan, run_cell

    funcs = make_functions(seed=seed)
    scn = make_scenario("on_off", funcs, P["n_vus"], P["duration_s"], seed=seed)
    scn = dataclasses.replace(scn, faults=make_plan(fault, P, seed=seed))
    return run_cell(column, scn, P, seed=seed)


@pytest.fixture(scope="module")
def shard_kill_cells():
    return {c: _chaos_cell(c) for c in ("pull", "pull@nosalvage", "pull@legacy")}


def test_salvage_strands_nothing_where_baselines_strand_or_lose(shard_kill_cells):
    """The §10 acceptance: under a correlated shard kill, pull+salvage
    strands zero queued tasks and loses fewer than the no-salvage baseline,
    at comparable surviving-traffic p99; the legacy engine (flat infinite
    retries, no salvage) strands > 0."""
    r_sal, m_sal = shard_kill_cells["pull"]
    r_nos, m_nos = shard_kill_cells["pull@nosalvage"]
    r_leg, _ = shard_kill_cells["pull@legacy"]
    assert r_sal.n_salvages > 0, "the kill must actually trigger salvage"
    assert r_sal.stranded == 0
    assert r_leg.stranded > 0  # pre-PR engine: dead-shard work spins forever
    # salvage converts would-be losses into recoveries
    assert r_nos.lost_tasks > 0 and m_nos.lost_task_rate > 0.0
    assert m_sal.lost_task_rate < m_nos.lost_task_rate
    # ... without blowing up the tail for surviving traffic
    assert m_sal.p99_ms < 1.5 * m_nos.p99_ms
    # failure telemetry is populated on the salvage run
    assert m_sal.resubmit_rate > 0.0
    assert m_sal.recovery_p99_ms >= m_sal.recovery_p50_ms > 0.0


def test_salvage_off_never_salvages(shard_kill_cells):
    r_nos, _ = shard_kill_cells["pull@nosalvage"]
    assert r_nos.n_salvages == 0 and not r_nos.salvages
    assert sum(s.salvaged_out for s in r_nos.shards) == 0


def test_chaos_run_is_deterministic():
    r1, _ = _chaos_cell("pull")
    r2, _ = _chaos_cell("pull")
    assert r1.records.equals(r2.records)
    assert np.array_equal(r1.assign_t, r2.assign_t)
    assert r1.salvages == r2.salvages
    assert r1.stranded == r2.stranded and r1.lost_tasks == r2.lost_tasks


@pytest.mark.parametrize("policy", available_policies())
def test_exactly_once_conservation_per_policy_under_faults(policy):
    """Every registered policy, with a correlated shard-kill plan active:
    salvage bookkeeping balances (drained == re-homed, nothing buffered),
    admission tables agree on every salvaged VU's global id, and no request
    completes twice."""
    run, _ = _chaos_cell(policy)
    assert run.n_salvages > 0  # the kill bites under every policy
    assert sum(s.salvaged_out for s in run.shards) == run.n_salvages
    assert sum(s.salvaged_in for s in run.shards) == run.n_salvages
    assert run.unsalvaged == 0 and run.stranded == 0
    for mv in run.salvages:
        src_tab = run.shards[mv.src].admitted
        dst_tab = run.shards[mv.dst].admitted
        assert src_tab[mv.src_vu] == dst_tab[mv.dst_vu]  # same global VU
        assert not run.shards[mv.src].alive  # only dead shards drain
    # exactly-once: one migrated completion per in-flight recovery (plus
    # steal migrations when the policy steals)
    n_inflight = sum(1 for mv in run.salvages if mv.in_flight)
    assert int(run.records.migrated.sum()) == n_inflight + run.n_migrations
    # no duplicated completion: a VU's submissions are unique in time
    order = np.lexsort((run.records.t_submit, run.records.vu))
    vu, ts = run.records.vu[order], run.records.t_submit[order]
    assert not ((np.diff(vu) == 0) & (np.diff(ts) == 0)).any()


# ----------------------------------------------------- revival regression
def test_dead_shard_revival_restores_admission_candidate():
    """Regression: ``inject_worker`` reviving a fully-dead shard brings it
    back as an admission candidate — late arrivals bind to it again and it
    finishes the run alive."""
    adm = AdmissionSimulator(
        2, 4, scheduler="hiku", seed=0,
        admission=AdmissionConfig(tick_s=0.25),
    )
    n_vus = 12
    funcs = adm.funcs
    progs = make_vu_programs(funcs, n_vus, 32, 0)
    arrivals = [0.0] * 6 + [8.0] * 6  # second half lands after the revival
    # shard 0 (workers 0,1) dies at t=3 and worker 0 rejoins at t=6
    plan = FaultPlan("kill+revive", [
        FaultEvent(t=3.0, kind="fail", worker=0),
        FaultEvent(t=3.0, kind="fail", worker=1),
        FaultEvent(t=6.0, kind="add", worker=0),
    ])
    run = adm.run(n_vus, 20.0, programs=progs, arrivals=arrivals, faults=plan)
    s0 = run.shards[0]
    assert s0.alive  # revived, not dead, at run end
    late = [t for t in s0.admit_t.tolist() if t >= 8.0]
    assert late, "revived shard never pulled a post-revival arrival"
    assert run.stranded == 0


def test_cluster_dark_buffers_then_revival_rehomes_exactly_once():
    """Whole-cluster outage: salvage exports buffer while no live shard
    exists, then re-home on the first revival — never lost, never doubled."""
    adm = AdmissionSimulator(
        2, 4, scheduler="hiku", seed=1,
        admission=AdmissionConfig(tick_s=0.25),
    )
    n_vus = 8
    progs = make_vu_programs(adm.funcs, n_vus, 32, 1)
    events = [FaultEvent(t=4.0, kind="fail", worker=w) for w in range(4)]
    events.append(FaultEvent(t=7.0, kind="add", worker=2))  # shard 1 revives
    run = adm.run(n_vus, 25.0, programs=progs,
                  faults=FaultPlan("blackout", events))
    assert run.n_salvages > 0
    assert run.unsalvaged == 0  # the buffer drained onto the revived shard
    assert all(mv.dst == 1 for mv in run.salvages)  # only live home
    assert sum(s.salvaged_out for s in run.shards) == run.n_salvages
    assert run.stranded == 0
    # the revived shard finished the recovered work
    assert int(run.records.migrated.sum()) == sum(
        1 for mv in run.salvages if mv.in_flight
    )


# -------------------------------------------- doomed-worker notice signal
def test_notices_surface_as_doomed_workers():
    from repro.core.policies import PullPolicy, register_policy, unregister_policy

    seen = []

    class ProbePolicy(PullPolicy):
        name = "probe_doomed"

        def want_pull(self, state):
            seen.append((state.index, state.doomed_workers))
            return super().want_pull(state)

    register_policy(ProbePolicy)
    try:
        adm = AdmissionSimulator(
            2, 4, scheduler="hiku", seed=0,
            admission=AdmissionConfig(policy="probe_doomed", tick_s=0.25),
        )
        progs = make_vu_programs(adm.funcs, 8, 32, 0)
        plan = FaultPlan("spot", [
            FaultEvent(t=2.0, kind="notice", worker=0, until=5.0),
            FaultEvent(t=5.0, kind="fail", worker=0),
        ])
        adm.run(8, 12.0, programs=progs, faults=plan,
                arrivals=[0.0, 0.0, 0.0, 0.0, 2.5, 2.5, 2.5, 2.5])
        doomed0 = {d for k, d in seen if k == 0}
        assert 1 in doomed0  # shard 0 read its doomed worker in the window
        assert all(d == 0 for k, d in seen if k == 1)
    finally:
        unregister_policy("probe_doomed")


# --------------------------------------------- static-path byte identity
def test_salvage_flag_is_inert_without_faults():
    """AdmissionConfig.salvage must be a pure no-op on fault-free runs —
    the static pull path stays byte-identical with the drain armed."""
    from repro.core.admission import make_skewed_programs

    progs = None
    runs = []
    for salvage in (True, False):
        adm = AdmissionSimulator(
            2, 8, scheduler="hiku", seed=0,
            admission=AdmissionConfig(salvage=salvage),
        )
        if progs is None:
            progs = make_skewed_programs(adm.funcs, 16, 64, 0)
        runs.append(adm.run(16, 10.0, programs=progs))
    a, b = runs
    assert a.records.equals(b.records)
    assert np.array_equal(a.assign_t, b.assign_t)
    assert np.array_equal(a.assign_w, b.assign_w)
    assert a.n_salvages == b.n_salvages == 0
    assert a.stranded == b.stranded and a.lost_tasks == b.lost_tasks == 0


def test_doomed_worker_excluded_from_warm_signals():
    """Pinned regression: a worker inside an open preemption-notice window
    contributes neither headroom (warm_capacity) nor warmth (warm_digest) —
    and contributes both again once the window closes without a kill."""
    funcs = make_functions(seed=0)
    sim = Simulator(make_scheduler("hiku", 1, seed=3), funcs=funcs,
                    cfg=SimConfig(n_workers=1), seed=3)
    sim.inject_notice(8.0, 0, 12.0)
    # long-running programs keep the event clock moving through the window
    sim.begin(n_vus=2, duration_s=30.0,
              programs=make_vu_programs(funcs, 2, 64, 3))
    sim.step_until(7.0)  # pre-window: the sole worker is plain headroom
    assert sim.t < 8.0
    assert sim.warm_capacity() > 0.0 and sim.warm_digest()
    sim.step_until(9.5)  # inside [8, 12): every live worker is doomed
    assert 8.0 <= sim.t < 12.0
    assert sim.warm_capacity() == 0.0
    assert sim.warm_digest() == {}
    sim.step_until(13.0)  # window expired (no kill): signal restored
    assert sim.t >= 12.0
    assert sim.warm_capacity() > 0.0
    restored = sim.warm_digest()
    recount = {}
    for w in sim.workers.values():
        for func, lst in w.idle.items():
            if lst:
                recount[func] = recount.get(func, 0) + len(lst)
    assert restored and restored == recount


def test_doomed_warm_capacity_reaches_admission_snapshots():
    """The admission tier forwards notices to the owning shard engine, so a
    policy's ShardState.warm_capacity drops for the doomed shard's window."""
    from repro.core.policies import CostPolicy, register_policy, unregister_policy

    seen = []

    class WarmProbe(CostPolicy):
        name = "probe_warm"

        def want_pull(self, state):
            seen.append((state.index, state.t, state.warm_capacity))
            return super().want_pull(state)

    register_policy(WarmProbe)
    try:
        adm = AdmissionSimulator(
            2, 2, scheduler="hiku", seed=0,  # 1 worker per shard
            admission=AdmissionConfig(policy="probe_warm", tick_s=0.25),
        )
        progs = make_vu_programs(adm.funcs, 8, 16, 0)
        plan = FaultPlan("spot", [
            FaultEvent(t=2.0, kind="notice", worker=0, until=6.0),
        ])
        adm.run(8, 12.0, programs=progs, faults=plan,
                arrivals=[0.0, 0.0, 2.5, 2.5, 3.0, 3.5, 7.0, 7.5])
        shard0_in = [w for k, t, w in seen if k == 0 and 2.0 <= t < 6.0]
        assert shard0_in and all(w == 0.0 for w in shard0_in)
        # the same shard reads normal headroom outside the window ...
        assert any(w > 0.0 for k, t, w in seen if k == 0 and t >= 6.0)
        # ... and the un-noticed shard never reads a doomed zero
        assert all(w > 0.0 for k, _, w in seen if k == 1)
    finally:
        unregister_policy("probe_warm")


# -------------------------------------------------- dark-cluster drain order
def test_drain_ordering_oldest_outage_first_across_dark_ticks():
    """Pinned regression for drain_tick's buffer ordering: exports carried
    across multiple fully-dark ticks stay ahead of every newer outage's
    exports, and the first live shard receives them in exactly that order."""
    from repro.core.stealing import drain_tick

    simA, _ = _dead_pressured_sim(seed=5)
    simB, _ = _dead_pressured_sim(seed=6)
    inv = [0.5, 0.5]
    # tick 1: only A is down, cluster fully dark — its exports buffer
    moves, left1 = drain_tick([simA], [0.5], t=5.0)
    assert moves == [] and len(left1) > 0
    assert all(src == 0 for src, _ in left1)
    # tick 2: still dark; B's outage is newer — appended AFTER the buffer
    moves, left2 = drain_tick([simA, simB], inv, t=6.0, pending=left1)
    assert moves == []
    assert left2[: len(left1)] == left1  # oldest outage stays first
    assert len(left2) > len(left1)
    assert all(src == 1 for src, _ in left2[len(left1):])
    # exactly-once: the dead shards have nothing left to export
    assert simA.salvage_queued() == [] and simB.salvage_queued() == []
    # tick 3: a live shard appears — placement follows buffer order exactly
    funcs = make_functions(seed=0)
    live = Simulator(make_scheduler("hiku", 4, seed=9), funcs=funcs,
                     cfg=SimConfig(n_workers=4), seed=9)
    live.begin(n_vus=1, duration_s=30.0,
               programs=make_vu_programs(funcs, 1, 8, 9))
    live.step_until(7.0)
    moves, left3 = drain_tick([simA, simB, live], inv + [0.25], t=7.0,
                              pending=left2)
    assert left3 == []
    assert [(mv.src, mv.src_vu, mv.func, mv.ev_idx) for mv in moves] == [
        (src, sv.stolen.src_vu, sv.stolen.func, sv.stolen.ev_idx)
        for src, sv in left2
    ]
    assert all(mv.dst == 2 for mv in moves)


# ------------------------------------ learned state under active faults
def test_learned_completion_feed_exactly_once_under_shard_kill():
    """A learned policy's completion feed, with a correlated shard-kill
    plan active and salvage re-homing VUs mid-run: every merged request
    record is observed by the policy exactly once — salvaged VUs (which
    complete later work on a *new* shard under a fresh local id) are never
    double-counted and never dropped by the per-shard cursors."""
    from collections import Counter

    from repro.core.policies import SjfPolicy, register_policy, unregister_policy

    class ProbeSjf(SjfPolicy):
        name = "probe_sjf"
        seen = []  # every completion handed to fold, across windows

        def __init__(self, cfg, **kw):
            # update_every=1: every tick's drain folds immediately, so
            # `seen` is exactly what the feed delivered over the whole run
            super().__init__(cfg, update_every=1, **kw)

        def fold(self, completions):
            type(self).seen.extend(completions)
            super().fold(completions)

    register_policy(ProbeSjf)
    try:
        run, _ = _chaos_cell("probe_sjf")
        seen = ProbeSjf.seen
        assert run.n_salvages > 0  # the kill bit: VUs really moved shards
        assert len(seen) == len(run.records)
        got = Counter((c.gid, c.func) for c in seen)
        want = Counter(zip(run.records.vu.tolist(), run.records.func.tolist()))
        assert got == want  # same multiset: exactly once, nothing doubled
        assert all(
            c.duration_ms > 0 and np.isfinite(c.duration_ms) for c in seen
        )
        # the salvaged VUs' post-move completions were observed too
        moved = {run.shards[mv.dst].admitted[mv.dst_vu] for mv in run.salvages}
        assert moved & {c.gid for c in seen}
    finally:
        unregister_policy("probe_sjf")
        ProbeSjf.seen.clear()


@pytest.mark.parametrize("policy", ["sjf", "bandit", "bandit+steal"])
def test_learned_policies_deterministic_under_shard_kill(policy):
    """Learned state folding + an active fault plan must still be a pure
    function of the run: two identical chaos runs agree byte-for-byte on
    records AND on the recorded per-window policy snapshots."""
    import warnings

    from benchmarks.bench_chaos import QUICK as P
    from benchmarks.bench_chaos import make_plan

    def one():
        funcs = make_functions(seed=0)
        scn = make_scenario("on_off", funcs, P["n_vus"], P["duration_s"],
                            seed=0)
        scn = dataclasses.replace(scn, faults=make_plan("shard_kill", P, seed=0))
        adm = AdmissionSimulator(
            P["n_shards"], P["n_workers"], scheduler="hiku",
            cfg=SimConfig(mem_pool_mb=P["mem_pool_mb"]), seed=0,
            admission=AdmissionConfig(
                policy=policy, steal_watermark=1.25,
                policy_args={"record_state": True},
            ),
        )
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            return adm.run(scn.n_vus, P["duration_s"], **scn.run_kwargs())

    r1 = one()
    r2 = one()
    assert r1.n_salvages > 0
    assert r1.records.equals(r2.records)
    assert r1.policy_state and r1.policy_state == r2.policy_state
