"""Dirty-shard coordination (docs/ARCHITECTURE.md §13).

Pins the tentpole contract: the coordinator's cached pressure/dead view and
persistent lazy-deletion admission heap produce **byte-identical** decisions
to the O(K) rebuild loop — full-run record streams, admission tables, steal
schedules and salvage moves compared across the policy matrix (pull,
pull+steal, affinity+steal, sjf, bandit+steal), with and without a
``shard_kill_wave`` fault plan.  The legacy baseline is the same code forced
back into the old behavior at every decision point: the rebuild ``admit_tick``
branch, all-dirty refreshes, live-pressure steal/drain reads, no steal-round
skip, no ``step_until`` frontier skip.

Plus unit pins for the engine's incremental pressure counters (against the
retained ``_pressure_ref`` scan oracle), dirty marking, heap supersession,
and compaction.
"""

import warnings

import numpy as np
import pytest

from repro.core import SimConfig, Simulator, make_functions, make_scheduler
from repro.core import admission as admission_mod
from repro.core.admission import AdmissionConfig, AdmissionSimulator
from repro.core.chaos import shard_kill_wave
from repro.core.coord import ShardCoordinator
from repro.core.policies import AdmissionPolicy
from repro.core.stealing import drain_tick as _real_drain
from repro.core.stealing import steal_tick as _real_steal
from repro.core.trace import make_vu_programs
from repro.core.workloads import make_scenario

pytestmark = pytest.mark.shard

FUNCS = make_functions(seed=0)

#: the acceptance matrix: heap-default policies (fast path), a stealing
#: pair, the warm-locality override path, and both learned queue/watermark
#: policies
MATRIX = ["pull", "pull+steal", "affinity+steal", "sjf", "bandit+steal"]


# ------------------------------------------------- the forced-legacy baseline
class _AlwaysDirtyCoordinator(ShardCoordinator):
    """Coordinator with every O(dirty) shortcut disabled: each refresh
    re-reads every shard (the O(K) poll), and the steal round can never be
    skipped on the victim probe."""

    def refresh(self):
        self.dirty.update(range(len(self.sims)))
        return super().refresh()

    def pressure_max(self):
        return float("inf")


def _legacy_admit(self, t, ctx):
    # route the fast-path dispatch back into the rebuild branch
    coord, ctx.coord = ctx.coord, None
    try:
        self.admit_tick(t, ctx)
    finally:
        ctx.coord = coord


def _legacy_steal(sims, **kw):
    kw.pop("pressures", None)  # force live engine reads, as before
    return _real_steal(sims, **kw)


def _legacy_drain(sims, inv_workers, t, pending=None, **kw):
    return _real_drain(sims, inv_workers, t, pending=pending)


def _run(policy, scn, dur, faults=None, legacy=False, seed=0, K=4, W=16,
         autoscale=False, coords=None):
    adm = AdmissionSimulator(
        K, W, scheduler="hiku", cfg=SimConfig(mem_pool_mb=1024.0), seed=seed,
        admission=AdmissionConfig(policy=policy, steal_watermark=1.25),
    )
    kw = scn.run_kwargs()
    if autoscale:
        from repro.core import AutoscaleConfig, Autoscaler

        kw["autoscaler"] = Autoscaler(
            AutoscaleConfig(mode="predictive", target_pressure=0.6)
        )
    with pytest.MonkeyPatch.context() as mp:
        coord_cls = _AlwaysDirtyCoordinator if legacy else ShardCoordinator
        if coords is not None:
            base = coord_cls

            class _Capture(base):
                def __init__(self, *a, **k):
                    super().__init__(*a, **k)
                    coords.append(self)

            coord_cls = _Capture
        if coords is not None or legacy:
            mp.setattr(admission_mod, "ShardCoordinator", coord_cls)
        if legacy:
            mp.setattr(admission_mod, "steal_tick", _legacy_steal)
            mp.setattr(admission_mod, "drain_tick", _legacy_drain)
            mp.setattr(
                AdmissionPolicy, "_admit_tick_incremental", _legacy_admit
            )
            # disable the frontier skip: every shard steps every tick
            mp.setattr(
                Simulator, "next_event_time", lambda self: float("-inf")
            )
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            return adm.run(scn.n_vus, dur, faults=faults, **kw)


def _assert_same_run(a, b):
    assert a.records.equals(b.records)
    np.testing.assert_array_equal(a.assign_t, b.assign_t)
    np.testing.assert_array_equal(a.assign_w, b.assign_w)
    assert a.admitted == b.admitted and a.unadmitted == b.unadmitted
    assert a.n_events == b.n_events
    assert a.migrations == b.migrations
    assert a.salvages == b.salvages
    for sa, sb in zip(a.shards, b.shards):
        np.testing.assert_array_equal(sa.admitted, sb.admitted)
        np.testing.assert_array_equal(sa.admit_t, sb.admit_t)
        assert sa.pulls == sb.pulls
        assert (sa.stolen_in, sa.stolen_out) == (sb.stolen_in, sb.stolen_out)
        assert (sa.salvaged_in, sa.salvaged_out) == (
            sb.salvaged_in,
            sb.salvaged_out,
        )


@pytest.mark.parametrize("policy", MATRIX)
def test_coordinator_byte_identical_to_rebuild_loop(policy):
    scn = make_scenario("flash_crowd", FUNCS, 48, 12.0, seed=3)
    a = _run(policy, scn, 12.0)
    b = _run(policy, scn, 12.0, legacy=True)
    _assert_same_run(a, b)


@pytest.mark.parametrize("policy", MATRIX)
def test_coordinator_byte_identical_under_shard_kill_wave(policy):
    scn = make_scenario("heavy_tail", FUNCS, 48, 12.0, seed=5)
    faults = shard_kill_wave(4, 16, shards=[1, 2], t_kill=3.0, stagger_s=1.0)
    a = _run(policy, scn, 12.0, faults=faults)
    b = _run(policy, scn, 12.0, faults=faults, legacy=True)
    _assert_same_run(a, b)
    assert a.n_salvages > 0  # the wave actually exercised the drain path


@pytest.mark.parametrize("policy", ["pull", "pull+steal"])
def test_coordinator_byte_identical_on_autoscaled_runs(policy):
    """§14 x §13: autoscaler mutations (adds, notices, kills) flow through
    the same dirty marks as faults, and the published ``pressure`` payload
    is read from the coordinator cache — so an autoscaled run under the
    cached coordinator is byte-identical to the all-dirty rebuild (same
    records, same worker-seconds bill) while doing strictly fewer
    refreshes.  A missing dirty mark on any elasticity hook would skew the
    cached pressures, change a sizing decision, and fail the comparison."""
    scn = make_scenario("flash_crowd", FUNCS, 48, 12.0, seed=3)
    ca, cb = [], []
    a = _run(policy, scn, 12.0, autoscale=True, coords=ca)
    b = _run(policy, scn, 12.0, legacy=True, autoscale=True, coords=cb)
    _assert_same_run(a, b)
    assert a.worker_seconds == b.worker_seconds < 16 * 12.0
    assert len(ca) == len(cb) == 1
    assert ca[0].refreshes < cb[0].refreshes  # the A/B refreshes pin


# -------------------------------------------- incremental pressure counters
def test_pressure_matches_reference_scan_oracle():
    """The O(1) counter-backed pressure equals the retained O(workers) scan
    (``_pressure_ref``) at every step of a queue-building run, including
    across worker failures."""
    progs = make_vu_programs(FUNCS, 12, 48, seed=9)
    sim = Simulator(
        make_scheduler("hiku", 3, seed=9), funcs=FUNCS,
        cfg=SimConfig(n_workers=3, mem_pool_mb=400.0), seed=9,
    )
    sim.inject_failure(6.0, 1)
    sim.begin(n_vus=12, duration_s=20.0, programs=progs)
    for i in range(1, 80):
        sim.step_until(i * 0.25)
        assert sim.pressure() == sim._pressure_ref()
    assert sim.pressure() == sim._pressure_ref()


def test_pressure_ref_is_inf_for_dead_shard_both_paths():
    sim = Simulator(
        make_scheduler("hiku", 1, seed=0), funcs=FUNCS,
        cfg=SimConfig(n_workers=1), seed=0,
    )
    sim.inject_failure(0.5, 0)
    sim.begin(n_vus=0, duration_s=5.0, programs=[])
    sim.step_until(1.0)
    assert sim.pressure() == sim._pressure_ref() == float("inf")


# --------------------------------------------------- dirty marks and refresh
def _idle_pair(dur=30.0):
    sims = []
    for k in range(2):
        sim = Simulator(
            make_scheduler("hiku", 2, seed=k), funcs=FUNCS,
            cfg=SimConfig(n_workers=2), seed=k,
        )
        sim.begin(n_vus=0, duration_s=dur, programs=[])
        sims.append(sim)
    return sims


def test_idle_shards_stay_clean_after_first_refresh():
    sims = _idle_pair()
    coord = ShardCoordinator(sims)  # constructor refreshes everyone once
    assert coord.refreshes == 2 and not coord.dirty
    # step strictly below the event frontier (an idle engine still holds
    # e.g. keep-alive sweep events): nothing pops, nothing marks
    t_first = min(sim.next_event_time() for sim in sims)
    hi = 3.0 if t_first == float("inf") else t_first
    for frac in (0.25, 0.5, 0.75):
        for sim in sims:
            sim.step_until(hi * frac)  # below the frontier: pure no-op
        assert coord.refresh() == 0  # nothing marked, nothing re-read
    assert coord.refreshes == 2


def test_admit_marks_dirty_and_refresh_recaches():
    sims = _idle_pair()
    coord = ShardCoordinator(sims)
    progs = make_vu_programs(FUNCS, 1, 8, seed=0)
    sims[1].admit_vu(progs[0], t=0.0)
    assert coord.dirty == {1}  # admission published, neighbor stayed clean
    sims[1].step_until(0.5)  # submit fires: live pressure moves
    assert coord.refresh() == 1
    assert coord.pressure[1] == sims[1].pressure()
    assert coord.pressure[0] == 0.0


def test_dead_shard_enters_dead_set_on_refresh():
    doomed = Simulator(
        make_scheduler("hiku", 2, seed=0), funcs=FUNCS,
        cfg=SimConfig(n_workers=2), seed=0,
    )
    doomed.inject_failure(0.5, 0)
    doomed.inject_failure(0.5, 1)
    doomed.begin(n_vus=0, duration_s=30.0, programs=[])
    sims = [doomed, _idle_pair()[1]]
    coord = ShardCoordinator(sims)
    sims[0].step_until(1.0)
    coord.refresh()
    assert coord.dead == {0}
    assert coord.pressure[0] == float("inf")
    assert coord.pressure_max() == float("inf")


# ------------------------------------------------------ persistent heap unit
def test_heap_peek_pop_push_and_supersession():
    sims = _idle_pair()
    coord = ShardCoordinator(sims)
    assert coord.peek() == (0.0, 0)  # (pressure, index) total order
    assert coord.pop() == (0.0, 0)
    assert coord.peek() == (0.0, 1)
    coord.push(0.5, 0)  # re-enter above shard 1
    assert coord.peek() == (0.0, 1)
    coord.pop()
    assert coord.peek() == (0.5, 0)
    # a refresh supersedes any live entry: the stale 0.5 key is discarded
    coord.dirty.add(0)
    coord.refresh()
    assert coord.peek() == (0.0, 0)


def test_compaction_preserves_the_valid_entry_multiset():
    sims = _idle_pair()
    coord = ShardCoordinator(sims)
    for _ in range(200):  # far past the compaction threshold
        coord.dirty.update((0, 1))
        coord.refresh()
    assert len(coord._heap) <= coord._compact_at + 2
    assert coord.pop() == (0.0, 0)
    assert coord.pop() == (0.0, 1)
    assert coord.peek() is None
    assert coord.pressure_max() == 0.0
