"""Executable-documentation smoke: every fenced ``python``/``bash`` block in
README.md and docs/*.md must run green (quick settings), so the examples
cannot rot.

Conventions (stated in docs/ARCHITECTURE.md):

* blocks are executed in file order; python blocks share one namespace per
  file, so later snippets may build on earlier ones;
* a block preceded by an ``<!-- docs-smoke: skip -->`` comment (the nearest
  non-blank line above the fence) is skipped — reserved for human-workflow
  commands like running the full test suite;
* untagged fences are never executed (use them for output or pseudo-code);
* executed blocks must finish in well under the per-block timeout
  (``PER_BLOCK_TIMEOUT_S``) — keep doc examples at quick-settings scale.
"""

import os
import re
import signal
import subprocess
import sys
from pathlib import Path
from typing import List, NamedTuple

import pytest

pytestmark = pytest.mark.docs

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
SKIP_MARK = "<!-- docs-smoke: skip -->"
PER_BLOCK_TIMEOUT_S = 600
_FENCE = re.compile(r"^```(\w*)\s*$")


class Block(NamedTuple):
    lang: str
    code: str
    lineno: int  # 1-based line of the opening fence
    skipped: bool


def extract_blocks(path: Path) -> List[Block]:
    blocks: List[Block] = []
    lines = path.read_text().splitlines()
    i = 0
    last_nonblank = ""
    while i < len(lines):
        m = _FENCE.match(lines[i])
        if m and m.group(1):
            lang = m.group(1).lower()
            start = i + 1
            j = start
            while j < len(lines) and not lines[j].startswith("```"):
                j += 1
            code = "\n".join(lines[start:j])
            blocks.append(
                Block(lang, code, start, skipped=last_nonblank.strip() == SKIP_MARK)
            )
            i = j + 1
            last_nonblank = ""
            continue
        if lines[i].strip():
            last_nonblank = lines[i]
        i += 1
    return blocks


def test_doc_files_exist():
    assert (ROOT / "README.md").exists()
    assert (ROOT / "docs" / "ARCHITECTURE.md").exists()
    assert (ROOT / "docs" / "BENCHMARKS.md").exists()


def test_readme_links_architecture():
    assert "docs/ARCHITECTURE.md" in (ROOT / "README.md").read_text()


@pytest.mark.slow
@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_doc_code_blocks_execute(doc):
    blocks = [b for b in extract_blocks(doc) if b.lang in ("python", "bash")]
    runnable = [b for b in blocks if not b.skipped]
    if not runnable:
        pytest.skip(f"{doc.name}: no executable blocks")
    ns = {"__name__": f"docs_smoke[{doc.name}]"}
    env = {**os.environ, "PYTHONPATH": str(ROOT / "src")}
    for b in runnable:
        where = f"{doc.name}:{b.lineno}"
        if b.lang == "python":
            # exec runs in-process (blocks share a namespace), so the
            # timeout has to come from SIGALRM rather than subprocess.
            def _alarm(signum, frame, where=where):
                raise TimeoutError(
                    f"python block at {where} exceeded {PER_BLOCK_TIMEOUT_S}s"
                )

            old_handler = signal.signal(signal.SIGALRM, _alarm)
            signal.alarm(PER_BLOCK_TIMEOUT_S)
            try:
                exec(compile(b.code, where, "exec"), ns)  # noqa: S102
            except Exception as e:  # surface the snippet location
                pytest.fail(f"python block at {where} failed: {e!r}")
            finally:
                signal.alarm(0)
                signal.signal(signal.SIGALRM, old_handler)
        else:
            out = subprocess.run(
                ["bash", "-ceu", b.code],
                cwd=ROOT,
                env=env,
                capture_output=True,
                text=True,
                timeout=PER_BLOCK_TIMEOUT_S,
            )
            assert out.returncode == 0, (
                f"bash block at {where} failed:\n{out.stdout[-1500:]}{out.stderr[-1500:]}"
            )


def test_example_policy_comparison_section_runs():
    """The serve_cluster policy-comparison section (pull vs deadline on the
    flash-crowd scenario) runs green at quick scale — the example can't
    rot even though the quickstart block itself carries the skip marker."""
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "serve_cluster_docs_smoke", ROOT / "examples" / "serve_cluster.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    mod.policy_comparison(quick=True, n_shards=2)


def test_skip_marker_parsed():
    """The README's human-workflow quickstart block stays unexecuted."""
    blocks = extract_blocks(ROOT / "README.md")
    bash = [b for b in blocks if b.lang == "bash"]
    assert any(b.skipped for b in bash), "README quickstart should carry the skip marker"


if sys.platform == "win32":  # bash-based smoke is POSIX-only
    test_doc_code_blocks_execute = pytest.mark.skip("POSIX only")(
        test_doc_code_blocks_execute
    )
