"""Old-vs-new engine equivalence: the PR-1 hot-path refactor must not change
a single scheduling decision.

``tests/legacy`` is a frozen copy of the seed simulator + schedulers.  For
every paper scheduler and a spread of configurations (memory pressure, worker
failure, elastic scale-up, 100-worker scale) the refactored engine must
produce a byte-identical ``RequestRecord`` stream — same submit/complete
timestamps (float-exact), same worker, same cold flag, same VU — and the
identical assignment trace."""

import pytest

from legacy import SimConfig as LegacySimConfig
from legacy import Simulator as LegacySimulator
from legacy import make_scheduler as legacy_make_scheduler
from repro.core import SimConfig, Simulator, make_scheduler

PAPER_SCHEDULERS = ["hiku", "ch_bl", "least_connections", "random"]


def _run(stack, name, seed, n_workers, n_vus, dur, cfg_kw, failures, adds):
    mk, Sim, Cfg = stack
    sched = mk(name, n_workers, seed=seed)
    sim = Sim(sched, cfg=Cfg(n_workers=n_workers, **cfg_kw), seed=seed)
    for t, w in failures:
        sim.inject_failure(t, w)
    for t, w in adds:
        sim.inject_worker(t, w)
    recs = sim.run(n_vus=n_vus, duration_s=dur)
    return recs, list(sim.assignments)


def _assert_identical(name, seed=7, n_workers=5, n_vus=30, dur=40.0, cfg_kw=None,
                      failures=(), adds=()):
    cfg_kw = cfg_kw or {}
    legacy_stack = (legacy_make_scheduler, LegacySimulator, LegacySimConfig)
    new_stack = (make_scheduler, Simulator, SimConfig)
    r1, a1 = _run(legacy_stack, name, seed, n_workers, n_vus, dur, cfg_kw, failures, adds)
    r2, a2 = _run(new_stack, name, seed, n_workers, n_vus, dur, cfg_kw, failures, adds)
    assert len(r1) == len(r2), f"{name}: {len(r1)} vs {len(r2)} records"
    assert r1, f"{name}: empty record stream"
    for i, (x, y) in enumerate(zip(r1, r2)):
        assert (x.t_submit, x.t_complete, x.func, x.worker, x.cold, x.vu) == (
            y.t_submit, y.t_complete, y.func, y.worker, y.cold, y.vu
        ), f"{name}: record {i} diverged: {x} vs {y}"
    assert a1 == a2, f"{name}: assignment traces diverged"


@pytest.mark.parametrize("name", PAPER_SCHEDULERS)
def test_paper_schedulers_byte_identical(name):
    _assert_identical(name)


@pytest.mark.parametrize("name", PAPER_SCHEDULERS)
def test_byte_identical_under_memory_pressure(name):
    """Small pools force LRU evictions + pending queues on every scheduler."""
    _assert_identical(name, seed=11, n_vus=40, dur=30.0,
                      cfg_kw=dict(mem_pool_mb=1024.0))


@pytest.mark.parametrize("name", ["hiku", "least_connections"])
def test_byte_identical_through_failure_and_scaleup(name):
    _assert_identical(name, seed=1, n_vus=20, dur=40.0,
                      failures=[(10.0, 2)], adds=[(20.0, 7)])


def test_byte_identical_service_times_are_request_identity_seeded():
    """The fluctuation band must reproduce the per-request default_rng draws."""
    import numpy as np

    from repro.core.trace import service_fluctuations

    sigma = 0.25
    got = service_fluctuations(123, 5, 40, sigma)
    for vu in range(5):
        for ev in range(40):
            want = np.random.default_rng((123, vu, ev)).lognormal(
                mean=-0.5 * sigma**2, sigma=sigma
            )
            assert got[vu, ev] == want, (vu, ev)


@pytest.mark.slow
def test_byte_identical_at_scale():
    """100 workers / 500 VUs: the config class the refactor targets."""
    _assert_identical("hiku", seed=0, n_workers=100, n_vus=500, dur=10.0)


@pytest.mark.shard
@pytest.mark.parametrize("backend", ["interleaved", "process"])
def test_kshard_streams_byte_identical_to_seed_engine(backend):
    """Every shard of a K-shard run must replay byte-for-byte what the
    FROZEN seed engine produces for that shard's slice (same seed, worker
    count, VU count, duration).  The seed baseline is tests/legacy and is
    never regenerated — this extends the PR-1 contract to the sharded
    driver on both execution backends."""
    import dataclasses

    from repro.core.shard import ShardedSimulator

    driver = ShardedSimulator(3, 9, scheduler="hiku", seed=5, backend=backend)
    merged = driver.run(n_vus=18, duration_s=25.0)
    assert len(merged.records) > 0
    # the frozen legacy config predates the retry/backoff knobs (PR 6);
    # project onto its fields — on static runs they change nothing
    legacy_fields = {f.name for f in dataclasses.fields(LegacySimConfig)}
    for res in merged.shards:
        spec = res.spec
        lsched = legacy_make_scheduler(spec.scheduler, spec.cfg.n_workers, seed=spec.seed)
        cfg_kw = {
            k: v for k, v in dataclasses.asdict(spec.cfg).items() if k in legacy_fields
        }
        lsim = LegacySimulator(lsched, cfg=LegacySimConfig(**cfg_kw), seed=spec.seed)
        lrecs = lsim.run(n_vus=spec.n_vus, duration_s=spec.duration_s)
        cols = res.records
        assert len(lrecs) == len(cols) > 0, f"shard {spec.index}"
        got = list(
            zip(cols.t_submit.tolist(), cols.t_done.tolist(), cols.func.tolist(),
                cols.worker.tolist(), cols.cold.tolist(), cols.vu.tolist())
        )
        want = [(r.t_submit, r.t_complete, r.func, r.worker, r.cold, r.vu) for r in lrecs]
        assert got == want, f"shard {spec.index} diverged from the seed engine"
        got_asg = list(zip(res.assign_t.tolist(), res.assign_w.tolist()))
        assert got_asg == [(t, w) for t, w in lsim.assignments], f"shard {spec.index}"


def test_byte_identical_with_warm_digest_polling():
    """Reading the warm-set digest (and warm_capacity) between time slices is
    pure observation: a polled static run still replays the FROZEN seed
    engine byte-for-byte — the docs/ARCHITECTURE.md §11 off-path guarantee.
    The small pool forces LRU evictions, so the digest's decrement paths are
    exercised while the identity holds."""
    name, seed, n_workers, n_vus, dur = "hiku", 11, 5, 40, 30.0
    cfg_kw = dict(mem_pool_mb=1024.0)
    lsim = LegacySimulator(
        legacy_make_scheduler(name, n_workers, seed=seed),
        cfg=LegacySimConfig(n_workers=n_workers, **cfg_kw), seed=seed,
    )
    lrecs = lsim.run(n_vus=n_vus, duration_s=dur)
    sim = Simulator(
        make_scheduler(name, n_workers, seed=seed),
        cfg=SimConfig(n_workers=n_workers, **cfg_kw), seed=seed,
    )
    sim.begin(n_vus=n_vus, duration_s=dur)
    polled_nonempty = 0
    for i in range(1, int(dur * 2) + 1):
        sim.step_until(i * 0.5)
        polled_nonempty += bool(sim.warm_digest())
        sim.warm_capacity()
    sim.step_until(float("inf"))  # drain completions past the poll horizon
    assert sim.done and polled_nonempty > 0
    cols = sim.record_columns
    got = list(
        zip(cols.t_submit.tolist(), cols.t_done.tolist(), cols.func.tolist(),
            cols.worker.tolist(), cols.cold.tolist(), cols.vu.tolist())
    )
    want = [(r.t_submit, r.t_complete, r.func, r.worker, r.cold, r.vu)
            for r in lrecs]
    assert got == want
    assert list(sim.assignments) == list(lsim.assignments)
