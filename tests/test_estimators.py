"""Learned-state building blocks (core.estimators): the online duration
estimator's update/predict/snapshot contract and the bandit tuner's
deterministic selection + snapshot contract.

These are the deterministic unit tests (seeded numpy permutations stand in
for free generation); the hypothesis property tier lives in
tests/test_estimators_properties.py so environments without hypothesis
still run this module."""

import json
import math

import numpy as np
import pytest

from repro.core.estimators import BanditTuner, DurationEstimator


def _observations(seed=0, n=200, n_funcs=5):
    rng = np.random.default_rng(seed)
    funcs = rng.integers(0, n_funcs, size=n)
    durs = rng.lognormal(mean=3.0, sigma=1.0, size=n) + 0.1  # ms, > 0
    return list(zip(funcs.tolist(), durs.tolist()))


# --------------------------------------------------------------- estimator
def test_estimator_prior_then_global_then_per_func_fallback():
    est = DurationEstimator(prior_ms=123.0)
    # estimator cold start: the static prior, for any function
    assert est.predict_ms(0) == 123.0 and est.predict_ms(7) == 123.0
    assert est.total_updates == 0 and est.n(0) == 0
    assert math.isnan(est.mean_ms(0))
    est.update(0, 50.0)
    # seen function: its own mean; unseen function: the global mean
    assert est.predict_ms(0) == 50.0
    assert est.predict_ms(7) == 50.0
    est.update(0, 150.0)
    est.update(3, 1000.0)
    assert est.predict_ms(0) == pytest.approx(100.0)
    assert est.n(0) == 2 and est.n(3) == 1 and est.total_updates == 3
    assert est.predict_ms(7) == pytest.approx((50.0 + 150.0 + 1000.0) / 3)


def test_estimator_mean_variance_match_numpy():
    est = DurationEstimator()
    durs = [12.5, 90.0, 33.3, 45.0, 250.0, 18.75]
    for d in durs:
        est.update(4, d)
    assert est.mean_ms(4) == pytest.approx(np.mean(durs), rel=1e-12)
    assert est.variance_ms2(4) == pytest.approx(np.var(durs, ddof=1), rel=1e-12)
    assert est.std_ms(4) == pytest.approx(np.std(durs, ddof=1), rel=1e-12)


def test_estimator_variance_nonnegative_and_zero_below_two_samples():
    est = DurationEstimator()
    assert est.variance_ms2(0) == 0.0
    est.update(0, 77.0)
    assert est.variance_ms2(0) == 0.0  # n < 2: no sample variance yet
    # many identical observations: catastrophic-cancellation territory for
    # the naive sum-of-squares formula; Welford + clamp must stay >= 0
    for _ in range(500):
        est.update(1, 1e6 + 1e-4)
    assert est.variance_ms2(1) >= 0.0


def test_estimator_rejects_junk_at_the_update_boundary_state_untouched():
    est = DurationEstimator()
    est.update(2, 40.0)
    before = est.snapshot()
    for bad in (float("nan"), float("inf"), float("-inf"), 0.0, -5.0):
        with pytest.raises(ValueError, match="finite and > 0"):
            est.update(2, bad)
    with pytest.raises(ValueError, match="func index"):
        est.update(-1, 10.0)
    assert est.snapshot() == before  # every rejected update left no trace
    with pytest.raises(ValueError, match="prior_ms"):
        DurationEstimator(prior_ms=0.0)
    with pytest.raises(ValueError, match="prior_ms"):
        DurationEstimator(prior_ms=float("nan"))


def test_estimator_counts_are_exactly_permutation_invariant():
    """The documented update-order contract: counts are exact under
    permutation; means/variances agree to numerical noise (Welford is not
    float-commutative — determinism comes from canonical fold order)."""
    obs = _observations(seed=3)
    rng = np.random.default_rng(7)
    a, b = DurationEstimator(), DurationEstimator()
    for f, d in obs:
        a.update(f, d)
    for i in rng.permutation(len(obs)).tolist():
        b.update(*obs[i])
    funcs = sorted({f for f, _ in obs})
    assert a.total_updates == b.total_updates
    for f in funcs:
        assert a.n(f) == b.n(f)  # exact
        assert a.mean_ms(f) == pytest.approx(b.mean_ms(f), rel=1e-9)
        assert a.variance_ms2(f) == pytest.approx(b.variance_ms2(f), rel=1e-6)


def test_estimator_snapshot_restore_continue_is_bit_exact():
    """snapshot -> restore -> keep updating == never snapshotting at all,
    float-for-float — the property the run-level replay tier rests on."""
    obs = _observations(seed=11, n=120)
    cont = DurationEstimator(prior_ms=42.0)
    for f, d in obs[:60]:
        cont.update(f, d)
    resumed = DurationEstimator.from_snapshot(cont.snapshot())
    for f, d in obs[60:]:
        cont.update(f, d)
        resumed.update(f, d)
    assert resumed.snapshot() == cont.snapshot()  # exact, not approx
    for f in sorted({f for f, _ in obs}):
        assert resumed.mean_ms(f) == cont.mean_ms(f)
        assert resumed.variance_ms2(f) == cont.variance_ms2(f)


def test_estimator_snapshot_survives_json_round_trip_bit_exactly():
    est = DurationEstimator()
    for f, d in _observations(seed=5, n=80):
        est.update(f, d)
    snap = est.snapshot()
    wire = json.loads(json.dumps(snap))
    assert wire == snap  # Python floats round-trip JSON bit-exactly
    back = DurationEstimator.from_snapshot(wire)
    assert back.snapshot() == snap
    assert back.predict_ms(0) == est.predict_ms(0)
    with pytest.raises(ValueError, match="snapshot"):
        DurationEstimator.from_snapshot({"version": 99})


# ------------------------------------------------------------ bandit tuner
def test_bandit_validates_construction():
    with pytest.raises(ValueError, match="at least one arm"):
        BanditTuner(())
    with pytest.raises(ValueError, match="mode"):
        BanditTuner((1.0,), mode="thompson")
    with pytest.raises(ValueError, match="epsilon"):
        BanditTuner((1.0,), mode="egreedy", epsilon=1.5)
    with pytest.raises(ValueError, match="ucb_c"):
        BanditTuner((1.0,), ucb_c=-0.1)
    with pytest.raises(ValueError, match="finite"):
        BanditTuner((1.0, 2.0)).feed(float("nan"))


def test_bandit_tries_every_arm_once_then_ucb_exploits_the_best():
    tuner = BanditTuner((0.5, 1.0, 2.0), mode="ucb", ucb_c=0.5)
    assert tuner.arm_index == 0 and tuner.current == 0.5
    rewards = {0: -3.0, 1: -1.0, 2: -2.0}  # arm 1 is clearly best
    order = []
    for _ in range(3):  # warm-up: untried arms in index order
        order.append(tuner.arm_index)
        tuner.feed(rewards[tuner.arm_index])
    assert order == [0, 1, 2]
    for _ in range(40):
        tuner.feed(rewards[tuner.arm_index])
    # UCB settles on the best arm: it gets the lion's share of pulls
    assert tuner.pulls(1) > tuner.pulls(0) and tuner.pulls(1) > tuner.pulls(2)
    assert tuner.mean_reward(1) == pytest.approx(-1.0)


def test_bandit_selection_is_deterministic_for_both_modes():
    for mode in ("ucb", "egreedy"):
        runs = []
        for _ in range(2):
            t = BanditTuner((1, 2, 3, 4), mode=mode, epsilon=0.3, seed=9)
            trace = []
            for i in range(50):
                trace.append(t.arm_index)
                t.feed(-float((t.arm_index - 2) ** 2) - 0.01 * i)
            runs.append(trace)
        assert runs[0] == runs[1], mode


def test_bandit_egreedy_explores_but_mostly_exploits():
    t = BanditTuner((0, 1, 2, 3), mode="egreedy", epsilon=0.25, seed=1)
    rewards = [1.0, 5.0, 2.0, 0.0]
    pulls = []
    for _ in range(400):
        pulls.append(t.arm_index)
        t.feed(rewards[t.arm_index])
    counts = [pulls.count(i) for i in range(4)]
    assert counts[1] > 200  # exploit share goes to the best arm
    assert all(c >= 5 for c in counts)  # epsilon keeps every arm sampled


def test_bandit_snapshot_restore_continue_matches_and_json_round_trips():
    rewards = lambda i: [-2.0, -0.5, -1.0][i]  # noqa: E731
    cont = BanditTuner((0.6, 1.0, 1.6), mode="egreedy", epsilon=0.2, seed=4)
    for _ in range(17):
        cont.feed(rewards(cont.arm_index))
    snap = json.loads(json.dumps(cont.snapshot()))
    assert snap == cont.snapshot()
    resumed = BanditTuner((0.6, 1.0, 1.6), mode="egreedy", epsilon=0.2, seed=4)
    resumed.restore(snap)
    assert resumed.arm_index == cont.arm_index
    for _ in range(30):  # futures coincide: selection is pure state function
        assert resumed.arm_index == cont.arm_index
        cont.feed(rewards(cont.arm_index))
        resumed.feed(rewards(resumed.arm_index))
    assert resumed.snapshot() == cont.snapshot()


def test_bandit_snapshot_rejects_mismatched_arm_set():
    t = BanditTuner((1.0, 2.0))
    t.feed(0.5)
    snap = t.snapshot()
    other = BanditTuner((1.0, 2.0, 3.0))
    with pytest.raises(ValueError, match="arm"):
        other.restore(snap)
    with pytest.raises(ValueError, match="snapshot"):
        t.restore({"version": 2})
