"""Hypothesis property tests for the learned-state building blocks
(core.estimators): for *arbitrary* observation streams — not just the ones
the admission loop happens to emit — the estimator's documented contract
holds: counts exactly permutation-invariant (moments to numerical noise),
variance never negative, snapshot -> restore -> continue float-identical to
never snapshotting, snapshots JSON-round-trip bit-exactly, and junk is
rejected at the update boundary with state untouched.

Separate module so environments without hypothesis still run the
deterministic tests in test_estimators.py (this module skips there)."""

import json
import math

import pytest

pytest.importorskip("hypothesis")  # optional dep: skip only the property tests
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.estimators import BanditTuner, DurationEstimator  # noqa: E402

# realistic request durations in ms: positive, finite, non-degenerate scale
_durations = st.floats(
    min_value=1e-3, max_value=1e6, allow_nan=False, allow_infinity=False, width=64
)
_obs = st.lists(st.tuples(st.integers(0, 15), _durations), max_size=120)
_rewards = st.lists(
    st.floats(min_value=-1e6, max_value=1e6, allow_nan=False, allow_infinity=False),
    max_size=80,
)


def _fold(obs, est=None):
    est = est or DurationEstimator()
    for f, d in obs:
        est.update(f, d)
    return est


@settings(max_examples=60, deadline=None)
@given(obs=_obs, data=st.data())
def test_counts_exactly_permutation_invariant(obs, data):
    perm = data.draw(st.permutations(obs))
    a, b = _fold(obs), _fold(perm)
    assert a.total_updates == b.total_updates
    for f in range(16):
        assert a.n(f) == b.n(f)
        if a.n(f):  # moments: order-invariant up to float noise only
            assert b.mean_ms(f) == pytest.approx(a.mean_ms(f), rel=1e-9)
            assert b.variance_ms2(f) == pytest.approx(
                a.variance_ms2(f), rel=1e-6, abs=1e-9
            )


@settings(max_examples=60, deadline=None)
@given(obs=_obs)
def test_variance_is_never_negative_and_mean_stays_in_hull(obs):
    est = _fold(obs)
    per_func = {}
    for f, d in obs:
        per_func.setdefault(f, []).append(d)
    for f, ds in per_func.items():
        assert est.variance_ms2(f) >= 0.0
        assert est.std_ms(f) >= 0.0
        assert min(ds) <= est.mean_ms(f) <= max(ds)  # Welford mean in hull
    assert est.variance_ms2(99) == 0.0  # unseen: defined, not negative


@settings(max_examples=60, deadline=None)
@given(obs=_obs, cut=st.integers(0, 120))
def test_snapshot_restore_continue_equals_uninterrupted(obs, cut):
    cut = min(cut, len(obs))
    cont = _fold(obs[:cut])
    resumed = DurationEstimator.from_snapshot(cont.snapshot())
    _fold(obs[cut:], cont)
    _fold(obs[cut:], resumed)
    assert resumed.snapshot() == cont.snapshot()  # exact float equality
    for f in range(16):
        assert resumed.predict_ms(f) == cont.predict_ms(f)


@settings(max_examples=60, deadline=None)
@given(obs=_obs)
def test_snapshot_json_round_trip_bit_exact(obs):
    snap = _fold(obs).snapshot()
    wire = json.loads(json.dumps(snap))
    assert wire == snap
    assert DurationEstimator.from_snapshot(wire).snapshot() == snap


@settings(max_examples=60, deadline=None)
@given(
    obs=_obs,
    bad=st.one_of(
        st.just(float("nan")),
        st.just(float("inf")),
        st.just(float("-inf")),
        st.floats(max_value=0.0, allow_nan=False, width=64),
    ),
    func=st.integers(0, 15),
)
def test_junk_rejected_at_boundary_state_untouched(obs, bad, func):
    est = _fold(obs)
    before = est.snapshot()
    with pytest.raises(ValueError):
        est.update(func, bad)
    with pytest.raises(ValueError):
        est.update(-1 - func, 50.0)
    assert est.snapshot() == before


@settings(max_examples=40, deadline=None)
@given(
    rewards=_rewards,
    n_arms=st.integers(1, 6),
    mode=st.sampled_from(["ucb", "egreedy"]),
    eps=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
    cut=st.integers(0, 80),
)
def test_bandit_snapshot_resume_and_determinism(rewards, n_arms, mode, eps, seed, cut):
    arms = tuple(range(n_arms))
    mk = lambda: BanditTuner(arms, mode=mode, epsilon=eps, seed=seed)  # noqa: E731
    cut = min(cut, len(rewards))
    cont = mk()
    for r in rewards[:cut]:
        cont.feed(r)
    resumed = mk()
    resumed.restore(json.loads(json.dumps(cont.snapshot())))
    for r in rewards[cut:]:
        assert resumed.arm_index == cont.arm_index  # selection is pure state
        cont.feed(r)
        resumed.feed(r)
    assert resumed.snapshot() == cont.snapshot()
    assert 0 <= cont.arm_index < n_arms
    assert sum(cont.pulls(i) for i in range(n_arms)) == len(rewards)
    for i in range(n_arms):
        assert math.isfinite(cont.mean_reward(i))
