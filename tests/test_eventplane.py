"""Event plane (docs/ARCHITECTURE.md §14): the deterministic pub/sub bus.

Unit pins for the bus semantics — sealing, wildcard patterns, registration-
order delivery, immutable payloads, the delivery log — plus the publication
contracts of both drivers: ``ShardedSimulator.run_stream(bus=...)`` window
summaries are identical on every backend and never perturb the stream, and
the admission loop's per-window shard/cluster events tile the run (counts
sum to the full record stream) in the §14 publish order.
"""

import numpy as np
import pytest

from repro.core import EventPlane, SimConfig
from repro.core.admission import AdmissionConfig, AdmissionSimulator
from repro.core.eventplane import CLUSTER_TOPIC, SHARD_TOPIC, MetricEvent
from repro.core.shard import ShardedSimulator

pytestmark = pytest.mark.shard

K, W, VUS, DUR, WIN = 3, 9, 18, 15.0, 1.5


# ------------------------------------------------------------ bus semantics
def test_subscribe_validates_pattern_and_seal_freezes():
    bus = EventPlane()
    with pytest.raises(ValueError):
        bus.subscribe((), lambda ev: None)  # empty
    with pytest.raises(ValueError):
        bus.subscribe(["shard", 0], lambda ev: None)  # not a tuple
    bus.subscribe((SHARD_TOPIC, "*"), lambda ev: None)
    assert not bus.sealed
    bus.seal()
    assert bus.sealed
    bus.seal()  # idempotent
    with pytest.raises(RuntimeError, match="sealed"):
        bus.subscribe((CLUSTER_TOPIC,), lambda ev: None)


def test_publish_seals_implicitly():
    bus = EventPlane()
    bus.publish((CLUSTER_TOPIC,), 0, 0.0, 1.0, {"n_done": 0})
    assert bus.sealed
    with pytest.raises(RuntimeError):
        bus.subscribe((CLUSTER_TOPIC,), lambda ev: None)


def test_wildcard_matching_and_counters():
    bus = EventPlane()
    got = {"shard": [], "cluster": [], "one": []}
    bus.subscribe((SHARD_TOPIC, "*"), got["shard"].append)
    bus.subscribe((CLUSTER_TOPIC,), got["cluster"].append)
    bus.subscribe((SHARD_TOPIC, 1), got["one"].append)
    for k in range(3):
        bus.publish((SHARD_TOPIC, k), 0, 0.0, 1.0, {"k": k})
    bus.publish((CLUSTER_TOPIC,), 0, 0.0, 1.0, {})
    assert [ev.topic for ev in got["shard"]] == [(SHARD_TOPIC, k) for k in range(3)]
    assert [ev.topic for ev in got["one"]] == [(SHARD_TOPIC, 1)]
    assert len(got["cluster"]) == 1  # ("cluster",) never matches ("shard", k)
    assert bus.published == 4 and bus.delivered == 5
    # seq is the global publish order, shared across topics
    assert [ev.seq for ev in got["shard"]] == [0, 1, 2]
    assert got["cluster"][0].seq == 3


def test_delivery_is_registration_order_and_payload_immutable():
    bus = EventPlane()
    order = []
    bus.subscribe((SHARD_TOPIC, "*"), lambda ev: order.append("a"))
    bus.subscribe((SHARD_TOPIC, 0), lambda ev: order.append("b"))
    bus.subscribe((SHARD_TOPIC, "*"), lambda ev: order.append("c"))
    ev = bus.publish((SHARD_TOPIC, 0), 7, 1.0, 2.0, {"n_done": 3})
    assert order == ["a", "b", "c"]
    assert isinstance(ev, MetricEvent) and ev.window == 7
    with pytest.raises(TypeError):
        ev.payload["n_done"] = 99  # MappingProxyType: read-only for everyone
    # the source dict is copied: later caller mutation is invisible
    src = {"x": 1}
    ev2 = bus.publish((SHARD_TOPIC, 0), 8, 2.0, 3.0, src)
    src["x"] = 2
    assert ev2.payload["x"] == 1


def test_delivery_log_is_pure_function_of_subscriptions():
    """Same subscription set + same publish sequence => identical logs."""

    def build():
        bus = EventPlane()
        bus.subscribe((SHARD_TOPIC, "*"), lambda ev: None)
        bus.subscribe((CLUSTER_TOPIC,), lambda ev: None)
        bus.subscribe((SHARD_TOPIC, 2), lambda ev: None)
        rng = np.random.default_rng(11)
        for i in range(50):
            k = int(rng.integers(0, 4))
            topic = (SHARD_TOPIC, k) if k < 3 else (CLUSTER_TOPIC,)
            bus.publish(topic, i, float(i), float(i + 1), {"i": i})
        return bus

    a, b = build(), build()
    assert a.log == b.log and len(a.log) > 0
    assert (a.published, a.delivered) == (b.published, b.delivered)


# ----------------------------------------------- run_stream(bus=...) driver
def _collect(bus):
    events = []
    bus.subscribe((SHARD_TOPIC, "*"), events.append)
    bus.subscribe((CLUSTER_TOPIC,), events.append)
    return events


def _stream_with_bus(backend):
    bus = EventPlane()
    events = _collect(bus)
    driver = ShardedSimulator(K, W, scheduler="hiku", seed=5, backend=backend)
    chunks = list(
        driver.run_stream(n_vus=VUS, duration_s=DUR, window_s=WIN, bus=bus)
    )
    return bus, events, chunks


@pytest.mark.parametrize("backend", ["serial", "interleaved", "process"])
def test_run_stream_publishes_window_summaries(backend):
    """Per chunk: K shard events (ascending k) then the cluster event, with
    counts that reconcile exactly against the chunk itself."""
    bus, events, chunks = _stream_with_bus(backend)
    assert bus.sealed
    per_window = (K + 1)
    assert len(events) == per_window * len(chunks)
    for i, ch in enumerate(chunks):
        window = events[i * per_window : (i + 1) * per_window]
        assert [ev.topic for ev in window] == [
            (SHARD_TOPIC, k) for k in range(K)
        ] + [(CLUSTER_TOPIC,)]
        assert all(ev.window == ch.index for ev in window)
        assert all((ev.t_lo, ev.t_hi) == (ch.t_lo, ch.t_hi) for ev in window)
        for k in range(K):
            assert window[k].payload["n_done"] == int(ch.shard_counts[k])
        assert window[K].payload["n_done"] == len(ch.records)
        assert window[K].payload["n_assign"] == len(ch.assign_t)


def test_run_stream_summaries_identical_across_backends():
    """The published event stream is a pure function of the run — byte-equal
    topics, windows, and payloads on every backend (§14 replayability)."""
    ref = None
    for backend in ("serial", "interleaved", "process"):
        _, events, _ = _stream_with_bus(backend)
        flat = [(ev.topic, ev.window, ev.seq, dict(ev.payload)) for ev in events]
        if ref is None:
            ref = flat
        else:
            assert flat == ref
    assert ref  # the run published something


def test_run_stream_bus_does_not_perturb_stream():
    """Publishing is passive: chunks with a bus == chunks without, byte for
    byte (the static byte-identity half of the §14 contract)."""
    plain = list(
        ShardedSimulator(K, W, scheduler="hiku", seed=5, backend="serial")
        .run_stream(n_vus=VUS, duration_s=DUR, window_s=WIN)
    )
    _, _, published = _stream_with_bus("serial")
    assert len(plain) == len(published)
    for a, b in zip(plain, published):
        assert a.records.equals(b.records)
        np.testing.assert_array_equal(a.assign_t, b.assign_t)
        np.testing.assert_array_equal(a.assign_w, b.assign_w)
        np.testing.assert_array_equal(a.shard_counts, b.shard_counts)


def test_late_subscribe_during_stream_raises():
    bus = EventPlane()
    driver = ShardedSimulator(K, W, scheduler="hiku", seed=5, backend="serial")
    stream = driver.run_stream(n_vus=VUS, duration_s=DUR, window_s=WIN, bus=bus)
    next(stream)  # arms the run: the bus is sealed now
    with pytest.raises(RuntimeError, match="sealed"):
        bus.subscribe((CLUSTER_TOPIC,), lambda ev: None)
    stream.close()


# --------------------------------------------------- admission-loop driver
def _admission(seed=0):
    return AdmissionSimulator(
        K, W, scheduler="hiku", cfg=SimConfig(mem_pool_mb=1024.0), seed=seed,
        admission=AdmissionConfig(),
    )


def test_admission_publishes_windows_that_tile_the_run():
    """Per metric window: K shard events then cluster, windows contiguous,
    and the per-shard/cluster ``n_done`` counts sum to the full record
    stream (the final partial window is flushed after the loop)."""
    bus = EventPlane()
    events = _collect(bus)
    run = _admission().run(VUS, 8.0, bus=bus, metrics_window_s=1.0)
    assert bus.sealed and len(events) > 0
    per_window = K + 1
    assert len(events) % per_window == 0
    shard_total = 0
    cluster_total = 0
    prev_hi = 0.0
    for i in range(0, len(events), per_window):
        window = events[i : i + per_window]
        assert [ev.topic for ev in window] == [
            (SHARD_TOPIC, k) for k in range(K)
        ] + [(CLUSTER_TOPIC,)]
        assert all(ev.window == i // per_window for ev in window)
        assert window[0].t_lo == prev_hi  # windows tile: (t_lo, t_hi]
        prev_hi = window[0].t_hi
        shard_total += sum(window[k].payload["n_done"] for k in range(K))
        assert window[K].payload["n_done"] == sum(
            window[k].payload["n_done"] for k in range(K)
        )
        cluster_total += window[K].payload["n_done"]
        assert window[K].payload["queue_depth"] >= 0
        for k in range(K):
            assert window[k].payload["alive"] >= 0
            assert window[k].payload["load"] >= 0
    assert shard_total == cluster_total == len(run.records) > 0
    # arrivals are window-scoped eligibility counts: each VU enters the
    # admission queue exactly once, so the published sum never exceeds it
    arrivals = sum(
        ev.payload["arrivals"] for ev in events if ev.topic == (CLUSTER_TOPIC,)
    )
    assert 0 < arrivals <= VUS


def test_admission_static_run_with_bus_is_byte_identical():
    """A passive bus (no autoscaler) never perturbs the run."""
    a = _admission().run(VUS, 8.0)
    b = _admission().run(VUS, 8.0, bus=EventPlane(), metrics_window_s=2.0)
    assert a.records.equals(b.records)
    np.testing.assert_array_equal(a.assign_t, b.assign_t)
    np.testing.assert_array_equal(a.assign_w, b.assign_w)
    assert a.admitted == b.admitted and a.n_events == b.n_events
    assert a.worker_seconds == b.worker_seconds == W * 8.0


def test_admission_rejects_window_off_the_tick_grid():
    """window_s must be a positive multiple of tick_s (default 0.25):
    publication happens on tick boundaries only."""
    with pytest.raises(ValueError, match="multiple"):
        _admission().run(VUS, 8.0, bus=EventPlane(), metrics_window_s=0.3)
    with pytest.raises(ValueError):
        _admission().run(VUS, 8.0, bus=EventPlane(), metrics_window_s=-1.0)
