"""fastrng: the vectorized service-time RNG must be bit-identical to
per-tuple ``np.random.default_rng((seed, vu, ev)).lognormal(...)``."""

import numpy as np
import pytest

from repro.core import fastrng


def _reference(seed, n_vus, n_events, mean, sigma):
    return np.array(
        [
            [
                np.random.default_rng((seed, v, e)).lognormal(mean=mean, sigma=sigma)
                for e in range(n_events)
            ]
            for v in range(n_vus)
        ]
    )


def test_selftest_passes():
    assert fastrng.selftest()


@pytest.mark.parametrize("seed", [0, 1, 42, 999_999, 2**31])
def test_bit_exact_vs_default_rng(seed):
    mean, sigma = -0.5 * 0.25**2, 0.25
    got = fastrng.lognormal_matrix(seed, 8, 64, mean, sigma)
    want = _reference(seed, 8, 64, mean, sigma)
    assert np.array_equal(got, want)


def test_bit_exact_other_sigma():
    got = fastrng.lognormal_matrix(7, 4, 32, -0.08, 0.4)
    want = _reference(7, 4, 32, -0.08, 0.4)
    assert np.array_equal(got, want)


def test_ev_start_band():
    mean, sigma = -0.03125, 0.25
    full = fastrng.lognormal_matrix(3, 4, 48, mean, sigma)
    band = fastrng.lognormal_matrix(3, 4, 16, mean, sigma, ev_start=32)
    assert np.array_equal(full[:, 32:48], band)


def test_out_of_range_seed_falls_back():
    # >=2**32 entropy uses a multi-word mix schedule: must take the slow path
    seed = 2**33 + 5
    got = fastrng.lognormal_matrix(seed, 2, 8, -0.03125, 0.25)
    want = _reference(seed, 2, 8, -0.03125, 0.25)
    assert np.array_equal(got, want)


def test_state_reset_fallback_matches_fresh_generator():
    """The cheap PCG64 state-reset fallback must replay the full stream."""
    vu = np.arange(50, dtype=np.uint32)
    ev = np.full(50, 3, np.uint32)
    sh, sl, inch, incl = fastrng._init_state(77, vu, ev)
    for i in range(50):
        state = (int(sh[i]) << 64) | int(sl[i])
        inc = (int(inch[i]) << 64) | int(incl[i])
        got = fastrng._slow_from_state(state, inc, -0.03125, 0.25)
        want = float(np.random.default_rng((77, int(vu[i]), 3)).lognormal(-0.03125, 0.25))
        assert got == want


@pytest.mark.slow
def test_bit_exact_large_sample():
    """Broad sweep: ~20k draws covering all ziggurat strips + rejection paths."""
    mean, sigma = -0.5 * 0.25**2, 0.25
    got = fastrng.lognormal_matrix(1234, 20, 1000, mean, sigma)
    want = _reference(1234, 20, 1000, mean, sigma)
    assert np.array_equal(got, want)
