"""fastrng: the vectorized service-time RNG must be bit-identical to
per-tuple ``np.random.default_rng((seed, vu, ev)).lognormal(...)``."""

import numpy as np
import pytest

from repro.core import fastrng


def _reference(seed, n_vus, n_events, mean, sigma):
    return np.array(
        [
            [
                np.random.default_rng((seed, v, e)).lognormal(mean=mean, sigma=sigma)
                for e in range(n_events)
            ]
            for v in range(n_vus)
        ]
    )


def test_selftest_passes():
    assert fastrng.selftest()


@pytest.mark.parametrize("seed", [0, 1, 42, 999_999, 2**31])
def test_bit_exact_vs_default_rng(seed):
    mean, sigma = -0.5 * 0.25**2, 0.25
    got = fastrng.lognormal_matrix(seed, 8, 64, mean, sigma)
    want = _reference(seed, 8, 64, mean, sigma)
    assert np.array_equal(got, want)


def test_bit_exact_other_sigma():
    got = fastrng.lognormal_matrix(7, 4, 32, -0.08, 0.4)
    want = _reference(7, 4, 32, -0.08, 0.4)
    assert np.array_equal(got, want)


def test_ev_start_band():
    mean, sigma = -0.03125, 0.25
    full = fastrng.lognormal_matrix(3, 4, 48, mean, sigma)
    band = fastrng.lognormal_matrix(3, 4, 16, mean, sigma, ev_start=32)
    assert np.array_equal(full[:, 32:48], band)


def test_out_of_range_seed_falls_back():
    # >=2**32 entropy uses a multi-word mix schedule: must take the slow path
    seed = 2**33 + 5
    got = fastrng.lognormal_matrix(seed, 2, 8, -0.03125, 0.25)
    want = _reference(seed, 2, 8, -0.03125, 0.25)
    assert np.array_equal(got, want)


def test_state_reset_fallback_matches_fresh_generator():
    """The cheap PCG64 state-reset fallback must replay the full stream."""
    vu = np.arange(50, dtype=np.uint32)
    ev = np.full(50, 3, np.uint32)
    sh, sl, inch, incl = fastrng._init_state(77, vu, ev)
    for i in range(50):
        state = (int(sh[i]) << 64) | int(sl[i])
        inc = (int(inch[i]) << 64) | int(incl[i])
        got = fastrng._slow_from_state(state, inc, -0.03125, 0.25)
        want = float(np.random.default_rng((77, int(vu[i]), 3)).lognormal(-0.03125, 0.25))
        assert got == want


def test_selftest_failure_warns_once_and_stays_bit_exact(monkeypatch):
    """A degraded environment (self-test mismatch, e.g. a numpy whose
    default_rng stream differs from the learned tables) must fall back to
    per-tuple draws — bit-exact — and emit exactly ONE warning, not one per
    call."""
    import warnings

    monkeypatch.setattr(fastrng, "_SELFTEST_OK", False)
    monkeypatch.setattr(fastrng, "_FALLBACK_WARNED", False)
    mean, sigma = -0.03125, 0.25
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        got1 = fastrng.lognormal_matrix(11, 3, 16, mean, sigma)
        got2 = fastrng.lognormal_matrix(12, 4, 8, mean, sigma)
    fallback_warnings = [w for w in caught if "fastrng fast path disabled" in str(w.message)]
    assert len(fallback_warnings) == 1
    assert issubclass(fallback_warnings[0].category, RuntimeWarning)
    assert np.array_equal(got1, _reference(11, 3, 16, mean, sigma))
    assert np.array_equal(got2, _reference(12, 4, 8, mean, sigma))


def test_fast_path_emits_no_fallback_warning():
    import warnings

    assert fastrng.selftest()  # healthy stream on this numpy
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        fastrng.lognormal_matrix(3, 2, 16, -0.03125, 0.25)
    assert not [w for w in caught if "fastrng" in str(w.message)]


def test_unlearned_tables_fallback_bit_exact(monkeypatch):
    """Regression pin for a numpy stream the learned ziggurat tables do not
    cover (tables are numpy-stream-specific): with every strip marked
    unusable, *all* draws must take the per-element state-reset fallback
    and still be bit-identical to fresh default_rng draws."""
    wi, ki, usable = fastrng._load_tables()
    monkeypatch.setattr(
        fastrng, "_TABLES", (wi, ki, np.zeros_like(usable))
    )
    mean, sigma = -0.5 * 0.25**2, 0.25
    got = fastrng.lognormal_matrix(99, 5, 40, mean, sigma)
    assert np.array_equal(got, _reference(99, 5, 40, mean, sigma))


@pytest.mark.parametrize("seed", [0, 7, 123_456])
def test_vu_programs_vec_bit_identical_to_ref(seed):
    """The vectorized VU-program builder (consumer of ``uniform_block``)
    reproduces the per-VU ``default_rng((seed, vu))`` loop bit-for-bit —
    function choices AND think times — not just the spot-checked row 0."""
    from repro.core import trace

    weights = np.array([0.5, 0.3, 0.2])
    vec = trace._vu_programs_vec(3, weights, 12, 40, seed, 1.0, 3.0)
    ref = trace._vu_programs_ref(3, weights, 12, 40, seed, 1.0, 3.0)
    assert len(vec) == len(ref) == 12
    for a, b in zip(vec, ref):
        assert np.array_equal(a.func_idx, b.func_idx)
        assert np.array_equal(a.sleep_s, b.sleep_s)
    assert trace._PROG_FAST_OK  # the spot check passed on this numpy


@pytest.mark.slow
def test_bit_exact_large_sample():
    """Broad sweep: ~20k draws covering all ziggurat strips + rejection paths."""
    mean, sigma = -0.5 * 0.25**2, 0.25
    got = fastrng.lognormal_matrix(1234, 20, 1000, mean, sigma)
    want = _reference(1234, 20, 1000, mean, sigma)
    assert np.array_equal(got, want)
