"""Unit tests for the HLO collective parser (the roofline's data source)."""

import textwrap

from repro.utils.hlo import (
    _group_size,
    _shape_bytes_of,
    _traffic,
    collective_stats,
    op_census,
    total_collective_bytes,
)

SYNTH = textwrap.dedent("""\
    HloModule jit_step, is_scheduled=true

    %cond.1 (p: (s32[], f32[8])) -> pred[] {
      %p = (s32[], f32[8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %constant.7 = s32[] constant(12)
      ROOT %lt = pred[] compare(%i, %constant.7), direction=LT
    }

    %body.2 (p: (s32[], f32[8])) -> (s32[], f32[8]) {
      %p = (s32[], f32[8]) parameter(0)
      %x = f32[8]{0} get-tuple-element(%p), index=1
      %ar = f32[8]{0} all-reduce(%x), channel_id=1, replica_groups=[4,4]<=[16], to_apply=%add
      %ag = f32[32]{0} all-gather(%x), channel_id=2, replica_groups=[4,4]<=[16], dimensions={0}
      ROOT %t = (s32[], f32[8]) tuple(%i2, %ar)
    }

    ENTRY %main (a: f32[8]) -> f32[8] {
      %a = f32[8]{0} parameter(0)
      %big = f32[1024]{0} all-reduce(%pad), channel_id=3, replica_groups={{0,1},{2,3}}, to_apply=%add
      %w = (s32[], f32[8]) while(%init), condition=%cond.1, body=%body.2
      ROOT %r = f32[8]{0} get-tuple-element(%w), index=1
    }
""")


def test_shape_bytes():
    assert _shape_bytes_of("f32", "8") == 32
    assert _shape_bytes_of("bf16", "2,3") == 12
    assert _shape_bytes_of("pred", "") == 1


def test_group_size_formats():
    assert _group_size("replica_groups=[4,4]<=[16]") == 4
    assert _group_size("replica_groups={{0,1,2},{3,4,5}}") == 3


def test_traffic_models():
    # all-reduce ring: 2*(g-1)/g of payload
    assert _traffic("all-reduce", 100, 4) == 150.0
    # all-gather: (g-1)/g of the gathered result
    assert _traffic("all-gather", 100, 4) == 75.0
    # degenerate group: no wire traffic
    assert _traffic("all-reduce", 100, 1) == 0.0


def test_while_trip_count_multiplication():
    stats = collective_stats(SYNTH)
    # in-loop all-reduce (f32[8]=32B) executes 12x; entry all-reduce once
    ar = stats["all-reduce"]
    assert ar["count"] == 12 + 1
    assert ar["result_bytes"] == 12 * 32 + 4096
    ag = stats["all-gather"]
    assert ag["count"] == 12
    assert ag["result_bytes"] == 12 * 128
    traffic, result = total_collective_bytes(stats)
    assert traffic > 0 and result == ar["result_bytes"] + ag["result_bytes"]


def test_op_census():
    c = op_census("  %f = f32[2]{0} fusion(%a), kind=kLoop\n  %d = f32[2]{0} dot(%a, %b)\n")
    assert c.get("fusion") == 1 and c.get("dot") == 1
