"""Cross-scheduler conservation invariants.

For every registered scheduler x {steady, bursty, failure, memory-pressure}
scenario the engine must conserve requests and respect worker physics:

* one record per arrival — the closed loop keeps at most ONE outstanding
  request per VU, so ``submitted - completed`` is 0 or 1 per VU, through
  retries, failures and memory stalls;
* ``t_done >= t_submit`` for every record;
* per-worker concurrent memory (busy + idle sandboxes) never exceeds the
  pool, checked after every allocation via an instrumented simulator;
* sharded (K>1) runs are record-for-record a permutation of the monolithic
  runs of their slices;
* under the global admission tier, conservation + exactly-once hold for
  EVERY registered admission policy (``core.policies``) on a bursty
  scenario: unique global binding, strictly increasing per-VU submissions
  through migrations, one migrated record per migration.
"""

import numpy as np
import pytest

from repro.core import (
    SimConfig,
    Simulator,
    available_policies,
    available_schedulers,
    make_scheduler,
)
from repro.core.trace import make_vu_programs

N_VUS = 16
DURATION_S = 15.0

SCENARIOS = {
    "steady": {},
    "bursty": {"programs": "bursty"},
    "failure": {"failures": [(6.0, 1)], "adds": [(10.0, 9)]},
    "memory_pressure": {"cfg_kw": {"mem_pool_mb": 700.0}},
}


class CheckedSimulator(Simulator):
    """Asserts the memory-pool cap after every sandbox allocation."""

    def _start_or_queue(self, worker, task):
        super()._start_or_queue(worker, task)
        assert worker.busy_mem_mb + worker.idle_mem_mb <= worker.pool_mb + 1e-9, (
            worker.wid,
            worker.busy_mem_mb,
            worker.idle_mem_mb,
        )


def _run_scenario(scheduler: str, scenario: dict):
    cfg_kw = scenario.get("cfg_kw", {})
    sched = make_scheduler(scheduler, 5, seed=13)
    sim = CheckedSimulator(sched, cfg=SimConfig(n_workers=5, **cfg_kw), seed=13)
    for t, w in scenario.get("failures", ()):
        sim.inject_failure(t, w)
    for t, w in scenario.get("adds", ()):
        sim.inject_worker(t, w)
    programs = None
    if scenario.get("programs") == "bursty":
        # near-zero think time: every VU hammers the cluster (arrival bursts)
        programs = make_vu_programs(
            sim.funcs, N_VUS, int(DURATION_S * 60) + 16, 13,
            think_lo=0.005, think_hi=0.05,
        )
    recs = sim.run(n_vus=N_VUS, duration_s=DURATION_S, programs=programs)
    return sim, recs


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
@pytest.mark.parametrize("scheduler", available_schedulers())
def test_conservation_invariants(scheduler, scenario):
    sim, recs = _run_scenario(scheduler, SCENARIOS[scenario])
    assert recs, f"{scheduler}/{scenario}: no requests completed"

    per_vu_submits = {}
    for r in recs:
        assert r.t_complete >= r.t_submit, r
        per_vu_submits.setdefault(r.vu, []).append(r.t_submit)

    # closed loop: per-VU submit times strictly increase (no duplicated or
    # double-completed arrival, even across failure retries)
    for vu, subs in per_vu_submits.items():
        assert all(b > a for a, b in zip(subs, subs[1:])), (vu, subs)

    # one record per arrival: at most the single in-flight request per VU
    # (closed loop => <=1 outstanding) separates submits from completions
    for vu in range(N_VUS):
        submitted = sim._vu_pos[vu]
        completed = len(per_vu_submits.get(vu, []))
        assert submitted - completed in (0, 1), (vu, submitted, completed)

    # a completion implies a dispatch; retries may add extra assignments
    assert len(recs) <= len(sim.assignments)

    # memory cap also holds at the end of the run (and was asserted after
    # every allocation by CheckedSimulator)
    for w in sim.workers.values():
        assert w.busy_mem_mb + w.idle_mem_mb <= w.pool_mb + 1e-9


@pytest.mark.shard
@pytest.mark.parametrize("scheduler", ["hiku", "ch_bl", "least_connections", "random"])
def test_sharded_records_permutation_identical_to_monolithic(scheduler):
    """Merged K>1 output == multiset of monolithic per-slice runs."""
    from repro.core.shard import ShardedSimulator, build_simulator

    driver = ShardedSimulator(2, 8, scheduler=scheduler, seed=9, backend="process")
    merged = driver.run(n_vus=12, duration_s=15.0)
    assert len(merged.records) > 0

    mono = []
    for spec in driver.plan(12, 15.0):
        sim = build_simulator(spec)
        for r in sim.run(n_vus=spec.n_vus, duration_s=spec.duration_s):
            mono.append(
                (r.t_submit, r.t_complete, r.func,
                 r.worker + spec.worker_offset, r.cold, r.vu + spec.vu_offset)
            )
    g = merged.records
    got = list(
        zip(g.t_submit.tolist(), g.t_done.tolist(), g.func.tolist(),
            g.worker.tolist(), g.cold.tolist(), g.vu.tolist())
    )
    assert sorted(got) == sorted(mono)


@pytest.mark.shard
@pytest.mark.parametrize("policy", available_policies())
def test_admission_conservation_per_policy(policy):
    """Conservation + exactly-once, for EVERY registered admission policy:
    each admitted VU binds once globally (a migrated VU appears in two
    admission tables but completes each request exactly once), per-VU
    submissions strictly increase through migrations, records respect
    ``t_done >= t_submit``, and the migrated record count equals the
    migration schedule length."""
    import warnings

    from repro.core import SimConfig, make_functions
    from repro.core.admission import AdmissionConfig, AdmissionSimulator
    from repro.core.workloads import make_scenario

    funcs = make_functions(seed=0)
    scn = make_scenario("flash_crowd", funcs, 24, 12.0, seed=7)
    adm = AdmissionSimulator(
        2, 8, scheduler="hiku", cfg=SimConfig(mem_pool_mb=1024.0), seed=7,
        admission=AdmissionConfig(policy=policy, steal_watermark=1.25),
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        run = adm.run(scn.n_vus, 12.0, **scn.run_kwargs())
    g = run.records
    assert len(g) > 0, f"{policy}: no requests completed"
    assert (g.t_done >= g.t_submit).all()
    # population conservation: admitted + unadmitted == n_vus, ids unique
    all_gids = [gid for s in run.shards for gid in s.admitted.tolist()]
    unique = set(all_gids)
    assert run.admitted + run.unadmitted == scn.n_vus
    assert len(unique) == run.admitted
    # a VU appears in at most 1 + (times migrated) admission tables
    assert len(all_gids) == run.admitted + run.n_migrations
    # exactly-once: one migrated record per migration, none when off
    assert int(g.migrated.sum()) == run.n_migrations
    # per-VU global submissions strictly increase (no duplicated or lost
    # arrival, even across cross-shard migration)
    order = np.lexsort((g.t_submit, g.vu))
    vu, ts = g.vu[order], g.t_submit[order]
    same_vu = np.diff(vu) == 0
    assert (np.diff(ts)[same_vu] > 0).all()
    # merged stream is exactly the union of the per-shard streams
    assert len(g) == sum(len(s.records) for s in run.shards)


@pytest.mark.shard
def test_sharded_conservation_across_shards():
    """Conservation holds shard-by-shard under a failure inside one shard."""
    from repro.core.shard import ShardedSimulator

    driver = ShardedSimulator(2, 10, scheduler="hiku", seed=21, backend="interleaved")
    driver.inject_failure(4.0, 7)
    merged = driver.run(n_vus=12, duration_s=15.0)
    total = sum(len(r.records) for r in merged.shards)
    assert len(merged.records) == total
    for res in merged.shards:
        cols = res.records
        assert (cols.t_done >= cols.t_submit).all()
        for vu in set(cols.vu.tolist()):
            subs = cols.t_submit[cols.vu == vu]
            assert (np.diff(subs) > 0).all()
