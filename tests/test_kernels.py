"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU), plus hypothesis property
tests on the scheduler kernel's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,KH,hd,causal,window",
    [
        (1, 128, 4, 4, 64, True, None),     # MHA causal
        (2, 256, 8, 2, 64, True, None),     # GQA
        (1, 256, 4, 1, 128, True, 64),      # MQA + sliding window
        (2, 128, 4, 4, 32, False, None),    # bidirectional (whisper encoder)
    ],
)
def test_flash_attention_sweep(B, S, H, KH, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KH, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KH, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


# ------------------------------------------------------------ decode attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,KH,hd,valid,window",
    [
        (2, 512, 8, 2, 64, 511, None),
        (1, 256, 4, 4, 128, 100, None),
        (2, 512, 16, 2, 64, 300, 128),   # SWA decode
        (1, 128, 8, 1, 64, 0, None),     # first token
    ],
)
def test_decode_attention_sweep(B, S, H, KH, hd, valid, window, dtype):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kc = jax.random.normal(ks[1], (B, S, KH, hd), dtype)
    vc = jax.random.normal(ks[2], (B, S, KH, hd), dtype)
    out = ops.decode_attention(q, kc, vc, jnp.int32(valid), window=window, block_k=128)
    want = ref.decode_attention_ref(q, kc, vc, jnp.int32(valid), window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_decode_attention_matches_flash_last_row():
    """Decode of the last position == last row of full flash attention."""
    ks = jax.random.split(jax.random.key(2), 3)
    B, S, H, KH, hd = 1, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KH, hd))
    v = jax.random.normal(ks[2], (B, S, KH, hd))
    full = ref.flash_attention_ref(q, k, v, causal=True)
    dec = ops.decode_attention(q[:, -1], k, v, jnp.int32(S - 1), block_k=64)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]), atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------------- SSD scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,P,N,chunk,block_h",
    [
        (2, 256, 8, 16, 32, 64, 4),
        (1, 128, 24, 64, 128, 64, 8),   # mamba2-130m dims
        (1, 64, 4, 16, 16, 64, 4),      # single chunk
        (2, 192, 6, 16, 32, 64, 6),     # H % block_h fallback
    ],
)
def test_ssd_scan_sweep(B, S, H, P, N, chunk, block_h, dtype):
    ks = jax.random.split(jax.random.key(3), 5)
    x = (jax.random.normal(ks[0], (B, S, H, P)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, S, 1, N)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, 1, N)) * 0.3).astype(dtype)
    y, st = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, block_h=block_h)
    yr, sr = ref.ssd_scan_ref(
        x.astype(jnp.float32), dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32), chunk
    )
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), **tol)


def test_ssd_scan_state_carry_equals_two_halves():
    """Scanning S tokens == scanning S/2 then S/2 with carried state."""
    ks = jax.random.split(jax.random.key(4), 5)
    B, S, H, P, N, chunk = 1, 128, 4, 16, 16, 32
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, 1, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, 1, N)) * 0.3
    y_full, st_full = ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk)
    h = S // 2
    y1, st1 = ref.ssd_scan_ref(x[:, :h], dt[:, :h], A, Bm[:, :h], Cm[:, :h], chunk)
    y2, st2 = ref.ssd_scan_ref(x[:, h:], dt[:, h:], A, Bm[:, h:], Cm[:, h:], chunk, init_state=st1)
    np.testing.assert_allclose(np.asarray(y_full[:, h:]), np.asarray(y2), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_full), np.asarray(st2), atol=1e-4, rtol=1e-3)


# ------------------------------------------------------------- scheduler step
@pytest.mark.parametrize("R,F,W", [(16, 4, 8), (64, 10, 16), (8, 1, 4), (128, 40, 5)])
def test_sched_step_sweep(R, F, W):
    ks = jax.random.split(jax.random.key(5), 3)
    funcs = jax.random.randint(ks[0], (R,), 0, F)
    idle = jax.random.randint(ks[1], (F, W), 0, 3)
    conns = jax.random.randint(ks[2], (W,), 0, 5)
    a, warm, i2, c2 = ops.sched_step(funcs, idle, conns)
    ar, wr, ir, cr = ref.sched_step_ref(funcs, idle, conns)
    assert jnp.all(a == ar) and jnp.all(warm == wr.astype(jnp.int32))
    assert jnp.all(i2 == ir) and jnp.all(c2 == cr)


@settings(max_examples=30, deadline=None)
@given(
    r=st.integers(1, 40),
    f=st.integers(1, 8),
    w=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_sched_step_invariants(r, f, w, seed):
    """Property: conservation + warm-iff-idle-available (Algorithm 1)."""
    ks = jax.random.split(jax.random.key(seed), 3)
    funcs = jax.random.randint(ks[0], (r,), 0, f)
    idle = jax.random.randint(ks[1], (f, w), 0, 3)
    conns = jax.random.randint(ks[2], (w,), 0, 4)
    a, warm, i2, c2 = ref.sched_step_ref(funcs, idle, conns)
    a, warm, i2, c2 = map(np.asarray, (a, warm, i2, c2))
    # every request assigned to a real worker
    assert ((a >= 0) & (a < w)).all()
    # connections increase by exactly R in total
    assert c2.sum() == np.asarray(conns).sum() + r
    # idle entries only ever decrease, by exactly the number of warm hits
    assert (i2 <= np.asarray(idle)).all()
    assert np.asarray(idle).sum() - i2.sum() == warm.sum()
    # a request is warm iff its function had an idle instance at its turn
    # (checked constructively by replay)
    idle_sim = np.asarray(idle).copy()
    conns_sim = np.asarray(conns).copy()
    for i in range(r):
        fi = int(funcs[i])
        has = idle_sim[fi].sum() > 0
        assert bool(warm[i]) == bool(has)
        if has:
            row = np.where(idle_sim[fi] > 0, conns_sim, 2**30)
            wi = int(row.argmin())
            idle_sim[fi, wi] -= 1
        else:
            wi = int(conns_sim.argmin())
        assert wi == int(a[i])
        conns_sim[wi] += 1
