"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU).  Hypothesis property
tests live in test_kernels_properties.py so this module runs even where
hypothesis isn't installed."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,KH,hd,causal,window",
    [
        (1, 128, 4, 4, 64, True, None),     # MHA causal
        (2, 256, 8, 2, 64, True, None),     # GQA
        (1, 256, 4, 1, 128, True, 64),      # MQA + sliding window
        (2, 128, 4, 4, 32, False, None),    # bidirectional (whisper encoder)
    ],
)
def test_flash_attention_sweep(B, S, H, KH, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KH, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KH, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


# ------------------------------------------------------------ decode attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,KH,hd,valid,window",
    [
        (2, 512, 8, 2, 64, 511, None),
        (1, 256, 4, 4, 128, 100, None),
        (2, 512, 16, 2, 64, 300, 128),   # SWA decode
        (1, 128, 8, 1, 64, 0, None),     # first token
    ],
)
def test_decode_attention_sweep(B, S, H, KH, hd, valid, window, dtype):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kc = jax.random.normal(ks[1], (B, S, KH, hd), dtype)
    vc = jax.random.normal(ks[2], (B, S, KH, hd), dtype)
    out = ops.decode_attention(q, kc, vc, jnp.int32(valid), window=window, block_k=128)
    want = ref.decode_attention_ref(q, kc, vc, jnp.int32(valid), window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_decode_attention_matches_flash_last_row():
    """Decode of the last position == last row of full flash attention."""
    ks = jax.random.split(jax.random.key(2), 3)
    B, S, H, KH, hd = 1, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KH, hd))
    v = jax.random.normal(ks[2], (B, S, KH, hd))
    full = ref.flash_attention_ref(q, k, v, causal=True)
    dec = ops.decode_attention(q[:, -1], k, v, jnp.int32(S - 1), block_k=64)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]), atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------------- SSD scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,P,N,chunk,block_h",
    [
        (2, 256, 8, 16, 32, 64, 4),
        (1, 128, 24, 64, 128, 64, 8),   # mamba2-130m dims
        (1, 64, 4, 16, 16, 64, 4),      # single chunk
        (2, 192, 6, 16, 32, 64, 6),     # H % block_h fallback
    ],
)
def test_ssd_scan_sweep(B, S, H, P, N, chunk, block_h, dtype):
    ks = jax.random.split(jax.random.key(3), 5)
    x = (jax.random.normal(ks[0], (B, S, H, P)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, S, 1, N)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, 1, N)) * 0.3).astype(dtype)
    y, st = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, block_h=block_h)
    yr, sr = ref.ssd_scan_ref(
        x.astype(jnp.float32), dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32), chunk
    )
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), **tol)


def test_ssd_scan_state_carry_equals_two_halves():
    """Scanning S tokens == scanning S/2 then S/2 with carried state."""
    ks = jax.random.split(jax.random.key(4), 5)
    B, S, H, P, N, chunk = 1, 128, 4, 16, 16, 32
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, 1, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, 1, N)) * 0.3
    y_full, st_full = ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk)
    h = S // 2
    y1, st1 = ref.ssd_scan_ref(x[:, :h], dt[:, :h], A, Bm[:, :h], Cm[:, :h], chunk)
    y2, st2 = ref.ssd_scan_ref(x[:, h:], dt[:, h:], A, Bm[:, h:], Cm[:, h:], chunk, init_state=st1)
    np.testing.assert_allclose(np.asarray(y_full[:, h:]), np.asarray(y2), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_full), np.asarray(st2), atol=1e-4, rtol=1e-3)


# ------------------------------------------------- fused mixed-event dispatch
@pytest.mark.parametrize("R,F,W", [(32, 4, 8), (100, 10, 16), (57, 3, 5), (128, 40, 130)])
def test_sched_events_sweep(R, F, W):
    """Fused (ARRIVAL|FINISH|EVICT) kernel == the jax_sched scan oracle."""
    rng = np.random.default_rng(R * 1000 + W)
    kinds = rng.integers(0, 3, R)
    funcs = rng.integers(0, F, R)
    workers = np.where(kinds == 0, -1, rng.integers(0, W, R))
    idle = rng.integers(0, 3, (F, W))
    conns = rng.integers(0, 5, W)
    args = [jnp.asarray(a, jnp.int32) for a in (kinds, funcs, workers, idle, conns)]
    a, warm, i2, c2 = ops.sched_events(*args)
    ar, wr, ir, cr = ref.sched_events_ref(*args)
    assert jnp.all(a == ar) and jnp.all(warm == wr)
    assert jnp.all(i2 == ir) and jnp.all(c2 == cr)


def test_sched_events_arrival_only_matches_sched_step():
    """On a pure ARRIVAL burst the mixed kernel degenerates to sched_step."""
    ks = jax.random.split(jax.random.key(9), 3)
    R, F, W = 48, 6, 8
    funcs = jax.random.randint(ks[0], (R,), 0, F)
    idle = jax.random.randint(ks[1], (F, W), 0, 3)
    conns = jax.random.randint(ks[2], (W,), 0, 5)
    kinds = jnp.zeros((R,), jnp.int32)
    workers = jnp.full((R,), -1, jnp.int32)
    a1, w1, i1, c1 = ops.sched_events(kinds, funcs, workers, idle, conns)
    a2, w2, i2, c2 = ops.sched_step(funcs, idle, conns)
    assert jnp.all(a1 == a2) and jnp.all(w1 == w2)
    assert jnp.all(i1 == i2) and jnp.all(c1 == c2)


# ------------------------------------------------------------- scheduler step
@pytest.mark.parametrize("R,F,W", [(16, 4, 8), (64, 10, 16), (8, 1, 4), (128, 40, 5)])
def test_sched_step_sweep(R, F, W):
    ks = jax.random.split(jax.random.key(5), 3)
    funcs = jax.random.randint(ks[0], (R,), 0, F)
    idle = jax.random.randint(ks[1], (F, W), 0, 3)
    conns = jax.random.randint(ks[2], (W,), 0, 5)
    a, warm, i2, c2 = ops.sched_step(funcs, idle, conns)
    ar, wr, ir, cr = ref.sched_step_ref(funcs, idle, conns)
    assert jnp.all(a == ar) and jnp.all(warm == wr.astype(jnp.int32))
    assert jnp.all(i2 == ir) and jnp.all(c2 == cr)
