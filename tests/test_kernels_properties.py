"""Hypothesis property tests on the scheduler kernel's invariants.

Kept separate from test_kernels.py so the deterministic kernel sweeps still
run on environments without hypothesis (this module is skipped there)."""

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip only the property tests
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.kernels import ref  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(
    r=st.integers(1, 40),
    f=st.integers(1, 8),
    w=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_sched_step_invariants(r, f, w, seed):
    """Property: conservation + warm-iff-idle-available (Algorithm 1)."""
    ks = jax.random.split(jax.random.key(seed), 3)
    funcs = jax.random.randint(ks[0], (r,), 0, f)
    idle = jax.random.randint(ks[1], (f, w), 0, 3)
    conns = jax.random.randint(ks[2], (w,), 0, 4)
    a, warm, i2, c2 = ref.sched_step_ref(funcs, idle, conns)
    a, warm, i2, c2 = map(np.asarray, (a, warm, i2, c2))
    # every request assigned to a real worker
    assert ((a >= 0) & (a < w)).all()
    # connections increase by exactly R in total
    assert c2.sum() == np.asarray(conns).sum() + r
    # idle entries only ever decrease, by exactly the number of warm hits
    assert (i2 <= np.asarray(idle)).all()
    assert np.asarray(idle).sum() - i2.sum() == warm.sum()
    # a request is warm iff its function had an idle instance at its turn
    # (checked constructively by replay)
    idle_sim = np.asarray(idle).copy()
    conns_sim = np.asarray(conns).copy()
    for i in range(r):
        fi = int(funcs[i])
        has = idle_sim[fi].sum() > 0
        assert bool(warm[i]) == bool(has)
        if has:
            row = np.where(idle_sim[fi] > 0, conns_sim, 2**30)
            wi = int(row.argmin())
            idle_sim[fi, wi] -= 1
        else:
            wi = int(conns_sim.argmin())
        assert wi == int(a[i])
        conns_sim[wi] += 1
