"""The launcher CLIs (train/serve) run end to end."""

import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).parent.parent
ENV = dict(os.environ, PYTHONPATH=str(ROOT / "src"))


def _run(mod, *args, timeout=240):
    return subprocess.run([sys.executable, "-m", mod, *args],
                          capture_output=True, text=True, env=ENV, cwd=ROOT,
                          timeout=timeout)


def test_train_cli(tmp_path):
    out = _run("repro.launch.train", "--arch", "mamba2-130m", "--steps", "12",
               "--ckpt-dir", str(tmp_path), "--ckpt-every", "6")
    assert out.returncode == 0, out.stderr
    assert "done: 12 steps" in out.stdout
    assert list(tmp_path.glob("step_*")), "checkpoint not written"
    # resume from the checkpoint
    out2 = _run("repro.launch.train", "--arch", "mamba2-130m", "--steps", "14",
                "--ckpt-dir", str(tmp_path), "--resume")
    assert out2.returncode == 0, out2.stderr
    assert "resumed from step" in out2.stdout


def test_serve_cli():
    out = _run("repro.launch.serve", "--scheduler", "hiku", "--workers", "2",
               "--endpoints", "2", "--requests", "5", "--fail-at", "2")
    assert out.returncode == 0, out.stderr
    assert "failed; worker" in out.stdout  # failure + elastic join happened
    assert "summary:" in out.stdout
