"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture instantiates a REDUCED config of the same family
and runs one forward/train step on CPU, asserting output shapes and no NaNs.
Decode steps are exercised for every family that has one.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model, unzip


def _batch_for(cfg, key, B=2, S=32):
    ks = jax.random.split(key, 3)
    if cfg.enc_dec:
        return {
            "frames": jax.random.normal(ks[0], (B, S, cfg.d_model)) * 0.02,
            "tokens": jax.random.randint(ks[1], (B, max(S // 4, 8)), 0, cfg.vocab),
        }
    batch = {"tokens": jax.random.randint(ks[1], (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        n_img = cfg.n_frontend_tokens
        batch["patches"] = jax.random.normal(ks[2], (B, n_img, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = unzip(model.init(jax.random.key(0), max_seq=64))
    batch = _batch_for(cfg, jax.random.key(1))
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # loss should be near ln(vocab) at random init
    assert float(loss) < 2.5 * np.log(cfg.vocab) + 5

    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), grads)
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = unzip(model.init(jax.random.key(0), max_seq=64))
    B, S = 2, 32
    batch = _batch_for(cfg, jax.random.key(1), B, S)
    logits, _, _ = model.forward(params, batch, mode="train")
    S_out = batch["tokens"].shape[1]
    assert logits.shape == (B, S_out, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_smoke(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = unzip(model.init(jax.random.key(0), max_seq=64))
    B, S_cache = 2, 16
    cache = model.init_cache(B, S_cache, dtype=jnp.float32, memory_t=8)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, tok, cache, jnp.int32(3))
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch}: NaN decode"
    # cache must keep its structure and shapes
    s1 = jax.tree.map(lambda a: a.shape, cache)
    s2 = jax.tree.map(lambda a: a.shape, cache2)
    assert s1 == s2


@pytest.mark.parametrize("arch", ["gemma3_4b", "mamba2_130m", "mixtral_8x22b", "deepseek_v3_671b"])
def test_prefill_then_decode_consistency(arch):
    """Prefill cache then decode; logits must be finite and cache consistent."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = unzip(model.init(jax.random.key(0), max_seq=64))
    B, S = 2, 16
    batch = _batch_for(cfg, jax.random.key(1), B, S)
    cache, last_logits = model.prefill(params, batch)
    assert last_logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(last_logits, np.float32)).all()


def test_param_counts_full_configs():
    """The analytic n_params() of each FULL config lands near its nameplate."""
    expect = {
        "gemma3_4b": (3.0e9, 6.0e9),
        "command_r_35b": (30e9, 40e9),
        "minicpm_2b": (2.0e9, 3.3e9),
        "command_r_plus_104b": (95e9, 115e9),
        "whisper_small": (0.15e9, 0.35e9),
        "mixtral_8x22b": (120e9, 150e9),
        "deepseek_v3_671b": (600e9, 720e9),
        "zamba2_2p7b": (2.0e9, 3.5e9),
        "llava_next_mistral_7b": (6.0e9, 8.0e9),
        "mamba2_130m": (0.1e9, 0.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: n_params={n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]B"
