"""Expert-parallel MoE (shard_map) must be bit-exact vs the in-graph path,
across mesh shapes and modes (the §Perf hillclimb correctness gate)."""

import dataclasses
import os

import pytest

# 8 virtual devices for the mesh sweeps — set before jax initializes.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models import unzip  # noqa: E402
from repro.models.moe import apply_moe, init_moe, route  # noqa: E402
from repro.sharding.ctx import use_rules  # noqa: E402
from repro.sharding.rules import make_plan  # noqa: E402


def _setup(cf=8.0):
    cfg = get_config("mixtral_8x22b").reduced()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cf))
    p, _ = unzip({"m": init_moe(jax.random.key(0), cfg, jnp.float32)})
    x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model)) * 0.5
    return cfg, p["m"], x


MESHES = [((2, 2), ("data", "model")), ((2, 4), ("data", "model")),
          ((2, 2, 2), ("pod", "data", "model")),
          ((1, 8), ("data", "model"))]  # E=4 < n_model=8: TP-within-expert


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 virtual devices")
@pytest.mark.parametrize("mode", ["capacity", "resident"])
@pytest.mark.parametrize("mesh_shape,axes", MESHES)
def test_ep_bit_exact(mode, mesh_shape, axes):
    cfg, p, x = _setup()
    y_ref, aux_ref = apply_moe(p, x, cfg)
    mesh = jax.make_mesh(mesh_shape, axes)
    plan = make_plan("t", moe_mode=mode)
    with use_rules(mesh, plan.activation_rules, moe_mode=mode):
        y, aux = jax.jit(lambda p, x: apply_moe(p, x, cfg))(p, x)
    # expert-split paths are bit-exact; the TP-within-expert fallback
    # re-orders f32 partial sums (1e-5-level)
    assert float(jnp.abs(y - y_ref).max()) < 1e-4
    assert abs(float(aux - aux_ref)) < 1e-6


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 virtual devices")
def test_ep_gradients_match():
    cfg, p, x = _setup()

    def loss_plain(p, x):
        y, aux = apply_moe(p, x, cfg)
        return (y ** 2).sum() + aux

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = make_plan("t", moe_mode="capacity")

    def loss_ep(p, x):
        with use_rules(mesh, plan.activation_rules, moe_mode="capacity"):
            y, aux = apply_moe(p, x, cfg)
            return (y ** 2).sum() + aux

    g1 = jax.grad(loss_plain)(p, x)
    g2 = jax.jit(jax.grad(loss_ep))(p, x)
    # relative check: psum changes f32 accumulation order
    rel = jax.tree.reduce(
        max,
        jax.tree.map(
            lambda a, b: float(jnp.abs(a - b).max() / (jnp.abs(a).max() + 1e-9)), g1, g2
        ),
    )
    assert rel < 1e-4, rel


def test_sigmoid_router_deepseek():
    """DeepSeek sigmoid routing: top-k of biased scores, gates from raw."""
    cfg = get_config("deepseek_v3_671b").reduced()
    p, _ = unzip({"m": init_moe(jax.random.key(0), cfg, jnp.float32)})
    x = jax.random.normal(jax.random.key(1), (8, cfg.d_model))
    gates, idx, aux = route(p["m"], x, cfg)
    assert gates.shape == (8, cfg.moe.top_k)
    assert float(jnp.abs(gates.sum(-1) - 1.0).max()) < 1e-5  # normalized
    assert float(aux) >= 0
