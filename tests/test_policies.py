"""Pluggable admission-policy registry: registration round-trip, byte
identity of the ported policies against a verbatim replica of the
pre-registry admission loop, determinism for every registered policy, the
new deadline/cost/predictive behaviors, the workload scenario suite, and
the flash-crowd acceptance (deadline beats pull on miss rate, p99 within
10%)."""

import dataclasses
import heapq
import time
import warnings
from collections import deque

import numpy as np
import pytest

from repro.core import SimConfig, Simulator, make_functions, make_scheduler
from repro.core.admission import AdmissionConfig, AdmissionRun, AdmissionSimulator
from repro.core.policies import (
    AdmissionPolicy,
    ShardState,
    available_policies,
    get_policy_class,
    make_policy,
    register_policy,
    unregister_policy,
)
from repro.core.shard import shard_seed
from repro.core.stealing import steal_tick
from repro.core.trace import default_n_events
from repro.core.workloads import available_scenarios, make_scenario

pytestmark = pytest.mark.shard

FUNCS = make_functions(seed=0)


def _quick_scenario(name="flash_crowd", n_vus=24, dur=10.0, seed=0):
    return make_scenario(name, FUNCS, n_vus, dur, seed=seed), dur


def _run(policy, scn, dur, K=2, W=8, seed=0, **adm_kw):
    adm = AdmissionSimulator(
        K, W, scheduler="hiku", cfg=SimConfig(mem_pool_mb=1024.0), seed=seed,
        admission=AdmissionConfig(policy=policy, steal_watermark=1.25, **adm_kw),
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return adm.run(scn.n_vus, dur, **scn.run_kwargs())


# ------------------------------------------------------------ the registry
def test_available_policies_contains_the_builtins():
    names = available_policies()
    for name in ("pull", "pull+steal", "round_robin", "deadline", "cost",
                 "predictive", "affinity", "affinity+steal",
                 "sjf", "bandit", "bandit+steal"):
        assert name in names
    # the learned flag partitions the registry (the leaderboard's axis)
    for name in ("sjf", "bandit", "bandit+steal"):
        assert get_policy_class(name).learned
    for name in ("pull", "deadline", "cost", "affinity"):
        assert not get_policy_class(name).learned


def test_unknown_policy_error_lists_available():
    with pytest.raises(ValueError, match=r"available.*pull"):
        AdmissionConfig(policy="gossip")
    with pytest.raises(ValueError, match="available"):
        get_policy_class("nope")
    with pytest.raises(ValueError):
        unregister_policy("never-registered")


def test_register_resolve_run_unregister_round_trip():
    """Satellite acceptance: register -> resolve -> run -> unregister."""

    class EveryOther(AdmissionPolicy):
        """Admit only on even shards — deliberately quirky but deterministic."""

        name = "every_other"

        def want_pull(self, state):
            return state.index % 2 == 0 and state.pressure < self.cfg.watermark

    register_policy(EveryOther)
    try:
        assert "every_other" in available_policies()
        assert get_policy_class("every_other") is EveryOther
        scn, dur = _quick_scenario(n_vus=12)
        r = _run("every_other", scn, dur)
        assert isinstance(r, AdmissionRun)
        # odd shards never pulled
        assert all(len(r.shards[k].admitted) == 0 for k in range(1, len(r.shards), 2))
        assert sum(len(s.admitted) for s in r.shards) == r.admitted > 0
    finally:
        assert unregister_policy("every_other") is EveryOther
    assert "every_other" not in available_policies()
    with pytest.raises(ValueError, match="available"):
        AdmissionConfig(policy="every_other")
    # double registration of a taken name is rejected
    register_policy(EveryOther)
    try:
        class Imposter(AdmissionPolicy):
            name = "every_other"

        with pytest.raises(ValueError, match="already registered"):
            register_policy(Imposter)
    finally:
        unregister_policy("every_other")


def test_policy_args_validated_at_config_time():
    with pytest.raises(TypeError, match="unknown policy_args"):
        AdmissionConfig(policy="pull", policy_args={"bogus": 1})
    with pytest.raises(ValueError, match="cost_weight"):
        AdmissionConfig(policy="cost", policy_args={"cost_weight": -1.0})
    with pytest.raises(ValueError, match="alpha"):
        AdmissionConfig(policy="predictive", policy_args={"alpha": 0.0})
    # well-formed knobs construct fine
    AdmissionConfig(policy="cost", policy_args={"cost_weight": 0.8})


def test_policy_args_error_names_key_and_lists_accepted_knobs():
    """Satellite bugfix pin: the config-time rejection must name the
    offending key(s) and list the resolved policy class's accepted knobs
    (walked across the MRO, so inherited knobs show up too)."""
    with pytest.raises(
        TypeError, match=r"'priors_ms'.*accepted knobs.*'prior_ms'"
    ):
        AdmissionConfig(policy="sjf", policy_args={"priors_ms": 100.0})
    # a policy with no knobs at all says so instead of listing nothing
    with pytest.raises(
        TypeError, match=r"'record_state'.*accepted knobs: \(none\)"
    ):
        AdmissionConfig(policy="pull", policy_args={"record_state": True})
    # several unknown keys: all named, sorted, next to the class name
    with pytest.raises(
        TypeError, match=r"BanditPolicy.*'eps', 'sead'.*'bandit_seed'"
    ):
        AdmissionConfig(policy="bandit", policy_args={"sead": 1, "eps": 0.2})
    # knob sets are the policy's own: bandit's knobs include the inherited
    # LearnedPolicy window controls
    from repro.core.policies import BanditStealPolicy, SjfPolicy, policy_knobs

    assert policy_knobs(SjfPolicy) == [
        "prior_ms", "record_state", "replay_from", "update_every",
    ]
    assert "arms" in policy_knobs(BanditStealPolicy)
    assert "update_every" in policy_knobs(BanditStealPolicy)


def test_shard_state_is_frozen():
    s = ShardState(0, 0.0, 4, 0.25, 1.0, 0, 0.0)
    with pytest.raises(dataclasses.FrozenInstanceError):
        s.pressure = 1.0


# ----------------------------------- byte identity vs the pre-registry tier
def _legacy_run(adm: AdmissionSimulator, n_vus, duration_s, programs, arrivals=None):
    """Verbatim replica of the PRE-REGISTRY AdmissionSimulator.run loop
    (hard-wired pull/round_robin/pull+steal), driving the same engine
    hooks.  The registry dispatch must reproduce its streams byte-for-byte.
    """
    cfg = adm.admission
    programs = list(programs)
    arr = np.zeros(n_vus) if arrivals is None else np.asarray(arrivals, np.float64)
    order = np.argsort(arr, kind="stable")
    sims = []
    for k in range(adm.n_shards):
        sk = shard_seed(adm.seed, k)
        sched = make_scheduler(adm.scheduler, adm.worker_split[k], seed=sk)
        sim = Simulator(
            sched, funcs=adm.funcs,
            cfg=dataclasses.replace(adm.cfg, n_workers=adm.worker_split[k]), seed=sk,
        )
        sim.begin(n_vus=0, duration_s=duration_s, programs=[])
        sims.append(sim)
    admitted = [[] for _ in range(adm.n_shards)]
    admit_t = [[] for _ in range(adm.n_shards)]
    pulls = [0] * adm.n_shards
    migrations = []
    waiting = deque()
    qpos = 0
    rr_next = 0
    tick = 0
    t = 0.0
    while True:
        while qpos < n_vus and arr[order[qpos]] <= t:
            waiting.append(int(order[qpos]))
            qpos += 1
        if t < duration_s and waiting:
            if cfg.policy == "round_robin":
                quota = n_vus if cfg.batch_size is None else cfg.batch_size * adm.n_shards
                while waiting and quota > 0:
                    quota -= 1
                    gid = waiting.popleft()
                    k = rr_next % adm.n_shards
                    rr_next += 1
                    sims[k].admit_vu(programs[gid], t=t)
                    admitted[k].append(gid)
                    admit_t[k].append(t)
                    pulls[k] += 1
            else:
                tick_pulls = [0] * adm.n_shards
                heap = [(sims[k].pressure(), k) for k in range(adm.n_shards)]
                heapq.heapify(heap)
                while waiting and heap:
                    p, k = heap[0]
                    if p >= cfg.watermark:
                        break
                    gid = waiting.popleft()
                    sims[k].admit_vu(programs[gid], t=t)
                    admitted[k].append(gid)
                    admit_t[k].append(t)
                    pulls[k] += 1
                    tick_pulls[k] += 1
                    if cfg.batch_size is not None and tick_pulls[k] >= cfg.batch_size:
                        heapq.heappop(heap)
                    else:
                        heapq.heapreplace(heap, (p + adm.inv_workers[k], k))
        if cfg.policy == "pull+steal" and t < duration_s:
            moves = steal_tick(
                sims, steal_watermark=cfg.steal_watermark,
                pull_watermark=cfg.watermark, inv_workers=adm.inv_workers,
                t=t, max_moves=cfg.steal_batch,
            )
            for mv in moves:
                gid = admitted[mv.src][mv.src_vu]
                admitted[mv.dst].append(gid)
                admit_t[mv.dst].append(t)
            migrations.extend(moves)
        if t >= duration_s and all(s.done for s in sims):
            break
        tick += 1
        t = tick * cfg.tick_s
        for sim in sims:
            sim.step_until(t)
    return adm._merge(
        sims, admitted, admit_t, pulls, n_vus, 0.0, [], [], migrations
    )


@pytest.mark.parametrize("policy", ["pull", "round_robin", "pull+steal"])
@pytest.mark.parametrize("batch_size", [None, 2])
def test_ported_policies_byte_identical_to_pre_registry_loop(policy, batch_size):
    """Acceptance: the three original behaviors, dispatched through the
    registry, reproduce the pre-registry admission tier byte-for-byte —
    records, assignments, admission tables and migration schedules."""
    from repro.core.admission import make_sleeper_programs

    K, W, VUS, DUR = 2, 8, 24, 12.0
    cfg = AdmissionConfig(policy=policy, steal_watermark=1.25, batch_size=batch_size)
    programs = make_sleeper_programs(FUNCS, VUS, default_n_events(DUR), 3)
    arrivals = [(vu % 3) * 2.0 for vu in range(VUS)]
    adm = AdmissionSimulator(
        K, W, scheduler="hiku", cfg=SimConfig(mem_pool_mb=1024.0), seed=3,
        admission=cfg,
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        new = adm.run(VUS, DUR, programs=programs, arrivals=arrivals)
        adm2 = AdmissionSimulator(
            K, W, scheduler="hiku", cfg=SimConfig(mem_pool_mb=1024.0), seed=3,
            admission=cfg,
        )
        old = _legacy_run(adm2, VUS, DUR, programs, arrivals)
    assert new.records.equals(old.records)
    assert np.array_equal(new.assign_t, old.assign_t)
    assert np.array_equal(new.assign_w, old.assign_w)
    assert [s.admitted.tolist() for s in new.shards] == [
        s.admitted.tolist() for s in old.shards
    ]
    assert [s.pulls for s in new.shards] == [s.pulls for s in old.shards]
    assert new.migrations == old.migrations


def test_deadline_without_metadata_degrades_to_pull():
    """EDF with no deadline annotations is FIFO by arrival: identical
    streams to plain pull (the documented fallback)."""
    scn, dur = _quick_scenario("on_off", n_vus=16)
    scn = dataclasses.replace(scn, deadlines=None)
    r_pull = _run("pull", scn, dur)
    r_dl = _run("deadline", scn, dur)
    assert r_dl.records.equals(r_pull.records)
    assert np.array_equal(r_dl.assign_w, r_pull.assign_w)


# ------------------------------------------------------------- determinism
@pytest.mark.parametrize("policy", sorted(available_policies()))
def test_every_registered_policy_is_deterministic(policy):
    scn, dur = _quick_scenario("flash_crowd", n_vus=20)
    r1 = _run(policy, scn, dur)
    r2 = _run(policy, scn, dur)
    assert r1.records.equals(r2.records)
    assert np.array_equal(r1.assign_t, r2.assign_t)
    assert [s.admitted.tolist() for s in r1.shards] == [
        s.admitted.tolist() for s in r2.shards
    ]


# ------------------------------------------------------ the new behaviors
def test_flash_crowd_acceptance_deadline_beats_pull():
    """Acceptance: on the flash-crowd scenario the deadline policy beats
    pull on deadline-miss rate with p99 within 10% (the bench_policies
    acceptance row, pinned at quick scale)."""
    from benchmarks.bench_policies import QUICK, run_cell

    scn = make_scenario(
        "flash_crowd", FUNCS, QUICK["n_vus"], QUICK["duration_s"], seed=0
    )
    _, m_pull = run_cell("pull", scn, QUICK, seed=0)
    _, m_dl = run_cell("deadline", scn, QUICK, seed=0)
    assert m_pull.deadline_miss_rate > 0, "scenario must actually miss under pull"
    assert m_dl.deadline_miss_rate < m_pull.deadline_miss_rate
    assert abs(m_dl.p99_ms - m_pull.p99_ms) <= 0.10 * m_pull.p99_ms


def test_deadline_policy_orders_queue_by_edf():
    """Tight-SLO VUs admitted under backlog bind before slack ones."""
    scn, dur = _quick_scenario("flash_crowd", n_vus=24)
    r = _run("deadline", scn, dur)
    tight = set(np.flatnonzero(np.isfinite(scn.deadlines)).tolist())
    admit_time = {}
    for s in r.shards:
        for g, t in zip(s.admitted.tolist(), s.admit_t.tolist()):
            admit_time.setdefault(g, t)
    spike_arrival = scn.arrivals[sorted(tight)[0]]
    tight_waits = [admit_time[g] - scn.arrivals[g] for g in tight if g in admit_time]
    # every tight VU admitted, promptly (spike VUs without SLO wait longer)
    assert len(tight_waits) == len(tight)
    spike_loose = [
        g for g in range(scn.n_vus)
        if g not in tight and scn.arrivals[g] >= spike_arrival and g in admit_time
    ]
    if spike_loose:  # backlogged slack VUs bind strictly later on average
        loose_waits = [admit_time[g] - scn.arrivals[g] for g in spike_loose]
        assert np.mean(tight_waits) <= np.mean(loose_waits)


def test_cost_policy_prefers_warm_shards():
    """A shard with zero warm capacity is gated harder than a warm one."""
    cfg = AdmissionConfig(policy="cost")
    pol = make_policy("cost", cfg)
    warm = ShardState(0, 0.5, 4, 0.25, 1.0, 0, 0.0)
    cold = ShardState(1, 0.5, 4, 0.25, 0.0, 0, 0.0)
    assert pol.want_pull(warm)
    assert not pol.want_pull(cold)  # 0.5 + 0.5 penalty >= 0.75 watermark
    keys = dict((k, key) for key, k in pol.rank_shards([warm, cold]))
    assert keys[0] < keys[1]


def test_affinity_policy_scores_warm_hit_against_digest():
    """The hit blend: profile overlap plus first-call warmth, and the
    pressure discount ranks a warmer-but-busier shard first."""
    from repro.core.policies import AffinityPolicy

    prof = ((3, 0.5), (7, 0.25), (9, 0.25))
    assert AffinityPolicy.warm_hit(prof, {3: 2, 9: 1}) == pytest.approx(0.75)
    assert AffinityPolicy.warm_hit(prof, {}) == 0.0
    assert AffinityPolicy.warm_hit(prof, None) == 0.0
    assert AffinityPolicy.warm_hit((), {3: 1}) == 0.0


def test_affinity_policy_routes_to_warm_shard():
    """A VU whose functions are warm on the busier shard still binds there:
    warmth is a pressure discount (the KV-router analog)."""
    import warnings as _w

    from repro.core import make_functions as _mf
    from repro.core.trace import make_vu_programs

    adm = AdmissionSimulator(
        2, 8, scheduler="hiku", cfg=SimConfig(mem_pool_mb=1024.0), seed=0,
        admission=AdmissionConfig(policy="affinity", tick_s=0.25),
    )
    funcs = adm.funcs
    # wave 1 seeds shard warmth; wave 2 (identical programs) arrives once
    # the wave-1 VUs' functions are warm *somewhere* and should co-locate
    progs = make_vu_programs(funcs, 8, 16, 0)
    progs = progs[:4] + progs[:4]  # wave 2 repeats wave 1's programs
    arrivals = [0.0] * 4 + [3.0] * 4
    with _w.catch_warnings():
        _w.simplefilter("ignore", RuntimeWarning)
        run = adm.run(8, 12.0, programs=progs, arrivals=arrivals)
    assert run.admitted == 8
    home = {}
    for s in run.shards:
        for g in s.admitted.tolist():
            home.setdefault(g, s.index)
    # each wave-2 VU landed on its wave-1 twin's shard (warm for exactly
    # its function mix), despite that shard carrying the wave-1 load
    for g in range(4):
        assert home[g + 4] == home[g], (g, home)


def test_nan_rank_key_raises_clear_error():
    """Satellite bugfix: a NaN rank key (the classic undeclared
    warm_capacity read) fails loudly instead of silently freezing the
    heap."""

    class NanRank(AdmissionPolicy):
        name = "nan_rank"

        # deliberately MISSING uses_warm_capacity = True
        def rank_shards(self, states):
            return [(s.pressure + s.warm_capacity, s.index) for s in states]

    register_policy(NanRank)
    try:
        scn, dur = _quick_scenario(n_vus=4)
        with pytest.raises(ValueError, match="uses_warm_capacity"):
            _run("nan_rank", scn, dur)
    finally:
        unregister_policy("nan_rank")


def test_warm_digest_gated_by_flag_and_read_only():
    """ShardState.warm_digest is None unless the policy declares
    ``uses_warm_digest``; when populated it is a read-only mapping view."""
    seen = {}

    class DigestProbe(AdmissionPolicy):
        name = "digest_probe"
        uses_warm_digest = True

        def want_pull(self, state):
            seen[state.index] = state.warm_digest
            return super().want_pull(state)

    class PlainProbe(AdmissionPolicy):
        name = "plain_probe"
        plain_seen = []

        def want_pull(self, state):
            PlainProbe.plain_seen.append(state.warm_digest)
            return super().want_pull(state)

    register_policy(DigestProbe)
    register_policy(PlainProbe)
    try:
        scn, dur = _quick_scenario(n_vus=6)
        _run("digest_probe", scn, dur)
        assert seen, "probe never saw a shard state"
        for digest in seen.values():
            assert digest is not None
            with pytest.raises(TypeError):
                digest[0] = 99  # frozen-snapshot read surface
        _run("plain_probe", scn, dur)
        assert PlainProbe.plain_seen
        assert all(d is None for d in PlainProbe.plain_seen)
    finally:
        unregister_policy("digest_probe")
        unregister_policy("plain_probe")


def test_predictive_policy_raises_watermark_under_bursts():
    cfg = AdmissionConfig(policy="predictive")
    pol = make_policy("predictive", cfg)

    class _Ctx:
        total_workers = 8

    assert pol._watermark == cfg.watermark
    pol.observe(0.0, 16, _Ctx())  # a burst: 16 arrivals in one tick
    assert pol._watermark > cfg.watermark
    high = pol._watermark
    for i in range(1, 60):  # long calm: forecast decays back
        pol.observe(i * 0.25, 0, _Ctx())
    assert cfg.watermark <= pol._watermark < high
    assert pol._watermark == pytest.approx(cfg.watermark, abs=1e-3)


def test_warm_capacity_signal_bounds():
    sim = Simulator(make_scheduler("hiku", 2, seed=0), cfg=SimConfig(n_workers=2), seed=0)
    sim.begin(n_vus=0, duration_s=5.0, programs=[])
    assert sim.warm_capacity() == 1.0  # idle cluster: whole pool is headroom
    dead = Simulator(make_scheduler("hiku", 1, seed=0), cfg=SimConfig(n_workers=1), seed=0)
    dead.inject_failure(0.5, 0)
    dead.begin(n_vus=0, duration_s=5.0, programs=[])
    dead.step_until(1.0)
    assert dead.warm_capacity() == 0.0  # dead cluster: no headroom at all
    busy = Simulator(make_scheduler("hiku", 2, seed=0), cfg=SimConfig(n_workers=2), seed=0)
    busy.begin(n_vus=8, duration_s=5.0)
    busy.step_until(0.1)
    assert 0.0 <= busy.warm_capacity() < 1.0  # running tasks pin pool memory


# --------------------------------------------------------- workload suite
def test_scenario_registry_and_unknown_name():
    assert available_scenarios() == ["diurnal", "flash_crowd", "heavy_tail", "on_off"]
    with pytest.raises(ValueError, match="available"):
        make_scenario("tsunami", FUNCS, 8, 10.0)


@pytest.mark.parametrize("name", sorted(available_scenarios()))
def test_scenarios_replay_bit_exactly(name):
    """Scenario generation is a pure function of (seed, vu) — the identity
    seeding contract extended to the workload tier."""
    a = make_scenario(name, FUNCS, 16, 12.0, seed=5)
    b = make_scenario(name, FUNCS, 16, 12.0, seed=5)
    c = make_scenario(name, FUNCS, 16, 12.0, seed=6)
    assert np.array_equal(a.arrivals, b.arrivals)
    assert not np.array_equal(a.arrivals, c.arrivals)
    if a.deadlines is None:
        assert b.deadlines is None
    else:
        assert np.array_equal(a.deadlines, b.deadlines)
    for pa, pb in zip(a.programs, b.programs):
        assert np.array_equal(pa.func_idx, pb.func_idx)
        assert np.array_equal(pa.sleep_s, pb.sleep_s)


@pytest.mark.parametrize("name", sorted(available_scenarios()))
def test_scenarios_shape_and_admissibility(name):
    dur = 12.0
    scn = make_scenario(name, FUNCS, 16, dur, seed=1)
    assert scn.n_vus == 16 and scn.arrivals.shape == (16,)
    assert (scn.arrivals >= 0).all()
    # no VU lands in the end-of-run admission blind window by construction
    assert scn.arrivals.max() < 0.9 * dur
    n_ev = default_n_events(dur)
    for p in scn.programs:
        assert p.func_idx.shape == (n_ev,)
        assert (p.func_idx >= 0).all() and (p.func_idx < len(FUNCS)).all()
        assert (p.sleep_s >= 0).all()


def test_run_validates_deadlines_shape():
    adm = AdmissionSimulator(2, 4, seed=0)
    scn, dur = _quick_scenario(n_vus=8)
    with pytest.raises(ValueError, match="deadlines"):
        adm.run(8, dur, programs=scn.programs, arrivals=scn.arrivals,
                deadlines=[1.0])


def test_deadline_miss_rate_zero_without_metadata():
    scn, dur = _quick_scenario("flash_crowd", n_vus=12)
    r = _run("pull", scn, dur)
    assert r.summarize(dur).deadline_miss_rate >= 0.0
    bare = dataclasses.replace(scn, deadlines=None)
    r2 = _run("pull", bare, dur)
    m = r2.summarize(dur)
    assert m.deadline_miss_rate == 0.0


def test_pull_tick_shim_removed_registry_path_drives_external_queue():
    """The deprecated direct-drive ``_pull_tick`` shim is gone; the registry
    path — one ``admit_tick`` over a caller-built PolicyContext — is the
    direct-drive entry point and admits from a caller-seeded queue."""
    from repro.core.policies import PolicyContext
    from repro.core.trace import make_vu_programs

    adm = AdmissionSimulator(2, 4, scheduler="hiku", seed=0)
    assert not hasattr(adm, "_pull_tick")  # the PR-5 shim is removed
    progs = make_vu_programs(FUNCS, 4, 32, 0)
    sims = []
    for k in range(2):
        sim = Simulator(
            make_scheduler("hiku", 2, seed=k), funcs=FUNCS,
            cfg=SimConfig(n_workers=2), seed=k,
        )
        sim.begin(n_vus=0, duration_s=10.0, programs=[])
        sims.append(sim)
    policy = make_policy("pull", adm.admission)
    admitted, admit_t, pulls = [[], []], [[], []], [0, 0]
    ctx = PolicyContext(
        sims=sims, programs=progs, worker_split=adm.worker_split,
        inv_workers=adm.inv_workers, admitted=admitted, admit_t=admit_t,
        pulls=pulls, policy=policy,
    )
    for gid in range(4):
        ctx.enqueue(gid)
    policy.admit_tick(0.0, ctx)
    assert sum(pulls) == 4 and ctx.waiting_n == 0


# ------------------------------------------------------ learned policies
class _ProfileCtx:
    """Minimal PolicyContext stand-in for queue_key unit tests: programs +
    the func_profile contract (sorted by func, frequencies summing to 1)."""

    def __init__(self, programs):
        self.programs = programs

    def func_profile(self, gid):
        fi = self.programs[gid].func_idx.tolist()
        if not fi:
            return ()
        counts = {}
        for f in fi:
            counts[f] = counts.get(f, 0) + 1
        return tuple((f, c / len(fi)) for f, c in sorted(counts.items()))


class _Prog:
    def __init__(self, func_idx):
        self.func_idx = np.asarray(func_idx, np.int64)


def test_sjf_queue_key_orders_by_predicted_total_service():
    """Stubbed estimator state: the queue key is ``n_calls * sum(freq *
    predict_ms(f))``, so observed-short VUs jump observed-long ones and
    never-seen functions fall back to the global mean."""
    from repro.core.policies import make_policy

    pol = make_policy("sjf", AdmissionConfig(policy="sjf"))
    for _ in range(4):
        pol.estimator.update(0, 10.0)     # func 0: quick
        pol.estimator.update(1, 1000.0)   # func 1: an elephant
    ctx = _ProfileCtx([
        _Prog([0, 0, 0, 0]),  # 4 quick calls        -> 40
        _Prog([1, 1]),        # 2 elephant calls     -> 2000
        _Prog([5, 5]),        # unseen func: global mean 505 each -> 1010
    ])
    keys = [pol.queue_key(g, ctx) for g in range(3)]
    assert keys[0] == pytest.approx(40.0)
    assert keys[1] == pytest.approx(2000.0)
    assert keys[2] == pytest.approx(1010.0)
    assert keys[0] < keys[2] < keys[1]
    # pre-observation the key is n_calls * prior: FIFO up to program length
    fresh = make_policy("sjf", AdmissionConfig(
        policy="sjf", policy_args={"prior_ms": 500.0}))
    assert fresh.queue_key(0, ctx) == pytest.approx(4 * 500.0)
    assert fresh.queue_key(1, ctx) == pytest.approx(2 * 500.0)


def test_bandit_folds_windowed_reward_and_scales_the_pull_gate():
    """One reward window moves the tuner off the warm-up arm; the pull
    gate is ``cfg.watermark * current_arm`` so the same pressure reads
    differently under different arms.  Empty windows feed nothing."""
    from repro.core.policies import Completion, make_policy

    cfg = AdmissionConfig(policy="bandit")
    pol = make_policy("bandit", cfg)
    assert pol.tuner.current == (0.6, 1.0)  # warm-up starts on arm 0
    state = ShardState(0, 0.5, 4, 0.25, 1.0, 0, 0.0)
    assert not pol.want_pull(state)  # gate 0.75 * 0.6 = 0.45 < pressure
    comps = tuple(
        Completion(gid=0, func=0, duration_ms=d, cold=False, shard=0)
        for d in (10.0, 20.0, 30.0)
    )
    pol.fold(comps)
    assert pol.tuner.pulls(0) == 1 and pol.tuner.arm_index == 1
    assert pol.want_pull(state)  # gate 0.75 * 1.0 = 0.75 > pressure
    pol.fold(())  # an empty window is no evidence: arm and stats unchanged
    assert pol.tuner.pulls(1) == 0 and pol.tuner.arm_index == 1


def test_bandit_steal_retunes_the_watermark_pair_per_arm():
    """bandit+steal routes its current arm through steal_params; a
    hand-tuned policy reports the config pair unchanged; and any arm that
    would invert the band is rejected at construction."""
    from repro.core.policies import make_policy

    cfg = AdmissionConfig(policy="bandit+steal", steal_watermark=1.25)
    pol = make_policy("bandit+steal", cfg)
    wm, sm = pol.tuner.current
    assert pol.steal_params() == (1.25 * sm, cfg.watermark * wm)
    for arm_pair in pol.tuner.arms:  # every arm keeps the band uninverted
        assert 1.25 * arm_pair[1] >= cfg.watermark * arm_pair[0]
    hand = make_policy("pull+steal", AdmissionConfig(
        policy="pull+steal", steal_watermark=1.25))
    assert hand.steal_params() == (1.25, hand.cfg.watermark)
    with pytest.raises(ValueError, match="steal victim and pull thief"):
        AdmissionConfig(
            policy="bandit+steal", steal_watermark=1.25,
            policy_args={"arms": [(2.0, 0.5)]},
        )


def test_learned_policy_validates_window_and_requires_observe_feed():
    with pytest.raises(ValueError, match="update_every"):
        AdmissionConfig(policy="sjf", policy_args={"update_every": 0})
    # the estimator only ever moves at window boundaries driven by observe
    scn, dur = _quick_scenario("heavy_tail", n_vus=16)
    r = _run("sjf", scn, dur, policy_args={"record_state": True})
    assert r.policy_state  # windows closed and were recorded
    totals = [
        s["estimator"]["global"][0] for s in r.policy_state
    ]
    assert totals == sorted(totals)  # monotone: folds only accumulate
    assert totals[-1] > 0  # the completion feed actually reached the fold


def test_leaderboard_requires_strict_win_over_every_hand_policy():
    """Unit pin of the leaderboard semantics: ties never count as a
    learned win; rankings break ties by name deterministically."""
    from benchmarks.bench_policies import leaderboard

    policies = ["pull", "sjf"]
    tie = {"s": {"pull": {"a": 1.0}, "sjf": {"a": 1.0}}}
    board = leaderboard(tie, ["s"], policies, {"s": ["a"]})
    assert board["learned_vs_hand"] == []
    assert board["rankings"]["s"]["a"] == ["pull", "sjf"]
    win = {"s": {"pull": {"a": 2.0}, "sjf": {"a": 1.0}}}
    board = leaderboard(win, ["s"], policies, {"s": ["a"]})
    assert board["rankings"]["s"]["a"] == ["sjf", "pull"]
    (w,) = board["learned_vs_hand"]
    assert w["winner"] == "sjf" and w["best_hand"] == "pull"
    assert w["winner_value"] < w["best_hand_value"]


def test_checked_in_leaderboard_has_learned_outright_wins():
    """PR acceptance: in the checked-in full-scale matrix a learned policy
    strictly beats every hand-tuned policy on at least one (scenario,
    axis) — pinned to the sjf heavy-tail p99 win the bench module
    documents."""
    import json
    from pathlib import Path

    path = (Path(__file__).resolve().parent.parent
            / "benchmarks" / "results" / "policies.json")
    payload = json.loads(path.read_text())
    wins = payload["leaderboard"]["learned_vs_hand"]
    assert wins, "no learned policy outranks the hand-tuned field anywhere"
    for w in wins:
        assert get_policy_class(w["winner"]).learned
        assert w["winner_value"] < w["best_hand_value"]  # strict, not a tie
        assert w["scenario"] in payload["scenarios"]
        assert w["axis"] in ("p99_ms", "mean_ms", "deadline_miss_rate",
                             "cold_rate")
    assert any(
        w["winner"] == "sjf" and w["scenario"] == "heavy_tail"
        and w["axis"] == "p99_ms"
        for w in wins
    ), wins


@pytest.mark.slow
def test_full_scale_leaderboard_reproduces_checked_in_artifact():
    """The checked-in benchmarks/results/policies.json is a pure function
    of the code: re-running the full-scale matrix reproduces its
    leaderboard exactly (results land in the gitignored local dir — the
    artifact itself only changes via an explicit --results-dir refresh)."""
    import json
    from pathlib import Path

    from benchmarks.bench_policies import run as bench_run

    bench_run(quick=False)
    root = Path(__file__).resolve().parent.parent / "benchmarks" / "results"
    checked_in = json.loads((root / "policies.json").read_text())
    fresh = json.loads((root / "local" / "policies.json").read_text())
    assert fresh["leaderboard"] == checked_in["leaderboard"]
    assert fresh["policies"] == checked_in["policies"]
