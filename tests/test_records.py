"""Columnar record store: lossless round-trips, merge primitives, and
float-exact parity between the legacy list metrics path and the columnar
path (tolerance 0 — the vectorized expressions must be the same IEEE ops)."""

import numpy as np
import pytest

from repro.core import SimConfig, Simulator, make_scheduler
from repro.core.metrics import latency_cdf, load_cv_per_second, summarize
from repro.core.records import (
    REC_DTYPE,
    RecordAccumulator,
    RecordColumns,
    RequestRecord,
)


@pytest.fixture(scope="module")
def sim_run():
    sched = make_scheduler("hiku", 5, seed=17)
    sim = Simulator(sched, cfg=SimConfig(mem_pool_mb=1024.0), seed=17)
    recs = sim.run(n_vus=25, duration_s=30.0)
    assert len(recs) > 100
    return sim, recs


def test_round_trip_records_columns_records(sim_run):
    _, recs = sim_run
    cols = RecordColumns.from_records(recs)
    back = cols.to_records()
    assert back == recs  # NamedTuple equality: every field bit-identical
    assert all(isinstance(r, RequestRecord) for r in back)
    assert all(isinstance(r.cold, bool) for r in back)


def test_accumulator_is_the_simulator_store(sim_run):
    sim, recs = sim_run
    cols = sim.record_columns
    assert len(cols) == len(recs)
    assert cols.to_records() == recs
    assert sim.records is sim.records  # cached materialization


def test_column_dtypes_and_structured_view(sim_run):
    sim, recs = sim_run
    cols = sim.record_columns
    assert cols.t_submit.dtype == np.float64
    assert cols.t_done.dtype == np.float64
    assert cols.func.dtype == np.int32
    assert cols.worker.dtype == np.int32
    assert cols.cold.dtype == np.bool_
    assert cols.vu.dtype == np.int32
    packed = cols.as_structured()
    assert packed.dtype == REC_DTYPE and len(packed) == len(cols)
    assert RecordColumns.from_structured(packed).equals(cols)


def test_concat_take_remap_getitem(sim_run):
    _, recs = sim_run
    cols = RecordColumns.from_records(recs)
    a, b = cols[: len(cols) // 2], cols[len(cols) // 2 :]
    cat = RecordColumns.concat([a, b])
    assert cat.equals(cols)
    rev = cols.take(np.arange(len(cols))[::-1])
    assert rev[0] == recs[-1] and rev[-1] == recs[0]
    shifted = cols.remap(worker_offset=100, vu_offset=1000)
    assert np.array_equal(shifted.worker, cols.worker + 100)
    assert np.array_equal(shifted.vu, cols.vu + 1000)
    assert np.array_equal(shifted.t_submit, cols.t_submit)
    assert cols.remap() is cols  # no-op fast path
    assert cols[3] == recs[3]
    assert list(cols[:2]) == recs[:2]


def test_from_structured_defaults_only_migrated(sim_run):
    """Pre-work-stealing captures (no ``migrated`` field) load with the
    column defaulted; any other missing field is corruption and raises."""
    sim, _ = sim_run
    cols = sim.record_columns
    legacy_dtype = np.dtype([d for d in REC_DTYPE.descr if d[0] != "migrated"])
    legacy = np.empty(len(cols), legacy_dtype)
    for name in legacy_dtype.names:
        legacy[name] = getattr(cols, name)
    back = RecordColumns.from_structured(legacy)
    assert back.equals(cols)  # migrated was all-False in this run
    assert not back.migrated.any()
    truncated = np.empty(3, np.dtype([("t_submit", "<f8"), ("t_done", "<f8")]))
    with pytest.raises(ValueError, match="lacks fields"):
        RecordColumns.from_structured(truncated)


def test_empty_store():
    empty = RecordColumns.empty()
    assert len(empty) == 0 and empty.to_records() == []
    assert RecordColumns.from_records([]).equals(empty)
    assert RecordColumns.concat([]).equals(empty)
    acc = RecordAccumulator()
    assert len(acc) == 0 and acc.columns().equals(empty)


def test_mismatched_column_lengths_rejected():
    with pytest.raises(ValueError):
        RecordColumns([0.0, 1.0], [1.0], [0], [0], [False], [0])


def test_accumulator_append_and_clear():
    acc = RecordAccumulator()
    acc.append(0.5, 1.5, 3, 2, True, 7)
    acc.append(0.6, 1.1, 1, 0, False, 4)
    assert len(acc) == 2
    assert acc.to_records() == [
        RequestRecord(0.5, 1.5, 3, 2, True, 7),
        RequestRecord(0.6, 1.1, 1, 0, False, 4),
    ]
    assert acc.columns().to_records() == acc.to_records()
    acc.clear()
    assert len(acc) == 0


def test_latency_vector_matches_row_property(sim_run):
    _, recs = sim_run
    cols = RecordColumns.from_records(recs)
    want = np.array([r.latency_ms for r in recs])
    assert np.array_equal(cols.latency_ms, want)


# ------------------------------------------------------- metrics parity
def test_summarize_list_vs_columnar_tolerance_zero(sim_run):
    sim, recs = sim_run
    m_list = summarize(recs, sim.assignments, list(range(5)), 30.0)
    m_cols = summarize(sim.record_columns, sim.assignment_columns, list(range(5)), 30.0)
    assert m_list == m_cols  # dataclass equality: float-exact


def test_latency_cdf_list_vs_columnar(sim_run):
    sim, recs = sim_run
    x1, y1 = latency_cdf(recs)
    x2, y2 = latency_cdf(sim.record_columns)
    assert np.array_equal(x1, x2) and np.array_equal(y1, y2)


def test_load_cv_matches_python_loop_reference(sim_run):
    """The vectorized bincount must reproduce the seed implementation's
    per-assignment Python loop bit-for-bit."""
    sim, _ = sim_run
    assignments = sim.assignments
    workers, t_end = list(range(5)), 30.0

    # reference: the pre-columnar implementation, verbatim
    n_bins = int(np.ceil(t_end)) + 1
    wid_index = {w: i for i, w in enumerate(workers)}
    counts = np.zeros((n_bins, len(workers)))
    for t, w in assignments:
        if w in wid_index:
            counts[min(int(t), n_bins - 1), wid_index[w]] += 1
    active = counts.sum(axis=1) > 0
    counts = counts[active]
    mean = counts.mean(axis=1)
    std = counts.std(axis=1)
    want = np.where(mean > 0, std / np.maximum(mean, 1e-12), 0.0)

    got_list = load_cv_per_second(assignments, workers, t_end)
    got_cols = load_cv_per_second(sim.assignment_columns, workers, t_end)
    assert np.array_equal(got_list, want)
    assert np.array_equal(got_cols, want)


def test_load_cv_ignores_unknown_workers(sim_run):
    """Assignments to workers outside the metric's worker set are dropped,
    exactly like the legacy dict-membership test did."""
    sim, _ = sim_run
    sub = [0, 2, 4]
    got = load_cv_per_second(sim.assignments, sub, 30.0)
    n_bins = int(np.ceil(30.0)) + 1
    wid_index = {w: i for i, w in enumerate(sub)}
    counts = np.zeros((n_bins, len(sub)))
    for t, w in sim.assignments:
        if w in wid_index:
            counts[min(int(t), n_bins - 1), wid_index[w]] += 1
    counts = counts[counts.sum(axis=1) > 0]
    mean, std = counts.mean(axis=1), counts.std(axis=1)
    want = np.where(mean > 0, std / np.maximum(mean, 1e-12), 0.0)
    assert np.array_equal(got, want)


def test_load_cv_accepts_plain_list_columns(sim_run):
    sim, _ = sim_run
    at, aw = sim.assignment_columns
    want = load_cv_per_second((at, aw), list(range(5)), 30.0)
    got = load_cv_per_second((at.tolist(), aw.tolist()), list(range(5)), 30.0)
    assert np.array_equal(got, want)


def test_load_cv_rejects_mismatched_columns():
    with pytest.raises(ValueError):
        load_cv_per_second((np.zeros(3), np.zeros(2, np.int64)), [0, 1], 5.0)


def test_summarize_empty_records_keeps_seed_semantics():
    m = summarize([], [], [0, 1], 10.0)
    assert m.n_requests == 0
    assert m.mean_latency_ms == 0.0 and m.cold_rate == 0.0
    assert m.load_cv == 0.0 and m.throughput_rps == 0.0
