"""Hypothesis property tests for the columnar record store.

Round-trip ``records <-> columns`` must preserve order, dtype, and every
flag bit-exactly, and the metrics must agree between the legacy list path
and the columnar path at float64 tolerance 0, for *arbitrary* record
streams — not just ones the simulator happens to emit.

Separate module so environments without hypothesis still run the
deterministic columnar tests in test_records.py (this module skips there).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip only the property tests
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.metrics import latency_cdf, load_cv_per_second, summarize  # noqa: E402
from repro.core.records import RecordColumns, RequestRecord  # noqa: E402

_times = st.floats(
    min_value=0.0, max_value=1e6, allow_nan=False, allow_infinity=False, width=64
)

_records = st.lists(
    st.builds(
        RequestRecord,
        t_submit=_times,
        t_complete=_times,
        func=st.integers(0, 63),
        worker=st.integers(0, 99),
        cold=st.booleans(),
        vu=st.integers(0, 499),
    ),
    max_size=200,
)

_assignments = st.lists(
    st.tuples(st.floats(min_value=0.0, max_value=99.0, allow_nan=False), st.integers(0, 9)),
    max_size=200,
)


@settings(max_examples=60, deadline=None)
@given(records=_records)
def test_round_trip_preserves_order_dtype_and_flags(records):
    cols = RecordColumns.from_records(records)
    assert len(cols) == len(records)
    assert cols.t_submit.dtype == np.float64 and cols.cold.dtype == np.bool_
    back = cols.to_records()
    assert back == records  # bit-exact fields, identical order
    assert [r.cold for r in back] == [r.cold for r in records]
    # structured pack/unpack is equally lossless
    assert RecordColumns.from_structured(cols.as_structured()).to_records() == records


@settings(max_examples=60, deadline=None)
@given(records=_records, assignments=_assignments, duration=st.floats(1.0, 500.0))
def test_summarize_list_vs_columnar_tolerance_zero(records, assignments, duration):
    workers = list(range(10))
    m_list = summarize(records, assignments, workers, duration)
    cols = RecordColumns.from_records(records)
    at = np.array([t for t, _ in assignments], np.float64)
    aw = np.array([w for _, w in assignments], np.int64)
    m_cols = summarize(cols, (at, aw), workers, duration)
    assert m_list == m_cols  # dataclass equality: every float identical


@settings(max_examples=40, deadline=None)
@given(records=_records.filter(len))
def test_latency_cdf_list_vs_columnar(records):
    x1, y1 = latency_cdf(records)
    x2, y2 = latency_cdf(RecordColumns.from_records(records))
    assert np.array_equal(x1, x2) and np.array_equal(y1, y2)


@settings(max_examples=40, deadline=None)
@given(assignments=_assignments, t_end=st.floats(1.0, 200.0))
def test_load_cv_list_vs_columnar(assignments, t_end):
    workers = list(range(10))
    got_list = load_cv_per_second(assignments, workers, t_end)
    at = np.array([t for t, _ in assignments], np.float64)
    aw = np.array([w for _, w in assignments], np.int64)
    got_cols = load_cv_per_second((at, aw), workers, t_end)
    assert np.array_equal(got_list, got_cols)


@settings(max_examples=40, deadline=None)
@given(records=_records, split=st.integers(0, 200))
def test_concat_of_split_is_identity(records, split):
    cols = RecordColumns.from_records(records)
    split = min(split, len(cols))
    again = RecordColumns.concat([cols[:split], cols[split:]])
    assert again.equals(cols)
