"""Shared-memory columnar transport (docs/ARCHITECTURE.md §13).

Pins the segment layer ``core.records`` exposes to the process backend:
headerless ``REC_DTYPE`` rows + aligned assignment sections, byte-exact
round-trips (``migrated`` included), explicit lifetime (no segment survives
its driver — even when the writer crashes before shipping metadata).
"""

import os

import numpy as np
import pytest

from repro.core.records import (
    REC_DTYPE,
    RecordColumns,
    read_columns_shm,
    shm_layout,
    unlink_columns_shm,
    write_columns_shm,
)
from repro.core.shard import SHM_PREFIX, ShardedSimulator

pytestmark = pytest.mark.shard

_SHM_DIR = "/dev/shm"


def _segments():
    """Live segment names carrying this suite's transport prefix."""
    if not os.path.isdir(_SHM_DIR):  # non-POSIX-shm platform
        return set()
    return {f for f in os.listdir(_SHM_DIR) if f.startswith(SHM_PREFIX)}


def _sample_columns():
    """A small stream with every column exercised, including migrated rows."""
    return RecordColumns(
        t_submit=[0.125, 0.25, 1.5, 2.75],
        t_done=[0.5, 1.0, 2.0, 3.5],
        func=[0, 3, 1, 2],
        worker=[2, 0, 1, 3],
        cold=[True, False, False, True],
        vu=[0, 1, 2, 1],
        migrated=[False, True, False, True],
    )


def test_shm_layout_is_aligned_and_exact():
    at_off, aw_off, total = shm_layout(n_rec=3, n_asg=5)
    assert at_off % 8 == 0 and aw_off % 8 == 0
    assert at_off >= 3 * REC_DTYPE.itemsize  # rows fit before the pad
    assert aw_off == at_off + 5 * 8
    assert total == aw_off + 5 * 8
    assert shm_layout(0, 0) == (0, 0, 0)  # nothing to ship -> no segment


def test_round_trip_preserves_structured_view_and_migrated(tmp_path):
    from multiprocessing import shared_memory

    cols = _sample_columns()
    at = np.array([0.1, 0.2, 0.3])
    aw = np.array([2, 0, 1], np.int64)
    name = f"{SHM_PREFIX}test-{os.getpid()}-rt"
    try:
        assert write_columns_shm(name, cols, at, aw) == name
        # the row section *is* the packed structured layout, byte for byte
        shm = shared_memory.SharedMemory(name=name)
        try:
            view = np.ndarray(len(cols), dtype=REC_DTYPE, buffer=shm.buf)
            np.testing.assert_array_equal(np.array(view), cols.as_structured())
        finally:
            del view
            shm.close()
        out, at2, aw2 = read_columns_shm(name, len(cols), len(at))
        assert out.equals(cols)
        np.testing.assert_array_equal(out.migrated, cols.migrated)
        np.testing.assert_array_equal(at2, at)
        np.testing.assert_array_equal(aw2, aw)
        # the copies own their memory: still valid after the segment is gone
        unlink_columns_shm(name)
        assert out.migrated.tolist() == [False, True, False, True]
        assert aw2.sum() == 3
    finally:
        unlink_columns_shm(name)
    assert name not in _segments()


def test_zero_row_shard_creates_no_segment():
    name = f"{SHM_PREFIX}test-{os.getpid()}-empty"
    assert write_columns_shm(name, RecordColumns.empty(), [], []) is None
    assert name not in _segments()
    # reading the degenerate shape needs no segment either
    unlink_columns_shm(name)  # idempotent on a never-created name
    unlink_columns_shm(None)  # and on the no-segment sentinel


def test_unlink_is_idempotent():
    name = f"{SHM_PREFIX}test-{os.getpid()}-idem"
    write_columns_shm(name, _sample_columns(), [0.5], [1])
    unlink_columns_shm(name)
    unlink_columns_shm(name)  # second pass: already gone, not an error
    assert name not in _segments()


def test_unlink_raced_between_attach_and_unlink_stays_tracker_balanced(
    monkeypatch,
):
    """The double-unlink race: a concurrent cleanup wins between our attach
    and our ``unlink()``.  The failed unlink must not raise — and it must
    still unregister the attach-time ``resource_tracker`` registration
    (attaching registers on Python <= 3.12): left unbalanced, the tracker
    re-unlinks the *name* at interpreter exit, clobbering any later segment
    that reused it."""
    from multiprocessing import shared_memory

    from repro.core import records as records_mod

    name = f"{SHM_PREFIX}test-{os.getpid()}-race"
    write_columns_shm(name, _sample_columns(), [0.5], [1])
    untracked = []
    real_untrack = records_mod._untrack_shm
    monkeypatch.setattr(
        records_mod, "_untrack_shm",
        lambda shm: (untracked.append(shm._name), real_untrack(shm))[-1],
    )

    class _RacedShm(shared_memory.SharedMemory):
        def unlink(self):
            super().unlink()  # the racing winner removes the segment...
            raise FileNotFoundError(self._name)  # ...and we observe the loss

    monkeypatch.setattr(shared_memory, "SharedMemory", _RacedShm)
    unlink_columns_shm(name)  # must swallow the race AND untrack
    assert [n.lstrip("/") for n in untracked] == [name]
    assert name not in _segments()
    unlink_columns_shm(name)  # and stays idempotent afterwards


def _crash_after_write(spec):
    """Stand-in pool entry simulating a writer that dies after creating its
    segment but before shipping the metadata back (the orphan hazard)."""
    from repro.core.records import write_columns_shm as _write

    cols = RecordColumns([0.0], [1.0], [0], [0], [False], [0])
    _write(spec.shm_name, cols, np.zeros(1), np.zeros(1, np.int64))
    raise RuntimeError("writer crashed before shipping metadata")


def test_writer_crash_before_merge_leaves_no_orphans(monkeypatch):
    """The driver names every segment up front and unlinks them all in its
    ``finally`` — a child crash between segment creation and metadata
    shipment must not orphan anything in /dev/shm."""
    from repro.core import shard as shard_mod

    monkeypatch.setattr(shard_mod, "_run_shard_shipped", _crash_after_write)
    before = _segments()
    driver = ShardedSimulator(2, 4, scheduler="hiku", seed=0, backend="process")
    with pytest.raises(RuntimeError, match="writer crashed"):
        driver.run(n_vus=4, duration_s=2.0)
    assert _segments() - before == set()
