"""Record-then-replay for learned admission policies: in-run snapshot
replay (``record_state`` -> ``replay_from``) reproduces every learned run
byte-for-byte including the re-recorded snapshots; scripted per-shard
replay (core.replay) reproduces recorded shards on all three execution
backends; cross-shard identity moves are refused loudly; and the frozen
seed engine (tests/legacy) stays byte-identical to a static run with the
estimator layer imported but idle."""

import dataclasses
import json
import warnings

import numpy as np
import pytest

from repro.core import SimConfig, Simulator, make_functions, make_scheduler
from repro.core.admission import AdmissionConfig, AdmissionSimulator
from repro.core.chaos import shard_kill_wave
from repro.core.replay import REPLAY_BACKENDS, replay_shards, scripts_from_run
from repro.core.workloads import make_scenario

pytestmark = pytest.mark.shard

FUNCS = make_functions(seed=0)
K, W, DUR = 2, 8, 12.0
LEARNED = ["sjf", "bandit", "bandit+steal"]


def _record(policy, *, policy_args=None, scenario="heavy_tail", n_vus=24,
            dur=DUR, faults=None, seed=0):
    """One recorded admission run; returns (adm, run, scenario)."""
    scn = make_scenario(scenario, FUNCS, n_vus, dur, seed=seed)
    if faults is not None:
        scn = dataclasses.replace(scn, faults=faults)
    adm = AdmissionSimulator(
        K, W, scheduler="hiku", cfg=SimConfig(mem_pool_mb=1024.0), seed=seed,
        admission=AdmissionConfig(
            policy=policy, steal_watermark=1.25, policy_args=policy_args,
        ),
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        run = adm.run(scn.n_vus, dur, **scn.run_kwargs())
    return adm, run, scn


# ------------------------------------------- in-run snapshot record/replay
@pytest.mark.parametrize("policy", LEARNED)
def test_record_then_replay_byte_identical(policy):
    """The headline contract: a learned run recorded with per-window state
    snapshots, replayed from those snapshots (through a JSON wire round
    trip), reproduces the record streams, assignment traces, admission
    tables AND the snapshots themselves byte-for-byte — proof the snapshot
    captures *all* decision-relevant learned state."""
    _, r, _ = _record(policy, policy_args={"record_state": True})
    assert r.policy_state, "run too short: no reward window ever closed"
    wire = json.loads(json.dumps(r.policy_state))
    assert wire == r.policy_state  # snapshots are JSON-wire bit-exact
    _, r2, _ = _record(
        policy, policy_args={"replay_from": wire, "record_state": True}
    )
    assert r2.records.equals(r.records)
    assert np.array_equal(r2.assign_t, r.assign_t)
    assert np.array_equal(r2.assign_w, r.assign_w)
    assert [s.admitted.tolist() for s in r2.shards] == [
        s.admitted.tolist() for s in r.shards
    ]
    assert [s.admit_t.tolist() for s in r2.shards] == [
        s.admit_t.tolist() for s in r.shards
    ]
    assert r2.policy_state == r.policy_state


def test_policy_state_absent_unless_recording():
    _, r, _ = _record("sjf")
    assert r.policy_state is None  # recording is strictly opt-in


def test_replay_runs_out_of_snapshots_fails_loudly():
    """A replay schedule shorter than the run's window count must raise,
    not silently fall back to live folding (which would silently fork the
    replayed timeline)."""
    _, r, _ = _record("sjf", policy_args={"record_state": True})
    assert len(r.policy_state) >= 2
    with pytest.raises(IndexError):
        _record("sjf", policy_args={"replay_from": r.policy_state[:1]})


# ------------------------------------------------- scripted shard replay
@pytest.fixture(scope="module")
def sjf_recording():
    adm, r, scn = _record("sjf")
    assert r.n_migrations == 0 and r.n_salvages == 0
    return adm, r, scn


@pytest.mark.parametrize("backend", REPLAY_BACKENDS)
def test_scripted_replay_matches_recorded_shards(backend, sjf_recording):
    """Each shard of a recorded learned run, re-executed from nothing but
    its admission schedule, reproduces its record stream and assignment
    trace byte-for-byte — on the serial, interleaved and process
    backends."""
    adm, r, scn = sjf_recording
    scripts = scripts_from_run(adm, r, scn.programs, DUR)
    assert len(scripts) == K
    results = replay_shards(scripts, backend=backend)
    assert [res.index for res in results] == list(range(K))
    for res, shard in zip(results, r.shards):
        assert len(res.records) > 0
        assert res.matches(shard), f"shard {res.index} diverged on {backend}"


def test_scripted_replay_carries_engine_local_faults():
    """Worker kills that do NOT kill a whole shard are engine-local: the
    fault schedule rides on the script and the replay still matches."""
    from repro.core.chaos import FaultEvent, FaultPlan

    plan = FaultPlan("one_worker", [FaultEvent(t=4.0, kind="fail", worker=0)])
    adm, r, scn = _record("sjf", scenario="on_off", faults=plan)
    assert r.n_salvages == 0  # 3 of 4 workers survive: no drain
    assert all(s.alive for s in r.shards)
    scripts = scripts_from_run(adm, r, scn.programs, DUR)
    assert scripts[0].failures == ((4.0, 0),)  # routed, shard-local id
    for res, shard in zip(replay_shards(scripts), r.shards):
        assert res.matches(shard)


def test_scripts_refuse_cross_shard_identity_moves():
    """Salvaged (or stolen) VUs carry their service identity across
    engines; per-shard scripting cannot replay that and must refuse."""
    plan = shard_kill_wave(K, W, shards=[0], t_kill=3.0)
    adm, r, scn = _record("pull", scenario="on_off", n_vus=32, dur=14.0,
                          faults=plan)
    assert r.n_salvages > 0, "the kill must actually trigger salvage"
    with pytest.raises(ValueError, match="cannot be replayed"):
        scripts_from_run(adm, r, scn.programs, 14.0)


def test_unknown_replay_backend_lists_available():
    with pytest.raises(ValueError, match="serial"):
        replay_shards([], backend="quantum")


# --------------------------------------- static byte-identity regression
def test_static_run_byte_identical_to_seed_engine_with_estimators_idle():
    """The frozen-seed-baseline contract extended to this PR: importing the
    estimator layer and holding an (idle) estimator changes nothing about a
    static run — byte-identical records and assignments vs tests/legacy."""
    from legacy import SimConfig as LegacySimConfig
    from legacy import Simulator as LegacySimulator
    from legacy import make_scheduler as legacy_make_scheduler

    from repro.core.estimators import DurationEstimator

    est = DurationEstimator()  # instantiated, never updated: pure bystander
    name, seed, n_workers, n_vus, dur = "hiku", 7, 5, 30, 40.0
    lsim = LegacySimulator(
        legacy_make_scheduler(name, n_workers, seed=seed),
        cfg=LegacySimConfig(n_workers=n_workers), seed=seed,
    )
    lrecs = lsim.run(n_vus=n_vus, duration_s=dur)
    sim = Simulator(
        make_scheduler(name, n_workers, seed=seed),
        cfg=SimConfig(n_workers=n_workers), seed=seed,
    )
    recs = sim.run(n_vus=n_vus, duration_s=dur)
    assert len(recs) == len(lrecs) > 0
    for x, y in zip(recs, lrecs):
        assert (x.t_submit, x.t_complete, x.func, x.worker, x.cold, x.vu) == (
            y.t_submit, y.t_complete, y.func, y.worker, y.cold, y.vu
        )
    assert list(sim.assignments) == list(lsim.assignments)
    assert est.total_updates == 0  # nothing ever fed it
