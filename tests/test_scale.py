"""Scale smoke tests: the simulator + Hiku at 100 workers / 500 VUs.

The hot-path refactor exists to make this class of run routine; these tests
pin the structural invariants (scheduler-view consistency, queue bookkeeping
through worker removal, worker memory accounting) at that scale."""

import pytest

from repro.core import SimConfig, Simulator, make_scheduler


@pytest.fixture(scope="module")
def scale_run():
    sched = make_scheduler("hiku", 100, seed=0)
    sim = Simulator(sched, cfg=SimConfig(n_workers=100), seed=0)
    sim.inject_failure(4.0, 17)
    sim.inject_failure(4.0, 18)
    sim.inject_worker(7.0, 120)
    recs = sim.run(n_vus=500, duration_s=12.0)
    return sched, sim, recs


def test_scale_run_completes_requests(scale_run):
    sched, sim, recs = scale_run
    assert len(recs) > 5000  # closed loop at 500 VUs must sustain throughput
    assert {r.vu for r in recs} == set(range(500))  # no VU starves or is lost
    assert sched.pull_hits > 0 and sched.fallback_assigns > 0


def test_scale_no_negative_connections(scale_run):
    sched, _, _ = scale_run
    assert all(c >= 0 for c in sched.conns.values())
    assert sched.total_conns == sum(sched.conns[w] for w in sched.workers)


def test_scale_queue_depth_consistent_after_worker_removal(scale_run):
    sched, sim, recs = scale_run
    # removed workers must be fully purged from every queue structure
    for dead in (17, 18):
        assert dead not in sched.workers
        assert all(dead not in counts for counts in sched.idle_counts.values())
        assert dead not in sched._worker_funcs or not sched._worker_funcs[dead]
        assert not any(r.worker == dead for r in recs if r.t_submit > 4.5)
    # elastic join picks up load
    assert any(r.worker == 120 for r in recs)
    # multiset totals == sum of counts, and depth telemetry agrees
    for func, counts in sched.idle_counts.items():
        assert all(n > 0 for n in counts.values())
        assert sched.queue_depth(func) == sum(counts.values())
    assert sched.queue_depth() == sum(
        sum(c.values()) for c in sched.idle_counts.values()
    )


def test_scale_worker_accounting(scale_run):
    _, sim, _ = scale_run
    for w in sim.workers.values():
        assert w.busy_mem_mb >= -1e-9 and w.idle_mem_mb >= -1e-9
        assert w.mem_usage() <= w.pool_mb + 1e-9
        # per-func idle lists stay ascending in last_used (LRU invariant)
        for lst in w.idle.values():
            assert all(a.last_used <= b.last_used for a, b in zip(lst, lst[1:]))
        assert w.idle_mem_mb == pytest.approx(
            sum(i.mem_mb for lst in w.idle.values() for i in lst)
        )


def test_scale_queue_entries_reference_live_workers(scale_run):
    sched, _, _ = scale_run
    live = set(sched.workers)
    for counts in sched.idle_counts.values():
        assert set(counts) <= live
