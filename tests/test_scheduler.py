"""Scheduler semantics: Algorithm 1, baselines, and the JAX formulation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    ARRIVAL,
    EVICT,
    FINISH,
    HikuScheduler,
    available_schedulers,
    init_state,
    make_scheduler,
    sched_many,
    sched_step,
)


def test_registry_has_paper_baselines():
    have = set(available_schedulers())
    assert {"hiku", "ch", "ch_bl", "rj_ch", "least_connections", "random"} <= have


class _FirstChoice:
    """Deterministic stand-in for random.Random: always pick first/lowest."""

    def choice(self, xs):
        return min(xs)


def test_hiku_algorithm1_semantics():
    s = HikuScheduler(3, seed=0)
    s.rng = _FirstChoice()
    # no idle instances -> fallback least-connections (all zero -> worker 0)
    w = s.schedule("f1")
    assert w == 0 and s.conns[0] == 1
    # finish -> pull enqueue into PQ_f1
    s.on_finish(0, "f1")
    assert s.queue_depth("f1") == 1
    # next request for f1 MUST be pulled from the queue (warm)
    w = s.schedule("f1")
    assert w == 0 and s.queue_depth("f1") == 0
    # requests for other functions do not touch PQ_f1 (fallback path instead)
    s.on_finish(0, "f1")
    w2 = s.schedule("f2")
    assert s.queue_depth("f1") == 1  # PQ_f1 untouched by the f2 request
    assert w2 == 0  # LC tie-break: deterministic stub picks lowest index


def test_hiku_dequeues_least_loaded():
    s = HikuScheduler(3, seed=0)
    s.rng = _FirstChoice()
    # enqueue workers 1 and 2 with different loads
    s.conns = {0: 0, 1: 5, 2: 2}
    s.idle_queues["f"] = [1, 2]
    w = s.schedule("f")
    assert w == 2  # least-loaded enqueued worker, NOT global least-loaded (0)


def test_hiku_eviction_notification():
    s = HikuScheduler(2, seed=0)
    s.on_finish(1, "f")
    s.on_finish(1, "f")
    assert s.queue_depth("f") == 2
    s.on_evict(1, "f")  # removes FIRST occurrence only (Algorithm 1 l.19)
    assert s.queue_depth("f") == 1


def test_hiku_worker_removal_purges_queues():
    s = HikuScheduler(3, seed=0)
    s.on_finish(2, "a")
    s.on_finish(2, "b")
    s.on_worker_removed(2)
    assert s.queue_depth() == 0
    assert all(s.schedule(f) != 2 for f in ("a", "b", "c"))


def test_ch_locality_and_stability():
    s = make_scheduler("ch", 5, seed=1)
    w1 = [s.select("func-x") for _ in range(10)]
    assert len(set(w1)) == 1  # perfect locality
    # removing an unrelated worker must not remap func-x (consistency)
    target = w1[0]
    other = (target + 1) % 5
    s.on_worker_removed(other)
    assert s.select("func-x") == target


def test_chbl_respects_bound():
    s = make_scheduler("ch_bl", 4, seed=0, threshold=1.25)
    target = s.ring.lookup("hot")
    s.conns = {w: 0 for w in s.workers}
    s.conns[target] = 10  # overloaded far beyond bound
    w = s.select("hot")
    assert w != target  # spills to next non-overloaded clockwise


# ------------------------------------------------- python <-> jax equivalence
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n_events=st.integers(1, 60),
       F=st.integers(1, 5), W=st.integers(1, 6))
def test_jax_sched_equivalent_to_python(seed, n_events, F, W):
    """Deterministic-tie-break JIQ: array formulation == Algorithm 1 object."""
    rng = np.random.default_rng(seed)
    py = HikuScheduler(W, seed=0)
    py.rng = _FirstChoice()
    state = init_state(F, W)
    events = []
    running = []  # (worker, func) active
    for _ in range(n_events):
        kind = rng.choice([ARRIVAL, FINISH]) if running else ARRIVAL
        if kind == ARRIVAL:
            f = int(rng.integers(0, F))
            events.append((ARRIVAL, f, -1))
        else:
            w, f = running.pop(int(rng.integers(0, len(running))))
            events.append((FINISH, f, w))
        # drive python scheduler
        k, f, w = events[-1]
        if k == ARRIVAL:
            wpy = py.schedule(str(f))
            running.append((wpy, f))
            events[-1] = (ARRIVAL, f, -1, wpy)  # remember for the check
        else:
            py.on_finish(w, str(f))
            events[-1] = (FINISH, f, w, -1)
    ev_arr = jnp.array([(k, f, w) for (k, f, w, _) in events], jnp.int32)
    state, (ws, warm) = sched_many(state, ev_arr, key=None)
    for i, (k, f, w, wpy) in enumerate(events):
        if k == ARRIVAL:
            assert int(ws[i]) == wpy, f"event {i}: jax={int(ws[i])} py={wpy}"
    # final connection counts agree
    np.testing.assert_array_equal(
        np.asarray(state.conns), np.array([py.conns[w] for w in range(W)])
    )


def test_jax_sched_evict():
    state = init_state(2, 3)
    ev = jnp.array([
        (ARRIVAL, 0, -1),  # cold -> worker 0
        (FINISH, 0, 0),    # enqueue PQ_0 <- w0
        (EVICT, 0, 0),     # notification removes it
        (ARRIVAL, 0, -1),  # must be cold again
    ], jnp.int32)
    state, (ws, warm) = sched_many(state, ev)
    assert not bool(warm[3])
    assert int(state.idle.sum()) == 0


def test_jax_sched_random_tiebreak_uniform():
    """Fallback random tie-break covers tied workers (Algorithm 1 l.10)."""
    state = init_state(1, 4)
    ev = jnp.array([(ARRIVAL, 0, -1)], jnp.int32)
    picks = set()
    for i in range(40):
        _, (w, _) = sched_many(state, ev, key=jax.random.key(i))
        picks.add(int(w[0]))
    assert picks == {0, 1, 2, 3}
