"""Scheduler semantics: Algorithm 1, baselines, and the JAX formulation.

The hypothesis-based python<->jax equivalence property test lives in
test_scheduler_properties.py so this module runs without hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ARRIVAL,
    EVICT,
    FINISH,
    HikuScheduler,
    available_schedulers,
    init_state,
    make_scheduler,
    sched_many,
    sched_step,
)


def test_registry_has_paper_baselines():
    have = set(available_schedulers())
    assert {"hiku", "ch", "ch_bl", "rj_ch", "least_connections", "random"} <= have


class _FirstChoice:
    """Deterministic stand-in for random.Random: always pick first/lowest."""

    def choice(self, xs):
        return min(xs)

    def randrange(self, n):
        return 0  # tie index 0 == lowest tied worker id


def test_hiku_algorithm1_semantics():
    s = HikuScheduler(3, seed=0)
    s.rng = _FirstChoice()
    # no idle instances -> fallback least-connections (all zero -> worker 0)
    w = s.schedule("f1")
    assert w == 0 and s.conns[0] == 1
    # finish -> pull enqueue into PQ_f1
    s.on_finish(0, "f1")
    assert s.queue_depth("f1") == 1
    # next request for f1 MUST be pulled from the queue (warm)
    w = s.schedule("f1")
    assert w == 0 and s.queue_depth("f1") == 0
    # requests for other functions do not touch PQ_f1 (fallback path instead)
    s.on_finish(0, "f1")
    w2 = s.schedule("f2")
    assert s.queue_depth("f1") == 1  # PQ_f1 untouched by the f2 request
    assert w2 == 0  # LC tie-break: deterministic stub picks lowest index


def test_hiku_dequeues_least_loaded():
    s = HikuScheduler(3, seed=0)
    s.rng = _FirstChoice()
    # enqueue workers 1 and 2 with different loads (pull signals decrement
    # the connection count, so pre-load one extra connection each)
    for w, c in ((1, 6), (2, 3)):
        for _ in range(c):
            s.on_assign(w, "f")
    s.on_finish(1, "f")  # conns: {0: 0, 1: 5, 2: 3}; PQ_f = {1}
    s.on_finish(2, "f")  # conns: {0: 0, 1: 5, 2: 2}; PQ_f = {1, 2}
    w = s.schedule("f")
    assert w == 2  # least-loaded enqueued worker, NOT global least-loaded (0)


def test_hiku_eviction_notification():
    s = HikuScheduler(2, seed=0)
    s.on_finish(1, "f")
    s.on_finish(1, "f")
    assert s.queue_depth("f") == 2
    s.on_evict(1, "f")  # removes FIRST occurrence only (Algorithm 1 l.19)
    assert s.queue_depth("f") == 1


def test_hiku_worker_removal_purges_queues():
    s = HikuScheduler(3, seed=0)
    s.on_finish(2, "a")
    s.on_finish(2, "b")
    s.on_worker_removed(2)
    assert s.queue_depth() == 0
    assert all(s.schedule(f) != 2 for f in ("a", "b", "c"))


def test_ch_locality_and_stability():
    s = make_scheduler("ch", 5, seed=1)
    w1 = [s.select("func-x") for _ in range(10)]
    assert len(set(w1)) == 1  # perfect locality
    # removing an unrelated worker must not remap func-x (consistency)
    target = w1[0]
    other = (target + 1) % 5
    s.on_worker_removed(other)
    assert s.select("func-x") == target


def test_chbl_respects_bound():
    s = make_scheduler("ch_bl", 4, seed=0, threshold=1.25)
    target = s.ring.lookup("hot")
    s.conns = {w: 0 for w in s.workers}
    s.conns[target] = 10  # overloaded far beyond bound
    w = s.select("hot")
    assert w != target  # spills to next non-overloaded clockwise


def test_jax_sched_evict():
    state = init_state(2, 3)
    ev = jnp.array([
        (ARRIVAL, 0, -1),  # cold -> worker 0
        (FINISH, 0, 0),    # enqueue PQ_0 <- w0
        (EVICT, 0, 0),     # notification removes it
        (ARRIVAL, 0, -1),  # must be cold again
    ], jnp.int32)
    state, (ws, warm) = sched_many(state, ev)
    assert not bool(warm[3])
    assert int(state.idle.sum()) == 0


def test_sched_many_fused_matches_scan():
    """Chunked fused dispatch (interpret mode) == event-by-event scan."""
    from repro.core import sched_many_fused

    rng = np.random.default_rng(5)
    state = init_state(6, 9)
    events = []
    for _ in range(150):
        k = int(rng.integers(0, 3))
        events.append((k, int(rng.integers(0, 6)), -1 if k == ARRIVAL else int(rng.integers(0, 9))))
    ev = jnp.array(events, jnp.int32)
    s1, (ws1, warm1) = sched_many(state, ev)
    s2, (ws2, warm2) = sched_many_fused(state, ev, chunk=64, interpret=True)
    assert jnp.all(ws1 == ws2) and jnp.all(warm1 == warm2)
    assert jnp.all(s1.idle == s2.idle) and jnp.all(s1.conns == s2.conns)
    # off-TPU default silently falls back to the scan path
    s3, (ws3, _) = sched_many_fused(state, ev)
    assert jnp.all(ws1 == ws3) and jnp.all(s1.conns == s3.conns)


def _mixed_events(rng, n, n_funcs=6, n_workers=9):
    events = []
    for _ in range(n):
        k = int(rng.integers(0, 3))
        events.append(
            (k, int(rng.integers(0, n_funcs)),
             -1 if k == ARRIVAL else int(rng.integers(0, n_workers)))
        )
    return jnp.array(events, jnp.int32)


def test_sched_many_adaptive_matches_scan_across_chunk_switches():
    """Burst-adaptive dispatch == event-by-event scan, bitwise, while the
    detector actually switches chunk sizes mid-stream (the density samples
    drive it from single-event stepping into fused chunks and back)."""
    from repro.core import BurstDetector, sched_many_adaptive

    det = BurstDetector(alpha=1.0, thresholds=((100.0, 64),), base_chunk=1)
    ev = _mixed_events(np.random.default_rng(7), 300)
    # windows 0,3 step one event at a time; windows 1,2 fuse with chunk=64
    densities = [0.0, 500.0, 500.0, 0.0]
    s1, (ws1, warm1) = sched_many(init_state(6, 9), ev)
    s2, (ws2, warm2) = sched_many_adaptive(
        init_state(6, 9), ev, det, densities=densities, segment=80,
        interpret=True,
    )
    assert det.chunk == 1  # the quiet tail pulled the EWMA back down
    assert jnp.all(ws1 == ws2) and jnp.all(warm1 == warm2)
    assert jnp.all(s1.idle == s2.idle) and jnp.all(s1.conns == s2.conns)


def test_sched_many_adaptive_default_density_and_edges():
    """Without explicit samples the window's own event count drives the
    detector; ragged tails, empty streams and the PRNG-key fallback all
    reduce to the scan path's results."""
    from repro.core import BurstDetector, sched_many_adaptive

    ev = _mixed_events(np.random.default_rng(11), 130)
    det = BurstDetector(alpha=1.0, thresholds=((64.0, 32),), base_chunk=1)
    s1, (ws1, warm1) = sched_many(init_state(6, 9), ev)
    s2, (ws2, warm2) = sched_many_adaptive(
        init_state(6, 9), ev, det, segment=64, interpret=True
    )
    assert jnp.all(ws1 == ws2) and jnp.all(warm1 == warm2)
    assert jnp.all(s1.conns == s2.conns)
    # empty stream: no windows, empty outputs, untouched state
    det2 = BurstDetector()
    s3, (ws3, warm3) = sched_many_adaptive(
        init_state(2, 2), jnp.zeros((0, 3), jnp.int32), det2
    )
    assert ws3.shape == (0,) and warm3.shape == (0,)
    assert int(s3.idle.sum()) == 0 and det2.ewma == 0.0
    # randomized tie-breaks route through the scan path unchanged
    key = jax.random.key(3)
    sa, (wa, _) = sched_many(init_state(6, 9), ev, key=key)
    sb, (wb, _) = sched_many_adaptive(init_state(6, 9), ev, det, key=key)
    assert jnp.all(wa == wb) and jnp.all(sa.conns == sb.conns)
    # density samples must cover every window
    import pytest

    with pytest.raises(ValueError):
        sched_many_adaptive(init_state(6, 9), ev, det, densities=[1.0], segment=64)


def test_burst_detector_thresholds_and_hysteresis():
    from repro.core import BurstDetector

    det = BurstDetector(
        alpha=0.5, thresholds=((1000.0, 1024), (100.0, 128)), base_chunk=1
    )
    assert det.observe(2000.0) == 1024  # first sample primes the EWMA
    assert det.observe(0.0) == 1024  # one quiet window: smoothed to 1000
    assert det.observe(0.0) == 128  # decays through the lower band (500)
    assert det.observe(0.0) == 128  # 250 still above 100
    assert det.observe(0.0) == 128  # 125 still above 100
    assert det.observe(0.0) == 1  # 62.5: below every threshold, base chunk
    import pytest

    with pytest.raises(ValueError):
        BurstDetector(alpha=0.0)
    with pytest.raises(ValueError):
        BurstDetector(thresholds=((10.0, 16), (20.0, 32)))  # not descending
    with pytest.raises(ValueError):
        BurstDetector(base_chunk=0)


def test_least_connections_tracker_matches_ref_live(monkeypatch):
    """The bitmap-tracker fallback equals the full-scan reference on every
    call of a live run — same worker picked, same randomness consumed —
    including across a worker failure and rejoin (tracker drop/add)."""
    from repro.core import SimConfig, Simulator
    from repro.core.scheduler import Scheduler

    calls = []
    orig = Scheduler._least_connections

    def checked(self):
        before = self.rng.getstate()
        w = orig(self)
        after = self.rng.getstate()
        self.rng.setstate(before)
        assert Scheduler._least_connections_ref(self) == w
        assert self.rng.getstate() == after  # identical RNG consumption
        calls.append(w)
        return w

    monkeypatch.setattr(Scheduler, "_least_connections", checked)
    for name in ("hiku", "least_connections"):
        sim = Simulator(
            make_scheduler(name, 40, seed=3), cfg=SimConfig(n_workers=40), seed=3
        )
        sim.inject_failure(3.0, 7)
        sim.inject_worker(9.0, 7)
        sim.run(n_vus=120, duration_s=30.0)
    assert len(calls) > 50  # the fallback path was actually exercised


def test_jax_sched_random_tiebreak_uniform():
    """Fallback random tie-break covers tied workers (Algorithm 1 l.10)."""
    state = init_state(1, 4)
    ev = jnp.array([(ARRIVAL, 0, -1)], jnp.int32)
    picks = set()
    for i in range(40):
        _, (w, _) = sched_many(state, ev, key=jax.random.key(i))
        picks.add(int(w[0]))
    assert picks == {0, 1, 2, 3}
