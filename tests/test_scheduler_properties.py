"""Hypothesis property test: Algorithm-1 object == JAX array formulation.

Separate from test_scheduler.py so the deterministic scheduler tests still
run on environments without hypothesis (this module is skipped there)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip only the property tests
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import ARRIVAL, FINISH, HikuScheduler, init_state, sched_many  # noqa: E402


class _FirstChoice:
    """Deterministic stand-in for random.Random: always pick first/lowest."""

    def choice(self, xs):
        return min(xs)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n_events=st.integers(1, 60),
       F=st.integers(1, 5), W=st.integers(1, 6))
def test_jax_sched_equivalent_to_python(seed, n_events, F, W):
    """Deterministic-tie-break JIQ: array formulation == Algorithm 1 object."""
    rng = np.random.default_rng(seed)
    py = HikuScheduler(W, seed=0)
    py.rng = _FirstChoice()
    state = init_state(F, W)
    events = []
    running = []  # (worker, func) active
    for _ in range(n_events):
        kind = rng.choice([ARRIVAL, FINISH]) if running else ARRIVAL
        if kind == ARRIVAL:
            f = int(rng.integers(0, F))
            events.append((ARRIVAL, f, -1))
        else:
            w, f = running.pop(int(rng.integers(0, len(running))))
            events.append((FINISH, f, w))
        # drive python scheduler
        k, f, w = events[-1]
        if k == ARRIVAL:
            wpy = py.schedule(str(f))
            running.append((wpy, f))
            events[-1] = (ARRIVAL, f, -1, wpy)  # remember for the check
        else:
            py.on_finish(w, str(f))
            events[-1] = (FINISH, f, w, -1)
    ev_arr = jnp.array([(k, f, w) for (k, f, w, _) in events], jnp.int32)
    state, (ws, warm) = sched_many(state, ev_arr, key=None)
    for i, (k, f, w, wpy) in enumerate(events):
        if k == ARRIVAL:
            assert int(ws[i]) == wpy, f"event {i}: jax={int(ws[i])} py={wpy}"
    # final connection counts agree
    np.testing.assert_array_equal(
        np.asarray(state.conns), np.array([py.conns[w] for w in range(W)])
    )
