"""Serving engine on real JAX models: lifecycle, cold/warm, eviction
notifications, failures — the control plane of Figure 1/2."""

import dataclasses

import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.serving import Endpoint, ServingEngine


def _tiny_endpoint(name, seed=0):
    cfg = get_config("mamba2_130m").reduced()
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=32, vocab=64,
                              ssm=dataclasses.replace(cfg.ssm, d_state=8, headdim=8))
    return Endpoint(name=name, cfg=cfg, seed=seed, max_cache_len=32)


@pytest.fixture(scope="module")
def engine():
    eps = [_tiny_endpoint(f"f{i}", seed=i) for i in range(3)]
    return ServingEngine(eps, n_workers=2, scheduler="hiku", keep_alive_s=600.0)


def test_cold_then_warm(engine):
    r1 = engine.submit("f0")
    r2 = engine.submit("f0")
    assert r1.cold and not r2.cold
    # cold start must be measurably slower (compile + init) — Table I effect
    assert r1.latency_ms > 1.5 * r2.latency_ms


def test_pull_locality(engine):
    """Repeated requests for one function stick to the warm worker."""
    first = engine.submit("f1")
    workers = {engine.submit("f1").worker for _ in range(4)}
    assert workers == {first.worker}
    assert all(not engine.records[-i].cold for i in range(1, 5))


def test_scheduler_overhead_negligible(engine):
    """Paper §V-B: decision overhead ~0.015 ms; ours must stay sub-ms."""
    s = engine.summary()
    assert s["sched_overhead_ms"] < 1.0


def test_worker_failure_reroutes(engine):
    r = engine.submit("f2")
    dead = r.worker
    engine.fail_worker(dead)
    r2 = engine.submit("f2")
    assert r2.worker != dead
    assert r2.cold  # instance was lost with the worker
    engine.add_worker(dead)  # restore for other tests


def test_eviction_notifies_scheduler():
    eps = [_tiny_endpoint(f"g{i}", seed=i) for i in range(4)]
    # pool sized to hold ~1 instance -> every new function evicts the previous
    small = eps[0].est_bytes() + eps[1].est_bytes() // 2
    eng = ServingEngine(eps, n_workers=1, scheduler="hiku", mem_pool_bytes=small)
    eng.submit("g0")
    assert eng.sched.queue_depth("g0") == 1
    eng.submit("g1")  # forces LRU eviction of g0's instance
    assert eng.sched.queue_depth("g0") == 0  # notification removed it
    r = eng.submit("g0")
    assert r.cold
