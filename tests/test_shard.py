"""Sharded multi-cluster driver: partition/seed contracts, backend
equivalence, merge semantics, per-shard exactness, failure routing."""

import dataclasses

import numpy as np
import pytest

from repro.core import SimConfig, Simulator, make_scheduler
from repro.core.shard import (
    SEED_STRIDE,
    ShardedSimulator,
    build_simulator,
    run_shard,
    shard_seed,
    split_even,
)

pytestmark = pytest.mark.shard


def test_split_even_contract():
    for total, parts in [(10, 3), (8, 8), (1600, 7), (5, 5), (9, 2)]:
        sizes = split_even(total, parts)
        assert sum(sizes) == total and len(sizes) == parts
        assert max(sizes) - min(sizes) <= 1
        assert sizes == sorted(sizes, reverse=True)  # remainder goes first


def test_shard_seed_contract():
    assert shard_seed(7, 0) == 7
    assert shard_seed(7, 3) == (7 + 3 * SEED_STRIDE) % 2**32
    seeds = {shard_seed(0, k) for k in range(64)}
    assert len(seeds) == 64  # distinct per shard
    assert all(0 <= s < 2**32 for s in seeds)  # stays in fast-RNG entropy range


def test_plan_partitions_and_offsets():
    driver = ShardedSimulator(3, 10, scheduler="hiku", seed=9)
    specs = driver.plan(n_vus=11, duration_s=7.0)
    assert [s.cfg.n_workers for s in specs] == [4, 3, 3]
    assert [s.worker_offset for s in specs] == [0, 4, 7]
    assert [s.n_vus for s in specs] == [4, 4, 3]
    assert [s.vu_offset for s in specs] == [0, 4, 8]
    assert [s.seed for s in specs] == [shard_seed(9, k) for k in range(3)]
    assert all(s.duration_s == 7.0 for s in specs)


@pytest.mark.parametrize("backend", ["interleaved", "process"])
def test_backends_identical_to_serial(backend):
    def run(b):
        return ShardedSimulator(3, 9, scheduler="hiku", seed=5, backend=b).run(
            n_vus=18, duration_s=15.0
        )

    base, other = run("serial"), run(backend)
    assert len(base.records) > 0
    for r1, r2 in zip(base.shards, other.shards):
        assert r1.records.equals(r2.records)
        assert np.array_equal(r1.assign_t, r2.assign_t)
        assert np.array_equal(r1.assign_w, r2.assign_w)
        assert r1.n_events == r2.n_events
    assert base.records.equals(other.records)
    assert np.array_equal(base.assign_t, other.assign_t)
    assert np.array_equal(base.assign_w, other.assign_w)


def test_process_transport_fallback_matches_shm(monkeypatch):
    """``REPRO_SHARD_TRANSPORT=pickle`` ships the same bytes the shared-
    memory segments do — the transport is invisible to every consumer."""

    def run():
        return ShardedSimulator(
            2, 6, scheduler="hiku", seed=7, backend="process"
        ).run(n_vus=10, duration_s=10.0)

    via_shm = run()
    monkeypatch.setenv("REPRO_SHARD_TRANSPORT", "pickle")
    via_pickle = run()
    assert len(via_shm.records) > 0
    assert via_shm.records.equals(via_pickle.records)
    assert np.array_equal(via_shm.assign_t, via_pickle.assign_t)
    assert np.array_equal(via_shm.assign_w, via_pickle.assign_w)
    assert via_shm.n_events == via_pickle.n_events
    for r1, r2 in zip(via_shm.shards, via_pickle.shards):
        assert r1.spec == r2.spec  # and the caller-visible spec carries
        assert r1.spec.shm_name is None  # no transport detail either way
        assert (r1.resubmits, r1.lost_tasks) == (r2.resubmits, r2.lost_tasks)


def test_process_backend_teardown_is_deterministic():
    """Two back-to-back process-backend runs in one interpreter leave no
    shared-memory segments behind — teardown is explicit close/unlink in
    the driver, not interpreter-exit garbage collection."""
    import os

    from repro.core.shard import SHM_PREFIX

    def segments():
        if not os.path.isdir("/dev/shm"):
            return set()
        return {f for f in os.listdir("/dev/shm") if f.startswith(SHM_PREFIX)}

    before = segments()
    for seed in (1, 2):
        merged = ShardedSimulator(
            2, 6, scheduler="hiku", seed=seed, backend="process"
        ).run(n_vus=8, duration_s=8.0)
        assert len(merged.records) > 0
        assert segments() - before == set()  # clean between runs, not just after


def test_shard_stream_equals_standalone_simulator():
    """A shard's stream is byte-identical to a monolithic run of its slice."""
    driver = ShardedSimulator(2, 8, scheduler="least_connections", seed=4,
                              backend="interleaved")
    merged = driver.run(n_vus=14, duration_s=12.0)
    for res in merged.shards:
        spec = res.spec
        sched = make_scheduler(spec.scheduler, spec.cfg.n_workers, seed=spec.seed)
        solo = Simulator(sched, cfg=spec.cfg, seed=spec.seed)
        solo.run(n_vus=spec.n_vus, duration_s=spec.duration_s)
        assert res.records.equals(solo.record_columns)
        at, aw = solo.assignment_columns
        assert np.array_equal(res.assign_t, at)
        assert np.array_equal(res.assign_w, aw)


def test_merge_remaps_to_disjoint_global_ids():
    driver = ShardedSimulator(3, 9, scheduler="hiku", seed=2, backend="serial")
    merged = driver.run(n_vus=18, duration_s=15.0)
    assert len(merged.records) == sum(len(r.records) for r in merged.shards)
    assert merged.workers == list(range(9))
    # each record's global worker/vu id falls inside its shard's range
    for res in merged.shards:
        lo, hi = res.spec.worker_offset, res.spec.worker_offset + res.spec.cfg.n_workers
        w = res.records.worker
        assert ((w >= 0) & (w < res.spec.cfg.n_workers)).all()  # local ids
        vlo = res.spec.vu_offset
        assert ((res.records.vu >= 0) & (res.records.vu < res.spec.n_vus)).all()
        del lo, hi, vlo
    g = merged.records
    assert g.worker.min() >= 0 and g.worker.max() < 9
    assert g.vu.min() >= 0 and g.vu.max() < 18
    # merged stream is completion-ordered like a monolithic engine's
    assert (np.diff(g.t_done) >= 0).all()
    assert (np.diff(merged.assign_t) >= 0).all()


def test_merged_vu_populations_disjoint():
    driver = ShardedSimulator(2, 6, scheduler="hiku", seed=1, backend="serial")
    merged = driver.run(n_vus=10, duration_s=12.0)
    vu_sets = [
        set((res.records.vu + res.spec.vu_offset).tolist()) for res in merged.shards
    ]
    assert vu_sets[0].isdisjoint(vu_sets[1])


def test_failure_injection_routes_to_owning_shard():
    driver = ShardedSimulator(2, 10, scheduler="hiku", seed=6, backend="serial")
    driver.inject_failure(5.0, 7)  # global worker 7 -> shard 1, local 2
    specs = driver.plan(n_vus=12, duration_s=20.0)
    assert specs[0].failures == () and specs[1].failures == ((5.0, 2),)
    merged = driver.run(n_vus=12, duration_s=20.0)
    late = merged.records[merged.records.t_submit > 10.0]
    assert len(late) and 7 not in set(late.worker.tolist())


def test_rejoin_after_failure_stays_in_shard_span():
    driver = ShardedSimulator(2, 10, scheduler="hiku", seed=6, backend="serial")
    driver.inject_failure(4.0, 7)
    driver.inject_worker(8.0, 7)  # re-join of failed global worker 7
    specs = driver.plan(n_vus=12, duration_s=25.0)
    assert specs[1].failures == ((4.0, 2),) and specs[1].additions == ((8.0, 2),)
    merged = driver.run(n_vus=12, duration_s=25.0)
    late = merged.records[merged.records.t_submit > 12.0]
    assert len(late) and 7 in set(late.worker.tolist())  # global id 7 is back
    # additions beyond the static partition would collide with another
    # shard's global id range after the merge remap: rejected up front
    with pytest.raises(ValueError):
        driver.inject_worker(8.0, 10)


def test_inject_worker_legacy_shard_form_removed():
    """The deprecated ``inject_worker(t, local_id, shard=k)`` form is gone:
    the unified global-id signature rejects a ``shard`` keyword outright,
    and the global form maps onto the same (shard, local) pair the legacy
    form used to produce."""
    driver = ShardedSimulator(2, 10, scheduler="hiku", seed=6, backend="serial")
    driver.inject_failure(4.0, 7)  # global 7 -> shard 1, local 2
    driver.inject_worker(8.0, 7)
    with pytest.raises(TypeError):
        driver.inject_worker(8.0, 2, shard=1)
    su = driver.plan(12, 25.0)
    assert su[1].failures == ((4.0, 2),) and su[1].additions == ((8.0, 2),)


def test_shard_of_worker_bounds():
    driver = ShardedSimulator(2, 10, scheduler="hiku", seed=0)
    assert driver.shard_of_worker(0) == (0, 0)
    assert driver.shard_of_worker(9) == (1, 4)
    with pytest.raises(ValueError):
        driver.shard_of_worker(10)


def test_run_shard_is_picklable_roundtrip():
    import pickle

    driver = ShardedSimulator(2, 6, scheduler="hiku", seed=8)
    spec = driver.plan(8, 10.0)[1]
    spec2 = pickle.loads(pickle.dumps(spec))
    assert spec2 == spec
    res = run_shard(spec)
    res2 = pickle.loads(pickle.dumps(res))
    assert res2.records.equals(res.records)
    assert res2.n_events == res.n_events


def test_merged_summarize_matches_direct_metrics():
    from repro.core import summarize

    driver = ShardedSimulator(2, 6, scheduler="hiku", seed=3, backend="serial")
    merged = driver.run(n_vus=10, duration_s=15.0)
    m = merged.summarize(15.0)
    direct = summarize(
        merged.records, (merged.assign_t, merged.assign_w), merged.workers, 15.0
    )
    assert m == direct
    assert m.n_requests == len(merged.records)


def test_constructor_validation():
    with pytest.raises(ValueError):
        ShardedSimulator(0, 4)
    with pytest.raises(ValueError):
        ShardedSimulator(5, 4)
    with pytest.raises(ValueError):
        ShardedSimulator(2, 4, backend="threads")


def test_cfg_template_propagates_to_shards():
    cfg = SimConfig(mem_pool_mb=1234.0, keep_alive_s=7.0)
    driver = ShardedSimulator(2, 6, scheduler="hiku", cfg=cfg, seed=0)
    for spec in driver.plan(4, 5.0):
        assert spec.cfg.mem_pool_mb == 1234.0
        assert spec.cfg.keep_alive_s == 7.0
        assert spec.cfg.n_workers == 3
    assert cfg.n_workers == 5  # template untouched


def test_build_simulator_applies_spec(monkeypatch):
    driver = ShardedSimulator(2, 6, scheduler="random", seed=12)
    driver.inject_failure(2.0, 4)
    spec = driver.plan(6, 8.0)[1]
    sim = build_simulator(spec)
    assert sim.seed == spec.seed
    assert sim.cfg == spec.cfg
    assert sim.sched.name == "random"
    assert sim._failures == [(2.0, 1)]
