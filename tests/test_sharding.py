"""Sharding rules resolution, plan selection, and a reduced-mesh dry-run CI
(subprocess with its own XLA device count, as dryrun.py requires)."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).parent.parent


def test_resolve_divisibility_guard():
    import jax
    from repro.sharding.ctx import _resolve
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    rules = {"batch": ("data",), "heads": ("model",)}
    # dims that don't divide -> axis dropped, never an error
    spec = _resolve(("batch", None, "heads"), rules, mesh, (7, 3, 5))
    assert all(s is None or True for s in spec)


def test_auto_plan_selection():
    from repro.configs import get_config
    from repro.sharding.rules import auto_plan

    # small model: plain TP; big model train: FSDP
    p1 = auto_plan(get_config("gemma3_4b"), "train", n_model=16)
    assert "fsdp" not in p1.name
    p2 = auto_plan(get_config("command_r_plus_104b"), "train", n_model=16)
    assert "fsdp" in p2.name
    # long-context decode at B=1: sequence sharding
    p3 = auto_plan(get_config("mamba2_130m"), "decode", n_model=16, batch=1)
    assert "seqshard" in p3.name
    # opt level turns on the hillclimb levers
    p4 = auto_plan(get_config("deepseek_v3_671b"), "train", n_model=16, level="opt")
    assert p4.moe_mode == "capacity"
    p5 = auto_plan(get_config("deepseek_v3_671b"), "decode", n_model=16, level="opt")
    assert p5.moe_mode == "resident" and p5.activation_rules["batch"] == ()


def test_param_shardings_tree():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.specs import abstract_params
    from repro.models import build_model
    from repro.sharding.rules import make_plan, param_shardings

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    model = build_model(get_config("gemma3_4b").reduced())
    sds, axes = abstract_params(model)
    sh = param_shardings(mesh, make_plan("tp"), axes, sds)
    flat = jax.tree.leaves(sh)
    assert flat and all(hasattr(s, "spec") for s in flat)


@pytest.mark.slow
def test_dryrun_reduced_mesh_subprocess():
    """The dry-run driver must pass on a CI-scale mesh for a fast arch."""
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="16",
               PYTHONPATH=str(ROOT / "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-130m", "--shape", "decode_32k", "--mesh", "single",
         "--mesh-shape", "4x4", "--out", "/tmp/dryrun_pytest", "--force"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=500,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    d = json.loads(Path("/tmp/dryrun_pytest/mamba2_130m__decode_32k__single.json").read_text())
    assert d["roofline"]["compute_s"] > 0
    assert d["cost_analysis"]["flops"] > 0


def test_full_sweep_results_complete():
    """All 40 cells x 2 meshes are present in the committed dry-run results."""
    from repro.configs import ARCH_IDS, SHAPES, get_config

    d = ROOT / "benchmarks" / "results" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run results not generated yet")
    missing, failed = [], []
    for mesh in ("single", "multi"):
        for arch in ARCH_IDS:
            for shape in SHAPES:
                p = d / f"{arch}__{shape}__{mesh}.json"
                if not p.exists():
                    missing.append(p.name)
                    continue
                cell = json.loads(p.read_text())
                if cell.get("skipped"):
                    assert not get_config(arch).sub_quadratic
                elif "roofline" not in cell:
                    failed.append(p.name)
    assert not missing, f"missing cells: {missing[:5]}..."
    assert not failed
